"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + properties.

Kernels run in interpret mode on CPU (same body, Python evaluation) — this
is the validation the container supports; Mosaic compilation happens on a
real TPU backend.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dependency (pyproject [dev]); shim sweeps
    from _hypothesis_shim import given, settings, st

from repro.core import svgp
from repro.gp import make_covariance
from repro.kernels import ops, ref


def _inputs(key, B, m, d, dtype=jnp.float32):
    kx, kz, kl, kv = jax.random.split(key, 4)
    x = jax.random.normal(kx, (B, d), dtype)
    z = jax.random.normal(kz, (m, d), dtype)
    lls = (0.4 * jax.random.normal(kl, (d,))).astype(dtype)
    lv = (0.2 * jax.random.normal(kv, ())).astype(dtype)
    return x, z, lls, lv


# ---- shape sweep: unaligned and aligned, tiny paper-scale and MXU-scale ----
SHAPES = [
    (8, 5, 2),     # paper's m=5 E3SM setting
    (32, 10, 2),   # paper's m=10
    (100, 20, 3),  # paper's m=20, odd batch, 3-d inputs
    (128, 128, 2), # exactly one MXU tile
    (200, 130, 4), # crosses both tile boundaries
    (7, 1, 2),     # degenerate single inducing point
]


@pytest.mark.parametrize("B,m,d", SHAPES)
def test_rbf_kernel_matches_oracle(B, m, d):
    x, z, lls, lv = _inputs(jax.random.PRNGKey(B * m + d), B, m, d)
    got = ops.rbf_cross_cov(x, z, lls, lv)
    want = ref.rbf_cross_cov(x, z, lls, lv)
    assert got.shape == (B, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("B,m,d", SHAPES)
def test_svgp_projection_matches_oracle(B, m, d):
    x, z, lls, lv = _inputs(jax.random.PRNGKey(1000 + B * m + d), B, m, d)
    kmm = ref.rbf_cross_cov(z, z, lls, lv) + 1e-4 * jnp.eye(m)
    lmm = jnp.linalg.cholesky(kmm)
    got = ops.svgp_projection(x, z, lls, lv, lmm)
    want = ops.svgp_projection_ref(x, z, lls, lv, lmm)
    for g, w, name in zip(got, want, ("knm", "lk_t", "q_diag"), strict=True):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), rtol=2e-4, atol=1e-4, err_msg=name
        )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rbf_kernel_dtypes(dtype):
    x, z, lls, lv = _inputs(jax.random.PRNGKey(3), 64, 24, 2, dtype=dtype)
    got = ops.rbf_cross_cov(x, z, lls.astype(jnp.float32), lv.astype(jnp.float32))
    want = ref.rbf_cross_cov(
        x.astype(jnp.float32), z.astype(jnp.float32),
        lls.astype(jnp.float32), lv.astype(jnp.float32),
    )
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), rtol=tol, atol=tol
    )
    assert got.dtype == dtype


@given(
    B=st.integers(1, 80),
    m=st.integers(1, 40),
    d=st.integers(1, 4),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=30, deadline=None)
def test_rbf_kernel_property_sweep(B, m, d, seed):
    """Hypothesis sweep over arbitrary (B, m, d): padding logic must never
    corrupt true outputs."""
    x, z, lls, lv = _inputs(jax.random.PRNGKey(seed), B, m, d)
    got = ops.rbf_cross_cov(x, z, lls, lv)
    want = ref.rbf_cross_cov(x, z, lls, lv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_rbf_kernel_invariances():
    """K(X,X) has variance on the diagonal; K is symmetric for X=Z; values
    bounded by the process variance (RBF properties, computed by kernel)."""
    x, _, lls, lv = _inputs(jax.random.PRNGKey(9), 50, 50, 2)
    k = np.asarray(ops.rbf_cross_cov(x, x, lls, lv))
    var = float(jnp.exp(lv))
    np.testing.assert_allclose(np.diag(k), var, rtol=1e-5)
    np.testing.assert_allclose(k, k.T, rtol=1e-4, atol=1e-6)
    assert (k <= var * (1 + 1e-5)).all() and (k > 0).all()


def test_projection_gradients_match_ref():
    """custom_vjp: d(ELBO)/d(params) through the kernel == through the ref."""
    cov_fn = make_covariance("rbf")
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (40, 2))
    y = jnp.sin(x[:, 0])
    cfg = svgp.SVGPConfig(num_inducing=10, input_dim=2)
    params = svgp.init_svgp_params(jax.random.PRNGKey(1), cfg, x_init=x)
    g0 = jax.grad(lambda p: svgp.elbo(p, cov_fn, x, y, use_pallas=False))(params)
    g1 = jax.grad(lambda p: svgp.elbo(p, cov_fn, x, y, use_pallas=True))(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1), strict=True):
        scale = np.maximum(np.abs(np.asarray(a)), 1.0)
        np.testing.assert_allclose(
            np.asarray(a) / scale, np.asarray(b) / scale, atol=5e-3
        )


def test_projection_q_diag_nonnegative_and_bounded():
    """q_diag = k^T Kmm^{-1} k in [0, k_ii]: the Nystrom residual k~_ii >= 0
    (what makes eq. 3's trace term a valid variance)."""
    x, z, lls, lv = _inputs(jax.random.PRNGKey(11), 64, 16, 2)
    kmm = ref.rbf_cross_cov(z, z, lls, lv) + 1e-5 * jnp.eye(16)
    lmm = jnp.linalg.cholesky(kmm)
    _, _, qd = ops.svgp_projection(x, z, lls, lv, lmm)
    qd = np.asarray(qd)
    kd = float(jnp.exp(lv))
    assert (qd >= -1e-5).all()
    assert (qd <= kd * (1 + 1e-3)).all()


def test_pallas_elbo_used_by_trainer():
    """End-to-end: PSVGP trainer with use_pallas=True trains w/o NaNs and
    reaches a loss close to the jnp path's."""
    from repro.core import psvgp
    from repro.core.metrics import rmspe
    from repro.core.partition import make_grid, partition_data
    from repro.data.spatial import e3sm_like_field

    ds = e3sm_like_field(n=1200, seed=3)
    grid = make_grid(ds.x, 4, 4)
    data = partition_data(ds.x, ds.y, grid)
    out = {}
    for use_pallas in (False, True):
        cfg = psvgp.PSVGPConfig(
            svgp=svgp.SVGPConfig(num_inducing=8, input_dim=2, use_pallas=use_pallas),
            delta=0.2, batch_size=16, learning_rate=0.05,
        )
        static = psvgp.build(cfg, data)
        state = psvgp.init(jax.random.PRNGKey(0), cfg, data)
        state = psvgp.fit(static, state, data, 150)
        out[use_pallas] = float(rmspe(static, state, data))
    assert np.isfinite(out[True])
    assert abs(out[True] - out[False]) < 0.05 * out[False] + 0.02, out
