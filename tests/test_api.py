"""The ``repro.api`` front door: config validation + JSON round-trip,
fit -> artifact -> serve, and the GOLDEN equivalence gates of the
api_redesign — the new ``Server`` path must be bitwise-identical to the
pre-refactor driver compositions it replaced.

Three layers:

  * config: frozen dataclasses validate on construction, round-trip
    through JSON, reject unknown fields, and resolve ``backend="auto"``
    to the fastest COMPILED lane (warning once when an explicit Pallas
    backend falls back to interpret mode off-TPU);
  * replicated lifecycle (in-process): ``fit`` reproduces the pre-api
    training recipe bitwise on a fixed seed; ``save``/``load`` restores a
    PosteriorCache whose predictions are bitwise-identical to the
    in-memory model; the replicated ``Server`` answers exactly like
    ``blend.predict_blended``;
  * sharded golden + artifact round-trip (subprocess — the mesh needs
    virtual host devices before jax initializes): ``Server`` results
    bitwise == the pre-refactor ``make_request_stages`` + serial/
    pipelined loop compositions, for single AND two-level routers, plus
    the fixed-q_max prepass lane; ``Server.from_artifact`` serves a
    two-level pipelined stream bitwise == the in-memory server (no
    retraining anywhere on that path); the "pallas"/"fused" kernel
    backends match "ref" to float32 accuracy through the same program.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro import api
from repro.api import config as api_config

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# configs
# ---------------------------------------------------------------------------


def test_fit_config_validation_and_json_round_trip():
    cfg = api.FitConfig(grid=3, m=4, delta=0.5, train_iters=10, seed=7)
    assert api.FitConfig.from_json(cfg.to_json()) == cfg
    assert cfg.num_partitions == 9
    for bad in (
        dict(grid=0),
        dict(m=0),
        dict(delta=1.5),
        dict(delta=-0.1),
        dict(train_iters=-1),
        dict(batch_size=0),
        dict(learning_rate=0.0),
        dict(comm="carrier-pigeon"),
        dict(covariance="linear"),
        dict(jitter=0.0),
    ):
        with pytest.raises(ValueError):
            api.FitConfig(**bad)
    with pytest.raises(ValueError, match="unknown FitConfig fields"):
        api.FitConfig.from_dict({"grid": 3, "banana": 1})


def test_serve_config_validation_and_json_round_trip():
    cfg = api.ServeConfig(
        mode="sharded", pipeline="pipelined", router="two-level",
        backend="fused", headroom=1.5, pad_multiple=16,
    )
    assert api.ServeConfig.from_json(cfg.to_json()) == cfg
    # q_max=None must survive the JSON round trip too
    cfg2 = api.ServeConfig(mode="sharded", q_max=64)
    assert api.ServeConfig.from_json(cfg2.to_json()) == cfg2
    for bad in (
        dict(mode="clustered"),
        dict(pipeline="async"),
        dict(router="three-level"),
        dict(backend="cuda"),
        dict(headroom=0.9),
        dict(pad_multiple=0),
        # replicated mode has no mesh stage / device blocks / kernel lanes
        dict(mode="replicated", pipeline="pipelined"),
        dict(mode="replicated", router="two-level"),
        dict(mode="replicated", backend="fused"),
        dict(mode="replicated", backend="pallas"),
        # fixed q_max is the sharded single-router prepass lane only
        dict(mode="replicated", q_max=8),
        dict(mode="sharded", router="two-level", q_max=8),
        dict(mode="sharded", q_max=0),
    ):
        with pytest.raises(ValueError):
            api.ServeConfig(**bad)
    with pytest.raises(ValueError, match="unknown ServeConfig fields"):
        api.ServeConfig.from_dict({"mode": "sharded", "routerr": "single"})


def test_serve_config_policy_and_backend_resolution():
    import jax

    from repro.core import routing

    on_tpu = jax.default_backend() == "tpu"
    # auto -> the fastest lane that actually compiles here
    auto = api.ServeConfig(mode="sharded", backend="auto").resolve_backend()
    assert auto == ("fused" if on_tpu else "ref")
    # replicated always serves the blend path
    assert api.ServeConfig(mode="replicated").resolve_backend() == "ref"
    # explicit interpret-mode backends are honored but warn ONCE
    if not on_tpu:
        api_config._WARNED_INTERPRET.clear()
        with pytest.warns(RuntimeWarning, match="INTERPRET"):
            got = api.ServeConfig(mode="sharded", backend="fused").resolve_backend()
        assert got == "fused"
        import warnings as _warnings

        with _warnings.catch_warnings():
            _warnings.simplefilter("error")  # a second warning would raise
            assert api.ServeConfig(
                mode="sharded", backend="fused"
            ).resolve_backend() == "fused"
    # the policy factory mirrors the router field
    assert isinstance(
        api.ServeConfig(mode="sharded", router="two-level").make_policy(),
        routing.TwoLevelQMax,
    )
    pol = api.ServeConfig(mode="sharded", headroom=2.0, pad_multiple=4).make_policy()
    assert isinstance(pol, routing.StreamingQMax)
    assert pol.headroom == 2.0 and pol.pad_multiple == 4
    assert api.ServeConfig(mode="sharded", q_max=32).make_policy() is None


# ---------------------------------------------------------------------------
# replicated lifecycle (no mesh needed)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_fitted():
    from repro.data.spatial import e3sm_like_field

    ds = e3sm_like_field(n=700, seed=0)
    cfg = api.FitConfig(grid=3, m=4, train_iters=60, seed=0)
    return ds, api.fit(cfg, ds)


def test_fit_matches_pre_api_recipe_bitwise(tiny_fitted):
    """api.fit is the OLD driver recipe behind a config — same grid, same
    padded partitioning, same init key, same SGD stream — so a fixed seed
    reproduces the pre-refactor trained state bitwise."""
    import jax

    from repro.core import psvgp, svgp
    from repro.core.partition import make_grid, partition_data

    ds, fitted = tiny_fitted
    grid = make_grid(ds.x, 3, 3)
    data = partition_data(ds.x, ds.y, grid)
    pcfg = psvgp.PSVGPConfig(
        svgp=svgp.SVGPConfig(num_inducing=4, input_dim=2),
        delta=0.25, batch_size=32, learning_rate=0.05,
    )
    static = psvgp.build(pcfg, data)
    state = psvgp.init(jax.random.PRNGKey(0), pcfg, data)
    state = psvgp.fit(static, state, data, 60)
    for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(fitted.state.params), strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(grid.x_edges), np.asarray(fitted.grid.x_edges))


def test_artifact_round_trip_replicated_bitwise(tiny_fitted, tmp_path):
    """save -> load restores config, grid and a PosteriorCache whose
    predictions are bitwise-identical — the artifact IS the model."""
    ds, fitted = tiny_fitted
    path = fitted.save(str(tmp_path / "artifact"))
    assert api.peek_fit_config(path) == fitted.config

    loaded = api.FittedPSVGP.load(path)
    assert loaded.config == fitted.config
    np.testing.assert_array_equal(loaded.grid.x_edges, fitted.grid.x_edges)
    np.testing.assert_array_equal(loaded.grid.y_edges, fitted.grid.y_edges)
    import jax

    for a, b in zip(jax.tree.leaves(fitted.cache), jax.tree.leaves(loaded.cache), strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    q = ds.x[:128]
    m0, v0 = fitted.predict(q)
    m1, v1 = loaded.predict(q)
    np.testing.assert_array_equal(np.asarray(m0), np.asarray(m1))
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(v1))


def test_replicated_server_matches_predict_blended_bitwise(tiny_fitted, tmp_path):
    from repro.core.blend import predict_blended

    ds, fitted = tiny_fitted
    q = ds.x[:96]
    server = api.Server(fitted, api.ServeConfig(mode="replicated"))
    sm, sv = server.submit(q)
    bm, bv = predict_blended(
        fitted.static, fitted.state, fitted.grid, q, cache=fitted.cache
    )
    np.testing.assert_array_equal(sm, np.asarray(bm))
    np.testing.assert_array_equal(sv, np.asarray(bv))

    # from_artifact serves without retraining, bitwise the same answers
    path = fitted.save(str(tmp_path / "a"))
    loaded_server = api.Server.from_artifact(path)
    lm, lv = loaded_server.submit(q)
    np.testing.assert_array_equal(lm, sm)
    np.testing.assert_array_equal(lv, sv)

    got = {}
    report = loaded_server.stream(
        [ds.x[:64], ds.x[64:128]], on_result=lambda i, out: got.setdefault(i, out)
    )
    assert sorted(got) == [0, 1]
    assert set(report["latency_ms"]) == {"p50_ms", "p95_ms", "p99_ms"}
    assert report["points_per_s"] > 0
    assert report["serve_config"] == loaded_server.config.to_dict()
    assert report["backend"] == "ref" and report["qmax_policy"] is None


def test_fit_rejects_bad_data_shapes():
    with pytest.raises(ValueError, match=r"\(N, 2\)"):
        api.fit(api.FitConfig(grid=2, m=2, train_iters=0),
                (np.zeros((10, 3)), np.zeros(10)))


def test_predict_cached_slots_backend_lanes_agree():
    """The three kernel lanes of the device-side hot path compute the same
    numbers (Pallas lanes in interpret mode here): backend='pallas' is the
    single-block kernel through the reshape round-trip, 'fused' the
    slot-stacked launch, 'ref' the jnp oracle."""
    import jax

    from repro.core import posterior, svgp
    from repro.gp.covariances import make_covariance

    cfg = svgp.SVGPConfig(num_inducing=5, input_dim=2)
    params = svgp.init_svgp_params(jax.random.PRNGKey(1), cfg)
    cov_fn = make_covariance("rbf")
    cache = posterior.build_cache(params, cov_fn)
    xslots = np.asarray(
        np.random.default_rng(2).normal(size=(9, 24, 2)), np.float32
    )
    m_ref, v_ref = posterior.predict_cached_slots(cache, cov_fn, xslots)
    for backend in ("pallas", "fused"):
        m_b, v_b = posterior.predict_cached_slots(
            cache, cov_fn, xslots, backend=backend
        )
        np.testing.assert_allclose(np.asarray(m_b), np.asarray(m_ref), atol=1e-5)
        np.testing.assert_allclose(np.asarray(v_b), np.asarray(v_ref), atol=1e-5)
    with pytest.raises(ValueError, match="not both"):
        posterior.predict_cached_slots(
            cache, cov_fn, xslots, use_pallas=True, backend="ref"
        )
    with pytest.raises(ValueError, match="backend"):
        posterior.predict_cached_slots(cache, cov_fn, xslots, backend="mosaic")


def test_request_stages_honor_policy_pad_multiple():
    """A non-default pad_multiple must reach build_routing_table, not just
    the policy — otherwise the table's own default of 8 re-rounds the
    policy's q_max and the policy counters describe block shapes that were
    never compiled. (The route stage is pure host: no mesh needed.)"""
    from repro.core import routing
    from repro.core.partition import make_grid
    from repro.launch import serve_sharded as ss

    rng = np.random.default_rng(0)
    pts = rng.uniform(0.0, 1.0, size=(25, 2)).astype(np.float32)
    grid = make_grid(pts, 3, 3)

    policy = routing.StreamingQMax(headroom=1.0, pad_multiple=4)
    route, _, _ = ss.make_request_stages(
        grid, blend_fn=None, cache_sh=None, policy=policy
    )
    table, _ = route(pts)
    assert table.q_max % 4 == 0
    assert table.q_max == policy.q_max  # counters match the compiled shape

    route_f, _, _ = ss.make_request_stages(
        grid, blend_fn=None, cache_sh=None, q_max=4, pad_multiple=4
    )
    # one point per cell: every bucket fits the fixed q_max=4 budget
    pts_f = np.array(
        [[0.1, 0.1], [0.5, 0.5], [0.9, 0.9], [0.1, 0.9]], np.float32
    )
    table_f, _ = route_f(pts_f)
    assert table_f.q_max == 4


# ---------------------------------------------------------------------------
# sharded golden equivalence + artifact round-trip (subprocess: the mesh
# needs virtual host devices before jax initializes)
# ---------------------------------------------------------------------------

_SHARDED_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=9"
    import tempfile

    import jax
    import numpy as np

    from repro import api
    from repro.core import psvgp, routing
    from repro.data.spatial import e3sm_like_field, zipf_query_stream
    from repro.launch import serve_sharded as ss

    GS, M, IT = 3, 4, 120
    ds = e3sm_like_field(n=1000, seed=0)
    fitted = api.fit(api.FitConfig(grid=GS, m=M, train_iters=IT, seed=0), ds)
    grid = fitted.grid

    # ---- the PRE-REFACTOR composition, built from the same primitives the
    # old drivers wired by hand ----------------------------------------------
    cache = psvgp.posterior_cache(fitted.static, fitted.state)
    mesh = ss.mesh_for_grid(grid)
    cache_sh = ss.shard_cache(cache, mesh)
    jax.block_until_ready(cache_sh)
    blend_fn = ss.make_sharded_blend(
        mesh, mesh.axis_names, grid, fitted.static.cov_fn, cache_sh
    )

    rng = np.random.default_rng(3)
    lo, hi = ds.x.min(axis=0), ds.x.max(axis=0)
    uni = [rng.uniform(lo, hi, (160, 2)).astype(np.float32) for _ in range(4)]
    zipf = zipf_query_stream(grid, 160, 4, alpha=1.2, seed=5)

    def old_results(batches, router, pipeline, q_max=None):
        if q_max is not None:
            policy = None
        elif router == "two-level":
            policy = routing.TwoLevelQMax()
        else:
            policy = routing.StreamingQMax()
        route, submit, collect = ss.make_request_stages(
            grid, blend_fn, cache_sh, policy=policy, q_max=q_max)
        if pipeline == "serial":
            return [collect(submit(route(q))) for q in batches]
        got = {}
        ss.pipelined_request_loop(route, submit, collect, batches, warm=False,
                                  on_result=lambda i, out: got.setdefault(i, out))
        return [got[i] for i in range(len(batches))]

    def new_results(fitted_, batches, router, pipeline, backend="ref", q_max=None):
        srv = api.Server(fitted_, api.ServeConfig(
            mode="sharded", pipeline=pipeline, router=router,
            backend=backend, q_max=q_max))
        got = {}
        srv.stream(batches, warm=False,
                   on_result=lambda i, out: got.setdefault(i, out))
        return [got[i] for i in range(len(batches))]

    def assert_bitwise(old, new, tag):
        for i, ((mo, vo), (mn, vn)) in enumerate(zip(old, new, strict=True)):
            assert np.array_equal(mo, mn) and np.array_equal(vo, vn), (tag, i)

    # GOLDEN: serial and pipelined, single and two-level router
    for router, batches in (("single", uni), ("two-level", zipf)):
        for pipeline in ("serial", "pipelined"):
            assert_bitwise(
                old_results(batches, router, pipeline),
                new_results(fitted, batches, router, pipeline),
                (router, pipeline),
            )
    print("golden: Server bitwise == pre-refactor loops (2 routers x 2 loops)")

    # GOLDEN: the fixed-q_max whole-stream-prepass lane
    qm, cells = ss.prepass_routing(grid, uni)
    assert_bitwise(
        old_results(uni, "single", "serial", q_max=qm),
        new_results(fitted, uni, "single", "serial", q_max=qm),
        "fixed-q_max",
    )
    print("golden: fixed-q_max prepass lane bitwise OK")

    # kernel backends through the same device program: float32-accurate
    ref = new_results(fitted, uni[:2], "single", "pipelined")
    for backend in ("pallas", "fused"):
        got = new_results(fitted, uni[:2], "single", "pipelined", backend=backend)
        for (mr, vr), (mb, vb) in zip(ref, got, strict=True):
            assert np.abs(mb - mr).max() <= 1e-4, backend
            assert np.abs(vb - vr).max() <= 1e-4, backend
    print("backends: pallas/fused match ref through the sharded program")

    # ARTIFACT round-trip: Server.from_artifact serves the two-level
    # pipelined stream bitwise == the in-memory server, without retraining
    with tempfile.TemporaryDirectory() as td:
        fitted.save(td)
        mem = new_results(fitted, zipf, "two-level", "pipelined")
        srv_art = api.Server.from_artifact(td, api.ServeConfig(
            mode="sharded", pipeline="pipelined", router="two-level",
            backend="ref"))
        got = {}
        srv_art.stream(zipf, warm=False,
                       on_result=lambda i, out: got.setdefault(i, out))
        art = [got[i] for i in range(len(zipf))]
        assert_bitwise(mem, art, "artifact")
        # and the replicated view of the same artifact, also bitwise
        rep_art = api.Server.from_artifact(td)
        m_a, v_a = rep_art.submit(uni[0])
        m_m, v_m = fitted.predict(uni[0])
        assert np.array_equal(m_a, np.asarray(m_m))
        assert np.array_equal(v_a, np.asarray(v_m))
    print("artifact: sharded two-level stream + replicated bitwise OK")
    print("SHARDED-API-OK")
    """
)


@pytest.mark.smoke
def test_sharded_server_golden_and_artifact_round_trip():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-c", _SHARDED_SCRIPT],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=570,
    )
    assert r.returncode == 0, r.stderr[-4000:]
    assert "SHARDED-API-OK" in r.stdout
