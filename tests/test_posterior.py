"""PosteriorCache equivalence: cached prediction == uncached SVGP math.

The cache path (repro.core.posterior) must reproduce the solve-based
marginal q(f) of repro.core.svgp.q_f — same mean, same variance — for both
parameterizations, and the fused Pallas prediction kernel must match the
jnp reference through the padding/dispatch layer.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import posterior, psvgp, svgp
from repro.core.blend import predict_blended
from repro.core.partition import make_grid, partition_data
from repro.data.spatial import e3sm_like_field
from repro.gp.covariances import make_covariance
from repro.kernels import ops


def _grid_z(m, d, key):
    side = int(np.ceil(m ** (1.0 / d)))
    axes = [jnp.linspace(-2, 2, side)] * d
    zz = jnp.stack(jnp.meshgrid(*axes), -1).reshape(-1, d)[:m]
    return zz + 0.05 * jax.random.normal(key, zz.shape)


def _model(key, m=12, d=2, covariance="rbf"):
    """A converged-looking model: grid-spread z with a matched lengthscale
    (well-conditioned Kmm), SMOOTH m_star, SMALL S.

    A converged posterior has m_star ~ f(z) for a smooth f (so the
    projected mean Kmm^{-1} m_star stays O(1)), S well below I, and
    inducing points its lengthscale can resolve. Random independent
    m_star / clumped z under a long lengthscale / near-init S ~ I make
    every f32 formulation — cached, solve-based, and the f64 oracle cast
    down — disagree at 1e-3 scale through sheer cancellation; serving
    never sees such states."""
    ks = jax.random.split(key, 3)
    cfg = svgp.SVGPConfig(
        num_inducing=m, input_dim=d, covariance=covariance, init_lengthscale=0.5
    )
    params = svgp.init_svgp_params(ks[0], cfg)
    z = _grid_z(m, d, ks[1])
    m_star = jnp.sin(2.0 * z[:, 0]) + 0.5 * jnp.cos(3.0 * z[:, min(1, d - 1)])
    s_tril = 0.05 * jax.random.normal(ks[2], (m, m)) - 2.0 * jnp.eye(m)
    return cfg, params._replace(z=z, m_star=m_star, s_tril=s_tril)


@pytest.mark.parametrize("whitened", [False, True])
@pytest.mark.parametrize("covariance", ["rbf", "matern52"])
def test_predict_cached_matches_qf(whitened, covariance):
    cfg, params = _model(jax.random.PRNGKey(0), covariance=covariance)
    cov_fn = make_covariance(covariance)
    xs = jax.random.uniform(jax.random.PRNGKey(5), (257, 2), minval=-2.5, maxval=2.5)
    mean_u, var_u = svgp.q_f(params, cov_fn, xs, cfg.jitter, whitened)
    cache = posterior.build_cache(params, cov_fn, jitter=cfg.jitter, whitened=whitened)
    mean_c, var_c = posterior.predict_cached(cache, cov_fn, xs)
    np.testing.assert_allclose(np.asarray(mean_c), np.asarray(mean_u), atol=1e-5)
    np.testing.assert_allclose(np.asarray(var_c), np.asarray(var_u), atol=1e-5)


@pytest.mark.parametrize("whitened", [False, True])
def test_svgp_predict_is_cached_path(whitened):
    """svgp.predict == build_cache + predict_cached (it delegates)."""
    cfg, params = _model(jax.random.PRNGKey(1))
    cov_fn = make_covariance("rbf")
    xs = jax.random.uniform(jax.random.PRNGKey(6), (64, 2), minval=-2, maxval=2)
    m_p, v_p = svgp.predict(params, cov_fn, xs, whitened=whitened, include_noise=True)
    cache = posterior.build_cache(params, cov_fn, jitter=cfg.jitter, whitened=whitened)
    m_c, v_c = posterior.predict_cached(cache, cov_fn, xs, include_noise=True)
    np.testing.assert_array_equal(np.asarray(m_p), np.asarray(m_c))
    np.testing.assert_array_equal(np.asarray(v_p), np.asarray(v_c))


@pytest.mark.parametrize("Q,m,d", [(1, 1, 1), (7, 5, 2), (100, 25, 2), (128, 128, 3), (300, 40, 2)])
def test_pallas_prediction_kernel_matches_ref(Q, m, d):
    """Fused kernel vs jnp reference through the padding/dispatch layer,
    including ragged (non-tile-aligned) Q and m."""
    ks = jax.random.split(jax.random.PRNGKey(Q * 1000 + m), 5)
    x = jax.random.uniform(ks[0], (Q, d), minval=-2, maxval=2)
    cfg, params = _model(ks[1], m=m, d=d)
    cov_fn = make_covariance("rbf")
    cache = posterior.build_cache(params, cov_fn)
    args = (x, cache.z, cache.cov.log_lengthscale, cache.cov.log_variance,
            cache.w, cache.u, cache.c)
    mean_k, var_k = ops.posterior_predict(*args)
    mean_r, var_r = ops.posterior_predict_ref(*args)
    np.testing.assert_allclose(np.asarray(mean_k), np.asarray(mean_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(var_k), np.asarray(var_r), atol=1e-5)


def test_predict_cached_pallas_path_matches_jnp():
    cfg, params = _model(jax.random.PRNGKey(2))
    cov_fn = make_covariance("rbf")
    xs = jax.random.uniform(jax.random.PRNGKey(7), (130, 2), minval=-2, maxval=2)
    cache = posterior.build_cache(params, cov_fn)
    m_j, v_j = posterior.predict_cached(cache, cov_fn, xs)
    m_p, v_p = posterior.predict_cached(cache, cov_fn, xs, use_pallas=True)
    np.testing.assert_allclose(np.asarray(m_p), np.asarray(m_j), atol=1e-5)
    np.testing.assert_allclose(np.asarray(v_p), np.asarray(v_j), atol=1e-5)


@pytest.mark.parametrize("S,Q,md", [(1, 8, (5, 2)), (9, 24, (12, 2)),
                                    (9, 130, (25, 2)), (3, 7, (128, 3))])
def test_pallas_slots_kernel_matches_ref(S, Q, md):
    """Slot-stacked fused kernel vs jnp reference through the
    padding/dispatch layer, incl. ragged (non-tile-aligned) S/Q/m."""
    m, d = md
    ks = jax.random.split(jax.random.PRNGKey(S * 100 + Q), 2)
    cfg, params = _model(ks[0], m=m, d=d)
    cov_fn = make_covariance("rbf")
    cache = posterior.build_cache(params, cov_fn)
    hx = jax.random.uniform(ks[1], (S, Q, d), minval=-2, maxval=2)
    args = (hx, cache.z, cache.cov.log_lengthscale, cache.cov.log_variance,
            cache.w, cache.u, cache.c)
    mean_k, var_k = ops.posterior_predict_slots(*args)
    mean_r, var_r = ops.posterior_predict_slots_ref(*args)
    assert mean_k.shape == (S, Q) and var_k.shape == (S, Q)
    np.testing.assert_allclose(np.asarray(mean_k), np.asarray(mean_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(var_k), np.asarray(var_r), atol=1e-5)


def test_slots_kernel_masked_oracle_and_row_independence():
    """The TWO-LEVEL routing contract on the slot-stacked kernel: a block
    may mix owner rows, spilled-in neighbor rows and padded placeholder
    rows, which is only safe because every output row depends on its own
    input row and the resident factors alone. Held two ways: kernel *
    qmask equals the masked oracle (ref.posterior_predict_slots_masked),
    and junk written into the masked rows' INPUTS leaves every valid row
    of the kernel output bitwise unchanged."""
    from repro.kernels import ref as kref

    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    cfg, params = _model(ks[0], m=12, d=2)
    cov_fn = make_covariance("rbf")
    cache = posterior.build_cache(params, cov_fn)
    S, Q = 9, 24
    hx = jax.random.uniform(ks[1], (S, Q, 2), minval=-2, maxval=2)
    qmask = (jax.random.uniform(ks[2], (S, Q)) < 0.6).astype(hx.dtype)
    tail = (cache.z, cache.cov.log_lengthscale, cache.cov.log_variance,
            cache.w, cache.u, cache.c)
    mean_k, var_k = ops.posterior_predict_slots(hx, *tail)
    mean_o, var_o = kref.posterior_predict_slots_masked(hx, qmask, *tail)
    np.testing.assert_allclose(
        np.asarray(mean_k * qmask), np.asarray(mean_o), atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(var_k * qmask), np.asarray(var_o), atol=1e-5)

    junk = jnp.where(qmask[..., None] > 0, hx, 1e3 * jnp.ones_like(hx))
    mean_j, var_j = ops.posterior_predict_slots(junk, *tail)
    keep = np.asarray(qmask) > 0
    np.testing.assert_array_equal(np.asarray(mean_k)[keep], np.asarray(mean_j)[keep])
    np.testing.assert_array_equal(np.asarray(var_k)[keep], np.asarray(var_j)[keep])


def test_pallas_slots_kernel_on_halo_stacked_blocks():
    """The kernel's real serving input: halo-stacked blocks from a routing
    table, including edge/corner partitions whose off-grid slots are
    zero-filled, and a ragged q_max."""
    from repro.core import routing
    from repro.core.partition import make_grid

    rng = np.random.default_rng(0)
    pts = rng.uniform(-1.0, 1.0, size=(300, 2)).astype(np.float32)
    grid = make_grid(pts, 4, 3)
    table = routing.build_routing_table(grid, pts)
    hx_all = routing.make_halo_stacker(grid)(table.xq)  # (P, 9, q, 2)

    cfg, params = _model(jax.random.PRNGKey(3), m=10, d=2)
    cov_fn = make_covariance("rbf")
    cache = posterior.build_cache(params, cov_fn)
    # corner (0), edge (1), interior (center of the 4x3 grid)
    for p in (0, 1, grid.index_of(1, 1)):
        hx = jnp.asarray(hx_all[p])
        m_j, v_j = posterior.predict_cached_slots(cache, cov_fn, hx)
        m_p, v_p = posterior.predict_cached_slots(cache, cov_fn, hx, use_pallas=True)
        np.testing.assert_allclose(np.asarray(m_p), np.asarray(m_j), atol=1e-5)
        np.testing.assert_allclose(np.asarray(v_p), np.asarray(v_j), atol=1e-5)


def test_predict_cached_slots_jnp_is_per_slot_predict_cached():
    """The slot stack is a pure batching: slot k's row equals a plain
    predict_cached call on that block (bitwise, same code path)."""
    cfg, params = _model(jax.random.PRNGKey(4))
    cov_fn = make_covariance("rbf")
    cache = posterior.build_cache(params, cov_fn)
    hx = jax.random.uniform(jax.random.PRNGKey(8), (9, 16, 2), minval=-2, maxval=2)
    ms, vs = posterior.predict_cached_slots(cache, cov_fn, hx, include_noise=True)
    for k in (0, 4, 8):
        m1, v1 = posterior.predict_cached(cache, cov_fn, hx[k], include_noise=True)
        np.testing.assert_allclose(np.asarray(ms[k]), np.asarray(m1), atol=1e-7)
        np.testing.assert_allclose(np.asarray(vs[k]), np.asarray(v1), atol=1e-7)


@pytest.mark.parametrize("covariance", ["matern32", "matern52"])
def test_pallas_paths_reject_non_rbf(covariance):
    """use_pallas with a non-RBF covariance must raise, not silently
    return RBF answers — on every cached-prediction entry point."""
    cfg, params = _model(jax.random.PRNGKey(5), covariance=covariance)
    cov_fn = make_covariance(covariance)
    cache = posterior.build_cache(params, cov_fn)
    xs = jax.random.uniform(jax.random.PRNGKey(9), (16, 2), minval=-2, maxval=2)
    with pytest.raises(ValueError, match="rbf"):
        posterior.predict_cached(cache, cov_fn, xs, use_pallas=True)
    with pytest.raises(ValueError, match="rbf"):
        posterior.predict_cached_slots(
            cache, cov_fn, xs[None].repeat(9, axis=0), use_pallas=True
        )
    stacked = jax.tree.map(lambda a: jnp.stack([a, a]), cache)
    with pytest.raises(ValueError, match="rbf"):
        posterior.predict_cached_stacked(
            stacked, cov_fn, jnp.stack([xs, xs]), use_pallas=True
        )
    # the jnp path keeps serving every covariance
    m_j, v_j = posterior.predict_cached(cache, cov_fn, xs)
    assert np.isfinite(np.asarray(m_j)).all() and (np.asarray(v_j) > 0).all()


@pytest.fixture(scope="module")
def trained_psvgp():
    ds = e3sm_like_field(n=2500, seed=0)
    grid = make_grid(ds.x, 4, 4)
    data = partition_data(ds.x, ds.y, grid)
    cfg = psvgp.PSVGPConfig(
        svgp=svgp.SVGPConfig(num_inducing=6, input_dim=2),
        delta=0.25, batch_size=16, learning_rate=0.05,
    )
    static = psvgp.build(cfg, data)
    state = psvgp.init(jax.random.PRNGKey(0), cfg, data)
    state = psvgp.fit(static, state, data, 300)
    return ds, grid, data, static, state


def test_prediction_entry_points_share_cache(trained_psvgp):
    """predict_local / predict_at_partitions / predict_blended give the
    same answers with a precomputed cache as without (cache reuse is a pure
    optimization, not a different model)."""
    ds, grid, data, static, state = trained_psvgp
    cache = psvgp.posterior_cache(static, state)

    m0, v0 = psvgp.predict_local(static, state, data.x)
    m1, v1 = psvgp.predict_local(static, state, data.x, cache=cache)
    np.testing.assert_array_equal(np.asarray(m0), np.asarray(m1))
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(v1))

    ids = jnp.asarray([0, 5, 10])
    pts = data.x[:3, :4]
    m0, v0 = psvgp.predict_at_partitions(static, state, ids, pts)
    m1, v1 = psvgp.predict_at_partitions(static, state, ids, pts, cache=cache)
    np.testing.assert_array_equal(np.asarray(m0), np.asarray(m1))

    q = jnp.asarray(ds.x[:500])
    mb0, vb0 = predict_blended(static, state, grid, q)
    mb1, vb1 = predict_blended(static, state, grid, q, cache=cache)
    np.testing.assert_array_equal(np.asarray(mb0), np.asarray(mb1))
    np.testing.assert_array_equal(np.asarray(vb0), np.asarray(vb1))


def test_blended_continuity_preserved_after_rewrite(trained_psvgp):
    """The cached rewrite keeps the bilinear stitch continuous across a
    partition boundary (epsilon probes either side agree)."""
    ds, grid, data, static, state = trained_psvgp
    cache = psvgp.posterior_cache(static, state)
    xb = float(grid.x_edges[2])
    ys = np.linspace(grid.y_edges[1], grid.y_edges[3], 9).astype(np.float32)
    eps = 1e-4
    left = np.stack([np.full_like(ys, xb - eps), ys], -1)
    right = np.stack([np.full_like(ys, xb + eps), ys], -1)
    ml, _ = predict_blended(static, state, grid, jnp.asarray(left), cache=cache)
    mr, _ = predict_blended(static, state, grid, jnp.asarray(right), cache=cache)
    np.testing.assert_allclose(np.asarray(ml), np.asarray(mr), atol=2e-3)


def test_blended_matches_local_model_at_cell_center(trained_psvgp):
    ds, grid, data, static, state = trained_psvgp
    from repro.core.partition import partition_centers

    cache = psvgp.posterior_cache(static, state)
    centers = partition_centers(grid)[[5, 9]]
    ids = jnp.asarray([5, 9])
    mb, _ = predict_blended(static, state, grid, jnp.asarray(centers), cache=cache)
    ml, _ = psvgp.predict_at_partitions(
        static, state, ids, jnp.asarray(centers)[:, None], cache=cache
    )
    np.testing.assert_allclose(np.asarray(mb), np.asarray(ml)[:, 0], atol=1e-4)
