"""Serving-benchmark smoke lane (default pytest run, `smoke` marker).

Runs ``benchmarks.bench_serve --smoke`` — the full rebuilt pipeline
(train, shard, serial + pipelined + fused lanes, equivalence gates) on a
3x3 mesh in seconds — so a pipeline regression fails the tier-1 run, not
just the next full benchmark refresh. ``make bench-serve-smoke`` runs the
same thing by hand. Needs a subprocess: the benchmark forces virtual host
devices before jax initializes.
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.smoke
def test_bench_serve_smoke(tmp_path):
    out = tmp_path / "BENCH_serve_smoke.json"
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_serve", "--smoke", "--out", str(out)],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=600,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    rec = json.loads(out.read_text())

    # every lane present and sane
    for lane in ("replicated", "sharded_serial", "sharded_pipelined",
                 "sharded_pipelined_fused"):
        assert rec[lane]["p50_ms"] > 0, lane
        assert rec[lane]["points_per_s"] > 0, lane

    # the hard gates the full-size benchmark is held to
    eq = rec["equivalence"]
    assert eq["atol_1e5_ok"], eq
    assert eq["pipelined_bitwise_serial"], "pipelining changed the math"
    assert eq["fused_vs_jnp_max_abs_err_mean"] <= 1e-4, eq
    assert eq["fused_vs_jnp_max_abs_err_var"] <= 1e-4, eq

    # structure the README/architecture docs cite
    assert rec["sharded_serial"]["cache_shard_ratio"] == rec["P"]
    pol = rec["sharded_pipelined"]["qmax_policy"]
    assert pol["q_max"] > 0 and pol["compiles"] >= 1
    assert rec["speedup"]["pipelined_vs_serial_p50"] > 0
    # the PR-2 cross-run comparison is only valid on its own 16x16 shape
    assert "baseline" not in rec and "serial_vs_pr2_p50" not in rec["speedup"]

    # skew lanes: the two-level router must keep its acceptance gates even
    # at smoke shapes — >= 2x padded-row waste reduction on the zipf
    # stream, results equal to the single-level route, replicated-level
    # accuracy, pipelined bitwise == serial
    skew = rec["skew"]
    assert skew["two_level"]["qmax_policy"]["q_max"] <= \
        skew["single_level"]["qmax_policy"]["q_max"]
    assert skew["waste_reduction_vs_single"] >= 2.0, skew
    zeq = skew["equivalence"]
    assert zeq["atol_1e5_ok"], zeq
    assert zeq["two_level_vs_single_max_abs_err"] <= 1e-5, zeq
    assert zeq["pipelined_bitwise_serial"], "two-level pipelining changed the math"
