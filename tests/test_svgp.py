"""SVGP correctness vs the exact GP oracle (paper eq. 2 vs eq. 3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import svgp
from repro.gp import exact_gp_logml, exact_gp_predict, make_covariance

jax.config.update("jax_enable_x64", False)


def _toy(key, n=64, d=2, noise=0.1):
    kx, kf = jax.random.split(key)
    x = jax.random.uniform(kx, (n, d), minval=-2.0, maxval=2.0)
    f = jnp.sin(x[:, 0] * 2.0) + 0.5 * jnp.cos(x[:, 1] * 3.0)
    y = f + noise * jax.random.normal(kf, (n,))
    return x, y


@pytest.mark.parametrize("whitened", [False, True])
def test_elbo_lower_bounds_exact_logml(whitened):
    key = jax.random.PRNGKey(0)
    x, y = _toy(key)
    cov_fn = make_covariance("rbf")
    cfg = svgp.SVGPConfig(num_inducing=16, input_dim=2, whitened=whitened)
    params = svgp.init_svgp_params(jax.random.PRNGKey(1), cfg, x_init=x)
    bound = svgp.elbo(params, cov_fn, x, y, whitened=whitened)
    logml = exact_gp_logml(params.cov, params.log_beta, cov_fn, x, y)
    assert float(bound) <= float(logml) + 1e-3


def _optimal_q(params, cov_fn, x, y, jitter=1e-6):
    """Closed-form Titsias-optimal q(u) = N(m*, S*):
    S* = Kmm (Kmm + beta Kmn Knm)^{-1} Kmm,  m* = beta S* Kmm^{-1} Kmn y."""
    beta = jnp.exp(params.log_beta)
    m = params.z.shape[0]
    kmm = cov_fn(params.cov, params.z, params.z) + jitter * jnp.eye(m)
    kmn = cov_fn(params.cov, params.z, x)
    a = kmm + beta * kmn @ kmn.T
    a_inv_kmm = jnp.linalg.solve(a, kmm)
    s_star = kmm @ a_inv_kmm
    m_star = beta * kmm @ jnp.linalg.solve(a, kmn @ y)
    # encode S* into the unconstrained s_tril parameterization
    sl = jnp.linalg.cholesky(s_star + 1e-10 * jnp.eye(m))
    s_tril = jnp.tril(sl, -1) + jnp.diag(jnp.log(jnp.diagonal(sl)))
    return params._replace(m_star=m_star, s_tril=s_tril)


def test_elbo_tight_when_inducing_equal_data():
    """With z = x and the closed-form optimal q(u), the bound is exactly the
    exact-GP log marginal likelihood (Titsias 2009)."""
    key = jax.random.PRNGKey(0)
    x, y = _toy(key, n=32)
    cov_fn = make_covariance("rbf")
    cfg = svgp.SVGPConfig(num_inducing=32, input_dim=2)
    params = svgp.init_svgp_params(jax.random.PRNGKey(1), cfg)
    params = params._replace(z=x)
    params = _optimal_q(params, cov_fn, x, y)
    bound = float(svgp.elbo(params, cov_fn, x, y, jitter=1e-6))
    logml = float(exact_gp_logml(params.cov, params.log_beta, cov_fn, x, y, jitter=1e-6))
    assert bound <= logml + 1e-3
    assert abs(bound - logml) < 0.02 * abs(logml) + 0.2


def test_minibatch_elbo_unbiased():
    """E_minibatch[ELBO_est] == full ELBO (eq. 3 factorization)."""
    key = jax.random.PRNGKey(2)
    x, y = _toy(key, n=60)
    cov_fn = make_covariance("rbf")
    cfg = svgp.SVGPConfig(num_inducing=8, input_dim=2)
    params = svgp.init_svgp_params(jax.random.PRNGKey(3), cfg, x_init=x)
    full = float(svgp.elbo(params, cov_fn, x, y))
    # average the minibatch estimator over disjoint batches covering the data
    ests = []
    for i in range(0, 60, 12):
        ests.append(float(svgp.elbo(params, cov_fn, x[i : i + 12], y[i : i + 12], n_total=60.0)))
    # mean over a uniform partition of the data = full ELBO exactly
    # (the KL enters every estimate, and sum_i l_i splits exactly).
    np.testing.assert_allclose(np.mean(ests), full, rtol=1e-4)


def test_mask_equivalence():
    """Masked padded batch == unpadded batch."""
    key = jax.random.PRNGKey(4)
    x, y = _toy(key, n=20)
    cov_fn = make_covariance("matern52")
    cfg = svgp.SVGPConfig(num_inducing=8, input_dim=2)
    params = svgp.init_svgp_params(jax.random.PRNGKey(5), cfg, x_init=x)
    pad = 12
    xp = jnp.concatenate([x, jnp.zeros((pad, 2))])
    yp = jnp.concatenate([y, jnp.full((pad,), 1e6)])  # garbage in padded slots
    mask = jnp.concatenate([jnp.ones(20), jnp.zeros(pad)])
    a = float(svgp.elbo(params, cov_fn, x, y))
    b = float(svgp.elbo(params, cov_fn, xp, yp, mask=mask))
    np.testing.assert_allclose(a, b, rtol=1e-5)


def test_predict_matches_exact_gp_with_full_inducing():
    """SVGP with z=x and optimal q(u) reproduces exact GP predictions."""
    key = jax.random.PRNGKey(6)
    x, y = _toy(key, n=32)
    xs = jax.random.uniform(jax.random.PRNGKey(7), (16, 2), minval=-2, maxval=2)
    cov_fn = make_covariance("rbf")
    cfg = svgp.SVGPConfig(num_inducing=32, input_dim=2)
    params = svgp.init_svgp_params(jax.random.PRNGKey(8), cfg)
    params = params._replace(z=x)
    params = _optimal_q(params, cov_fn, x, y)
    mean_s, var_s = svgp.predict(params, cov_fn, xs, jitter=1e-6)
    mean_e, var_e = exact_gp_predict(params.cov, params.log_beta, cov_fn, x, y, xs, jitter=1e-6)
    np.testing.assert_allclose(np.asarray(mean_s), np.asarray(mean_e), atol=0.05)
    np.testing.assert_allclose(np.asarray(var_s), np.asarray(var_e), atol=0.05)


def test_init_inducing_sampled_from_valid_rows_only():
    """Regression: with a validity mask, inducing init must never draw the
    padded rows (they replicate the partition's first point, stacking
    duplicate inducing points there — singular-to-jitter Kmm, chaotic
    Cholesky gradients) and must not duplicate rows when enough valid
    points exist."""
    key = jax.random.PRNGKey(0)
    x_valid = jax.random.uniform(key, (12, 2))
    # padded-storage layout of core.partition: pad slots replicate row 0
    x_pad = jnp.concatenate([x_valid, jnp.broadcast_to(x_valid[0], (20, 2))])
    mask = jnp.concatenate([jnp.ones(12), jnp.zeros(20)])
    cfg = svgp.SVGPConfig(num_inducing=8, input_dim=2)
    for seed in range(5):
        params = svgp.init_svgp_params(jax.random.PRNGKey(seed), cfg, x_init=x_pad, mask=mask)
        z = np.asarray(params.z)
        valid = np.asarray(x_valid)
        for row in z:
            assert np.isclose(valid, row[None], atol=0).all(axis=1).any(), row
        assert len(np.unique(z, axis=0)) == cfg.num_inducing  # no duplicates
    # under vmap (the PSVGP init path) the same property must hold
    xb = jnp.stack([x_pad, x_pad])
    mb = jnp.stack([mask, mask])
    keys = jax.random.split(jax.random.PRNGKey(9), 2)
    pb = jax.vmap(lambda k, x, m: svgp.init_svgp_params(k, cfg, x_init=x, mask=m))(keys, xb, mb)
    for z in np.asarray(pb.z):
        assert len(np.unique(z, axis=0)) == cfg.num_inducing


def test_whitened_unwhitened_same_objective_at_init():
    """At S=I, m=0 the two parameterizations give the same ELBO value."""
    key = jax.random.PRNGKey(9)
    x, y = _toy(key, n=40)
    cov_fn = make_covariance("rbf")
    cfg = svgp.SVGPConfig(num_inducing=10, input_dim=2)
    params = svgp.init_svgp_params(jax.random.PRNGKey(10), cfg, x_init=x)
    # whitened init (m=0, S=I) corresponds to unwhitened (m=0, S=Kmm):
    # instead compare KL=0 case: whitened KL at init is 0; unwhitened is not.
    kl_w = svgp.kl_to_prior(params, cov_fn, 1e-5, whitened=True)
    assert abs(float(kl_w)) < 1e-5
