"""Blended (stitched) prediction — beyond-paper §6 follow-up.

Flake audit note: the two fit-quality assertions here (boundary gap
ratio, blended-vs-base RMSPE) bound a STOCHASTIC optimization outcome
with a fixed tolerance. A single training run's metric fluctuates right
around such bounds when anything upstream perturbs the RNG stream (a new
jax version, a reordered op), so both tests average the metric over two
init seeds before asserting — the same template as
test_psvgp.test_ppermute_and_gather_converge_similarly. The structural
tests (weights collapse at cell centers) keep a single seed: their
property holds for ANY fit.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import psvgp, svgp
from repro.core.blend import predict_blended
from repro.core.metrics import rmspe
from repro.core.partition import make_grid, partition_data
from repro.data.spatial import e3sm_like_field


def _fit(n=4000, gx=5, iters=800, delta=0.0, seed=0):
    ds = e3sm_like_field(n=n, seed=0)
    grid = make_grid(ds.x, gx, gx)
    data = partition_data(ds.x, ds.y, grid)
    cfg = psvgp.PSVGPConfig(
        svgp=svgp.SVGPConfig(num_inducing=6, input_dim=2),
        delta=delta, batch_size=16, learning_rate=0.05,
    )
    static = psvgp.build(cfg, data)
    state = psvgp.init(jax.random.PRNGKey(seed), cfg, data)
    state = psvgp.fit(static, state, data, iters)
    return ds, grid, data, static, state


def test_blended_prediction_continuous_across_boundary():
    """Evaluating the stitched surface epsilon on either side of a
    partition boundary gives (near-)identical values — the discontinuity
    ISVGP suffers from vanishes at stitch time. Averaged over 2 seeds
    (see the module docstring): the gap ratio of one run sits well below
    the bound but fluctuates with the local models' disagreement."""
    from repro.core.psvgp import predict_at_partitions

    abs_gaps, blended_gaps, local_gaps = [], [], []
    for seed in (1, 2):
        ds, grid, data, static, state = _fit(seed=seed)
        xb = float(grid.x_edges[2])  # interior vertical boundary
        ys = np.linspace(grid.y_edges[1], grid.y_edges[3], 7).astype(np.float32)
        eps = 1e-4
        left = np.stack([np.full_like(ys, xb - eps), ys], -1)
        right = np.stack([np.full_like(ys, xb + eps), ys], -1)
        ml, _ = predict_blended(static, state, grid, jnp.asarray(left))
        mr, _ = predict_blended(static, state, grid, jnp.asarray(right))
        abs_gaps.append(float(jnp.max(jnp.abs(ml - mr))))

        # whereas the two LOCAL models disagree by much more at the spot
        pl = grid.index_of(1, 2)
        pr = grid.index_of(2, 2)
        mid = jnp.asarray(np.stack([np.full_like(ys, xb), ys], -1))[None]
        m_l, _ = predict_at_partitions(static, state, jnp.asarray([pl]), mid)
        m_r, _ = predict_at_partitions(static, state, jnp.asarray([pr]), mid)
        local_gaps.append(float(jnp.max(jnp.abs(m_l - m_r))))
        blended_gaps.append(float(jnp.max(jnp.abs(ml - mr))))
    assert np.mean(abs_gaps) < 2e-3, abs_gaps
    assert np.mean(blended_gaps) < 0.05 * np.mean(local_gaps) + 1e-4, (
        blended_gaps, local_gaps,
    )


def test_blended_prediction_accuracy_not_worse():
    """Stitching must not cost accuracy: blended RMSPE within 10% of the
    per-partition RMSPE (it usually improves, acting as model averaging),
    averaged over 2 seeds (see the module docstring).

    Trains with delta > 0 (the paper's actual method): the blend evaluates
    the up-to-4 surrounding models near shared boundaries, which is only
    meaningful when those models have SEEN neighbor mini-batches during
    training. At delta = 0 (ISVGP) every corner model is a pure
    extrapolator outside its own cell, and blending necessarily costs
    accuracy (measured: ratio 1.21 at delta=0 vs 0.98 at delta=0.25) —
    that is a property of ISVGP, not of the stitching."""
    ratios = []
    for seed in (1, 2):
        ds, grid, data, static, state = _fit(delta=0.25, seed=seed)
        base = float(rmspe(static, state, data))
        mean, var = predict_blended(static, state, grid, jnp.asarray(ds.x))
        blended = float(jnp.sqrt(jnp.mean((mean - jnp.asarray(ds.y)) ** 2)))
        ratios.append(blended / base)
        assert np.isfinite(np.asarray(var)).all() and (np.asarray(var) > 0).all()
    assert np.mean(ratios) < 1.1, ratios


def test_blended_matches_local_at_cell_centers():
    """At a partition's center the bilinear weights collapse onto that
    partition's own model."""
    ds, grid, data, static, state = _fit(iters=200)
    from repro.core.partition import partition_centers
    from repro.core.psvgp import predict_at_partitions

    centers = partition_centers(grid)[[6, 12]]
    ids = jnp.asarray([6, 12])
    m_blend, _ = predict_blended(static, state, grid, jnp.asarray(centers))
    m_local, _ = predict_at_partitions(static, state, ids, jnp.asarray(centers)[:, None])
    np.testing.assert_allclose(
        np.asarray(m_blend), np.asarray(m_local)[:, 0], atol=1e-4
    )
