"""SPMD (shard_map + ppermute) PSVGP == single-host simulation, bit-for-bit.

The SPMD program needs multiple XLA host devices, which must be configured
before jax initializes — so the check runs in a subprocess with its own
XLA_FLAGS (tests in this process keep seeing 1 device).
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import jax, jax.numpy as jnp, numpy as np
    from repro.data.spatial import e3sm_like_field
    from repro.core.partition import make_grid, partition_data
    from repro.core import psvgp, svgp
    from repro.core.psvgp_spmd import make_spmd_step
    from repro.runtime import compat

    ds = e3sm_like_field(n=2000, seed=0)
    grid = make_grid(ds.x, gx=4, gy=4)
    data = partition_data(ds.x, ds.y, grid)
    cfg = psvgp.PSVGPConfig(
        svgp=svgp.SVGPConfig(num_inducing=8, input_dim=2),
        delta=0.2, batch_size=8, learning_rate=0.05, comm="ppermute")
    static = psvgp.build(cfg, data)
    state = psvgp.init(jax.random.PRNGKey(0), cfg, data)
    mesh = jax.make_mesh((4, 4), ("data", "model"))
    step = make_spmd_step(mesh, ("data", "model"), grid, cfg, static.cov_fn, static.p_dir)

    st_spmd = state
    st_sim = state
    key = jax.random.PRNGKey(42)
    # Two steps: enough to exercise the exchange + update path while staying
    # below Adam's chaotic divergence horizon (the sqrt(nu) normalization
    # amplifies float-reassociation noise exponentially across steps; step-0
    # agreement is ~1e-9, step-4 would be ~1e-3 with identical math).
    with compat.set_mesh(mesh):
        for _ in range(2):
            st_spmd, loss_spmd = step(
                st_spmd, key, data.x, data.y, data.mask,
                static.dist.probs, static.dist.n_eff)
    for _ in range(2):
        st_sim, loss_sim = psvgp.train_step_ppermute(
            st_sim, key, data.x, data.y, data.mask, static.dist,
            static.perms, static.p_dir, cfg, static.cov_fn)

    a = jax.device_get(st_spmd.params)
    b = jax.device_get(st_sim.params)
    # atol covers two Adam steps of float-reassociation noise between the
    # two independently compiled programs (see comment above): the noise is
    # run-to-run nondeterministic on CPU (thread-level reduction order
    # across the 16 virtual devices; measured 1e-5..7e-5 across runs) and
    # each sqrt(nu)-normalized step multiplies it. A real exchange/weight
    # bug shows up at 1e-1 scale (2 x lr sign flips), 3 orders above this.
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b), strict=True):
        np.testing.assert_allclose(la, lb, atol=2e-4)

    # the lowered SPMD program must actually contain a collective-permute —
    # the paper's decentralized p2p exchange on the ICI torus.
    lowered = step.lower(state, key, data.x, data.y, data.mask,
                         static.dist.probs, static.dist.n_eff)
    txt = lowered.as_text() + lowered.compile().as_text()
    assert ("collective_permute" in txt) or ("collective-permute" in txt), \
        "no collective-permute in lowered/compiled HLO"
    print("OK")
    """
)


@pytest.mark.slow
def test_spmd_step_matches_simulation():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
