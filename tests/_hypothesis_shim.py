"""Minimal stand-in for ``hypothesis`` when it is not installed.

hypothesis is an OPTIONAL dev dependency (see pyproject.toml); the container
that runs tier-1 does not ship it. The property tests only use three scalar
strategies (integers / floats / booleans), so this shim emulates them with a
deterministic per-test PRNG sweep: each ``@given`` test body runs
``max_examples`` times over pseudo-random draws. No shrinking, no database,
no assume() — if a property fails here, rerun with real hypothesis installed
to minimize the counterexample.

Usage (the pattern in the test files):

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_shim import given, settings, st
"""
from __future__ import annotations

import random
from types import SimpleNamespace


class _Strategy:
    def __init__(self, draw):
        self._draw = draw


def _integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def _floats(min_value, max_value):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def _booleans():
    return _Strategy(lambda rng: rng.random() < 0.5)


st = SimpleNamespace(integers=_integers, floats=_floats, booleans=_booleans)

_DEFAULT_EXAMPLES = 10


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_ignored):
    """Records max_examples on the function for ``given`` to pick up."""

    def deco(f):
        f._shim_max_examples = max_examples
        return f

    return deco


def given(*arg_strategies, **kw_strategies):
    """Run the test once per example with deterministic draws (seeded by the
    test name, so failures reproduce run-to-run)."""

    def deco(f):
        def wrapper():
            # read the attribute from the wrapper too: real hypothesis
            # accepts @settings above OR below @given, and the above-order
            # stamps the wrapper, not f
            n = getattr(
                wrapper, "_shim_max_examples",
                getattr(f, "_shim_max_examples", _DEFAULT_EXAMPLES),
            )
            rng = random.Random(f.__qualname__)
            for _ in range(n):
                drawn = [s._draw(rng) for s in arg_strategies]
                drawn_kw = {k: s._draw(rng) for k, s in kw_strategies.items()}
                f(*drawn, **drawn_kw)

        # NOT functools.wraps: that would copy __wrapped__ and the original
        # signature, making pytest treat the drawn arguments as fixtures.
        wrapper.__name__ = f.__name__
        wrapper.__doc__ = f.__doc__
        wrapper.__module__ = f.__module__
        return wrapper

    return deco
