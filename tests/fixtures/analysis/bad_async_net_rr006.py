"""RR006 fixture, transport-shaped: an HTTP-endpoint-like class whose
request counter is written by both a connection-handler coroutine (event
loop) and a stats flusher handed to a worker thread — no lock, no
CONFINEMENT entry. The shipped ``repro.net.server.NetServer`` avoids
exactly this by never handing a method to a thread (its counters are
loop-confined; see the asynclint CONFINEMENT manifest)."""
import asyncio
import concurrent.futures


class Endpoint:
    def __init__(self):
        self._pool = concurrent.futures.ThreadPoolExecutor(max_workers=1)
        self.http_requests = 0

    def _flush_stats(self):
        self.http_requests = 0

    async def handle_conn(self, reader, writer):
        self.http_requests += 1
        loop = asyncio.get_running_loop()
        done = await loop.run_in_executor(self._pool, self._flush_stats)
        return done
