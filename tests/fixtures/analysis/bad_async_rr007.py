"""RR007 fixture: the spawned task's only reference is dropped — its
exception vanishes and the task itself may be garbage-collected."""
import asyncio


async def work():
    return 3


async def main():
    loop = asyncio.get_running_loop()
    loop.create_task(work())
    await asyncio.sleep(0)
