"""noqa fixture: the same violations as the bad files, each suppressed
with the per-line escape hatch — the linter must report nothing here."""
import dataclasses

import jax.numpy as jnp

QUAD_NODES = jnp.linspace(-1.0, 1.0, 8)  # repro: noqa-RR001


@dataclasses.dataclass(frozen=True)
class KnownUnvalidated:  # repro: noqa-RR004
    mode: str = "replicated"
