"""RR008 fixture: the delivery path can raise between collect and
``set_result`` with no rejecting handler — the batch's clients hang."""


async def resolve(batch, collect):
    mean, var = await collect(batch.handle)
    outs = demux(batch.sizes, mean, var)
    for req, out in zip(batch.reqs, outs):
        req.future.set_result(out)


def demux(sizes, mean, var):
    return list(zip(mean, var))
