"""Async rules suppressed in place — the ``# repro: noqa-RRxxx`` escape
hatch works, and stripping the comments brings the findings back
(tests/test_analysis.py proves both directions)."""
import asyncio
import time


async def sleepy():
    time.sleep(0.001)  # repro: noqa-RR005


async def spawner():
    loop = asyncio.get_running_loop()
    loop.create_task(asyncio.sleep(0))  # repro: noqa-RR007
    await asyncio.sleep(0)
