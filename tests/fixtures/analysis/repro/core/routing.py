"""RR002 fixture: a jnp call smuggled into a declared pure-numpy routing
helper. The directory layout makes this file's path end with
``repro/core/routing.py``, so the suffix-keyed rule config applies to it
exactly as it does to the real module. Every declared function exists (a
missing one is its own RR002 finding — not what this fixture tests);
only ``make_halo_stacker`` violates.
"""
import numpy as np


def owning_cells(grid, pts):
    return np.zeros(len(pts), np.int64), np.zeros(len(pts), np.int64)


def ceil_to(n, k):
    return -(-n // k) * k


def halo_ids(grid):
    return np.zeros((1, 9), np.int64)


def spill_assign(grid, own, ids, q_max):
    return own


def min_spill_q_max(grid, own, ids):
    return 1


def build_routing_table(grid, points):
    return None


def halo_slot_on_grid(grid):
    return np.ones((1, 9), bool)


def make_halo_stacker(grid):
    import jax.numpy as jnp

    def stack(xq):
        return jnp.asarray(xq)  # <- the violation: routing went on-device

    return stack


def scatter_results(table, values):
    return np.asarray(values).ravel()


class StreamingQMax:
    def fit(self, counts):
        return int(counts.max())


class TwoLevelQMax(StreamingQMax):
    def fit_spill(self, grid, own, ids):
        return 1, own
