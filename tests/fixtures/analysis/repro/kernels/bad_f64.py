"""RR003 fixture: bare float64 in a kernel hot path (suffix-matched)."""
import numpy as np


def stage_factors(w):
    return np.asarray(w).astype(np.float64)  # <- the violation
