"""RR005 fixture: a blocking sleep inside ``async def`` — one stalled
callback freezes every client the loop serves."""
import time


async def handler():
    time.sleep(0.5)
    return 1
