"""RR006 fixture: the worker thread and the event loop both write
``self.count`` — no lock, no confinement declaration."""
import asyncio
import concurrent.futures


class Door:
    def __init__(self):
        self._pool = concurrent.futures.ThreadPoolExecutor(max_workers=1)
        self.count = 0

    def _work(self):
        self.count += 1

    async def tick(self):
        loop = asyncio.get_running_loop()
        done = await loop.run_in_executor(self._pool, self._work)
        self.count += 1
        return done
