"""RR001 fixture: device-array creation at module import time.

The gp/likelihoods.py bug class: this initializes the jax backend before
any launcher can force the virtual device count.
"""
import jax.numpy as jnp

QUAD_NODES = jnp.linspace(-1.0, 1.0, 8)  # <- the violation


def uses_it(x):
    # lazy use is fine; only the module-scope creation above is the bug
    return jnp.sum(QUAD_NODES * x)
