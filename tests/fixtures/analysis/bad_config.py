"""RR004 fixture: a frozen config dataclass that never validates."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class SilentConfig:
    mode: str = "replicated"
    q_max: int = -3  # an illegal value nothing will ever reject
