"""Partitioner + neighborhood topology invariants (unit + property tests)."""
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dependency (pyproject [dev]); shim sweeps
    from _hypothesis_shim import given, settings, st

from repro.core.partition import make_grid, partition_data, partition_centers
from repro.core.neighbors import boundary_probes, direction_permutations, neighbor_table
from repro.data.spatial import e3sm_like_field


def test_partition_counts_conserved():
    ds = e3sm_like_field(n=5000, seed=1)
    grid = make_grid(ds.x, 10, 10)
    data = partition_data(ds.x, ds.y, grid)
    assert int(np.sum(np.asarray(data.counts))) == 5000
    assert np.allclose(np.asarray(data.mask).sum(), 5000)


def test_partition_points_in_cell():
    ds = e3sm_like_field(n=2000, seed=2)
    grid = make_grid(ds.x, 5, 4)
    data = partition_data(ds.x, ds.y, grid)
    x = np.asarray(data.x)
    m = np.asarray(data.mask)
    for p in range(grid.num_partitions):
        ix, iy = grid.cell_of(p)
        pts = x[p][m[p] > 0]
        if len(pts) == 0:
            continue
        assert pts[:, 0].min() >= grid.x_edges[ix] - 1e-5
        assert pts[:, 0].max() <= grid.x_edges[ix + 1] + 1e-5
        assert pts[:, 1].min() >= grid.y_edges[iy] - 1e-5
        assert pts[:, 1].max() <= grid.y_edges[iy + 1] + 1e-5


def test_pole_partitions_are_sparse():
    """Uniform-on-sphere sampling must reproduce the paper's unbalanced
    partitioning (pole partitions have fewer observations)."""
    ds = e3sm_like_field(n=48602, seed=0)
    grid = make_grid(ds.x, 20, 20)
    data = partition_data(ds.x, ds.y, grid)
    counts = np.asarray(data.counts).reshape(20, 20)  # (iy, ix)
    pole_rows = counts[[0, -1]].mean()
    equator_rows = counts[9:11].mean()
    assert pole_rows < 0.5 * equator_rows
    # the paper's numbers: 8..222 per partition, median ~150
    assert np.median(counts) > 50


@given(gx=st.integers(2, 7), gy=st.integers(2, 7), wrap=st.booleans())
@settings(max_examples=25, deadline=None)
def test_neighbor_table_symmetry(gx, gy, wrap):
    """j in N_k iff k in N_j; self always slot 0; wrap only in x."""
    grid = make_grid(np.zeros((1, 2), np.float32), gx, gy, wrap_x=wrap,
                     bounds=(0.0, 1.0, 0.0, 1.0))
    tbl = neighbor_table(grid)
    P = grid.num_partitions
    assert (tbl[:, 0] == np.arange(P)).all()
    for j in range(P):
        for s in range(1, 5):
            k = tbl[j, s]
            if k < 0:
                continue
            assert j in tbl[k, 1:], (j, k)
    # edge-sharing counts: interior partitions have 4 neighbors
    interior = [
        grid.index_of(ix, iy) for ix in range(1, gx - 1) for iy in range(1, gy - 1)
    ]
    for j in interior:
        assert (tbl[j, 1:] >= 0).all()


@given(gx=st.integers(2, 6), gy=st.integers(2, 6))
@settings(max_examples=20, deadline=None)
def test_direction_permutations_inverse_pairs(gx, gy):
    """east/west (and north/south) perms are inverse on interior cells."""
    grid = make_grid(np.zeros((1, 2), np.float32), gx, gy, bounds=(0, 1, 0, 1))
    perm = direction_permutations(grid)
    tbl = neighbor_table(grid)
    for j in range(grid.num_partitions):
        if tbl[j, 1] >= 0:  # has east neighbor
            assert perm[2][perm[1][j]] == j  # west(east(j)) == j
        if tbl[j, 3] >= 0:
            assert perm[4][perm[3][j]] == j


def test_boundary_probe_count_matches_paper_scale():
    """20x20 unwrapped grid with 23 probes/edge ~= the paper's 17,556."""
    grid = make_grid(np.zeros((1, 2), np.float32), 20, 20, bounds=(0, 1, 0, 1))
    probes = boundary_probes(grid, probes_per_edge=23)
    total = probes.points.shape[0] * probes.points.shape[1]
    assert total == (19 * 20 + 20 * 19) * 23  # 17,480 — paper reports 17,556
    # every probe lies on the shared edge of its (left, right) pair
    for e in range(probes.left.shape[0]):
        l, r = int(probes.left[e]), int(probes.right[e])
        lx, ly = grid.cell_of(l)
        rx, ry = grid.cell_of(r)
        assert abs(lx - rx) + abs(ly - ry) == 1


def test_partition_centers_shape():
    grid = make_grid(np.zeros((1, 2), np.float32), 4, 3, bounds=(0, 4, 0, 3))
    c = partition_centers(grid)
    assert c.shape == (12, 2)
    np.testing.assert_allclose(c[0], [0.5, 0.5])
    np.testing.assert_allclose(c[-1], [3.5, 2.5])
