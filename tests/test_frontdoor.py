"""The async front door (repro.api.frontdoor): golden bitwise property,
admission control, and batching behavior.

The golden property — however concurrent requests interleave, coalesce
into device batches, and demux, every request's (mean, var) equals
serving it alone through ``Server.submit`` — is gated here at BITWISE
strictness wherever the serving program is shape-stable: the sharded
mesh path (fixed (P, q_max) padded blocks; under the smoke marker,
across both router policies) and any same-shape replicated comparison.
Replicated cross-shape comparisons are gated at float32 resolution
instead: XLA re-specializes ``fitted.predict`` per batch shape, and a
tiny request inside a large batch can round a last bit differently than
alone (measured ~1e-7 ULP noise on CPU — see the frontdoor module
docstring). The determinism does not depend on scheduling, so the
jittered async clients are a real adversarial schedule, not a fixed
script.

Replicated tests run in-process (no mesh). The sharded test runs in a
subprocess because virtual host devices must be forced before the jax
backend initializes (same pattern as tests/test_api.py).
"""
import asyncio
import logging
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro import api
from repro.data.spatial import e3sm_like_field

REPO = Path(__file__).resolve().parent.parent


class _AsyncioLogCapture(logging.Handler):
    def __init__(self):
        super().__init__(level=logging.WARNING)
        self.messages = []

    def emit(self, record):
        self.messages.append(record.getMessage())


@pytest.fixture(autouse=True)
def _fail_on_slow_loop_callbacks():
    """Satellite gate: under ``PYTHONASYNCIODEBUG=1`` (the CI tier-1 lane
    runs this module that way) any event-loop callback over the 100 ms
    slow-callback threshold is a FAILURE, not a log line. The dispatch and
    collect executors exist precisely so jit recompiles and device syncs
    never run on the loop; this fixture turns that design claim into an
    assertion. A no-op without the env var, so local plain runs behave."""
    if not os.environ.get("PYTHONASYNCIODEBUG"):
        yield
        return
    handler = _AsyncioLogCapture()
    log = logging.getLogger("asyncio")
    log.addHandler(handler)
    try:
        yield
    finally:
        log.removeHandler(handler)
    slow = [m for m in handler.messages if "Executing" in m and "took" in m]
    assert not slow, f"blocking work ran on the event loop: {slow}"


@pytest.fixture(scope="module")
def server():
    """One small replicated server shared by the in-process tests."""
    ds = e3sm_like_field(n=500, seed=0)
    fitted = api.fit(api.FitConfig(grid=2, m=4, train_iters=60, seed=0), ds)
    return api.Server(fitted)


def _requests(server, n_req, seed, max_rows=64):
    rng = np.random.default_rng(seed)
    lo, hi = server.fitted.grid.x_edges[0], server.fitted.grid.x_edges[-1]
    lo_y, hi_y = server.fitted.grid.y_edges[0], server.fitted.grid.y_edges[-1]
    return [
        rng.uniform(
            [lo, lo_y], [hi, hi_y], (int(rng.integers(1, max_rows + 1)), 2)
        ).astype(np.float32)
        for _ in range(n_req)
    ]


def _assert_bitwise(got, solo, tag=""):
    for i, ((mg, vg), (ms, vs)) in enumerate(zip(got, solo, strict=True)):
        assert np.array_equal(mg, ms) and np.array_equal(vg, vs), (tag, i)


def _assert_f32_equal(got, solo, tag=""):
    """Replicated cross-shape gate: exact to float32 resolution (XLA
    shape specialization allows ULP-level drift, nothing more)."""
    for i, ((mg, vg), (ms, vs)) in enumerate(zip(got, solo, strict=True)):
        np.testing.assert_allclose(mg, ms, atol=1e-5, rtol=1e-5,
                                   err_msg=f"{tag} mean req {i}")
        np.testing.assert_allclose(vg, vs, atol=1e-5, rtol=1e-5,
                                   err_msg=f"{tag} var req {i}")


def test_concurrent_clients_equal_solo(server):
    """12 async clients with seeded jitter: every coalesced-then-demuxed
    answer equals the solo ``Server.submit`` answer (float32-exact; the
    window composition varies with scheduling, so the batch shapes do
    too), and the report accounts for every request."""
    reqs = _requests(server, 12, seed=1)
    jitter = np.random.default_rng(2).uniform(0, 0.004, len(reqs))

    async def client(fd, i):
        await asyncio.sleep(float(jitter[i]))
        return await fd.submit(reqs[i])

    async def main():
        async with api.FrontDoor(
            server, api.FrontDoorConfig(max_wait_ms=2.0, max_rows=256)
        ) as fd:
            got = await asyncio.gather(*(client(fd, i) for i in range(len(reqs))))
        return got, fd.report()

    got, rep = asyncio.run(main())
    _assert_f32_equal(got, [server.submit(q) for q in reqs])
    r = rep["requests"]
    assert r["arrived"] == r["admitted"] == r["completed"] == len(reqs)
    assert r["shed"] == 0
    assert rep["batches"]["rows_total"] == sum(len(q) for q in reqs)
    assert rep["latency_ms"]["p95_ms"] > 0
    assert rep["recompiles"] == 0  # replicated path has no q_max policy


def test_submit_many_equal_solo_and_exact_demux(server):
    """The synchronous coalesce seam under the front door: one device
    batch, per-request answers float32-exact vs solo submits — and
    BITWISE equal to slicing the coalesced batch's own results (demux is
    pure bookkeeping, never arithmetic)."""
    from repro.core import routing

    reqs = _requests(server, 7, seed=3)
    many = server.submit_many(reqs)
    _assert_f32_equal(many, [server.submit(q) for q in reqs])
    pts, sizes = routing.coalesce_requests(reqs)
    mean, var = server.submit(pts)
    off = 0
    for (mg, vg), n in zip(many, sizes, strict=True):
        np.testing.assert_array_equal(mg, mean[off:off + n])
        np.testing.assert_array_equal(vg, var[off:off + n])
        off += int(n)


def test_requests_coalesce_into_one_batch(server):
    """Requests queued before the engine wakes share ONE device batch —
    the continuous-batching window actually coalesces — and the answers
    are BITWISE the ``submit_many`` answers (identical coalesced batch,
    identical program: same-shape determinism holds even replicated)."""
    reqs = _requests(server, 6, seed=4, max_rows=8)

    async def main():
        async with api.FrontDoor(
            server, api.FrontDoorConfig(max_wait_ms=20.0, max_rows=4096)
        ) as fd:
            got = await asyncio.gather(*(fd.submit(q) for q in reqs))
        return got, fd.report()

    got, rep = asyncio.run(main())
    assert rep["batches"]["count"] == 1
    assert rep["batches"]["requests_per_batch_mean"] == 6.0
    _assert_bitwise(got, server.submit_many(reqs))


def test_shed_admission_rejects_over_capacity(server):
    """admission="shed": a client arriving at a full queue gets
    ``RequestRejected`` immediately; admitted requests still complete and
    the report counts both sides."""

    async def main():
        fd = api.FrontDoor(
            server,
            api.FrontDoorConfig(queue_depth=1, admission="shed", max_wait_ms=1.0),
        )
        reqs = _requests(server, 8, seed=5, max_rows=4)
        got = await asyncio.gather(
            *(fd.submit(q) for q in reqs), return_exceptions=True
        )
        await fd.close()
        return got, fd.report()

    got, rep = asyncio.run(main())
    shed = [g for g in got if isinstance(g, api.RequestRejected)]
    served = [g for g in got if not isinstance(g, BaseException)]
    assert shed and served  # queue_depth=1 cannot hold 8 concurrent arrivals
    assert len(shed) + len(served) == 8
    r = rep["requests"]
    assert r["shed"] == len(shed) and r["completed"] == len(served)
    assert r["arrived"] == 8 and r["admitted"] == len(served)


def test_delay_admission_backpressures_and_serves_all(server):
    """admission="delay": a full queue blocks the client instead of
    shedding — every request completes, the delays are counted."""

    async def main():
        async with api.FrontDoor(
            server,
            api.FrontDoorConfig(queue_depth=1, admission="delay", max_wait_ms=1.0),
        ) as fd:
            reqs = _requests(server, 6, seed=6, max_rows=4)
            got = await asyncio.gather(*(fd.submit(q) for q in reqs))
        return got, reqs, fd.report()

    got, reqs, rep = asyncio.run(main())
    _assert_f32_equal(got, [server.submit(q) for q in reqs])
    r = rep["requests"]
    assert r["completed"] == 6 and r["shed"] == 0
    assert r["delayed"] >= 1  # depth-1 queue cannot admit 6 burst arrivals


def test_validation_and_lifecycle(server):
    """Malformed requests fail fast with ValueError (never reaching a
    batch); oversized requests point the caller at Server.submit; a
    closed front door refuses new work; close is idempotent."""

    async def main():
        fd = api.FrontDoor(server, api.FrontDoorConfig(max_request_rows=8))
        with pytest.raises(ValueError, match=r"\(n, 2\)"):
            await fd.submit(np.zeros((3, 3), np.float32))
        with pytest.raises(ValueError, match="Server.submit"):
            await fd.submit(np.zeros((9, 2), np.float32))
        with pytest.raises(ValueError):
            await fd.submit(np.zeros((0, 2), np.float32))
        # one real request so the engine actually runs before closing
        out = await fd.submit(np.array([[0.5, 0.5]], np.float32))
        assert out[0].shape == (1,)
        await fd.close()
        await fd.close()  # idempotent
        with pytest.raises(RuntimeError, match="closed"):
            await fd.submit(np.array([[0.5, 0.5]], np.float32))
        rep = fd.report()  # report stays readable after close
        assert rep["requests"]["completed"] == 1

    asyncio.run(main())


def test_oversized_request_raises_typed_request_too_large(server):
    """An over-cap request raises the TYPED ``RequestTooLarge`` — a
    ``ValueError`` subclass (existing callers keep working) that the
    transport layer maps to HTTP 413 without string-matching — while an
    empty request stays a plain ValueError (a malformed request, not an
    admission decision)."""
    assert issubclass(api.RequestTooLarge, ValueError)

    async def main():
        fd = api.FrontDoor(server, api.FrontDoorConfig(max_request_rows=8))
        with pytest.raises(api.RequestTooLarge, match="Server.submit"):
            await fd.submit(np.zeros((9, 2), np.float32))
        with pytest.raises(ValueError) as exc:
            await fd.submit(np.zeros((0, 2), np.float32))
        assert not isinstance(exc.value, api.RequestTooLarge)
        assert not fd.broken  # validation rejections never break the engine
        await fd.close()

    asyncio.run(main())


def test_engine_crash_rejects_all_queued_futures(server):
    """The engine dying mid-stream must REJECT every windowed and queued
    future — a hung client is worse than an error — and the door must
    refuse new submits yet still close cleanly afterwards."""
    reqs = _requests(server, 10, seed=7, max_rows=4)

    async def main():
        fd = api.FrontDoor(
            server,
            api.FrontDoorConfig(max_wait_ms=1.0, max_rows=8, max_request_rows=4),
        )
        real_submit = fd._submit
        calls = 0

        def boom(routed):
            nonlocal calls
            calls += 1
            if calls >= 2:  # batch 1 dispatches fine; batch 2 kills the engine
                raise RuntimeError("boom")
            return real_submit(routed)

        fd._submit = boom
        got = await asyncio.wait_for(
            asyncio.gather(*(fd.submit(q) for q in reqs), return_exceptions=True),
            timeout=30,  # the bug this gates is clients hanging forever
        )
        with pytest.raises(RuntimeError, match="engine failed"):
            await fd.submit(np.array([[0.5, 0.5]], np.float32))
        await fd.close()  # close after a crash must not hang either
        return got

    got = asyncio.run(main())
    served = [g for g in got if not isinstance(g, BaseException)]
    failed = [g for g in got if isinstance(g, BaseException)]
    assert len(served) + len(failed) == len(reqs)
    assert served and failed  # batch 1 answered; the crash rejected the rest
    assert all(isinstance(g, RuntimeError) for g in failed), failed


def test_collect_failure_rejects_batch_but_engine_survives(server):
    """A device-side failure (collect raising) rejects THAT batch's
    clients and nothing else — the engine keeps serving later windows."""

    async def main():
        fd = api.FrontDoor(server, api.FrontDoorConfig(max_wait_ms=1.0))
        real_collect = fd._collect
        failed_once = False

        def flaky(handle):
            nonlocal failed_once
            if not failed_once:
                failed_once = True
                raise RuntimeError("device fell over")
            return real_collect(handle)

        fd._collect = flaky
        with pytest.raises(RuntimeError, match="device fell over"):
            await fd.submit(np.array([[0.5, 0.5]], np.float32))
        out = await fd.submit(np.array([[0.5, 0.5]], np.float32))
        await fd.close()
        return out, fd.report()

    (mean, var), rep = asyncio.run(main())
    assert mean.shape == (1,) and var.shape == (1,)
    assert rep["requests"]["arrived"] == 2
    assert rep["requests"]["completed"] == 1


# ---------------------------------------------------------------------------
# sharded mesh path: golden bitwise property across router policies
# (subprocess: virtual host devices before jax init — see test_api.py)
# ---------------------------------------------------------------------------

_SHARDED_FRONTDOOR_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=9"
    import asyncio

    import numpy as np

    from repro import api
    from repro.data.spatial import e3sm_like_field

    GS, M, IT = 3, 4, 120
    ds = e3sm_like_field(n=1000, seed=0)
    fitted = api.fit(api.FitConfig(grid=GS, m=M, train_iters=IT, seed=0), ds)
    lo, hi = ds.x.min(axis=0), ds.x.max(axis=0)

    for router in ("single", "two-level"):
        server = api.Server(fitted, api.ServeConfig(
            mode="sharded", pipeline="pipelined", router=router, backend="ref"))
        rng = np.random.default_rng(11)
        reqs = [rng.uniform(lo, hi, (int(rng.integers(1, 65)), 2))
                    .astype(np.float32) for _ in range(10)]
        jitter = rng.uniform(0, 0.01, len(reqs))

        async def client(fd, i):
            await asyncio.sleep(float(jitter[i]))
            return await fd.submit(reqs[i])

        async def main():
            async with api.FrontDoor(
                server, api.FrontDoorConfig(max_wait_ms=3.0, max_rows=256)
            ) as fd:
                got = await asyncio.gather(
                    *(client(fd, i) for i in range(len(reqs))))
            return got, fd.report()

        got, rep = asyncio.run(main())
        # the streaming policy grew q_max at least once under the stream,
        # i.e. the device program recompiled while the queue absorbed load
        assert rep["recompiles"] >= 1, rep["recompiles"]
        assert rep["requests"]["completed"] == len(reqs)
        for i, ((mg, vg), q) in enumerate(zip(got, reqs)):
            ms, vs = server.submit(q)
            assert np.array_equal(mg, ms) and np.array_equal(vg, vs), (router, i)
        print(f"golden: frontdoor bitwise == solo submit ({router})")
    print("SHARDED-FRONTDOOR-OK")
    """
)


@pytest.mark.smoke
def test_sharded_frontdoor_golden_across_routers():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-c", _SHARDED_FRONTDOOR_SCRIPT],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=570,
    )
    assert r.returncode == 0, r.stderr[-4000:]
    assert "SHARDED-FRONTDOOR-OK" in r.stdout
