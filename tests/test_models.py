"""LM substrate tests: every block kind, train + serve, cache consistency.

Cache-vs-full-forward equality is THE correctness property for serving: a
decode step at position S against a prefilled cache must reproduce the
logits of an uncached forward over the S+1 tokens.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer
from repro.models.config import (
    EncoderConfig,
    MLAConfig,
    MoEConfig,
    ModelConfig,
    VisionStubConfig,
)
from repro.runtime.steps import (
    cross_entropy,
    init_train_state,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _dense(**kw):
    base = dict(
        name="t-dense", arch_type="dense", num_layers=4, d_model=128, num_heads=4,
        num_kv_heads=2, d_ff=256, vocab_size=512, dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base).validate()


CONFIGS = {
    "dense-gqa": _dense(qk_norm=True, qkv_bias=True),
    "dense-swa": _dense(name="t-swa", sliding_window=16, block_pattern=("local_attn",)),
    "mla": _dense(
        name="t-mla", block_pattern=("mla",),
        mla=MLAConfig(q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16),
    ),
    "moe": ModelConfig(
        name="t-moe", arch_type="moe", num_layers=3, d_model=128, num_heads=4,
        num_kv_heads=4, d_ff=256, vocab_size=512, dtype="float32",
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=64, num_shared=1,
                      first_layer_dense=True, dense_d_ff=256, capacity_factor=4.0),
    ).validate(),
    "xlstm": ModelConfig(
        name="t-xlstm", arch_type="ssm", num_layers=4, d_model=128, num_heads=4,
        num_kv_heads=4, d_ff=0, vocab_size=512, dtype="float32", mlp_kind="none",
        rnn_width=256, block_pattern=("mlstm", "mlstm", "mlstm", "slstm"), pos_kind="none",
    ).validate(),
    "hybrid": ModelConfig(
        name="t-rg", arch_type="hybrid", num_layers=3, d_model=128, num_heads=4,
        num_kv_heads=1, d_ff=256, vocab_size=512, dtype="float32", sliding_window=16,
        block_pattern=("rglru", "rglru", "local_attn"),
    ).validate(),
}


@pytest.fixture(scope="module")
def toks():
    return jax.random.randint(KEY, (B, S), 0, 256)


@pytest.mark.parametrize("name", list(CONFIGS))
def test_train_step_finite_and_decreases(name, toks):
    cfg = CONFIGS[name]
    state = init_train_state(KEY, cfg)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, axis=1)}
    ts = jax.jit(make_train_step(cfg, learning_rate=1e-3))
    losses = []
    for _ in range(8):
        state, m = ts(state, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(l) for l in losses)
    assert losses[-1] < losses[0]  # overfits one batch


@pytest.mark.parametrize("name", list(CONFIGS))
def test_decode_matches_full_forward(name, toks):
    """Prefill(S) + decode(1) == uncached forward over S+1 tokens."""
    cfg = CONFIGS[name]
    state = init_train_state(KEY, cfg)
    pf = jax.jit(make_prefill_step(cfg, cache_len=S + 8))
    _, cache = pf(state.params, toks)
    dec = jax.jit(make_decode_step(cfg))
    nxt = toks[:, :1]
    lg, _ = dec(state.params, cache, jnp.asarray(S, jnp.int32), nxt)
    full, _, _ = transformer.forward(state.params, cfg, jnp.concatenate([toks, nxt], 1))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, -1]), atol=2e-4)


def test_multi_token_decode_chain(toks):
    """8 sequential decode steps == uncached forward (dense cfg)."""
    cfg = CONFIGS["dense-gqa"]
    state = init_train_state(KEY, cfg)
    pf = jax.jit(make_prefill_step(cfg, cache_len=S + 16))
    _, cache = pf(state.params, toks)
    dec = jax.jit(make_decode_step(cfg))
    cont = jax.random.randint(jax.random.PRNGKey(5), (B, 8), 0, 256)
    outs = []
    for i in range(8):
        lg, cache = dec(state.params, cache, jnp.asarray(S + i, jnp.int32), cont[:, i : i + 1])
        outs.append(lg)
    full, _, _ = transformer.forward(state.params, cfg, jnp.concatenate([toks, cont], 1))
    got = np.stack([np.asarray(o) for o in outs], axis=1)  # (B, 8, V)
    np.testing.assert_allclose(got, np.asarray(full[:, S:]), atol=2e-4)


def test_sliding_window_ring_cache_long_decode():
    """Decode far beyond the window: ring cache (window slots) must agree
    with an uncached forward — the property long_500k relies on."""
    cfg = _dense(name="t-swa2", num_layers=2, sliding_window=8, block_pattern=("local_attn",))
    state = init_train_state(KEY, cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 12), 0, 256)  # > window
    pf = jax.jit(make_prefill_step(cfg, cache_len=64))
    _, cache = pf(state.params, prompt)
    assert cache["stack"]["b0"]["k"].shape[2] == cfg.sliding_window  # (P, B, W, KV, hd)
    dec = jax.jit(make_decode_step(cfg))
    cont = jax.random.randint(jax.random.PRNGKey(3), (1, 10), 0, 256)
    outs = []
    for i in range(10):
        lg, cache = dec(state.params, cache, jnp.asarray(12 + i, jnp.int32), cont[:, i : i + 1])
        outs.append(np.asarray(lg))
    full, _, _ = transformer.forward(state.params, cfg, jnp.concatenate([prompt, cont], 1))
    np.testing.assert_allclose(
        np.stack(outs, 1), np.asarray(full[:, 12:]), atol=3e-4
    )


def test_whisper_style_encdec(toks):
    cfg = ModelConfig(
        name="t-encdec", arch_type="audio", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=4, d_ff=256, vocab_size=512, dtype="float32", mlp_kind="gelu",
        pos_kind="learned", max_position=128,
        encoder=EncoderConfig(num_layers=2, num_frames=20, frontend_dim=64),
    ).validate()
    state = init_train_state(KEY, cfg)
    frames = jax.random.normal(KEY, (B, 20, 64))
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, 1), "frames": frames}
    ts = jax.jit(make_train_step(cfg, learning_rate=1e-3))
    state, m = ts(state, batch)
    assert np.isfinite(float(m["loss"]))
    # serve: prefill consumes the prompt + frames, decode runs without frames
    pf = jax.jit(make_prefill_step(cfg, cache_len=S + 8))
    _, cache = pf(state.params, toks, frames=frames)
    dec = jax.jit(make_decode_step(cfg))
    lg, _ = dec(state.params, cache, jnp.asarray(S, jnp.int32), toks[:, :1])
    assert np.isfinite(np.asarray(lg)).all()


def test_vlm_patches_prepended(toks):
    cfg = ModelConfig(
        name="t-vlm", arch_type="vlm", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, d_ff=256, vocab_size=512, dtype="float32",
        vision=VisionStubConfig(num_patches=8, vit_dim=96),
    ).validate()
    state = init_train_state(KEY, cfg)
    patches = jax.random.normal(KEY, (B, 8, 96))
    logits, _, _ = transformer.forward(state.params, cfg, toks, patches=patches)
    assert logits.shape == (B, 8 + S, cfg.vocab_size)  # image tokens prepended
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, 1), "patches": patches}
    ts = jax.jit(make_train_step(cfg, learning_rate=1e-3))
    state, m = ts(state, batch)
    assert np.isfinite(float(m["loss"]))


def test_remainder_layers_used():
    """num_layers not divisible by the pattern period: remainder layers
    must exist and contribute (26-layer RecurrentGemma case)."""
    cfg = ModelConfig(
        name="t-rem", arch_type="hybrid", num_layers=5, d_model=64, num_heads=2,
        num_kv_heads=1, d_ff=128, vocab_size=128, dtype="float32", sliding_window=8,
        block_pattern=("rglru", "rglru", "local_attn"),
    ).validate()
    params = transformer.init_model_params(KEY, cfg)
    assert len(params["remainder"]) == 2
    t = jax.random.randint(KEY, (1, 16), 0, 128)
    lg, _, _ = transformer.forward(params, cfg, t)
    assert np.isfinite(np.asarray(lg)).all()
    # zeroing a remainder layer's output-proj changes logits => it is used
    params2 = jax.tree.map(lambda a: a, params)
    params2["remainder"][0]["mix"]["w_out"] = jnp.zeros_like(
        params2["remainder"][0]["mix"]["w_out"]
    )
    lg2, _, _ = transformer.forward(params2, cfg, t)
    assert float(jnp.max(jnp.abs(lg - lg2))) > 1e-6


def test_cross_entropy_uniform():
    V = 64
    logits = jnp.zeros((2, 3, V))
    tgt = jnp.zeros((2, 3), jnp.int32)
    np.testing.assert_allclose(float(cross_entropy(logits, tgt)), np.log(V), rtol=1e-5)


def test_moe_capacity_drops_and_aux():
    """Tight capacity drops tokens (output changes) but keeps finiteness;
    aux loss is ~1 for a balanced router at init."""
    from repro.models.moe import moe_forward, init_moe_params

    cfg = CONFIGS["moe"]
    p = init_moe_params(KEY, cfg)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    # dispatch_groups=1: tiny per-group token counts never exceed capacity,
    # so drop behaviour is exercised with a single global group here
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, dispatch_groups=1))
    out_hi, aux = moe_forward(p, cfg, x)
    cfg_lo = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.25, dispatch_groups=1)
    )
    out_lo, _ = moe_forward(p, cfg_lo, x)
    assert np.isfinite(np.asarray(out_lo)).all()
    assert float(jnp.max(jnp.abs(out_hi - out_lo))) > 1e-6
    assert 0.5 < float(aux) < 2.0
