"""Dry-run smoke: one representative (arch x shape) per step kind lowers and
compiles on the production meshes inside a subprocess (512 virtual devices).

The full 40-combo sweep runs via
  PYTHONPATH=src python -m repro.launch.dryrun --all --out ...
and its results are recorded in EXPERIMENTS.md; these tests guard the
machinery itself (specs, extrapolation, collective parsing) in CI time.
"""
import json
import os
import subprocess
import sys

import pytest

_CASES = [
    ("qwen3_0_6b", "train_4k", []),
    ("recurrentgemma_2b", "long_500k", []),
    ("whisper_base", "decode_32k", []),
    ("deepseek_moe_16b", "prefill_32k", ["--multi-pod"]),
]


def _run(args, timeout=1200):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True, text=True, env=env, timeout=timeout,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape,extra", _CASES)
def test_dryrun_lowers_and_compiles(arch, shape, extra, tmp_path):
    out = tmp_path / "rec.jsonl"
    r = _run(["--arch", arch, "--shape", shape, "--out", str(out), *extra])
    assert r.returncode == 0, r.stderr[-4000:]
    rec = json.loads(out.read_text().strip().splitlines()[-1])
    assert rec["arch"] == arch and rec["shape"] == shape
    assert rec["flops_per_device"] > 0
    assert rec["roofline_s"]["memory"] > 0
    assert rec["dominant"] in ("compute", "memory", "collective")
    if extra:
        assert rec["chips"] == 512  # multi-pod: the pod axis actually shards
    # decode/prefill of real models must communicate something
    assert rec["collective_bytes_per_device"] >= 0


@pytest.mark.slow
def test_dryrun_psvgp_contains_collective_permute(tmp_path):
    out = tmp_path / "psvgp.jsonl"
    r = _run(["--psvgp", "--comm", "ppermute", "--out", str(out)])
    assert r.returncode == 0, r.stderr[-4000:]
    rec = json.loads(out.read_text().strip().splitlines()[-1])
    assert rec["chips"] == 256
    # the paper's decentralized p2p: collective-permute must appear, and the
    # payload must stay tiny (mini-batches only — "lightweight, limited")
    assert "collective-permute" in rec["collective_breakdown"]
    assert rec["collective_bytes_per_device"] < 10e6, rec["collective_breakdown"]


def test_collective_bytes_parser():
    from repro.launch.hlo_analysis import collective_bytes

    hlo = """
      %ag = f32[128,256]{1,0} all-gather(%x), dimensions={0}
      %ar = (bf16[64]{0}, bf16[32]{0}) all-reduce(%a, %b), to_apply=%sum
      %cp = f32[8]{0} collective-permute(%y), source_target_pairs={{0,1}}
      %a2a = f32[16,16]{1,0} all-to-all(%z), dimensions={1}
      %rs = f32[4096]{0} reduce-scatter(%w), dimensions={0}
      %not_a_coll = f32[2]{0} add(%p, %q)
    """
    got = collective_bytes(hlo)
    assert got["all-gather"] == 128 * 256 * 4
    assert got["all-reduce"] == (64 + 32) * 2
    assert got["collective-permute"] == 8 * 4
    assert got["all-to-all"] == 16 * 16 * 4
    assert got["reduce-scatter"] == 4096 * 4
    assert set(got) == {"all-gather", "all-reduce", "collective-permute",
                        "all-to-all", "reduce-scatter"}
