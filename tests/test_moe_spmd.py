"""The shard_map MoE path (§Perf-2) must agree with the local path.

Runs in a subprocess with 16 virtual devices: same params, same tokens —
the manually-partitioned dispatch must reproduce the single-device outputs
(capacity generous so no drops differ; grads checked too).
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.models.config import ModelConfig, MoEConfig
    from repro.models.moe import init_moe_params, moe_forward, _moe_forward_local
    from repro.runtime import compat

    cfg = ModelConfig(
        name="t", arch_type="moe", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, d_ff=64, vocab_size=128, dtype="float32",
        moe=MoEConfig(num_experts=8, top_k=2, d_expert=32, num_shared=1,
                      capacity_factor=8.0, dispatch_groups=1),
    ).validate()
    p = init_moe_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 64))

    # local reference (no mesh)
    out_ref, aux_ref = _moe_forward_local(p, cfg, x)
    gref = jax.grad(lambda pp: _moe_forward_local(pp, cfg, x)[0].sum())(p)

    mesh = compat.make_mesh((8, 2), ("data", "model"))
    with compat.set_mesh(mesh):
        out, aux = jax.jit(lambda pp, xx: moe_forward(pp, cfg, xx))(p, x)
        g = jax.jit(jax.grad(lambda pp: moe_forward(pp, cfg, x)[0].sum()))(p)

    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref), atol=2e-5)
    # aux is computed per data shard then averaged (GShard per-group
    # semantics) — close to but not identical with the global statistic
    np.testing.assert_allclose(float(aux), float(aux_ref), rtol=0.15)
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(gref), strict=True):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-4)
    print("OK")
    """
)


@pytest.mark.slow
def test_moe_spmd_matches_local():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))), timeout=900,
    )
    assert r.returncode == 0, r.stderr[-4000:]
    assert "OK" in r.stdout
