"""The static verification layer's own tests.

Three kinds of coverage:

  * the linter catches what it claims to catch — every known-bad fixture
    in tests/fixtures/analysis/ is flagged by EXACTLY its rule, synthetic
    bad HLO text trips each HLO check, and injected violations in real
    lowered programs (an all_gather smuggled into a shard_map) are found;
  * the escape hatches and declarations are load-bearing — noqa lines
    suppress, deleting a @contract is a finding, unknown invariant names
    are findings, manifest rot (a lane dict that stops parsing as a
    ServeConfig) is a finding;
  * the shipped codebase is CLEAN — the AST and async passes over src/,
    the host-side contract harnesses in-process, and the full five-pass
    CLI in a subprocess (which is also the < 120 s budget check, on a
    small grid);
  * the cost gates judge correctly — pure exponent-fit/budget/baseline
    checks on synthetic records in-process, plus REAL compiled injections
    (a replicated cache in the sharded in_specs, a pairwise q_max^2 term)
    in a subprocess, and the CLI baseline-drift / --update-baselines
    round trip.

Mesh-requiring checks (HLO lowering, sharded contracts) run via the CLI
subprocess: the analysis front door forces virtual host devices before
jax initializes, which an already-initialized pytest process cannot.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import Finding, astlint, asynclint, contracts, costs, hlo
from repro.analysis import invariants as inv

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(REPO, "tests", "fixtures", "analysis")


def _fixture(rel):
    path = os.path.join(FIXTURES, rel)
    with open(path, encoding="utf-8") as f:
        return path, f.read()


# --------------------------------------------------------------------------
# Finding / manifest basics
# --------------------------------------------------------------------------


def test_finding_validates():
    with pytest.raises(ValueError):
        Finding("nonsense-pass", "R", "w", "m")
    with pytest.raises(ValueError):
        Finding("ast", "", "w", "m")
    f = Finding("ast", "RR001", "a.py:3", "boom")
    assert f.to_dict()["rule"] == "RR001" and "a.py:3" in str(f)


def test_lane_manifest_is_valid_serve_configs():
    from repro.api.config import ServeConfig

    assert len(inv.LANES) == 14
    names = [l.name for l in inv.LANES]
    assert len(set(names)) == len(names)
    for lane in inv.LANES:
        cfg = ServeConfig.from_dict(lane.serve)  # manifest rot -> raises
        assert cfg.mode in ("replicated", "sharded")
    # exactly 4 distinct device programs behind the 14 lanes
    assert len({l.program_key for l in inv.LANES}) == 4


def test_lane_invariant_rejects_bad_declarations():
    with pytest.raises(ValueError):
        inv.LaneInvariant(
            name="x", serve={}, program="warp-drive", backend="ref",
            max_collective_permute=0, forbidden_ops=(),
        )
    with pytest.raises(ValueError):
        inv.LaneInvariant(
            name="x", serve={}, program="sharded-blend", backend="ref",
            max_collective_permute=2, min_collective_permute=4,
            forbidden_ops=(),
        )
    with pytest.raises(ValueError):
        inv.LaneInvariant(
            name="x", serve={}, program="sharded-blend", backend="ref",
            max_collective_permute=8, forbidden_ops=("warp-gather",),
        )


# --------------------------------------------------------------------------
# HLO pass: text checks on synthetic programs (no jax needed)
# --------------------------------------------------------------------------

SHARDED_LANE = next(l for l in inv.LANES if l.program == "sharded-blend")
REPLICATED_LANE = next(l for l in inv.LANES if l.program == "replicated-blend")

# a minimal halo-shaped program: 4 ppermutes, f32 only
GOOD_TEXT = "\n".join(
    f'%r{i} = "stablehlo.collective_permute"(%a) : tensor<9x64xf32>'
    for i in range(4)
)


def _rules(findings):
    return sorted({f.rule for f in findings})


def test_hlo_good_text_is_clean():
    findings, counts = hlo.check_text(SHARDED_LANE, GOOD_TEXT)
    assert findings == [] and counts["collective-permute"] == 4


@pytest.mark.parametrize(
    "mutation,rule",
    [
        # a gathering collective in a sharded program
        ('%g = "stablehlo.all_gather"(%a) : tensor<16x8xf32>', "HLO-FORBIDDEN-OP"),
        # HLO (dashed) spelling must be caught too
        ("%g = all-gather(%a)", "HLO-FORBIDDEN-OP"),
        ("%g = all-reduce-start(%a)", "HLO-FORBIDDEN-OP"),
        # an f64 leak
        ("%c = stablehlo.constant dense<0.5> : tensor<64xf64>", "HLO-DTYPE-F64"),
        ("%c = f64[9,64] constant(...)", "HLO-DTYPE-F64"),
        # a host transfer inside the compiled program
        ('%h = "stablehlo.infeed"(%tok)', "HLO-HOST-TRANSFER"),
        ("%h = xla_python_cpu_callback(%a)", "HLO-HOST-TRANSFER"),
    ],
)
def test_hlo_bad_text_caught_by_exactly_the_expected_rule(mutation, rule):
    findings, _ = hlo.check_text(SHARDED_LANE, GOOD_TEXT + "\n" + mutation)
    assert _rules(findings) == [rule], findings


def test_hlo_budget_and_floor():
    over = GOOD_TEXT + "\n" + "\n".join(
        f'%e{i} = "stablehlo.collective_permute"(%a)' for i in range(9)
    )
    findings, counts = hlo.check_text(SHARDED_LANE, over)
    assert _rules(findings) == ["HLO-COLLECTIVE-BUDGET"] and counts[
        "collective-permute"
    ] == 13
    # the floor: a sharded program whose halo vanished is wrong too
    findings, _ = hlo.check_text(SHARDED_LANE, "%z = stablehlo.add(%a, %b)")
    assert _rules(findings) == ["HLO-COLLECTIVE-MISSING"]


def test_hlo_replicated_lane_forbids_all_collectives():
    findings, _ = hlo.check_text(REPLICATED_LANE, GOOD_TEXT)
    assert "HLO-COLLECTIVE-BUDGET" in _rules(findings)
    findings, _ = hlo.check_text(
        REPLICATED_LANE, '%r = "stablehlo.all_reduce"(%a)'
    )
    assert "HLO-FORBIDDEN-OP" in _rules(findings)


def test_hlo_manifest_rot_is_a_finding():
    rotten = inv.LaneInvariant(
        name="rotten", serve={"mode": "sharded", "warp_factor": 9},
        program="sharded-blend", backend="ref",
        max_collective_permute=8, forbidden_ops=(),
    )
    findings, report = hlo.run(lanes=(rotten,))
    assert _rules(findings) == ["HLO-MANIFEST"]
    assert report["lanes"] == []  # never lowered


# --------------------------------------------------------------------------
# AST pass: fixtures each caught by exactly the expected rule
# --------------------------------------------------------------------------


@pytest.mark.parametrize(
    "rel,rule",
    [
        ("bad_import_time.py", "RR001"),
        (os.path.join("repro", "core", "routing.py"), "RR002"),
        (os.path.join("repro", "kernels", "bad_f64.py"), "RR003"),
        ("bad_config.py", "RR004"),
    ],
)
def test_fixture_caught_by_exactly_the_expected_rule(rel, rule):
    path, source = _fixture(rel)
    findings = astlint.lint_source(path, source)
    assert findings, f"{rel}: nothing caught"
    assert _rules(findings) == [rule], findings


def test_noqa_suppresses():
    path, source = _fixture("suppressed_ok.py")
    assert astlint.lint_source(path, source) == []
    # and removing the noqa markers brings the findings back
    stripped = "\n".join(
        line.split("# repro: noqa-")[0] for line in source.splitlines()
    )
    assert _rules(astlint.lint_source(path, stripped)) == ["RR001", "RR004"]


def test_rr002_declared_function_cannot_silently_vanish():
    source = "import numpy as np\n"  # none of the declared functions exist
    findings = astlint.lint_source("src/repro/core/routing.py", source)
    assert findings and _rules(findings) == ["RR002"]
    assert any("not found" in f.message for f in findings)


def test_rr001_skips_lazy_contexts():
    source = textwrap.dedent(
        """
        import functools
        import jax
        import jax.numpy as jnp

        def f(x):
            return jnp.asarray(x)

        g = functools.partial(jax.jit, static_argnames=("k",))

        @jax.jit
        def h(x):
            return x
        """
    )
    assert astlint.lint_source("src/repro/x.py", source) == []


def test_rr001_catches_function_default_args():
    source = "import jax.numpy as jnp\ndef f(x=jnp.zeros(3)):\n    return x\n"
    assert _rules(astlint.lint_source("src/repro/x.py", source)) == ["RR001"]


def test_shipped_codebase_is_clean():
    findings, report = astlint.run(os.path.join(REPO, "src"))
    assert findings == [], [str(f) for f in findings]
    assert report["files_scanned"] > 60


def test_fixture_tree_is_dirty_end_to_end():
    findings, _ = astlint.run(FIXTURES)
    assert _rules(findings) == ["RR001", "RR002", "RR003", "RR004"]


# --------------------------------------------------------------------------
# Async pass: fixtures, escape hatch, confinement, shipped-clean
# --------------------------------------------------------------------------


@pytest.mark.parametrize(
    "rel,rule",
    [
        ("bad_async_rr005.py", "RR005"),
        ("bad_async_rr006.py", "RR006"),
        ("bad_async_net_rr006.py", "RR006"),
        ("bad_async_rr007.py", "RR007"),
        ("bad_async_rr008.py", "RR008"),
    ],
)
def test_async_fixture_caught_by_exactly_the_expected_rule(rel, rule):
    path, source = _fixture(rel)
    findings = asynclint.lint_source(path, source)
    assert findings, f"{rel}: nothing caught"
    assert _rules(findings) == [rule], findings


def test_async_noqa_suppresses():
    path, source = _fixture("suppressed_async_ok.py")
    assert asynclint.lint_source(path, source) == []
    stripped = "\n".join(
        line.split("# repro: noqa-")[0] for line in source.splitlines()
    )
    assert _rules(asynclint.lint_source(path, stripped)) == ["RR005", "RR007"]


def test_async_shipped_codebase_is_clean():
    findings, report = asynclint.run(os.path.join(REPO, "src"))
    assert findings == [], [str(f) for f in findings]
    assert report["files_scanned"] > 60


def test_async_fixture_tree_is_dirty_end_to_end():
    findings, _ = asynclint.run(FIXTURES)
    assert _rules(findings) == ["RR005", "RR006", "RR007", "RR008"]


def test_rr005_awaited_asyncio_queue_is_fine_unawaited_is_not():
    good = textwrap.dedent(
        """
        class A:
            async def f(self):
                return await self._queue.get()
        """
    )
    assert asynclint.lint_source("x.py", good) == []
    bad = good.replace("await self._queue.get()", "self._queue.get()")
    assert _rules(asynclint.lint_source("x.py", bad)) == ["RR005"]


def test_rr005_stdlib_queue_is_blocking_even_without_queue_in_the_name():
    source = textwrap.dedent(
        """
        import queue

        jobs = queue.Queue()

        async def f():
            return jobs.get()
        """
    )
    assert _rules(asynclint.lint_source("x.py", source)) == ["RR005"]


def test_rr006_lock_guarded_dual_writes_pass():
    source = textwrap.dedent(
        """
        import asyncio
        import concurrent.futures
        import threading

        class Door:
            def __init__(self):
                self._pool = concurrent.futures.ThreadPoolExecutor(max_workers=1)
                self._stats_lock = threading.Lock()
                self.count = 0

            def _work(self):
                with self._stats_lock:
                    self.count += 1

            async def tick(self):
                loop = asyncio.get_running_loop()
                done = await loop.run_in_executor(self._pool, self._work)
                with self._stats_lock:
                    self.count += 1
                return done
        """
    )
    assert asynclint.lint_source("x.py", source) == []


def test_rr006_confinement_manifest_declares_the_exemption(monkeypatch):
    path, source = _fixture("bad_async_rr006.py")
    assert _rules(asynclint.lint_source(path, source)) == ["RR006"]
    monkeypatch.setitem(
        asynclint.CONFINEMENT,
        "bad_async_rr006.py",
        {"Door": {"count": "test-only: single increment, torn reads ok"}},
    )
    assert asynclint.lint_source(path, source) == []


def test_rr007_stored_or_awaited_spawns_pass():
    source = textwrap.dedent(
        """
        async def main(loop, pool, work):
            t = loop.create_task(work())
            r = await loop.run_in_executor(pool, work)
            await t
            return r
        """
    )
    assert asynclint.lint_source("x.py", source) == []


def test_rr008_rejecting_handler_passes_even_via_helper():
    # the shape of the real FrontDoor._resolve/_engine: fallible work in a
    # try whose handler rejects through a one-call helper
    source = textwrap.dedent(
        """
        async def resolve(batch, collect, pool, loop):
            try:
                mean, var = await loop.run_in_executor(pool, collect, batch.handle)
                outs = demux(batch.sizes, mean, var)
            except Exception as err:
                fail_requests(batch.reqs, err)
                return
            for req, out in zip(batch.reqs, outs):
                req.future.set_result(out)


        def fail_requests(reqs, err):
            for req in reqs:
                if not req.future.done():
                    req.future.set_exception(err)
        """
    )
    assert asynclint.lint_source("x.py", source) == []
    # drop the handler and the orphaned-future path comes back
    naked = textwrap.dedent(
        """
        async def resolve(batch, collect, pool, loop):
            mean, var = await loop.run_in_executor(pool, collect, batch.handle)
            outs = demux(batch.sizes, mean, var)
            for req, out in zip(batch.reqs, outs):
                req.future.set_result(out)
        """
    )
    assert _rules(asynclint.lint_source("x.py", naked)) == ["RR008"]


def test_rr008_engine_shaped_loop_requires_crash_handling():
    source = textwrap.dedent(
        """
        async def engine(self):
            while True:
                reqs = await self._queue.get()
                batch = self._dispatch(reqs)
                pending = self._loop.create_task(self._resolve(batch))
                await pending
        """
    )
    assert _rules(asynclint.lint_source("x.py", source)) == ["RR008"]


# --------------------------------------------------------------------------
# Costs pass: pure judgment on synthetic records (no jax, no mesh)
# --------------------------------------------------------------------------


def test_cost_budget_rejects_bad_declarations():
    kw = dict(scale_axis="q_max", anchor="a", max_flop_exponent=1.3,
              max_flops=1.0, max_bytes_accessed=1.0, max_arg_bytes=1,
              max_temp_bytes=1)
    with pytest.raises(ValueError):
        inv.CostBudget(program="warp-drive", **kw)
    with pytest.raises(ValueError):  # >= quadratic allowance is vacuous
        inv.CostBudget(program="sharded-blend", **{**kw, "max_flop_exponent": 2.0})
    with pytest.raises(ValueError):
        inv.CostBudget(program="sharded-blend", **{**kw, "max_flops": 0.0})
    with pytest.raises(ValueError):
        inv.CostBudget(
            program="sharded-blend", **kw, max_device_exponent=1.5
        )
    assert set(inv.COST_BUDGETS) == {"replicated-blend", "sharded-blend"}


def test_fit_exponent():
    assert costs.fit_exponent([32, 64, 128], [10, 20, 40]) == pytest.approx(1.0)
    assert costs.fit_exponent([2, 4, 8], [4, 16, 64]) == pytest.approx(2.0)
    assert costs.fit_exponent([4, 9, 16], [7, 7, 7]) == pytest.approx(0.0)
    with pytest.raises(ValueError):
        costs.fit_exponent([2], [4])
    with pytest.raises(ValueError):
        costs.fit_exponent([2, 2], [4, 8])


def _mk_sharded(mem_exp=0.0, q_exp=1.0):
    """A synthetic sharded-blend record shaped like the real one: flat per
    device (unless ``mem_exp``), linear in q_max (unless ``q_exp``)."""
    points, axes = {}, {"devices": {}, "q_max": {}}
    for side in (2, 3, 4):
        p = side * side
        lab = f"grid={side}/q=64"
        points[lab] = {
            "flops": 220000.0, "bytes_accessed": 274000.0,
            "arg_bytes": int(7276 * (p / 16) ** mem_exp),
            "out_bytes": 528, "temp_bytes": 73728,
        }
        axes["devices"][lab] = p
    for q in (32, 64, 128):
        lab = f"grid=4/q={q}"
        points.setdefault(lab, {
            "flops": 220000.0 * (q / 64) ** q_exp,
            "bytes_accessed": 274000.0 * q / 64,
            "arg_bytes": int(7276 * q / 64),
            "out_bytes": 528 * q // 64, "temp_bytes": 73728 * q // 64,
        })
        axes["q_max"][lab] = q
    rec = {"points": points, "axes": axes}
    rec["exponents"] = costs.compute_exponents(rec)
    return rec


SHARDED_BUDGET = inv.COST_BUDGETS["sharded-blend"]


def test_cost_healthy_record_is_clean():
    assert costs.check_budget("sharded-blend/ref", _mk_sharded(), SHARDED_BUDGET) == []


def test_cost_replicated_cache_growth_caught():
    rec = _mk_sharded(mem_exp=0.5)  # per-device bytes growing with P
    findings = costs.check_budget("sharded-blend/ref", rec, SHARDED_BUDGET)
    assert _rules(findings) == ["COST-MEM-SCALING"], findings


def test_cost_qmax_flop_blowup_caught():
    rec = _mk_sharded(q_exp=2.0)  # a pairwise term crept in
    findings = costs.check_budget("sharded-blend/ref", rec, SHARDED_BUDGET)
    assert "COST-FLOP-SUPERLINEAR" in _rules(findings), findings


def test_cost_absolute_ceiling_and_missing_anchor_caught():
    import dataclasses

    rec = _mk_sharded()
    for lab in rec["points"]:
        rec["points"][lab]["temp_bytes"] = 10_000_000
    rec["exponents"] = costs.compute_exponents(rec)
    findings = costs.check_budget("sharded-blend/ref", rec, SHARDED_BUDGET)
    assert _rules(findings) == ["COST-BUDGET"], findings
    moved = dataclasses.replace(SHARDED_BUDGET, anchor="grid=9/q=9")
    findings = costs.check_budget("sharded-blend/ref", _mk_sharded(), moved)
    assert _rules(findings) == ["COST-BUDGET"]
    assert any("anchor" in f.message for f in findings)


def test_cost_baseline_drift_missing_and_improvement():
    rec = _mk_sharded()
    base = {"points": {lab: dict(m) for lab, m in rec["points"].items()}}
    assert costs.check_baseline("sharded-blend/ref", rec, base) == []
    # regression: one metric doubles -> drift finding
    worse = _mk_sharded()
    worse["points"]["grid=4/q=64"]["flops"] *= 2
    findings = costs.check_baseline("sharded-blend/ref", worse, base)
    assert _rules(findings) == ["COST-BASELINE-DRIFT"], findings
    # improvement: cheaper never gates
    better = _mk_sharded()
    better["points"]["grid=4/q=64"]["flops"] /= 2
    assert costs.check_baseline("sharded-blend/ref", better, base) == []
    # a scale point the baseline has never seen gates
    short = {"points": {k: v for k, v in base["points"].items()
                        if k != "grid=4/q=128"}}
    findings = costs.check_baseline("sharded-blend/ref", rec, short)
    assert _rules(findings) == ["COST-BASELINE-MISSING"]
    # no baseline at all gates with the how-to-fix message
    findings = costs.check_baseline("sharded-blend/ref", rec, None)
    assert _rules(findings) == ["COST-BASELINE-MISSING"]
    assert any("--update-baselines" in f.message for f in findings)


def test_lane_cost_records_cover_every_lane():
    repl_points = {
        f"n={n}": {"flops": 2300.0 * n, "bytes_accessed": 5900.0 * n,
                   "arg_bytes": 20000, "out_bytes": 8 * n + 16,
                   "temp_bytes": 576 * n}
        for n in (128, 256, 512)
    }
    repl = {"points": repl_points,
            "axes": {"n_queries": {f"n={n}": n for n in (128, 256, 512)}}}
    repl["exponents"] = costs.compute_exponents(repl)
    programs = {"replicated-blend/ref": repl, "sharded-blend/ref": _mk_sharded()}
    records = costs.lane_cost_records(programs)
    assert len(records) == len(inv.LANES)
    skipped = [r for r in records if "skipped" in r]
    measured = [r for r in records if "anchor_cost" in r]
    assert len(skipped) + len(measured) == len(records)
    # every pallas/fused lane is skipped WITH a reason; every ref lane maps
    # to its program's anchor cost and exponents
    assert skipped and all(
        r["program"].endswith(("/pallas", "/fused")) for r in skipped
    )
    for r in measured:
        assert r["anchor_cost"] is not None and r["exponents"]


# --------------------------------------------------------------------------
# Contracts pass
# --------------------------------------------------------------------------


def test_parse_and_unify():
    assert contracts.parse_shape("(S, Q, 4)") == ("S", "Q", 4)
    assert contracts.parse_shape("(N,)") == ("N",)
    env = {}
    assert contracts.unify("(S, Q)", (9, 64), env) is None
    assert env == {"S": 9, "Q": 64}
    assert contracts.unify("(S, 4)", (9, 4), env) is None
    assert contracts.unify("(S, Q)", (8, 64), env)  # S rebind -> error
    assert contracts.unify("(S, Q)", (9,), env)  # rank -> error
    assert contracts.unify("(S, 4)", (9, 5), env)  # literal -> error
    with pytest.raises(ValueError):
        contracts.parse_shape("S, Q")


def test_missing_contract_is_a_finding():
    import importlib

    target = contracts.EXPECTED_TARGETS[1]  # scatter_results, host-only
    importlib.import_module(target.rsplit(".", 1)[0])  # populate registry
    saved = contracts._REGISTRY.pop(target)
    try:
        findings, _ = contracts.run(targets=(target,), include_mesh=False)
        assert _rules(findings) == ["CONTRACT-MISSING"]
    finally:
        contracts._REGISTRY[target] = saved


def test_unknown_invariant_name_is_a_finding():
    decl = contracts.ContractDecl(
        target="repro.core.routing.scatter_results",
        spec={"returns": "(N,)", "invariants": ("made-up-claim",)},
    )
    findings = contracts.harness_scatter_results(decl)
    assert any(f.rule == "CONTRACT-DECL" for f in findings)


def test_stale_shape_declaration_fails():
    decl = contracts.ContractDecl(
        target="repro.core.routing.scatter_results",
        spec={"args": {"values": "(P, Q, 3)"}, "returns": "(N,)"},
    )
    findings = contracts.harness_scatter_results(decl)
    assert any(f.rule == "CONTRACT-SHAPE" for f in findings)


def test_host_side_contracts_clean_in_process():
    findings, report = contracts.run(include_mesh=False)
    assert findings == [], [str(f) for f in findings]
    assert "repro.core.routing.scatter_results" in report["targets_checked"]
    assert "repro.core.posterior.predict_cached_slots" in report["targets_checked"]


# --------------------------------------------------------------------------
# The CLI front door (subprocess: forces its own virtual devices)
# --------------------------------------------------------------------------


def _run_cli(*argv, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)  # the CLI must set this itself
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *argv],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=timeout,
    )


def test_cli_full_run_clean_on_shipped_codebase(tmp_path):
    out = tmp_path / "ANALYSIS.json"
    r = _run_cli("--grid", "3", "--out", str(out))
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    report = json.loads(out.read_text())
    assert report["total_findings"] == 0
    lanes = report["passes"]["hlo"]["lanes"]
    assert len(lanes) == len(inv.LANES)
    by_name = {l["lane"]: l for l in lanes}
    # the headline claims, as recorded artifacts: replicated collective-free,
    # sharded exactly the 4 composed reverse-halo ppermutes
    assert by_name["replicated/serial/single/ref"]["collectives"][
        "collective-permute"] == 0
    for name, rec in by_name.items():
        if name.startswith("sharded/"):
            assert rec["collectives"]["collective-permute"] == 4, name
            assert rec["collectives"]["all-gather"] == 0, name
    assert report["passes"]["contracts"]["targets_skipped"] == []
    # pass 4: costs gated against the committed baseline, headline shapes
    crec = report["passes"]["costs"]
    assert crec["baseline_checked"] is True
    exps = crec["programs"]["sharded-blend/ref"]["exponents"]
    assert exps["flops_vs_devices"] <= 0.05  # per-device work FLAT in P
    assert exps["arg_bytes_vs_devices"] <= 0.05  # the 1/P residency claim
    assert 0.9 <= exps["flops_vs_q_max"] <= 1.1  # linear blend, no pairwise
    assert len(crec["lanes"]) == len(inv.LANES)
    # pass 5: the shipped tree is race-clean under every RR005-RR008 rule
    assert report["passes"]["async"]["rules"] == {r: 0 for r in asynclint.RULES}
    assert report["seconds"] < 120


def test_cli_exits_nonzero_on_violations(tmp_path):
    out = tmp_path / "ANALYSIS.json"
    r = _run_cli(
        "--passes", "ast", "--root", "tests/fixtures/analysis",
        "--out", str(out),
    )
    assert r.returncode == 1, r.stdout[-2000:] + r.stderr[-2000:]
    report = json.loads(out.read_text())
    per_rule = report["passes"]["ast"]["findings_per_rule"]
    assert all(per_rule[r] >= 1 for r in ("RR001", "RR002", "RR003", "RR004"))


def test_cli_rejects_unknown_pass():
    r = _run_cli("--passes", "vibes")
    assert r.returncode == 2


# --------------------------------------------------------------------------
# Injected violation in a REAL lowered program (subprocess, own devices)
# --------------------------------------------------------------------------

_INJECT_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.analysis import hlo
    from repro.analysis import invariants as inv
    from repro.launch import serve_sharded as ss
    from repro.runtime import compat

    grid = hlo.probe_grid(4)
    mesh = ss.mesh_for_grid(grid)

    # an all_gather smuggled into a shard_map program: the factors move
    gathered = jax.jit(compat.shard_map(
        lambda x: jax.lax.all_gather(x, mesh.axis_names[0]),
        mesh=mesh, in_specs=P(tuple(mesh.axis_names)), out_specs=P(),
        check_vma=False,
    ))
    txt = gathered.lower(
        jax.ShapeDtypeStruct((grid.num_partitions, 8), jnp.float32)
    ).as_text()
    lane = inv.LaneInvariant(
        name="probe", serve={"mode": "sharded"}, program="sharded-blend",
        backend="ref", max_collective_permute=8,
        forbidden_ops=inv.GATHERING_COLLECTIVES,
    )
    findings, counts = hlo.check_text(lane, txt)
    rules = sorted({f.rule for f in findings})
    assert counts["all-gather"] >= 1, counts
    assert rules == ["HLO-FORBIDDEN-OP"], findings

    # and the REAL serving program stays clean under the same invariant
    clean_txt = hlo.lower_program(("sharded-blend", "ref"))
    lane4 = inv.LaneInvariant(
        name="probe4", serve={"mode": "sharded"}, program="sharded-blend",
        backend="ref", max_collective_permute=8, min_collective_permute=4,
        forbidden_ops=inv.GATHERING_COLLECTIVES,
    )
    clean_findings, clean_counts = hlo.check_text(lane4, clean_txt)
    assert clean_findings == [] and clean_counts["collective-permute"] == 4
    print("OK")
    """
)


def test_injected_all_gather_caught_in_real_lowered_program():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", _INJECT_SCRIPT],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=600,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


# --------------------------------------------------------------------------
# Cost pass through the CLI: drift gating + the --update-baselines flow
# --------------------------------------------------------------------------


def test_cli_cost_baseline_drift_gates(tmp_path):
    baseline = json.loads(
        open(os.path.join(REPO, costs.DEFAULT_BASELINE), encoding="utf-8").read()
    )
    # the committed baseline halved = today's (unchanged) program looks 2x
    # more expensive than its baseline -> drift findings, exit 1
    for rec in baseline["programs"].values():
        for metrics in rec["points"].values():
            metrics["flops"] = metrics["flops"] / 2
    stale = tmp_path / "stale_costs.json"
    stale.write_text(json.dumps(baseline))
    out = tmp_path / "ANALYSIS.json"
    r = _run_cli(
        "--passes", "costs", "--baselines", str(stale), "--out", str(out)
    )
    assert r.returncode == 1, r.stdout[-2000:] + r.stderr[-2000:]
    report = json.loads(out.read_text())
    rules = {f["rule"] for f in report["findings"]}
    assert rules == {"COST-BASELINE-DRIFT"}, rules


def test_cli_update_baselines_round_trip(tmp_path):
    fresh = tmp_path / "fresh_costs.json"
    out = tmp_path / "ANALYSIS.json"
    # no baseline yet: a plain run gates on COST-BASELINE-MISSING...
    r = _run_cli(
        "--passes", "costs", "--baselines", str(fresh), "--out", str(out)
    )
    assert r.returncode == 1
    report = json.loads(out.read_text())
    assert {f["rule"] for f in report["findings"]} == {"COST-BASELINE-MISSING"}
    # ...--update-baselines writes it and exits clean...
    r = _run_cli(
        "--passes", "costs", "--baselines", str(fresh), "--out", str(out),
        "--update-baselines",
    )
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    payload = json.loads(fresh.read_text())
    assert set(payload["programs"]) == {"replicated-blend/ref", "sharded-blend/ref"}
    assert payload["_meta"]["tolerance"] == costs.DRIFT_TOLERANCE
    # ...and the next gated run against it is clean
    r = _run_cli(
        "--passes", "costs", "--baselines", str(fresh), "--out", str(out)
    )
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    report = json.loads(out.read_text())
    assert report["passes"]["costs"]["baseline_checked"] is True
    assert report["total_findings"] == 0


# --------------------------------------------------------------------------
# Injected cost violations in REAL compiled programs (subprocess)
# --------------------------------------------------------------------------

_COST_INJECT_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.analysis import costs, hlo
    from repro.analysis import invariants as inv
    from repro.launch import serve_sharded as ss
    from repro.runtime import compat

    def f32(*shape):
        return jax.ShapeDtypeStruct(shape, jnp.float32)

    # 1) a REPLICATED cache (in_specs P()) — per-device argument bytes now
    #    grow with the mesh, the exact failure COST-MEM-SCALING exists for
    def leaky(side):
        grid = hlo.probe_grid(side)
        mesh = ss.mesh_for_grid(grid)
        ax = mesh.axis_names[0]
        Pn = grid.num_partitions
        fn = jax.jit(compat.shard_map(
            lambda cache, q: (q @ cache.T).sum(-1),
            mesh=mesh, in_specs=(P(), P(ax)), out_specs=P(ax),
            check_vma=False,
        ))
        return costs.extract(
            fn.lower(f32(Pn * 8, 8), f32(Pn, 64, 8)).compile()
        )

    points, axes = {}, {"devices": {}, "q_max": {}}
    for side in (2, 3, 4):
        lab = f"grid={side}/q=64"
        points[lab] = leaky(side)
        axes["devices"][lab] = side * side
    for q in (32, 64, 128):
        axes["q_max"][f"grid=4/q={q}"] = q
        points.setdefault(f"grid=4/q={q}", points["grid=4/q=64"])
    rec = {"points": points, "axes": axes}
    rec["exponents"] = costs.compute_exponents(rec)
    assert rec["exponents"]["arg_bytes_vs_devices"] > 0.3, rec["exponents"]
    budget = inv.COST_BUDGETS["sharded-blend"]
    rules = sorted({f.rule for f in costs.check_budget("leaky", rec, budget)})
    assert "COST-MEM-SCALING" in rules, rules

    # 2) a PAIRWISE q x q term — flops quadratic in the block size, the
    #    exact failure COST-FLOP-SUPERLINEAR exists for
    def pairwise(q_max):
        grid = hlo.probe_grid(4)
        mesh = ss.mesh_for_grid(grid)
        ax = mesh.axis_names[0]
        Pn = grid.num_partitions
        fn = jax.jit(compat.shard_map(
            lambda q: ((q[:, :, None, :] - q[:, None, :, :]) ** 2
                       ).sum((-1, -2, -3)),
            mesh=mesh, in_specs=P(ax), out_specs=P(ax), check_vma=False,
        ))
        return costs.extract(fn.lower(f32(Pn, q_max, 2)).compile())

    points, axes = {}, {"devices": {}, "q_max": {}}
    for side in (2, 3, 4):
        lab = f"grid={side}/q=64"
        points[lab] = pairwise(64)
        axes["devices"][lab] = side * side
    for q in (32, 64, 128):
        lab = f"grid=4/q={q}"
        points.setdefault(lab, pairwise(q))
        axes["q_max"][lab] = q
    rec = {"points": points, "axes": axes}
    rec["exponents"] = costs.compute_exponents(rec)
    assert rec["exponents"]["flops_vs_q_max"] > 1.8, rec["exponents"]
    rules = sorted({f.rule for f in costs.check_budget("pairwise", rec, budget)})
    assert "COST-FLOP-SUPERLINEAR" in rules, rules

    # and the REAL programs stay inside every budget under the same judge
    programs = costs.measure_programs()
    for name, real in programs.items():
        real["exponents"] = costs.compute_exponents(real)
        clean = costs.check_budget(
            name, real, inv.COST_BUDGETS[name.split("/")[0]]
        )
        assert clean == [], [str(f) for f in clean]
    print("OK")
    """
)


def test_injected_cost_violations_caught_in_real_compiled_programs():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", _COST_INJECT_SCRIPT],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=600,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
