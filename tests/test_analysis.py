"""The static verification layer's own tests.

Three kinds of coverage:

  * the linter catches what it claims to catch — every known-bad fixture
    in tests/fixtures/analysis/ is flagged by EXACTLY its rule, synthetic
    bad HLO text trips each HLO check, and injected violations in real
    lowered programs (an all_gather smuggled into a shard_map) are found;
  * the escape hatches and declarations are load-bearing — noqa lines
    suppress, deleting a @contract is a finding, unknown invariant names
    are findings, manifest rot (a lane dict that stops parsing as a
    ServeConfig) is a finding;
  * the shipped codebase is CLEAN — the AST pass over src/, the host-side
    contract harnesses in-process, and the full three-pass CLI in a
    subprocess (which is also the < 120 s budget check, on a small grid).

Mesh-requiring checks (HLO lowering, sharded contracts) run via the CLI
subprocess: the analysis front door forces virtual host devices before
jax initializes, which an already-initialized pytest process cannot.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import Finding, astlint, contracts, hlo
from repro.analysis import invariants as inv

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(REPO, "tests", "fixtures", "analysis")


def _fixture(rel):
    path = os.path.join(FIXTURES, rel)
    with open(path, encoding="utf-8") as f:
        return path, f.read()


# --------------------------------------------------------------------------
# Finding / manifest basics
# --------------------------------------------------------------------------


def test_finding_validates():
    with pytest.raises(ValueError):
        Finding("nonsense-pass", "R", "w", "m")
    with pytest.raises(ValueError):
        Finding("ast", "", "w", "m")
    f = Finding("ast", "RR001", "a.py:3", "boom")
    assert f.to_dict()["rule"] == "RR001" and "a.py:3" in str(f)


def test_lane_manifest_is_valid_serve_configs():
    from repro.api.config import ServeConfig

    assert len(inv.LANES) == 14
    names = [l.name for l in inv.LANES]
    assert len(set(names)) == len(names)
    for lane in inv.LANES:
        cfg = ServeConfig.from_dict(lane.serve)  # manifest rot -> raises
        assert cfg.mode in ("replicated", "sharded")
    # exactly 4 distinct device programs behind the 14 lanes
    assert len({l.program_key for l in inv.LANES}) == 4


def test_lane_invariant_rejects_bad_declarations():
    with pytest.raises(ValueError):
        inv.LaneInvariant(
            name="x", serve={}, program="warp-drive", backend="ref",
            max_collective_permute=0, forbidden_ops=(),
        )
    with pytest.raises(ValueError):
        inv.LaneInvariant(
            name="x", serve={}, program="sharded-blend", backend="ref",
            max_collective_permute=2, min_collective_permute=4,
            forbidden_ops=(),
        )
    with pytest.raises(ValueError):
        inv.LaneInvariant(
            name="x", serve={}, program="sharded-blend", backend="ref",
            max_collective_permute=8, forbidden_ops=("warp-gather",),
        )


# --------------------------------------------------------------------------
# HLO pass: text checks on synthetic programs (no jax needed)
# --------------------------------------------------------------------------

SHARDED_LANE = next(l for l in inv.LANES if l.program == "sharded-blend")
REPLICATED_LANE = next(l for l in inv.LANES if l.program == "replicated-blend")

# a minimal halo-shaped program: 4 ppermutes, f32 only
GOOD_TEXT = "\n".join(
    f'%r{i} = "stablehlo.collective_permute"(%a) : tensor<9x64xf32>'
    for i in range(4)
)


def _rules(findings):
    return sorted({f.rule for f in findings})


def test_hlo_good_text_is_clean():
    findings, counts = hlo.check_text(SHARDED_LANE, GOOD_TEXT)
    assert findings == [] and counts["collective-permute"] == 4


@pytest.mark.parametrize(
    "mutation,rule",
    [
        # a gathering collective in a sharded program
        ('%g = "stablehlo.all_gather"(%a) : tensor<16x8xf32>', "HLO-FORBIDDEN-OP"),
        # HLO (dashed) spelling must be caught too
        ("%g = all-gather(%a)", "HLO-FORBIDDEN-OP"),
        ("%g = all-reduce-start(%a)", "HLO-FORBIDDEN-OP"),
        # an f64 leak
        ("%c = stablehlo.constant dense<0.5> : tensor<64xf64>", "HLO-DTYPE-F64"),
        ("%c = f64[9,64] constant(...)", "HLO-DTYPE-F64"),
        # a host transfer inside the compiled program
        ('%h = "stablehlo.infeed"(%tok)', "HLO-HOST-TRANSFER"),
        ("%h = xla_python_cpu_callback(%a)", "HLO-HOST-TRANSFER"),
    ],
)
def test_hlo_bad_text_caught_by_exactly_the_expected_rule(mutation, rule):
    findings, _ = hlo.check_text(SHARDED_LANE, GOOD_TEXT + "\n" + mutation)
    assert _rules(findings) == [rule], findings


def test_hlo_budget_and_floor():
    over = GOOD_TEXT + "\n" + "\n".join(
        f'%e{i} = "stablehlo.collective_permute"(%a)' for i in range(9)
    )
    findings, counts = hlo.check_text(SHARDED_LANE, over)
    assert _rules(findings) == ["HLO-COLLECTIVE-BUDGET"] and counts[
        "collective-permute"
    ] == 13
    # the floor: a sharded program whose halo vanished is wrong too
    findings, _ = hlo.check_text(SHARDED_LANE, "%z = stablehlo.add(%a, %b)")
    assert _rules(findings) == ["HLO-COLLECTIVE-MISSING"]


def test_hlo_replicated_lane_forbids_all_collectives():
    findings, _ = hlo.check_text(REPLICATED_LANE, GOOD_TEXT)
    assert "HLO-COLLECTIVE-BUDGET" in _rules(findings)
    findings, _ = hlo.check_text(
        REPLICATED_LANE, '%r = "stablehlo.all_reduce"(%a)'
    )
    assert "HLO-FORBIDDEN-OP" in _rules(findings)


def test_hlo_manifest_rot_is_a_finding():
    rotten = inv.LaneInvariant(
        name="rotten", serve={"mode": "sharded", "warp_factor": 9},
        program="sharded-blend", backend="ref",
        max_collective_permute=8, forbidden_ops=(),
    )
    findings, report = hlo.run(lanes=(rotten,))
    assert _rules(findings) == ["HLO-MANIFEST"]
    assert report["lanes"] == []  # never lowered


# --------------------------------------------------------------------------
# AST pass: fixtures each caught by exactly the expected rule
# --------------------------------------------------------------------------


@pytest.mark.parametrize(
    "rel,rule",
    [
        ("bad_import_time.py", "RR001"),
        (os.path.join("repro", "core", "routing.py"), "RR002"),
        (os.path.join("repro", "kernels", "bad_f64.py"), "RR003"),
        ("bad_config.py", "RR004"),
    ],
)
def test_fixture_caught_by_exactly_the_expected_rule(rel, rule):
    path, source = _fixture(rel)
    findings = astlint.lint_source(path, source)
    assert findings, f"{rel}: nothing caught"
    assert _rules(findings) == [rule], findings


def test_noqa_suppresses():
    path, source = _fixture("suppressed_ok.py")
    assert astlint.lint_source(path, source) == []
    # and removing the noqa markers brings the findings back
    stripped = "\n".join(
        line.split("# repro: noqa-")[0] for line in source.splitlines()
    )
    assert _rules(astlint.lint_source(path, stripped)) == ["RR001", "RR004"]


def test_rr002_declared_function_cannot_silently_vanish():
    source = "import numpy as np\n"  # none of the declared functions exist
    findings = astlint.lint_source("src/repro/core/routing.py", source)
    assert findings and _rules(findings) == ["RR002"]
    assert any("not found" in f.message for f in findings)


def test_rr001_skips_lazy_contexts():
    source = textwrap.dedent(
        """
        import functools
        import jax
        import jax.numpy as jnp

        def f(x):
            return jnp.asarray(x)

        g = functools.partial(jax.jit, static_argnames=("k",))

        @jax.jit
        def h(x):
            return x
        """
    )
    assert astlint.lint_source("src/repro/x.py", source) == []


def test_rr001_catches_function_default_args():
    source = "import jax.numpy as jnp\ndef f(x=jnp.zeros(3)):\n    return x\n"
    assert _rules(astlint.lint_source("src/repro/x.py", source)) == ["RR001"]


def test_shipped_codebase_is_clean():
    findings, report = astlint.run(os.path.join(REPO, "src"))
    assert findings == [], [str(f) for f in findings]
    assert report["files_scanned"] > 60


def test_fixture_tree_is_dirty_end_to_end():
    findings, _ = astlint.run(FIXTURES)
    assert _rules(findings) == ["RR001", "RR002", "RR003", "RR004"]


# --------------------------------------------------------------------------
# Contracts pass
# --------------------------------------------------------------------------


def test_parse_and_unify():
    assert contracts.parse_shape("(S, Q, 4)") == ("S", "Q", 4)
    assert contracts.parse_shape("(N,)") == ("N",)
    env = {}
    assert contracts.unify("(S, Q)", (9, 64), env) is None
    assert env == {"S": 9, "Q": 64}
    assert contracts.unify("(S, 4)", (9, 4), env) is None
    assert contracts.unify("(S, Q)", (8, 64), env)  # S rebind -> error
    assert contracts.unify("(S, Q)", (9,), env)  # rank -> error
    assert contracts.unify("(S, 4)", (9, 5), env)  # literal -> error
    with pytest.raises(ValueError):
        contracts.parse_shape("S, Q")


def test_missing_contract_is_a_finding():
    import importlib

    target = contracts.EXPECTED_TARGETS[1]  # scatter_results, host-only
    importlib.import_module(target.rsplit(".", 1)[0])  # populate registry
    saved = contracts._REGISTRY.pop(target)
    try:
        findings, _ = contracts.run(targets=(target,), include_mesh=False)
        assert _rules(findings) == ["CONTRACT-MISSING"]
    finally:
        contracts._REGISTRY[target] = saved


def test_unknown_invariant_name_is_a_finding():
    decl = contracts.ContractDecl(
        target="repro.core.routing.scatter_results",
        spec={"returns": "(N,)", "invariants": ("made-up-claim",)},
    )
    findings = contracts.harness_scatter_results(decl)
    assert any(f.rule == "CONTRACT-DECL" for f in findings)


def test_stale_shape_declaration_fails():
    decl = contracts.ContractDecl(
        target="repro.core.routing.scatter_results",
        spec={"args": {"values": "(P, Q, 3)"}, "returns": "(N,)"},
    )
    findings = contracts.harness_scatter_results(decl)
    assert any(f.rule == "CONTRACT-SHAPE" for f in findings)


def test_host_side_contracts_clean_in_process():
    findings, report = contracts.run(include_mesh=False)
    assert findings == [], [str(f) for f in findings]
    assert "repro.core.routing.scatter_results" in report["targets_checked"]
    assert "repro.core.posterior.predict_cached_slots" in report["targets_checked"]


# --------------------------------------------------------------------------
# The CLI front door (subprocess: forces its own virtual devices)
# --------------------------------------------------------------------------


def _run_cli(*argv, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)  # the CLI must set this itself
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *argv],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=timeout,
    )


def test_cli_full_run_clean_on_shipped_codebase(tmp_path):
    out = tmp_path / "ANALYSIS.json"
    r = _run_cli("--grid", "3", "--out", str(out))
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    report = json.loads(out.read_text())
    assert report["total_findings"] == 0
    lanes = report["passes"]["hlo"]["lanes"]
    assert len(lanes) == len(inv.LANES)
    by_name = {l["lane"]: l for l in lanes}
    # the headline claims, as recorded artifacts: replicated collective-free,
    # sharded exactly the 4 composed reverse-halo ppermutes
    assert by_name["replicated/serial/single/ref"]["collectives"][
        "collective-permute"] == 0
    for name, rec in by_name.items():
        if name.startswith("sharded/"):
            assert rec["collectives"]["collective-permute"] == 4, name
            assert rec["collectives"]["all-gather"] == 0, name
    assert report["passes"]["contracts"]["targets_skipped"] == []
    assert report["seconds"] < 120


def test_cli_exits_nonzero_on_violations(tmp_path):
    out = tmp_path / "ANALYSIS.json"
    r = _run_cli(
        "--passes", "ast", "--root", "tests/fixtures/analysis",
        "--out", str(out),
    )
    assert r.returncode == 1, r.stdout[-2000:] + r.stderr[-2000:]
    report = json.loads(out.read_text())
    per_rule = report["passes"]["ast"]["findings_per_rule"]
    assert all(per_rule[r] >= 1 for r in ("RR001", "RR002", "RR003", "RR004"))


def test_cli_rejects_unknown_pass():
    r = _run_cli("--passes", "vibes")
    assert r.returncode == 2


# --------------------------------------------------------------------------
# Injected violation in a REAL lowered program (subprocess, own devices)
# --------------------------------------------------------------------------

_INJECT_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.analysis import hlo
    from repro.analysis import invariants as inv
    from repro.launch import serve_sharded as ss
    from repro.runtime import compat

    grid = hlo.probe_grid(4)
    mesh = ss.mesh_for_grid(grid)

    # an all_gather smuggled into a shard_map program: the factors move
    gathered = jax.jit(compat.shard_map(
        lambda x: jax.lax.all_gather(x, mesh.axis_names[0]),
        mesh=mesh, in_specs=P(tuple(mesh.axis_names)), out_specs=P(),
        check_vma=False,
    ))
    txt = gathered.lower(
        jax.ShapeDtypeStruct((grid.num_partitions, 8), jnp.float32)
    ).as_text()
    lane = inv.LaneInvariant(
        name="probe", serve={"mode": "sharded"}, program="sharded-blend",
        backend="ref", max_collective_permute=8,
        forbidden_ops=inv.GATHERING_COLLECTIVES,
    )
    findings, counts = hlo.check_text(lane, txt)
    rules = sorted({f.rule for f in findings})
    assert counts["all-gather"] >= 1, counts
    assert rules == ["HLO-FORBIDDEN-OP"], findings

    # and the REAL serving program stays clean under the same invariant
    clean_txt = hlo.lower_program(("sharded-blend", "ref"))
    lane4 = inv.LaneInvariant(
        name="probe4", serve={"mode": "sharded"}, program="sharded-blend",
        backend="ref", max_collective_permute=8, min_collective_permute=4,
        forbidden_ops=inv.GATHERING_COLLECTIVES,
    )
    clean_findings, clean_counts = hlo.check_text(lane4, clean_txt)
    assert clean_findings == [] and clean_counts["collective-permute"] == 4
    print("OK")
    """
)


def test_injected_all_gather_caught_in_real_lowered_program():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", _INJECT_SCRIPT],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=600,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
