"""Sharded (shard_map + halo-exchange) serving == replicated serving.

Like tests/test_psvgp_spmd.py, the SPMD program needs multiple XLA host
devices configured before jax initializes, so the checks run in one
subprocess with its own XLA_FLAGS. Covered there:

  * halo exchange resolves corners exactly (probe payload = partition id,
    compared against routing.halo_ids — the SPMD corner-resolution test);
  * sharded blend == predict_routed reference == replicated
    predict_blended to atol 1e-5 on the same trained state;
  * per-device cache-factor memory is exactly 1/P of replicated;
  * the lowered program contains collective-permutes and NO all-gather of
    the cache factors (the decentralized-serving claim).
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import psvgp, routing, svgp
    from repro.core.blend import predict_blended
    from repro.core.partition import make_grid, partition_data
    from repro.data.spatial import e3sm_like_field
    from repro.launch import serve_sharded as ss

    ds = e3sm_like_field(n=3000, seed=0)
    grid = make_grid(ds.x, gx=4, gy=4)
    data = partition_data(ds.x, ds.y, grid)
    cfg = psvgp.PSVGPConfig(
        svgp=svgp.SVGPConfig(num_inducing=6, input_dim=2),
        delta=0.25, batch_size=16, learning_rate=0.05)
    static = psvgp.build(cfg, data)
    state = psvgp.init(jax.random.PRNGKey(0), cfg, data)
    state = psvgp.fit(static, state, data, 300)
    cache = psvgp.posterior_cache(static, state)
    mesh = ss.mesh_for_grid(grid)

    # --- halo-exchange corner resolution: ship each device its pid, check
    # every on-grid slot sees the right neighbor and off-grid slots zero.
    pid = jnp.arange(grid.num_partitions, dtype=jnp.float32)[:, None]
    halo = np.asarray(ss.make_halo_gather(mesh, mesh.axis_names, grid)(pid))[:, :, 0]
    hids = routing.halo_ids(grid)
    for p in range(grid.num_partitions):
        ix, iy = grid.cell_of(p)
        for k, (dx, dy) in enumerate(routing.OFFSETS):
            on_grid = 0 <= ix + dx < grid.gx and 0 <= iy + dy < grid.gy
            want = float(hids[p, k]) if on_grid else 0.0
            assert halo[p, k] == want, (p, k, halo[p, k], want)

    # --- sharded == routed reference == replicated ---
    cache_sh = ss.shard_cache(cache, mesh)
    total_b, device_b = ss.cache_memory_bytes(cache_sh)
    assert total_b == device_b * grid.num_partitions, (total_b, device_b)

    rng = np.random.default_rng(1)
    lo, hi = np.asarray(ds.x).min(0), np.asarray(ds.x).max(0)
    q = rng.uniform(lo, hi, (777, 2)).astype(np.float32)
    table = routing.build_routing_table(grid, q)
    xq, cs, cw = ss.shard_table(table, mesh)
    blend_fn = ss.make_sharded_blend(mesh, mesh.axis_names, grid, static.cov_fn, cache_sh)
    mean, var = blend_fn(cache_sh, xq, cs, cw)
    m_sh = routing.scatter_results(table, np.asarray(mean))
    v_sh = routing.scatter_results(table, np.asarray(var))

    m_rt, v_rt = routing.predict_routed(cache, static.cov_fn, grid, table)
    m_rep, v_rep = predict_blended(static, state, grid, jnp.asarray(q), cache=cache)
    np.testing.assert_allclose(m_sh, m_rt, atol=1e-5)
    np.testing.assert_allclose(v_sh, v_rt, atol=1e-5)
    np.testing.assert_allclose(m_sh, np.asarray(m_rep), atol=1e-5)
    np.testing.assert_allclose(v_sh, np.asarray(v_rep), atol=1e-5)

    # --- the program must be halo-shaped: collective-permute yes,
    # all-gather of factors no ---
    txt = blend_fn.lower(cache_sh, xq, cs, cw).as_text()
    assert ("collective_permute" in txt) or ("collective-permute" in txt), \
        "no collective-permute in the lowered serving program"
    assert "all-gather" not in txt and "all_gather" not in txt, \
        "serving program gathers state — the cache must stay sharded"
    print("OK")
    """
)


@pytest.mark.slow
def test_sharded_serving_matches_replicated():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
