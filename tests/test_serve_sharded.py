"""Sharded (shard_map + halo-exchange) serving == replicated serving.

Like tests/test_psvgp_spmd.py, the SPMD program needs multiple XLA host
devices configured before jax initializes, so the checks run in one
subprocess with its own XLA_FLAGS. Covered there:

  * halo exchange resolves corners exactly (probe payload = partition id,
    compared against routing.halo_ids — the SPMD corner-resolution test),
    and the HOST-side halo stacker reproduces the mesh-side gather
    bitwise (the ingest the serving program now uses for queries);
  * sharded blend == predict_routed reference == replicated
    predict_blended to atol 1e-5 on the same trained state — through the
    pipeline stages the production driver uses;
  * TWO-LEVEL routing through the SAME shard_map program: a hot-cell
    batch routed with spill (TwoLevelQMax, q_max under the hot peak)
    still matches replicated to atol 1e-5 — spill rows ride the identical
    device program, collectives and all;
  * pipelined loop == serial loop BITWISE on the same request stream
    (overlap is scheduling, never math), with the streaming q_max policy;
  * the fused slot-stacked Pallas program (use_pallas=True, interpret on
    CPU) matches the jnp program to 1e-5 inside the same shard_map;
  * per-device cache-factor memory is exactly 1/P of replicated.

The STRUCTURAL claims about the lowered program (collective-permute
budget 4..8, no all-gather of the factors, f32-only, no host transfers)
moved out of this slow lane: they are checked on every push by the
``repro.analysis`` HLO pass against the invariant manifest — see
docs/analysis.md and tests/test_analysis.py.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import psvgp, routing, svgp
    from repro.core.blend import predict_blended
    from repro.core.partition import make_grid, partition_data
    from repro.data.spatial import e3sm_like_field
    from repro.launch import serve_sharded as ss

    ds = e3sm_like_field(n=3000, seed=0)
    grid = make_grid(ds.x, gx=4, gy=4)
    data = partition_data(ds.x, ds.y, grid)
    cfg = psvgp.PSVGPConfig(
        svgp=svgp.SVGPConfig(num_inducing=6, input_dim=2),
        delta=0.25, batch_size=16, learning_rate=0.05)
    static = psvgp.build(cfg, data)
    state = psvgp.init(jax.random.PRNGKey(0), cfg, data)
    state = psvgp.fit(static, state, data, 300)
    cache = psvgp.posterior_cache(static, state)
    mesh = ss.mesh_for_grid(grid)

    # --- halo-exchange corner resolution: ship each device its pid, check
    # every on-grid slot sees the right neighbor and off-grid slots zero.
    pid = jnp.arange(grid.num_partitions, dtype=jnp.float32)[:, None]
    halo = np.asarray(ss.make_halo_gather(mesh, mesh.axis_names, grid)(pid))[:, :, 0]
    hids = routing.halo_ids(grid)
    for p in range(grid.num_partitions):
        ix, iy = grid.cell_of(p)
        for k, (dx, dy) in enumerate(routing.OFFSETS):
            on_grid = 0 <= ix + dx < grid.gx and 0 <= iy + dy < grid.gy
            want = float(hids[p, k]) if on_grid else 0.0
            assert halo[p, k] == want, (p, k, halo[p, k], want)

    # --- the host-side halo stacker delivers bitwise what the mesh-side
    # exchange would (the serving ingest replaces the query ppermutes)
    stacked = routing.make_halo_stacker(grid)(np.asarray(pid)[:, None, :])
    np.testing.assert_array_equal(stacked[:, :, 0, 0], halo)

    # --- sharded == routed reference == replicated, via the production
    # pipeline stages ---
    cache_sh = ss.shard_cache(cache, mesh)
    total_b, device_b = ss.cache_memory_bytes(cache_sh)
    assert total_b == device_b * grid.num_partitions, (total_b, device_b)

    rng = np.random.default_rng(1)
    lo, hi = np.asarray(ds.x).min(0), np.asarray(ds.x).max(0)
    batches = [rng.uniform(lo, hi, (n, 2)).astype(np.float32)
               for n in (777, 400, 777, 1200)]
    q = batches[0]
    blend_fn = ss.make_sharded_blend(mesh, mesh.axis_names, grid, static.cov_fn, cache_sh)
    route, submit, collect = ss.make_request_stages(
        grid, blend_fn, cache_sh, policy=routing.StreamingQMax())
    m_sh, v_sh = collect(submit(route(q)))

    table = routing.build_routing_table(grid, q)
    m_rt, v_rt = routing.predict_routed(cache, static.cov_fn, grid, table)
    m_rep, v_rep = predict_blended(static, state, grid, jnp.asarray(q), cache=cache)
    np.testing.assert_allclose(m_sh, m_rt, atol=1e-5)
    np.testing.assert_allclose(v_sh, v_rt, atol=1e-5)
    np.testing.assert_allclose(m_sh, np.asarray(m_rep), atol=1e-5)
    np.testing.assert_allclose(v_sh, np.asarray(v_rep), atol=1e-5)

    # --- pipelined == serial BITWISE on the same stream (fresh policies
    # so both see the identical q_max sequence) ---
    route_s, submit_s, collect_s = ss.make_request_stages(
        grid, blend_fn, cache_sh, policy=routing.StreamingQMax())
    serial = [collect_s(submit_s(route_s(b))) for b in batches]
    route_p, submit_p, collect_p = ss.make_request_stages(
        grid, blend_fn, cache_sh, policy=routing.StreamingQMax())
    piped = {}
    ss.pipelined_request_loop(route_p, submit_p, collect_p, batches,
                              warm=False, on_result=lambda i, o: piped.setdefault(i, o))
    for i, (ms, vs) in enumerate(serial):
        np.testing.assert_array_equal(piped[i][0], ms)
        np.testing.assert_array_equal(piped[i][1], vs)

    # --- TWO-LEVEL routing through the SAME shard_map program: a skewed
    # batch (hot cell) routed with spill at a q_max under the hot peak
    # must serve the same answers as the replicated blend ---
    hotq = np.concatenate([
        q, rng.uniform(lo + 0.30 * (hi - lo), lo + 0.45 * (hi - lo),
                       (1500, 2)).astype(np.float32)])
    pol2 = routing.TwoLevelQMax()
    route2, submit2, collect2 = ss.make_request_stages(
        grid, blend_fn, cache_sh, policy=pol2)
    m_2l, v_2l = collect2(submit2(route2(hotq)))
    cells = routing.owning_cells(grid, hotq)
    peak = int(np.bincount(cells[1] * grid.gx + cells[0],
                           minlength=grid.num_partitions).max())
    assert pol2.q_max < peak and pol2.spilled > 0, (pol2.stats(), peak)
    m2_rep, v2_rep = predict_blended(static, state, grid, jnp.asarray(hotq), cache=cache)
    np.testing.assert_allclose(m_2l, np.asarray(m2_rep), atol=1e-5)
    np.testing.assert_allclose(v_2l, np.asarray(v2_rep), atol=1e-5)

    # --- fused slot-stacked Pallas program (interpret on CPU) matches the
    # jnp program inside the same shard_map ---
    blend_fu = ss.make_sharded_blend(
        mesh, mesh.axis_names, grid, static.cov_fn, cache_sh, use_pallas=True)
    route_f, submit_f, collect_f = ss.make_request_stages(
        grid, blend_fu, cache_sh, policy=routing.StreamingQMax())
    m_fu, v_fu = collect_f(submit_f(route_f(q)))
    np.testing.assert_allclose(m_fu, m_sh, atol=1e-5)
    np.testing.assert_allclose(v_fu, v_sh, atol=1e-5)
    print("OK")
    """
)


@pytest.mark.slow
def test_sharded_serving_matches_replicated():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=900,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
