"""The in-situ lifecycle (docs/lifecycle.md): warm-start refit, the
format=2 append-only artifact store, and zero-downtime ``Server.swap``.

The three gates this module holds:

  * REFIT == FIT, bitwise, when refit runs from scratch init with the
    full budget — ``api.refit`` and ``api.fit`` share one training code
    path, and this test is what keeps that true.
  * FORMAT=2 ROUND-TRIP is bitwise: a step committed with ``save_step``
    restores a cache whose predictions are identical to the in-memory
    model's, format=1 artifacts keep loading, and the step index is
    readable as plain JSON.
  * SWAP IS ATOMIC PER REQUEST: under a live FrontDoor stream, every
    answer is bitwise the OLD model's or bitwise the NEW model's (never
    a mix), the old→new transition is monotone in service order, and the
    swap sheds nothing. Replicated runs in-process with fixed-shape
    requests (XLA specializes per shape, so equal shapes ⇒ equal
    programs ⇒ bitwise); the sharded mesh lane runs in a subprocess
    (virtual host devices before jax init, same pattern as test_api.py)
    with the q_max high-water mark pre-warmed so every window reuses one
    compiled program across both models.
"""
import asyncio
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro import api
from repro.checkpoint import store as artifact_store
from repro.data.spatial import e3sm_like_field

REPO = Path(__file__).resolve().parent.parent


def _params_equal(a, b) -> bool:
    import jax

    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


@pytest.fixture(scope="module")
def slices():
    """Two consecutive 'simulation steps' of the drifting field."""
    return e3sm_like_field(n=600, seed=0), e3sm_like_field(n=600, seed=1)


@pytest.fixture(scope="module")
def fitted(slices):
    return api.fit(api.FitConfig(grid=2, m=4, train_iters=60, seed=0), slices[0])


# ---------------------------------------------------------------------------
# refit
# ---------------------------------------------------------------------------


def test_refit_scratch_full_budget_is_bitwise_fit(fitted, slices):
    """The anchor gate: scratch init + the full FitConfig budget must run
    the IDENTICAL recipe as fit() on the new slice — bitwise params and
    bitwise predictions, so refit is fit plus a warm-start option, not a
    second training path that can drift."""
    _, ds1 = slices
    scratch = api.refit(
        fitted, ds1,
        api.RefitConfig(train_iters=fitted.config.train_iters, init="scratch"),
    )
    fresh = api.fit(fitted.config, ds1)
    assert _params_equal(scratch.state.params, fresh.state.params)
    q = ds1.x[:32]
    np.testing.assert_array_equal(
        np.asarray(scratch.predict(q)[0]), np.asarray(fresh.predict(q)[0])
    )


def test_refit_warm_start_carries_previous_state(fitted, slices):
    """Warm refit: starts FROM the previous params (0 iters is the
    identity), a short budget moves them, the input model is never
    mutated, and the step config/timing land on the result."""
    _, ds1 = slices
    before = fitted.state.params

    frozen = api.refit(fitted, ds1, api.RefitConfig(train_iters=0))
    assert _params_equal(frozen.state.params, before)

    moved = api.refit(fitted, ds1, api.RefitConfig(train_iters=15))
    assert not _params_equal(moved.state.params, before)
    assert _params_equal(fitted.state.params, before)  # input untouched
    assert moved.config.train_iters == 15  # budget recorded on the artifact
    assert moved.config.grid == fitted.config.grid
    assert moved.refit_seconds is not None and moved.refit_seconds > 0
    # warm refit differs from a scratch refit of the same budget (it
    # actually used the carried state, not a silent re-init)
    scratch = api.refit(fitted, ds1, api.RefitConfig(train_iters=15, init="scratch"))
    assert not _params_equal(moved.state.params, scratch.state.params)


def test_refit_optimizer_reset_and_lr_override(fitted, slices):
    """reset_optimizer zeroes the Adam moments (different trajectory than
    carrying them); learning_rate overrides for the step only."""
    _, ds1 = slices
    carried = api.refit(fitted, ds1, api.RefitConfig(train_iters=15))
    reset = api.refit(
        fitted, ds1, api.RefitConfig(train_iters=15, reset_optimizer=True)
    )
    assert not _params_equal(carried.state.params, reset.state.params)
    hot = api.refit(
        fitted, ds1, api.RefitConfig(train_iters=15, learning_rate=0.5)
    )
    assert hot.config.learning_rate == 0.5
    assert not _params_equal(carried.state.params, hot.state.params)


def test_refit_from_loaded_artifact(fitted, slices, tmp_path):
    """A loaded artifact has params but no Adam moments — refit must
    re-create the optimizer state instead of crashing, and still warm
    start from the persisted params."""
    _, ds1 = slices
    loaded = api.FittedPSVGP.load(fitted.save(str(tmp_path / "art")))
    assert loaded.state.opt.mu is None
    out = api.refit(loaded, ds1, api.RefitConfig(train_iters=0))
    assert _params_equal(out.state.params, fitted.state.params)
    moved = api.refit(loaded, ds1, api.RefitConfig(train_iters=10))
    assert not _params_equal(moved.state.params, fitted.state.params)


def test_refit_config_validates_and_round_trips():
    with pytest.raises(ValueError, match="init"):
        api.RefitConfig(init="tepid")
    with pytest.raises(ValueError, match="train_iters"):
        api.RefitConfig(train_iters=-1)
    with pytest.raises(ValueError, match="learning_rate"):
        api.RefitConfig(learning_rate=0.0)
    cfg = api.RefitConfig(train_iters=25, init="scratch", learning_rate=0.1)
    assert api.RefitConfig.from_json(cfg.to_json()) == cfg


# ---------------------------------------------------------------------------
# format=2 store
# ---------------------------------------------------------------------------


def test_store_round_trip_bitwise_and_peek(fitted, slices, tmp_path):
    """save_step → load restores a bitwise-identical cache; the step
    index and each step's FitConfig peek as plain JSON; append-only and
    strictly-increasing commits are enforced."""
    _, ds1 = slices
    store = str(tmp_path / "store")
    step1 = api.refit(fitted, ds1, api.RefitConfig(train_iters=10))

    fitted.save_step(store, 0)
    step1.save_step(store, 3, meta={"note": "field drifted"})

    assert api.peek_steps(store) == [0, 3]
    assert api.peek_fit_config(store, step=0) == fitted.config
    assert api.peek_fit_config(store) == step1.config  # latest by default
    index = artifact_store.read_index(store)
    assert index["format"] == 2
    assert index["steps"][1]["note"] == "field drifted"
    assert "refit_s" not in index["steps"][1]  # explicit meta= replaces the default

    latest = api.FittedPSVGP.load(store)
    np.testing.assert_array_equal(
        np.asarray(latest.cache.w), np.asarray(step1.cache.w)
    )
    old = api.FittedPSVGP.load(store, step=0)
    np.testing.assert_array_equal(np.asarray(old.cache.w), np.asarray(fitted.cache.w))
    q = ds1.x[:16]
    np.testing.assert_array_equal(
        np.asarray(old.predict(q)[0]), np.asarray(fitted.predict(q)[0])
    )
    # each step dir is itself a complete format=1 artifact
    direct = api.FittedPSVGP.load(artifact_store.step_dir(store, 0))
    np.testing.assert_array_equal(np.asarray(direct.cache.w), np.asarray(old.cache.w))

    with pytest.raises(ValueError, match="append-only"):
        step1.save_step(store, 3)
    with pytest.raises(ValueError, match="append-only"):
        step1.save_step(store, 1)  # older than the newest committed step
    with pytest.raises(KeyError, match="no step 7"):
        api.FittedPSVGP.load(store, step=7)


def test_refit_seconds_defaults_into_step_meta(fitted, slices, tmp_path):
    _, ds1 = slices
    store = str(tmp_path / "store")
    stepped = api.refit(fitted, ds1, api.RefitConfig(train_iters=5))
    stepped.save_step(store, 0)
    entry = artifact_store.read_index(store)["steps"][0]
    assert entry["refit_s"] == pytest.approx(stepped.refit_seconds)


def test_format1_artifact_read_compat(fitted, tmp_path):
    """Format=1 stays exactly as it was: flat save/load, no step index,
    and asking a flat artifact for a step is an explicit error."""
    art = fitted.save(str(tmp_path / "flat"))
    again = api.FittedPSVGP.load(art)
    np.testing.assert_array_equal(np.asarray(again.cache.w), np.asarray(fitted.cache.w))
    assert api.peek_fit_config(art) == fitted.config
    assert not artifact_store.is_store(art)
    with pytest.raises(ValueError, match="format-1"):
        api.FittedPSVGP.load(art, step=0)
    with pytest.raises(ValueError, match="format-1"):
        api.peek_fit_config(art, step=0)


# ---------------------------------------------------------------------------
# Server.swap
# ---------------------------------------------------------------------------


def test_swap_replicated_flips_model_and_records_lifecycle(fitted, slices):
    _, ds1 = slices
    new = api.refit(fitted, ds1, api.RefitConfig(train_iters=10))
    server = api.Server(fitted)
    q = ds1.x[:16]
    pre = server.submit(q)
    np.testing.assert_array_equal(pre[0], np.asarray(fitted.predict(q)[0]))

    rec = server.swap(new, version="step-1")
    assert rec["swaps"] == 1 and rec["version"] == "step-1"
    assert server.fitted is new

    post = server.submit(q)
    np.testing.assert_array_equal(post[0], np.asarray(new.predict(q)[0]))
    assert not np.array_equal(pre[0], post[0])

    lc = server.lifecycle()
    assert lc["swaps"] == 1 and lc["active_version"] == "step-1"
    assert [v["version"] for v in lc["versions"]] == [0, "step-1"]
    assert lc["versions"][0]["requests"] == 1  # pre-swap submit
    assert lc["versions"][1]["requests"] == 1  # post-swap submit
    assert lc["versions"][1]["refit_s"] == pytest.approx(new.refit_seconds)
    assert lc["versions"][1]["build_s"] > 0

    report = server.stream([q, q], warm=False)
    assert report["lifecycle"]["swaps"] == 1


def test_swap_under_load_replicated(fitted, slices):
    """The zero-downtime gate, replicated lane: a FrontDoor stream stays
    up across a mid-stream swap — nothing shed, every answer bitwise the
    old model's or bitwise the new model's, transition monotone in
    service order with both models observed.

    Every request reuses one of 4 fixed (8, 2) shapes and the window is
    capped at 8 rows, so each device batch is exactly one request and
    the replicated program is shape-stable — which is what makes the
    bitwise classification valid off the sharded path."""
    _, ds1 = slices
    new = api.refit(fitted, ds1, api.RefitConfig(train_iters=10))
    server = api.Server(fitted)

    rng = np.random.default_rng(5)
    lo = [fitted.grid.x_edges[0], fitted.grid.y_edges[0]]
    hi = [fitted.grid.x_edges[-1], fitted.grid.y_edges[-1]]
    pool = [rng.uniform(lo, hi, (8, 2)).astype(np.float32) for _ in range(4)]
    n_req = 24
    ref_a = [server.submit(p) for p in pool]  # active model: old

    served = []  # (request index, label-by-settle-order) — service order

    async def drive():
        loop = asyncio.get_running_loop()
        swap_done = asyncio.Event()
        completed = 0

        fd_cfg = api.FrontDoorConfig(
            max_wait_ms=1.0, max_rows=8, max_request_rows=8, admission="shed"
        )

        async def client(fd, i):
            nonlocal completed
            if i >= 16:
                await swap_done.wait()  # guaranteed post-flip arrivals
            else:
                await asyncio.sleep(0.002 * i)
            out = await fd.submit(pool[i % 4])
            completed += 1
            served.append((i, out))
            return out

        async def swapper():
            while completed < 6:  # guaranteed pre-flip completions first
                await asyncio.sleep(0.001)
            await loop.run_in_executor(None, server.swap, new)
            swap_done.set()

        async with api.FrontDoor(server, fd_cfg) as fd:
            results = await asyncio.gather(
                swapper(), *(client(fd, i) for i in range(n_req))
            )
        return results[1:], fd.report()

    got, rep = asyncio.run(drive())
    assert rep["requests"]["shed"] == 0
    assert rep["requests"]["completed"] == n_req

    ref_b = [server.submit(p) for p in pool]  # active model: new

    def classify(i, out):
        if np.array_equal(out[0], ref_a[i % 4][0]) and np.array_equal(
            out[1], ref_a[i % 4][1]
        ):
            return "A"
        if np.array_equal(out[0], ref_b[i % 4][0]) and np.array_equal(
            out[1], ref_b[i % 4][1]
        ):
            return "B"
        return "?"

    labels = [classify(i, out) for i, out in served]
    assert "?" not in labels, "an answer matched NEITHER model bitwise"
    assert "A" in labels and "B" in labels  # the flip happened mid-stream
    assert labels == sorted(labels), (
        f"old-model answer served after the flip: {labels}"
    )
    lc = rep["lifecycle"]
    assert lc["swaps"] == 1 and len(lc["versions"]) == 2


def test_swap_rejects_mesh_incompatible_model(slices):
    """Sharded swap requires the same grid side (one partition per
    device); the replicated server takes any grid. Checked here on the
    replicated server's config validation path via grid mismatch on the
    sharded branch being unreachable in-process — the real sharded
    rejection is asserted in the subprocess script below."""
    ds0, ds1 = slices
    small = api.fit(api.FitConfig(grid=2, m=4, train_iters=5), ds0)
    bigger = api.fit(api.FitConfig(grid=3, m=4, train_iters=5), ds1)
    server = api.Server(small)  # replicated: grid change is allowed
    server.swap(bigger)
    assert server.fitted is bigger


# ---------------------------------------------------------------------------
# sharded mesh lane (subprocess: virtual devices before jax init)
# ---------------------------------------------------------------------------

_SHARDED_SWAP_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=9"
    import asyncio

    import numpy as np

    from repro import api

    from repro.data.spatial import e3sm_like_field

    GS, M = 3, 4
    ds_a = e3sm_like_field(n=1000, seed=0)
    ds_b = e3sm_like_field(n=1000, seed=7)
    fitted_a = api.fit(api.FitConfig(grid=GS, m=M, train_iters=120, seed=0), ds_a)
    fitted_b = api.refit(fitted_a, ds_b, api.RefitConfig(train_iters=40))

    server = api.Server(fitted_a, api.ServeConfig(
        mode="sharded", pipeline="pipelined", router="two-level", backend="ref"))

    # wrong grid side must be refused BEFORE touching the serving path
    try:
        server.swap(api.fit(api.FitConfig(grid=2, m=M, train_iters=5), ds_a))
        raise SystemExit("grid-side mismatch was not rejected")
    except ValueError as e:
        assert "mesh" in str(e), e

    rng = np.random.default_rng(11)
    lo, hi = ds_a.x.min(axis=0), ds_a.x.max(axis=0)
    # pre-warm the q_max high-water mark far beyond any 32-row window so
    # every later batch reuses ONE compiled shape across both models —
    # the premise of the bitwise classification below
    server.submit(rng.uniform(lo, hi, (512, 2)).astype(np.float32))
    compiles_before = server.policy.stats()["compiles"]

    pool = [rng.uniform(lo, hi, (int(n), 2)).astype(np.float32)
            for n in rng.integers(1, 9, 6)]
    n_req = 30
    ref_a = [server.submit(p) for p in pool]

    served = []

    async def drive():
        loop = asyncio.get_running_loop()
        swap_done = asyncio.Event()
        state = {"completed": 0}
        fd_cfg = api.FrontDoorConfig(
            max_wait_ms=1.0, max_rows=32, max_request_rows=8, admission="shed")

        async def client(fd, i):
            if i >= 20:
                await swap_done.wait()
            else:
                await asyncio.sleep(0.002 * i)
            out = await fd.submit(pool[i % len(pool)])
            state["completed"] += 1
            served.append((i, out))

        async def swapper():
            while state["completed"] < 6:
                await asyncio.sleep(0.001)
            await loop.run_in_executor(
                None, lambda: server.swap(fitted_b, version="step-1"))
            swap_done.set()

        async with api.FrontDoor(server, fd_cfg) as fd:
            await asyncio.gather(swapper(), *(client(fd, i) for i in range(n_req)))
        return fd.report()

    rep = asyncio.run(drive())
    assert rep["requests"]["shed"] == 0, rep["requests"]
    assert rep["requests"]["completed"] == n_req, rep["requests"]
    # shape-stability premise: the stream (and the swap itself) never grew
    # q_max, so one compiled shape served both models
    assert server.policy.stats()["compiles"] == compiles_before

    ref_b = [server.submit(p) for p in pool]

    labels = []
    for i, out in served:
        ra, rb = ref_a[i % len(pool)], ref_b[i % len(pool)]
        if np.array_equal(out[0], ra[0]) and np.array_equal(out[1], ra[1]):
            labels.append("A")
        elif np.array_equal(out[0], rb[0]) and np.array_equal(out[1], rb[1]):
            labels.append("B")
        else:
            raise SystemExit(f"request {i} matched neither model bitwise")
    assert "A" in labels and "B" in labels, labels
    assert labels == sorted(labels), labels
    lc = rep["lifecycle"]
    assert lc["swaps"] == 1 and lc["active_version"] == "step-1", lc
    assert lc["versions"][0]["requests"] > 0 and lc["versions"][1]["requests"] > 0
    print("SHARDED-SWAP-OK")
    """
)


@pytest.mark.smoke
def test_sharded_swap_under_load():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-c", _SHARDED_SWAP_SCRIPT],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=570,
    )
    assert r.returncode == 0, r.stderr[-4000:]
    assert "SHARDED-SWAP-OK" in r.stdout
