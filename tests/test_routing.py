"""Query routing for distributed serving (repro.core.routing) + the public
corner_ids_weights API it is built on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import posterior, psvgp, routing, svgp
from repro.core.blend import corner_ids_weights, predict_blended
from repro.core.partition import make_grid, partition_data
from repro.data.spatial import e3sm_like_field
from repro.gp.covariances import make_covariance


def _grid_and_queries(gx=5, gy=4, n=613, seed=3):
    rng = np.random.default_rng(seed)
    pts = rng.uniform([-1.0, 2.0], [3.0, 5.0], size=(n, 2)).astype(np.float32)
    grid = make_grid(pts, gx, gy)
    return grid, pts


def test_corner_ids_weights_public_api():
    """Weights are a partition of unity; ids always name the 4 cell-center
    corners surrounding the point. The pre-PR-2 private alias
    ``_corner_ids_weights`` is gone (removed after its deprecation cycle)."""
    grid, pts = _grid_and_queries()
    ids, w = corner_ids_weights(grid, pts)
    assert ids.shape == (len(pts), 4) and w.shape == (len(pts), 4)
    np.testing.assert_allclose(w.sum(axis=1), 1.0, atol=1e-6)
    assert (w >= 0).all()
    assert (ids >= 0).all() and (ids < grid.num_partitions).all()

    # every corner is within one grid step (incl. diagonal) of the owner
    ix, iy = routing.owning_cells(grid, pts)
    dx = ids % grid.gx - ix[:, None]
    dy = ids // grid.gx - iy[:, None]
    assert (np.abs(dx) <= 1).all() and (np.abs(dy) <= 1).all()

    from repro.core import blend

    assert not hasattr(blend, "_corner_ids_weights")


def test_routing_table_round_trip():
    """Every query lands in its owning cell's block exactly once, slots
    reconstruct the corner ids, and scatter inverts the routing."""
    grid, pts = _grid_and_queries()
    table = routing.build_routing_table(grid, pts)

    P, qm = table.num_partitions, table.q_max
    assert P == grid.num_partitions and qm % 8 == 0
    assert table.num_queries == len(pts)
    np.testing.assert_array_equal(
        table.counts, np.bincount(
            routing.owning_cells(grid, pts)[1] * grid.gx
            + routing.owning_cells(grid, pts)[0],
            minlength=P,
        ),
    )
    # each partition's valid rows hold points inside that partition's cell
    for p in range(P):
        k = int(table.counts[p])
        assert (table.qmask[p, :k] == 1).all() and (table.qmask[p, k:] == 0).all()
        ix, iy = grid.cell_of(p)
        x = table.xq[p, :k]
        assert (grid.x_edges[ix] <= x[:, 0]).all() and (x[:, 0] <= grid.x_edges[ix + 1]).all()
        assert (grid.y_edges[iy] <= x[:, 1]).all() and (x[:, 1] <= grid.y_edges[iy + 1]).all()

    # scatter is the exact inverse of the routing permutation
    np.testing.assert_array_equal(routing.scatter_results(table, table.xq), pts)
    # weights ride along unchanged and padded rows carry zero weight
    w_back = routing.scatter_results(table, table.corner_w)
    np.testing.assert_array_equal(w_back, corner_ids_weights(grid, pts)[1])
    assert (table.corner_w[table.qmask == 0] == 0).all()

    # halo-slot encoding: slot k of owner p names partition halo_ids[p, k],
    # which must equal the blend's corner id
    hids = routing.halo_ids(grid)
    ids = corner_ids_weights(grid, pts)[0]
    slot_back = routing.scatter_results(table, table.corner_slot)
    ix, iy = routing.owning_cells(grid, pts)
    own = iy * grid.gx + ix
    np.testing.assert_array_equal(np.take_along_axis(hids[own], slot_back, axis=1), ids)


def test_routing_table_overflow_and_padding():
    grid, pts = _grid_and_queries(n=64)
    with pytest.raises(ValueError):
        routing.build_routing_table(grid, pts, q_max=1)
    t = routing.build_routing_table(grid, pts, q_max=50)
    assert t.q_max == 56  # rounded up to the pad multiple
    # padded rows are the owning cell's center (in-domain covariance input)
    p = int(np.argmin(t.counts))
    if t.counts[p] < t.q_max:
        ix, iy = grid.cell_of(p)
        cx = 0.5 * (grid.x_edges[ix] + grid.x_edges[ix + 1])
        cy = 0.5 * (grid.y_edges[iy] + grid.y_edges[iy + 1])
        np.testing.assert_allclose(t.xq[p, -1], [cx, cy], rtol=1e-6)


def test_routing_table_cells_passthrough():
    """Precomputed cells (the q_max policies bin the batch before building
    the table) must produce a table identical to in-place binning — every
    field, bitwise."""
    grid, pts = _grid_and_queries()
    cells = routing.owning_cells(grid, pts)
    t0 = routing.build_routing_table(grid, pts)
    t1 = routing.build_routing_table(grid, pts, cells=cells)
    for a, b in zip(t0, t1, strict=True):
        np.testing.assert_array_equal(a, b)
    with pytest.raises(ValueError, match="cells"):
        routing.build_routing_table(grid, pts, cells=(cells[0][:5], cells[1][:5]))


def test_streaming_qmax_policy():
    """High-water mark semantics: one compile for a steady stream, growth
    only on overflow, headroom + alignment on every growth."""
    pol = routing.StreamingQMax(headroom=1.25, pad_multiple=8)
    q1 = pol.fit(np.array([10, 3, 0]))
    assert q1 == routing.ceil_to(int(np.ceil(10 * 1.25)), 8) == 16
    assert pol.stats() == {"q_max": 16, "compiles": 1, "overflows": 0}
    # anything under the mark: no shape change, no recompile
    for c in ([12, 9], [16, 16], [1, 0]):
        assert pol.fit(np.array(c)) == 16
    assert pol.stats() == {"q_max": 16, "compiles": 1, "overflows": 0}
    # an overflowing batch grows the mark (and is counted)
    q2 = pol.fit(np.array([40]))
    assert q2 == routing.ceil_to(50, 8) == 56
    assert pol.stats() == {"q_max": 56, "compiles": 2, "overflows": 1}
    # empty batch never shrinks or breaks the mark
    assert pol.fit(np.array([])) == 56
    with pytest.raises(ValueError):
        routing.StreamingQMax(headroom=0.5)


def test_streaming_qmax_recompile_count_bounded():
    """Regression: an adversarial monotonically-growing stream must cost
    O(log(peak/first)) recompiles, not one per batch — the multiplicative
    headroom is what bounds the device-program recompiles on a live
    stream."""
    pol = routing.StreamingQMax(headroom=1.25, pad_multiple=8)
    needs = np.unique(np.geomspace(8, 4096, 200).astype(int))  # every batch grows
    for n in needs:
        pol.fit(np.array([n]))
    bound = int(np.ceil(np.log(4096 / 8) / np.log(1.25))) + 2
    assert pol.compiles <= bound, (pol.compiles, bound)
    assert pol.q_max >= 4096
    # steady stream at the peak: zero further compiles
    before = pol.compiles
    for _ in range(50):
        pol.fit(np.array([4096]))
    assert pol.compiles == before


def test_prepass_returns_reusable_cells():
    """The whole-stream prepass hands back its binning so the serving loop
    never re-bins (the PR-2 hot path binned every batch twice)."""
    from repro.launch import serve_sharded as ss

    grid, pts = _grid_and_queries()
    batches = [pts[:200], pts[200:500], pts[500:]]
    q_max, cells = ss.prepass_routing(grid, batches)
    assert q_max == ss.fixed_q_max(grid, batches)
    assert len(cells) == len(batches)
    for q, c in zip(batches, cells, strict=True):
        ix, iy = routing.owning_cells(grid, q)
        np.testing.assert_array_equal(c[0], ix)
        np.testing.assert_array_equal(c[1], iy)
        t0 = routing.build_routing_table(grid, q, q_max=q_max)
        t1 = routing.build_routing_table(grid, q, q_max=q_max, cells=c)
        np.testing.assert_array_equal(t0.xq, t1.xq)


def _skewed_queries(gx=6, gy=5, n_base=500, n_hot=1500, seed=5):
    """A batch with one synthetic hot cell (the two-level router's prey)."""
    rng = np.random.default_rng(seed)
    base = rng.uniform([0.0, 0.0], [6.0, 5.0], size=(n_base, 2))
    hot = rng.uniform([2.0, 2.0], [3.0, 3.0], size=(n_hot, 2))
    pts = np.concatenate([base, hot]).astype(np.float32)
    rng.shuffle(pts)
    grid = make_grid(pts, gx, gy)
    return grid, pts


def test_two_level_table_spills_within_corner_windows():
    """The tentpole's core invariants: a spill table at a q_max far below
    the hot-cell peak still recovers every query bitwise, respects the
    per-slot occupancy cap, hosts every spilled query on one of its own
    corner cells (so its blend corners stay inside the host's halo), and
    resolves exactly the same (corner id, weight) pairs as the blend."""
    grid, pts = _skewed_queries()
    ix, iy = routing.owning_cells(grid, pts)
    own = iy * grid.gx + ix
    ids, w = corner_ids_weights(grid, pts)
    single = routing.build_routing_table(grid, pts)

    q_max = routing.min_spill_q_max(own, ids, grid.num_partitions)
    assert q_max < int(single.counts.max())  # the cap really is below peak
    table = routing.build_routing_table(
        grid, pts, q_max=q_max, cells=(ix, iy), corners=(ids, w), spill=True
    )
    assert table.num_queries == len(pts)
    assert int(table.counts.max()) <= table.q_max
    assert table.num_spilled() > 0
    assert table.waste_rows() * 2 <= single.waste_rows()  # the point of it

    # scatter inverts the two-level permutation bitwise
    np.testing.assert_array_equal(routing.scatter_results(table, table.xq), pts)
    np.testing.assert_array_equal(routing.scatter_results(table, table.corner_w), w)
    np.testing.assert_array_equal(routing.scatter_results(table, table.owner), own)

    # host-relative slots resolve to the blend's corner ids, and every
    # spilled query is hosted on one of its corner cells
    P = grid.num_partitions
    hids = routing.halo_ids(grid)
    host_of_row = np.broadcast_to(np.arange(P)[:, None], table.qmask.shape)
    host_back = routing.scatter_results(table, host_of_row)
    slot_back = routing.scatter_results(table, table.corner_slot)
    np.testing.assert_array_equal(
        np.take_along_axis(hids[host_back], slot_back, axis=1), ids
    )
    spilled = host_back != own
    assert spilled.sum() == table.num_spilled()
    assert (host_back[:, None] == np.where(ids == own[:, None], -1, ids))[
        spilled
    ].any(axis=1).all(), "a spilled query left its corner window"

    # padded rows still carry weight zero / self slots
    assert (table.corner_w[table.qmask == 0] == 0).all()
    assert (table.corner_slot[table.qmask == 0] == routing.SELF_SLOT).all()


def test_two_level_infeasible_and_guards():
    grid, pts = _skewed_queries()
    with pytest.raises(ValueError, match="spill=True needs an explicit q_max"):
        routing.build_routing_table(grid, pts, spill=True)
    # below the feasible floor the assignment must refuse, not drop
    ix, iy = routing.owning_cells(grid, pts)
    own = iy * grid.gx + ix
    ids, _ = corner_ids_weights(grid, pts)
    floor = routing.min_spill_q_max(own, ids, grid.num_partitions)
    assert routing.spill_assign(own, ids, max(floor - 9, 1), grid.num_partitions) is None
    with pytest.raises(ValueError, match="infeasible"):
        routing.build_routing_table(grid, pts, q_max=max(floor - 9, 1),
                                    pad_multiple=1, spill=True)
    # determinism: two identical calls produce identical assignments
    h1 = routing.spill_assign(own, ids, floor, grid.num_partitions)
    h2 = routing.spill_assign(own, ids, floor, grid.num_partitions)
    np.testing.assert_array_equal(h1, h2)


def test_two_level_qmax_policy():
    """Post-spill high-water-mark semantics: a steady skewed stream costs
    ONE compile at a q_max well under the hot-cell peak; only a genuinely
    infeasible burst grows the mark; spill totals are reported."""
    grid, pts = _skewed_queries()
    ix, iy = routing.owning_cells(grid, pts)
    own = iy * grid.gx + ix
    ids, _ = corner_ids_weights(grid, pts)
    peak = int(np.bincount(own, minlength=grid.num_partitions).max())

    pol = routing.TwoLevelQMax(headroom=1.25, pad_multiple=8)
    qm0, hosts = pol.fit_spill(grid, own, ids)
    assert hosts.shape == own.shape and qm0 < peak
    assert pol.stats()["compiles"] == 1 and pol.stats()["overflows"] == 0
    assert pol.stats()["spilled"] > 0
    # steady stream: same batch fits the mark, no recompile
    for _ in range(3):
        qm, _ = pol.fit_spill(grid, own, ids)
        assert qm == qm0
    assert pol.stats()["compiles"] == 1
    # a much hotter burst overflows the mark and grows it
    burst = np.concatenate([pts] * 4)
    bix, biy = routing.owning_cells(grid, burst)
    bids, _ = corner_ids_weights(grid, burst)
    qm2, hosts2 = pol.fit_spill(grid, biy * grid.gx + bix, bids)
    assert qm2 > qm0
    assert pol.stats() == {
        "q_max": qm2, "compiles": 2, "overflows": 1, "spilled": pol.spilled
    }
    # the mark never shrinks and the single-level fit API is refused
    qm3, _ = pol.fit_spill(grid, own, ids)
    assert qm3 == qm2
    with pytest.raises(TypeError):
        pol.fit(np.array([1, 2, 3]))


def test_streaming_qmax_overflow_recovery_matches_prepass():
    """A stream whose PEAK ARRIVES LATE must re-route (never drop) the
    overflowing batch: the streaming policy grows its mark to cover the
    peak batch, whose routed table — and therefore its served results —
    must match the whole-stream prepass route BITWISE. Pre-peak batches
    route at a smaller q_max, so for them only full recovery (the scatter
    inverse) is asserted, not table equality."""
    from repro.launch import serve_sharded as ss

    grid, pts = _skewed_queries()
    rng = np.random.default_rng(9)
    small = [pts[rng.choice(len(pts), 300, replace=False)] for _ in range(3)]
    batches = small + [pts]  # the peak arrives last

    q_fix, cells = ss.prepass_routing(grid, batches)
    pol = routing.StreamingQMax()  # same headroom/alignment defaults
    tables_stream, tables_fix = [], []
    for i, q in enumerate(batches):
        c = routing.owning_cells(grid, q)
        counts = np.bincount(
            c[1] * grid.gx + c[0], minlength=grid.num_partitions
        )
        qm = pol.fit(counts)
        tables_stream.append(
            routing.build_routing_table(grid, q, q_max=qm, cells=c)
        )
        tables_fix.append(
            routing.build_routing_table(grid, q, q_max=q_fix, cells=cells[i])
        )
    assert pol.overflows >= 1  # the late peak really burst the mark
    # every batch fully recovered (nothing dropped) at every mark
    for q, t in zip(batches, tables_stream, strict=True):
        assert t.num_queries == len(q)
        np.testing.assert_array_equal(routing.scatter_results(t, t.xq), q)
    # the peak batch: policy mark == prepass mark, tables bitwise equal...
    assert tables_stream[-1].q_max == q_fix
    for a, b in zip(tables_stream[-1], tables_fix[-1], strict=True):
        np.testing.assert_array_equal(a, b)
    # ...and so are the served results (single-host reference program)
    cov_fn = make_covariance("rbf")
    params = jax.vmap(
        lambda k: svgp.init_svgp_params(
            k, svgp.SVGPConfig(num_inducing=5, input_dim=2)
        )
    )(jax.random.split(jax.random.PRNGKey(0), grid.num_partitions))
    cache = posterior.build_cache_stacked(params, cov_fn)
    m_s, v_s = routing.predict_routed(cache, cov_fn, grid, tables_stream[-1])
    m_f, v_f = routing.predict_routed(cache, cov_fn, grid, tables_fix[-1])
    np.testing.assert_array_equal(m_s, m_f)
    np.testing.assert_array_equal(v_s, v_f)


def test_halo_stacker_matches_halo_ids():
    """The host-side halo ingest: hx[p, k] is partition p+OFFSETS[k]'s
    block on-grid and zeros off-grid — exactly what a mesh-side ppermute
    exchange would deliver (the SPMD probe in test_serve_sharded asserts
    the same contract against the real collective)."""
    grid, pts = _grid_and_queries(gx=4, gy=3, n=217)
    table = routing.build_routing_table(grid, pts)
    hx = routing.make_halo_stacker(grid)(table.xq)
    P_, q = table.num_partitions, table.q_max
    assert hx.shape == (P_, routing.NUM_HALO_SLOTS, q, 2)
    hids = routing.halo_ids(grid)
    on = routing.halo_slot_on_grid(grid)
    for p in range(P_):
        ix, iy = grid.cell_of(p)
        for k, (dx, dy) in enumerate(routing.OFFSETS):
            on_grid = 0 <= ix + dx < grid.gx and 0 <= iy + dy < grid.gy
            assert on[p, k] == (1.0 if on_grid else 0.0)
            want = table.xq[hids[p, k]] if on_grid else np.zeros((q, 2), np.float32)
            np.testing.assert_array_equal(hx[p, k], want)


def test_predict_routed_matches_predict_blended():
    """The routed (sharded-math) serving path == the replicated blend on a
    trained model — the single-host half of the distributed-equivalence
    guarantee (the SPMD half is tests/test_serve_sharded.py)."""
    ds = e3sm_like_field(n=3000, seed=0)
    grid = make_grid(ds.x, 4, 4)
    data = partition_data(ds.x, ds.y, grid)
    cfg = psvgp.PSVGPConfig(
        svgp=svgp.SVGPConfig(num_inducing=6, input_dim=2),
        delta=0.25, batch_size=16, learning_rate=0.05,
    )
    static = psvgp.build(cfg, data)
    state = psvgp.init(jax.random.PRNGKey(0), cfg, data)
    state = psvgp.fit(static, state, data, 300)

    rng = np.random.default_rng(1)
    lo, hi = np.asarray(ds.x).min(0), np.asarray(ds.x).max(0)
    q = rng.uniform(lo, hi, (513, 2)).astype(np.float32)

    cache = psvgp.posterior_cache(static, state)
    table = routing.build_routing_table(grid, q)
    m_rt, v_rt = routing.predict_routed(cache, static.cov_fn, grid, table)
    m_rep, v_rep = predict_blended(static, state, grid, jnp.asarray(q), cache=cache)
    np.testing.assert_allclose(m_rt, np.asarray(m_rep), atol=1e-5)
    np.testing.assert_allclose(v_rt, np.asarray(v_rep), atol=1e-5)

    # the TWO-LEVEL route through the same program serves the same answers
    # (row placement is scheduling, never math)
    ix, iy = routing.owning_cells(grid, q)
    own = iy * grid.gx + ix
    ids, w = corner_ids_weights(grid, q)
    qm = routing.min_spill_q_max(own, ids, grid.num_partitions)
    t2 = routing.build_routing_table(
        grid, q, q_max=qm, cells=(ix, iy), corners=(ids, w), spill=True
    )
    m_2l, v_2l = routing.predict_routed(cache, static.cov_fn, grid, t2)
    np.testing.assert_allclose(m_2l, np.asarray(m_rep), atol=1e-5)
    np.testing.assert_allclose(v_2l, np.asarray(v_rep), atol=1e-5)
    np.testing.assert_allclose(m_2l, m_rt, atol=1e-6)
    np.testing.assert_allclose(v_2l, v_rt, atol=1e-6)
