"""Per-architecture smoke tests (assignment requirement): instantiate the
REDUCED same-family variant, run one forward + one train step on CPU,
assert output shapes and no NaNs. The FULL configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation) — see launch/dryrun.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get, get_smoke, swa_variant
from repro.models import transformer
from repro.runtime.steps import init_train_state, make_decode_step, make_prefill_step, make_train_step

KEY = jax.random.PRNGKey(0)
B, S = 2, 24

# exact assigned full-config numbers (guards against config drift)
EXPECTED_FULL = {
    "deepseek_moe_16b": dict(num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16, d_ff=1408, vocab_size=102400),
    "internvl2_76b": dict(num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8, d_ff=28672, vocab_size=128256),
    "qwen2_0_5b": dict(num_layers=24, d_model=896, num_heads=14, num_kv_heads=2, d_ff=4864, vocab_size=151936),
    "minicpm3_4b": dict(num_layers=62, d_model=2560, num_heads=40, d_ff=6400, vocab_size=73448),
    "qwen3_0_6b": dict(num_layers=28, d_model=1024, num_heads=16, num_kv_heads=8, d_ff=3072, vocab_size=151936),
    "whisper_base": dict(num_layers=6, d_model=512, num_heads=8, num_kv_heads=8, d_ff=2048, vocab_size=51865),
    "xlstm_350m": dict(num_layers=24, d_model=1024, num_heads=4, d_ff=0, vocab_size=50304),
    "recurrentgemma_2b": dict(num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1, d_ff=7680, vocab_size=256000),
    "qwen3_moe_30b_a3b": dict(num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4, d_ff=768, vocab_size=151936),
    "h2o_danube_3_4b": dict(num_layers=24, d_model=3840, num_heads=32, num_kv_heads=8, d_ff=10240, vocab_size=32000),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    cfg = get(arch)
    for k, v in EXPECTED_FULL[arch].items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def _batch(cfg):
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, 1)}
    if cfg.encoder is not None:
        e = cfg.encoder
        batch["frames"] = jax.random.normal(KEY, (B, e.num_frames, e.frontend_dim))
    if cfg.vision is not None:
        v = cfg.vision
        batch["patches"] = jax.random.normal(KEY, (B, v.num_patches, v.vit_dim))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke(arch)
    assert cfg.num_layers <= 4 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4
    state = init_train_state(KEY, cfg)
    batch = _batch(cfg)
    logits, _, _ = transformer.forward(
        state.params, cfg, batch["tokens"],
        frames=batch.get("frames"), patches=batch.get("patches"),
    )
    S_out = S + (cfg.vision.num_patches if cfg.vision is not None else 0)
    assert logits.shape == (B, S_out, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), "NaN in forward logits"
    state2, m = jax.jit(make_train_step(cfg, learning_rate=1e-3))(state, batch)
    assert np.isfinite(float(m["loss"])), "NaN train loss"
    # params actually changed
    d = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(state2.params), strict=True)
    )
    assert d > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_serve_step(arch):
    """Prefill + one decode step for every architecture (the decode_32k /
    long_500k path). Enc-dec prefills with frames; VLM with patches."""
    cfg = get_smoke(arch)
    state = init_train_state(KEY, cfg)
    batch = _batch(cfg)
    pf = jax.jit(make_prefill_step(cfg, cache_len=S + 8))
    lg_p, cache = pf(
        state.params, batch["tokens"],
        frames=batch.get("frames"), patches=batch.get("patches"),
    )
    assert np.isfinite(np.asarray(lg_p)).all()
    dec = jax.jit(make_decode_step(cfg))
    pos = S + (cfg.vision.num_patches if cfg.vision is not None else 0)
    lg, cache2 = dec(state.params, cache, jnp.asarray(pos, jnp.int32), batch["tokens"][:, :1])
    assert lg.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(lg)).all(), "NaN decode logits"


def test_swa_variant_only_rewrites_quadratic_attention():
    assert swa_variant(get("qwen2-0.5b")).block_pattern == ("local_attn",)
    assert swa_variant(get("qwen2-0.5b")).sliding_window == 4096
    # sub-quadratic archs unchanged
    assert swa_variant(get("xlstm-350m")) is get("xlstm-350m")
    assert swa_variant(get("recurrentgemma-2b")) is get("recurrentgemma-2b")
    assert swa_variant(get("h2o-danube-3-4b")) is get("h2o-danube-3-4b")
    # MLA keeps its native compressed cache
    assert swa_variant(get("minicpm3-4b")) is get("minicpm3-4b")


def test_registry_roundtrip():
    for arch in ARCH_IDS:
        assert get(arch).name.replace("-", "_").replace(".", "_") == arch
