"""Integration tests for the PSVGP trainer (paper §4) — both comm modes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import psvgp, svgp
from repro.core.metrics import boundary_rmsd, per_partition_rmspe, rmspe
from repro.core.neighbors import boundary_probes
from repro.core.partition import make_grid, partition_data
from repro.data.spatial import e3sm_like_field


@pytest.fixture(scope="module")
def small_problem():
    ds = e3sm_like_field(n=3000, seed=0)
    grid = make_grid(ds.x, gx=6, gy=6)
    data = partition_data(ds.x, ds.y, grid)
    probes = boundary_probes(grid, probes_per_edge=6)
    return ds, grid, data, probes


def _train(data, delta, comm, iters=300, m=8, seed=0, lr=0.05, B=16):
    cfg = psvgp.PSVGPConfig(
        svgp=svgp.SVGPConfig(num_inducing=m, input_dim=2),
        delta=delta,
        batch_size=B,
        learning_rate=lr,
        comm=comm,
        seed=seed,
    )
    static = psvgp.build(cfg, data)
    state = psvgp.init(jax.random.PRNGKey(seed), cfg, data)
    state = psvgp.fit(static, state, data, iters)
    return static, state


@pytest.mark.parametrize("comm", ["gather", "ppermute"])
def test_training_reduces_rmspe(small_problem, comm):
    ds, grid, data, probes = small_problem
    cfg = psvgp.PSVGPConfig(
        svgp=svgp.SVGPConfig(num_inducing=8, input_dim=2),
        delta=0.15, batch_size=16, learning_rate=0.05, comm=comm,
    )
    static = psvgp.build(cfg, data)
    state0 = psvgp.init(jax.random.PRNGKey(0), cfg, data)
    r0 = float(rmspe(static, state0, data))
    state = psvgp.fit(static, state0, data, 300)
    r1 = float(rmspe(static, state, data))
    assert np.isfinite(r1)
    assert r1 < 0.8 * r0  # substantial fit improvement
    assert np.isfinite(float(boundary_rmsd(static, state, probes)))


def test_delta_zero_matches_independent_training(small_problem):
    """PSVGP with delta=0 IS ISVGP: identical to a trainer whose sampler is
    hard-pinned to the home partition (paper §4.3)."""
    ds, grid, data, probes = small_problem
    static_a, state_a = _train(data, delta=0.0, comm="gather", iters=50)
    # pinned sampler: force slot distribution to delta=0 analytically ==
    # the same code path, so instead compare against delta=tiny>0 with the
    # SAME seed: updates must differ (sanity that delta matters) while
    # delta=0 twice is bitwise identical.
    static_b, state_b = _train(data, delta=0.0, comm="gather", iters=50)
    for a, b in zip(jax.tree.leaves(state_a.params), jax.tree.leaves(state_b.params), strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    static_c, state_c = _train(data, delta=0.8, comm="gather", iters=50)
    diffs = [
        float(jnp.max(jnp.abs(a - c)))
        for a, c in zip(jax.tree.leaves(state_a.params), jax.tree.leaves(state_c.params), strict=True)
    ]
    assert max(diffs) > 1e-6  # neighbor sampling actually changed training


@pytest.mark.slow
def test_delta_improves_boundary_smoothness(small_problem):
    """The paper's headline claim (fig. 4 right): delta > 0 reduces boundary
    RMSD relative to ISVGP (delta = 0). Needs converged models (the effect
    is invisible mid-training), hence 1500+ iters and 2 seeds averaged —
    the paper itself averages 10 replications."""
    ds, grid, data, probes = small_problem
    r0, r1 = [], []
    for seed in (1, 2):
        s0, st0 = _train(data, delta=0.0, comm="gather", iters=1500, m=5, seed=seed)
        s1, st1 = _train(data, delta=1.0, comm="gather", iters=1500, m=5, seed=seed)
        r0.append(float(boundary_rmsd(s0, st0, probes)))
        r1.append(float(boundary_rmsd(s1, st1, probes)))
    assert np.mean(r1) < np.mean(r0), (r0, r1)


@pytest.mark.slow
def test_ppermute_and_gather_converge_similarly(small_problem):
    """The TPU-native synchronized-direction estimator optimizes the same
    objective: final RMSPE within 20% of the gather mode's (its importance-
    weighted gradients have higher variance, so exact parity per-step is
    not expected — unbiasedness is what matters). Averaged over 2 seeds,
    like the boundary-smoothness test above: a single run's gap fluctuates
    right around the bound (measured 0.21 / 0.16 on seeds 3 / 4)."""
    ds, grid, data, probes = small_problem
    ra, rb = [], []
    for seed in (3, 4):
        sa, st_a = _train(data, delta=0.25, comm="gather", iters=1500, seed=seed)
        sb, st_b = _train(data, delta=0.25, comm="ppermute", iters=1500, seed=seed)
        ra.append(float(rmspe(sa, st_a, data)))
        rb.append(float(rmspe(sb, st_b, data)))
    ra, rb = np.mean(ra), np.mean(rb)
    assert abs(ra - rb) < 0.2 * ra, (ra, rb)


def test_per_partition_rmspe_finite(small_problem):
    ds, grid, data, probes = small_problem
    static, state = _train(data, delta=0.1, comm="gather", iters=100)
    pp = np.asarray(per_partition_rmspe(static, state, data))
    assert pp.shape == (data.num_partitions,)
    assert np.isfinite(pp).all()


def test_no_nans_with_tiny_partitions():
    """Partitions with very few points (the paper's pole cells have as few
    as 8 obs) must not produce NaNs."""
    rng = np.random.default_rng(0)
    x = rng.uniform(0, 1, (40, 2)).astype(np.float32)
    y = rng.normal(size=40).astype(np.float32)
    grid = make_grid(x, 4, 4)  # ~2.5 points per partition; some empty
    data = partition_data(x, y, grid)
    static, state = _train(data, delta=0.5, comm="gather", iters=100, m=4, B=8)
    assert all(np.isfinite(np.asarray(l)).all() for l in jax.tree.leaves(state.params))
    assert np.isfinite(float(rmspe(static, state, data)))
