"""Non-Gaussian likelihood extension (paper §6 future work) + closed forms."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import psvgp, svgp
from repro.core.partition import make_grid, partition_data
from repro.gp.likelihoods import (
    gaussian_expected_loglik,
    poisson_expected_loglik,
    poisson_expected_loglik_quadrature,
)


def test_poisson_closed_form_matches_quadrature():
    key = jax.random.PRNGKey(0)
    fmean = jax.random.normal(key, (50,))
    fvar = jax.random.uniform(jax.random.PRNGKey(1), (50,), minval=0.01, maxval=0.5)
    y = jax.random.poisson(jax.random.PRNGKey(2), jnp.exp(fmean)).astype(jnp.float32)
    a = poisson_expected_loglik(y, fmean, fvar)
    b = poisson_expected_loglik_quadrature(y, fmean, fvar)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-3)


def test_gaussian_expected_loglik_zero_variance_is_logpdf():
    y = jnp.asarray([0.3, -1.2])
    f = jnp.asarray([0.1, -1.0])
    got = gaussian_expected_loglik(y, f, jnp.zeros(2), jnp.asarray(0.0))
    want = -0.5 * np.log(2 * np.pi) - 0.5 * (np.asarray(y) - np.asarray(f)) ** 2
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6)


def test_poisson_psvgp_fits_count_field():
    """End-to-end: PSVGP with the Poisson likelihood learns a count field
    (rate = exp(smooth surface)); predictions correlate with the truth."""
    rng = np.random.default_rng(0)
    n = 3000
    x = rng.uniform(0, 4, (n, 2)).astype(np.float32)
    f_true = 1.2 * np.sin(x[:, 0] * 1.5) + 0.8 * np.cos(x[:, 1] * 1.2)
    y = rng.poisson(np.exp(f_true)).astype(np.float32)

    grid = make_grid(x, 4, 4)
    data = partition_data(x, y, grid)
    # whitened=True is REQUIRED here: with the unwhitened parameterization
    # the q(u) gradients are conditioned through an ill-conditioned Kmm and
    # minibatch SGD stalls for non-Gaussian likelihoods (measured corr 0.16
    # vs 0.98 whitened — EXPERIMENTS.md beyond-paper notes).
    cfg = psvgp.PSVGPConfig(
        svgp=svgp.SVGPConfig(num_inducing=8, input_dim=2, likelihood="poisson",
                             whitened=True),
        delta=0.125, batch_size=32, learning_rate=0.05,
    )
    static = psvgp.build(cfg, data)
    state = psvgp.init(jax.random.PRNGKey(0), cfg, data)
    state = psvgp.fit(static, state, data, 800)

    from repro.core.psvgp import predict_local

    fmean, _ = predict_local(static, state, data.x)
    mask = np.asarray(data.mask) > 0
    # latent prediction should correlate strongly with the true log-rate
    f_hat = np.asarray(fmean)[mask]
    # recompute true f at the padded layout
    xs = np.asarray(data.x)[mask]
    f_ref = 1.2 * np.sin(xs[:, 0] * 1.5) + 0.8 * np.cos(xs[:, 1] * 1.2)
    r = np.corrcoef(f_hat, f_ref)[0, 1]
    assert np.isfinite(f_hat).all()
    assert r > 0.8, r
