"""Property tests for the eq. (8)/(9) delta-weighted sampler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dependency (pyproject [dev]); shim sweeps
    from _hypothesis_shim import given, settings, st

from repro.core.neighbors import neighbor_table
from repro.core.partition import make_grid
from repro.core.sampler import (
    sample_minibatch_indices,
    sample_slots,
    slot_distribution,
)


def _grid(gx=4, gy=4):
    return make_grid(np.zeros((1, 2), np.float32), gx, gy, bounds=(0, 1, 0, 1))


@given(delta=st.floats(0.0, 1.0), seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_slot_probs_sum_to_one(delta, seed):
    grid = _grid()
    tbl = jnp.asarray(neighbor_table(grid))
    counts = jnp.asarray(
        np.random.default_rng(seed).integers(5, 200, grid.num_partitions), jnp.int32
    )
    dist = slot_distribution(counts, tbl, delta)
    np.testing.assert_allclose(np.asarray(dist.probs).sum(1), 1.0, rtol=1e-5)
    assert (np.asarray(dist.probs) >= 0).all()


def test_delta_zero_is_isvgp():
    """delta=0 must make the sampler ALWAYS choose the home partition —
    the paper's claim that PSVGP(delta=0) == ISVGP."""
    grid = _grid()
    tbl = jnp.asarray(neighbor_table(grid))
    counts = jnp.full((grid.num_partitions,), 100, jnp.int32)
    dist = slot_distribution(counts, tbl, 0.0)
    probs = np.asarray(dist.probs)
    np.testing.assert_allclose(probs[:, 0], 1.0)
    np.testing.assert_allclose(probs[:, 1:], 0.0)
    np.testing.assert_allclose(np.asarray(dist.n_eff), counts)
    kprime, slot = sample_slots(jax.random.PRNGKey(0), dist)
    np.testing.assert_array_equal(np.asarray(kprime), np.arange(grid.num_partitions))


@pytest.mark.parametrize("delta", [0.25, 0.5, 1.0])
def test_balanced_grid_self_probability_formula(delta):
    """Paper §4.3: on a balanced grid, an interior partition takes its own
    mini-batch with probability 1 - 2 d delta / (2 d + 1) ... which for the
    eq. (9) weights means P(self) = n / (n + 4 delta n) = 1 / (1 + 4 delta).
    The paper's closed form is stated for its delta-parameterized sampler;
    we verify the eq. (9) math directly."""
    grid = _grid(5, 5)
    tbl = jnp.asarray(neighbor_table(grid))
    counts = jnp.full((25,), 100, jnp.int32)
    dist = slot_distribution(counts, tbl, delta)
    interior = grid.index_of(2, 2)
    p_self = float(dist.probs[interior, 0])
    np.testing.assert_allclose(p_self, 1.0 / (1.0 + 4.0 * delta), rtol=1e-5)


def test_empirical_slot_frequencies_match_probs():
    """Gumbel-max categorical sampling is faithful to eq. (9)."""
    grid = _grid(3, 3)
    tbl = jnp.asarray(neighbor_table(grid))
    counts = jnp.asarray(np.random.default_rng(0).integers(50, 150, 9), jnp.int32)
    dist = slot_distribution(counts, tbl, 0.5)
    S = 4000
    keys = jax.random.split(jax.random.PRNGKey(1), S)
    slots = jax.vmap(lambda k: sample_slots(k, dist)[1])(keys)  # (S, P)
    emp = np.stack([(np.asarray(slots) == s).mean(0) for s in range(5)], axis=1)
    np.testing.assert_allclose(emp, np.asarray(dist.probs), atol=0.03)


@given(batch=st.integers(1, 32), n_valid=st.integers(1, 40), seed=st.integers(0, 50))
@settings(max_examples=30, deadline=None)
def test_minibatch_without_replacement(batch, n_valid, seed):
    """No index repeats among valid draws; masked-out slots never sampled
    unless the partition runs out of points (then bmask flags them)."""
    n_max = 48
    mask = jnp.zeros((2, n_max)).at[:, :n_valid].set(1.0)
    idx, bmask = sample_minibatch_indices(jax.random.PRNGKey(seed), mask, batch)
    idx, bmask = np.asarray(idx), np.asarray(bmask)
    for p in range(2):
        valid_idx = idx[p][bmask[p] > 0]
        assert len(np.unique(valid_idx)) == len(valid_idx)  # no replacement
        assert (valid_idx < n_valid).all()  # only true points
        assert bmask[p].sum() == min(batch, n_valid)  # degrades gracefully


def test_minibatch_uniformity():
    """Each valid point is equally likely to be drawn (chi-square-ish)."""
    n_max, n_valid, B, S = 16, 12, 4, 3000
    mask = jnp.zeros((1, n_max)).at[:, :n_valid].set(1.0)
    keys = jax.random.split(jax.random.PRNGKey(7), S)
    idx = jax.vmap(lambda k: sample_minibatch_indices(k, mask, B)[0])(keys)
    freq = np.bincount(np.asarray(idx).ravel(), minlength=n_max) / (S * B)
    np.testing.assert_allclose(freq[:n_valid], 1.0 / n_valid, atol=0.01)
    assert freq[n_valid:].sum() == 0.0
