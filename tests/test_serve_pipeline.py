"""The overlapped serving pipeline — host-side logic, no device mesh.

The driver (``serve_sharded.pipelined_request_loop``) and the stage
factory are pure host scheduling around an opaque device program, so the
double-buffering contract is testable with stub stages in the default
lane: results bitwise-identical to serial on the same stream and in
order, batch t+1 routed BEFORE batch t's result is collected, the
streaming q_max policy driving recompiles boundedly. The real-mesh half
(shard_map program, collectives) is the slow lane in
tests/test_serve_sharded.py.

Also covers the shard_map in_spec derivation (``cache_in_specs``): specs
must come from the pytree STRUCTURE of the cache being served, never from
a hand-built field-by-field literal that a future PosteriorCache field
would silently desync from.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import posterior, routing, svgp
from repro.gp.covariances import CovarianceParams, make_covariance
from repro.launch import serve_sharded as ss


def _stub_stages(log):
    """Stage callables that tag events instead of touching devices.
    submit 'evaluates' instantly (sum per batch) so collect is a no-op
    unwrap — the loop's scheduling is what is under test."""

    def route(q):
        log.append(("route", int(q[0])))
        return ("table", q)

    def submit(routed):
        _, q = routed
        log.append(("submit", int(q[0])))
        return ("pending", q, q.sum())

    def collect(pending):
        _, q, s = pending
        log.append(("collect", int(q[0])))
        return (q * 2.0, np.full_like(q, s))

    return route, submit, collect


def _stream(n=6, size=5):
    rng = np.random.default_rng(0)
    out = []
    for i in range(n):
        q = rng.normal(size=(size,)).astype(np.float32)
        q[0] = i  # tag each batch with its index for the event log
        out.append(q)
    return out


def test_pipelined_results_bitwise_equal_serial_and_ordered():
    batches = _stream()
    route, submit, collect = _stub_stages([])
    serial = [collect(submit(route(q))) for q in batches]

    got = {}
    pct, qps = ss.pipelined_request_loop(
        route, submit, collect, batches,
        warm=False, on_result=lambda i, out: got.setdefault(i, out),
    )
    assert sorted(got) == list(range(len(batches)))  # every result, in order
    for i, (m_s, v_s) in enumerate(serial):
        np.testing.assert_array_equal(got[i][0], m_s)
        np.testing.assert_array_equal(got[i][1], v_s)
    assert set(pct) == {"p50_ms", "p95_ms", "p99_ms"} and qps > 0


def test_pipelined_loop_overlaps_route_with_inflight_batch():
    """The point of the pipeline: batch t+1 is routed AFTER batch t is
    submitted but BEFORE batch t's result is collected — for every t."""
    batches = _stream()
    log = []
    route, submit, collect = _stub_stages(log)
    ss.pipelined_request_loop(route, submit, collect, batches, warm=False)
    for t in range(len(batches) - 1):
        i_sub = log.index(("submit", t))
        i_rt = log.index(("route", t + 1))
        i_col = log.index(("collect", t))
        assert i_sub < i_rt < i_col, (t, log)


def test_pipelined_warm_runs_batch0_through_all_stages():
    batches = _stream(3)
    log = []
    route, submit, collect = _stub_stages(log)
    ss.pipelined_request_loop(route, submit, collect, batches, warm=True)
    # warm pass + measured pass both start with batch 0
    assert [e for e in log if e[0] == "route"][:2] == [("route", 0), ("route", 0)]


def test_make_request_stages_policy_xor_qmax():
    with pytest.raises(ValueError, match="exactly one"):
        ss.make_request_stages(None, None, None)
    with pytest.raises(ValueError, match="exactly one"):
        ss.make_request_stages(
            None, None, None, policy=routing.StreamingQMax(), q_max=8
        )


def _tiny_cache(key=0, m=5):
    cov_fn = make_covariance("rbf")
    params = svgp.init_svgp_params(
        jax.random.PRNGKey(key), svgp.SVGPConfig(num_inducing=m, input_dim=2)
    )
    return posterior.build_cache(params, cov_fn)


def test_cache_in_specs_derived_from_structure():
    """The spec tree must mirror the cache pytree exactly (same treedef,
    the given spec at every leaf). The expected literal below is the
    regression oracle: if PosteriorCache grows a field, this test fails
    and forces a conscious decision about how the new field shards."""
    cache = jax.tree.map(lambda a: jnp.stack([a, a]), _tiny_cache())
    sentinel = object()
    specs = ss.cache_in_specs(cache, sentinel)
    assert jax.tree.structure(specs) == jax.tree.structure(cache)
    assert all(s is sentinel for s in jax.tree.leaves(specs))
    expected = posterior.PosteriorCache(
        z=sentinel, w=sentinel, u=sentinel, c=sentinel,
        cov=CovarianceParams(log_lengthscale=sentinel, log_variance=sentinel),
        log_beta=sentinel,
    )
    assert jax.tree.structure(specs) == jax.tree.structure(expected), (
        "PosteriorCache grew a field: decide how it shards in the serving "
        "program (cache_in_specs gives it the leading-P spec automatically; "
        "update this oracle once that is confirmed correct)"
    )


def test_two_level_policy_drives_pipeline_shapes():
    """Host half of two-level serving: a skewed stream routed through the
    stage factory with a TwoLevelQMax policy keeps q_max well under the
    hot-cell peak, every table honors the policy's mark, the halo stack
    keeps the (P, 9, q_max, 2) contract, and the scatter inverse recovers
    every batch bitwise."""
    from repro.core.partition import make_grid

    rng = np.random.default_rng(3)
    base = rng.uniform(-1.0, 1.0, size=(1500, 2)).astype(np.float32)
    # hot spot well inside the CENTER cell of the 3x3 grid over [-1, 1]^2
    hot = rng.uniform(-0.25, -0.05, size=(3500, 2)).astype(np.float32)
    pts = np.concatenate([base, hot])
    rng.shuffle(pts)
    grid = make_grid(pts, 3, 3)
    policy = routing.TwoLevelQMax()
    stacker = routing.make_halo_stacker(grid)
    from repro.core.blend import corner_ids_weights

    peak = 0
    for nsz in (800, 800, 5000, 5000):
        q = pts[:nsz]
        cells = routing.owning_cells(grid, q)
        own = cells[1] * grid.gx + cells[0]
        ids, w = corner_ids_weights(grid, q)
        peak = max(peak, int(np.bincount(own, minlength=9).max()))
        qm, hosts = policy.fit_spill(grid, own, ids)
        table = routing.build_routing_table(
            grid, q, q_max=qm, cells=cells, corners=(ids, w),
            spill=True, hosts=hosts,
        )
        assert table.q_max == qm
        assert int(table.counts.max()) <= qm
        np.testing.assert_array_equal(routing.scatter_results(table, table.xq), q)
        hx = stacker(table.xq)
        assert hx.shape == (grid.num_partitions, 9, qm, 2)
    assert policy.q_max < peak  # the budget stayed under the hot peak
    assert policy.compiles <= 2 and policy.spilled > 0


def test_streaming_policy_drives_pipeline_shapes():
    """End-to-end host half: a growing stream recompiles boundedly and
    every batch's table honors the policy's q_max."""
    rng = np.random.default_rng(1)
    pts = rng.uniform(-1.0, 1.0, size=(4000, 2)).astype(np.float32)
    from repro.core.partition import make_grid

    grid = make_grid(pts, 3, 3)
    policy = routing.StreamingQMax()
    stacker = routing.make_halo_stacker(grid)
    sizes = [40, 60, 60, 500, 500, 3000, 3000]
    q_maxes = []
    for nsz in sizes:
        q = pts[:nsz]
        cells = routing.owning_cells(grid, q)
        counts = np.bincount(
            cells[1] * grid.gx + cells[0], minlength=grid.num_partitions
        )
        qm = policy.fit(counts)
        table = routing.build_routing_table(grid, q, q_max=qm, cells=cells)
        assert table.q_max == qm
        hx = stacker(table.xq)
        assert hx.shape == (grid.num_partitions, 9, qm, 2)
        q_maxes.append(qm)
    assert policy.compiles == len(set(q_maxes))  # every shape counted once
    assert policy.compiles <= 4  # 3 growth steps + first on this stream
    assert policy.overflows == policy.compiles - 1
