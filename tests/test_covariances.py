"""Covariance-function properties (unit + hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dependency (pyproject [dev]); shim sweeps
    from _hypothesis_shim import given, settings, st

from repro.gp.covariances import (
    _LON_PERIOD,
    init_covariance_params,
    make_covariance,
    matern32,
    matern52,
    periodic_lon_rbf,
    rbf,
)

KERNELS = ["rbf", "matern32", "matern52", "periodic_lon_rbf"]


@pytest.mark.parametrize("name", KERNELS)
def test_psd_and_symmetric(name):
    """K(X,X) must be symmetric PSD with variance on the diagonal."""
    k = make_covariance(name)
    x = jax.random.normal(jax.random.PRNGKey(0), (40, 2))
    p = init_covariance_params(2, lengthscale=0.7, variance=1.3)
    K = np.asarray(k(p, x, x))
    np.testing.assert_allclose(K, K.T, atol=1e-6)
    w = np.linalg.eigvalsh(K + 1e-5 * np.eye(40))
    assert w.min() > -1e-4
    np.testing.assert_allclose(np.diag(K), 1.3, rtol=1e-5)


@given(
    l=st.floats(0.2, 3.0), v=st.floats(0.2, 3.0), seed=st.integers(0, 100)
)
@settings(max_examples=20, deadline=None)
def test_bounded_by_variance(l, v, seed):
    for name in KERNELS:
        k = make_covariance(name)
        key = jax.random.PRNGKey(seed)
        x = jax.random.normal(key, (10, 2))
        z = jax.random.normal(jax.random.fold_in(key, 1), (12, 2))
        p = init_covariance_params(2, lengthscale=l, variance=v)
        K = np.asarray(k(p, x, z))
        assert (K <= v * (1 + 1e-5)).all()
        assert (K >= 0).all()


def test_periodic_lon_wraps_seam():
    """Points separated by exactly one longitude period are identical to
    the kernel — the 0/360-seam fix for wrap_x grids."""
    p = init_covariance_params(2, lengthscale=1.0, variance=1.0)
    a = jnp.asarray([[0.1, 0.5]])
    b = jnp.asarray([[0.1 + _LON_PERIOD, 0.5]])
    c = jnp.asarray([[0.1 + _LON_PERIOD / 2, 0.5]])  # opposite side of globe
    k_same = float(periodic_lon_rbf(p, a, b)[0, 0])
    k_far = float(periodic_lon_rbf(p, a, c)[0, 0])
    np.testing.assert_allclose(k_same, 1.0, rtol=1e-6)
    assert k_far < k_same


def test_matern_smoothness_ordering():
    """At moderate distance: rbf (smoothest) >= matern52 >= matern32."""
    p = init_covariance_params(2, lengthscale=1.0, variance=1.0)
    x = jnp.zeros((1, 2))
    z = jnp.asarray([[0.8, 0.0]])
    k_rbf = float(rbf(p, x, z)[0, 0])
    k_52 = float(matern52(p, x, z)[0, 0])
    k_32 = float(matern32(p, x, z)[0, 0])
    assert k_rbf > k_52 > k_32


def test_wrapped_psvgp_with_periodic_kernel():
    """End-to-end: wrap_x grid + periodic kernel trains with neighbor
    sampling across the dateline seam and stays finite."""
    from repro.core import psvgp, svgp
    from repro.core.metrics import rmspe
    from repro.core.partition import make_grid, partition_data
    from repro.data.spatial import e3sm_like_field

    ds = e3sm_like_field(n=2500, seed=0)
    grid = make_grid(ds.x, 5, 4, wrap_x=True)
    data = partition_data(ds.x, ds.y, grid)
    cfg = psvgp.PSVGPConfig(
        svgp=svgp.SVGPConfig(num_inducing=6, input_dim=2, covariance="periodic_lon_rbf"),
        delta=0.25, batch_size=16, learning_rate=0.05,
    )
    static = psvgp.build(cfg, data)
    state = psvgp.init(jax.random.PRNGKey(0), cfg, data)
    state = psvgp.fit(static, state, data, 300)
    assert np.isfinite(float(rmspe(static, state, data)))
