"""Runtime integration: LM checkpoint/resume, hybrid long decode, data flow."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_train_state, save_train_state
from repro.configs import get_smoke
from repro.data.tokens import synthetic_token_batches
from repro.models import transformer
from repro.runtime.steps import init_train_state, make_decode_step, make_prefill_step, make_train_step

KEY = jax.random.PRNGKey(0)


def test_lm_train_checkpoint_resume(tmp_path):
    """Interrupted training resumes bit-exactly from the checkpoint."""
    cfg = get_smoke("qwen3-0.6b")
    state = init_train_state(KEY, cfg)
    ts = jax.jit(make_train_step(cfg, learning_rate=1e-3))
    data = list(synthetic_token_batches(cfg.vocab_size, 2, 32, seed=1, num_batches=6))

    # run 3 steps, checkpoint, run 3 more
    for toks, tg in data[:3]:
        state, _ = ts(state, {"tokens": jnp.asarray(toks), "targets": jnp.asarray(tg)})
    save_train_state(str(tmp_path), 3, state)
    cont = state
    for toks, tg in data[3:]:
        cont, m_direct = ts(cont, {"tokens": jnp.asarray(toks), "targets": jnp.asarray(tg)})

    # restore and replay the same 3 steps
    template = init_train_state(KEY, cfg)
    restored = load_train_state(str(tmp_path), template)
    assert int(restored.step) == 3
    for toks, tg in data[3:]:
        restored, m_resumed = ts(restored, {"tokens": jnp.asarray(toks), "targets": jnp.asarray(tg)})
    np.testing.assert_allclose(float(m_direct["loss"]), float(m_resumed["loss"]), rtol=1e-6)
    for a, b in zip(jax.tree.leaves(cont.params), jax.tree.leaves(restored.params), strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_hybrid_long_decode_state_and_ring_cache():
    """RecurrentGemma-family: decode far past the attention window keeps the
    RG-LRU state exact and the ring cache consistent with a full forward."""
    cfg = get_smoke("recurrentgemma-2b")  # window 16, pattern r,r,a,r
    state = init_train_state(KEY, cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 20), 0, cfg.vocab_size)
    cont = jax.random.randint(jax.random.PRNGKey(2), (1, 12), 0, cfg.vocab_size)

    pf = jax.jit(make_prefill_step(cfg, cache_len=64))
    _, cache = pf(state.params, prompt)
    dec = jax.jit(make_decode_step(cfg))
    outs = []
    for i in range(12):
        lg, cache = dec(state.params, cache, jnp.asarray(20 + i, jnp.int32), cont[:, i : i + 1])
        outs.append(np.asarray(lg))
    full, _, _ = transformer.forward(state.params, cfg, jnp.concatenate([prompt, cont], 1))
    np.testing.assert_allclose(
        np.stack(outs, 1), np.asarray(full[:, 20:]), atol=5e-4
    )


def test_xlstm_decode_long_chain():
    """SSM decode: 20-step chain == full forward (matrix + scalar memory)."""
    cfg = get_smoke("xlstm-350m")
    state = init_train_state(KEY, cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(3), (2, 16), 0, cfg.vocab_size)
    cont = jax.random.randint(jax.random.PRNGKey(4), (2, 20), 0, cfg.vocab_size)
    pf = jax.jit(make_prefill_step(cfg, cache_len=48))
    _, cache = pf(state.params, prompt)
    dec = jax.jit(make_decode_step(cfg))
    outs = []
    for i in range(20):
        lg, cache = dec(state.params, cache, jnp.asarray(16 + i, jnp.int32), cont[:, i : i + 1])
        outs.append(np.asarray(lg))
    full, _, _ = transformer.forward(state.params, cfg, jnp.concatenate([prompt, cont], 1))
    np.testing.assert_allclose(np.stack(outs, 1), np.asarray(full[:, 16:]), atol=5e-4)
