"""Substrate tests: optimizer, schedules, checkpointing, data, sharding rules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dependency (pyproject [dev]); shim sweeps
    from _hypothesis_shim import given, settings, st

from repro.checkpoint import load_train_state, save_train_state, save_pytree, load_pytree
from repro.data.tokens import synthetic_token_batches
from repro.optim import (
    adam_init,
    adam_update,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    cosine_schedule,
    linear_warmup_cosine,
)


def test_adam_converges_quadratic():
    """Adam minimizes a convex quadratic to high precision."""
    target = jnp.asarray([1.5, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    state = adam_init(params)
    grad_fn = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))
    for _ in range(600):
        params, state = adam_update(params, grad_fn(params), state, lr=0.05)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=1e-3)


def test_adamw_decays_unused_weights():
    params = {"w": jnp.ones(4)}
    state = adam_init(params)
    zeros = {"w": jnp.zeros(4)}
    for _ in range(100):
        params, state = adamw_update(params, zeros, state, lr=1e-2, weight_decay=0.1)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1.0  # decayed toward zero


@given(st.floats(0.1, 10.0), st.integers(1, 5))
@settings(max_examples=10, deadline=None)
def test_clip_by_global_norm(max_norm, seed):
    g = {"a": jax.random.normal(jax.random.PRNGKey(seed), (8,)) * 100}
    clipped = clip_by_global_norm(g, max_norm)
    assert float(global_norm(clipped)) <= max_norm * (1 + 1e-5)


def test_schedules():
    s = cosine_schedule(1.0, 100, final_frac=0.1)
    assert abs(float(s(jnp.asarray(0))) - 1.0) < 1e-6
    assert abs(float(s(jnp.asarray(100))) - 0.1) < 1e-6
    w = linear_warmup_cosine(1.0, 10, 110)
    assert float(w(jnp.asarray(5))) == pytest.approx(0.5, abs=1e-6)
    assert float(w(jnp.asarray(10))) == pytest.approx(1.0, abs=1e-2)


def test_checkpoint_roundtrip(tmp_path):
    from repro.core import psvgp, svgp
    from repro.core.partition import make_grid, partition_data
    from repro.data.spatial import e3sm_like_field

    ds = e3sm_like_field(n=500, seed=0)
    grid = make_grid(ds.x, 3, 3)
    data = partition_data(ds.x, ds.y, grid)
    cfg = psvgp.PSVGPConfig(svgp=svgp.SVGPConfig(num_inducing=4, input_dim=2))
    state = psvgp.init(jax.random.PRNGKey(0), cfg, data)
    p = save_train_state(str(tmp_path), 7, state)
    assert os.path.exists(os.path.join(p, "arrays.npz"))
    restored = load_train_state(str(tmp_path), state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored), strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    t1 = {"w": jnp.ones((3, 3))}
    save_pytree(str(tmp_path / "c"), t1)
    with pytest.raises(ValueError):
        load_pytree(str(tmp_path / "c"), {"w": jnp.ones((4, 3))})


def test_token_pipeline_determinism_and_sharding():
    a1 = list(synthetic_token_batches(1000, 4, 16, seed=3, num_batches=2))
    a2 = list(synthetic_token_batches(1000, 4, 16, seed=3, num_batches=2))
    for (t1, y1), (t2, y2) in zip(a1, a2, strict=True):
        np.testing.assert_array_equal(t1, t2)
        assert t1.shape == (4, 16) and t1.dtype == np.int32
        assert (t1 >= 0).all() and (t1 < 1000).all()
        np.testing.assert_array_equal(y1[:, :-1], t1[:, 1:])  # targets shifted
    # different host row offsets -> different (non-overlapping) streams
    b = next(iter(synthetic_token_batches(1000, 4, 16, seed=3, start_row=10)))
    assert not np.array_equal(a1[0][0], b[0])


def test_sharding_rules_divisibility_fallback():
    """14 heads on a 16-wide model axis must fall back to replicated while
    the divisible FFN stays sharded (the qwen2 case)."""
    import os, subprocess, sys, textwrap

    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.models.config import ModelConfig
        from repro.models import transformer
        from repro.sharding import params_pspecs

        cfg = ModelConfig(name="q2ish", arch_type="dense", num_layers=2, d_model=112,
                          num_heads=14, num_kv_heads=2, d_ff=120, vocab_size=150,
                          dtype="float32")
        params = transformer.init_model_params(jax.random.PRNGKey(0), cfg)
        mesh = jax.make_mesh((1, 16), ("data", "model"))
        specs = params_pspecs(params, mesh)
        # flattened q output 14*8=112 divides 16 -> sharded (legal; the
        # head reshape reshards, which the roofline surfaces as collectives)
        wq = specs["stack"]["b0"]["mix"]["wq"]
        assert wq == P(None, None, "model"), wq
        # kv product 2*8=16 divides -> sharded
        wk = specs["stack"]["b0"]["mix"]["wk"]
        assert wk == P(None, None, "model"), wk
        # d_ff=120 does NOT divide 16 -> replicated fallback
        wg = specs["stack"]["b0"]["mlp"]["w_gate"]
        assert wg == P(None, None, None), wg
        # vocab 150 is PADDED to 256 (ModelConfig.padded_vocab_size) so the
        # embedding always shards — the fallback no longer triggers there
        assert params["embed"].shape[0] == 256
        emb = specs["embed"]
        assert emb == P("model", None), emb
        # norms replicate
        assert specs["final_norm"] == P()
        print("OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", script], capture_output=True, text=True,
                       env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
