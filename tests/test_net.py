"""The wire layer (repro.net): protocol strictness, client retry/deadline
behavior, the HTTP endpoint's status-code contract, and the golden
property extended over real sockets.

Three tiers, cheapest first:

  * pure protocol tests — encode/decode round trips and every malformed-
    frame class (truncated, trailing, garbage, version mismatch, key-set
    violations), no sockets, no jax;
  * client-vs-scripted-server tests — a threaded plain-socket HTTP stub
    answers a scripted status sequence, driving the retry/backoff/
    deadline logic of both clients deterministically;
  * end-to-end tests — a real ``NetServer`` (port 0) over a small
    replicated model in a background loop thread, exercised by both
    clients: payload equivalence, every engine outcome's HTTP status,
    keepalive, /healthz, /slo, and a mid-stream ``Server.swap`` with
    zero failed requests. The sharded BITWISE golden gate runs in a
    subprocess (virtual host devices before jax init, as everywhere).
"""
import asyncio
import contextlib
import http.client
import json
import os
import socket
import subprocess
import sys
import textwrap
import threading
import time
import types
from pathlib import Path

import msgpack
import numpy as np
import pytest

from repro.net import protocol
from repro.net.client import (
    AsyncNetClient,
    DeadlineExceeded,
    NetClient,
    RetriesExhausted,
    RetryPolicy,
    ServerError,
)

REPO = Path(__file__).resolve().parent.parent


# ---------------------------------------------------------------------------
# protocol: round trips
# ---------------------------------------------------------------------------


def test_predict_request_round_trip_is_bitwise():
    pts = np.random.default_rng(0).uniform(-3, 7, (17, 2))
    req = protocol.PredictRequest.from_points("req-1", pts)
    out = protocol.decode_frame(req.encode())
    assert out == req and isinstance(out, protocol.PredictRequest)
    # float32 cast happens exactly once, at from_points
    assert np.array_equal(out.points(), pts.astype(np.float32))
    assert out.points().dtype == np.float32


def test_predict_response_round_trip_is_bitwise():
    mean = np.random.default_rng(1).normal(size=9).astype(np.float32)
    var = np.random.default_rng(2).uniform(0.1, 2, 9).astype(np.float32)
    resp = protocol.PredictResponse.from_arrays(
        "r", mean, var, server_version=3, timing_ms=(0.5, 1.5, 2.25)
    )
    out = protocol.decode_frame(resp.encode())
    assert out == resp
    assert np.array_equal(out.mean(), mean) and np.array_equal(out.var(), var)
    assert out.server_version == 3
    assert out.timing() == {"decode_ms": 0.5, "engine_ms": 1.5, "total_ms": 2.25}


@pytest.mark.parametrize("retry_ms", [None, 50.0])
def test_error_frame_round_trip(retry_ms):
    frame = protocol.ErrorFrame("x", "shed", "queue full", retry_after_ms=retry_ms)
    out = protocol.decode_frame(frame.encode())
    assert out == frame and out.retry_after_ms == retry_ms


def test_every_error_code_pins_a_status():
    for code in protocol.ERROR_CODES:
        frame = protocol.ErrorFrame("", code, "x")
        assert frame.status == protocol.STATUS_FOR_CODE[code]
    assert sorted(protocol.STATUS_FOR_CODE) == sorted(protocol.ERROR_CODES)


# ---------------------------------------------------------------------------
# protocol: strict decode — every malformed class raises ProtocolError
# ---------------------------------------------------------------------------


def _valid_frame_dict():
    return msgpack.unpackb(
        protocol.PredictRequest.from_points("r", np.zeros((2, 2))).encode(),
        raw=False,
    )


@pytest.mark.parametrize(
    "mutate,why",
    [
        (lambda buf: buf[:-3], "truncated"),
        (lambda buf: buf + b"xx", "trailing bytes"),
        (lambda buf: b"\xc1garbage", "garbage"),
        (lambda buf: b"", "empty"),
    ],
)
def test_malformed_bytes_raise_protocol_error(mutate, why):
    buf = protocol.PredictRequest.from_points("r", np.zeros((3, 2))).encode()
    with pytest.raises(protocol.ProtocolError):
        protocol.decode_frame(mutate(buf)), why


@pytest.mark.parametrize(
    "mutate,match",
    [
        (lambda d: d.update(v=99), "version mismatch"),
        (lambda d: d.pop("v"), "missing protocol version"),
        (lambda d: d.update(kind="telemetry"), "unknown frame kind"),
        (lambda d: d.update(extra=1), "key set mismatch"),
        (lambda d: d.pop("n"), "key set mismatch"),
        (lambda d: d.update(n="2"), "must be an int"),
        (lambda d: d.update(n=5), "must be .* bytes"),  # n disagrees with bytes
        (lambda d: d.update(request_id=""), "non-empty str"),
    ],
)
def test_structurally_invalid_frames_raise_protocol_error(mutate, match):
    d = _valid_frame_dict()
    mutate(d)
    with pytest.raises(protocol.ProtocolError, match=match):
        protocol.decode_frame(msgpack.packb(d, use_bin_type=True))


def test_non_map_frame_raises():
    with pytest.raises(protocol.ProtocolError, match="msgpack map"):
        protocol.decode_frame(msgpack.packb([1, 2, 3]))


def test_construction_validation():
    with pytest.raises(protocol.ProtocolError, match=r"\(n >= 1, 2\)"):
        protocol.PredictRequest.from_points("r", np.zeros((0, 2)))
    with pytest.raises(protocol.ProtocolError, match="non-empty str"):
        protocol.PredictRequest.from_points("", np.zeros((1, 2)))
    with pytest.raises(protocol.ProtocolError, match="code must be one of"):
        protocol.ErrorFrame("", "nope", "x")
    with pytest.raises(protocol.ProtocolError, match="equal-length"):
        protocol.PredictResponse.from_arrays(
            "r", np.zeros(3), np.zeros(4), server_version=0,
            timing_ms=(0.0, 0.0, 0.0),
        )


# ---------------------------------------------------------------------------
# clients vs a scripted server: retry, backoff, deadline
# ---------------------------------------------------------------------------


class ScriptedHTTP:
    """Plain-socket HTTP stub in a daemon thread answering POST /predict
    with a scripted (status, body, headers) sequence — the last entry
    repeats. Drives the clients' retry logic without an engine."""

    def __init__(self, script):
        self.script = list(script)
        self.hits = 0
        self._srv = socket.create_server(("127.0.0.1", 0))
        self.port = self._srv.getsockname()[1]
        threading.Thread(target=self._serve, daemon=True).start()

    def _serve(self):
        while True:
            try:
                conn, _ = self._srv.accept()
            except OSError:
                return  # listener closed: stub done
            threading.Thread(
                target=self._conn, args=(conn,), daemon=True
            ).start()

    def _conn(self, conn):
        with conn, contextlib.suppress(ConnectionError, OSError, ValueError):
            f = conn.makefile("rb")
            while self._one(conn, f):
                pass

    def _one(self, conn, f):
        line = f.readline()
        if not line:
            return False
        headers = {}
        while True:
            h = f.readline()
            if h in (b"\r\n", b"\n", b""):
                break
            k, _, v = h.decode("latin-1").partition(":")
            headers[k.strip().lower()] = v.strip()
        n = int(headers.get("content-length", "0"))
        if n:
            f.read(n)
        status, body, extra = self.script[min(self.hits, len(self.script) - 1)]
        self.hits += 1
        head = (
            f"HTTP/1.1 {status} X\r\nContent-Type: application/msgpack\r\n"
            f"Content-Length: {len(body)}\r\nConnection: keep-alive\r\n"
        )
        for k, v in extra.items():
            head += f"{k}: {v}\r\n"
        conn.sendall(head.encode("latin-1") + b"\r\n" + body)
        return True

    def close(self):
        self._srv.close()


def _ok(request_id, n=2):
    return protocol.PredictResponse.from_arrays(
        request_id, np.zeros(n, np.float32), np.ones(n, np.float32),
        server_version=0, timing_ms=(0.1, 0.2, 0.3),
    ).encode()


def _err(code, retry_ms=None):
    return protocol.ErrorFrame(
        "", code, f"scripted {code}", retry_after_ms=retry_ms
    ).encode()


PTS = np.zeros((2, 2), np.float32)
FAST = RetryPolicy(max_attempts=4, base_backoff_ms=1.0, jitter=0.0)


@contextlib.contextmanager
def scripted(script):
    stub = ScriptedHTTP(script)
    try:
        yield stub
    finally:
        stub.close()


def test_sync_client_retries_shed_then_succeeds():
    script = [(429, _err("shed", 5.0), {}), (429, _err("shed", 5.0), {}),
              (200, _ok("r1"), {})]
    with scripted(script) as stub, NetClient(
        "127.0.0.1", stub.port, retry=FAST, seed=0
    ) as c:
        resp = c.predict(PTS, request_id="r1")
    assert isinstance(resp, protocol.PredictResponse)
    assert stub.hits == 3  # two sheds burned two attempts, third answered


def test_sync_client_exhausts_attempts():
    with scripted([(429, _err("shed", 1.0), {})]) as stub, NetClient(
        "127.0.0.1", stub.port,
        retry=RetryPolicy(max_attempts=2, base_backoff_ms=1.0, jitter=0.0),
    ) as c:
        with pytest.raises(RetriesExhausted) as exc:
            c.predict(PTS)
    assert exc.value.status == 429 and exc.value.frame.code == "shed"
    assert stub.hits == 2  # exactly the attempt budget, then gave up


def test_sync_client_never_retries_4xx_that_cannot_succeed():
    with scripted([(413, _err("oversized"), {})]) as stub, NetClient(
        "127.0.0.1", stub.port, retry=FAST
    ) as c:
        with pytest.raises(ServerError) as exc:
            c.predict(PTS)
    assert not isinstance(exc.value, RetriesExhausted)
    assert exc.value.status == 413 and exc.value.frame.code == "oversized"
    assert stub.hits == 1  # oversized will never fit: one attempt only


def test_sync_client_honors_frame_retry_hint():
    hint = 80.0
    script = [(429, _err("shed", hint), {}), (200, _ok("r1"), {})]
    with scripted(script) as stub, NetClient(
        "127.0.0.1", stub.port, retry=FAST
    ) as c:
        t0 = time.monotonic()
        c.predict(PTS, request_id="r1")
        waited = time.monotonic() - t0
    assert stub.hits == 2
    assert waited >= hint / 1e3  # jitter=0: the wait is at least the hint


def test_sync_client_deadline_beats_long_retry_hint():
    with scripted([(429, _err("shed", 500.0), {})]) as stub, NetClient(
        "127.0.0.1", stub.port, retry=FAST
    ) as c:
        with pytest.raises(DeadlineExceeded, match="cross the deadline"):
            c.predict(PTS, deadline_s=0.05)
    assert stub.hits == 1  # refused to sleep past the deadline


def test_200_with_wrong_frame_kind_is_a_protocol_error():
    with scripted([(200, _err("internal"), {})]) as stub, NetClient(
        "127.0.0.1", stub.port, retry=FAST
    ) as c:
        with pytest.raises(protocol.ProtocolError, match="ErrorFrame"):
            c.predict(PTS)
    del stub


def test_200_with_foreign_request_id_is_a_protocol_error():
    with scripted([(200, _ok("someone-else"), {})]) as stub, NetClient(
        "127.0.0.1", stub.port, retry=FAST
    ) as c:
        with pytest.raises(protocol.ProtocolError, match="someone-else"):
            c.predict(PTS, request_id="mine")
    del stub


def test_async_client_retries_then_succeeds_and_reuses_connection():
    script = [(429, _err("shed", 2.0), {}), (200, _ok("r1"), {}),
              (200, _ok("r2"), {})]

    async def main(port):
        async with AsyncNetClient(
            "127.0.0.1", port, retry=FAST, seed=0
        ) as c:
            r1 = await c.predict(PTS, request_id="r1")
            writer = c._writer  # persistent pair after the first success
            r2 = await c.predict(PTS, request_id="r2")
            assert c._writer is writer  # keepalive: no reconnect
        return r1, r2

    with scripted(script) as stub:
        r1, r2 = asyncio.run(main(stub.port))
    assert r1.request_id == "r1" and r2.request_id == "r2"
    assert stub.hits == 3


def test_async_client_deadline():
    async def main(port):
        async with AsyncNetClient("127.0.0.1", port, retry=FAST) as c:
            with pytest.raises(DeadlineExceeded):
                await c.predict(PTS, deadline_s=0.05)

    with scripted([(429, _err("shed", 500.0), {})]) as stub:
        asyncio.run(main(stub.port))


def test_retry_policy_validates_and_schedules():
    with pytest.raises(ValueError, match="max_attempts"):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError, match="jitter"):
        RetryPolicy(jitter=1.5)
    with pytest.raises(ValueError, match="max_backoff_ms"):
        RetryPolicy(base_backoff_ms=100.0, max_backoff_ms=10.0)
    import random

    p = RetryPolicy(base_backoff_ms=10.0, max_backoff_ms=40.0, jitter=0.0)
    rng = random.Random(0)
    assert p.delay_s(0, None, rng) == 0.010
    assert p.delay_s(2, None, rng) == 0.040  # capped at max_backoff
    assert p.delay_s(0, 200.0, rng) == 0.200  # server hint dominates


# ---------------------------------------------------------------------------
# end-to-end over a real NetServer (replicated model, loop in a thread)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def server():
    from repro import api
    from repro.data.spatial import e3sm_like_field

    ds = e3sm_like_field(n=500, seed=0)
    fitted = api.fit(api.FitConfig(grid=2, m=4, train_iters=60, seed=0), ds)
    return api.Server(fitted)


@contextlib.contextmanager
def running(server, net=None, frontdoor=None):
    """A NetServer on its own loop thread — so the BLOCKING NetClient can
    be exercised against it from the test thread. Defaults to port 0
    (OS-assigned) so concurrent test runs never collide on the fixed
    NetConfig default."""
    from repro import api
    from repro.net.server import NetServer

    if net is None:
        net = api.NetConfig(port=0)
    box = {}
    started = threading.Event()

    def run():
        async def main():
            box["loop"] = asyncio.get_running_loop()
            box["stop"] = asyncio.Event()
            async with NetServer(server, net, frontdoor) as ns:
                box["ns"] = ns
                started.set()
                await box["stop"].wait()

        asyncio.run(main())

    t = threading.Thread(target=run, daemon=True)
    t.start()
    assert started.wait(60), "NetServer failed to start"
    try:
        yield box["ns"]
    finally:
        box["loop"].call_soon_threadsafe(box["stop"].set)
        t.join(30)


def test_predict_over_the_wire_matches_submit(server):
    rng = np.random.default_rng(5)
    pts = rng.uniform(0, 1, (16, 2)).astype(np.float32)
    with running(server) as ns, NetClient("127.0.0.1", ns.port) as c:
        resp = c.predict(pts, deadline_s=30.0)
        conn = c._conn
        again = c.predict(pts, deadline_s=30.0)
        assert c._conn is conn  # keepalive held across requests
    mean, var = server.submit(pts)
    # replicated path: float32-exact (XLA respecializes per batch shape)
    np.testing.assert_allclose(resp.mean(), mean, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(resp.var(), var, atol=1e-5, rtol=1e-5)
    # same wire frame twice -> same engine answer, bitwise
    assert np.array_equal(resp.mean(), again.mean())
    assert resp.server_version == 0
    t = resp.timing()
    assert t["total_ms"] >= t["engine_ms"] >= 0 and t["decode_ms"] >= 0


def test_async_client_end_to_end(server):
    pts = np.random.default_rng(6).uniform(0, 1, (8, 2)).astype(np.float32)

    async def main(port):
        async with AsyncNetClient("127.0.0.1", port) as c:
            resp = await c.predict(pts)
            status, health = await c.healthz()
        return resp, status, health

    with running(server) as ns:
        resp, status, health = asyncio.run(main(ns.port))
    mean, _ = server.submit(pts)
    np.testing.assert_allclose(resp.mean(), mean, atol=1e-5, rtol=1e-5)
    assert status == 200 and health["status"] == "ok"
    assert health["protocol_version"] == protocol.PROTOCOL_VERSION


def test_healthz_slo_and_transport_counters(server):
    with running(server) as ns, NetClient("127.0.0.1", ns.port) as c:
        status, health = c.healthz()
        assert status == 200 and health["status"] == "ok"
        c.predict(np.zeros((1, 2), np.float32))
        slo = c.slo()
    assert slo["requests"]["completed"] == 1
    http_sec = slo["http"]
    assert http_sec["requests"] >= 3  # healthz + predict + slo
    assert http_sec["errors"] == dict.fromkeys(protocol.ERROR_CODES, 0)
    assert http_sec["net_config"]["port"] == 0  # the config, not the bind


def _raw_post(port, path, body, content_type="application/msgpack"):
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=30)
    try:
        conn.request("POST", path, body=body,
                     headers={"Content-Type": content_type})
        resp = conn.getresponse()
        return resp.status, resp.read()
    finally:
        conn.close()


def test_garbage_body_is_400_bad_request(server):
    with running(server) as ns:
        status, body = _raw_post(ns.port, "/predict", b"\x00not msgpack")
    assert status == 400
    frame = protocol.decode_frame(body)
    assert frame.code == "bad-request" and frame.retry_after_ms is None


def test_wrong_frame_kind_is_400(server):
    with running(server) as ns:
        status, body = _raw_post(ns.port, "/predict", _err("internal"))
    assert status == 400
    assert protocol.decode_frame(body).code == "bad-request"


def test_unknown_path_404_and_wrong_method_405(server):
    with running(server) as ns:
        conn = http.client.HTTPConnection("127.0.0.1", ns.port, timeout=30)
        try:
            conn.request("GET", "/nope")
            r = conn.getresponse()
            assert r.status == 404 and "error" in json.loads(r.read())
            conn.request("GET", "/predict")
            r = conn.getresponse()
            assert r.status == 405 and "error" in json.loads(r.read())
        finally:
            conn.close()


def test_oversized_request_rows_map_to_413(server):
    from repro import api

    fd_cfg = api.FrontDoorConfig(max_request_rows=8)
    pts = np.zeros((9, 2), np.float32)
    with running(server, frontdoor=fd_cfg) as ns, NetClient(
        "127.0.0.1", ns.port, retry=FAST
    ) as c:
        with pytest.raises(ServerError) as exc:
            c.predict(pts)
        slo = c.slo()
    assert exc.value.status == 413 and exc.value.frame.code == "oversized"
    assert not isinstance(exc.value, RetriesExhausted)  # no retry: typed 4xx
    assert slo["http"]["errors"]["oversized"] == 1


def test_oversized_body_refused_before_read(server):
    from repro import api

    net = api.NetConfig(port=0, max_body_bytes=1024)
    pts = np.zeros((200, 2), np.float32)  # 1600 raw bytes > 1024 cap
    with running(server, net=net) as ns, NetClient(
        "127.0.0.1", ns.port, retry=FAST
    ) as c:
        with pytest.raises(ServerError) as exc:
            c.predict(pts)
    assert exc.value.status == 413
    assert "max_body_bytes" in exc.value.frame.message


def test_shed_maps_to_429_with_retry_after(server):
    from repro import api

    with running(server) as ns:
        async def reject(pts):
            raise api.RequestRejected("admission queue full")

        ns._fd.submit = reject
        with NetClient(
            "127.0.0.1", ns.port,
            retry=RetryPolicy(max_attempts=2, base_backoff_ms=1.0, jitter=0.0),
        ) as c:
            with pytest.raises(RetriesExhausted) as exc:
                c.predict(np.zeros((1, 2), np.float32))
            slo = c.slo()
    assert exc.value.status == 429 and exc.value.frame.code == "shed"
    from repro.net.server import SHED_RETRY_MS

    assert exc.value.frame.retry_after_ms == SHED_RETRY_MS
    assert slo["http"]["errors"]["shed"] == 2  # both attempts were shed


def test_broken_engine_maps_to_503_and_healthz_degrades(server):
    with running(server) as ns:
        ns._fd._broken = RuntimeError("engine died in a test")
        with NetClient(
            "127.0.0.1", ns.port,
            retry=RetryPolicy(max_attempts=1, base_backoff_ms=1.0, jitter=0.0),
        ) as c:
            status, health = c.healthz()
            assert status == 503 and health["status"] == "broken"
            with pytest.raises(RetriesExhausted) as exc:
                c.predict(np.zeros((1, 2), np.float32))
    assert exc.value.status == 503
    assert exc.value.frame.code == "engine-broken"
    assert exc.value.frame.retry_after_ms is not None  # worth retrying later


def test_swap_under_wire_load_zero_failures(server):
    """``Server.swap`` mid-stream, observed THROUGH the transport: every
    HTTP request succeeds, the served model version flips monotonically,
    and both versions answered traffic (the endpoint never drops a
    request to go live — docs/lifecycle.md, now over sockets)."""
    from repro import api
    from repro.data.spatial import e3sm_like_field

    ds = e3sm_like_field(n=500, seed=1)
    fitted_b = api.fit(api.FitConfig(grid=2, m=4, train_iters=30, seed=1), ds)
    swap_server = api.Server(
        api.fit(api.FitConfig(grid=2, m=4, train_iters=30, seed=0), ds)
    )
    pts = np.random.default_rng(7).uniform(0, 1, (4, 2)).astype(np.float32)
    n_req = 24

    async def drive(port):
        loop = asyncio.get_running_loop()
        state = {"done": 0}

        async def stream(c):
            # sequential on ONE persistent connection (the client is a
            # single stream pair; ordering doubles as the route order the
            # monotone-flip assertion needs)
            versions = []
            for i in range(n_req):
                resp = await c.predict(pts, request_id=f"s{i}")
                state["done"] += 1
                versions.append(resp.server_version)
            return versions

        async def swapper():
            while state["done"] < 5:
                await asyncio.sleep(0.001)
            await loop.run_in_executor(
                None, lambda: swap_server.swap(fitted_b, version=1)
            )

        async with AsyncNetClient("127.0.0.1", port) as c:
            _, versions = await asyncio.gather(swapper(), stream(c))
        return versions

    with running(swap_server) as ns:
        versions = asyncio.run(drive(ns.port))
    assert len(versions) == n_req  # zero failures: gather raised nothing
    assert set(versions) == {0, 1}  # both models served traffic
    assert versions == sorted(versions)  # the flip is monotone, no flapping
    lc = swap_server.lifecycle()
    assert lc["swaps"] == 1 and lc["active_version"] == 1


# ---------------------------------------------------------------------------
# session files: --http needs a net section
# ---------------------------------------------------------------------------


def test_http_flag_requires_net_section(tmp_path):
    from repro.launch import serve_sharded as ss

    path = tmp_path / "session.json"
    path.write_text(json.dumps({"fit": {"grid": 2, "m": 4}}))
    args = types.SimpleNamespace(config=str(path), http=True)
    with pytest.raises(SystemExit, match="no 'net' section"):
        ss.session_configs(args, expect_mode="replicated")
    # same session without --http parses fine; with a net section, both do
    args.http = False
    _, _, net_cfg = ss.session_configs(args, expect_mode="replicated")
    assert net_cfg is None
    path.write_text(json.dumps({"fit": {"grid": 2, "m": 4},
                                "net": {"port": 0}}))
    args.http = True
    _, _, net_cfg = ss.session_configs(args, expect_mode="replicated")
    assert net_cfg.port == 0 and net_cfg.host == "127.0.0.1"


# ---------------------------------------------------------------------------
# sharded mesh path: the golden property holds BITWISE over the wire
# (subprocess: virtual host devices before jax init — see test_api.py)
# ---------------------------------------------------------------------------

_SHARDED_HTTP_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=9"
    import asyncio

    import numpy as np

    from repro import api
    from repro.net.client import AsyncNetClient, RetryPolicy, ServerError
    from repro.net.server import NetServer

    ds_kwargs = dict(n=1000, seed=0)
    from repro.data.spatial import e3sm_like_field
    ds = e3sm_like_field(**ds_kwargs)
    fitted = api.fit(api.FitConfig(grid=3, m=4, train_iters=120, seed=0), ds)
    server = api.Server(fitted, api.ServeConfig(
        mode="sharded", pipeline="pipelined", router="two-level",
        backend="ref"))
    lo, hi = ds.x.min(axis=0), ds.x.max(axis=0)
    rng = np.random.default_rng(11)
    reqs = [rng.uniform(lo, hi, (int(rng.integers(1, 65)), 2))
                .astype(np.float32) for _ in range(10)]
    jitter = rng.uniform(0, 0.01, len(reqs))

    async def main():
        async with NetServer(server, api.NetConfig(port=0)) as ns:
            async def one(i):
                # one connection per simulated client: concurrent arrivals
                # coalesce in the front door's batching window
                await asyncio.sleep(float(jitter[i]))
                async with AsyncNetClient("127.0.0.1", ns.port) as c:
                    return await c.predict(reqs[i], request_id=f"g{i}")
            got = await asyncio.gather(*(one(i) for i in range(len(reqs))))
            # typed 413 comes back over the wire too
            async with AsyncNetClient("127.0.0.1", ns.port) as c:
                try:
                    await c.predict(np.zeros((65, 2), np.float32))
                except ServerError as err:
                    assert err.status == 413 and err.frame.code == "oversized"
                else:
                    raise SystemExit("oversized request was not refused")
            return got

    got = asyncio.run(main())
    for i, (resp, q) in enumerate(zip(got, reqs)):
        ms, vs = server.submit(q)
        assert np.array_equal(resp.mean(), ms), i
        assert np.array_equal(resp.var(), vs), i
    print("golden: HTTP payload bitwise == solo Server.submit (sharded)")
    print("SHARDED-HTTP-OK")
    """
)


@pytest.mark.smoke
def test_sharded_http_golden_bitwise():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-c", _SHARDED_HTTP_SCRIPT],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=570,
    )
    assert r.returncode == 0, r.stderr[-4000:]
    assert "SHARDED-HTTP-OK" in r.stdout
