"""Docs-check lane: the commands and snippets documented in README.md and
docs/ must keep running as written (``make docs-check`` / ``pytest -m docs``).

Enforcement levels:
  * ```bash blocks — every ``python -m <module>`` command line must name an
    importable module whose CLI still accepts every ``--flag`` used (checked
    against the module's ``--help`` in a subprocess); plain
    ``python <script>`` lines must name a file that byte-compiles.
  * ```python blocks — executed verbatim (keep them small when documenting).

Blocks that should not be checked use a different fence language (e.g.
```text). ``python -m pytest`` lines are exempt from --help (pytest's own
CLI), but any ``-m "<marker> ..."`` expression they use must only name
markers registered in pyproject.toml.
"""
from __future__ import annotations

import os
import py_compile
import re
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOC_FILES = ["README.md"] + sorted(
    os.path.join("docs", f)
    for f in (os.listdir(os.path.join(REPO, "docs")) if os.path.isdir(os.path.join(REPO, "docs")) else [])
    if f.endswith(".md")
)

_FENCE = re.compile(r"```(\w+)\n(.*?)```", re.DOTALL)


def _blocks(lang: str):
    out = []
    for rel in DOC_FILES:
        with open(os.path.join(REPO, rel)) as f:
            text = f.read()
        for m in _FENCE.finditer(text):
            if m.group(1) == lang:
                out.append((rel, m.group(2)))
    return out


def _command_lines():
    """Join backslash continuations; yield (docfile, command) pairs."""
    for rel, block in _blocks("bash"):
        joined = re.sub(r"\\\n\s*", " ", block)
        for line in joined.splitlines():
            line = line.strip()
            if line and not line.startswith("#"):
                yield rel, line


def _registered_markers():
    with open(os.path.join(REPO, "pyproject.toml")) as f:
        txt = f.read()
    return set(re.findall(r'^\s*"(\w+):', txt, re.MULTILINE))


_HELP_CACHE: dict = {}


def _module_help(module: str) -> str:
    if module not in _HELP_CACHE:
        env = dict(os.environ, PYTHONPATH="src")
        r = subprocess.run(
            [sys.executable, "-m", module, "--help"],
            capture_output=True, text=True, env=env, cwd=REPO, timeout=300,
        )
        assert r.returncode == 0, (
            f"`python -m {module} --help` failed:\n{r.stderr[-2000:]}"
        )
        _HELP_CACHE[module] = r.stdout + r.stderr
    return _HELP_CACHE[module]


@pytest.mark.docs
@pytest.mark.parametrize("rel,cmd", list(_command_lines()),
                         ids=[f"{r}:{c[:60]}" for r, c in _command_lines()])
def test_documented_command(rel, cmd):
    tokens = cmd.split()
    assert "python" in tokens, f"{rel}: non-python command documented: {cmd}"
    py = tokens.index("python")
    rest = tokens[py + 1:]
    if rest[:1] == ["-m"]:
        module = rest[1]
        flags = {t.split("=")[0] for t in rest[2:] if t.startswith("--")}
        if module == "pytest":
            # pytest's CLI is upstream; check our marker expressions only
            markers = set()
            m = re.search(r"-m\s+\"([^\"]+)\"", cmd)
            if m:
                markers = {w for w in re.findall(r"\w+", m.group(1))
                           if w not in ("or", "and", "not")}
            unknown = markers - _registered_markers()
            assert not unknown, f"{rel}: unregistered pytest markers {unknown}: {cmd}"
            return
        help_text = _module_help(module)
        # word-boundary match: "--gp" must not pass just because "--gp-grid"
        # survives in the help text
        missing = {
            f for f in flags
            if not re.search(rf"(?<![\w-]){re.escape(f)}(?![\w-])", help_text)
        }
        assert not missing, f"{rel}: flags {missing} not in `{module}` --help: {cmd}"
    else:
        script = rest[0]
        path = os.path.join(REPO, script)
        assert os.path.exists(path), f"{rel}: documented script missing: {script}"
        py_compile.compile(path, doraise=True)


@pytest.mark.docs
@pytest.mark.parametrize("rel,code", _blocks("python"),
                         ids=[r for r, _ in _blocks("python")])
def test_documented_python_snippet(rel, code):
    sys.path.insert(0, os.path.join(REPO, "src"))
    try:
        exec(compile(code, f"<{rel} snippet>", "exec"), {"__name__": "__docs__"})
    finally:
        sys.path.pop(0)
