"""Property-based sweep of the routing/serving host core (pure numpy).

The example-based suite (tests/test_routing.py) pins specific grids and
clouds; this file sweeps randomized (grid, cloud, skew) instances over the
invariants the serving path stands on:

  * route -> scatter is an EXACT inverse: any per-row function evaluated on
    the padded blocks comes back bitwise in request order;
  * two-level spill rows are only ever re-hosted on a corner cell of their
    own blend window (the device slot encoding is valid iff this holds);
  * ``min_spill_q_max`` always names a feasible budget;
  * coalesce -> demux is an exact inverse of request concatenation (the
    front door's ingest/egress pair, ``repro.api.frontdoor``).

Runs under real ``hypothesis`` when installed, else the deterministic
``tests/_hypothesis_shim`` sweep (same properties, fixed PRNG, no
shrinking).
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from _hypothesis_shim import given, settings, st

from repro.core import routing
from repro.core.blend import corner_ids_weights
from repro.core.partition import make_grid

_LO = np.array([-2.0, 1.0])
_HI = np.array([3.0, 4.5])


def _instance(seed, n, gx, gy, skew):
    """One randomized routing instance: a grid over a uniform cloud, with a
    ``skew`` fraction of the points piled into one random hot cell."""
    rng = np.random.default_rng(seed)
    pts = rng.uniform(_LO, _HI, size=(n, 2)).astype(np.float32)
    grid = make_grid(pts, gx, gy)
    k = int(skew * n)
    if k:
        cell = rng.integers(0, gx * gy)
        cx, cy = cell % gx, cell // gx
        lo = np.array([grid.x_edges[cx], grid.y_edges[cy]])
        hi = np.array([grid.x_edges[cx + 1], grid.y_edges[cy + 1]])
        # interior of the hot cell (strictly inside: ownership unambiguous)
        pts[:k] = rng.uniform(lo + 1e-4, hi - 1e-4, size=(k, 2)).astype(np.float32)
    return grid, pts


def _row_fn(xy):
    """A per-row probe function — float32 in, float32 out, so evaluating it
    on the padded blocks vs on the raw batch is the SAME computation and
    the inverse check below can demand bitwise equality."""
    return np.float32(7) * xy[..., 0] + np.float32(3) * xy[..., 1]


def _assert_scatter_inverts(grid, pts, table):
    n = len(pts)
    valid = table.qmask > 0
    assert int(valid.sum()) == n  # every query exactly once, no drops
    # every valid padded row holds its source point verbatim
    np.testing.assert_array_equal(table.xq[valid], pts[table.src_idx[valid]])
    # per-row results come home bitwise in request order
    got = routing.scatter_results(table, _row_fn(table.xq))
    np.testing.assert_array_equal(got, _row_fn(pts))
    # blocks respect the padded budget
    assert int(table.counts.max()) <= table.q_max


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(1, 400),
    gx=st.integers(1, 6),
    gy=st.integers(1, 5),
    skew=st.floats(0.0, 0.9),
)
def test_scatter_inverts_single_level_routing(seed, n, gx, gy, skew):
    """Default (single-level) routing: scatter is an exact inverse for any
    grid shape, batch size, and hot-cell skew."""
    grid, pts = _instance(seed, n, gx, gy, skew)
    table = routing.build_routing_table(grid, pts)
    _assert_scatter_inverts(grid, pts, table)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(8, 400),
    gx=st.integers(2, 6),
    gy=st.integers(2, 5),
    skew=st.floats(0.3, 0.95),
)
def test_two_level_scatter_inverts_and_spills_on_corners(seed, n, gx, gy, skew):
    """Two-level routing at the minimum feasible budget: scatter still
    inverts exactly, and every spilled row is hosted on one of its OWN
    blend-window corner cells (never an arbitrary neighbor)."""
    grid, pts = _instance(seed, n, gx, gy, skew)
    ix, iy = routing.owning_cells(grid, pts)
    own = iy * grid.gx + ix
    ids, _ = corner_ids_weights(grid, pts)
    qm = routing.min_spill_q_max(own, ids, grid.num_partitions)
    table = routing.build_routing_table(grid, pts, q_max=qm, spill=True)
    _assert_scatter_inverts(grid, pts, table)

    valid = table.qmask > 0
    host = np.broadcast_to(
        np.arange(grid.num_partitions, dtype=np.int64)[:, None], valid.shape
    )
    src = table.src_idx[valid]
    # host cell is always one of the query's 4 corner cells...
    assert (host[valid][:, None] == ids[src]).any(axis=1).all()
    # ...and the spill mask is exactly the host != owner rows
    np.testing.assert_array_equal(
        table.spill_mask()[valid], host[valid] != own[src]
    )


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(1, 300),
    gx=st.integers(1, 6),
    gy=st.integers(1, 5),
    skew=st.floats(0.0, 0.95),
)
def test_min_spill_q_max_is_feasible_and_bounded(seed, n, gx, gy, skew):
    """``min_spill_q_max`` returns a budget the greedy assignment actually
    routes at (occupancy within budget), never worse than the single-level
    answer and never below the row-coverage floor."""
    grid, pts = _instance(seed, n, gx, gy, skew)
    ix, iy = routing.owning_cells(grid, pts)
    own = iy * grid.gx + ix
    ids, _ = corner_ids_weights(grid, pts)
    P = grid.num_partitions
    qm = routing.min_spill_q_max(own, ids, P)

    single = int(np.bincount(own, minlength=P).max())
    assert -(-n // P) <= qm <= single
    host = routing.spill_assign(own, ids, qm, P)
    assert host is not None
    assert int(np.bincount(host, minlength=P).max()) <= qm


def test_two_level_domain_corner_hot_cell():
    """Degenerate corner windows: a hot cell at the DOMAIN corner has
    queries whose 4 blend corners collapse toward fewer distinct cells, so
    spill capacity is scarcest there. The budget floor must still route,
    and immovable (candidate-less) queries must stay primary."""
    rng = np.random.default_rng(7)
    pts = rng.uniform(_LO, _HI, size=(120, 2)).astype(np.float32)
    grid = make_grid(pts, 3, 3)
    # pile 100 of 120 points into the domain-corner cell (0, 0)
    lo = np.array([grid.x_edges[0], grid.y_edges[0]])
    hi = np.array([grid.x_edges[1], grid.y_edges[1]])
    pts[:100] = rng.uniform(lo + 1e-4, hi - 1e-4, size=(100, 2)).astype(np.float32)

    ix, iy = routing.owning_cells(grid, pts)
    own = iy * grid.gx + ix
    ids, _ = corner_ids_weights(grid, pts)
    qm = routing.min_spill_q_max(own, ids, grid.num_partitions)
    assert qm < int(np.bincount(own, minlength=grid.num_partitions).max())

    table = routing.build_routing_table(grid, pts, q_max=qm, spill=True)
    _assert_scatter_inverts(grid, pts, table)
    assert table.num_spilled() > 0
    # spilled rows sit on corner cells of the hot cell's 2x2 windows only
    valid = table.qmask > 0
    host = np.broadcast_to(
        np.arange(grid.num_partitions, dtype=np.int64)[:, None], valid.shape
    )
    spilled_hosts = np.unique(host[valid & table.spill_mask()])
    assert set(spilled_hosts.tolist()) <= {1, 3, 4}  # neighbors of cell 0


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), r=st.integers(1, 24))
def test_coalesce_demux_round_trip(seed, r):
    """coalesce -> demux is the exact inverse of request concatenation, for
    any request-count/size mix and for extra per-point result arrays of
    any trailing shape."""
    rng = np.random.default_rng(seed)
    reqs = [
        rng.uniform(_LO, _HI, size=(int(rng.integers(1, 65)), 2)).astype(np.float32)
        for _ in range(r)
    ]
    pts, sizes = routing.coalesce_requests(reqs)
    assert pts.shape == (int(sizes.sum()), 2) and len(sizes) == r

    mean = rng.standard_normal(len(pts)).astype(np.float32)
    cov3 = rng.standard_normal((len(pts), 3))
    outs = routing.demux_results(sizes, mean, cov3)
    assert len(outs) == r
    off = 0
    for req, (m_i, c_i) in zip(reqs, outs, strict=True):
        n_i = len(req)
        np.testing.assert_array_equal(pts[off:off + n_i], req)
        np.testing.assert_array_equal(m_i, mean[off:off + n_i])
        np.testing.assert_array_equal(c_i, cov3[off:off + n_i])
        off += n_i
    # demuxed slices are copies: mutating the batch buffer must not alias
    mean[:] = 0
    assert not np.array_equal(outs[0][0], mean[: len(reqs[0])]) or reqs[0].shape[0] == 0


def test_coalesce_rejects_malformed_requests():
    """Admission-side validation: empty list, empty request, and wrong
    trailing dim are errors — a malformed request must never reach a
    coalesced device batch."""
    with pytest.raises(ValueError, match="at least one"):
        routing.coalesce_requests([])
    with pytest.raises(ValueError, match="request 1"):
        routing.coalesce_requests([np.zeros((3, 2)), np.zeros((0, 2))])
    with pytest.raises(ValueError, match="request 0"):
        routing.coalesce_requests([np.zeros((3, 3))])
    with pytest.raises(ValueError, match="rows"):
        routing.demux_results(np.array([2, 2]), np.zeros(3))
