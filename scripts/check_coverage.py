"""Coverage floor gate for the serving front door and the routing core.

Reads a coverage.py JSON report (``pytest --cov ... --cov-report=json``)
and enforces minimum line coverage over the subsystems this repo's
serving guarantees live in:

  * ``repro/api/`` — the session layer + async front door (smoke.py is
    excluded: it is a CLI demo driver, exercised by ``make api-smoke``,
    not a unit-testable surface);
  * ``repro/core/routing.py`` — the host routing/scatter core whose
    invariants the property suite sweeps;
  * ``repro/analysis/`` — the static verification passes themselves (a
    linter nobody tests is a linter nobody can trust). The mesh-touching
    measurement halves (hlo lowering, cost compilation, sharded
    contracts) run via CLI subprocesses, so in-process coverage
    understates them — the floor is set for the pure judgment code.
  * ``repro/net/`` — the wire layer (protocol, transport, clients). The
    CLI ``main``s and the sharded over-the-wire path run in subprocesses
    (``tests/test_net.py``), invisible to in-process coverage, so the
    floor covers the frame codec + client/server state machines.

The floors are RATCHETS, not aspirations: set below current coverage so
the gate only fires when tests are lost or a new untested surface lands.
Raise them in the same commit that raises coverage. Sharded ``Server``
internals run in subprocesses in the test suite (virtual devices must be
forced before jax init), so in-process coverage understates them — the
floors account for that.

  PYTHONPATH=src python scripts/check_coverage.py coverage.json
"""
from __future__ import annotations

import json
import sys

# (path fragment, excluded suffixes, floor %)
FLOORS = (
    ("repro/api/", ("smoke.py",), 65.0),
    ("repro/core/routing.py", (), 80.0),
    # __main__.py is the CLI driver: exercised end-to-end by the
    # subprocess tests and make analyze, invisible to in-process cov
    ("repro/analysis/", ("__main__.py",), 75.0),
    ("repro/net/", (), 70.0),
)


def check(report_path: str) -> int:
    with open(report_path) as f:
        files = json.load(f)["files"]

    failed = False
    for fragment, excluded, floor in FLOORS:
        statements = covered = 0
        matched = []
        for fname, rec in files.items():
            path = fname.replace("\\", "/")
            if fragment not in path:
                continue
            if any(path.endswith(suf) for suf in excluded):
                continue
            s = rec["summary"]
            statements += s["num_statements"]
            covered += s["covered_lines"]
            matched.append(path)
        if not matched:
            print(f"FAIL: no files matched {fragment!r} in {report_path} — "
                  "was coverage collected with --cov=repro?")
            failed = True
            continue
        pct = 100.0 * covered / max(statements, 1)
        ok = pct >= floor
        print(f"{'OK' if ok else 'FAIL'}: {fragment} "
              f"{pct:.1f}% line coverage ({covered}/{statements} statements, "
              f"floor {floor:.0f}%, {len(matched)} files)")
        failed = failed or not ok
    return 1 if failed else 0


def main() -> None:
    if len(sys.argv) != 2:
        sys.exit(__doc__)
    sys.exit(check(sys.argv[1]))


if __name__ == "__main__":
    main()
