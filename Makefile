# Convenience lanes (the repo runs from source: PYTHONPATH=src).
PY := PYTHONPATH=src python

.PHONY: test test-asyncio-debug test-full docs-check lint analyze api-smoke serve-http coverage bench-predict bench-serve bench-serve-smoke bench-frontdoor bench-net bench-net-smoke bench-gate

test:            ## tier-1: default lane (skips the slow marker)
	$(PY) -m pytest -x -q

test-asyncio-debug: ## front door under asyncio debug: any >=100ms event-loop callback is a FAILURE
	PYTHONASYNCIODEBUG=1 $(PY) -m pytest tests/test_frontdoor.py -q

analyze:         ## static verification: HLO invariants, AST rules, contracts, cost gates, async race lint -> ANALYSIS.json
	$(PY) -m repro.analysis

api-smoke:       ## fit a toy model, save, serve the loaded artifact (replicated + sharded)
	$(PY) -m repro.api.smoke

serve-http:      ## fit a toy model and serve it over HTTP (Ctrl-C to stop; see docs/net.md)
	$(PY) -m repro.net.server --gp-grid 3 --gp-m 5

test-full:       ## everything, including the slow SPMD/dry-run lane
	$(PY) -m pytest -q -m "slow or not slow"

docs-check:      ## README + docs/ commands and snippets must run as written
	$(PY) -m pytest -q -m docs

lint:            ## ruff over the whole repo (config in pyproject.toml)
	@if command -v ruff >/dev/null 2>&1; then \
		ruff check . ; \
	else \
		echo "ruff not installed — skipping locally (CI enforces it: pip install ruff)"; \
	fi

coverage:        ## tier-1 lane under line coverage + floors on repro.api / routing core / analysis / wire layer
	@if $(PY) -c "import pytest_cov" >/dev/null 2>&1; then \
		$(PY) -m pytest -q --cov=repro.api --cov=repro.core.routing \
			--cov=repro.analysis --cov=repro.net \
			--cov-report=term --cov-report=json:coverage.json && \
		$(PY) scripts/check_coverage.py coverage.json ; \
	else \
		echo "pytest-cov not installed — skipping locally (CI enforces the floors: pip install pytest-cov)"; \
	fi

bench-predict:   ## cached-prediction speedup report -> BENCH_predict.json
	$(PY) -m benchmarks.bench_predict

bench-serve:     ## replicated-vs-sharded serving SLO report -> BENCH_serve.json
	$(PY) -m benchmarks.bench_serve

bench-serve-smoke: ## seconds-scale serving pipeline smoke (3x3 mesh; also runs in tier-1 via the smoke marker)
	$(PY) -m benchmarks.bench_serve --smoke --out /tmp/BENCH_serve_smoke.json

bench-frontdoor: ## async front door under open-loop Poisson arrivals -> frontdoor section of BENCH_serve.json
	$(PY) -m benchmarks.bench_frontdoor

bench-net:       ## over-the-wire HTTP vs in-process latency + golden gate -> http section of BENCH_serve.json
	$(PY) -m benchmarks.bench_net

bench-net-smoke: ## seconds-scale over-the-wire smoke (replicated 3x3; real sockets)
	$(PY) -m benchmarks.bench_net --smoke --out /tmp/BENCH_net_smoke.json

bench-gate:      ## serve + frontdoor + hot-swap + wire smoke benches + regression gates vs the checked-in baselines
	$(PY) -m benchmarks.bench_serve --smoke --out /tmp/BENCH_serve_smoke.json
	$(PY) -m benchmarks.bench_frontdoor --smoke --out /tmp/BENCH_serve_smoke.json
	$(PY) -m benchmarks.bench_frontdoor --smoke --swap --out /tmp/BENCH_serve_smoke.json
	$(PY) -m benchmarks.bench_net --smoke --out /tmp/BENCH_serve_smoke.json
	$(PY) -m benchmarks.check_bench_regression /tmp/BENCH_serve_smoke.json
