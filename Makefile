# Convenience lanes (the repo runs from source: PYTHONPATH=src).
PY := PYTHONPATH=src python

.PHONY: test test-full docs-check bench-predict bench-serve bench-serve-smoke

test:            ## tier-1: default lane (skips the slow marker)
	$(PY) -m pytest -x -q

test-full:       ## everything, including the slow SPMD/dry-run lane
	$(PY) -m pytest -q -m "slow or not slow"

docs-check:      ## README + docs/ commands and snippets must run as written
	$(PY) -m pytest -q -m docs

bench-predict:   ## cached-prediction speedup report -> BENCH_predict.json
	$(PY) -m benchmarks.bench_predict

bench-serve:     ## replicated-vs-sharded serving SLO report -> BENCH_serve.json
	$(PY) -m benchmarks.bench_serve

bench-serve-smoke: ## seconds-scale serving pipeline smoke (3x3 mesh; also runs in tier-1 via the smoke marker)
	$(PY) -m benchmarks.bench_serve --smoke --out /tmp/BENCH_serve_smoke.json
