"""Quickstart: fit a PSVGP to a synthetic global temperature field.

Runs in ~1 minute on CPU. Demonstrates the public API end-to-end:
data -> partitioning -> PSVGP training (delta-weighted neighbor sampling)
-> stitched prediction -> the paper's two metrics.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import psvgp, svgp
from repro.core.metrics import boundary_rmsd, per_partition_rmspe, rmspe
from repro.core.neighbors import boundary_probes
from repro.core.partition import make_grid, partition_data
from repro.data.spatial import e3sm_like_field


def main() -> None:
    # 1. an E3SM-like field: ~12k observations, pole-sparse like the paper's
    ds = e3sm_like_field(n=12_000, seed=0)

    # 2. a 10x10 grid of spatially contiguous partitions (the in-situ layout:
    #    each partition would live on its own rank in production)
    grid = make_grid(ds.x, gx=10, gy=10)
    data = partition_data(ds.x, ds.y, grid)
    print(f"partitions: {data.num_partitions}, padded size: {data.n_max}, "
          f"counts: min={int(data.counts.min())} max={int(data.counts.max())}")

    # 3. PSVGP: m=5 inducing points per partition, delta=0.125 neighbor
    #    sampling (the paper's sweet spot)
    cfg = psvgp.PSVGPConfig(
        svgp=svgp.SVGPConfig(num_inducing=5, input_dim=2),
        delta=0.125,
        batch_size=32,
        learning_rate=0.02,
        comm="gather",  # paper-faithful mode; "ppermute" = TPU-native mode
    )
    static = psvgp.build(cfg, data)
    state = psvgp.init(jax.random.PRNGKey(0), cfg, data)

    print("training 1500 iterations (all 100 partitions in parallel)...")
    state = psvgp.fit(static, state, data, 1500, log_every=500)

    # 4. the paper's metrics
    probes = boundary_probes(grid, probes_per_edge=8)
    print(f"RMSPE           : {float(rmspe(static, state, data)):.4f}")
    print(f"boundary RMSD   : {float(boundary_rmsd(static, state, probes)):.4f}")
    pp = per_partition_rmspe(static, state, data)
    print(f"worst partition : {float(pp.max()):.4f} (pole partitions are hardest)")


if __name__ == "__main__":
    main()
