"""Train an LM-substrate architecture end-to-end (~100M-class when run with
--full on real hardware; smoke-sized by default for CPU).

  PYTHONPATH=src python examples/lm_train.py --arch qwen3-0.6b --steps 200
"""
import argparse
import subprocess
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true", help="full config (needs a pod)")
    args = ap.parse_args()
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", args.arch,
        "--steps", str(args.steps), "--batch", str(args.batch),
        "--seq", str(args.seq), "--lr", "1e-3", "--log-every", "20",
    ]
    if not args.full:
        cmd.append("--smoke")
    sys.exit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
