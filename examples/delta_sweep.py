"""The paper's central trade-off (fig. 4), as a runnable example: sweep
delta and print the accuracy-vs-smoothness frontier.

  PYTHONPATH=src python examples/delta_sweep.py --iters 1000
"""
import argparse

import jax

from repro.core import psvgp, svgp
from repro.core.metrics import boundary_rmsd, rmspe
from repro.core.neighbors import boundary_probes
from repro.core.partition import make_grid, partition_data
from repro.data.spatial import e3sm_like_field


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=2500)
    ap.add_argument("--m", type=int, default=5)
    ap.add_argument("--deltas", type=float, nargs="+",
                    default=[0.0, 0.125, 0.5, 1.0])
    ap.add_argument("--comm", default="gather", choices=["gather", "ppermute"])
    ap.add_argument("--noise", type=float, default=2.5,
                    help="observation noise sd; the paper's boundary effect "
                    "needs a noisy/sparse regime (EXPERIMENTS.md §Repro)")
    args = ap.parse_args()

    ds = e3sm_like_field(n=12_000, seed=0, noise_sd=args.noise)
    grid = make_grid(ds.x, 10, 10)
    data = partition_data(ds.x, ds.y, grid)
    probes = boundary_probes(grid, probes_per_edge=8)

    print(f"{'delta':>6} | {'RMSPE':>8} | {'bRMSD':>8} |")
    print("-" * 32)
    for delta in args.deltas:
        cfg = psvgp.PSVGPConfig(
            svgp=svgp.SVGPConfig(num_inducing=args.m, input_dim=2),
            delta=delta, batch_size=32, learning_rate=0.05, comm=args.comm,
        )
        static = psvgp.build(cfg, data)
        state = psvgp.init(jax.random.PRNGKey(0), cfg, data)
        state = psvgp.fit(static, state, data, args.iters)
        r = float(rmspe(static, state, data))
        b = float(boundary_rmsd(static, state, probes))
        tag = " (ISVGP)" if delta == 0 else ""
        print(f"{delta:>6} | {r:>8.4f} | {b:>8.4f} |{tag}")
    print("\nExpected (paper fig. 4, noisy regime): RMSPE rises slightly with")
    print("delta while boundary RMSD falls (minimum at interior delta).")
    print("Averages over seeds are in benchmarks/results/delta_sweep_gather.json;")
    print("single-seed runs like this one are noisier than the paper's 10-rep mean.")


if __name__ == "__main__":
    main()
