"""End-to-end in-situ driver (the paper's deployment scenario, §1/§5).

Simulates a running climate model: at each SIMULATION STEP a new time
slice of the field arrives, the PSVGP gets a fixed iteration budget (the
paper: ~100-150 SGD iterations fit inside one ~1 s E3SM step), and the
per-partition inducing-point summaries are CHECKPOINTED as the in-situ
analysis product (a few KB per partition instead of the raw field).

  PYTHONPATH=src python examples/e3sm_insitu.py --sim-steps 5
"""
import argparse
import time

import jax
import numpy as np

from repro.checkpoint import save_train_state
from repro.core import psvgp, svgp
from repro.core.metrics import boundary_rmsd, rmspe
from repro.core.neighbors import boundary_probes
from repro.core.partition import make_grid, partition_data
from repro.data.spatial import e3sm_like_field


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sim-steps", type=int, default=5)
    ap.add_argument("--iters-per-step", type=int, default=150)
    ap.add_argument("--n-obs", type=int, default=12_000)
    ap.add_argument("--grid", type=int, default=10)
    ap.add_argument("--delta", type=float, default=0.125)
    ap.add_argument("--ckpt-dir", default="/tmp/psvgp_insitu")
    args = ap.parse_args()

    cfg = psvgp.PSVGPConfig(
        svgp=svgp.SVGPConfig(num_inducing=5, input_dim=2),
        delta=args.delta, batch_size=32, learning_rate=0.02,
    )
    state = None
    static = None
    probes = None

    for t in range(args.sim_steps):
        # --- the "simulation" produces a new time slice (field drifts) ---
        ds = e3sm_like_field(n=args.n_obs, seed=100 + t)
        grid = make_grid(ds.x, args.grid, args.grid)
        data = partition_data(ds.x, ds.y, grid)
        if state is None:
            static = psvgp.build(cfg, data)
            state = psvgp.init(jax.random.PRNGKey(0), cfg, data)
            probes = boundary_probes(grid, probes_per_edge=8)
        else:
            # warm start from the previous slice's model — the in-situ loop
            static = psvgp.build(cfg, data)

        # --- in-situ budget: fixed iterations alongside the sim step ---
        t0 = time.time()
        state = psvgp.fit(static, state, data, args.iters_per_step)
        jax.block_until_ready(state.params.m_star)
        fit_s = time.time() - t0

        r = float(rmspe(static, state, data))
        b = float(boundary_rmsd(static, state, probes))
        path = save_train_state(args.ckpt_dir, t, state)
        kb = sum(np.prod(l.shape) for l in jax.tree.leaves(state.params)) * 4 / 1024
        print(f"slice {t}: fit {args.iters_per_step} iters in {fit_s:.2f}s | "
              f"RMSPE {r:.4f} | bRMSD {b:.4f} | summary {kb:.0f} KiB -> {path}")


if __name__ == "__main__":
    main()
