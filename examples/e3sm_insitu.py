"""End-to-end in-situ driver (the paper's deployment scenario, §1/§5).

Simulates a running climate model with a live query endpoint attached —
the full lifecycle from docs/lifecycle.md:

  step 0   ``api.fit`` trains the partitioned surface from scratch and a
           ``Server`` goes live on it.
  step t   a new time slice arrives (the field drifts); ``api.refit``
           warm-starts from step t-1's parameters under a fixed SGD
           budget (the paper: ~100-150 iterations fit inside one ~1 s
           E3SM step); the new model is committed to the format=2
           artifact store (``save_step`` — a few KB per partition
           instead of the raw field) and then ``Server.swap`` flips it
           live with zero downtime — queries keep being answered by the
           old model until the instant the new one is ready.
  post hoc the store is a complete, versioned timeline: any step loads
           back bitwise (``FittedPSVGP.load(store, step=t)``) without
           the simulation, the jax backend warm-up, or retraining.

  PYTHONPATH=src python examples/e3sm_insitu.py --sim-steps 5
"""
import argparse

import numpy as np

from repro import api
from repro.core.metrics import boundary_rmsd, rmspe
from repro.core.neighbors import boundary_probes
from repro.core.partition import partition_data
from repro.data.spatial import e3sm_like_field


def _rmspe_on(fitted: api.FittedPSVGP, ds) -> float:
    """Training-data RMSPE of ``fitted`` on its own slice."""
    data = partition_data(ds.x, ds.y, fitted.grid)
    return float(rmspe(fitted.static, fitted.state, data))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sim-steps", type=int, default=5)
    ap.add_argument("--iters-per-step", type=int, default=150,
                    help="warm-refit SGD budget per simulation step")
    ap.add_argument("--first-fit-iters", type=int, default=300,
                    help="from-scratch budget for step 0")
    ap.add_argument("--n-obs", type=int, default=12_000)
    ap.add_argument("--grid", type=int, default=10)
    ap.add_argument("--m", type=int, default=5)
    ap.add_argument("--delta", type=float, default=0.125)
    ap.add_argument("--store", default="/tmp/psvgp_store",
                    help="format=2 artifact store (one step dir per slice)")
    args = ap.parse_args()

    # --- step 0: train from scratch, go live ----------------------------
    ds = e3sm_like_field(n=args.n_obs, seed=100)
    cfg = api.FitConfig(grid=args.grid, m=args.m, delta=args.delta,
                        train_iters=args.first_fit_iters)
    fitted = api.fit(cfg, ds, verbose=True)
    fitted.save_step(args.store, 0, meta={"rmspe": _rmspe_on(fitted, ds)})
    server = api.Server(fitted)

    rng = np.random.default_rng(7)
    kb = sum(int(np.prod(p.shape)) for p in
             __import__("jax").tree.leaves(fitted.state.params)) * 4 / 1024
    print(f"slice 0: live (summary {kb:.0f} KiB -> {args.store}/step_00000000)")

    for t in range(1, args.sim_steps):
        # --- the "simulation" produces a new time slice (field drifts) ---
        ds = e3sm_like_field(n=args.n_obs, seed=100 + t)

        # --- in-situ budget: warm refit alongside the sim step ----------
        new = api.refit(fitted, ds,
                        api.RefitConfig(train_iters=args.iters_per_step))
        r = _rmspe_on(new, ds)
        b = float(boundary_rmsd(new.static, new.state,
                                boundary_probes(new.grid, probes_per_edge=8)))

        # --- commit the step, then flip it live (zero downtime) ---------
        path = new.save_step(args.store, t, meta={"refit_s": new.refit_seconds,
                                                  "rmspe": r})
        swap = server.swap(new, version=t)

        # the endpoint answers against the JUST-SWAPPED model
        lo = [new.grid.x_edges[0], new.grid.y_edges[0]]
        hi = [new.grid.x_edges[-1], new.grid.y_edges[-1]]
        probe = rng.uniform(lo, hi, (64, 2)).astype(np.float32)
        mean, _ = server.submit(probe)

        print(f"slice {t}: refit {args.iters_per_step} iters in "
              f"{new.refit_seconds:.2f}s | RMSPE {r:.4f} | bRMSD {b:.4f} | "
              f"swap build {swap['build_s']:.2f}s | "
              f"probe mean {float(mean.mean()):+.3f} -> {path}")
        fitted = new

    # --- lifecycle report: who served what, and for how long ------------
    lc = server.lifecycle()
    print(f"lifecycle: {lc['swaps']} swaps, active version {lc['active_version']}")
    for v in lc["versions"]:
        refit_s = f"{v['refit_s']:.2f}s" if v["refit_s"] is not None else "  (fit)"
        print(f"  version {v['version']}: {v['requests']} requests, "
              f"refit {refit_s}, build {v['build_s']:.2f}s")

    # --- post hoc: the store replays any step without the simulation -----
    steps = api.peek_steps(args.store)  # pure JSON — no jax needed to ask
    replay = api.FittedPSVGP.load(args.store, step=steps[-1])
    again, _ = replay.predict(probe)
    assert np.array_equal(np.asarray(again), np.asarray(mean)), \
        "post-hoc replay must be bitwise the live answer"
    print(f"store has steps {steps}; step {steps[-1]} replays bitwise")


if __name__ == "__main__":
    main()
