"""Serve a small model with batched requests (prefill + decode loop).

  PYTHONPATH=src python examples/serve_demo.py --arch recurrentgemma-2b
"""
import argparse
import subprocess
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()
    sys.exit(subprocess.call([
        sys.executable, "-m", "repro.launch.serve",
        "--arch", args.arch, "--smoke",
        "--batch", str(args.batch),
        "--prompt-len", str(args.prompt_len),
        "--gen", str(args.gen),
    ]))


if __name__ == "__main__":
    main()
