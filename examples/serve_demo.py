"""Serve a small model with batched requests.

LM mode (default): prefill + decode loop on a smoke-sized architecture.
GP mode (--gp): the paper's serving path — train the partitioned PSVGP
surface and answer query batches from the cached factors; --sharded
serves from the mesh-sharded cache through the overlapped pipeline
(virtual devices on CPU).

  PYTHONPATH=src python examples/serve_demo.py --arch recurrentgemma-2b
  PYTHONPATH=src python examples/serve_demo.py --gp
  PYTHONPATH=src python examples/serve_demo.py --gp --sharded
"""
import argparse
import subprocess
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--gp", action="store_true",
                    help="serve the blended PSVGP surface instead of an LM")
    ap.add_argument("--sharded", action="store_true",
                    help="GP mode: mesh-sharded cache + overlapped pipeline")
    args = ap.parse_args()
    if args.gp:
        cmd = [
            sys.executable, "-m", "repro.launch.serve", "--gp",
            "--gp-grid", "4", "--gp-n", "4000", "--gp-m", "6",
            "--gp-train-iters", "150", "--gp-batch", "512", "--gp-requests", "12",
        ]
        if args.sharded:
            cmd.append("--sharded")
    else:
        cmd = [
            sys.executable, "-m", "repro.launch.serve",
            "--arch", args.arch, "--smoke",
            "--batch", str(args.batch),
            "--prompt-len", str(args.prompt_len),
            "--gen", str(args.gen),
        ]
    sys.exit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
