"""Serve a small model with batched requests.

LM mode (default): prefill + decode loop on a smoke-sized architecture
(subprocess shim over ``repro.launch.serve``).

GP mode (--gp): the paper's full lifecycle through the ``repro.api``
front door — fit the partitioned surface, SAVE the artifact, then serve
query batches from the loaded artifact (``Server.from_artifact``; no
retraining on the serving path). ``--sharded`` serves from the
mesh-sharded cache through the overlapped pipeline (virtual devices on
CPU).

  PYTHONPATH=src python examples/serve_demo.py --arch recurrentgemma-2b
  PYTHONPATH=src python examples/serve_demo.py --gp
  PYTHONPATH=src python examples/serve_demo.py --gp --sharded
"""
import argparse
import subprocess
import sys
import tempfile


def run_gp(sharded: bool) -> None:
    # sharded mode maps one partition per device; on CPU the devices are
    # virtual and must be forced before jax initializes
    from repro.launch.serve_sharded import ensure_host_devices

    grid_side = 4
    if sharded:
        ensure_host_devices(grid_side * grid_side)

    import numpy as np

    from repro import api
    from repro.data.spatial import e3sm_like_field

    ds = e3sm_like_field(n=4000, seed=0)
    fitted = api.fit(
        api.FitConfig(grid=grid_side, m=6, train_iters=150), ds, verbose=True
    )

    rng = np.random.default_rng(1)
    lo, hi = ds.x.min(axis=0), ds.x.max(axis=0)
    batches = [
        rng.uniform(lo, hi, (512, 2)).astype(np.float32) for _ in range(12)
    ]

    cfg = api.ServeConfig(
        mode="sharded" if sharded else "replicated",
        pipeline="pipelined" if sharded else "serial",
    )
    with tempfile.TemporaryDirectory() as td:
        fitted.save(td)
        server = api.Server.from_artifact(td, cfg)  # serving != training
        report = server.stream(batches)
    pct = report["latency_ms"]
    print(f"served {len(batches)} requests x 512 points "
          f"({cfg.mode}/{cfg.pipeline}, backend={report['backend']})")
    print(f"latency/request ms: p50={pct['p50_ms']:.2f} "
          f"p95={pct['p95_ms']:.2f} p99={pct['p99_ms']:.2f}")
    print(f"throughput: {report['points_per_s']:,.0f} points/s")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--gp", action="store_true",
                    help="serve the blended PSVGP surface instead of an LM "
                         "(fit -> save artifact -> Server.from_artifact)")
    ap.add_argument("--sharded", action="store_true",
                    help="GP mode: mesh-sharded cache + overlapped pipeline")
    args = ap.parse_args()
    if args.gp:
        run_gp(args.sharded)
        return
    cmd = [
        sys.executable, "-m", "repro.launch.serve",
        "--arch", args.arch, "--smoke",
        "--batch", str(args.batch),
        "--prompt-len", str(args.prompt_len),
        "--gen", str(args.gen),
    ]
    sys.exit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
