"""Pure-jnp oracles for the Pallas kernels (the allclose targets).

These mirror what ``repro.gp.covariances`` / ``repro.core.svgp`` compute, but
are kept dependency-free and in the exact input convention of the kernels so
tests compare kernel output to THIS file, and this file is itself covered by
tests against the gp/ implementations.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp


def rbf_cross_cov(
    x: jnp.ndarray, z: jnp.ndarray, log_lengthscale: jnp.ndarray, log_variance: jnp.ndarray
) -> jnp.ndarray:
    """ARD-RBF K(X,Z): exp(lv) * exp(-0.5 sum_d (x_d - z_d)^2 / l_d^2).

    x: (n, d), z: (m, d) -> (n, m).
    """
    inv_l = jnp.exp(-log_lengthscale)
    diff = x[:, None, :] * inv_l - z[None, :, :] * inv_l
    r2 = jnp.sum(diff * diff, axis=-1)
    return jnp.exp(log_variance) * jnp.exp(-0.5 * r2)


def svgp_projection(
    x: jnp.ndarray,
    z: jnp.ndarray,
    log_lengthscale: jnp.ndarray,
    log_variance: jnp.ndarray,
    w: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused SVGP projection (the O(B m^2) ELBO hot path).

    w: (m, m) = Lmm^{-1} (dense lower-triangular inverse of chol(Kmm)).
    Returns:
      knm    (B, m)  cross-covariance K(X, Z)
      lk_t   (B, m)  K(X,Z) @ W^T  (row i = (Lmm^{-1} k_i)^T)
      q_diag (B,)    ||Lmm^{-1} k_i||^2 = k_i^T Kmm^{-1} k_i
    """
    knm = rbf_cross_cov(x, z, log_lengthscale, log_variance)
    lk_t = knm @ w.T
    q_diag = jnp.sum(lk_t * lk_t, axis=-1)
    return knm, lk_t, q_diag


def posterior_predict(
    x: jnp.ndarray,
    z: jnp.ndarray,
    log_lengthscale: jnp.ndarray,
    log_variance: jnp.ndarray,
    w: jnp.ndarray,
    u: jnp.ndarray,
    c: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused cached-posterior prediction (the serving hot path).

    w: (m, m) = Lmm^{-1};  u: (m, m) = Sl^T A;  c: (m,) projected mean
    (see repro.core.posterior for the factor definitions). Returns:
      mean (Q,)  K(X*,Z) @ c
      fvar (Q,)  k_** - ||W k_*||^2 + ||U k_*||^2   (un-clamped)
    """
    knm = rbf_cross_cov(x, z, log_lengthscale, log_variance)
    mean = knm @ c
    lk = knm @ w.T
    su = knm @ u.T
    fvar = jnp.exp(log_variance) - jnp.sum(lk * lk, axis=-1) + jnp.sum(su * su, axis=-1)
    return mean, fvar


def posterior_predict_slots(
    hx: jnp.ndarray,
    z: jnp.ndarray,
    log_lengthscale: jnp.ndarray,
    log_variance: jnp.ndarray,
    w: jnp.ndarray,
    u: jnp.ndarray,
    c: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Slot-stacked ``posterior_predict``: hx (S, Q, d) -> (S, Q) pairs.

    One model, S stacked query blocks (the serving program's 9 halo
    slots) — the allclose target for the slot-stacked Pallas launch.
    """
    return jax.vmap(
        lambda xs: posterior_predict(xs, z, log_lengthscale, log_variance, w, u, c)
    )(hx)


def posterior_predict_slots_masked(
    hx: jnp.ndarray,
    qmask: jnp.ndarray,
    z: jnp.ndarray,
    log_lengthscale: jnp.ndarray,
    log_variance: jnp.ndarray,
    w: jnp.ndarray,
    u: jnp.ndarray,
    c: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Masked slot-stacked oracle — the TWO-LEVEL routing contract.

    A two-level block mixes owner rows, spill rows (real queries hosted
    for an overflowing neighbor cell) and padded rows (qmask 0, cell-
    center placeholders). The kernel's guarantee that makes the mix safe
    is ROW INDEPENDENCE: every output row is a function of its own input
    row and the resident factors only, so spill rows compute exactly what
    they would as primaries and padded rows influence nothing.

    This oracle states that contract as math: it equals
    :func:`posterior_predict_slots` with masked rows forced to zero.
    Tests hold the Pallas kernel to it two ways — kernel * qmask must
    equal this oracle, and perturbing masked rows' INPUTS must leave
    valid rows bitwise unchanged (see tests/test_posterior.py).

    qmask: (S, Q) {0,1} row validity per slot block.
    """
    mean, fvar = posterior_predict_slots(
        hx, z, log_lengthscale, log_variance, w, u, c
    )
    return mean * qmask, fvar * qmask
