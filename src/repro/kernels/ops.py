"""Jit'd dispatch wrappers around the Pallas kernels.

Handles the TPU alignment contract (pad B to the sublane tile, m to the
128 lane width, zero-pad W) and strips the padding from outputs, so callers
(``repro.core.posterior.projection`` / ``predict_cached``) see clean
shapes. On CPU the kernels run
in interpret mode — same kernel body, Python evaluation — which is how this
container validates them; on a real TPU backend they compile to Mosaic.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl

from repro.gp.covariances import rbf as _rbf_covariance
from repro.kernels import ref
from repro.kernels.predict import posterior_predict_pallas, posterior_predict_slots_pallas
from repro.kernels.rbf import rbf_cross_cov_pallas
from repro.kernels.svgp_proj import svgp_projection_pallas

_LANE = 128
_SUBLANE = 8


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def require_rbf(cov_fn) -> None:
    """Refuse to route a non-RBF covariance through the Pallas kernels.

    Every kernel in this package hard-codes the ARD-RBF; dispatching any
    other covariance through them would silently return RBF answers (the
    kernel only ever sees log_lengthscale/log_variance, not ``cov_fn``).
    Callers that know their covariance (``posterior.predict_cached`` and
    friends) pass it here before taking the ``use_pallas`` path; ``None``
    is accepted for call sites that only handle the RBF by construction.
    """
    if cov_fn is not None and cov_fn is not _rbf_covariance:
        name = getattr(cov_fn, "__name__", repr(cov_fn))
        raise ValueError(
            f"the Pallas prediction kernels implement only the 'rbf' "
            f"covariance, got {name!r}; run with use_pallas=False (the jnp "
            "path supports every covariance in repro.gp.covariances)"
        )


def _round_up(n: int, k: int) -> int:
    return ((n + k - 1) // k) * k


def rbf_cross_cov(
    x: jnp.ndarray,
    z: jnp.ndarray,
    log_lengthscale: jnp.ndarray,
    log_variance: jnp.ndarray,
    *,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """K(X, Z) via the Pallas kernel, padding-safe. x (B,d), z (m,d)."""
    interpret = _interpret_default() if interpret is None else interpret
    B, d = x.shape
    m = z.shape[0]
    bb = min(_LANE, _round_up(B, _SUBLANE))
    Bp, mp = _round_up(B, bb), _round_up(m, _LANE)
    xp = jnp.pad(x, ((0, Bp - B), (0, 0)))
    zp = jnp.pad(z, ((0, mp - m), (0, 0)))
    out = rbf_cross_cov_pallas(
        xp, zp, log_lengthscale, log_variance, block_b=bb, interpret=interpret
    )
    return out[:B, :m]


@jax.custom_vjp
def svgp_projection(
    x: jnp.ndarray,
    z: jnp.ndarray,
    log_lengthscale: jnp.ndarray,
    log_variance: jnp.ndarray,
    lmm: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused ELBO projection. lmm: (m, m) lower Cholesky of Kmm.

    Returns (knm (B,m), lk_t (B,m), q_diag (B,)) with TRUE shapes.
    The (m x m) triangular inversion W = Lmm^{-1} runs in XLA (one MXU tile;
    see svgp_proj.py docstring), the O(B m^2) bulk in Pallas.

    Differentiable via custom_vjp: the backward pass recomputes through the
    pure-jnp reference (flash-attention-style rematerialization) — Pallas
    kernels have no native autodiff rule, and the recompute keeps residual
    memory at zero extra HBM.
    """
    interpret = _interpret_default()
    B, d = x.shape
    m = z.shape[0]
    w = jsl.solve_triangular(lmm, jnp.eye(m, dtype=lmm.dtype), lower=True)
    bb = min(_LANE, _round_up(B, _SUBLANE))
    Bp, mp = _round_up(B, bb), _round_up(m, _LANE)
    xp = jnp.pad(x, ((0, Bp - B), (0, 0)))
    zp = jnp.pad(z, ((0, mp - m), (0, 0)))
    wp = jnp.pad(w, ((0, mp - m), (0, mp - m)))  # zero rows/cols: inert slots
    knm, lkt, qd = svgp_projection_pallas(
        xp, zp, log_lengthscale, log_variance, wp, block_b=bb, interpret=interpret
    )
    return knm[:B, :m], lkt[:B, :m], qd[:B]


def _svgp_projection_fwd(x, z, log_lengthscale, log_variance, lmm):
    out = svgp_projection(x, z, log_lengthscale, log_variance, lmm)
    return out, (x, z, log_lengthscale, log_variance, lmm)


def _svgp_projection_bwd(residuals, cotangents):
    _, vjp = jax.vjp(svgp_projection_ref, *residuals)
    return vjp(cotangents)


def svgp_projection_ref(x, z, log_lengthscale, log_variance, lmm):
    """Pure-jnp reference with the same signature (also the bwd path)."""
    w = jsl.solve_triangular(lmm, jnp.eye(lmm.shape[0], dtype=lmm.dtype), lower=True)
    return ref.svgp_projection(x, z, log_lengthscale, log_variance, w)


svgp_projection.defvjp(_svgp_projection_fwd, _svgp_projection_bwd)


def posterior_predict(
    x: jnp.ndarray,
    z: jnp.ndarray,
    log_lengthscale: jnp.ndarray,
    log_variance: jnp.ndarray,
    w: jnp.ndarray,
    u: jnp.ndarray,
    c: jnp.ndarray,
    *,
    interpret: bool | None = None,
    cov_fn=None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused cached-posterior prediction, padding-safe (serving hot path).

    x (Q, d) queries; z (m, d); w/u (m, m) cached factors; c (m,) cached
    projected mean (see repro.core.posterior). Returns (mean (Q,), fvar
    (Q,)) with TRUE shapes — fvar NOT yet clamped or noise-augmented
    (callers own that, matching the jnp path in posterior.predict_cached).

    Zero-padding w/u/c makes the padded inducing slots exactly inert; the
    padded query rows are computed then stripped. ``cov_fn``, when given,
    is validated by :func:`require_rbf` — the kernel computes the RBF
    whatever the caller believes their covariance is.
    """
    require_rbf(cov_fn)
    interpret = _interpret_default() if interpret is None else interpret
    Q, d = x.shape
    m = z.shape[0]
    bq = min(_LANE, _round_up(Q, _SUBLANE))
    Qp, mp = _round_up(Q, bq), _round_up(m, _LANE)
    xp = jnp.pad(x, ((0, Qp - Q), (0, 0)))
    zp = jnp.pad(z, ((0, mp - m), (0, 0)))
    wp = jnp.pad(w, ((0, mp - m), (0, mp - m)))
    up = jnp.pad(u, ((0, mp - m), (0, mp - m)))
    cp = jnp.pad(c, (0, mp - m))
    mean, fvar = posterior_predict_pallas(
        xp, zp, log_lengthscale, log_variance, wp, up, cp, block_q=bq, interpret=interpret
    )
    return mean[:Q], fvar[:Q]


def posterior_predict_slots(
    hx: jnp.ndarray,
    z: jnp.ndarray,
    log_lengthscale: jnp.ndarray,
    log_variance: jnp.ndarray,
    w: jnp.ndarray,
    u: jnp.ndarray,
    c: jnp.ndarray,
    *,
    interpret: bool | None = None,
    cov_fn=None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Slot-stacked fused prediction: hx (S, Q, d) -> (mean, fvar) (S, Q).

    The sharded serving hot path: ONE model evaluated on S stacked query
    blocks (the 9 halo slots) in a single Pallas launch whose grid spans
    (S x q-blocks) with W/U/c resident across the whole grid — see
    ``repro.kernels.predict.posterior_predict_slots_pallas``. Padding
    contract and output conventions match :func:`posterior_predict`
    (per-slot query rows padded then stripped; fvar un-clamped). Rows are
    evaluated independently, so blocks may mix owner, spilled-in and
    padded rows (two-level routing) — masked semantics are the caller's
    qmask/weights, oracle ``ref.posterior_predict_slots_masked``.
    """
    require_rbf(cov_fn)
    interpret = _interpret_default() if interpret is None else interpret
    S, Q, d = hx.shape
    m = z.shape[0]
    bq = min(_LANE, _round_up(Q, _SUBLANE))
    Qp, mp = _round_up(Q, bq), _round_up(m, _LANE)
    hp = jnp.pad(hx, ((0, 0), (0, Qp - Q), (0, 0)))
    zp = jnp.pad(z, ((0, mp - m), (0, 0)))
    wp = jnp.pad(w, ((0, mp - m), (0, mp - m)))
    up = jnp.pad(u, ((0, mp - m), (0, mp - m)))
    cp = jnp.pad(c, (0, mp - m))
    mean, fvar = posterior_predict_slots_pallas(
        hp, zp, log_lengthscale, log_variance, wp, up, cp,
        block_q=bq, interpret=interpret,
    )
    return mean[:, :Q], fvar[:, :Q]


def posterior_predict_ref(x, z, log_lengthscale, log_variance, w, u, c):
    """Pure-jnp reference with the same signature (the allclose target)."""
    return ref.posterior_predict(x, z, log_lengthscale, log_variance, w, u, c)


def posterior_predict_slots_ref(hx, z, log_lengthscale, log_variance, w, u, c):
    """Pure-jnp slot-stacked reference (the allclose target)."""
    return ref.posterior_predict_slots(hx, z, log_lengthscale, log_variance, w, u, c)


# Reference implementation re-exported so benchmarks/tests can compare the
# dispatch layer against the oracle through one import site.
rbf_cross_cov_ref = ref.rbf_cross_cov
