"""Pallas TPU kernel: fused SVGP projection — the ELBO's O(B m^2) hot path.

Fuses, per (block_b x m_pad) tile and in one VMEM residency of X:
    knm    = K(X, Z)                      (VPU, explicit-diff RBF)
    lk_t   = knm @ W^T                    (MXU, W = Lmm^{-1} resident)
    q_diag = row-sums of lk_t^2           (VPU reduction)

The unfused path writes knm to HBM and reads it back for the projection;
fusing removes a full (B x m_pad) HBM round-trip — that is the memory-term
optimization the roofline analysis attributes to this kernel. W stays
resident in VMEM across the whole grid (m_pad <= 256 -> <= 256 KiB).

The triangular solve producing W and the (m x m) Cholesky stay in XLA: one
128-lane tile of work, nothing for a custom kernel to win there.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _proj_kernel_body(x_ref, z_ref, invl_ref, var_ref, w_ref, knm_ref, lkt_ref, qd_ref):
    x = x_ref[...]  # (bb, d)
    z = z_ref[...]  # (m, d)
    inv_l = invl_ref[...]  # (1, d)
    xs = x * inv_l
    zs = z * inv_l
    diff = xs[:, None, :] - zs[None, :, :]
    r2 = jnp.sum(diff * diff, axis=-1)  # (bb, m)
    knm = var_ref[0, 0] * jnp.exp(-0.5 * r2)
    knm_ref[...] = knm
    # MXU: (bb, m) @ (m, m). fp32 accumulation regardless of input dtype.
    lkt = jax.lax.dot_general(
        knm,
        w_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),  # knm @ W^T
        preferred_element_type=jnp.float32,
    ).astype(knm.dtype)
    lkt_ref[...] = lkt
    qd_ref[...] = jnp.sum(lkt * lkt, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def svgp_projection_pallas(
    x: jnp.ndarray,
    z: jnp.ndarray,
    log_lengthscale: jnp.ndarray,
    log_variance: jnp.ndarray,
    w: jnp.ndarray,
    *,
    block_b: int = 128,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x (B, d), z (m, d), w (m, m) -> (knm (B,m), lk_t (B,m), q_diag (B,)).

    Caller contract: B % block_b == 0, m % 128 == 0, and w is ZERO-PADDED
    outside the true (m_true, m_true) block — zero rows/cols make padded
    inducing slots exactly inert in lk_t and q_diag (knm's padded columns
    are garbage by design; callers must mask them, ops.py does).
    """
    B, d = x.shape
    m, _ = z.shape
    grid = (B // block_b,)
    inv_l = jnp.exp(-log_lengthscale).reshape(1, d).astype(x.dtype)
    var = jnp.exp(log_variance).reshape(1, 1).astype(x.dtype)
    knm, lkt, qd = pl.pallas_call(
        _proj_kernel_body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((m, d), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((m, m), lambda i: (0, 0)),  # W resident across grid
        ],
        out_specs=[
            pl.BlockSpec((block_b, m), lambda i: (i, 0)),
            pl.BlockSpec((block_b, m), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, m), x.dtype),
            jax.ShapeDtypeStruct((B, m), x.dtype),
            jax.ShapeDtypeStruct((B, 1), x.dtype),
        ],
        interpret=interpret,
    )(x, z, inv_l, var, w)
    return knm, lkt, qd[:, 0]
