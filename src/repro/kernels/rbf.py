"""Pallas TPU kernel: tiled ARD-RBF cross-covariance K(X, Z).

TPU-native design (DESIGN.md §6): the paper's per-partition m is tiny
(5..20), hopeless for the 128x128 MXU on its own — so the kernel is shaped
for the BATCHED setting the PSVGP trainer actually runs: ``vmap`` over the
partition axis adds a leading grid dimension (Pallas batching rule), and
within one partition we tile the observation axis in ``block_b`` sublane
rows while the (padded) inducing axis occupies the 128-wide lane dimension.

Distance computation uses the explicit-difference form (not the
|x|^2+|z|^2-2xz MXU expansion): spatial inputs have d = 2..3, so the
contraction is lane-trivial and the subtract/square keeps full precision at
short distances, where exp(-r2/2) has all its curvature. For d >= 8 a dot-
based variant would win; spatial modeling never gets there.

VMEM per grid step: block_b*(d + 2*m_pad) + m_pad*d floats — a few tens of
KiB at the default (128, 128) tile, far under the ~16 MiB budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rbf_kernel_body(x_ref, z_ref, invl_ref, var_ref, out_ref):
    """One (block_b x m_pad) output tile.

    x_ref: (block_b, d) VMEM, z_ref: (m_pad, d) VMEM (fully resident),
    invl_ref: (1, d) VMEM, var_ref: (1, 1) VMEM.
    """
    x = x_ref[...]  # (bb, d)
    z = z_ref[...]  # (m, d)
    inv_l = invl_ref[...]  # (1, d)
    xs = x * inv_l  # scale once, reuse across the whole tile
    zs = z * inv_l
    # (bb, 1, d) - (1, m, d) -> (bb, m, d): explicit diff, VPU elementwise.
    diff = xs[:, None, :] - zs[None, :, :]
    r2 = jnp.sum(diff * diff, axis=-1)  # (bb, m)
    out_ref[...] = var_ref[0, 0] * jnp.exp(-0.5 * r2)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def rbf_cross_cov_pallas(
    x: jnp.ndarray,
    z: jnp.ndarray,
    log_lengthscale: jnp.ndarray,
    log_variance: jnp.ndarray,
    *,
    block_b: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """K(X, Z) for x (B, d), z (m, d) -> (B, m).

    Caller contract (enforced by ops.py): B % block_b == 0 and m % 128 == 0
    (pad with arbitrary rows; padded outputs are garbage the caller strips).
    """
    B, d = x.shape
    m, _ = z.shape
    grid = (B // block_b,)
    inv_l = jnp.exp(-log_lengthscale).reshape(1, d).astype(x.dtype)
    var = jnp.exp(log_variance).reshape(1, 1).astype(x.dtype)
    return pl.pallas_call(
        _rbf_kernel_body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),  # x tile marches over B
            pl.BlockSpec((m, d), lambda i: (0, 0)),  # z resident every step
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, m), x.dtype),
        interpret=interpret,
    )(x, z, inv_l, var)
