"""Pallas TPU kernel: fused cached-posterior prediction (serving hot path).

Per (block_q x m_pad) tile and in ONE VMEM residency of the query block:
    knm  = K(X*, Z)                      (VPU, explicit-diff RBF)
    mean = knm @ c                       (VPU reduction against resident c)
    lk   = knm @ W^T                     (MXU, W = Lmm^{-1} resident)
    su   = knm @ U^T                     (MXU, U = S-factor resident)
    var  = k_** - rowsum(lk^2) + rowsum(su^2)

The unfused path writes knm to HBM and reads it back TWICE (once per
projection); fusing removes both (Q x m_pad) round-trips and never
materializes lk/su in HBM at all — the kernel's only HBM traffic is the
query block in and two (Q,) vectors out. W, U and c stay resident in VMEM
across the whole grid (2 m_pad^2 + m_pad floats; m_pad <= 256 -> <= 513 KiB).

Same alignment contract as ``svgp_proj``: caller pads Q to the block, m to
the 128-lane width, and zero-pads W/U/c so padded inducing slots are inert
(zero COLUMNS of W/U kill the garbage knm columns; zero c entries kill them
in the mean). k_** for the stationary RBF is the process variance, exact
regardless of padding. Dispatch + padding live in ``kernels/ops.py``.

``posterior_predict_slots_pallas`` is the slot-stacked variant for the
SHARDED serving program: one launch whose grid spans (S halo slots x
q-blocks), evaluating the local model on all S stacked query blocks while
W, U and c stay resident in VMEM across the WHOLE (S x Qb) grid — the
factors are staged into VMEM once per request instead of once per slot,
and the (9*q_max, d) reshape round-trip of the unstacked call disappears.

Masking/row-mix contract (what lets TWO-LEVEL routing reuse this kernel
unchanged): both kernel bodies are strictly ROW-INDEPENDENT — output row
i is a function of input row i and the resident W/U/c only (the row-sum
reductions run along the m axis, never across queries). A block may
therefore freely mix owner rows, spilled-in neighbor rows and padded
placeholder rows; validity lives entirely in the caller's qmask /
corner-weight zeros, and the oracle for the masked semantics is
``ref.posterior_predict_slots_masked`` (held to the kernel in
tests/test_posterior.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _predict_kernel_body(
    x_ref, z_ref, invl_ref, var_ref, w_ref, u_ref, c_ref, mean_ref, fvar_ref
):
    x = x_ref[...]  # (bq, d)
    z = z_ref[...]  # (m, d)
    inv_l = invl_ref[...]  # (1, d)
    xs = x * inv_l
    zs = z * inv_l
    diff = xs[:, None, :] - zs[None, :, :]
    r2 = jnp.sum(diff * diff, axis=-1)  # (bq, m)
    var = var_ref[0, 0]
    knm = var * jnp.exp(-0.5 * r2)
    # VPU: mean = knm @ c with c resident as a (1, m) row.
    mean_ref[...] = jnp.sum(knm * c_ref[...], axis=-1, keepdims=True)
    # MXU: two (bq, m) @ (m, m) projections, fp32 accumulation.
    lk = jax.lax.dot_general(
        knm, w_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),  # knm @ W^T
        preferred_element_type=jnp.float32,
    ).astype(knm.dtype)
    su = jax.lax.dot_general(
        knm, u_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),  # knm @ U^T
        preferred_element_type=jnp.float32,
    ).astype(knm.dtype)
    fvar_ref[...] = (
        var
        - jnp.sum(lk * lk, axis=-1, keepdims=True)
        + jnp.sum(su * su, axis=-1, keepdims=True)
    )


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def posterior_predict_pallas(
    x: jnp.ndarray,
    z: jnp.ndarray,
    log_lengthscale: jnp.ndarray,
    log_variance: jnp.ndarray,
    w: jnp.ndarray,
    u: jnp.ndarray,
    c: jnp.ndarray,
    *,
    block_q: int = 128,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x (Q, d), z (m, d), w/u (m, m), c (m,) -> (mean (Q,), fvar (Q,)).

    Caller contract: Q % block_q == 0, m % 128 == 0, and w/u/c are
    ZERO-PADDED outside the true m_true block (see module docstring).
    """
    Q, d = x.shape
    m, _ = z.shape
    grid = (Q // block_q,)
    inv_l = jnp.exp(-log_lengthscale).reshape(1, d).astype(x.dtype)
    var = jnp.exp(log_variance).reshape(1, 1).astype(x.dtype)
    c_row = c.reshape(1, m).astype(x.dtype)
    mean, fvar = pl.pallas_call(
        _predict_kernel_body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            pl.BlockSpec((m, d), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((m, m), lambda i: (0, 0)),  # W resident across grid
            pl.BlockSpec((m, m), lambda i: (0, 0)),  # U resident across grid
            pl.BlockSpec((1, m), lambda i: (0, 0)),  # c resident across grid
        ],
        out_specs=[
            pl.BlockSpec((block_q, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_q, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, 1), x.dtype),
            jax.ShapeDtypeStruct((Q, 1), x.dtype),
        ],
        interpret=interpret,
    )(x, z, inv_l, var, w, u, c_row)
    return mean[:, 0], fvar[:, 0]


def _predict_slots_kernel_body(
    x_ref, z_ref, invl_ref, var_ref, w_ref, u_ref, c_ref, mean_ref, fvar_ref
):
    x = x_ref[0]  # (bq, d): this (slot, q-block) grid cell's queries
    z = z_ref[...]  # (m, d)
    inv_l = invl_ref[...]  # (1, d)
    xs = x * inv_l
    zs = z * inv_l
    diff = xs[:, None, :] - zs[None, :, :]
    r2 = jnp.sum(diff * diff, axis=-1)  # (bq, m)
    var = var_ref[0, 0]
    knm = var * jnp.exp(-0.5 * r2)
    mean_ref[0] = jnp.sum(knm * c_ref[...], axis=-1, keepdims=True)
    lk = jax.lax.dot_general(
        knm, w_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),  # knm @ W^T
        preferred_element_type=jnp.float32,
    ).astype(knm.dtype)
    su = jax.lax.dot_general(
        knm, u_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),  # knm @ U^T
        preferred_element_type=jnp.float32,
    ).astype(knm.dtype)
    fvar_ref[0] = (
        var
        - jnp.sum(lk * lk, axis=-1, keepdims=True)
        + jnp.sum(su * su, axis=-1, keepdims=True)
    )


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def posterior_predict_slots_pallas(
    hx: jnp.ndarray,
    z: jnp.ndarray,
    log_lengthscale: jnp.ndarray,
    log_variance: jnp.ndarray,
    w: jnp.ndarray,
    u: jnp.ndarray,
    c: jnp.ndarray,
    *,
    block_q: int = 128,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """hx (S, Q, d) slot-stacked queries -> (mean (S, Q), fvar (S, Q)).

    Grid = (S, Q // block_q): one launch covers every halo slot. The slot
    axis only moves the query BlockSpec — z/W/U/c index maps are constant,
    so the factors stay resident across the entire grid.

    Caller contract: Q % block_q == 0, m % 128 == 0, and w/u/c ZERO-PADDED
    outside the true m_true block (see module docstring).
    """
    S, Q, d = hx.shape
    m, _ = z.shape
    grid = (S, Q // block_q)
    inv_l = jnp.exp(-log_lengthscale).reshape(1, d).astype(hx.dtype)
    var = jnp.exp(log_variance).reshape(1, 1).astype(hx.dtype)
    c_row = c.reshape(1, m).astype(hx.dtype)
    mean, fvar = pl.pallas_call(
        _predict_slots_kernel_body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda s, i: (s, i, 0)),
            pl.BlockSpec((m, d), lambda s, i: (0, 0)),
            pl.BlockSpec((1, d), lambda s, i: (0, 0)),
            pl.BlockSpec((1, 1), lambda s, i: (0, 0)),
            pl.BlockSpec((m, m), lambda s, i: (0, 0)),  # W resident across grid
            pl.BlockSpec((m, m), lambda s, i: (0, 0)),  # U resident across grid
            pl.BlockSpec((1, m), lambda s, i: (0, 0)),  # c resident across grid
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, 1), lambda s, i: (s, i, 0)),
            pl.BlockSpec((1, block_q, 1), lambda s, i: (s, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, Q, 1), hx.dtype),
            jax.ShapeDtypeStruct((S, Q, 1), hx.dtype),
        ],
        interpret=interpret,
    )(hx, z, inv_l, var, w, u, c_row)
    return mean[..., 0], fvar[..., 0]
