"""Pallas TPU kernels for the SVGP ELBO hot path (+ jnp oracles).

Validated in interpret mode on CPU; compiled via Mosaic on real TPUs.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
