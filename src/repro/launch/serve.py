"""Serving driver: batched prefill + autoregressive decode.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get, get_smoke
from repro.runtime.steps import init_train_state, make_decode_step, make_prefill_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=1.0)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get(args.arch)
    key = jax.random.PRNGKey(args.seed)
    state = init_train_state(key, cfg)
    B, S = args.batch, args.prompt_len
    cache_len = S + args.gen + (cfg.vision.num_patches if cfg.vision is not None else 0)

    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    kwargs = {}
    if cfg.encoder is not None:
        e = cfg.encoder
        kwargs["frames"] = jnp.asarray(rng.normal(size=(B, e.num_frames, e.frontend_dim)), jnp.float32)
    if cfg.vision is not None:
        v = cfg.vision
        kwargs["patches"] = jnp.asarray(rng.normal(size=(B, v.num_patches, v.vit_dim)), jnp.float32)

    prefill = jax.jit(make_prefill_step(cfg, cache_len=cache_len))
    decode = jax.jit(make_decode_step(cfg))

    t0 = time.time()
    logits, cache = prefill(state.params, prompts, **kwargs)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    print(f"prefill: {B}x{S} tokens in {t_prefill*1e3:.1f} ms "
          f"({B*S/t_prefill:,.0f} tok/s)")

    pos0 = S + (cfg.vision.num_patches if cfg.vision is not None else 0)
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    generated = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(state.params, cache, jnp.asarray(pos0 + i, jnp.int32), tok)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / args.temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        generated.append(tok)
    jax.block_until_ready(generated[-1])
    t_dec = time.time() - t0
    out = jnp.concatenate(generated, axis=1)
    print(f"decode: {args.gen} steps x {B} seqs in {t_dec*1e3:.1f} ms "
          f"({B*(args.gen-1)/max(t_dec,1e-9):,.0f} tok/s)")
    print("sample row 0:", np.asarray(out[0])[:16], "...")


if __name__ == "__main__":
    main()
