"""Serving driver: batched prefill + autoregressive decode — and the GP
serving mode for the stitched PSVGP surface.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --batch 4 --prompt-len 64 --gen 32

GP mode (the paper's E3SM in-situ setting: train the partitioned surface,
then answer query batches at serving rates). A thin shim over
``repro.api``: the flags parse into a ``FitConfig``/``ServeConfig``,
``api.fit`` trains the PSVGP on the synthetic E3SM-like field (all local
posteriors factorized ONCE into a ``PosteriorCache``; ``--gp-save`` /
``--gp-artifact`` persist and reuse the trained artifact), and
``api.Server`` runs the batched query loop with a latency/throughput
report:

  PYTHONPATH=src python -m repro.launch.serve --gp \
      --gp-grid 8 --gp-m 10 --gp-train-iters 200 \
      --gp-batch 2048 --gp-requests 50

``--sharded`` switches the GP mode from the replicated cache to the
distributed endpoint (``repro.launch.serve_sharded``): the PosteriorCache
is sharded one partition per device over a gy x gx mesh, queries are
routed to their owning partition, and corner blending is resolved with a
1-hop ppermute halo exchange. Needs gp-grid^2 devices — on CPU they are
forced as virtual host devices, which must happen before jax initializes,
so --sharded is handled before any other jax work:

  PYTHONPATH=src python -m repro.launch.serve --gp --sharded --gp-grid 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get, get_smoke
from repro.runtime.steps import init_train_state, make_decode_step, make_prefill_step


def serve_gp(args) -> None:
    """Batched query loop over the blended PSVGP surface — a thin shim
    over ``repro.api``: fit (or load) the artifact, then serve the request
    stream through a replicated ``api.Server``."""
    from repro import api
    from repro.launch.serve_sharded import (
        load_or_train,
        query_batches,
        session_configs,
    )

    fit_cfg, serve_cfg, _ = session_configs(args, expect_mode="replicated")
    ds, fitted = load_or_train(args, fit_cfg=fit_cfg)

    t0 = time.time()
    if serve_cfg is None:
        serve_cfg = api.ServeConfig(mode="replicated")
    server = api.Server(fitted, serve_cfg)
    if ds is not None:
        print(f"posterior cache built in {(time.time()-t0)*1e3:.1f} ms "
              f"(one O(P m^3) factorization, reused by every request)")
    else:
        print("posterior cache restored from the artifact "
              "(no factorization at serve time)")

    # synthetic request stream: uniform query batches over the domain
    batches = query_batches(
        fitted.grid, ds, batch=args.gp_batch, requests=args.gp_requests,
        seed=args.seed, skew=getattr(args, "gp_skew", 0.0),
    )
    report = server.stream(batches)
    pct, qps = report["latency_ms"], report["points_per_s"]
    print(f"served {args.gp_requests} requests x {args.gp_batch} points")
    print(f"latency/request ms: p50={pct['p50_ms']:.2f} "
          f"p95={pct['p95_ms']:.2f} p99={pct['p99_ms']:.2f}")
    print(f"throughput: {qps:,.0f} points/s")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--gp", action="store_true", help="serve the stitched PSVGP surface")
    ap.add_argument("--sharded", action="store_true",
                    help="GP mode: serve from the mesh-sharded PosteriorCache "
                         "(repro.launch.serve_sharded) instead of the replicated one")
    # the --gp-* flags are owned by serve_sharded (one definition for both
    # entry points); its import is device-state free, so the virtual-device
    # setup of --sharded still works.
    from repro.launch.serve_sharded import add_gp_args

    add_gp_args(ap)
    args = ap.parse_args()

    if args.sharded and not args.gp:
        ap.error("--sharded only applies to the GP serving mode (add --gp)")
    if args.http and not args.gp:
        ap.error("--http only applies to the GP serving mode (add --gp)")
    if args.gp:
        if args.gp_requests < 1 or args.gp_batch < 1:
            ap.error("--gp-requests and --gp-batch must be >= 1")
        if args.http:
            # like --sharded below: nothing above initialized the jax
            # backend, so the HTTP driver can still force virtual devices.
            from repro.net.server import serve_http

            serve_http(
                args, expect_mode="sharded" if args.sharded else "replicated"
            )
            return
        if args.sharded:
            # imports and argparse above never initialize the jax backend,
            # so serve_sharded can still force the virtual device count.
            from repro.launch.serve_sharded import serve_sharded

            serve_sharded(args)
        else:
            serve_gp(args)
        return
    if not args.arch:
        ap.error("--arch required (or --gp for the PSVGP surface)")

    cfg = get_smoke(args.arch) if args.smoke else get(args.arch)
    key = jax.random.PRNGKey(args.seed)
    state = init_train_state(key, cfg)
    B, S = args.batch, args.prompt_len
    cache_len = S + args.gen + (cfg.vision.num_patches if cfg.vision is not None else 0)

    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    kwargs = {}
    if cfg.encoder is not None:
        e = cfg.encoder
        kwargs["frames"] = jnp.asarray(rng.normal(size=(B, e.num_frames, e.frontend_dim)), jnp.float32)
    if cfg.vision is not None:
        v = cfg.vision
        kwargs["patches"] = jnp.asarray(rng.normal(size=(B, v.num_patches, v.vit_dim)), jnp.float32)

    prefill = jax.jit(make_prefill_step(cfg, cache_len=cache_len))
    decode = jax.jit(make_decode_step(cfg))

    t0 = time.time()
    logits, cache = prefill(state.params, prompts, **kwargs)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    print(f"prefill: {B}x{S} tokens in {t_prefill*1e3:.1f} ms "
          f"({B*S/t_prefill:,.0f} tok/s)")

    pos0 = S + (cfg.vision.num_patches if cfg.vision is not None else 0)
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    generated = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(state.params, cache, jnp.asarray(pos0 + i, jnp.int32), tok)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / args.temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        generated.append(tok)
    jax.block_until_ready(generated[-1])
    t_dec = time.time() - t0
    out = jnp.concatenate(generated, axis=1)
    print(f"decode: {args.gen} steps x {B} seqs in {t_dec*1e3:.1f} ms "
          f"({B*(args.gen-1)/max(t_dec,1e-9):,.0f} tok/s)")
    print("sample row 0:", np.asarray(out[0])[:16], "...")


if __name__ == "__main__":
    main()
