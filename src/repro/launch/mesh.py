"""Production mesh construction.

Kept as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.runtime import compat


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod (TPU v5e pod slice); 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """Whatever this host actually has (CPU: 1 device) — for examples."""
    n = jax.device_count()
    return compat.make_mesh((n, 1), ("data", "model"))
