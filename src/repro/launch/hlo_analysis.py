"""Roofline-term derivation from compiled XLA artifacts (DESIGN.md §8).

This container is CPU-only (TPU v5e is the TARGET, not the runtime), so
wall-time cannot be measured; instead every (arch x shape x mesh) dry-run
yields the three roofline terms from its compiled module:

  compute term    = per-device HLO FLOPs / peak_FLOP/s      [s]
  memory term     = per-device HLO bytes / HBM_bw           [s]
  collective term = per-device collective bytes / link_bw   [s]

cost_analysis() is PER-DEVICE after SPMD partitioning (verified
empirically), matching the instructions' HLO_FLOPs/(chips x peak) with
HLO_FLOPs summed over chips. Collective bytes are NOT in cost_analysis:
they are parsed from the optimized HLO text by summing the result-shape
bytes of every collective op (payload ~ bytes leaving/entering a device).
"""
from __future__ import annotations

import re
from typing import NamedTuple

# TPU v5e hardware constants (per chip), from the assignment.
PEAK_FLOPS = 197e12  # bf16 FLOP/s
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"= (.+?) (all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"(pred|[subf]\d+|bf16|c\d+)\[([\d,]*)\]")


def _shape_bytes(spec: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(spec):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dtype, 4)
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-kind payload bytes (result shapes), per device."""
    out: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        result_spec, kind = m.group(1), m.group(2)
        out[kind] = out.get(kind, 0) + _shape_bytes(result_spec)
    return out


class RooflineTerms(NamedTuple):
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collective_breakdown: dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def total_s(self) -> float:
        # no-overlap upper bound; perfect overlap would be max() instead
        return self.compute_s + self.memory_s + self.collective_s


def roofline(compiled) -> RooflineTerms:
    from repro.runtime import compat

    ca = compat.cost_analysis(compiled)
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    breakdown = collective_bytes(compiled.as_text())
    cb = float(sum(breakdown.values()))
    return RooflineTerms(
        flops_per_device=flops,
        bytes_per_device=byts,
        collective_bytes_per_device=cb,
        collective_breakdown=breakdown,
        compute_s=flops / PEAK_FLOPS,
        memory_s=byts / HBM_BW,
        collective_s=cb / ICI_BW,
    )


def model_flops_train(cfg, seq_len: int, global_batch: int) -> float:
    """MODEL_FLOPS = 6 N D with N = active params (MoE: routed active only),
    D = tokens. Per the assignment's definition for the 'useful compute'
    ratio (train includes fwd+bwd: the 6x already accounts for it)."""
    n_active = active_param_count(cfg)
    return 6.0 * n_active * seq_len * global_batch


def model_flops_decode(cfg, global_batch: int) -> float:
    """One decoded token per sequence: 2 N D (fwd only)."""
    return 2.0 * active_param_count(cfg) * global_batch


def has_time_while_loops(cfg) -> bool:
    """True if any block runs a lax.scan over TIME (mlstm chunk scan, slstm
    step scan) — their in-loop cost is invisible to cost_analysis, so the
    dry-run swaps in the analytical count below for the compute term."""
    return any(b in ("mlstm", "slstm") for b in cfg.block_pattern)


def analytical_flops_recurrent(cfg, seq_len: int, batch: int, kind: str, chunk: int = 64) -> float:
    """TOTAL (all-device) flops for mlstm/slstm architectures, matmul-level
    accounting of exactly what repro.models.ssm computes.

    Train counts fwd x 4 (backward 2x + remat recompute 1x, matching
    cfg.remat=True); prefill counts fwd; decode counts the one-step path.
    """
    D, V = cfg.d_model, cfg.vocab_size
    H = cfg.num_heads
    inner = cfg.rnn_width or 2 * D
    dh = inner // H
    W = cfg.rnn_width or D

    def mlstm_tok(decode: bool) -> float:
        proj = 2 * D * inner * 2 + 3 * 2 * inner * inner + 2 * inner * 2 * H + 2 * inner * D
        conv = 2 * cfg.conv_width * inner
        if decode:
            rec = H * (6 * dh * dh + 6 * dh)  # kv outer + state read + norms
        else:
            # per-chunk: scores 2c^2 dh, intra-out 2c^2 dh, decay ~4c^2,
            # inter q@C 2c dh^2, state update 2c dh^2  => per token:
            rec = H * (4 * chunk * dh + 4 * dh * dh + 4 * chunk)
        return proj + conv + rec

    def slstm_tok(decode: bool) -> float:
        return 2 * D * 4 * W + 2 * W * 4 * W + 24 * W + 2 * W * D

    per_tok = 0.0
    for i in range(cfg.num_layers):
        kind_i = cfg.block_pattern[i % cfg.period]
        if kind_i == "mlstm":
            per_tok += mlstm_tok(kind == "decode")
        elif kind_i == "slstm":
            per_tok += slstm_tok(kind == "decode")
    per_tok += 2 * D * V  # lm head
    tokens = batch * (1 if kind == "decode" else seq_len)
    fwd = per_tok * tokens
    if kind == "train":
        return 4.0 * fwd
    return fwd


def active_param_count(cfg) -> float:
    """Active (per-token) parameter count from the config — non-embedding
    blocks + embeddings; MoE counts top_k + shared experts only."""
    D, L, V = cfg.d_model, cfg.num_layers, cfg.vocab_size
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    total = V * D * (1 if cfg.tie_embeddings else 2)  # embed + head
    per_pattern = {}
    for kind in set(cfg.block_pattern):
        if kind in ("attn", "local_attn"):
            p = D * H * hd + 2 * D * KV * hd + H * hd * D
        elif kind == "mla":
            a = cfg.mla
            qd = a.qk_nope_head_dim + a.qk_rope_head_dim
            p = (D * a.q_lora_rank + a.q_lora_rank * H * qd + D * a.kv_lora_rank
                 + D * a.qk_rope_head_dim + a.kv_lora_rank * H * a.qk_nope_head_dim
                 + a.kv_lora_rank * H * a.v_head_dim + H * a.v_head_dim * D)
        elif kind == "mlstm":
            inner = cfg.rnn_width or 2 * D
            p = 2 * D * inner + 3 * inner * inner + inner * 2 * H + inner * D
        elif kind == "slstm":
            W = cfg.rnn_width or D
            p = D * 4 * W + W * 4 * W + W * D
        elif kind == "rglru":
            W = cfg.rnn_width or D
            p = 2 * D * W + 2 * W * W + W * D
        else:
            p = 0
        per_pattern[kind] = p
    # mixing blocks, layer by layer (pattern cycled)
    for i in range(L):
        total += per_pattern[cfg.block_pattern[i % cfg.period]]
    # FFN per layer
    if cfg.mlp_kind != "none":
        if cfg.moe is not None:
            m = cfg.moe
            active_ff = (m.top_k + m.num_shared) * m.d_expert
            per_moe = 3 * D * active_ff + D * m.num_experts  # + router
            n_moe = L - (1 if m.first_layer_dense else 0)
            total += n_moe * per_moe
            if m.first_layer_dense:
                total += 3 * D * m.dense_d_ff
        else:
            mult = 3 if cfg.mlp_kind == "swiglu" else 2
            total += L * mult * D * cfg.d_ff
    # encoder stack (whisper)
    if cfg.encoder is not None:
        e = cfg.encoder
        enc_per = D * H * hd + 2 * D * KV * hd + H * hd * D + 2 * D * cfg.d_ff
        total += e.num_layers * enc_per
        # decoder cross-attention
        total += L * (D * H * hd + 2 * D * KV * hd + H * hd * D)
    return float(total)
