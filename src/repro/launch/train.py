"""Training driver for the LM substrate.

Runs REAL steps on whatever devices exist (CPU here, a pod in production —
the same code path; only the mesh differs). Wires data pipeline, sharding
rules, checkpointing and the metrics log together.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
      --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_train_state, save_train_state
from repro.configs import get, get_smoke
from repro.data.tokens import synthetic_token_batches
from repro.launch.mesh import make_host_mesh
from repro.runtime import compat
from repro.runtime.steps import init_train_state, make_train_step
from repro.sharding import state_pspecs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get(args.arch)
    mesh = make_host_mesh()
    print(f"arch={cfg.name} devices={jax.device_count()} mesh={dict(mesh.shape)}")

    state = init_train_state(jax.random.PRNGKey(args.seed), cfg)
    if args.ckpt_dir:
        restored = load_train_state(args.ckpt_dir, state)
        if restored is not None:
            state = restored
            print(f"restored checkpoint at step {int(state.step)}")
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(state.params))
    print(f"parameters: {n_params/1e6:.2f}M")

    pspecs = state_pspecs(state, mesh)
    with compat.set_mesh(mesh):
        step_fn = jax.jit(
            make_train_step(cfg, learning_rate=args.lr),
            in_shardings=compat.named_shardings(mesh, (pspecs, None)),
            out_shardings=compat.named_shardings(mesh, (pspecs, None)),
        )
        data = synthetic_token_batches(cfg.vocab_size, args.batch, args.seq, seed=args.seed)
        rng = np.random.default_rng(args.seed)
        t0 = time.time()
        for i, (toks, targets) in enumerate(data):
            if i >= args.steps:
                break
            batch = {"tokens": jnp.asarray(toks), "targets": jnp.asarray(targets)}
            if cfg.encoder is not None:
                e = cfg.encoder
                batch["frames"] = jnp.asarray(
                    rng.normal(size=(args.batch, e.num_frames, e.frontend_dim)), jnp.float32
                )
            if cfg.vision is not None:
                v = cfg.vision
                batch["patches"] = jnp.asarray(
                    rng.normal(size=(args.batch, v.num_patches, v.vit_dim)), jnp.float32
                )
            state, metrics = step_fn(state, batch)
            if (i + 1) % args.log_every == 0:
                dt = time.time() - t0
                tok_s = args.batch * args.seq * args.log_every / dt
                print(
                    f"step {i+1:5d}  loss {float(metrics['loss']):.4f}  "
                    f"ce {float(metrics['ce']):.4f}  {tok_s:,.0f} tok/s"
                )
                t0 = time.time()
            if args.ckpt_dir and args.ckpt_every and (i + 1) % args.ckpt_every == 0:
                p = save_train_state(args.ckpt_dir, i + 1, state)
                print(f"checkpoint -> {p}")
    print("done.")


if __name__ == "__main__":
    main()
