import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

Proves that EVERY (architecture x input shape) combination lowers AND
compiles on the production meshes — 16x16 single pod and 2x16x16 multi-pod
— with the framework's sharding rules, using ShapeDtypeStruct stand-ins
only (no parameter allocation; a 76B model lowers on a laptop).

Per combination it records memory_analysis() (proves fit), cost_analysis()
(FLOPs/bytes) and the collective-bytes breakdown parsed from the optimized
HLO — the inputs to benchmarks/roofline.py.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod --out out.json
  PYTHONPATH=src python -m repro.launch.dryrun --psvgp [--multi-pod]
"""
import argparse
import dataclasses
import functools
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, INPUT_SHAPES, get, input_specs, swa_variant
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.runtime import compat
from repro.models import transformer
from repro.runtime.steps import (
    init_train_state,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)
from repro.sharding import batch_pspec, cache_pspecs, data_axes, state_pspecs


def _data_shardable(n: int, mesh) -> bool:
    import numpy as np

    return n % int(np.prod([mesh.shape[a] for a in data_axes(mesh)])) == 0


def resolve_config(arch: str, shape_name: str):
    """Apply the long_500k SWA variant where the assignment requires it."""
    cfg = get(arch)
    if shape_name == "long_500k":
        cfg = swa_variant(cfg)
    return cfg


def _lower_combo(cfg, shape_name: str, mesh, fsdp: bool = False, microbatches: int = 1):
    """Lower + compile one (config, shape) on a mesh; return compiled module."""
    sh = INPUT_SHAPES[shape_name]
    key = jax.random.PRNGKey(0)

    state_shapes = jax.eval_shape(functools.partial(init_train_state, cfg=cfg), key)
    pspecs = state_pspecs(state_shapes, mesh, fsdp=fsdp)
    bspec = batch_pspec(mesh) if _data_shardable(sh.global_batch, mesh) else P()

    with compat.set_mesh(mesh):
        if sh.kind == "train":
            specs = input_specs(cfg, shape_name)
            batch_specs = {k: bspec if v.ndim >= 2 else P() for k, v in specs.items()}
            step = make_train_step(cfg, microbatches=microbatches)
            jitted = jax.jit(
                step,
                in_shardings=compat.named_shardings(mesh, (pspecs, batch_specs)),
                out_shardings=compat.named_shardings(mesh, (pspecs, None)),
            )
            lowered = jitted.lower(state_shapes, specs)
        elif sh.kind == "prefill":
            specs = input_specs(cfg, shape_name)
            step = make_prefill_step(cfg, cache_len=sh.seq_len)
            names = [k for k in ("tokens", "frames", "patches") if k in specs]
            in_sh = [pspecs.params] + [bspec for _ in names]
            jitted = jax.jit(
                lambda params, *args: step(params, **dict(zip(names, args, strict=True))),
                in_shardings=compat.named_shardings(mesh, tuple(in_sh)),
            )
            lowered = jitted.lower(state_shapes.params, *[specs[k] for k in names])
        else:  # decode
            serve_cfg = dataclasses.replace(cfg, remat=False)
            cache_shapes = jax.eval_shape(
                functools.partial(
                    transformer.init_cache, serve_cfg, sh.global_batch, sh.seq_len,
                    jnp.dtype(serve_cfg.dtype),
                )
            )
            cspecs = cache_pspecs(cache_shapes, mesh, shard_seq=(sh.global_batch == 1))
            step = make_decode_step(cfg)
            tok_spec = jax.ShapeDtypeStruct((sh.global_batch, 1), jnp.int32)
            pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
            jitted = jax.jit(
                step,
                in_shardings=compat.named_shardings(mesh, (pspecs.params, cspecs, P(), bspec)),
                out_shardings=compat.named_shardings(mesh, (None, cspecs)),
            )
            lowered = jitted.lower(state_shapes.params, cache_shapes, pos_spec, tok_spec)

        compiled = lowered.compile()
    return compiled


def _depth_variants(cfg):
    """Reduced-depth UNROLLED configs with 1 and 2 periods (same prelude and
    remainder) for the while-loop cost extrapolation: unrolled bodies are
    counted per period by cost_analysis, so (c2 - c1) = one period's cost."""
    prelude = 1 if (cfg.moe is not None and cfg.moe.first_layer_dense) else 0
    rem = (cfg.num_layers - prelude) % cfg.period
    n1 = prelude + cfg.period + rem
    n2 = n1 + cfg.period
    c1 = dataclasses.replace(cfg, num_layers=n1, unroll=True)
    c2 = dataclasses.replace(cfg, num_layers=n2, unroll=True)
    return c1, c2


def dryrun_one(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    verbose: bool = True,
    extrapolate: bool = True,
    cfg_override=None,
    fsdp: bool = False,
    microbatches: int = 1,
    q_chunk: int = 0,
):
    """Lower + compile one (arch, shape, mesh); return the analysis record.

    XLA's cost_analysis counts a while-loop body ONCE regardless of trip
    count (verified empirically), so the scan-over-periods body cost is
    recovered by lowering 1-period and 2-period variants and extrapolating
    linearly: total = c1 + (n_periods - 1) * (c2 - c1). Exact, because
    every period is identical work. memory_analysis comes from the FULL
    lowering (buffer sizes are trip-count independent).
    """
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = cfg_override if cfg_override is not None else resolve_config(arch, shape_name)
    if q_chunk:
        cfg = dataclasses.replace(cfg, attn_q_chunk=q_chunk)
    sh = INPUT_SHAPES[shape_name]
    if sh.kind != "train":
        # FSDP weight-gathers per decode step would wreck serving latency;
        # microbatching only applies to gradient steps.
        fsdp, microbatches = False, 1
    t0 = time.time()

    compiled = _lower_combo(cfg, shape_name, mesh, fsdp=fsdp, microbatches=microbatches)
    mem = compiled.memory_analysis()
    terms = hlo_analysis.roofline(compiled)

    prelude = 1 if (cfg.moe is not None and cfg.moe.first_layer_dense) else 0
    n_periods = (cfg.num_layers - prelude) // cfg.period
    flops_source = "hlo"
    if extrapolate and n_periods > 1:
        c1, c2 = _depth_variants(cfg)
        # metric variants use microbatches=1: the accumulation scan is a
        # while loop whose body cost_analysis would count once; the full
        # (memory) lowering above keeps the real microbatch count.
        t1 = hlo_analysis.roofline(_lower_combo(c1, shape_name, mesh, fsdp=fsdp))
        t2 = hlo_analysis.roofline(_lower_combo(c2, shape_name, mesh, fsdp=fsdp))
        k = n_periods - 1  # extra periods beyond the 1-period variant

        def ex(a1, a2):
            return a1 + k * (a2 - a1)

        breakdown = {
            key: max(
                int(ex(t1.collective_breakdown.get(key, 0), t2.collective_breakdown.get(key, 0))),
                t1.collective_breakdown.get(key, 0),
            )
            for key in set(t1.collective_breakdown) | set(t2.collective_breakdown)
        }
        flops = ex(t1.flops_per_device, t2.flops_per_device)
        byts = ex(t1.bytes_per_device, t2.bytes_per_device)
        cb = float(sum(breakdown.values()))
        terms = hlo_analysis.RooflineTerms(
            flops_per_device=flops,
            bytes_per_device=byts,
            collective_bytes_per_device=cb,
            collective_breakdown=breakdown,
            compute_s=flops / hlo_analysis.PEAK_FLOPS,
            memory_s=byts / hlo_analysis.HBM_BW,
            collective_s=cb / hlo_analysis.ICI_BW,
        )
        flops_source = "hlo+period-extrapolated"

    if hlo_analysis.has_time_while_loops(cfg):
        # mlstm/slstm scan over TIME: in-loop cost invisible to
        # cost_analysis even unrolled-by-period -> analytical count.
        total = hlo_analysis.analytical_flops_recurrent(
            cfg, sh.seq_len, sh.global_batch, sh.kind
        )
        flops = total / mesh.size
        terms = terms._replace(
            flops_per_device=flops, compute_s=flops / hlo_analysis.PEAK_FLOPS
        )
        flops_source = "analytical(time-scan)"

    if sh.kind == "train":
        mflops = hlo_analysis.model_flops_train(cfg, sh.seq_len, sh.global_batch)
    elif sh.kind == "prefill":
        mflops = hlo_analysis.model_flops_train(cfg, sh.seq_len, sh.global_batch) / 3.0
    else:
        mflops = hlo_analysis.model_flops_decode(cfg, sh.global_batch)
    chips = mesh.size
    total_hlo_flops = terms.flops_per_device * chips

    rec = {
        "arch": arch,
        "config_name": cfg.name,
        "shape": shape_name,
        "kind": sh.kind,
        "fsdp": fsdp,
        "microbatches": microbatches,
        "q_chunk": q_chunk,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "compile_s": round(time.time() - t0, 1),
        "bytes_per_device": {
            "arguments": mem.argument_size_in_bytes,
            "outputs": mem.output_size_in_bytes,
            "temps": mem.temp_size_in_bytes,
            "peak_est": mem.argument_size_in_bytes + mem.output_size_in_bytes
            + mem.temp_size_in_bytes,
        },
        "flops_per_device": terms.flops_per_device,
        "hlo_bytes_per_device": terms.bytes_per_device,
        "collective_bytes_per_device": terms.collective_bytes_per_device,
        "collective_breakdown": terms.collective_breakdown,
        "roofline_s": {
            "compute": terms.compute_s,
            "memory": terms.memory_s,
            "collective": terms.collective_s,
        },
        "dominant": terms.dominant,
        "flops_source": flops_source,
        "model_flops": mflops,
        "useful_compute_ratio": mflops / total_hlo_flops if total_hlo_flops else 0.0,
    }
    if verbose:
        print(json.dumps(rec, indent=2))
    return rec


def dryrun_psvgp(*, multi_pod: bool = False, comm: str = "ppermute", verbose: bool = True):
    """Lower + compile the PSVGP train step on the production mesh.

    One partition per device: 16x16 grid single-pod, 16x32 multi-pod
    (DESIGN.md §2). The paper's own technique — this record seeds the
    §Perf hillclimb."""
    import numpy as np

    from repro.configs.psvgp_e3sm import DRYRUN_MULTI_POD, DRYRUN_SINGLE_POD
    from repro.core import psvgp
    from repro.core.partition import make_grid
    from repro.core.psvgp_spmd import make_spmd_step
    from repro.core.sampler import slot_distribution
    from repro.core.neighbors import neighbor_table
    from repro.core.svgp import SVGPParams
    from repro.gp.covariances import CovarianceParams, make_covariance
    from repro.optim import AdamState
    from repro.core.psvgp import PSVGPState

    exp = DRYRUN_MULTI_POD if multi_pod else DRYRUN_SINGLE_POD
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = mesh.axis_names  # ("pod","data","model") rows = pod x data
    gx, gy = exp.grid
    grid = make_grid(np.zeros((1, 2), np.float32), gx, gy, bounds=(0.0, 10.0, 0.0, 10.0))
    cfg = exp.psvgp(comm=comm)
    P_ = grid.num_partitions
    n_max = 224  # the paper's max partition size (222), padded to sublane x8
    m, d = cfg.svgp.num_inducing, 2
    t0 = time.time()

    f32 = jnp.float32

    def sds(shape, dt=f32):
        return jax.ShapeDtypeStruct(shape, dt)

    params = SVGPParams(
        m_star=sds((P_, m)), s_tril=sds((P_, m, m)), z=sds((P_, m, d)),
        cov=CovarianceParams(log_lengthscale=sds((P_, d)), log_variance=sds((P_,))),
        log_beta=sds((P_,)),
    )
    state = PSVGPState(
        params=params,
        opt=AdamState(step=sds((), jnp.int32), mu=params, nu=params),
        step=sds((), jnp.int32),
    )
    tbl = jnp.asarray(neighbor_table(grid))
    dist_shapes = jax.eval_shape(
        lambda c: slot_distribution(c, tbl, cfg.delta), sds((P_,), jnp.int32)
    )
    p_dir = jnp.full((5,), 0.2, f32)

    cov_fn = make_covariance(cfg.svgp.covariance)
    with compat.set_mesh(mesh):
        if comm == "ppermute":
            step = make_spmd_step(mesh, axes, grid, cfg, cov_fn, p_dir)
            lowered = step.lower(
                state, sds((2,), jnp.uint32),
                sds((P_, n_max, d)), sds((P_, n_max)), sds((P_, n_max)),
                sds((P_, 5)), sds((P_,)),
            )
        else:  # gather mode under plain pjit
            pspec = P(tuple(axes))
            pl = SVGPParams(
                m_star=pspec, s_tril=pspec, z=pspec,
                cov=CovarianceParams(pspec, pspec), log_beta=pspec,
            )
            sspec = PSVGPState(params=pl, opt=AdamState(P(), pl, pl), step=P())
            from repro.core.sampler import SlotDistribution

            dspec = SlotDistribution(probs=pspec, n_eff=pspec, neighbor_tbl=pspec)
            jitted = jax.jit(
                functools.partial(
                    psvgp.train_step_gather, cfg=cfg, cov_fn=cov_fn
                ),
                in_shardings=compat.named_shardings(
                    mesh, (sspec, P(), pspec, pspec, pspec, dspec)
                ),
                out_shardings=compat.named_shardings(mesh, (sspec, None)),
            )
            lowered = jitted.lower(
                state, sds((2,), jnp.uint32),
                sds((P_, n_max, d)), sds((P_, n_max)), sds((P_, n_max)), dist_shapes,
            )
        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    terms = hlo_analysis.roofline(compiled)
    rec = {
        "arch": "psvgp-e3sm",
        "config_name": f"psvgp-{comm}",
        "shape": f"grid{gx}x{gy}-m{m}-B{cfg.batch_size}",
        "kind": "train",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": mesh.size,
        "compile_s": round(time.time() - t0, 1),
        "bytes_per_device": {
            "arguments": mem.argument_size_in_bytes,
            "outputs": mem.output_size_in_bytes,
            "temps": mem.temp_size_in_bytes,
            "peak_est": mem.argument_size_in_bytes + mem.output_size_in_bytes
            + mem.temp_size_in_bytes,
        },
        "flops_per_device": terms.flops_per_device,
        "hlo_bytes_per_device": terms.bytes_per_device,
        "collective_bytes_per_device": terms.collective_bytes_per_device,
        "collective_breakdown": terms.collective_breakdown,
        "roofline_s": {
            "compute": terms.compute_s,
            "memory": terms.memory_s,
            "collective": terms.collective_s,
        },
        "dominant": terms.dominant,
    }
    if verbose:
        print(json.dumps(rec, indent=2))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=[a.replace("_", "-").replace("-0-", "-0.") for a in ARCH_IDS] + ARCH_IDS)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true", help="every (arch x shape)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--fsdp", action="store_true", help="ZeRO-3 weight/opt sharding over data axes")
    ap.add_argument("--microbatches", type=int, default=1, help="gradient-accumulation chunks (train shapes)")
    ap.add_argument("--q-chunk", type=int, default=0, help="query-chunked attention block size (0=off)")
    ap.add_argument("--psvgp", action="store_true", help="dry-run the paper's PSVGP step")
    ap.add_argument("--comm", default="ppermute", choices=["ppermute", "gather"])
    ap.add_argument("--out", default=None, help="append JSONL records here")
    args = ap.parse_args()

    records, failures = [], []

    def emit(rec):
        records.append(rec)
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")

    if args.psvgp:
        emit(dryrun_psvgp(multi_pod=args.multi_pod, comm=args.comm))
    elif args.all:
        for arch in ARCH_IDS:
            for shape in INPUT_SHAPES:
                try:
                    emit(dryrun_one(arch, shape, multi_pod=args.multi_pod, fsdp=args.fsdp, microbatches=args.microbatches, q_chunk=args.q_chunk))
                except Exception as e:  # noqa: BLE001 — report all failures at end
                    traceback.print_exc()
                    failures.append((arch, shape, repr(e)))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required (or --all / --psvgp)")
        emit(dryrun_one(args.arch, args.shape, multi_pod=args.multi_pod, fsdp=args.fsdp, microbatches=args.microbatches, q_chunk=args.q_chunk))

    print(f"\n{len(records)} dry-runs OK, {len(failures)} failed")
    for f in failures:
        print("FAILED:", f)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
