"""Sharded multi-host GP serving — the distributed PosteriorCache endpoint.

Replicated serving (``repro.launch.serve --gp``) answers every query from
one host holding ALL P partitions' cached factors. This module completes
the paper's story at serving time: the ``PosteriorCache`` is sharded one
partition per device over the mesh (per-device factor memory = 1/P of
replicated), queries are routed to their owning partition by
``repro.core.routing``, and the 4-corner blend is resolved with a 1-hop
``ppermute`` halo exchange — exactly the training-time communication
pattern of ``repro.core.psvgp_spmd``, and NO all-gather of factors
anywhere.

Per request the device program does:

  1. halo-exchange the routed query blocks: every device receives its 8
     grid neighbors' (q_max, 2) query blocks (two ppermute rounds; the
     blend stencil never reaches further — see ``routing.OFFSETS``),
  2. evaluate the LOCAL cached posterior on all 9 blocks at once — one
     batched ``posterior.predict_cached`` of (9*q_max, 2) points
     (``use_pallas=True`` routes it through the fused Pallas prediction
     kernel of ``repro.kernels.predict`` on TPU),
  3. return each result block to the query's owner (the reverse halo:
     slot k's result travels along offset k carrying the evaluation of the
     slot 8-k block),
  4. blend the 4 corner evaluations per query on the owning device
     (``routing.blend_slots``).

Communication per request per device: 8 query blocks out + 8 result pairs
back — O(q_max) floats to nearest neighbors only, independent of P. The
factors, like the variational parameters during training, never move.

Usage (CPU dry-run; the grid is mapped one-partition-per-device onto
gy x gx virtual host devices):

  PYTHONPATH=src python -m repro.launch.serve_sharded \
      --gp-grid 8 --gp-m 10 --gp-train-iters 200 \
      --gp-batch 2048 --gp-requests 50

or equivalently through the main serving driver:

  PYTHONPATH=src python -m repro.launch.serve --gp --sharded --gp-grid 8
"""
from __future__ import annotations

import argparse
import os
import time
from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import posterior, routing
from repro.core.partition import PartitionGrid
from repro.gp.covariances import CovarianceParams
from repro.core.psvgp_spmd import grid_matches_mesh, shift_perm
from repro.runtime import compat
from repro.sharding import gp_stacked_pspecs


def ensure_host_devices(n: int) -> None:
    """Force >= n virtual host devices (must run before jax backend init).

    The host-device-count flag is written into XLA_FLAGS unconditionally
    (we cannot count devices without initializing the backend, and after
    init it is too late to set it) — on a real TPU slice the flag is inert
    for this process but IS inherited by child processes that run
    CPU-backed jax. An already-present but too-small count is rewritten
    upward (it only binds at backend init, so rewriting is still effective
    here). Raises with guidance if the backend initialized too early for
    the flag to take effect.
    """
    import re

    flags = os.environ.get("XLA_FLAGS", "")
    flag_re = r"--xla_force_host_platform_device_count=(\d+)"
    m = re.search(flag_re, flags)
    if m is None:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n}"
        ).strip()
    elif int(m.group(1)) < n:
        os.environ["XLA_FLAGS"] = re.sub(
            flag_re, f"--xla_force_host_platform_device_count={n}", flags
        )
    if jax.device_count() < n:
        raise RuntimeError(
            f"need {n} devices for one-partition-per-device serving, have "
            f"{jax.device_count()}. Set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n} before jax "
            "initializes (import order matters), or shrink --gp-grid."
        )


def mesh_for_grid(grid: PartitionGrid) -> Mesh:
    """(gy, gx) device mesh matching the partition grid, axes (data, model)
    — the serving analogue of the training mapping in
    ``repro.core.psvgp_spmd`` (grid x-steps shift along ``model``, y-steps
    along ``data``)."""
    return compat.make_mesh((grid.gy, grid.gx), ("data", "model"))


def shard_cache(
    cache: posterior.PosteriorCache, mesh: Mesh
) -> posterior.PosteriorCache:
    """Place the P-stacked cache one partition per device (leading axis
    over all mesh axes via ``sharding.gp_stacked_pspecs``)."""
    specs = gp_stacked_pspecs(cache, mesh)
    return jax.tree.map(
        lambda leaf, spec: jax.device_put(leaf, NamedSharding(mesh, spec)),
        cache, specs,
    )


def shard_table(table: routing.RoutingTable, mesh: Mesh):
    """Device-place the routed query blocks a request actually ships:
    (xq, corner_slot, corner_w), leading P axis over the mesh. qmask /
    src_idx / counts stay host-side (they only drive the result scatter)."""
    blocks = (
        jnp.asarray(table.xq),
        jnp.asarray(table.corner_slot),
        jnp.asarray(table.corner_w),
    )
    specs = gp_stacked_pspecs(blocks, mesh)
    return tuple(
        jax.device_put(b, NamedSharding(mesh, s)) for b, s in zip(blocks, specs)
    )


def _make_shift(axes: Sequence[str], gx: int, gy: int) -> Callable:
    """Build ``shift(tree, dx, dy)`` usable INSIDE a shard_map over ``axes``:
    every device receives the payload of the device at grid offset
    (dx, dy), zeros where that neighbor is off-grid (ppermute's edge
    semantics — routing guarantees off-grid slots are never blended).
    Diagonal offsets compose an x-hop and a y-hop; both are 1-hop
    nearest-neighbor collectives on the ICI torus, exactly like the
    training exchange in ``repro.core.psvgp_spmd``."""
    col_axis = axes[-1]
    row_axes = tuple(axes[:-1])
    row_ax = row_axes if len(row_axes) > 1 else row_axes[0]

    def shift(tree, dx: int, dy: int):
        def sh(a):
            if dx:
                a = jax.lax.ppermute(a, col_axis, shift_perm(gx, up=(dx > 0)))
            if dy:
                a = jax.lax.ppermute(a, row_ax, shift_perm(gy, up=(dy > 0)))
            return a

        return jax.tree.map(sh, tree)

    return shift


def make_halo_gather(mesh: Mesh, axes: Sequence[str], grid: PartitionGrid):
    """Jitted (P, ...) -> (P, 9, ...) halo gather: output slot k on device p
    is device p+OFFSETS[k]'s block (zeros off-grid). The standalone probe
    of the exchange step 1 uses in serving — tests assert it resolves
    corners exactly like ``routing.halo_ids``."""
    if not grid_matches_mesh(grid, mesh, axes):
        raise ValueError(
            f"grid {grid.gx}x{grid.gy} must match mesh axes {tuple(axes)}"
        )
    shift = _make_shift(axes, grid.gx, grid.gy)

    def gather(x):
        x = x[0]
        out = [
            x if k == routing.SELF_SLOT else shift(x, dx, dy)
            for k, (dx, dy) in enumerate(routing.OFFSETS)
        ]
        return jnp.stack(out)[None]

    pspec = P(tuple(axes))
    return jax.jit(
        compat.shard_map(
            gather, mesh=mesh, in_specs=(pspec,), out_specs=pspec, check_vma=False
        )
    )


def make_sharded_blend(
    mesh: Mesh,
    axes: Sequence[str],
    grid: PartitionGrid,
    cov_fn: Callable,
    cache_like: posterior.PosteriorCache | None = None,
    *,
    use_pallas: bool = False,
):
    """Build the jitted shard_map serving program.

    Call signature of the returned function (leading P axis of every array
    sharded one partition per device):

      blend_fn(cache, xq, corner_slot, corner_w) -> (mean, var)

    with cache a P-stacked ``PosteriorCache``, xq (P, q_max, 2),
    corner_slot (P, q_max, 4) int32, corner_w (P, q_max, 4), and outputs
    (P, q_max) each — padded rows carry garbage (weight-0 blends) and are
    dropped by ``routing.scatter_results``. Math identical to
    ``routing.predict_routed`` and, through it, ``blend.predict_blended``.

    ``cache_like``: the cache that will be served (only its pytree
    STRUCTURE is read, to build the shard_map in_specs) — pass it whenever
    available so a future PosteriorCache field cannot desync the spec
    tree; defaults to the current field layout.
    """
    if not grid_matches_mesh(grid, mesh, axes):
        raise ValueError(
            f"grid {grid.gx}x{grid.gy} must match mesh axes {tuple(axes)} "
            f"{[mesh.shape[a] for a in axes]} (one partition per device)"
        )
    if grid.wrap_x:
        raise NotImplementedError("wrapped grids need ring perms for the halo")
    shift = _make_shift(axes, grid.gx, grid.gy)

    def step(cache, xq, corner_slot, corner_w):
        local = jax.tree.map(lambda a: a[0], cache)  # this device's factors
        x = xq[0]  # (q, d)
        q, d = x.shape
        # 1. halo in: slot k = queries owned by the device at offset k
        halo = [
            x if k == routing.SELF_SLOT else shift(x, dx, dy)
            for k, (dx, dy) in enumerate(routing.OFFSETS)
        ]
        hx = jnp.stack(halo)  # (9, q, d)
        # 2. one batched local evaluation of all nine blocks
        mean, var = posterior.predict_cached(
            local, cov_fn, hx.reshape(routing.NUM_HALO_SLOTS * q, d),
            use_pallas=use_pallas,
        )
        mean = mean.reshape(routing.NUM_HALO_SLOTS, q)
        var = var.reshape(routing.NUM_HALO_SLOTS, q)
        # 3. halo out: this device's evaluation of the slot-(8-k) block
        # travels along offset k, landing on the owner as "the model at
        # offset k from me evaluated my queries".
        res = []
        for k, (dx, dy) in enumerate(routing.OFFSETS):
            rk = routing.NUM_HALO_SLOTS - 1 - k  # reverse slot: -OFFSETS[k]
            payload = (mean[rk], var[rk])
            res.append(payload if k == routing.SELF_SLOT else shift(payload, dx, dy))
        res_mean = jnp.stack([m for m, _ in res])  # (9, q)
        res_var = jnp.stack([v for _, v in res])
        # 4. 4-corner bilinear blend on the owning device
        bmean, bvar = routing.blend_slots(res_mean, res_var, corner_slot[0], corner_w[0])
        return bmean[None], bvar[None]

    pspec = P(tuple(axes))
    if cache_like is not None:
        cache_specs = jax.tree.map(lambda _: pspec, cache_like)
    else:
        cache_specs = posterior.PosteriorCache(
            z=pspec, w=pspec, u=pspec, c=pspec,
            cov=CovarianceParams(log_lengthscale=pspec, log_variance=pspec),
            log_beta=pspec,
        )
    step_fn = compat.shard_map(
        step,
        mesh=mesh,
        in_specs=(cache_specs, pspec, pspec, pspec),
        out_specs=(pspec, pspec),
        check_vma=False,
    )
    return jax.jit(step_fn)


# --------------------------------------------------------------------------
# Serving driver
# --------------------------------------------------------------------------


def train_demo_surface(
    *, seed: int, n: int, grid_side: int, m: int, train_iters: int
):
    """The ONE training recipe every serving driver/benchmark demos against
    (``serve --gp``, ``serve --gp --sharded``, ``benchmarks.bench_serve``):
    a PSVGP with the paper-flavored delta=0.25 on the synthetic E3SM-like
    field. Keeping it shared is what makes the replicated-vs-sharded
    equivalence checks compare the SAME posterior.

    Returns (ds, grid, data, static, state).
    """
    from repro.core import psvgp, svgp
    from repro.core.partition import make_grid, partition_data
    from repro.data.spatial import e3sm_like_field

    ds = e3sm_like_field(n=n, seed=seed)
    grid = make_grid(ds.x, grid_side, grid_side)
    data = partition_data(ds.x, ds.y, grid)
    cfg = psvgp.PSVGPConfig(
        svgp=svgp.SVGPConfig(num_inducing=m, input_dim=2),
        delta=0.25, batch_size=32, learning_rate=0.05,
    )
    static = psvgp.build(cfg, data)
    state = psvgp.init(jax.random.PRNGKey(seed), cfg, data)
    t0 = time.time()
    state = psvgp.fit(static, state, data, train_iters)
    jax.block_until_ready(state.params)
    print(f"trained P={grid.num_partitions} partitions, m={m}, "
          f"{train_iters} iters in {time.time()-t0:.1f} s")
    return ds, grid, data, static, state


def serve_sharded(args) -> dict:
    """Train, shard the cache over the mesh, and run the routed query loop.

    Mirrors ``serve.serve_gp`` (same flags) but serves from the distributed
    cache; prints and returns the latency/throughput record, including an
    allclose check against the replicated path on the first batch.
    """
    ensure_host_devices(args.gp_grid * args.gp_grid)

    from repro.core import psvgp
    from repro.core.blend import predict_blended

    ds, grid, data, static, state = train_demo_surface(
        seed=args.seed, n=args.gp_n, grid_side=args.gp_grid,
        m=args.gp_m, train_iters=args.gp_train_iters,
    )
    cache = psvgp.posterior_cache(static, state)
    mesh = mesh_for_grid(grid)
    cache_sh = shard_cache(cache, mesh)
    jax.block_until_ready(cache_sh)
    total_b, device_b = cache_memory_bytes(cache_sh)
    print(f"cache sharded over {mesh.size} devices: {total_b/1e6:.2f} MB total, "
          f"{device_b/1e3:.1f} kB/device (1/{total_b // max(device_b,1)} of replicated)")

    use_pallas = jax.default_backend() == "tpu"
    blend_fn = make_sharded_blend(
        mesh, mesh.axis_names, grid, static.cov_fn, cache_sh, use_pallas=use_pallas
    )

    rng = np.random.default_rng(args.seed + 1)
    lo, hi = ds.x.min(axis=0), ds.x.max(axis=0)
    B = args.gp_batch
    batches = [
        rng.uniform(lo, hi, (B, 2)).astype(np.float32)
        for _ in range(args.gp_requests)
    ]
    # one fixed q_max across the request stream = one compile
    q_max = fixed_q_max(grid, batches)

    def answer(q):
        table = routing.build_routing_table(grid, q, q_max=q_max)
        xq, cs, cw = shard_table(table, mesh)
        mean, var = blend_fn(cache_sh, xq, cs, cw)
        jax.block_until_ready((mean, var))
        return table, np.asarray(mean), np.asarray(var)

    # warmup + equivalence check against the replicated path
    table0, m0, v0 = answer(batches[0])
    m_rep, v_rep = predict_blended(static, state, grid, jnp.asarray(batches[0]))
    mean_err = float(np.abs(routing.scatter_results(table0, m0) - np.asarray(m_rep)).max())
    var_err = float(np.abs(routing.scatter_results(table0, v0) - np.asarray(v_rep)).max())
    print(f"sharded vs replicated on warmup batch: max|dmean|={mean_err:.2e} "
          f"max|dvar|={var_err:.2e}")

    def full_answer(q):
        table, mean, var = answer(q)
        return routing.scatter_results(table, mean), routing.scatter_results(table, var)

    # already warmed: the equivalence check above compiled and ran batch 0
    pct, qps = timed_request_loop(full_answer, batches, warm=False)
    rec = {
        "mesh": f"{grid.gy}x{grid.gx}",
        "devices": mesh.size,
        "q_max": q_max,
        "latency_ms": pct,
        "points_per_s": qps,
        "mean_err_vs_replicated": mean_err,
        "var_err_vs_replicated": var_err,
        "cache_bytes_total": total_b,
        "cache_bytes_per_device": device_b,
    }
    print(f"served {args.gp_requests} requests x {B} points")
    print(f"latency/request ms: p50={pct['p50_ms']:.2f} "
          f"p95={pct['p95_ms']:.2f} p99={pct['p99_ms']:.2f}")
    print(f"throughput: {qps:,.0f} points/s")
    return rec


def timed_request_loop(answer: Callable, batches, *, warm: bool = True) -> Tuple[dict, float]:
    """The ONE serving measurement loop (shared by ``serve --gp``,
    ``serve --gp --sharded`` and ``benchmarks.bench_serve``, so their SLO
    reports stay comparable): warm up on batches[0] (compile), then time
    each request end to end. Pass ``warm=False`` when the caller already
    ran a batch through ``answer`` (e.g. for an equivalence check) — the
    program is compiled and a second warmup pass would just burn a
    request's worth of wall clock.

    Returns ({p50_ms, p95_ms, p99_ms}, points_per_s).
    """
    if warm:
        answer(batches[0])
    lat = []
    t_all = time.time()
    for q in batches:
        t0 = time.time()
        answer(q)
        lat.append(time.time() - t0)
    wall = time.time() - t_all
    ms = np.sort(np.asarray(lat)) * 1e3
    pct = {
        "p50_ms": float(np.percentile(ms, 50)),
        "p95_ms": float(np.percentile(ms, 95)),
        "p99_ms": float(np.percentile(ms, 99)),
    }
    return pct, sum(len(q) for q in batches) / wall


def fixed_q_max(
    grid: PartitionGrid, batches, *, headroom: float = 1.25, pad_multiple: int = 8
) -> int:
    """One q_max covering every batch in a request stream (single compile):
    the observed max bucket count with headroom, rounded up with the SAME
    alignment rule ``routing.build_routing_table`` applies (pass the same
    ``pad_multiple`` to both, or the table re-rounds and recompiles)."""
    need = 1
    for q in batches:
        ix, iy = routing.owning_cells(grid, np.asarray(q, np.float32))
        c = np.bincount(iy * grid.gx + ix, minlength=grid.num_partitions)
        need = max(need, int(c.max()))
    return routing.ceil_to(int(np.ceil(need * headroom)), pad_multiple)


def cache_memory_bytes(cache: posterior.PosteriorCache) -> Tuple[int, int]:
    """(total, per-device-addressable) bytes of the cache factor leaves."""
    total = sum(leaf.nbytes for leaf in jax.tree.leaves(cache))
    per_dev = 0
    for leaf in jax.tree.leaves(cache):
        shards = getattr(leaf, "addressable_shards", None)
        if shards:
            per_dev += shards[0].data.nbytes
        else:
            per_dev += leaf.nbytes
    return total, per_dev


def add_gp_args(ap: argparse.ArgumentParser) -> None:
    """The --gp-* serving flags, shared with ``repro.launch.serve`` (which
    defines --seed itself for the LM path, so it is added separately)."""
    ap.add_argument("--gp-n", type=int, default=20_000, help="training observations")
    ap.add_argument("--gp-grid", type=int, default=8, help="partition grid is gp-grid^2")
    ap.add_argument("--gp-m", type=int, default=10, help="inducing points per partition")
    ap.add_argument("--gp-train-iters", type=int, default=200)
    ap.add_argument("--gp-batch", type=int, default=2048, help="query points per request")
    ap.add_argument("--gp-requests", type=int, default=50)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=0)
    add_gp_args(ap)
    args = ap.parse_args()
    if args.gp_requests < 1 or args.gp_batch < 1:
        ap.error("--gp-requests and --gp-batch must be >= 1")
    serve_sharded(args)


if __name__ == "__main__":
    main()
