"""Sharded multi-host GP serving — the distributed PosteriorCache endpoint.

Replicated serving (``repro.launch.serve --gp``) answers every query from
one host holding ALL P partitions' cached factors. This module completes
the paper's story at serving time: the ``PosteriorCache`` is sharded one
partition per device over the mesh (per-device factor memory = 1/P of
replicated), queries are routed to their owning partition by
``repro.core.routing``, and the 4-corner blend is resolved with a 1-hop
``ppermute`` halo exchange — exactly the training-time communication
pattern of ``repro.core.psvgp_spmd``, and NO all-gather of factors
anywhere.

Per request (the overlapped pipeline; serial mode runs the same stages
back-to-back):

  HOST, overlapped with the mesh evaluating the PREVIOUS request:
  1. route the batch (``routing.build_routing_table``; q_max follows the
     streaming high-water-mark policy ``routing.StreamingQMax``, or its
     two-level variant ``routing.TwoLevelQMax`` — ``--gp-router
     two-level`` — which spills hot-cell overflow onto corner-cell
     neighbors so skewed streams stop padding every device to the
     hottest cell) and stack each device's full 9-slot halo of query
     blocks (``routing.make_halo_stacker``) — queries are host data, so
     the halo ingest rides the dispatch-time host->device transfer and
     costs zero mesh collectives,

  DEVICE (``make_sharded_blend``):
  2. evaluate the LOCAL cached posterior on all 9 stacked blocks at once —
     ``posterior.predict_cached_slots``; with ``use_pallas`` that is ONE
     fused Pallas launch whose grid spans (9 slots x q-blocks) with the
     W/U/c factors resident in VMEM across the whole grid,
  3. return each result block to the query's owner over the COMPOSED
     1-hop reverse halo: a row exchange then a column exchange move all
     8 neighbor results in 4 ppermutes total (diagonals ride the
     composition; the PR-2 program paid 12 query hops out + 24 result
     hops back),
  4. blend the 4 corner evaluations per query on the owning device
     (``routing.blend_slots``),

  HOST:
  5. only when the result is CONSUMED, block on the device values and
     scatter them back to request order (``routing.scatter_results``) —
     jax's async dispatch keeps step 1 of batch t+1 running while the
     mesh is inside steps 2-4 of batch t (``pipelined_request_loop``).

Communication per request per device: 4 nearest-neighbor collectives
carrying 8 result pairs — O(q_max) floats, independent of P. The factors,
like the variational parameters during training, never move.

The CLI at the bottom is a thin shim over ``repro.api``: the flags parse
into a ``FitConfig``/``ServeConfig`` and ``api.Server`` composes the
stages defined here (this module remains the sharded-serving ENGINE —
mesh construction, the shard_map blend program, the request stages and
the serial/pipelined loops). ``--gp-save``/``--gp-artifact`` persist and
reuse the trained artifact (``api.FittedPSVGP``).

Usage (CPU dry-run; the grid is mapped one-partition-per-device onto
gy x gx virtual host devices):

  PYTHONPATH=src python -m repro.launch.serve_sharded \
      --gp-grid 8 --gp-m 10 --gp-train-iters 200 \
      --gp-batch 2048 --gp-requests 50

or equivalently through the main serving driver:

  PYTHONPATH=src python -m repro.launch.serve --gp --sharded --gp-grid 8
"""
from __future__ import annotations

import argparse
import os
import time
from collections.abc import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.analysis.contracts import contract
from repro.core import posterior, routing
from repro.core.partition import PartitionGrid
from repro.core.psvgp_spmd import grid_matches_mesh, shift_perm
from repro.runtime import compat
from repro.sharding import gp_stacked_pspecs


def ensure_host_devices(n: int) -> None:
    """Force >= n virtual host devices (must run before jax backend init).

    The host-device-count flag is written into XLA_FLAGS unconditionally
    (we cannot count devices without initializing the backend, and after
    init it is too late to set it) — on a real TPU slice the flag is inert
    for this process but IS inherited by child processes that run
    CPU-backed jax. An already-present but too-small count is rewritten
    upward (it only binds at backend init, so rewriting is still effective
    here). Raises with guidance if the backend initialized too early for
    the flag to take effect.
    """
    import re

    flags = os.environ.get("XLA_FLAGS", "")
    flag_re = r"--xla_force_host_platform_device_count=(\d+)"
    m = re.search(flag_re, flags)
    if m is None:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n}"
        ).strip()
    elif int(m.group(1)) < n:
        os.environ["XLA_FLAGS"] = re.sub(
            flag_re, f"--xla_force_host_platform_device_count={n}", flags
        )
    if jax.device_count() < n:
        raise RuntimeError(
            f"need {n} devices for one-partition-per-device serving, have "
            f"{jax.device_count()}. Set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n} before jax "
            "initializes (import order matters), or shrink --gp-grid."
        )


def mesh_for_grid(grid: PartitionGrid) -> Mesh:
    """(gy, gx) device mesh matching the partition grid, axes (data, model)
    — the serving analogue of the training mapping in
    ``repro.core.psvgp_spmd`` (grid x-steps shift along ``model``, y-steps
    along ``data``)."""
    return compat.make_mesh((grid.gy, grid.gx), ("data", "model"))


def shard_cache(
    cache: posterior.PosteriorCache, mesh: Mesh
) -> posterior.PosteriorCache:
    """Place the P-stacked cache one partition per device (leading axis
    over all mesh axes via ``sharding.gp_stacked_pspecs``)."""
    specs = gp_stacked_pspecs(cache, mesh)
    return jax.tree.map(
        lambda leaf, spec: jax.device_put(leaf, NamedSharding(mesh, spec)),
        cache, specs,
    )


def _make_shift(axes: Sequence[str], gx: int, gy: int) -> Callable:
    """Build ``shift(tree, dx, dy)`` usable INSIDE a shard_map over ``axes``:
    every device receives the payload of the device at grid offset
    (dx, dy), zeros where that neighbor is off-grid (ppermute's edge
    semantics — routing guarantees off-grid slots are never blended).
    Diagonal offsets compose an x-hop and a y-hop; both are 1-hop
    nearest-neighbor collectives on the ICI torus, exactly like the
    training exchange in ``repro.core.psvgp_spmd``."""
    col_axis = axes[-1]
    row_axes = tuple(axes[:-1])
    row_ax = row_axes if len(row_axes) > 1 else row_axes[0]

    def shift(tree, dx: int, dy: int):
        def sh(a):
            if dx:
                a = jax.lax.ppermute(a, col_axis, shift_perm(gx, up=(dx > 0)))
            if dy:
                a = jax.lax.ppermute(a, row_ax, shift_perm(gy, up=(dy > 0)))
            return a

        return jax.tree.map(sh, tree)

    return shift


def make_halo_gather(mesh: Mesh, axes: Sequence[str], grid: PartitionGrid):
    """Jitted (P, ...) -> (P, 9, ...) halo gather: output slot k on device p
    is device p+OFFSETS[k]'s block (zeros off-grid). The standalone probe of
    the ``shift`` semantics the serving program's reverse halo composes —
    tests assert it resolves corners exactly like ``routing.halo_ids``, and
    that the host-side ``routing.make_halo_stacker`` reproduces it."""
    if not grid_matches_mesh(grid, mesh, axes):
        raise ValueError(
            f"grid {grid.gx}x{grid.gy} must match mesh axes {tuple(axes)}"
        )
    shift = _make_shift(axes, grid.gx, grid.gy)

    def gather(x):
        x = x[0]
        out = [
            x if k == routing.SELF_SLOT else shift(x, dx, dy)
            for k, (dx, dy) in enumerate(routing.OFFSETS)
        ]
        return jnp.stack(out)[None]

    pspec = P(tuple(axes))
    return jax.jit(
        compat.shard_map(
            gather, mesh=mesh, in_specs=(pspec,), out_specs=pspec, check_vma=False
        )
    )


def cache_in_specs(cache_like, pspec) -> posterior.PosteriorCache:
    """shard_map in_specs for a P-stacked cache: every leaf carries
    ``pspec`` on its leading partition axis, DERIVED from the pytree
    structure of the cache actually served. Deriving (rather than
    hand-building a spec literal field by field) means a future
    ``PosteriorCache`` field can never desync the spec tree from the
    value tree — the exact hazard the old literal carried."""
    return jax.tree.map(lambda _: pspec, cache_like)


@contract(
    args={
        "hx": "(P, 9, Q, 2)",
        "corner_slot": "(P, Q, 4)",
        "corner_w": "(P, Q, 4)",
    },
    returns=("(P, Q)", "(P, Q)"),
    invariants=("outputs-f32",),
)
def make_sharded_blend(
    mesh: Mesh,
    axes: Sequence[str],
    grid: PartitionGrid,
    cov_fn: Callable,
    cache_like: posterior.PosteriorCache,
    *,
    use_pallas: bool = False,
    backend: str | None = None,
):
    """Build the jitted shard_map serving program.

    Call signature of the returned function (leading P axis of every array
    sharded one partition per device):

      blend_fn(cache, hx, corner_slot, corner_w) -> (mean, var)

    with cache a P-stacked ``PosteriorCache``, hx (P, 9, q_max, 2) the
    HOST-STACKED halo query blocks (``routing.make_halo_stacker``:
    hx[p, k] = partition p+OFFSETS[k]'s block, zeros off-grid), corner_slot
    (P, q_max, 4) int32, corner_w (P, q_max, 4), and outputs (P, q_max)
    each — padded rows carry garbage (weight-0 blends) and are dropped by
    ``routing.scatter_results``. Math identical to
    ``routing.predict_routed`` and, through it, ``blend.predict_blended``.

    The device program evaluates the local model on all 9 slots at once
    (``posterior.predict_cached_slots`` with the chosen kernel ``backend``
    — "ref" jnp, "pallas" single-block kernel via reshape, "fused" one
    slot-stacked launch; the legacy ``use_pallas`` bool maps True ->
    "fused". Pallas lanes compile to Mosaic on TPU only and are validated
    RBF-only) and returns the results
    over the COMPOSED reverse halo: slot k's evaluation must travel to the
    owner at offset OFFSETS[k], and because a diagonal hop is an x-hop
    then a y-hop, the whole 3x3 neighborhood moves in 4 ppermutes — one
    row exchange (x-+, x+) of the slot-flipped results, one column
    exchange (y-, y+) of the row-exchanged triples.

    ``cache_like``: the cache that will be served; only its pytree
    STRUCTURE is read (``cache_in_specs``) to build the shard_map
    in_specs, so the spec tree can never desync from the cache layout.
    """
    if not grid_matches_mesh(grid, mesh, axes):
        raise ValueError(
            f"grid {grid.gx}x{grid.gy} must match mesh axes {tuple(axes)} "
            f"{[mesh.shape[a] for a in axes]} (one partition per device)"
        )
    if grid.wrap_x:
        raise NotImplementedError("wrapped grids need ring perms for the halo")
    backend = posterior.resolve_slot_backend(use_pallas, backend)
    if backend != "ref":
        from repro.kernels import ops as kops

        kops.require_rbf(cov_fn)  # fail at build time, not trace time
    shift = _make_shift(axes, grid.gx, grid.gy)
    S = routing.NUM_HALO_SLOTS

    def step(cache, hx, corner_slot, corner_w):
        local = jax.tree.map(lambda a: a[0], cache)  # this device's factors
        h = hx[0]  # (9, q, d): slot k = queries owned by the device at offset k
        q = h.shape[1]
        # 1. one slot-stacked local evaluation of all nine blocks
        mean, var = posterior.predict_cached_slots(
            local, cov_fn, h, backend=backend
        )
        ev = jnp.stack([mean, var], axis=1)  # (9, 2, q): one halo payload
        # 2. composed reverse halo. The owner at offset OFFSETS[k] needs MY
        # evaluation of its queries, which sits in my slot 8-k; flipping
        # the slot axis puts "what must travel along offset (dx, dy)" at
        # halo position (dy+1, dx+1):
        f = ev[::-1].reshape(3, 3, 2, q)  # f[dy+1, dx+1] travels along (dx, dy)
        # row exchange: every column of the flipped stack moves its x-hop
        g = jnp.stack(
            [shift(f[:, 0], -1, 0), f[:, 1], shift(f[:, 2], 1, 0)], axis=1
        )
        # column exchange: row-exchanged triples move their y-hop
        res = jnp.concatenate(
            [shift(g[0], 0, -1)[None], g[1][None], shift(g[2], 0, 1)[None]]
        ).reshape(S, 2, q)  # res[k] = model at offset k's evaluation of MY queries
        # 3. 4-corner bilinear blend on the owning device
        bmean, bvar = routing.blend_slots(
            res[:, 0], res[:, 1], corner_slot[0], corner_w[0]
        )
        return bmean[None], bvar[None]

    pspec = P(tuple(axes))
    step_fn = compat.shard_map(
        step,
        mesh=mesh,
        in_specs=(cache_in_specs(cache_like, pspec), pspec, pspec, pspec),
        out_specs=(pspec, pspec),
        check_vma=False,
    )
    return jax.jit(step_fn)


# --------------------------------------------------------------------------
# Serving driver
# --------------------------------------------------------------------------


def train_demo_surface(
    *, seed: int, n: int, grid_side: int, m: int, train_iters: int,
    fit_cfg=None,
):
    """The ONE training recipe every serving driver/benchmark demos against
    (``serve --gp``, ``serve --gp --sharded``, ``benchmarks.bench_serve``):
    a PSVGP with the paper-flavored delta=0.25 on the synthetic E3SM-like
    field, trained through ``repro.api.fit``. Keeping it shared is what
    makes the replicated-vs-sharded equivalence checks compare the SAME
    posterior.

    Returns (ds, fitted) — the dataset (for query-domain bounds) and the
    ``repro.api.FittedPSVGP`` serving bundle. An explicit ``fit_cfg`` (the
    ``--config session.json`` lane) replaces the flag-derived FitConfig
    wholesale; the dataset size ``n`` stays a CLI concern either way.
    """
    from repro import api
    from repro.data.spatial import e3sm_like_field

    if fit_cfg is None:
        fit_cfg = api.FitConfig(
            grid=grid_side, m=m, train_iters=train_iters, seed=seed
        )
    ds = e3sm_like_field(n=n, seed=fit_cfg.seed)
    fitted = api.fit(fit_cfg, ds, verbose=True)
    return ds, fitted


@contract(
    route={
        "xq": "(P, Q, D)",
        "stacked": "(P, 9, Q, D)",
        "corner_slot": "(P, Q, 4)",
        "corner_w": "(P, Q, 4)",
    },
    invariants=("q_max-matches-policy", "q_max-aligned"),
)
def make_request_stages(
    grid: PartitionGrid,
    blend_fn: Callable,
    cache_sh: posterior.PosteriorCache,
    *,
    policy: routing.StreamingQMax | None = None,
    q_max: int | None = None,
    pad_multiple: int | None = None,
):
    """Split a request into the three pipeline stages the overlapped driver
    schedules (and the serial driver runs back-to-back):

      route(q)        HOST, pure numpy: bin the batch once
                      (``owning_cells``), fit q_max (streaming policy or
                      the fixed prepass value), build the table REUSING
                      the binning, halo-stack the blocks. Returns
                      (table, blocks). Deliberately NO device_put here: a
                      put targets the same devices the PREVIOUS request is
                      still executing on and serializes behind it, which
                      would stall the overlapped pipeline for a full
                      device window — the transfer happens at dispatch
                      time inside ``submit`` instead.
      submit(routed)  DEVICE: dispatch the shard_map program (host->device
                      transfer + async dispatch) — returns without waiting
                      for the result.
      collect(pending) HOST: block on the device values and scatter them
                      back to request order. The ONLY sync point.

    Exactly one of ``policy`` (live stream) / ``q_max`` (whole-stream
    prepass, ``fixed_q_max``) must be given. ``pad_multiple`` is the
    block-size alignment ``build_routing_table`` applies; it defaults to
    the POLICY's own alignment (so the policy's q_max high-water mark is
    never re-rounded — its compile/overflow counters always describe the
    block shapes actually compiled), or to the table default of 8 in the
    fixed-q_max lane. A
    :class:`routing.TwoLevelQMax` policy routes TWO-LEVEL: hot-cell
    overflow beyond the (post-spill) q_max budget is re-hosted on the
    queries' corner-cell neighbors, so a skewed stream no longer pads
    every device to the hottest cell's peak. The device program is the
    SAME either way — spill rows carry host-relative corner slots like
    any other row — so switching routers never recompiles per se; only
    the q_max trajectory differs. Route stays pure numpy in both modes.
    """
    if (policy is None) == (q_max is None):
        raise ValueError("pass exactly one of policy= (streaming) or q_max= (fixed)")
    if pad_multiple is None:
        pad_multiple = policy.pad_multiple if policy is not None else 8
    stacker = routing.make_halo_stacker(grid)
    two_level = isinstance(policy, routing.TwoLevelQMax)
    if two_level:
        from repro.core.blend import corner_ids_weights

    def route(q):
        pts = np.asarray(q, np.float32)
        cells = routing.owning_cells(grid, pts)
        if two_level:
            own = cells[1] * grid.gx + cells[0]
            corners = corner_ids_weights(grid, pts)
            qm, hosts = policy.fit_spill(grid, own, corners[0])
            table = routing.build_routing_table(
                grid, pts, q_max=qm, cells=cells, corners=corners,
                spill=True, hosts=hosts, pad_multiple=pad_multiple,
            )
        elif policy is not None:
            counts = np.bincount(
                cells[1] * grid.gx + cells[0], minlength=grid.num_partitions
            )
            qm = policy.fit(counts)
            table = routing.build_routing_table(
                grid, pts, q_max=qm, cells=cells, pad_multiple=pad_multiple
            )
        else:
            table = routing.build_routing_table(
                grid, pts, q_max=q_max, cells=cells, pad_multiple=pad_multiple
            )
        return table, (stacker(table.xq), table.corner_slot, table.corner_w)

    def submit(routed):
        table, (hx, cs, cw) = routed
        mean, var = blend_fn(cache_sh, hx, cs, cw)  # transfer + async dispatch
        return table, mean, var

    def collect(pending):
        table, mean, var = pending
        jax.block_until_ready((mean, var))
        return (
            routing.scatter_results(table, np.asarray(mean)),
            routing.scatter_results(table, np.asarray(var)),
        )

    return route, submit, collect


def as_batch_source(batches):
    """Normalize a batch SOURCE into an iterator of query batches.

    The pipelined loop used to demand a pre-built list — fine for
    benchmarks, useless for an endpoint whose batches are formed by live
    coalescing. Accepted shapes:

      * a sequence (list/tuple) — the original contract, replayed as-is;
      * an iterator/generator — consumed once (a live batcher can yield
        batches as its admission window closes);
      * a zero-arg callable — polled per batch; returning None ends the
        stream (the pull-model injection seam: the loop asks for the next
        batch exactly when it has host time to route it).
    """
    if callable(batches):
        def pull():
            while (b := batches()) is not None:
                yield b

        return pull()
    return iter(batches)


def pipelined_request_loop(
    route: Callable,
    submit: Callable,
    collect: Callable,
    batches,
    *,
    warm: bool = True,
    on_result: Callable | None = None,
) -> tuple[dict, float]:
    """The overlapped serving measurement loop (double-buffered).

    Batch t is submitted to the mesh, then batch t+1 is ROUTED ON THE HOST
    while the device program runs — jax's async dispatch means ``submit``
    returns without waiting for the result and the block happens only in
    ``collect``, when the result is consumed. Results are bitwise
    identical to the serial loop — scheduling never touches the math.

    ``batches`` is any :func:`as_batch_source` shape — a pre-built
    sequence (the benchmark lanes), or an INJECTABLE source (iterator /
    generator / zero-arg callable) whose batches may be formed while the
    loop runs; the next batch is pulled exactly at the overlap point,
    while the mesh evaluates the current one. ``warm=True`` needs a
    replayable first batch: it runs the stream's first batch once for
    compile+transfer warmup and then serves it again as batch 0 (the
    sequence semantics the benchmarks rely on).

    Per-request latency is the request's completion-to-completion SERVICE
    interval: the wall time the pipeline spends on it once it reaches the
    head of the queue (dispatch + device evaluation + result scatter).
    Host routing does not appear in it — that is the point of the
    overlap: it ran during the previous request's device window. The
    serial loop (:func:`timed_request_loop`) pays route + dispatch +
    device + scatter per request; the pipelined steady state pays
    max(route, device-window) per request.

    ``on_result(i, (mean, var))`` receives each scattered result (tests
    and the benchmark equivalence gate use it).

    Returns ({p50_ms, p95_ms, p99_ms}, points_per_s).
    """
    src = as_batch_source(batches)
    try:
        first = next(src)
    except StopIteration:
        raise ValueError("pipelined_request_loop needs a non-empty batch source") from None
    if warm:
        collect(submit(route(first)))
    lat = []
    points = 0
    t_all = time.time()
    nxt, nxt_points = route(first), len(first)
    mark = time.time()  # pipeline idle: batch 0's service starts here
    i = 0
    while nxt is not None:
        pending = submit(nxt)  # transfer + async dispatch: mesh starts batch i
        points += nxt_points
        b = next(src, None)
        if b is not None:
            nxt, nxt_points = route(b), len(b)  # host routes i+1 under batch i
        else:
            nxt = None
        out = collect(pending)  # sync point: batch i consumed
        if on_result is not None:
            on_result(i, out)
        now = time.time()
        lat.append(now - mark)
        mark = now
        i += 1
    wall = time.time() - t_all
    ms = np.sort(np.asarray(lat)) * 1e3
    pct = {
        "p50_ms": float(np.percentile(ms, 50)),
        "p95_ms": float(np.percentile(ms, 95)),
        "p99_ms": float(np.percentile(ms, 99)),
    }
    return pct, points / wall


def load_or_train(args, *, ensure_devices: bool = False, fit_cfg=None):
    """The shared fit-or-load front of both GP serving CLIs: returns
    (ds, fitted) where ds is None when serving from a persisted artifact
    (``--gp-artifact``; no retraining on that path). ``--gp-save``
    persists the freshly trained artifact. ``ensure_devices`` (the
    sharded caller) forces one virtual device per artifact partition and
    MUST then run before any other jax work — the artifact's grid side is
    peeked from pure JSON so the count can be forced first. ``fit_cfg``
    (a session file's fit section) replaces the flag-derived training
    config on the training path.
    """
    from repro import api

    if getattr(args, "gp_artifact", None):
        if ensure_devices:
            ensure_host_devices(api.peek_fit_config(args.gp_artifact).num_partitions)
        fitted = api.FittedPSVGP.load(args.gp_artifact)
        print(f"loaded artifact {args.gp_artifact}: grid="
              f"{fitted.grid.gx}x{fitted.grid.gy}, m={fitted.config.m} "
              "(serving without retraining)")
        ds = None
    else:
        ds, fitted = train_demo_surface(
            seed=args.seed, n=args.gp_n, grid_side=args.gp_grid,
            m=args.gp_m, train_iters=args.gp_train_iters, fit_cfg=fit_cfg,
        )
    if getattr(args, "gp_save", None):
        fitted.save(args.gp_save)
        print(f"artifact saved to {args.gp_save}")
    return ds, fitted


def query_batches(
    grid: PartitionGrid, ds=None, *, batch: int, requests: int,
    seed: int = 0, skew: float = 0.0,
) -> list:
    """The demo query stream the GP serving CLIs draw: zipf-skewed over
    cells when ``skew`` > 0 (the ``--gp-skew`` exponent), else uniform
    over the data domain (``ds``) or the grid bounds (``ds=None`` — the
    artifact-serving case, where no dataset exists). Plain parameters, so
    non-CLI callers can reuse it without fabricating an argparse
    namespace."""
    if skew > 0:
        from repro.data.spatial import zipf_query_stream

        return zipf_query_stream(grid, batch, requests, alpha=skew, seed=seed + 1)
    rng = np.random.default_rng(seed + 1)
    if ds is not None:
        lo, hi = ds.x.min(axis=0), ds.x.max(axis=0)
    else:
        lo = np.array([grid.x_edges[0], grid.y_edges[0]], np.float32)
        hi = np.array([grid.x_edges[-1], grid.y_edges[-1]], np.float32)
    return [
        rng.uniform(lo, hi, (batch, 2)).astype(np.float32)
        for _ in range(requests)
    ]


def session_configs(args, *, expect_mode: str):
    """The ``--config session.json`` lane shared by the serving CLIs:
    returns (fit_cfg, serve_cfg, net_cfg) — (None, None, None) without
    the flag. Loading is pure JSON (``api.load_session`` is
    stdlib-only), so the sharded caller can still force virtual devices
    afterwards (and the HTTP caller can read the bind address before
    jax initializes). A serve section whose mode contradicts the
    running entry point is an error, not a silent reroute — and so is
    ``--http`` against a session file with no ``net`` section: a
    recorded session must say where it binds, or the replay is not the
    session."""
    if not getattr(args, "config", None):
        return None, None, None
    from repro.api.config import load_session

    fit_cfg, serve_cfg, net_cfg = load_session(args.config)
    if serve_cfg is not None and serve_cfg.mode != expect_mode:
        raise SystemExit(
            f"--config {args.config}: serve section has mode="
            f"{serve_cfg.mode!r} but this entry point serves "
            f"{expect_mode!r} (pick the matching CLI or fix the session)"
        )
    if getattr(args, "http", False) and net_cfg is None:
        raise SystemExit(
            f"--http with --config {args.config}: the session file has no "
            "'net' section (host/port/max_body_bytes/read_timeout_s/"
            "keepalive — api.NetConfig). Add one, or drop --http to serve "
            "the in-process demo stream."
        )
    return fit_cfg, serve_cfg, net_cfg


def serve_sharded(args) -> dict:
    """Fit (or load) through ``repro.api`` and serve the routed query loop
    from the mesh-sharded cache — this CLI is a thin shim: flags parse
    into a ``ServeConfig`` and ``api.Server`` does the wiring.

    Mirrors ``serve.serve_gp`` (same flags) but serves from the
    distributed cache through the overlapped pipeline (``--gp-serial``
    falls back to the synchronous loop); prints and returns the
    latency/throughput record, including an allclose check against the
    replicated path on the first batch and the streaming-q_max policy
    counters.
    """
    fit_cfg, serve_cfg, _ = session_configs(args, expect_mode="sharded")
    if not getattr(args, "gp_artifact", None):
        grid_side = fit_cfg.grid if fit_cfg is not None else args.gp_grid
        ensure_host_devices(grid_side * grid_side)
    # (the artifact path sizes the device count from the artifact's own
    # grid — load_or_train peeks it from pure JSON before any jax work)

    from repro import api

    ds, fitted = load_or_train(args, ensure_devices=True, fit_cfg=fit_cfg)
    grid = fitted.grid
    if serve_cfg is None:
        serve_cfg = api.ServeConfig(
            mode="sharded",
            pipeline="serial" if getattr(args, "gp_serial", False) else "pipelined",
            router=getattr(args, "gp_router", "single"),
            backend="auto",
        )
    server = api.Server(fitted, serve_cfg)
    total_b, device_b = server.cache_bytes
    print(f"cache sharded over {server.mesh.size} devices: {total_b/1e6:.2f} MB total, "
          f"{device_b/1e3:.1f} kB/device (1/{total_b // max(device_b,1)} of replicated)")

    skew = getattr(args, "gp_skew", 0.0)
    batches = query_batches(
        grid, ds, batch=args.gp_batch, requests=args.gp_requests,
        seed=args.seed, skew=skew,
    )

    # warmup + equivalence check against the replicated path
    m0, v0 = server.submit(batches[0])
    m_rep, v_rep = fitted.predict(jnp.asarray(batches[0]))
    mean_err = float(np.abs(m0 - np.asarray(m_rep)).max())
    var_err = float(np.abs(v0 - np.asarray(v_rep)).max())
    print(f"sharded vs replicated on warmup batch: max|dmean|={mean_err:.2e} "
          f"max|dvar|={var_err:.2e}")

    # already warmed: the equivalence check above compiled and ran batch 0
    report = server.stream(batches, warm=False)
    pct, qps = report["latency_ms"], report["points_per_s"]
    policy = server.policy
    rec = {
        "mesh": f"{grid.gy}x{grid.gx}",
        "devices": server.mesh.size,
        "mode": serve_cfg.pipeline,
        "router": serve_cfg.router,
        "backend": server.backend,
        "serve_config": serve_cfg.to_dict(),
        "skew_alpha": skew,
        "qmax_policy": policy.stats(),
        "waste_rows_last_batch": server.mesh.size * policy.q_max - args.gp_batch,
        "latency_ms": pct,
        "points_per_s": qps,
        "mean_err_vs_replicated": mean_err,
        "var_err_vs_replicated": var_err,
        "cache_bytes_total": total_b,
        "cache_bytes_per_device": device_b,
    }
    print(f"served {args.gp_requests} requests x {args.gp_batch} points "
          f"({rec['mode']}; q_max={policy.q_max}, "
          f"{policy.compiles} compiles, {policy.overflows} overflows)")
    print(f"latency/request ms: p50={pct['p50_ms']:.2f} "
          f"p95={pct['p95_ms']:.2f} p99={pct['p99_ms']:.2f}")
    print(f"throughput: {qps:,.0f} points/s")
    return rec


def timed_request_loop(answer: Callable, batches, *, warm: bool = True) -> tuple[dict, float]:
    """The SERIAL serving measurement loop (shared by ``serve --gp``, the
    ``--gp-serial`` sharded mode and ``benchmarks.bench_serve``'s
    replicated + serial lanes, so their SLO reports stay comparable; the
    overlapped counterpart is :func:`pipelined_request_loop`): warm up on
    batches[0] (compile), then time
    each request end to end. Pass ``warm=False`` when the caller already
    ran a batch through ``answer`` (e.g. for an equivalence check) — the
    program is compiled and a second warmup pass would just burn a
    request's worth of wall clock.

    Returns ({p50_ms, p95_ms, p99_ms}, points_per_s).
    """
    if warm:
        answer(batches[0])
    lat = []
    t_all = time.time()
    for q in batches:
        t0 = time.time()
        answer(q)
        lat.append(time.time() - t0)
    wall = time.time() - t_all
    ms = np.sort(np.asarray(lat)) * 1e3
    pct = {
        "p50_ms": float(np.percentile(ms, 50)),
        "p95_ms": float(np.percentile(ms, 95)),
        "p99_ms": float(np.percentile(ms, 99)),
    }
    return pct, sum(len(q) for q in batches) / wall


def prepass_routing(
    grid: PartitionGrid, batches, *, headroom: float = 1.25, pad_multiple: int = 8
) -> tuple[int, list]:
    """Whole-stream q_max prepass, for streams known up front (benchmarks,
    batch jobs): one q_max covering every batch = single compile, the
    observed max bucket count with headroom, rounded with the SAME
    alignment rule ``routing.build_routing_table`` applies (pass the same
    ``pad_multiple`` to both, or the table re-rounds and recompiles).

    Returns (q_max, cells) where ``cells[i]`` is ``owning_cells`` for
    ``batches[i]`` — pass it into ``build_routing_table(..., cells=...)``
    so the binning this prepass already did is not repeated per request
    (it used to be: the prepass binned every batch, threw the result away,
    and the table re-binned on the serving critical path). Live streams
    should use ``routing.StreamingQMax`` instead — this prepass cannot see
    batches that have not arrived yet.
    """
    need, cells = 1, []
    for q in batches:
        ix, iy = routing.owning_cells(grid, np.asarray(q, np.float32))
        cells.append((ix, iy))
        c = np.bincount(iy * grid.gx + ix, minlength=grid.num_partitions)
        need = max(need, int(c.max()))
    return routing.ceil_to(int(np.ceil(need * headroom)), pad_multiple), cells


def fixed_q_max(
    grid: PartitionGrid, batches, *, headroom: float = 1.25, pad_multiple: int = 8
) -> int:
    """``prepass_routing`` when only the q_max is wanted (the cells are
    discarded — callers on the serving path should take both)."""
    return prepass_routing(
        grid, batches, headroom=headroom, pad_multiple=pad_multiple
    )[0]


def cache_memory_bytes(cache: posterior.PosteriorCache) -> tuple[int, int]:
    """(total, per-device-addressable) bytes of the cache factor leaves."""
    total = sum(leaf.nbytes for leaf in jax.tree.leaves(cache))
    per_dev = 0
    for leaf in jax.tree.leaves(cache):
        shards = getattr(leaf, "addressable_shards", None)
        if shards:
            per_dev += shards[0].data.nbytes
        else:
            per_dev += leaf.nbytes
    return total, per_dev


def add_gp_args(ap: argparse.ArgumentParser) -> None:
    """The --gp-* serving flags, shared with ``repro.launch.serve`` (which
    defines --seed itself for the LM path, so it is added separately)."""
    ap.add_argument("--gp-n", type=int, default=20_000, help="training observations")
    ap.add_argument("--gp-grid", type=int, default=8, help="partition grid is gp-grid^2")
    ap.add_argument("--gp-m", type=int, default=10, help="inducing points per partition")
    ap.add_argument("--gp-train-iters", type=int, default=200)
    ap.add_argument("--gp-batch", type=int, default=2048, help="query points per request")
    ap.add_argument("--gp-requests", type=int, default=50)
    ap.add_argument("--gp-serial", action="store_true",
                    help="sharded mode: run the synchronous request loop "
                         "instead of the overlapped (double-buffered) pipeline")
    ap.add_argument("--gp-skew", type=float, default=0.0, metavar="ALPHA",
                    help="query stream skew: zipf exponent over cells "
                         "(0 = uniform over the domain, the default)")
    ap.add_argument("--gp-router", choices=("single", "two-level"),
                    default="single",
                    help="q_max routing policy: 'single' pads every device "
                         "block to the hottest cell; 'two-level' spills "
                         "hot-cell overflow onto corner-cell neighbors "
                         "(routing.TwoLevelQMax), capping padded-row waste "
                         "under skewed streams")
    ap.add_argument("--gp-save", metavar="DIR", default=None,
                    help="persist the trained artifact (repro.api "
                         "FittedPSVGP.save: FitConfig + grid + params + "
                         "cached factors) to DIR after training")
    ap.add_argument("--gp-artifact", metavar="DIR", default=None,
                    help="serve from a persisted artifact instead of "
                         "training (repro.api Server.from_artifact); "
                         "ignores the --gp-n/--gp-m/--gp-train-iters "
                         "training flags")
    ap.add_argument("--config", metavar="SESSION_JSON", default=None,
                    help="session file with optional 'fit', 'serve' and "
                         "'net' sections (repro.api load_session). The fit "
                         "section replaces the --gp-grid/--gp-m/"
                         "--gp-train-iters training flags; the serve "
                         "section replaces --gp-serial/--gp-router (its "
                         "mode must match the chosen entry point); the net "
                         "section is required when combined with --http")
    ap.add_argument("--http", action="store_true",
                    help="serve over HTTP (repro.net.server: POST /predict "
                         "+ GET /healthz + GET /slo on the 'net' section's "
                         "or NetConfig's default bind address) instead of "
                         "running the in-process demo query stream")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=0)
    add_gp_args(ap)
    args = ap.parse_args()
    if args.gp_requests < 1 or args.gp_batch < 1:
        ap.error("--gp-requests and --gp-batch must be >= 1")
    if args.http:
        # imports and argparse above never initialize the jax backend, so
        # the HTTP driver can still force the virtual device count.
        from repro.net.server import serve_http

        serve_http(args, expect_mode="sharded")
        return
    serve_sharded(args)


if __name__ == "__main__":
    main()
