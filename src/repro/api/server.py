"""``Server`` — one front door for serving a fitted PSVGP.

    server = Server(fitted, ServeConfig(mode="sharded", pipeline="pipelined"))
    mean, var = server.submit(queries)           # one batch, blocking
    report = server.stream(batches)              # a request stream + SLO report
    server.swap(new_fitted, version=t)           # zero-downtime model update

or, from a persisted artifact (no retraining anywhere on this path):

    server = Server.from_artifact("runs/e3sm_t42/", ServeConfig(...))

Internally the config dispatches to the SAME primitives the pre-api
drivers composed by hand — ``blend.predict_blended`` for the replicated
fast path; ``serve_sharded.make_sharded_blend`` + ``make_request_stages``
+ the serial/pipelined request loops for the mesh endpoint, with the
router (``routing.StreamingQMax`` / ``TwoLevelQMax`` / fixed prepass
q_max) and kernel backend chosen by the config — so results are
bitwise-identical to the pre-refactor entry points (gated in
tests/test_api.py). What changed is only who does the wiring: a new
scenario is a ServeConfig field, not a new 600-line driver.

Hot swap (the in-situ lifecycle, docs/lifecycle.md): each model the
server has gone live with is an immutable ``_ServingContext`` — the
fitted model, its (sharded) cache placement, its compiled blend program,
and its route/submit/collect stages, bound together once at build time.
``swap(new_fitted)`` double-buffers the way ``pipelined_request_loop``
double-buffers batches: the ENTIRE new context is built (cache
factorized, sharded onto the mesh, program warmed) while the old context
keeps serving, and going live is one reference flip of ``_active`` under
``_swap_lock``. The stage triple ``request_stages`` hands out never
captures a context: its route stage snapshots ``_active`` exactly once
per request and threads that context through submit and collect — so a
request is answered wholly by the model that was active when it was
routed, never by a mix. That is the atomicity guarantee the swap tests
gate bitwise: pre-flip answers == old model, post-flip == new model,
and in-flight batches are never rejected or corrupted. The streaming
q_max policy is shared ACROSS contexts (the high-water mark is traffic
state, not model state), so a swap does not trigger a q_max recompile
storm.

Device-count contract: sharded mode needs one device per partition. On
CPU those are virtual host devices that must be forced BEFORE the jax
backend initializes — ``Server`` checks and raises with guidance
(``serve_sharded.ensure_host_devices``), but a process that already ran
jax work on too few devices cannot be fixed from here; CLI entry points
call ``ensure_host_devices`` (sized via ``api.peek_fit_config`` for
artifacts) first thing. A swapped-in model must keep the same partition
grid side (same mesh); its grid EDGES may move with the data.
"""
from __future__ import annotations

import threading
import time
from collections.abc import Callable

import jax
import numpy as np

from repro.api.config import ServeConfig
from repro.api.fitted import FittedPSVGP


class _ServingContext:
    """One serving generation: a fitted model bound to its device
    placement and request stages. Immutable after ``Server._build_context``
    returns (the ``requests`` counter is the one mutable field — the
    per-version served count for ``Server.lifecycle``)."""

    __slots__ = (
        "fitted", "version", "route", "submit", "collect",
        "mesh", "cache_bytes", "requests", "build_seconds",
    )

    def __init__(self, fitted: FittedPSVGP, version):
        self.fitted = fitted
        self.version = version
        self.route: Callable | None = None
        self.submit: Callable | None = None
        self.collect: Callable | None = None
        self.mesh = None
        self.cache_bytes: tuple[int, int] | None = None
        self.requests = 0
        self.build_seconds: float | None = None


class Server:
    """Serve a :class:`FittedPSVGP` the way a :class:`ServeConfig` says to.

    Attributes:
      fitted / config: the ACTIVE model (changes on :meth:`swap`) and the
        session config.
      backend: the RESOLVED kernel lane ("ref" | "pallas" | "fused" —
        ``ServeConfig.resolve_backend``).
      policy: the streaming q_max policy routing this server's stream
        (None in replicated mode and in the fixed-q_max lane). Shared
        across swapped model versions — q_max is traffic state.
      mesh / cache_bytes: sharded mode only — the active context's device
        mesh and (total, per-device) cache-factor memory.
    """

    def __init__(self, fitted: FittedPSVGP, config: ServeConfig | None = None):
        self.config = ServeConfig() if config is None else config
        self.backend = self.config.resolve_backend()
        self.policy = (
            self.config.make_policy() if self.config.mode == "sharded" else None
        )
        self._stats = {"requests": 0, "waste_rows": 0, "spilled": 0}
        # the swap flip: _active is written under this lock and snapshotted
        # exactly once per request by the route trampoline (see
        # analysis/asynclint.CONFINEMENT for the safety argument)
        self._swap_lock = threading.Lock()
        self._swaps = 0
        self._retired: list[_ServingContext] = []
        self._active = self._build_context(fitted, version=0)

    # -- construction ------------------------------------------------------

    @classmethod
    def from_artifact(cls, path: str, config: ServeConfig | None = None) -> "Server":
        """``FittedPSVGP.load`` + ``Server`` in one step — the post-hoc
        analysis entry point: serve a persisted artifact without ever
        touching training."""
        return cls(FittedPSVGP.load(path), config)

    @property
    def fitted(self) -> FittedPSVGP:
        """The model currently going live — i.e. the active context's."""
        return self._active.fitted

    @property
    def mesh(self):
        return self._active.mesh

    @property
    def cache_bytes(self) -> tuple[int, int] | None:
        return self._active.cache_bytes

    def _build_context(self, fitted: FittedPSVGP, version) -> _ServingContext:
        """Build one COMPLETE serving generation off the request path:
        factorize/place the cache, compile-memoize the blend program,
        wire the stage triple. Nothing here touches ``_active`` — the
        old context keeps serving until the caller flips."""
        t0 = time.perf_counter()
        ctx = _ServingContext(fitted, version)
        if self.config.mode == "sharded":
            self._build_sharded_stages(ctx)
        else:
            _ = fitted.cache  # factorize up front, off the request path
            self._build_replicated_stages(ctx)
        ctx.build_seconds = time.perf_counter() - t0
        return ctx

    def _build_sharded_stages(self, ctx: _ServingContext) -> None:
        from repro.launch import serve_sharded as ss

        fitted, grid = ctx.fitted, ctx.fitted.grid
        ss.ensure_host_devices(grid.num_partitions)
        cache = fitted._sharded_ctx
        if "mesh" not in cache:
            cache["mesh"] = ss.mesh_for_grid(grid)
            cache_sh = ss.shard_cache(fitted.cache, cache["mesh"])
            jax.block_until_ready(cache_sh)
            cache["cache_sh"] = cache_sh
        if ("blend", self.backend) not in cache:
            cache[("blend", self.backend)] = ss.make_sharded_blend(
                cache["mesh"],
                cache["mesh"].axis_names,
                grid,
                fitted.static.cov_fn,
                cache["cache_sh"],
                backend=self.backend,
            )
        ctx.mesh = cache["mesh"]
        ctx.cache_bytes = ss.cache_memory_bytes(cache["cache_sh"])
        route0, submit0, collect0 = ss.make_request_stages(
            grid,
            cache[("blend", self.backend)],
            cache["cache_sh"],
            policy=self.policy,
            q_max=self.config.q_max,
            pad_multiple=self.config.pad_multiple,
        )

        def route(q):
            table, blocks = route0(q)
            ctx.requests += 1
            self._stats["requests"] += 1
            self._stats["waste_rows"] += table.waste_rows()
            self._stats["spilled"] += table.num_spilled()
            return table, blocks

        ctx.route, ctx.submit, ctx.collect = route, submit0, collect0

    def _build_replicated_stages(self, ctx: _ServingContext) -> None:
        fitted = ctx.fitted

        def route(q):
            return np.asarray(q, np.float32)

        def submit(pts):
            ctx.requests += 1
            self._stats["requests"] += 1
            return fitted.predict(pts)

        def collect(pending):
            jax.block_until_ready(pending)
            return np.asarray(pending[0]), np.asarray(pending[1])

        ctx.route, ctx.submit, ctx.collect = route, submit, collect

    # -- lifecycle ---------------------------------------------------------

    def swap(self, new_fitted: FittedPSVGP, *, version=None, warm: bool = True) -> dict:
        """Go live with ``new_fitted`` with zero downtime.

        The new context is fully built FIRST — cache factorized, placed
        on the mesh (sharded), blend program compiled, optionally warmed
        with one tiny query batch — while the current model keeps
        answering every request. Going live is then a single reference
        flip under ``_swap_lock``: requests routed before the flip are
        answered by the old model end-to-end (the stage trampolines
        snapshot the context once, at route time), requests routed after
        it by the new one — bitwise, with no shed, rejected, or corrupted
        batch in between (gated in tests/test_lifecycle.py). The q_max
        policy (and its compiled-shape high-water mark) carries over, so
        a swap never forces a routing recompile by itself.

        Args:
          new_fitted: the replacement model, e.g. from ``api.refit`` or
            ``FittedPSVGP.load(store, step=...)``. Sharded mode requires
            the same partition grid side as the active model (same mesh).
          version: a label for the lifecycle report (artifact step id,
            say); defaults to the swap ordinal.
          warm: run one tiny batch through the new context before the
            flip so the first live request does not pay the compile.

        Returns ``{"version", "build_s", "swaps"}``.
        """
        old = self._active
        if self.config.mode == "sharded":
            og, ng = old.fitted.grid, new_fitted.grid
            if (og.gx, og.gy) != (ng.gx, ng.gy):
                raise ValueError(
                    f"cannot swap a {ng.gx}x{ng.gy} model into a "
                    f"{og.gx}x{og.gy} mesh — the device mesh is one "
                    "partition per device; refit with the same grid side"
                )
        if version is None:
            version = self._swaps + 1
        ctx = self._build_context(new_fitted, version)
        if warm:
            g = new_fitted.grid
            probe = np.array(
                [[np.mean(g.x_edges[[0, -1]]), np.mean(g.y_edges[[0, -1]])]],
                np.float32,
            )
            ctx.collect(ctx.submit(ctx.route(probe)))
            ctx.requests = 0  # the warm probe is not served traffic
        with self._swap_lock:
            self._retired.append(old)
            self._active = ctx
            self._swaps += 1
        return {
            "version": ctx.version,
            "build_s": ctx.build_seconds,
            "swaps": self._swaps,
        }

    def lifecycle(self) -> dict:
        """The lifecycle section of the SLO report: swap count, the active
        version, and per-version history — requests served, refit
        wall-clock (``FittedPSVGP.refit_seconds``), and context build
        time (the double-buffered work a swap did off the request path).
        """
        versions = [
            {
                "version": c.version,
                "requests": c.requests,
                "refit_s": c.fitted.refit_seconds,
                "build_s": c.build_seconds,
            }
            for c in (*self._retired, self._active)
        ]
        return {
            "swaps": self._swaps,
            "active_version": self._active.version,
            "versions": versions,
        }

    # -- serving -----------------------------------------------------------

    def submit(self, queries) -> tuple[np.ndarray, np.ndarray]:
        """Answer one query batch (N, 2), blocking: (mean (N,), var (N,))."""
        ctx = self._active
        return ctx.collect(ctx.submit(ctx.route(queries)))

    def submit_many(self, requests) -> list[tuple[np.ndarray, np.ndarray]]:
        """Answer many small independent requests as ONE device batch.

        The coalesce seam the async front door (``repro.api.frontdoor``)
        builds on: ``requests`` is a sequence of (n_i, 2) point arrays;
        they are concatenated (``routing.coalesce_requests``), served
        through the same memoized stages as :meth:`submit` — one routing
        pass, one device dispatch — and split back per request
        (``routing.demux_results``). Returns a list of (mean, var) numpy
        pairs, one per request, equal to calling :meth:`submit` on each
        request alone — BITWISE over the sharded path (the fixed-shape
        padded device program makes per-row results independent of batch
        composition), and exact to float32 ULP over the replicated path
        (XLA re-specializes per batch shape). Gated in
        tests/test_frontdoor.py.
        """
        from repro.core import routing

        pts, sizes = routing.coalesce_requests(requests)
        mean, var = self.submit(pts)
        return routing.demux_results(sizes, mean, var)

    def request_stages(self) -> tuple[Callable, Callable, Callable]:
        """The (route, submit, collect) stage triple of this server's
        serving path — the pipelining seam.

        Sharded route is pure numpy; submit is transfer + async dispatch;
        collect is the only sync point. Replicated mode has the same
        three-stage SHAPE around ``fitted.predict`` so a caller that
        overlaps stages — the front door's batching engine,
        ``pipelined_request_loop`` — works against either mode without
        branching.

        The triple survives :meth:`swap`: each stage is a trampoline
        over the ACTIVE context — route snapshots it exactly once and
        threads it through submit and collect, so every request is
        answered end-to-end by the model that was live when it was
        routed (a request never straddles a swap).
        """

        def route(q):
            ctx = self._active  # the one snapshot per request
            return ctx, ctx.route(q)

        def submit(routed):
            ctx, r = routed
            return ctx, ctx.submit(r)

        def collect(pending):
            ctx, p = pending
            return ctx.collect(p)

        return route, submit, collect

    def stream(self, batches, *, warm: bool = True, on_result: Callable | None = None) -> dict:
        """Serve a request stream through the configured loop; return the
        SLO report.

        Dispatch: sharded+pipelined runs the overlapped double-buffered
        driver (``serve_sharded.pipelined_request_loop`` — batch t+1
        routes on the host while the mesh evaluates batch t); everything
        else runs the synchronous ``timed_request_loop``. Results are
        delivered through ``on_result(i, (mean, var))`` in stream order
        (bitwise-identical between the two loops — overlap is scheduling,
        never math).

        ``warm=True`` runs batches[0] once before timing (compile +
        transfer warmup); pass ``warm=False`` when the caller already ran
        a batch (e.g. for an equivalence gate). The warm pass is not
        reported to ``on_result`` and not counted in the latency record.

        Returns ``{"serve_config", "backend", "latency_ms": {p50,p95,p99},
        "points_per_s", "qmax_policy", "lifecycle"}``.
        """
        from repro.launch import serve_sharded as ss

        if self.config.mode == "sharded" and self.config.pipeline == "pipelined":
            route, submit, collect = self.request_stages()
            pct, qps = ss.pipelined_request_loop(
                route, submit, collect, batches, warm=warm, on_result=on_result,
            )
        else:
            if warm:
                self.submit(batches[0])
            if on_result is None:
                answer = self.submit
            else:
                idx = {"i": 0}

                def answer(q):
                    out = self.submit(q)
                    on_result(idx["i"], out)
                    idx["i"] += 1
                    return out

            pct, qps = ss.timed_request_loop(answer, batches, warm=False)
        rec = {
            "serve_config": self.config.to_dict(),
            "backend": self.backend,
            "latency_ms": pct,
            "points_per_s": qps,
            "qmax_policy": (
                {"q_max": int(self.config.q_max), "fixed": True}
                if self.policy is None and self.config.mode == "sharded"
                else self.policy.stats() if self.policy is not None else None
            ),
            "lifecycle": self.lifecycle(),
        }
        return rec

    # -- introspection -----------------------------------------------------

    def stats(self) -> dict:
        """Cumulative serving counters: requests routed, padded-row waste
        and spilled queries (from each request's RoutingTable), plus the
        q_max policy record. Counters span model versions — swap does not
        reset them (``lifecycle()`` has the per-version split).
        ``reset_stats`` zeroes the table counters — benchmark lanes do
        that after their warm pass so the report covers the measured
        stream exactly once."""
        rec = dict(self._stats)
        if self.policy is not None:
            rec["qmax_policy"] = self.policy.stats()
        return rec

    def reset_stats(self) -> None:
        self._stats.update(requests=0, waste_rows=0, spilled=0)
