"""``Server`` — one front door for serving a fitted PSVGP.

    server = Server(fitted, ServeConfig(mode="sharded", pipeline="pipelined"))
    mean, var = server.submit(queries)           # one batch, blocking
    report = server.stream(batches)              # a request stream + SLO report

or, from a persisted artifact (no retraining anywhere on this path):

    server = Server.from_artifact("runs/e3sm_t42/", ServeConfig(...))

Internally the config dispatches to the SAME primitives the pre-api
drivers composed by hand — ``blend.predict_blended`` for the replicated
fast path; ``serve_sharded.make_sharded_blend`` + ``make_request_stages``
+ the serial/pipelined request loops for the mesh endpoint, with the
router (``routing.StreamingQMax`` / ``TwoLevelQMax`` / fixed prepass
q_max) and kernel backend chosen by the config — so results are
bitwise-identical to the pre-refactor entry points (gated in
tests/test_api.py). What changed is only who does the wiring: a new
scenario is a ServeConfig field, not a new 600-line driver.

Device-count contract: sharded mode needs one device per partition. On
CPU those are virtual host devices that must be forced BEFORE the jax
backend initializes — ``Server`` checks and raises with guidance
(``serve_sharded.ensure_host_devices``), but a process that already ran
jax work on too few devices cannot be fixed from here; CLI entry points
call ``ensure_host_devices`` (sized via ``api.peek_fit_config`` for
artifacts) first thing.
"""
from __future__ import annotations

from collections.abc import Callable

import jax
import numpy as np

from repro.api.config import ServeConfig
from repro.api.fitted import FittedPSVGP
from repro.core import routing


class Server:
    """Serve a :class:`FittedPSVGP` the way a :class:`ServeConfig` says to.

    Attributes:
      fitted / config: the model and the session config.
      backend: the RESOLVED kernel lane ("ref" | "pallas" | "fused" —
        ``ServeConfig.resolve_backend``).
      policy: the streaming q_max policy routing this server's stream
        (None in replicated mode and in the fixed-q_max lane).
      mesh / cache_bytes: sharded mode only — the device mesh and the
        (total, per-device) cache-factor memory.
    """

    def __init__(self, fitted: FittedPSVGP, config: ServeConfig | None = None):
        self.fitted = fitted
        self.config = ServeConfig() if config is None else config
        self.backend = self.config.resolve_backend()
        self.policy = None
        self.mesh = None
        self.cache_bytes: tuple[int, int] | None = None
        self._stats = {"requests": 0, "waste_rows": 0, "spilled": 0}
        if self.config.mode == "sharded":
            self._init_sharded()
        else:
            _ = fitted.cache  # factorize up front, off the request path

    # -- construction ------------------------------------------------------

    @classmethod
    def from_artifact(cls, path: str, config: ServeConfig | None = None) -> "Server":
        """``FittedPSVGP.load`` + ``Server`` in one step — the post-hoc
        analysis entry point: serve a persisted artifact without ever
        touching training."""
        return cls(FittedPSVGP.load(path), config)

    def _init_sharded(self) -> None:
        from repro.launch import serve_sharded as ss

        grid = self.fitted.grid
        ss.ensure_host_devices(grid.num_partitions)
        ctx = self.fitted._sharded_ctx
        if "mesh" not in ctx:
            ctx["mesh"] = ss.mesh_for_grid(grid)
            cache_sh = ss.shard_cache(self.fitted.cache, ctx["mesh"])
            jax.block_until_ready(cache_sh)
            ctx["cache_sh"] = cache_sh
        if ("blend", self.backend) not in ctx:
            ctx[("blend", self.backend)] = ss.make_sharded_blend(
                ctx["mesh"],
                ctx["mesh"].axis_names,
                grid,
                self.fitted.static.cov_fn,
                ctx["cache_sh"],
                backend=self.backend,
            )
        self.mesh = ctx["mesh"]
        self.cache_bytes = ss.cache_memory_bytes(ctx["cache_sh"])
        self.policy = self.config.make_policy()
        route0, self._submit_stage, self._collect_stage = ss.make_request_stages(
            grid,
            ctx[("blend", self.backend)],
            ctx["cache_sh"],
            policy=self.policy,
            q_max=self.config.q_max,
            pad_multiple=self.config.pad_multiple,
        )

        def route(q):
            table, blocks = route0(q)
            self._stats["requests"] += 1
            self._stats["waste_rows"] += table.waste_rows()
            self._stats["spilled"] += table.num_spilled()
            return table, blocks

        self._route_stage = route

    # -- serving -----------------------------------------------------------

    def submit(self, queries) -> tuple[np.ndarray, np.ndarray]:
        """Answer one query batch (N, 2), blocking: (mean (N,), var (N,))."""
        if self.config.mode == "sharded":
            return self._collect_stage(self._submit_stage(self._route_stage(queries)))
        self._stats["requests"] += 1
        mean, var = self.fitted.predict(queries)
        jax.block_until_ready((mean, var))
        return np.asarray(mean), np.asarray(var)

    def submit_many(self, requests) -> list[tuple[np.ndarray, np.ndarray]]:
        """Answer many small independent requests as ONE device batch.

        The coalesce seam the async front door (``repro.api.frontdoor``)
        builds on: ``requests`` is a sequence of (n_i, 2) point arrays;
        they are concatenated (``routing.coalesce_requests``), served
        through the same memoized stages as :meth:`submit` — one routing
        pass, one device dispatch — and split back per request
        (``routing.demux_results``). Returns a list of (mean, var) numpy
        pairs, one per request, equal to calling :meth:`submit` on each
        request alone — BITWISE over the sharded path (the fixed-shape
        padded device program makes per-row results independent of batch
        composition), and exact to float32 ULP over the replicated path
        (XLA re-specializes per batch shape). Gated in
        tests/test_frontdoor.py.
        """
        pts, sizes = routing.coalesce_requests(requests)
        mean, var = self.submit(pts)
        return routing.demux_results(sizes, mean, var)

    def request_stages(self) -> tuple[Callable, Callable, Callable]:
        """The (route, submit, collect) stage triple of this server's
        serving path — the pipelining seam.

        Sharded mode returns the memoized ``serve_sharded
        .make_request_stages`` stages (route = pure numpy; submit =
        transfer + async dispatch; collect = the only sync point).
        Replicated mode returns the same three-stage SHAPE around
        ``fitted.predict`` so a caller that overlaps stages — the front
        door's batching engine, ``pipelined_request_loop`` — works
        against either mode without branching: route validates the batch,
        submit dispatches without blocking (jax async dispatch), collect
        blocks and materializes numpy results.
        """
        if self.config.mode == "sharded":
            return self._route_stage, self._submit_stage, self._collect_stage
        fitted = self.fitted

        def route(q):
            return np.asarray(q, np.float32)

        def submit(pts):
            self._stats["requests"] += 1
            return fitted.predict(pts)

        def collect(pending):
            jax.block_until_ready(pending)
            return np.asarray(pending[0]), np.asarray(pending[1])

        return route, submit, collect

    def stream(self, batches, *, warm: bool = True, on_result: Callable | None = None) -> dict:
        """Serve a request stream through the configured loop; return the
        SLO report.

        Dispatch: sharded+pipelined runs the overlapped double-buffered
        driver (``serve_sharded.pipelined_request_loop`` — batch t+1
        routes on the host while the mesh evaluates batch t); everything
        else runs the synchronous ``timed_request_loop``. Results are
        delivered through ``on_result(i, (mean, var))`` in stream order
        (bitwise-identical between the two loops — overlap is scheduling,
        never math).

        ``warm=True`` runs batches[0] once before timing (compile +
        transfer warmup); pass ``warm=False`` when the caller already ran
        a batch (e.g. for an equivalence gate). The warm pass is not
        reported to ``on_result`` and not counted in the latency record.

        Returns ``{"serve_config", "backend", "latency_ms": {p50,p95,p99},
        "points_per_s", "qmax_policy"}``.
        """
        from repro.launch import serve_sharded as ss

        if self.config.mode == "sharded" and self.config.pipeline == "pipelined":
            pct, qps = ss.pipelined_request_loop(
                self._route_stage, self._submit_stage, self._collect_stage,
                batches, warm=warm, on_result=on_result,
            )
        else:
            if warm:
                self.submit(batches[0])
            if on_result is None:
                answer = self.submit
            else:
                idx = {"i": 0}

                def answer(q):
                    out = self.submit(q)
                    on_result(idx["i"], out)
                    idx["i"] += 1
                    return out

            pct, qps = ss.timed_request_loop(answer, batches, warm=False)
        rec = {
            "serve_config": self.config.to_dict(),
            "backend": self.backend,
            "latency_ms": pct,
            "points_per_s": qps,
            "qmax_policy": (
                {"q_max": int(self.config.q_max), "fixed": True}
                if self.policy is None and self.config.mode == "sharded"
                else self.policy.stats() if self.policy is not None else None
            ),
        }
        return rec

    # -- introspection -----------------------------------------------------

    def stats(self) -> dict:
        """Cumulative serving counters: requests routed, padded-row waste
        and spilled queries (from each request's RoutingTable), plus the
        q_max policy record. ``reset_stats`` zeroes the table counters —
        benchmark lanes do that after their warm pass so the report covers
        the measured stream exactly once."""
        rec = dict(self._stats)
        if self.policy is not None:
            rec["qmax_policy"] = self.policy.stats()
        return rec

    def reset_stats(self) -> None:
        self._stats.update(requests=0, waste_rows=0, spilled=0)
