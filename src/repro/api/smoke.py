"""api-smoke — the end-to-end proof of the fit -> artifact -> serve
lifecycle (the CI ``api-smoke`` step; ``make api-smoke``).

Fits a toy 4x4 model, SAVES the artifact, then serves requests through
``Server.from_artifact`` — i.e. from the loaded artifact, never the
in-memory model — in BOTH modes:

  * replicated: loaded predictions must be BITWISE-identical to the
    in-memory model's (the artifact round-trip contract);
  * sharded (pipelined, two-level router, auto backend): must match the
    replicated answers to float32 accuracy on every request.

Exits non-zero on any violation. Seconds-scale on CPU (the 16 mesh
devices are virtual host devices, forced before jax initializes).

  PYTHONPATH=src python -m repro.api.smoke
"""
from __future__ import annotations

import argparse
import tempfile


def run(*, grid: int = 4, m: int = 5, n: int = 1500, train_iters: int = 150,
        requests: int = 5, batch: int = 256, seed: int = 0) -> None:
    # virtual devices for the sharded half — before any jax computation
    from repro.launch.serve_sharded import ensure_host_devices

    ensure_host_devices(grid * grid)

    import numpy as np

    from repro import api
    from repro.data.spatial import e3sm_like_field

    ds = e3sm_like_field(n=n, seed=seed)
    fitted = api.fit(
        api.FitConfig(grid=grid, m=m, train_iters=train_iters, seed=seed),
        ds, verbose=True,
    )

    rng = np.random.default_rng(seed + 1)
    lo, hi = ds.x.min(axis=0), ds.x.max(axis=0)
    batches = [
        rng.uniform(lo, hi, (batch, 2)).astype(np.float32) for _ in range(requests)
    ]

    with tempfile.TemporaryDirectory() as td:
        fitted.save(td)
        print(f"artifact saved: grid={grid}x{grid}, m={m}")

        # replicated, from the artifact: bitwise == the in-memory model
        # (the in-memory predictions double as the sharded lane's reference)
        rep = api.Server.from_artifact(td, api.ServeConfig(mode="replicated"))
        reference = []
        for i, q in enumerate(batches):
            m_l, v_l = rep.submit(q)
            m_m, v_m = (np.asarray(a) for a in fitted.predict(q))
            reference.append((m_m, v_m))
            assert np.array_equal(m_l, m_m), f"replicated mean differs (batch {i})"
            assert np.array_equal(v_l, v_m), f"replicated var differs (batch {i})"
        print(f"replicated from_artifact: {requests} requests bitwise == in-memory")

        # sharded, from the artifact: float32-accurate vs replicated
        sh = api.Server.from_artifact(
            td,
            api.ServeConfig(mode="sharded", pipeline="pipelined",
                            router="two-level", backend="auto"),
        )
        results: dict = {}
        sh.stream(batches, warm=True, on_result=lambda i, out: results.setdefault(i, out))
        err = max(
            max(
                float(np.abs(results[i][0] - m_m).max()),
                float(np.abs(results[i][1] - v_m).max()),
            )
            for i, (m_m, v_m) in enumerate(reference)
        )
        assert err <= 1e-4, f"sharded from_artifact drifted from replicated: {err:.2e}"
        print(f"sharded from_artifact ({sh.backend} backend, "
              f"{sh.config.router} router): {requests} requests, "
              f"max |err| vs replicated = {err:.2e}")
    print("api-smoke OK")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--grid", type=int, default=4, help="partition grid side (devices = grid^2)")
    ap.add_argument("--m", type=int, default=5, help="inducing points per partition")
    ap.add_argument("--n", type=int, default=1500, help="training observations")
    ap.add_argument("--train-iters", type=int, default=150)
    ap.add_argument("--requests", type=int, default=5)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    run(grid=a.grid, m=a.m, n=a.n, train_iters=a.train_iters,
        requests=a.requests, batch=a.batch, seed=a.seed)


if __name__ == "__main__":
    main()
