"""Frozen session configs — the vocabulary of the ``repro.api`` front door.

Every entry point (``launch/serve.py --gp``, ``launch/serve_sharded.py``,
``benchmarks/bench_serve.py``, ``examples/serve_demo.py``) used to thread
its choices through ad-hoc argparse flags and positional wiring. These two
dataclasses are the replacement: a :class:`FitConfig` fully determines a
training run (``api.fit``), a :class:`ServeConfig` fully determines how a
trained artifact answers queries (``api.Server``), and both round-trip
through JSON so a benchmark row or a saved artifact carries the exact
session that produced it.

This module is deliberately stdlib-only (no jax import at module scope
except inside :meth:`ServeConfig.resolve_backend`, which is a serve-time
decision): configs must be constructible — and artifact manifests readable
— before the jax backend initializes, because the sharded serving path
needs to force virtual host devices FIRST (see
``serve_sharded.ensure_host_devices``).
"""
from __future__ import annotations

import dataclasses
import json
import warnings

_COMMS = ("gather", "ppermute")
_COVARIANCES = ("rbf", "matern32", "matern52")
_MODES = ("replicated", "sharded")
_PIPELINES = ("serial", "pipelined")
_ROUTERS = ("single", "two-level")
_BACKENDS = ("auto", "ref", "pallas", "fused")

# one warning per backend name per process — serving loops resolve the
# backend once per Server, but nothing stops a caller from resolving in a
# loop, and repeating the interpret-mode caveat per request is noise
_WARNED_INTERPRET: set = set()


def _check(cond: bool, msg: str) -> None:
    if not cond:
        raise ValueError(msg)


def _from_dict(cls, d: dict):
    """Shared strict constructor: unknown keys are config rot, not noise."""
    _check(isinstance(d, dict), f"{cls.__name__} expects a dict, got {type(d).__name__}")
    known = {f.name for f in dataclasses.fields(cls)}
    unknown = set(d) - known
    _check(not unknown, f"unknown {cls.__name__} fields {sorted(unknown)}; have {sorted(known)}")
    return cls(**d)


@dataclasses.dataclass(frozen=True)
class FitConfig:
    """Everything ``api.fit`` needs besides the data itself.

    Fields:
      grid: partition grid side — the model has ``grid**2`` partitions,
        and sharded serving wants one device per partition.
      m: inducing points per partition (the paper's m).
      delta: eq. (9) neighbor-sampling weight (0 = ISVGP, 1 = full PSVGP).
        Blending needs delta > 0 to be an interpolation rather than an
        extrapolation (README; tests/test_blend.py) — hence the default.
      train_iters / batch_size / learning_rate / seed: the SGD budget.
      comm: "gather" (paper-faithful) | "ppermute" (TPU-native).
      covariance / whitened / jitter: the local-SVGP numerics
        (``repro.core.svgp.SVGPConfig``).
    """

    grid: int = 8
    m: int = 10
    delta: float = 0.25
    train_iters: int = 200
    batch_size: int = 32
    learning_rate: float = 0.05
    seed: int = 0
    comm: str = "gather"
    covariance: str = "rbf"
    whitened: bool = False
    jitter: float = 1e-5

    def __post_init__(self) -> None:
        _check(int(self.grid) >= 1, f"grid must be >= 1, got {self.grid}")
        _check(int(self.m) >= 1, f"m must be >= 1, got {self.m}")
        _check(0.0 <= float(self.delta) <= 1.0, f"delta must be in [0, 1], got {self.delta}")
        _check(int(self.train_iters) >= 0, f"train_iters must be >= 0, got {self.train_iters}")
        _check(int(self.batch_size) >= 1, f"batch_size must be >= 1, got {self.batch_size}")
        _check(float(self.learning_rate) > 0, f"learning_rate must be > 0, got {self.learning_rate}")
        _check(self.comm in _COMMS, f"comm must be one of {_COMMS}, got {self.comm!r}")
        _check(
            self.covariance in _COVARIANCES,
            f"covariance must be one of {_COVARIANCES}, got {self.covariance!r}",
        )
        _check(float(self.jitter) > 0, f"jitter must be > 0, got {self.jitter}")

    @property
    def num_partitions(self) -> int:
        return int(self.grid) ** 2

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)

    @classmethod
    def from_dict(cls, d: dict) -> "FitConfig":
        return _from_dict(cls, d)

    @classmethod
    def from_json(cls, s: str) -> "FitConfig":
        return cls.from_dict(json.loads(s))


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """How a trained artifact answers queries.

    Fields:
      mode: "replicated" (one host holds every partition's cached factors
        — ``blend.predict_blended``) | "sharded" (cache one partition per
        device over a mesh, halo-exchange serving —
        ``launch.serve_sharded``).
      pipeline: "serial" (route + evaluate + scatter per request) |
        "pipelined" (batch t+1 routed on the host while the mesh evaluates
        batch t; bitwise-identical results). Sharded only — the replicated
        path has no device stage to overlap with.
      router: "single" (every device block pads to the hottest cell's
        count) | "two-level" (hot-cell overflow spills onto corner-cell
        neighbors — ``routing.TwoLevelQMax``). Sharded only.
      backend: kernel lane for the cached-posterior evaluation —
        "ref"    the pure-jnp path (XLA-compiled; every covariance);
        "pallas" the fused Pallas predict kernel via a (9·q, d) reshape
                 round-trip (RBF only);
        "fused"  the slot-stacked fused Pallas kernel, one launch over the
                 whole 9-slot halo grid (RBF only; the TPU production
                 lane);
        "auto"   resolve to the fastest COMPILED lane at serve time: the
                 Pallas kernels compile to Mosaic only on TPU — everywhere
                 else they run in interpret mode (a correctness lane, not
                 a speed lane), so auto picks "fused" on TPU and "ref"
                 otherwise. Explicitly requesting "pallas"/"fused" off-TPU
                 still works but warns once (see
                 :meth:`resolve_backend`).
      headroom / pad_multiple: the streaming q_max policy's growth rule
        (``routing.StreamingQMax``).
      q_max: fixed per-partition block size instead of the streaming
        policy — the whole-stream-prepass lane for streams known up front
        (``serve_sharded.prepass_routing``). Sharded single-router only.
    """

    mode: str = "replicated"
    pipeline: str = "serial"
    router: str = "single"
    backend: str = "auto"
    headroom: float = 1.25
    pad_multiple: int = 8
    q_max: int | None = None

    def __post_init__(self) -> None:
        _check(self.mode in _MODES, f"mode must be one of {_MODES}, got {self.mode!r}")
        _check(
            self.pipeline in _PIPELINES,
            f"pipeline must be one of {_PIPELINES}, got {self.pipeline!r}",
        )
        _check(self.router in _ROUTERS, f"router must be one of {_ROUTERS}, got {self.router!r}")
        _check(self.backend in _BACKENDS, f"backend must be one of {_BACKENDS}, got {self.backend!r}")
        _check(float(self.headroom) >= 1.0, f"headroom must be >= 1, got {self.headroom}")
        _check(int(self.pad_multiple) >= 1, f"pad_multiple must be >= 1, got {self.pad_multiple}")
        if self.mode == "replicated":
            _check(
                self.pipeline == "serial",
                "mode='replicated' serves synchronously — pipeline='pipelined' "
                "overlaps host routing with the device mesh, which only exists "
                "in mode='sharded'",
            )
            _check(
                self.router == "single",
                "router='two-level' balances per-DEVICE block padding — it "
                "only applies to mode='sharded'",
            )
            _check(
                self.backend in ("auto", "ref"),
                f"mode='replicated' evaluates through blend.predict_blended, "
                f"which has no {self.backend!r} lane — use backend='auto' or "
                "'ref', or serve sharded",
            )
        if self.q_max is not None:
            _check(int(self.q_max) >= 1, f"q_max must be >= 1, got {self.q_max}")
            _check(
                self.mode == "sharded" and self.router == "single",
                "a fixed q_max is the whole-stream-prepass lane of sharded "
                "single-router serving; streaming policies (and the two-level "
                "router's spill budget) own q_max otherwise",
            )

    def resolve_backend(self) -> str:
        """The concrete kernel lane this config serves with ("ref" |
        "pallas" | "fused").

        "auto" resolves to the fastest lane that actually COMPILES on the
        current jax backend: "fused" on TPU (Mosaic), "ref" everywhere
        else — off TPU the Pallas kernels only run in interpret mode,
        which is orders of magnitude slower than the XLA-compiled jnp
        path. An EXPLICIT "pallas"/"fused" off TPU is honored (it is the
        correctness lane the CPU test suite runs) but warns once per
        process, so a latency number measured on it cannot silently
        masquerade as a production figure. Replicated mode always
        resolves to "ref" (its blend path has no kernel lane).
        """
        import jax

        if self.mode == "replicated":
            return "ref"
        on_tpu = jax.default_backend() == "tpu"
        if self.backend == "auto":
            return "fused" if on_tpu else "ref"
        if self.backend in ("pallas", "fused") and not on_tpu:
            if self.backend not in _WARNED_INTERPRET:
                _WARNED_INTERPRET.add(self.backend)
                warnings.warn(
                    f"backend={self.backend!r} runs the Pallas kernels in "
                    f"INTERPRET mode on {jax.default_backend()!r} — a "
                    "correctness lane, not a speed lane; latency measured "
                    "here is not meaningful. Use backend='auto' to get the "
                    "fastest compiled lane for this machine.",
                    RuntimeWarning,
                    stacklevel=2,
                )
        return self.backend

    def make_policy(self):
        """The streaming q_max policy this config routes with, or None when
        ``q_max`` pins a fixed block size (exactly one of the two drives
        ``serve_sharded.make_request_stages``)."""
        from repro.core import routing

        if self.q_max is not None:
            return None
        if self.router == "two-level":
            return routing.TwoLevelQMax(
                headroom=self.headroom, pad_multiple=self.pad_multiple
            )
        return routing.StreamingQMax(
            headroom=self.headroom, pad_multiple=self.pad_multiple
        )

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)

    @classmethod
    def from_dict(cls, d: dict) -> "ServeConfig":
        return _from_dict(cls, d)

    @classmethod
    def from_json(cls, s: str) -> "ServeConfig":
        return cls.from_dict(json.loads(s))


_INITS = ("warm", "scratch")


@dataclasses.dataclass(frozen=True)
class RefitConfig:
    """How ``api.refit`` updates a fitted surface for a new simulation step.

    The in-situ loop (docs/lifecycle.md) refits the SAME FitConfig recipe
    against each new time slice, but with the previous step's parameters
    as the initializer and a much shorter SGD budget — the paper fits
    ~100-150 iterations inside one ~1 s E3SM step, versus the full
    from-scratch budget at step 0.

    Fields:
      train_iters: the refit SGD budget (iterations for THIS step).
      init: "warm" starts from the previous step's params (and Adam
        moments); "scratch" re-initializes from ``PRNGKey(seed)`` exactly
        like ``api.fit`` — with ``train_iters`` equal to the FitConfig's
        full budget, the scratch path is bitwise-identical to ``fit()``
        (gated in tests/test_lifecycle.py).
      reset_optimizer: warm-start the params but zero the Adam moments
        (useful when the field shifts abruptly and stale second moments
        would damp the correction). Artifacts loaded from disk carry no
        moments, so refitting a LOADED artifact always re-initializes
        the optimizer regardless of this flag.
      learning_rate: override the FitConfig learning rate for this refit
        only (None keeps it).
    """

    train_iters: int = 50
    init: str = "warm"
    reset_optimizer: bool = False
    learning_rate: float | None = None

    def __post_init__(self) -> None:
        _check(int(self.train_iters) >= 0, f"train_iters must be >= 0, got {self.train_iters}")
        _check(self.init in _INITS, f"init must be one of {_INITS}, got {self.init!r}")
        if self.learning_rate is not None:
            _check(
                float(self.learning_rate) > 0,
                f"learning_rate must be > 0, got {self.learning_rate}",
            )

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)

    @classmethod
    def from_dict(cls, d: dict) -> "RefitConfig":
        return _from_dict(cls, d)

    @classmethod
    def from_json(cls, s: str) -> "RefitConfig":
        return cls.from_dict(json.loads(s))


_ADMISSIONS = ("delay", "shed")


@dataclasses.dataclass(frozen=True)
class FrontDoorConfig:
    """How the async front door (``repro.api.frontdoor``) coalesces many
    small independent requests into device batches.

    Fields:
      max_wait_ms: batching-window time trigger — once the first request
        of a window arrives, the batcher waits at most this long for more
        before dispatching (the latency a lightly-loaded request pays to
        buy coalescing under load).
      max_rows: batching-window size trigger — dispatch as soon as the
        coalesced window reaches this many query points, however young
        the window is (caps the device batch, bounding q_max growth).
      max_request_rows: largest single request admitted (points per
        request). The front door serves MANY SMALL queries; a bulk batch
        should go straight to ``Server.submit``.
      queue_depth: admission-queue bound, in requests. The queue is what
        absorbs bursts — and what fills while the device program
        recompiles for a new q_max high-water mark.
      admission: what happens to a request arriving at a full queue —
        "delay" applies backpressure (the await blocks until a slot
        frees: closed-loop clients slow down), "shed" rejects it
        immediately (``frontdoor.RequestRejected``: open-loop traffic is
        load-shed instead of building an unbounded backlog).
    """

    max_wait_ms: float = 2.0
    max_rows: int = 1024
    max_request_rows: int = 64
    queue_depth: int = 256
    admission: str = "delay"

    def __post_init__(self) -> None:
        _check(float(self.max_wait_ms) >= 0, f"max_wait_ms must be >= 0, got {self.max_wait_ms}")
        _check(int(self.max_rows) >= 1, f"max_rows must be >= 1, got {self.max_rows}")
        _check(
            1 <= int(self.max_request_rows) <= int(self.max_rows),
            f"max_request_rows must be in [1, max_rows={self.max_rows}], "
            f"got {self.max_request_rows}",
        )
        _check(int(self.queue_depth) >= 1, f"queue_depth must be >= 1, got {self.queue_depth}")
        _check(
            self.admission in _ADMISSIONS,
            f"admission must be one of {_ADMISSIONS}, got {self.admission!r}",
        )

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)

    @classmethod
    def from_dict(cls, d: dict) -> "FrontDoorConfig":
        return _from_dict(cls, d)

    @classmethod
    def from_json(cls, s: str) -> "FrontDoorConfig":
        return cls.from_dict(json.loads(s))


@dataclasses.dataclass(frozen=True)
class NetConfig:
    """How the HTTP front door (``repro.net.server``) binds and guards the
    socket. The transport knobs only — batching and admission stay in
    :class:`FrontDoorConfig`, the model in Fit/ServeConfig.

    Fields:
      host / port: the listen address. Port 0 asks the OS for a free
        port (the test and benchmark lane); the bound port is in
        ``NetServer.port``.
      max_body_bytes: largest accepted ``POST /predict`` body. A body
        over the cap is refused with 413 BEFORE it is read into memory —
        the transport-level twin of the front door's
        ``max_request_rows`` admission check.
      read_timeout_s: per-request read deadline — a client that stalls
        mid-body is disconnected rather than pinning a reader task.
      keepalive: serve multiple requests per connection (HTTP/1.1
        persistent connections). Off, every response carries
        ``Connection: close`` — the A/B knob for measuring connection
        setup cost in ``bench_net``.
    """

    host: str = "127.0.0.1"
    port: int = 8777
    max_body_bytes: int = 1_048_576
    read_timeout_s: float = 30.0
    keepalive: bool = True

    def __post_init__(self) -> None:
        _check(
            isinstance(self.host, str) and len(self.host) > 0,
            f"host must be a non-empty str, got {self.host!r}",
        )
        _check(
            0 <= int(self.port) <= 65535,
            f"port must be in [0, 65535] (0 = OS-assigned), got {self.port}",
        )
        _check(
            int(self.max_body_bytes) >= 1024,
            f"max_body_bytes must be >= 1024, got {self.max_body_bytes}",
        )
        _check(
            float(self.read_timeout_s) > 0,
            f"read_timeout_s must be > 0, got {self.read_timeout_s}",
        )
        _check(
            isinstance(self.keepalive, bool),
            f"keepalive must be a bool, got {self.keepalive!r}",
        )

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)

    @classmethod
    def from_dict(cls, d: dict) -> "NetConfig":
        return _from_dict(cls, d)

    @classmethod
    def from_json(cls, s: str) -> "NetConfig":
        return cls.from_dict(json.loads(s))


def load_session(path: str):
    """Read a session file: ``{"fit": {...}, "serve": {...}, "net":
    {...}}``, every section optional, no other keys. Returns
    (fit, serve, net) with ``None`` for an absent section.

    This is the ``--config session.json`` lane of the serving CLIs — the
    same JSON a saved artifact manifest or a benchmark row carries, so a
    recorded session replays without reconstructing flag spellings.
    Stdlib-only on purpose: the sharded CLI must read the fit grid (to
    force one virtual device per partition) — and the HTTP CLI the bind
    address — BEFORE jax initializes.
    """
    with open(path, encoding="utf-8") as f:
        d = json.load(f)
    _check(isinstance(d, dict), f"session file {path} must hold a JSON object")
    unknown = set(d) - {"fit", "serve", "net"}
    _check(not unknown, f"unknown session sections {sorted(unknown)}; use 'fit'/'serve'/'net'")
    fit = FitConfig.from_dict(d["fit"]) if "fit" in d else None
    serve = ServeConfig.from_dict(d["serve"]) if "serve" in d else None
    net = NetConfig.from_dict(d["net"]) if "net" in d else None
    return fit, serve, net
