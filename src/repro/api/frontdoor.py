"""Async front door — continuous batching for many small independent queries.

Every serving number before this module came from a single-tenant
synchronous loop handing pre-built 2048-point batches to
``Server.submit``. The paper's in-situ setting (and the ROADMAP's
"millions of users" north star) is the opposite traffic shape: many
concurrent clients each asking for a handful of points. This module is
the in-process asyncio model of that endpoint, LLM-serving style:

  * clients ``await FrontDoor.submit(points)`` with tiny (1..64-point)
    requests; each gets its own future;
  * an admission queue bounds the backlog (``FrontDoorConfig
    .queue_depth``): a request arriving at a full queue is DELAYED
    (backpressure — the await blocks until a slot frees) or SHED
    (``RequestRejected``) per ``FrontDoorConfig.admission``. The queue is
    exactly what absorbs a burst while ``StreamingQMax`` /
    ``TwoLevelQMax`` grow q_max and the device program recompiles —
    recompiles are counted and surfaced in the SLO report;
  * a batching window coalesces queued requests into ONE jit-stable
    device batch (``routing.coalesce_requests``): dispatch triggers on
    ``max_rows`` coalesced points or ``max_wait_ms`` after the window
    opened, whichever first;
  * the engine is double-buffered the way
    ``serve_sharded.pipelined_request_loop`` is: batch t+1 is gathered
    on the event loop and routed + dispatched in a dedicated dispatch
    thread while batch t's device sync blocks in the collect thread —
    the event loop only ever coalesces python objects, so neither a
    q_max recompile nor a replicated shape re-specialization (both
    hundreds of ms) can stall admission (`tests/test_frontdoor.py`
    asserts exactly that under ``PYTHONASYNCIODEBUG=1``);
  * results come back per request via the routing ``src_idx`` inverse
    (``scatter_results`` inside ``Server.submit``) plus the ragged demux
    (``routing.demux_results``) — per-user demux is free, as the
    decentralized halo scheme promised.

The golden property (gated in tests/test_frontdoor.py and by
``benchmarks.bench_frontdoor``): however requests interleave, coalesce
and demux, every request's (mean, var) equals serving it alone through
``Server.submit``. Over the SHARDED path the equality is BITWISE: every
batch is padded into the same fixed-shape (P, q_max) device program
(q_max is the policy's sticky high-water mark), and every per-row
quantity of the slots kernel depends only on that row's query point and
the cached factors — batch composition is scheduling, never math. Over
the replicated path XLA re-specializes ``fitted.predict`` per batch
SHAPE, and differently-shaped programs can round a row differently by a
few float32 ULP (measured ~1e-7 on CPU) — there the guarantee is exact
to float32 resolution, and bitwise whenever the shapes coincide.

Usage::

    server = api.Server(fitted, api.ServeConfig(...))
    async with api.FrontDoor(server, api.FrontDoorConfig(max_wait_ms=2)) as fd:
        mean, var = await fd.submit(points)        # (n, 2) with n <= 64
    report = fd.report()                           # SLO: latency, sheds, recompiles

Works over both serve modes through ``Server.request_stages`` (replicated
needs no mesh, so the docs snippet and the default test lane run it
in-process; sharded needs the usual one-virtual-device-per-partition
setup BEFORE jax initializes).
"""
from __future__ import annotations

import asyncio
import concurrent.futures
import dataclasses
import threading
from typing import Any

import numpy as np

from repro.api.config import FrontDoorConfig
from repro.api.server import Server
from repro.core import routing

_SENTINEL = object()  # queue wake-up marker posted by close()


class RequestRejected(RuntimeError):
    """Raised to a client whose request was shed by admission control
    (``FrontDoorConfig.admission == "shed"`` and the queue was full)."""


class RequestTooLarge(ValueError):
    """Raised at admission for a single request above
    ``FrontDoorConfig.max_request_rows``. A ``ValueError`` subclass (it
    IS a validation failure) but typed so transports can distinguish
    it — the HTTP layer maps it to 413, where a generic bad request is
    400. Rejecting at admission is load-bearing, not cosmetic: a
    request bigger than the batching window could otherwise wedge
    ``_gather_window`` (``rows < max_rows`` never admits a second
    request yet the window is already over budget) and push a single
    coalesced batch past the jit-stable block budget the q_max policy
    sized for."""


@dataclasses.dataclass
class _Request:
    """One admitted client request waiting in the batching queue."""

    points: np.ndarray  # (n, 2) float32, validated at admission
    n: int
    future: asyncio.Future
    t_arrival: float  # event-loop clock, set at admission


@dataclasses.dataclass
class _Batch:
    """One dispatched (in-flight) coalesced batch."""

    reqs: list[_Request]
    sizes: np.ndarray  # (R,) rows per request, coalesce order
    handle: Any  # whatever the submit stage returned (pending device work)


class FrontDoor:
    """The asyncio in-process endpoint wrapping an ``api.Server``.

    Construction does not touch the server; the engine task starts lazily
    on the first :meth:`submit` (or explicitly via ``async with``). All
    device interaction goes through the server's
    :meth:`~repro.api.server.Server.request_stages` triple, so the same
    front door serves replicated and sharded, single and two-level
    router, any kernel backend.
    """

    def __init__(self, server: Server, config: FrontDoorConfig | None = None):
        self.server = server
        self.config = FrontDoorConfig() if config is None else config
        self._route, self._submit, self._collect = server.request_stages()
        self._queue: asyncio.Queue | None = None  # created on the running loop
        self._engine_task: asyncio.Task | None = None
        # collect blocks on device results — one worker thread keeps those
        # syncs off the event loop AND serializes them (jax dispatch from
        # the loop thread may overlap a block_until_ready here; two
        # concurrent blocking collects never happen)
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="frontdoor-collect"
        )
        # route + submit also leave the event loop: a window that grows
        # q_max (or a replicated batch with a novel coalesced shape)
        # recompiles the device program — hundreds of ms that must not
        # stall admission. One worker serializes dispatches so batches
        # reach the device in window order.
        self._dispatch_pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="frontdoor-dispatch"
        )
        # guards the per-batch counters: written by the dispatch thread,
        # read by report() on the event loop (see analysis RR006)
        self._stats_lock = threading.Lock()
        self._closing = False
        self._broken: BaseException | None = None  # engine crash, if any
        self._saw_sentinel = False  # close sentinel consumed mid-window
        # SLO counters
        self._arrived = 0
        self._admitted = 0
        self._completed = 0
        self._shed = 0
        self._delayed = 0
        self._recompiles = 0
        self._latency_s: list[float] = []
        self._batch_rows: list[int] = []
        self._batch_requests: list[int] = []

    # -- client side -------------------------------------------------------

    async def submit(self, points) -> tuple[np.ndarray, np.ndarray]:
        """Answer one small request: (n, 2) points with
        1 <= n <= ``max_request_rows`` -> (mean (n,), var (n,)).

        Validation failures raise ``ValueError`` immediately (a malformed
        request must never poison a coalesced batch). A full admission
        queue sheds (``RequestRejected``) or delays per the config.
        """
        pts = np.asarray(points, np.float32)
        if pts.ndim != 2 or pts.shape[1] != 2:
            raise ValueError(f"request must be (n, 2) points, got shape {pts.shape}")
        if pts.shape[0] < 1:
            raise ValueError(f"request must hold at least one point, got {pts.shape[0]}")
        if pts.shape[0] > self.config.max_request_rows:
            raise RequestTooLarge(
                f"request rows must be in [1, {self.config.max_request_rows}] "
                f"(FrontDoorConfig.max_request_rows), got {pts.shape[0]} — "
                "send bulk batches straight to Server.submit"
            )
        if self._closing:
            raise RuntimeError("front door is closed")
        if self._broken is not None:
            raise RuntimeError("front door engine failed") from self._broken
        self._ensure_started()
        loop = asyncio.get_running_loop()
        self._arrived += 1
        if self._queue.full():
            if self.config.admission == "shed":
                self._shed += 1
                raise RequestRejected(
                    f"admission queue full ({self.config.queue_depth} requests)"
                )
            self._delayed += 1  # backpressure: the put below blocks
        req = _Request(pts, int(pts.shape[0]), loop.create_future(), loop.time())
        await self._queue.put(req)
        self._admitted += 1
        return await req.future

    @property
    def broken(self) -> bool:
        """True once the engine has died — every subsequent submit raises.
        Read-only introspection for health endpoints (``repro.net``)."""
        return self._broken is not None

    # -- lifecycle ---------------------------------------------------------

    def _ensure_started(self) -> None:
        if self._engine_task is None:
            self._queue = asyncio.Queue(maxsize=self.config.queue_depth)
            self._engine_task = asyncio.get_running_loop().create_task(
                self._engine(), name="frontdoor-engine"
            )

    async def close(self) -> None:
        """Drain the queue, finish in-flight batches, stop the engine.
        Idempotent; the SLO report stays readable afterwards."""
        if self._closing:
            if self._engine_task is not None:
                await self._engine_task
            return
        self._closing = True
        if self._engine_task is not None:
            await self._queue.put(_SENTINEL)
            await self._engine_task
            # a submit that raced past the closing check into the dead
            # queue must fail loudly, not hang its client forever
            self._fail_requests(self._drain_now(), RuntimeError("front door closed"))
        self._pool.shutdown(wait=True)
        self._dispatch_pool.shutdown(wait=True)

    async def __aenter__(self) -> "FrontDoor":
        self._ensure_started()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.close()

    # -- engine ------------------------------------------------------------

    async def _engine(self) -> None:
        """Double-buffered batching loop.

        Mirrors ``pipelined_request_loop``: batch t's blocking device
        sync runs CONCURRENTLY (a resolve task whose wait lives in the
        collect thread) while the engine gathers batch t+1 on the event
        loop and routes + dispatches it in the dispatch thread — so the
        window for batch t+1 FILLS during batch t's device time (that is
        what makes the batching continuous rather than stop-and-wait).
        The previous resolve is awaited before the next one starts: at
        most two batches in flight, results settled in dispatch order —
        and a lone batch resolves while the engine sleeps on an empty
        queue (the resolve must never wait for a NEXT window that may
        not come).

        If the engine itself dies (a routing/dispatch bug, a poisoned
        window), a hung client is worse than an error: every future the
        engine still owns — the window being dispatched plus everything
        queued — is rejected, and the door refuses new submits. The
        in-flight resolve settles its own futures (see ``_resolve``).
        """
        loop = asyncio.get_running_loop()
        pending: asyncio.Task | None = None
        draining = False
        reqs: list[_Request] = []
        try:
            while True:
                if draining:
                    reqs = self._drain_now()
                else:
                    gathered = await self._gather_window()
                    if gathered is None or self._saw_sentinel:
                        # close() posted the sentinel (between windows, or
                        # consumed mid-window): serve everything left
                        draining = True
                        reqs = (gathered or []) + self._drain_now()
                    else:
                        reqs = gathered
                if reqs:
                    batch = await loop.run_in_executor(
                        self._dispatch_pool, self._dispatch, reqs
                    )
                    reqs = []  # futures now owned by the batch's resolve
                    if pending is not None:
                        await pending
                    pending = loop.create_task(self._resolve(batch))
                elif draining:
                    if pending is not None:
                        await pending
                    if self._queue.empty():
                        return
        except Exception as err:
            self._broken = err
            self._fail_requests([*reqs, *self._drain_now()], err)
            if pending is not None:
                await pending  # the in-flight batch settles its own futures

    async def _gather_window(self) -> list[_Request] | None:
        """One batching window: blocks for the first request, then keeps
        coalescing until ``max_rows`` points are queued or ``max_wait_ms``
        elapsed since the window opened. Returns None on the close
        sentinel. The last admitted request may carry the window past
        max_rows by at most ``max_request_rows - 1`` points — requests
        are never split across batches."""
        item = await self._queue.get()
        if item is _SENTINEL:
            return None
        reqs, rows = [item], item.n
        loop = asyncio.get_running_loop()
        deadline = loop.time() + self.config.max_wait_ms / 1e3
        while rows < self.config.max_rows:
            timeout = deadline - loop.time()
            if timeout <= 0:
                break
            try:
                item = await asyncio.wait_for(self._queue.get(), timeout)
            except (TimeoutError, asyncio.TimeoutError):
                break
            if item is _SENTINEL:
                # serve what we have; the engine drains on the next turn
                self._saw_sentinel = True
                break
            reqs.append(item)
            rows += item.n
        return reqs

    def _fail_requests(
        self, reqs: list[_Request], err: BaseException
    ) -> None:
        """Reject every unresolved future in ``reqs`` — no client may be
        left awaiting a future nobody owns anymore."""
        for req in reqs:
            if not req.future.done():
                req.future.set_exception(err)

    def _drain_now(self) -> list[_Request]:
        """Everything already queued, without waiting (close path)."""
        reqs = []
        while not self._queue.empty():
            item = self._queue.get_nowait()
            if item is not _SENTINEL:
                reqs.append(item)
        return reqs

    def _policy_compiles(self) -> int:
        pol = self.server.policy
        return int(pol.compiles) if pol is not None else 0

    def _dispatch(self, reqs: list[_Request]) -> _Batch:
        """Coalesce + route + async-dispatch one window. Runs in the
        dispatch worker thread (the same work ``pipelined_request_loop``
        overlaps with the device) so a recompile never blocks the event
        loop; the per-batch counters it updates are read by ``report()``
        on the loop thread, hence the lock."""
        pts, sizes = routing.coalesce_requests([r.points for r in reqs])
        before = self._policy_compiles()
        handle = self._submit(self._route(pts))
        grew = self._policy_compiles() - before
        with self._stats_lock:
            if grew:  # this window burst the q_max high-water mark
                self._recompiles += grew
            self._batch_rows.append(int(sizes.sum()))
            self._batch_requests.append(len(reqs))
        return _Batch(reqs, sizes, handle)

    async def _resolve(self, batch: _Batch) -> None:
        """Block on batch's device results (collect thread), demux, and
        settle every request future. ANY failure between here and
        settlement — collect raising, a demux shape mismatch — must
        reject the whole batch rather than orphan its futures."""
        loop = asyncio.get_running_loop()
        try:
            mean, var = await loop.run_in_executor(
                self._pool, self._collect, batch.handle
            )
            outs = routing.demux_results(batch.sizes, mean, var)
        except Exception as err:
            self._fail_requests(batch.reqs, err)
            return
        now = loop.time()
        for req, out in zip(batch.reqs, outs, strict=True):
            if not req.future.done():
                req.future.set_result(out)
            self._latency_s.append(now - req.t_arrival)
        self._completed += len(batch.reqs)

    # -- SLO report --------------------------------------------------------

    def report(self) -> dict:
        """The front door's SLO record.

        Fields: ``requests`` (arrived / admitted / completed / shed /
        delayed), ``batches`` (count, rows and requests per coalesced
        batch), ``latency_ms`` (p50/p95/p99 END-TO-END per request:
        admission to future resolution, queueing included — unlike the
        per-batch service intervals of ``Server.stream``), ``recompiles``
        (windows that burst the streaming q_max high-water mark — each
        one recompiled the device program while the admission queue
        absorbed, delayed, or shed the concurrent arrivals), plus the
        policy stats, both configs, and the server's ``lifecycle``
        section (``Server.lifecycle``: swaps, active version, requests
        served and refit wall-clock per model version — the front door
        keeps admitting straight through a ``Server.swap``, and this is
        where that shows up).
        """
        with self._stats_lock:
            rows = np.asarray(self._batch_rows, np.int64)
            per = np.asarray(self._batch_requests, np.int64)
            recompiles = self._recompiles
        lat = np.sort(np.asarray(self._latency_s, np.float64)) * 1e3
        pct = (
            {
                "p50_ms": float(np.percentile(lat, 50)),
                "p95_ms": float(np.percentile(lat, 95)),
                "p99_ms": float(np.percentile(lat, 99)),
            }
            if lat.size
            else None
        )
        pol = self.server.policy
        return {
            "frontdoor_config": self.config.to_dict(),
            "serve_config": self.server.config.to_dict(),
            "requests": {
                "arrived": self._arrived,
                "admitted": self._admitted,
                "completed": self._completed,
                "shed": self._shed,
                "delayed": self._delayed,
            },
            "batches": {
                "count": int(rows.size),
                "rows_total": int(rows.sum()) if rows.size else 0,
                "rows_per_batch_mean": float(rows.mean()) if rows.size else 0.0,
                "rows_per_batch_max": int(rows.max()) if rows.size else 0,
                "requests_per_batch_mean": float(per.mean()) if per.size else 0.0,
            },
            "latency_ms": pct,
            "recompiles": recompiles,
            "qmax_policy": pol.stats() if pol is not None else None,
            "lifecycle": self.server.lifecycle(),
        }
