"""repro.api — the one front door: fit -> artifact -> serve.

The paper's in-situ lifecycle (train on the simulation, persist a
parsimonious per-partition artifact, answer queries post hoc) as three
objects instead of four flag-sprawled drivers:

    from repro import api

    fitted = api.fit(api.FitConfig(grid=8, m=10, train_iters=200), (x, y))
    fitted.save("runs/e3sm_t42/")                 # few KB per partition

    server = api.Server.from_artifact(
        "runs/e3sm_t42/",
        api.ServeConfig(mode="sharded", pipeline="pipelined",
                        router="two-level", backend="auto"),
    )
    mean, var = server.submit(queries)            # one batch
    report = server.stream(batches)               # stream + SLO report

and the loop form of it — the in-situ lifecycle (docs/lifecycle.md):

    new = api.refit(fitted, next_slice, api.RefitConfig(train_iters=150))
    new.save_step(store, t)                       # format=2 append-only store
    server.swap(new, version=t)                   # zero-downtime hot swap

Every serving scenario — replicated vs sharded cache, serial vs
overlapped pipeline, single vs two-level router, jnp vs Pallas kernel
lane, streaming vs fixed q_max — is a :class:`ServeConfig` field; both
configs validate on construction and round-trip through JSON, so a saved
artifact or a benchmark row carries the exact session that produced it.
The CLI entry points (``launch/serve.py --gp``, ``launch/serve_sharded``,
``benchmarks/bench_serve``, ``examples/serve_demo.py``) are thin shims
over this package. See docs/api.md.
"""
from repro.api.config import (
    FitConfig,
    FrontDoorConfig,
    NetConfig,
    RefitConfig,
    ServeConfig,
    load_session,
)
from repro.api.fitted import FittedPSVGP, fit, peek_fit_config, peek_steps, refit
from repro.api.frontdoor import FrontDoor, RequestRejected, RequestTooLarge
from repro.api.server import Server

__all__ = [
    "FitConfig",
    "FrontDoor",
    "FrontDoorConfig",
    "NetConfig",
    "RefitConfig",
    "RequestRejected",
    "RequestTooLarge",
    "ServeConfig",
    "FittedPSVGP",
    "Server",
    "fit",
    "load_session",
    "peek_fit_config",
    "peek_steps",
    "refit",
]
