"""``fit`` and ``FittedPSVGP`` — train once, persist a parsimonious
artifact, serve forever.

The paper's in-situ story (§5/§6): the simulation trains the partitioned
surface where the data lives, persists a FEW-KB-PER-PARTITION summary
(inducing-point parameters + cached posterior factors — never the raw
field), and analyses answer queries against that artifact post hoc. This
module is that unit of exchange:

    fitted = api.fit(FitConfig(grid=8, m=10), (x, y))   # train
    fitted.save("runs/e3sm_t42/")                        # persist
    ...
    server = api.Server.from_artifact("runs/e3sm_t42/", ServeConfig(...))

The artifact directory holds ``artifact.json`` (FitConfig + grid geometry,
plain JSON — readable before jax initializes, which the sharded serving
path needs to size its device mesh) next to the ``repro.checkpoint``
npz/msgpack pytree of the trained parameters and the
``repro.core.posterior.PosteriorCache`` factors. Loading rebuilds the
serving bundle exactly: cached-factor prediction is bitwise-identical to
the in-memory model (gated in tests/test_api.py), and no retraining or
refactorization happens on the load path.

A LOADED artifact is a serving object: ``predict`` and ``Server`` work in
full, but the training-time topology tables (neighbor distribution,
direction permutations) are not persisted — resume training from a
``checkpoint.save_train_state`` checkpoint instead.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.config import FitConfig, RefitConfig
from repro.checkpoint import load_pytree, save_pytree
from repro.checkpoint import store as artifact_store
from repro.core import posterior, psvgp, svgp
from repro.core.blend import predict_blended
from repro.core.partition import PartitionGrid, make_grid, partition_data
from repro.gp.covariances import CovarianceParams, make_covariance
from repro.optim import AdamState, adam_init

ARTIFACT_MANIFEST = "artifact.json"
ARTIFACT_FORMAT = 1
INPUT_DIM = 2  # spatial modeling: (lon, lat) / (x, y) coordinates


def _psvgp_config(cfg: FitConfig) -> psvgp.PSVGPConfig:
    """The one FitConfig -> PSVGPConfig mapping every entry point shares."""
    return psvgp.PSVGPConfig(
        svgp=svgp.SVGPConfig(
            num_inducing=cfg.m,
            input_dim=INPUT_DIM,
            covariance=cfg.covariance,
            jitter=cfg.jitter,
            whitened=cfg.whitened,
        ),
        delta=cfg.delta,
        batch_size=cfg.batch_size,
        learning_rate=cfg.learning_rate,
        comm=cfg.comm,
        seed=cfg.seed,
    )


def _zeros(*shape) -> jnp.ndarray:
    return jnp.zeros(shape, jnp.float32)


def _artifact_templates(cfg: FitConfig) -> tuple[svgp.SVGPParams, posterior.PosteriorCache]:
    """Shape/dtype templates for the checkpointed pytrees — derived from the
    FitConfig alone, which is why the manifest makes the artifact
    self-describing (``checkpoint.load_pytree`` restores INTO a template)."""
    P, m, d = cfg.num_partitions, cfg.m, INPUT_DIM
    params = svgp.SVGPParams(
        m_star=_zeros(P, m),
        s_tril=_zeros(P, m, m),
        z=_zeros(P, m, d),
        cov=CovarianceParams(log_lengthscale=_zeros(P, d), log_variance=_zeros(P)),
        log_beta=_zeros(P),
    )
    cache = posterior.PosteriorCache(
        z=_zeros(P, m, d),
        w=_zeros(P, m, m),
        u=_zeros(P, m, m),
        c=_zeros(P, m),
        cov=CovarianceParams(log_lengthscale=_zeros(P, d), log_variance=_zeros(P)),
        log_beta=_zeros(P),
    )
    return params, cache


def peek_fit_config(path: str, *, step: int | None = None) -> FitConfig:
    """Read an artifact's FitConfig WITHOUT touching the jax backend.

    The sharded serving path must force virtual host devices before the
    jax backend initializes, and it needs the artifact's grid side to know
    how many — this is the pure-JSON peek that makes
    ``Server.from_artifact`` / ``serve --gp-artifact`` possible.

    ``path`` may be a format=1 artifact directory or a format=2 store
    (``checkpoint.store``); for a store, ``step`` picks a committed
    simulation step (latest when None).
    """
    if artifact_store.is_store(path):
        path = artifact_store.step_dir(path, step)
    elif step is not None:
        raise ValueError(
            f"{path!r} is a single format-1 artifact, not a format-2 store "
            "— it has no step index to select from"
        )
    with open(os.path.join(path, ARTIFACT_MANIFEST)) as f:
        manifest = json.load(f)
    return FitConfig.from_dict(manifest["fit_config"])


def peek_steps(path: str) -> list[int]:
    """The committed step ids of a format=2 store, in ascending order —
    pure JSON, readable before the jax backend initializes (the ops
    dashboard's "what steps do we have" query)."""
    return artifact_store.store_steps(path)


class FittedPSVGP:
    """A trained partitioned surface: config + grid + params + cached factors.

    Construct via :func:`fit` or :meth:`load`; hand to ``api.Server`` to
    serve. Attributes:

      config: the :class:`FitConfig` that produced (or describes) it.
      grid:   the ``PartitionGrid`` the state was trained on.
      static / state: the ``repro.core.psvgp`` bundle (training-time
        ``static.dist``/``perms``/``p_dir`` are None on loaded artifacts).
      cache:  the P-stacked ``PosteriorCache`` — factorized lazily once
        (O(P m^3)) and reused by every prediction and by ``save``.
    """

    def __init__(
        self,
        config: FitConfig,
        grid: PartitionGrid,
        static: psvgp.PSVGPStatic,
        state: psvgp.PSVGPState,
        cache: posterior.PosteriorCache | None = None,
    ):
        self.config = config
        self.grid = grid
        self.static = static
        self.state = state
        self._cache = cache
        # lifecycle observability: wall-clock of the training (or warm
        # refit) that produced this state — None on loaded artifacts.
        # Server.lifecycle() surfaces it per served version.
        self.train_seconds: float | None = None
        self.refit_seconds: float | None = None
        # sharded-serving context (mesh, sharded cache, blend programs),
        # built and memoized by api.Server — kept here so several Server
        # views of one model (serial + pipelined lanes of a benchmark, say)
        # share one device placement and one compile per kernel backend.
        self._sharded_ctx: dict = {}

    @property
    def cache(self) -> posterior.PosteriorCache:
        if self._cache is None:
            self._cache = psvgp.posterior_cache(self.static, self.state)
            jax.block_until_ready(self._cache)
        return self._cache

    def predict(self, points) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Replicated blended prediction at (N, 2) points -> (mean, var),
        served from the cached factors (``blend.predict_blended``)."""
        return predict_blended(
            self.static, self.state, self.grid, points, cache=self.cache
        )

    def save(self, path: str) -> str:
        """Persist the serving artifact to ``path`` (a directory).

        Writes ``artifact.json`` (FitConfig + grid geometry) and the
        checkpointed {params, cache} pytrees. Returns ``path``.
        """
        os.makedirs(path, exist_ok=True)
        manifest = {
            "format": ARTIFACT_FORMAT,
            "fit_config": self.config.to_dict(),
            "grid": {
                "gx": int(self.grid.gx),
                "gy": int(self.grid.gy),
                "wrap_x": bool(self.grid.wrap_x),
                "x_edges": np.asarray(self.grid.x_edges, np.float64).tolist(),
                "y_edges": np.asarray(self.grid.y_edges, np.float64).tolist(),
            },
        }
        with open(os.path.join(path, ARTIFACT_MANIFEST), "w") as f:
            json.dump(manifest, f, indent=2)
        save_pytree(path, {"params": self.state.params, "cache": self.cache})
        return path

    def save_step(self, store_path: str, step: int, *, meta: dict | None = None) -> str:
        """Commit this model as simulation step ``step`` of a format=2
        append-only store (``repro.checkpoint.store``).

        Writes a FULL format=1 artifact into ``store_path/step_NNNNNNNN/``
        (``artifact.json`` + the {params, cache} pytrees — same layout as
        :meth:`save`), then atomically appends the step to ``store.json``
        — the index rewrite is the commit point, so a crash mid-save
        leaves only an unindexed orphan directory, never a half-indexed
        step. ``meta`` (plain-JSON: refit wall-clock, fit metrics, ...)
        rides along in the step's index entry. Steps are append-only and
        strictly increasing. Returns the step directory.
        """
        dirname = artifact_store.step_dir_name(step)
        full = os.path.join(store_path, dirname)
        committed = (
            artifact_store.store_steps(store_path)
            if artifact_store.is_store(store_path)
            else []
        )
        if int(step) in committed or (committed and int(step) <= max(committed)):
            # fail BEFORE overwriting the step directory the index points at
            raise ValueError(
                f"step {step} cannot be committed to the store at "
                f"{store_path!r} (committed steps: {committed}) — the store "
                "is append-only, strictly increasing"
            )
        self.save(full)
        if meta is None and self.refit_seconds is not None:
            meta = {"refit_s": self.refit_seconds}
        artifact_store.commit_step(store_path, step, dirname, meta)
        return full

    @classmethod
    def load(cls, path: str, *, step: int | None = None) -> "FittedPSVGP":
        """Restore a serving artifact — no retraining, no refactorization;
        the cached factors come back bitwise and the first prediction is
        O(Q m^2) like any other.

        ``path`` is either a format=1 directory written by :meth:`save`
        or a format=2 store written by :meth:`save_step`; for a store,
        ``step`` selects a committed simulation step (latest when None).
        """
        if artifact_store.is_store(path):
            path = artifact_store.step_dir(path, step)
        elif step is not None:
            raise ValueError(
                f"{path!r} is a single format-1 artifact, not a format-2 "
                "store — it has no step index to select from"
            )
        with open(os.path.join(path, ARTIFACT_MANIFEST)) as f:
            manifest = json.load(f)
        if manifest.get("format") != ARTIFACT_FORMAT:
            raise ValueError(
                f"artifact at {path!r} has format {manifest.get('format')!r}; "
                f"this build reads format {ARTIFACT_FORMAT}"
            )
        config = FitConfig.from_dict(manifest["fit_config"])
        g = manifest["grid"]
        grid = PartitionGrid(
            gx=int(g["gx"]),
            gy=int(g["gy"]),
            x_edges=np.asarray(g["x_edges"], np.float64),
            y_edges=np.asarray(g["y_edges"], np.float64),
            wrap_x=bool(g["wrap_x"]),
        )
        if grid.gx != config.grid or grid.gy != config.grid:
            raise ValueError(
                f"artifact grid {grid.gx}x{grid.gy} disagrees with its "
                f"FitConfig grid={config.grid} — corrupt manifest"
            )
        params_t, cache_t = _artifact_templates(config)
        tree = load_pytree(path, {"params": params_t, "cache": cache_t})
        pcfg = _psvgp_config(config)
        static = psvgp.PSVGPStatic(
            cfg=pcfg,
            cov_fn=make_covariance(config.covariance),
            dist=None,  # training-time tables are not part of the artifact
            perms=None,
            p_dir=None,
        )
        state = psvgp.PSVGPState(
            params=tree["params"],
            opt=AdamState(step=jnp.zeros((), jnp.int32), mu=None, nu=None),
            step=jnp.zeros((), jnp.int32),
        )
        return cls(config, grid, static, state, cache=tree["cache"])


def _extract_xy(data: Any) -> tuple[np.ndarray, Any]:
    """The one data-adapter ``fit`` and ``refit`` share: an object with
    ``.x``/``.y`` attributes or an ``(x, y)`` tuple -> validated arrays."""
    if hasattr(data, "x") and hasattr(data, "y"):
        x, y = data.x, data.y
    else:
        x, y = data
    x = np.asarray(x, np.float32)
    if x.ndim != 2 or x.shape[1] != INPUT_DIM:
        raise ValueError(f"data x must be (N, {INPUT_DIM}), got {x.shape}")
    return x, y


def _train(
    config: FitConfig, x: np.ndarray, y: Any, init_state: psvgp.PSVGPState | None
) -> FittedPSVGP:
    """The shared training recipe behind ``fit`` and ``refit``: grid from
    the data's bounding box, padded partition storage, ``psvgp.build``,
    then ``psvgp.fit`` for ``config.train_iters`` from either a fresh
    ``psvgp.init(PRNGKey(config.seed))`` state (``init_state=None`` — the
    ``fit()`` path) or the given warm state. One code path means the
    refit-from-scratch gate (refit == fit, bitwise) holds by construction.
    """
    grid = make_grid(x, config.grid, config.grid)
    pdata = partition_data(x, y, grid)
    pcfg = _psvgp_config(config)
    static = psvgp.build(pcfg, pdata)
    if init_state is None:
        init_state = psvgp.init(jax.random.PRNGKey(config.seed), pcfg, pdata)
    t0 = time.time()
    state = psvgp.fit(static, init_state, pdata, config.train_iters)
    jax.block_until_ready(state.params)
    fitted = FittedPSVGP(config, grid, static, state)
    fitted.train_seconds = time.time() - t0
    return fitted


def fit(config: FitConfig, data: Any, *, verbose: bool = False) -> FittedPSVGP:
    """Train a partitioned surface: ``FitConfig`` + data -> :class:`FittedPSVGP`.

    Args:
      config: the training recipe (grid side, m, delta, SGD budget, ...).
      data: either an object with ``.x`` (N, 2) and ``.y`` (N,) attributes
        (e.g. ``repro.data.spatial.SpatialDataset``) or an ``(x, y)`` tuple
        of array-likes.
      verbose: print the one-line training summary the serving drivers show.

    The recipe is exactly the pre-api driver path (grid from the data's
    bounding box, padded partition storage, ``psvgp.build``/``init``/
    ``fit`` with ``PRNGKey(config.seed)``) — a fixed seed reproduces the
    same trained state bitwise.
    """
    x, y = _extract_xy(data)
    fitted = _train(config, x, y, None)
    if verbose:
        print(
            f"trained P={fitted.grid.num_partitions} partitions, m={config.m}, "
            f"{config.train_iters} iters in {fitted.train_seconds:.1f} s"
        )
    return fitted


def refit(
    fitted: FittedPSVGP,
    data: Any,
    config: RefitConfig | None = None,
    *,
    verbose: bool = False,
) -> FittedPSVGP:
    """One in-situ step: update ``fitted`` against a NEW time slice.

    Args:
      fitted: the previous step's model (from :func:`fit`, a previous
        ``refit``, or ``FittedPSVGP.load``).
      data: the new slice — same shapes as :func:`fit` accepts: ``.x``
        (N, 2) / ``.y`` (N,), or an ``(x, y)`` tuple.
      config: the :class:`~repro.api.config.RefitConfig` step recipe
        (default ``RefitConfig()``: warm start, 50 iterations).

    Returns a NEW :class:`FittedPSVGP` (the input is never mutated — the
    old model keeps serving while this one trains; hand the result to
    ``Server.swap`` to go live). The new model reuses ``fitted.config``
    with ``train_iters`` (and optionally ``learning_rate``) replaced by
    the refit budget; the partition grid and topology tables are rebuilt
    from the new slice's bounding box.

    Semantics by ``config.init``:
      * ``"warm"`` — previous params AND Adam moments carry over (the
        moments are re-zeroed when ``reset_optimizer`` is set, or when
        the artifact was loaded from disk and has none); the SGD key
        sequence continues from the carried step counter, so a refit
        never replays step 0's mini-batches.
      * ``"scratch"`` — re-initialize from ``PRNGKey(seed)`` and run the
        SAME code path as :func:`fit`; with the full FitConfig budget
        this is bitwise-identical to ``fit()`` on the new slice (gated
        in tests/test_lifecycle.py).

    ``result.refit_seconds`` records the wall-clock of the step (the
    lifecycle SLO input; ``save_step`` persists it into the store index).
    """
    cfg = RefitConfig() if config is None else config
    fit_cfg = fitted.config
    if cfg.learning_rate is not None:
        fit_cfg = dataclasses.replace(fit_cfg, learning_rate=cfg.learning_rate)
    fit_cfg = dataclasses.replace(fit_cfg, train_iters=int(cfg.train_iters))
    x, y = _extract_xy(data)
    if cfg.init == "scratch":
        warm = None
    else:
        warm = fitted.state
        if cfg.reset_optimizer or warm.opt.mu is None:
            # loaded artifacts persist params only — no Adam moments
            warm = psvgp.PSVGPState(
                params=warm.params, opt=adam_init(warm.params), step=warm.step
            )
    new = _train(fit_cfg, x, y, warm)
    new.refit_seconds = new.train_seconds
    if verbose:
        print(
            f"refit ({cfg.init}) P={new.grid.num_partitions} partitions, "
            f"{fit_cfg.train_iters} iters in {new.refit_seconds:.1f} s"
        )
    return new
