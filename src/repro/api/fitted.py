"""``fit`` and ``FittedPSVGP`` — train once, persist a parsimonious
artifact, serve forever.

The paper's in-situ story (§5/§6): the simulation trains the partitioned
surface where the data lives, persists a FEW-KB-PER-PARTITION summary
(inducing-point parameters + cached posterior factors — never the raw
field), and analyses answer queries against that artifact post hoc. This
module is that unit of exchange:

    fitted = api.fit(FitConfig(grid=8, m=10), (x, y))   # train
    fitted.save("runs/e3sm_t42/")                        # persist
    ...
    server = api.Server.from_artifact("runs/e3sm_t42/", ServeConfig(...))

The artifact directory holds ``artifact.json`` (FitConfig + grid geometry,
plain JSON — readable before jax initializes, which the sharded serving
path needs to size its device mesh) next to the ``repro.checkpoint``
npz/msgpack pytree of the trained parameters and the
``repro.core.posterior.PosteriorCache`` factors. Loading rebuilds the
serving bundle exactly: cached-factor prediction is bitwise-identical to
the in-memory model (gated in tests/test_api.py), and no retraining or
refactorization happens on the load path.

A LOADED artifact is a serving object: ``predict`` and ``Server`` work in
full, but the training-time topology tables (neighbor distribution,
direction permutations) are not persisted — resume training from a
``checkpoint.save_train_state`` checkpoint instead.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.config import FitConfig
from repro.checkpoint import load_pytree, save_pytree
from repro.core import posterior, psvgp, svgp
from repro.core.blend import predict_blended
from repro.core.partition import PartitionGrid, make_grid, partition_data
from repro.gp.covariances import CovarianceParams, make_covariance
from repro.optim import AdamState

ARTIFACT_MANIFEST = "artifact.json"
ARTIFACT_FORMAT = 1
INPUT_DIM = 2  # spatial modeling: (lon, lat) / (x, y) coordinates


def _psvgp_config(cfg: FitConfig) -> psvgp.PSVGPConfig:
    """The one FitConfig -> PSVGPConfig mapping every entry point shares."""
    return psvgp.PSVGPConfig(
        svgp=svgp.SVGPConfig(
            num_inducing=cfg.m,
            input_dim=INPUT_DIM,
            covariance=cfg.covariance,
            jitter=cfg.jitter,
            whitened=cfg.whitened,
        ),
        delta=cfg.delta,
        batch_size=cfg.batch_size,
        learning_rate=cfg.learning_rate,
        comm=cfg.comm,
        seed=cfg.seed,
    )


def _zeros(*shape) -> jnp.ndarray:
    return jnp.zeros(shape, jnp.float32)


def _artifact_templates(cfg: FitConfig) -> tuple[svgp.SVGPParams, posterior.PosteriorCache]:
    """Shape/dtype templates for the checkpointed pytrees — derived from the
    FitConfig alone, which is why the manifest makes the artifact
    self-describing (``checkpoint.load_pytree`` restores INTO a template)."""
    P, m, d = cfg.num_partitions, cfg.m, INPUT_DIM
    params = svgp.SVGPParams(
        m_star=_zeros(P, m),
        s_tril=_zeros(P, m, m),
        z=_zeros(P, m, d),
        cov=CovarianceParams(log_lengthscale=_zeros(P, d), log_variance=_zeros(P)),
        log_beta=_zeros(P),
    )
    cache = posterior.PosteriorCache(
        z=_zeros(P, m, d),
        w=_zeros(P, m, m),
        u=_zeros(P, m, m),
        c=_zeros(P, m),
        cov=CovarianceParams(log_lengthscale=_zeros(P, d), log_variance=_zeros(P)),
        log_beta=_zeros(P),
    )
    return params, cache


def peek_fit_config(path: str) -> FitConfig:
    """Read an artifact's FitConfig WITHOUT touching jax.

    The sharded serving path must force virtual host devices before the
    jax backend initializes, and it needs the artifact's grid side to know
    how many — this is the pure-JSON peek that makes
    ``Server.from_artifact`` / ``serve --gp-artifact`` possible.
    """
    with open(os.path.join(path, ARTIFACT_MANIFEST)) as f:
        manifest = json.load(f)
    return FitConfig.from_dict(manifest["fit_config"])


class FittedPSVGP:
    """A trained partitioned surface: config + grid + params + cached factors.

    Construct via :func:`fit` or :meth:`load`; hand to ``api.Server`` to
    serve. Attributes:

      config: the :class:`FitConfig` that produced (or describes) it.
      grid:   the ``PartitionGrid`` the state was trained on.
      static / state: the ``repro.core.psvgp`` bundle (training-time
        ``static.dist``/``perms``/``p_dir`` are None on loaded artifacts).
      cache:  the P-stacked ``PosteriorCache`` — factorized lazily once
        (O(P m^3)) and reused by every prediction and by ``save``.
    """

    def __init__(
        self,
        config: FitConfig,
        grid: PartitionGrid,
        static: psvgp.PSVGPStatic,
        state: psvgp.PSVGPState,
        cache: posterior.PosteriorCache | None = None,
    ):
        self.config = config
        self.grid = grid
        self.static = static
        self.state = state
        self._cache = cache
        # sharded-serving context (mesh, sharded cache, blend programs),
        # built and memoized by api.Server — kept here so several Server
        # views of one model (serial + pipelined lanes of a benchmark, say)
        # share one device placement and one compile per kernel backend.
        self._sharded_ctx: dict = {}

    @property
    def cache(self) -> posterior.PosteriorCache:
        if self._cache is None:
            self._cache = psvgp.posterior_cache(self.static, self.state)
            jax.block_until_ready(self._cache)
        return self._cache

    def predict(self, points) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Replicated blended prediction at (N, 2) points -> (mean, var),
        served from the cached factors (``blend.predict_blended``)."""
        return predict_blended(
            self.static, self.state, self.grid, points, cache=self.cache
        )

    def save(self, path: str) -> str:
        """Persist the serving artifact to ``path`` (a directory).

        Writes ``artifact.json`` (FitConfig + grid geometry) and the
        checkpointed {params, cache} pytrees. Returns ``path``.
        """
        os.makedirs(path, exist_ok=True)
        manifest = {
            "format": ARTIFACT_FORMAT,
            "fit_config": self.config.to_dict(),
            "grid": {
                "gx": int(self.grid.gx),
                "gy": int(self.grid.gy),
                "wrap_x": bool(self.grid.wrap_x),
                "x_edges": np.asarray(self.grid.x_edges, np.float64).tolist(),
                "y_edges": np.asarray(self.grid.y_edges, np.float64).tolist(),
            },
        }
        with open(os.path.join(path, ARTIFACT_MANIFEST), "w") as f:
            json.dump(manifest, f, indent=2)
        save_pytree(path, {"params": self.state.params, "cache": self.cache})
        return path

    @classmethod
    def load(cls, path: str) -> "FittedPSVGP":
        """Restore a serving artifact saved by :meth:`save` — no
        retraining, no refactorization; the cached factors come back
        bitwise and the first prediction is O(Q m^2) like any other."""
        with open(os.path.join(path, ARTIFACT_MANIFEST)) as f:
            manifest = json.load(f)
        if manifest.get("format") != ARTIFACT_FORMAT:
            raise ValueError(
                f"artifact at {path!r} has format {manifest.get('format')!r}; "
                f"this build reads format {ARTIFACT_FORMAT}"
            )
        config = FitConfig.from_dict(manifest["fit_config"])
        g = manifest["grid"]
        grid = PartitionGrid(
            gx=int(g["gx"]),
            gy=int(g["gy"]),
            x_edges=np.asarray(g["x_edges"], np.float64),
            y_edges=np.asarray(g["y_edges"], np.float64),
            wrap_x=bool(g["wrap_x"]),
        )
        if grid.gx != config.grid or grid.gy != config.grid:
            raise ValueError(
                f"artifact grid {grid.gx}x{grid.gy} disagrees with its "
                f"FitConfig grid={config.grid} — corrupt manifest"
            )
        params_t, cache_t = _artifact_templates(config)
        tree = load_pytree(path, {"params": params_t, "cache": cache_t})
        pcfg = _psvgp_config(config)
        static = psvgp.PSVGPStatic(
            cfg=pcfg,
            cov_fn=make_covariance(config.covariance),
            dist=None,  # training-time tables are not part of the artifact
            perms=None,
            p_dir=None,
        )
        state = psvgp.PSVGPState(
            params=tree["params"],
            opt=AdamState(step=jnp.zeros((), jnp.int32), mu=None, nu=None),
            step=jnp.zeros((), jnp.int32),
        )
        return cls(config, grid, static, state, cache=tree["cache"])


def fit(config: FitConfig, data: Any, *, verbose: bool = False) -> FittedPSVGP:
    """Train a partitioned surface: ``FitConfig`` + data -> :class:`FittedPSVGP`.

    Args:
      config: the training recipe (grid side, m, delta, SGD budget, ...).
      data: either an object with ``.x`` (N, 2) and ``.y`` (N,) attributes
        (e.g. ``repro.data.spatial.SpatialDataset``) or an ``(x, y)`` tuple
        of array-likes.
      verbose: print the one-line training summary the serving drivers show.

    The recipe is exactly the pre-api driver path (grid from the data's
    bounding box, padded partition storage, ``psvgp.build``/``init``/
    ``fit`` with ``PRNGKey(config.seed)``) — a fixed seed reproduces the
    same trained state bitwise.
    """
    if hasattr(data, "x") and hasattr(data, "y"):
        x, y = data.x, data.y
    else:
        x, y = data
    x = np.asarray(x, np.float32)
    if x.ndim != 2 or x.shape[1] != INPUT_DIM:
        raise ValueError(f"data x must be (N, {INPUT_DIM}), got {x.shape}")
    grid = make_grid(x, config.grid, config.grid)
    pdata = partition_data(x, y, grid)
    pcfg = _psvgp_config(config)
    static = psvgp.build(pcfg, pdata)
    state = psvgp.init(jax.random.PRNGKey(config.seed), pcfg, pdata)
    t0 = time.time()
    state = psvgp.fit(static, state, pdata, config.train_iters)
    jax.block_until_ready(state.params)
    if verbose:
        print(
            f"trained P={grid.num_partitions} partitions, m={config.m}, "
            f"{config.train_iters} iters in {time.time() - t0:.1f} s"
        )
    return FittedPSVGP(config, grid, static, state)
