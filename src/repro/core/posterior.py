"""Cached-posterior prediction — the serving-grade fast path.

Training optimizes q(u) per partition; once it converges, every prediction
against that posterior re-derives the same Kmm factorization. The seed code
paid that O(m^3) Cholesky (plus two triangular solves) on EVERY call —
``blend.predict_blended`` even paid it per query point per corner model.
Distributed low-rank spatial models get their serving speed precisely from
precomputing shared factors once and reusing them across predictions
(Katzfuss & Hammerling 2014; Peruzzi et al. 2020 use the same
cache-the-factorization pattern for partitioned prediction).

``PosteriorCache`` stores, per local model, everything S- and Kmm-dependent
that predictions reuse:

    w    (m, m)  Lmm^{-1}, Lmm = chol(Kmm+jI)  q_diag_i = ||W k_i||^2
    u    (m, m)  Sl^T A                        s_diag_i = ||U k_i||^2
    c    (m,)    projected variational mean    fmean_i  = k_i^T c

with A = Kmm^{-1}, c = Kmm^{-1} m_star for the standard parameterization and
A = Lmm^{-1}, c = Lmm^{-T} m_star for the whitened one — the whitening is
folded INTO the factors, so prediction itself is parameterization-agnostic.
A prediction at Q points then costs two (Q, m) x (m, m) matmuls and an
O(Q m) mean path instead of Q Choleskys: O(Q m^2) total, MXU-shaped.

Every function is vmap-friendly; the PSVGP layer stacks caches on a leading
partition axis (``build_cache_stacked``). The fused Pallas kernel variant of
``predict_cached`` lives in ``repro.kernels.predict`` (dispatch in
``kernels/ops.py``).

This module also owns the shared projection primitives (``s_chol``,
``kmm_chol``, ``projection``) that the training-time ELBO in
``repro.core.svgp`` builds on — one implementation of eq. (3)'s linear
algebra, used by both the training and the serving path.
"""
from __future__ import annotations

from collections.abc import Callable
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl

from repro.analysis.contracts import contract
from repro.gp.covariances import CovarianceParams, kdiag


class PosteriorCache(NamedTuple):
    """Per-model cached prediction factors (leaves stack/vmap over P).

    Only factors a prediction actually consumes live here — the blend path
    gathers every leaf per query point, so dead weight (e.g. Lmm itself,
    recoverable as w^{-1}) would be pure gather traffic on the hot path."""

    z: jnp.ndarray  # (m, d) inducing locations
    w: jnp.ndarray  # (m, m) Lmm^{-1}, Lmm = chol(Kmm + jitter I)
    u: jnp.ndarray  # (m, m) S-dependent variance factor (see module doc)
    c: jnp.ndarray  # (m,)   projected variational mean
    cov: CovarianceParams
    log_beta: jnp.ndarray  # ()


def s_chol(s_tril: jnp.ndarray) -> jnp.ndarray:
    """Constrained Cholesky factor of S_star: strictly-lower + exp(diag)."""
    ltri = jnp.tril(s_tril, -1)
    return ltri + jnp.diag(jnp.exp(jnp.diagonal(s_tril)))


def kmm_chol(params: Any, cov_fn: Callable, jitter: float) -> jnp.ndarray:
    """chol(Kmm + jitter I) for an SVGPParams-like bundle."""
    m = params.z.shape[0]
    kmm = cov_fn(params.cov, params.z, params.z)
    return jnp.linalg.cholesky(kmm + jitter * jnp.eye(m, dtype=kmm.dtype))


def projection(
    params: Any, cov_fn: Callable, x: jnp.ndarray, jitter: float, use_pallas: bool
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Shared O(B m^2) training hot path (the ELBO's eq. 3 projection).

    Returns (lk, kdiag_res, lmm) where
      lk   (m, B): Lmm^{-1} K_mz^T   (so a_i = Lmm^{-T} lk_i, A = Kmm^{-1}k_i)
      kdiag_res (B,): k~_ii = k_ii - ||lk_i||^2   (eq. 3's  k~ term)
      lmm  (m, m): chol(Kmm)
    When ``use_pallas`` is set, K(X,Z) and the triangular projection run in
    the fused Pallas kernel (repro.kernels); otherwise pure jnp.
    """
    lmm = kmm_chol(params, cov_fn, jitter)
    if use_pallas:
        from repro.kernels import ops as kops

        knm, lk_t, q_diag = kops.svgp_projection(
            x, params.z, params.cov.log_lengthscale, params.cov.log_variance, lmm
        )
        del knm
        lk = lk_t.T  # (m, B)
        kd = kdiag(params.cov, x) - q_diag
    else:
        knm = cov_fn(params.cov, x, params.z)  # (B, m)
        lk = jsl.solve_triangular(lmm, knm.T, lower=True)  # (m, B)
        kd = kdiag(params.cov, x) - jnp.sum(lk * lk, axis=0)
    return lk, kd, lmm


def build_cache(
    params: Any,
    cov_fn: Callable,
    *,
    jitter: float = 1e-5,
    whitened: bool = False,
) -> PosteriorCache:
    """Precompute the prediction factors for one model — O(m^3), once."""
    lmm = kmm_chol(params, cov_fn, jitter)
    m = lmm.shape[0]
    w = jsl.solve_triangular(lmm, jnp.eye(m, dtype=lmm.dtype), lower=True)
    sl = s_chol(params.s_tril)
    if whitened:
        # u = L v, q(v)=N(m_star, S): fmean = k^T Lmm^{-T} m_star
        c = jsl.solve_triangular(lmm.T, params.m_star, lower=False)
        u = sl.T @ w
    else:
        c = jsl.solve_triangular(
            lmm.T, jsl.solve_triangular(lmm, params.m_star, lower=True), lower=False
        )
        u = sl.T @ (w.T @ w)  # Sl^T Kmm^{-1}
    return PosteriorCache(
        z=params.z, w=w, u=u, c=c, cov=params.cov, log_beta=params.log_beta
    )


def predict_cached(
    cache: PosteriorCache,
    cov_fn: Callable,
    xstar: jnp.ndarray,
    *,
    include_noise: bool = False,
    use_pallas: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Predictive mean/variance at xstar (Q, d) from cached factors.

    fmean = K(x*, Z) c
    fvar  = k_** - ||W k_*||^2 + ||U k_*||^2     (clamped to >= 1e-12)

    ``use_pallas`` routes K(x*,Z) + both projections + the reductions
    through the fused prediction kernel — RBF covariance only, and that is
    VALIDATED: the kernel computes the RBF whatever ``cov_fn`` is, so a
    non-RBF covariance raises instead of silently returning RBF answers.
    """
    if use_pallas:
        from repro.kernels import ops as kops

        fmean, fvar = kops.posterior_predict(
            xstar, cache.z, cache.cov.log_lengthscale, cache.cov.log_variance,
            cache.w, cache.u, cache.c, cov_fn=cov_fn,
        )
    else:
        knm = cov_fn(cache.cov, xstar, cache.z)  # (Q, m)
        fmean = knm @ cache.c
        qd = jnp.sum((knm @ cache.w.T) ** 2, axis=-1)
        sd = jnp.sum((knm @ cache.u.T) ** 2, axis=-1)
        fvar = kdiag(cache.cov, xstar) - qd + sd
    fvar = jnp.maximum(fvar, 1e-12)
    if include_noise:
        fvar = fvar + jnp.exp(-cache.log_beta)
    return fmean, fvar


def build_cache_stacked(
    params: Any,
    cov_fn: Callable,
    *,
    jitter: float = 1e-5,
    whitened: bool = False,
) -> PosteriorCache:
    """vmap of ``build_cache`` over a leading partition axis — one batched
    O(P m^3) factorization for the whole partitioned model.

    Args:
      params: SVGPParams-like pytree whose every leaf has a leading (P, ...)
        partition axis (``psvgp.PSVGPState.params``).
      cov_fn / jitter / whitened: as in ``build_cache``.

    Returns a ``PosteriorCache`` with leaves z (P, m, d), w/u (P, m, m),
    c (P, m), cov (P, d)/(P,), log_beta (P,). The leading axis is what the
    sharded serving path partitions one-per-device over the mesh
    (``sharding.gp_stacked_pspecs`` / ``launch.serve_sharded``)."""
    return jax.vmap(
        lambda p: build_cache(p, cov_fn, jitter=jitter, whitened=whitened)
    )(params)


def predict_cached_stacked(
    cache: PosteriorCache,
    cov_fn: Callable,
    xstar: jnp.ndarray,
    *,
    include_noise: bool = False,
    use_pallas: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Each stacked model predicts at its own rows of xstar.

    Args:
      cache: P-stacked ``PosteriorCache`` (``build_cache_stacked``).
      cov_fn: covariance function (``repro.gp.covariances``).
      xstar: (P, Q, d) — model p sees only row p's Q query points.
      include_noise / use_pallas: as in ``predict_cached``.

    Returns (fmean (P, Q), fvar (P, Q)); fvar clamped to >= 1e-12."""
    return jax.vmap(
        lambda ca, xq: predict_cached(
            ca, cov_fn, xq, include_noise=include_noise, use_pallas=use_pallas
        )
    )(cache, xstar)


def resolve_slot_backend(use_pallas: bool, backend: str | None) -> str:
    """Normalize the (legacy ``use_pallas`` bool, ``backend`` name) pair to
    one kernel lane: "ref" | "pallas" | "fused". The ONE definition of the
    mapping — :func:`predict_cached_slots` and
    ``serve_sharded.make_sharded_blend`` both validate through it, so the
    lane vocabulary cannot drift between the prediction and serving layers.
    """
    if backend is None:
        return "fused" if use_pallas else "ref"
    if use_pallas:
        raise ValueError("pass either use_pallas or backend=, not both")
    if backend not in ("ref", "pallas", "fused"):
        raise ValueError(f"backend must be 'ref'|'pallas'|'fused', got {backend!r}")
    return backend


@contract(
    args={"xslots": "(S, Q, D)"},
    returns=("(S, Q)", "(S, Q)"),
    invariants=("outputs-f32",),
)
def predict_cached_slots(
    cache: PosteriorCache,
    cov_fn: Callable,
    xslots: jnp.ndarray,
    *,
    include_noise: bool = False,
    use_pallas: bool = False,
    backend: str | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """ONE model evaluated on S stacked query blocks: xslots (S, Q, d).

    This is the device-side serving hot path: the sharded blend evaluates
    the local model on all 9 halo slots at once. Three kernel lanes,
    selected by ``backend`` (the ``repro.api.ServeConfig`` vocabulary;
    the legacy ``use_pallas`` bool maps True -> "fused", False -> "ref"
    and may not be combined with an explicit ``backend``):

      "ref"    — pure jnp: a vmap of :func:`predict_cached` over the slot
                 axis (every covariance; the XLA-compiled CPU lane).
      "pallas" — the fused single-block Pallas predict kernel
                 (``kernels.ops.posterior_predict``) through a (S*Q, d)
                 reshape round-trip: one launch, but the factor tiles are
                 re-staged per q-block across the flattened stack.
      "fused"  — a SINGLE slot-stacked Pallas launch whose grid spans
                 (S x q-blocks) with W/U/c resident across the whole grid
                 (``repro.kernels.predict.posterior_predict_slots_pallas``)
                 — no reshape round-trip, no per-slot re-staging; the TPU
                 production lane.

    Returns (fmean (S, Q), fvar (S, Q)); fvar clamped to >= 1e-12.
    Non-RBF covariances raise on the Pallas lanes (see
    ``repro.kernels.ops.require_rbf``).
    """
    backend = resolve_slot_backend(use_pallas, backend)
    if backend == "ref":
        return jax.vmap(
            lambda xs: predict_cached(cache, cov_fn, xs, include_noise=include_noise)
        )(xslots)
    from repro.kernels import ops as kops

    if backend == "fused":
        fmean, fvar = kops.posterior_predict_slots(
            xslots, cache.z, cache.cov.log_lengthscale, cache.cov.log_variance,
            cache.w, cache.u, cache.c, cov_fn=cov_fn,
        )
    else:  # "pallas": flatten the stack through the single-block kernel
        S, Q, d = xslots.shape
        fmean, fvar = kops.posterior_predict(
            xslots.reshape(S * Q, d), cache.z,
            cache.cov.log_lengthscale, cache.cov.log_variance,
            cache.w, cache.u, cache.c, cov_fn=cov_fn,
        )
        fmean, fvar = fmean.reshape(S, Q), fvar.reshape(S, Q)
    fvar = jnp.maximum(fvar, 1e-12)
    if include_noise:
        fvar = fvar + jnp.exp(-cache.log_beta)
    return fmean, fvar


def take_cache(cache: PosteriorCache, ids: jnp.ndarray) -> PosteriorCache:
    """Gather stacked cache rows (e.g. one per query point or edge).

    ``ids`` is any int array; leaf p-axes are indexed by it, so the result
    stacks cache ids.shape[0] times (duplicates allowed — the blend path
    gathers one row per query per corner). The sharded serving path never
    calls this on the factors (that would be the all-gather it exists to
    avoid); it is the replicated path's tool."""
    return jax.tree.map(lambda a: jnp.take(a, ids, axis=0), cache)
