"""Post-hoc blended prediction — beyond-paper (the paper's §6 names
"post-hoc methods to increase boundary smoothness, for example based on
the patchwork kriging approach" as future work; this is the lightweight
variant of that idea).

Instead of asking ONE local model for the prediction at x, the stitched
surface blends the (up to) four models whose partition centers surround x,
with bilinear weights in cell-center coordinates. The blend is continuous
across partition boundaries BY CONSTRUCTION (weights of a model go to zero
exactly where its neighbor takes over), so the boundary-RMSD discontinuity
of ISVGP/PSVGP drops to zero at stitch time — at ZERO training cost and
with no extra communication (each model still predicts only near its own
territory; evaluating a neighbor's model at a point near the shared
boundary is local to that neighbor's rank in production).

Variances combine as the blend of second moments (a conservative mixture
bound): var = sum_i w_i (var_i + mean_i^2) - mean^2.

Serving path: evaluation runs against a ``repro.core.posterior``
PosteriorCache — the P local posteriors are factorized ONCE (O(P m^3),
amortized across every query batch; pass ``cache=`` to amortize across
calls too), and each corner is then one batched vmap of O(m^2) cached-
factor evaluations. The seed implementation re-ran a full Cholesky per
query point per corner; at the paper's P=400 / m=25 scale the cached path
is the difference between an analysis script and a serving endpoint (see
benchmarks/bench_predict.py, launch/serve.py --gp).
"""
from __future__ import annotations

import functools
from collections.abc import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import posterior
from repro.core.partition import PartitionGrid
from repro.core.psvgp import PSVGPState, PSVGPStatic, posterior_cache


def corner_ids_weights(grid: PartitionGrid, pts: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """The 4 surrounding partition models of each point + bilinear weights.

    This is the geometric core of both the blended predictor below and the
    distributed query router (``repro.core.routing``): a point x is blended
    from the (up to) four partitions whose CELL CENTERS surround it.

    Args:
      grid: the partition grid topology.
      pts: (N, 2) query coordinates (host numpy; routing is host-side).

    Returns:
      ids (N, 4) int64: flat partition ids of the corner models, ordered
        [lower-left, lower-right, upper-left, upper-right] in cell-center
        coordinates. At domain edges the out-of-grid corners are CLIPPED
        onto the boundary cells, so ids may repeat within a row — the
        bilinear weights of clipped duplicates are consistent (they sum to
        the same total mass; the blend degenerates to linear/nearest at
        edges by construction).
      w (N, 4) float32: bilinear weights, >= 0, summing to 1 per row.

    Every corner id is always within one grid step (including diagonals) of
    the cell that OWNS the point — the invariant that lets distributed
    serving resolve corners with a 1-hop halo exchange (see
    ``repro.core.routing.halo_ids``).
    """
    xe, ye = grid.x_edges, grid.y_edges
    cw = xe[1] - xe[0]
    ch = ye[1] - ye[0]
    # cell-center coordinates: center of cell (i) is at x0 + (i + .5) cw
    u = (pts[:, 0] - xe[0]) / cw - 0.5
    v = (pts[:, 1] - ye[0]) / ch - 0.5
    ix0 = np.clip(np.floor(u).astype(np.int64), 0, grid.gx - 1)
    iy0 = np.clip(np.floor(v).astype(np.int64), 0, grid.gy - 1)
    ix1 = np.clip(ix0 + 1, 0, grid.gx - 1)
    iy1 = np.clip(iy0 + 1, 0, grid.gy - 1)
    fx = np.clip(u - ix0, 0.0, 1.0)
    fy = np.clip(v - iy0, 0.0, 1.0)
    ids = np.stack(
        [
            iy0 * grid.gx + ix0,
            iy0 * grid.gx + ix1,
            iy1 * grid.gx + ix0,
            iy1 * grid.gx + ix1,
        ],
        axis=1,
    )  # (N, 4)
    w = np.stack(
        [(1 - fx) * (1 - fy), fx * (1 - fy), (1 - fx) * fy, fx * fy], axis=1
    ).astype(np.float32)
    return ids, w


@functools.partial(jax.jit, static_argnames=("cov_fn",))
def _blend_eval(
    cache: posterior.PosteriorCache,
    cov_fn: Callable,
    xq: jnp.ndarray,
    ids: jnp.ndarray,
    w: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """All N points against all 4 corners — cached factors only, no
    factorization anywhere inside."""

    def eval_corner(c):
        cache_c = posterior.take_cache(cache, ids[:, c])  # leaves (N, ...)

        def one(ca, xi):
            mean, var = posterior.predict_cached(ca, cov_fn, xi[None])
            return mean[0], var[0]

        return jax.vmap(one)(cache_c, xq)

    means, varis = zip(*(eval_corner(c) for c in range(4)), strict=True)
    means = jnp.stack(means, axis=1)  # (N, 4)
    varis = jnp.stack(varis, axis=1)
    mean = jnp.sum(w * means, axis=1)
    second = jnp.sum(w * (varis + means**2), axis=1)
    var = jnp.maximum(second - mean**2, 1e-12)
    return mean, var


def predict_blended(
    static: PSVGPStatic,
    state: PSVGPState,
    grid: PartitionGrid,
    points: jnp.ndarray,
    cache: posterior.PosteriorCache | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Continuous stitched prediction at arbitrary points.

    Args:
      static / state: the trained PSVGP bundle (``psvgp.build`` / ``fit``).
      grid: partition grid the state was trained on.
      points: (N, 2) query coordinates (any array-like; moved to host).
      cache: optional precomputed ``psvgp.posterior_cache``. Pass it when
        issuing repeated query batches against one trained state — the
        serving loop in ``repro.launch.serve --gp`` does exactly that.

    Returns:
      (mean (N,), var (N,)): the bilinear 4-corner blend of the local
      posteriors. var >= 1e-12 (clamped), WITHOUT observation noise.

    This is the replicated serving path: the full cache is resident on the
    calling host. The sharded multi-host equivalent (same math, cache
    factors partitioned over a device mesh) is
    ``repro.launch.serve_sharded``."""
    pts = np.asarray(points, np.float32)
    ids, w = corner_ids_weights(grid, pts)
    if cache is None:
        cache = posterior_cache(static, state)
    return _blend_eval(cache, static.cov_fn, jnp.asarray(pts), jnp.asarray(ids), jnp.asarray(w))
