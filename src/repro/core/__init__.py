"""Core of the reproduction: SVGP + the paper's PSVGP distribution scheme."""
from repro.core.svgp import SVGPConfig, SVGPParams, init_svgp_params, elbo, predict, q_f

__all__ = ["SVGPConfig", "SVGPParams", "init_svgp_params", "elbo", "predict", "q_f"]
