"""Evaluation metrics from the paper's §5.

* RMSPE over all observations — each partition's model predicts its own
  data (in-sample, as the paper reports).
* Boundary RMSD — root mean square difference between the predictions of
  neighboring local models at probe locations equally spaced along shared
  boundaries (the paper uses 17,556 such locations for the 20x20 grid).

All metrics accept an optional precomputed ``PosteriorCache`` (see
``repro.core.posterior``); pass one when evaluating several metrics against
the same trained state so the P Cholesky factorizations run once, not once
per metric.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.neighbors import BoundaryProbes
from repro.core.partition import PartitionedData
from repro.core.posterior import PosteriorCache
from repro.core.psvgp import (
    PSVGPState,
    PSVGPStatic,
    posterior_cache,
    predict_at_partitions,
    predict_local,
)


def rmspe(
    static: PSVGPStatic,
    state: PSVGPState,
    data: PartitionedData,
    cache: PosteriorCache | None = None,
) -> jnp.ndarray:
    """Global in-sample root-mean-square prediction error."""
    mean, _ = predict_local(static, state, data.x, cache=cache)  # (P, n_max)
    se = (mean - data.y) ** 2 * data.mask
    return jnp.sqrt(jnp.sum(se) / jnp.maximum(jnp.sum(data.mask), 1.0))


def boundary_rmsd(
    static: PSVGPStatic,
    state: PSVGPState,
    probes: BoundaryProbes,
    cache: PosteriorCache | None = None,
) -> jnp.ndarray:
    """RMS disagreement between the two models sharing each boundary."""
    if cache is None:
        cache = posterior_cache(static, state)
    mean_l, _ = predict_at_partitions(static, state, probes.left, probes.points, cache=cache)
    mean_r, _ = predict_at_partitions(static, state, probes.right, probes.points, cache=cache)
    return jnp.sqrt(jnp.mean((mean_l - mean_r) ** 2))


def per_partition_rmspe(
    static: PSVGPStatic,
    state: PSVGPState,
    data: PartitionedData,
    cache: PosteriorCache | None = None,
) -> jnp.ndarray:
    """(P,) in-sample RMSPE per partition (diagnostic; pole partitions in the
    paper are the hard ones)."""
    mean, _ = predict_local(static, state, data.x, cache=cache)
    se = (mean - data.y) ** 2 * data.mask
    cnt = jnp.maximum(jnp.sum(data.mask, axis=1), 1.0)
    return jnp.sqrt(jnp.sum(se, axis=1) / cnt)


def holdout_rmspe(
    static: PSVGPStatic,
    state: PSVGPState,
    x_hold: jnp.ndarray,
    y_hold: jnp.ndarray,
    mask_hold: jnp.ndarray,
    cache: PosteriorCache | None = None,
) -> jnp.ndarray:
    """Out-of-sample RMSPE on held-out points already routed to partitions
    (x_hold: (P, Q, d)) — beyond-paper diagnostic (the paper reports
    in-sample only)."""
    mean, _ = predict_local(static, state, x_hold, cache=cache)
    se = (mean - y_hold) ** 2 * mask_hold
    return jnp.sqrt(jnp.sum(se) / jnp.maximum(jnp.sum(mask_hold), 1.0))
