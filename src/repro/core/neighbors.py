"""Neighborhood topology N_j (paper eq. 5) and boundary probe points.

Neighbors share an edge (4-neighborhood on the grid): this matches the
paper's "partitions j and k share a boundary" and its balanced-grid formula
1 - 2 d delta / (2d + 1) with d = 2 spatial dimensions (4 neighbors + self).

Slot convention used across the sampler and both comm modes:
    slot 0 = self, 1 = +x (east), 2 = -x (west), 3 = +y (north), 4 = -y (south)
Missing neighbors (domain edges, when wrap is off) are -1.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np
import jax.numpy as jnp

from repro.core.partition import PartitionGrid

NUM_SLOTS = 5  # self + 4 directions
DIR_NAMES = ("self", "east", "west", "north", "south")


def neighbor_table(grid: PartitionGrid) -> np.ndarray:
    """(P, 5) int32: [self, east, west, north, south], -1 where absent."""
    P = grid.num_partitions
    tbl = np.full((P, NUM_SLOTS), -1, np.int32)
    for p in range(P):
        ix, iy = grid.cell_of(p)
        tbl[p, 0] = p
        # east / west with optional longitude wrap
        if ix + 1 < grid.gx:
            tbl[p, 1] = grid.index_of(ix + 1, iy)
        elif grid.wrap_x:
            tbl[p, 1] = grid.index_of(0, iy)
        if ix - 1 >= 0:
            tbl[p, 2] = grid.index_of(ix - 1, iy)
        elif grid.wrap_x:
            tbl[p, 2] = grid.index_of(grid.gx - 1, iy)
        # north / south never wrap (poles)
        if iy + 1 < grid.gy:
            tbl[p, 3] = grid.index_of(ix, iy + 1)
        if iy - 1 >= 0:
            tbl[p, 4] = grid.index_of(ix, iy - 1)
    return tbl


def direction_permutations(grid: PartitionGrid) -> np.ndarray:
    """(5, P) int32 permutation tables for the ppermute comm mode.

    perm[d][j] = source partition whose mini-batch partition j receives when
    the globally-sampled direction is d; j itself where the neighbor is
    absent (those steps contribute weight 0 for j via the importance weight,
    so receiving own data is merely a no-op placeholder).
    """
    tbl = neighbor_table(grid)
    P = grid.num_partitions
    perm = np.tile(np.arange(P, dtype=np.int32), (NUM_SLOTS, 1))
    for d in range(1, NUM_SLOTS):
        src = tbl[:, d]
        perm[d] = np.where(src >= 0, src, np.arange(P, dtype=np.int32))
    return perm


class BoundaryProbes(NamedTuple):
    """Probe locations along interior partition boundaries (for the RMSD
    smoothness metric of §5: "17,556 locations equally spaced along the
    boundaries between partitions")."""

    points: jnp.ndarray  # (E, ppe, 2) probe coordinates
    left: jnp.ndarray  # (E,) int32 partition on one side
    right: jnp.ndarray  # (E,) int32 partition on the other side


def boundary_probes(grid: PartitionGrid, probes_per_edge: int = 23) -> BoundaryProbes:
    """Equally spaced probes on every interior (and wrapped) shared edge."""
    pts, lefts, rights = [], [], []
    xe, ye = grid.x_edges, grid.y_edges

    def edge_points_vertical(x0, ylo, yhi):
        t = (np.arange(probes_per_edge) + 0.5) / probes_per_edge
        return np.stack([np.full(probes_per_edge, x0), ylo + t * (yhi - ylo)], -1)

    def edge_points_horizontal(y0, xlo, xhi):
        t = (np.arange(probes_per_edge) + 0.5) / probes_per_edge
        return np.stack([xlo + t * (xhi - xlo), np.full(probes_per_edge, y0)], -1)

    for iy in range(grid.gy):
        for ix in range(grid.gx):
            p = grid.index_of(ix, iy)
            # vertical boundary with the east neighbor
            if ix + 1 < grid.gx:
                pts.append(edge_points_vertical(xe[ix + 1], ye[iy], ye[iy + 1]))
                lefts.append(p)
                rights.append(grid.index_of(ix + 1, iy))
            elif grid.wrap_x:
                pts.append(edge_points_vertical(xe[-1], ye[iy], ye[iy + 1]))
                lefts.append(p)
                rights.append(grid.index_of(0, iy))
            # horizontal boundary with the north neighbor
            if iy + 1 < grid.gy:
                pts.append(edge_points_horizontal(ye[iy + 1], xe[ix], xe[ix + 1]))
                lefts.append(p)
                rights.append(grid.index_of(ix, iy + 1))
    return BoundaryProbes(
        points=jnp.asarray(np.stack(pts), jnp.float32),
        left=jnp.asarray(np.asarray(lefts, np.int32)),
        right=jnp.asarray(np.asarray(rights, np.int32)),
    )
