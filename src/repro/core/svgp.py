"""Sparse Variational Gaussian Process (Hensman et al. 2013) — eq. (3).

One local model. The PSVGP layer (``repro.core.psvgp``) vmaps everything in
this file over a leading partition axis, so every function here is written
for a single un-batched model and must stay vmap-friendly (no python-level
data-dependent control flow).

Parameterization (all unconstrained, phi in the paper's notation):
  m_star     (m,)      variational mean of q(u)
  s_tril     (m, m)    unconstrained Cholesky of S_star: tril, diag via exp
  z          (m, d)    inducing point locations
  cov        CovarianceParams (ARD log-lengthscales, log-variance)
  log_beta   ()        log noise precision

``whitened=True`` reparameterizes q(u) = N(L v_m, L V L^T) with L = chol(Kmm),
a beyond-paper numerical option (KL becomes Kmm-free); default False matches
the paper / Hensman 2013 exactly.
"""
from __future__ import annotations

import math
from collections.abc import Callable
from typing import NamedTuple

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl

from repro.core.posterior import (
    build_cache,
    kmm_chol as _kmm_chol,
    predict_cached,
    projection as _projection,
    s_chol,
)
from repro.gp.covariances import CovarianceParams, init_covariance_params
from repro.gp.likelihoods import gaussian_expected_loglik

_LOG2PI = 1.8378770664093453


class SVGPParams(NamedTuple):
    m_star: jnp.ndarray  # (m,)
    s_tril: jnp.ndarray  # (m, m) unconstrained
    z: jnp.ndarray  # (m, d)
    cov: CovarianceParams
    log_beta: jnp.ndarray  # ()


class SVGPConfig(NamedTuple):
    num_inducing: int
    input_dim: int
    covariance: str = "rbf"
    jitter: float = 1e-5
    whitened: bool = False
    init_lengthscale: float = 1.0
    init_variance: float = 1.0
    init_beta: float = 1.0
    use_pallas: bool = False  # route the O(B m^2) hot path through kernels/
    likelihood: str = "gaussian"  # gaussian | poisson — the paper's §6
    # "extensions to non-Gaussian likelihoods ... count data" future work


def init_svgp_params(
    key: jax.Array,
    cfg: SVGPConfig,
    x_init: jnp.ndarray | None = None,
    mask: jnp.ndarray | None = None,
    dtype=jnp.float32,
) -> SVGPParams:
    """Initialize; inducing points from data subsample if provided, else N(0,1).

    mask: optional (n,) {0,1} row validity for ``x_init`` (the PSVGP layer's
    partitions are padded to a common n_max). Sampling is restricted to valid
    rows, uniformly WITHOUT replacement — padded slots replicate the
    partition's first point, and drawing them would stack duplicate inducing
    points there, making Kmm singular up to jitter (chaotic Cholesky
    gradients, wasted inducing capacity on exactly the small edge partitions
    that need it most). Partitions with fewer valid points than m still get
    duplicates (there is nothing else to sample); jitter handles those.
    """
    m, d = cfg.num_inducing, cfg.input_dim
    kz, = jax.random.split(key, 1)
    if x_init is not None:
        if mask is None:
            idx = jax.random.choice(kz, x_init.shape[0], (m,), replace=x_init.shape[0] < m)
        else:
            # Uniform top-k over valid rows (same idiom as the minibatch
            # sampler): distinct valid rows first, padded rows only when the
            # partition runs out of points. vmap-safe (no data-dependent
            # shapes), unlike random.choice with a probability vector.
            scores = jax.random.uniform(kz, (x_init.shape[0],)) + (mask - 1.0) * 1e9
            idx = jax.lax.top_k(scores, m)[1]
        z = x_init[idx].astype(dtype)
    else:
        z = jax.random.normal(kz, (m, d), dtype)
    return SVGPParams(
        m_star=jnp.zeros((m,), dtype),
        # exp(diag)=1 -> S_star initialized to the identity
        s_tril=jnp.zeros((m, m), dtype),
        z=z,
        cov=init_covariance_params(d, cfg.init_lengthscale, cfg.init_variance, dtype),
        log_beta=jnp.asarray(math.log(cfg.init_beta), dtype),
    )


# s_chol / _kmm_chol / _projection now live in repro.core.posterior (the
# shared prediction-math module); re-imported above so the ELBO below and
# external callers keep their historical access path.


def q_f(
    params: SVGPParams,
    cov_fn: Callable,
    x: jnp.ndarray,
    jitter: float = 1e-5,
    whitened: bool = False,
    use_pallas: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Marginal q(f_i) = N(fmean_i, fvar_i) at inputs x — the SVGP predictive.

    fmean = k_i^T Kmm^{-1} m_star              (unwhitened)
    fvar  = k~_ii + a_i^T S a_i  with a_i = Kmm^{-1} k_i
    """
    lk, kd, lmm = _projection(params, cov_fn, x, jitter, use_pallas)
    sl = s_chol(params.s_tril)  # (m, m)
    if whitened:
        # u = L v, q(v)=N(m_star, S): fmean = lk^T m_star, a_i^T S a_i = ||sl^T lk||^2
        fmean = lk.T @ params.m_star
        tmp = sl.T @ lk  # (m, B)
        fvar = kd + jnp.sum(tmp * tmp, axis=0)
    else:
        a = jsl.solve_triangular(lmm.T, lk, lower=False)  # (m, B) = Kmm^{-1} k_i
        fmean = a.T @ params.m_star
        tmp = sl.T @ a
        fvar = kd + jnp.sum(tmp * tmp, axis=0)
    return fmean, jnp.maximum(fvar, 1e-12)


def kl_to_prior(params: SVGPParams, cov_fn: Callable, jitter: float, whitened: bool) -> jnp.ndarray:
    """KL( N(m_star, S_star) || p(u) ) — eq. (3)'s last term (times n/n = 1)."""
    m = params.m_star.shape[0]
    sl = s_chol(params.s_tril)
    logdet_s = 2.0 * jnp.sum(jnp.diagonal(params.s_tril))  # log|S| from exp-diag
    if whitened:
        # KL(N(m,S) || N(0,I))
        trace = jnp.sum(sl * sl)
        quad = jnp.sum(params.m_star**2)
        return 0.5 * (trace + quad - m - logdet_s)
    lmm = _kmm_chol(params, cov_fn, jitter)
    linv_sl = jsl.solve_triangular(lmm, sl, lower=True)
    trace = jnp.sum(linv_sl * linv_sl)  # tr(Kmm^{-1} S)
    linv_m = jsl.solve_triangular(lmm, params.m_star, lower=True)
    quad = jnp.sum(linv_m**2)  # m^T Kmm^{-1} m
    logdet_kmm = 2.0 * jnp.sum(jnp.log(jnp.diagonal(lmm)))
    return 0.5 * (trace + quad - m + logdet_kmm - logdet_s)


def elbo(
    params: SVGPParams,
    cov_fn: Callable,
    x: jnp.ndarray,
    y: jnp.ndarray,
    *,
    mask: jnp.ndarray | None = None,
    n_total: jnp.ndarray | float | None = None,
    jitter: float = 1e-5,
    whitened: bool = False,
    use_pallas: bool = False,
    ll_weight: jnp.ndarray | float = 1.0,
    likelihood: str = "gaussian",
) -> jnp.ndarray:
    """Minibatch estimate of eq. (3):  (n/B) * sum_batch l_i  -  KL.

    mask: optional (B,) {0,1} — padded slots contribute nothing, and the
          scaling uses the effective batch size sum(mask). Required by the
          PSVGP layer whose partitions are ragged (8..222 obs in the paper).
    n_total: the "n" of eq. (3); for PSVGP this is n_eff,j of eq. (9).
             Defaults to the (effective) batch size, i.e. full-batch ELBO.
    ll_weight: importance weight applied to the LIKELIHOOD term only (the
          KL is deterministic, so weighting it would add pure variance) —
          used by the TPU-native synchronized-direction estimator.
    likelihood: "gaussian" (closed-form eq. 3) or "poisson" (log-link,
          closed-form expectation) — the paper's §6 count-data extension.
    """
    fmean, fvar = q_f(params, cov_fn, x, jitter, whitened, use_pallas)
    if likelihood == "gaussian":
        ll = gaussian_expected_loglik(y, fmean, fvar, params.log_beta)  # (B,)
    elif likelihood == "poisson":
        from repro.gp.likelihoods import poisson_expected_loglik

        ll = poisson_expected_loglik(y, fmean, fvar)
    else:
        raise ValueError(likelihood)
    if mask is not None:
        ll = ll * mask
        batch_n = jnp.maximum(jnp.sum(mask), 1.0)
    else:
        batch_n = jnp.asarray(float(x.shape[0]), ll.dtype)
    n_tot = batch_n if n_total is None else jnp.asarray(n_total, ll.dtype)
    scale = n_tot / batch_n
    return ll_weight * scale * jnp.sum(ll) - kl_to_prior(params, cov_fn, jitter, whitened)


def predict(
    params: SVGPParams,
    cov_fn: Callable,
    xstar: jnp.ndarray,
    jitter: float = 1e-5,
    whitened: bool = False,
    include_noise: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Predictive mean/variance at new locations (latent f by default).

    One-shot path: factorizes Kmm, predicts, discards the factors. Callers
    issuing MANY predictions against a fixed posterior should build a
    ``repro.core.posterior.PosteriorCache`` once and call ``predict_cached``
    (this function is exactly build + predict, so the two agree)."""
    cache = build_cache(params, cov_fn, jitter=jitter, whitened=whitened)
    return predict_cached(cache, cov_fn, xstar, include_noise=include_noise)
