"""The paper's modified SGD sampler — eq. (8) with the delta interpolation
of eq. (9).

Per iteration and per partition j:
  1. choose a source slot k' over {self, 4 neighbors} with probabilities
        P(k'=j)            = n_j / n_eff_j
        P(k'=k), k in N_j  = delta * n_k / n_eff_j
        n_eff_j            = n_j + delta * sum_{k in N_j, k != j} n_k
     (we read eq. (9)'s "delta n_j 1(k in N_j)" as delta n_k — the weights
     "proportional to the number of observations in each partition" of
     eq. (8), consistent with the paper's own n_eff definition; taking it
     literally as n_j would make all neighbor weights equal regardless of
     their size, contradicting eq. (8).)
  2. draw B observations uniformly without replacement from partition k'.
  3. scale the mini-batch gradient by n_eff_j / B_eff.

delta = 0 reduces exactly to ISVGP (always slot 0); delta = 1 is full PSVGP.

Everything is computed for ALL partitions at once (leading axis P) so the
trainer can vmap; slot probabilities use the (P, 5) neighbor table from
``repro.core.neighbors``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.neighbors import NUM_SLOTS


class SlotDistribution(NamedTuple):
    probs: jnp.ndarray  # (P, 5) slot probabilities, rows sum to 1
    n_eff: jnp.ndarray  # (P,) effective data sizes n_eff_j (eq. 9)
    neighbor_tbl: jnp.ndarray  # (P, 5) int32, -1 where absent


def slot_distribution(
    counts: jnp.ndarray, neighbor_tbl: jnp.ndarray, delta: float | jnp.ndarray
) -> SlotDistribution:
    """Build eq. (9) slot probabilities for every partition.

    counts: (P,) true n_k. neighbor_tbl: (P, 5) with slot 0 = self.
    """
    valid = neighbor_tbl >= 0  # (P, 5)
    safe = jnp.maximum(neighbor_tbl, 0)
    n_k = jnp.take(counts, safe, axis=0).astype(jnp.float32) * valid  # (P, 5)
    delta = jnp.asarray(delta, jnp.float32)
    w = n_k.at[:, 1:].multiply(delta)  # self keeps n_j, neighbors get delta*n_k
    n_eff = jnp.sum(w, axis=1)  # (P,)
    probs = w / jnp.maximum(n_eff[:, None], 1e-12)
    return SlotDistribution(probs=probs, n_eff=n_eff, neighbor_tbl=neighbor_tbl)


def sample_slots(key: jax.Array, dist: SlotDistribution) -> jnp.ndarray:
    """k' sampling, vectorized over partitions -> (P,) partition indices."""
    P = dist.probs.shape[0]
    g = jax.random.gumbel(key, (P, NUM_SLOTS))
    logp = jnp.log(jnp.maximum(dist.probs, 1e-30))
    slot = jnp.argmax(logp + g, axis=1)  # (P,) Gumbel-max categorical
    return jnp.take_along_axis(dist.neighbor_tbl, slot[:, None], axis=1)[:, 0], slot


def sample_row_indices(key: jax.Array, mask_row: jnp.ndarray, batch: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Single-row version: (n_max,) mask -> (B,) indices + validity.

    This is the per-partition primitive; the SPMD step calls it directly with
    a per-device key, the vmap trainer maps it with per-partition folded keys
    — the two are therefore bit-identical (DESIGN.md §2 equivalence test).
    """
    n_max = mask_row.shape[0]
    scores = jax.random.uniform(key, (n_max,)) + (mask_row - 1.0) * 1e9
    idx = jax.lax.top_k(scores, batch)[1]
    return idx, jnp.take(mask_row, idx)


def sample_minibatch_indices(
    key: jax.Array, mask_rows: jnp.ndarray, batch: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Uniform WITHOUT-replacement indices from masked rows.

    mask_rows: (P, n_max) validity of each stored point in the SOURCE row.
    Returns (idx, bmask): (P, B) indices into n_max and their validity —
    if a source partition has fewer than B points, the surplus slots are
    masked out (bmask=0), i.e. the batch degrades to "all n_k points".
    Row p uses the independent stream fold_in(key, p).
    """
    P, _ = mask_rows.shape
    keys = jax.vmap(lambda p: jax.random.fold_in(key, p))(jnp.arange(P))
    return jax.vmap(lambda k, m: sample_row_indices(k, m, batch))(keys, mask_rows)


def gather_minibatch(
    x: jnp.ndarray,
    y: jnp.ndarray,
    mask: jnp.ndarray,
    kprime: jnp.ndarray,
    idx: jnp.ndarray,
    bmask_from_source: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Materialize the (P, B, ...) mini-batches from source partitions kprime.

    This is the paper-faithful "gather" communication mode: under SPMD the
    cross-partition take lowers to a gather/all-gather of B-point blocks.
    """
    xs = jnp.take(x, kprime, axis=0)  # (P, n_max, d)
    ys = jnp.take(y, kprime, axis=0)
    ms = jnp.take(mask, kprime, axis=0)
    bx = jnp.take_along_axis(xs, idx[:, :, None], axis=1)  # (P, B, d)
    by = jnp.take_along_axis(ys, idx, axis=1)  # (P, B)
    bm = jnp.take_along_axis(ms, idx, axis=1)
    return bx, by, bm
