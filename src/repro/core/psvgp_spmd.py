"""Device-sharded PSVGP — the production shard_map program (DESIGN.md §2).

Layout: ONE partition per device. The partition grid (gx x gy) is mapped
onto the physical mesh so that grid x-steps are shifts along the ``model``
mesh axis and grid y-steps are shifts along the (``pod`` x) ``data`` axes:

    partition (ix, iy)  <->  device (pod = iy // data, data = iy % data, model = ix)

East/west exchange is then a ``lax.ppermute`` along ``model``; north/south a
``lax.ppermute`` along the flattened (``pod``, ``data``) product axis — i.e.
every step costs exactly ONE collective-permute of one mini-batch per device
(the paper's "communicates with at most one of its neighbors per iteration"
mapped onto the ICI torus). The optimizer state and variational parameters
never move; only B-point mini-batches do (zero memory overhead, as the
paper claims).

Math is bit-identical to ``psvgp.train_step_ppermute`` (same fold_in key
streams) — tested in tests/test_psvgp_spmd.py.
"""
from __future__ import annotations

import functools
from collections.abc import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import svgp
from repro.core.partition import PartitionGrid
from repro.core.psvgp import PSVGPConfig, PSVGPState, _loss_one
from repro.core.sampler import sample_row_indices
from repro.optim import adam_update
from repro.runtime import compat


def _row_axes(axes: Sequence[str]) -> tuple[str, ...]:
    """Mesh axes carrying the grid's y coordinate (all but the last)."""
    return tuple(axes[:-1])


def grid_matches_mesh(grid: PartitionGrid, mesh: Mesh, axes: Sequence[str]) -> bool:
    gx = mesh.shape[axes[-1]]
    gy = int(np.prod([mesh.shape[a] for a in _row_axes(axes)]))
    return grid.gx == gx and grid.gy == gy


def shift_perm(n: int, up: bool) -> list[tuple[int, int]]:
    """(src, dst) ppermute pairs for 'receive from index+1' (up) or
    'index-1'; edge devices receive nothing (ppermute zero-fills them).

    Public API: the serving halo exchange (``repro.launch.serve_sharded``)
    builds its 3x3 neighborhood from the same permutation tables the
    training exchange uses, which is what keeps the two communication
    patterns provably identical."""
    if up:
        return [(i + 1, i) for i in range(n - 1)]
    return [(i - 1, i) for i in range(1, n)]



def make_spmd_step(
    mesh: Mesh,
    axes: Sequence[str],
    grid: PartitionGrid,
    cfg: PSVGPConfig,
    cov_fn: Callable,
    p_dir: jnp.ndarray,
):
    """Build the jitted, shard_map'd PSVGP train step.

    Arguments at call time (all sharded over the partition axis):
      state (PSVGPState with leading P axis), key, x (P,n,d), y (P,n),
      mask (P,n), probs (P,5), n_eff (P,).
    Returns (state, mean weighted loss).
    """
    if not grid_matches_mesh(grid, mesh, axes):
        raise ValueError(
            f"grid {grid.gx}x{grid.gy} must equal mesh axes {axes} "
            f"{[mesh.shape[a] for a in axes]} (one partition per device)"
        )
    if grid.wrap_x:
        raise NotImplementedError("wrapped grids need ring perms; default grids are unwrapped")
    gx, gy = grid.gx, grid.gy
    col_axis = axes[-1]
    row_axes = _row_axes(axes)
    B = cfg.batch_size

    def device_pid():
        """Flat partition id of this device: iy * gx + ix."""
        ix = jax.lax.axis_index(col_axis)
        iy = jax.lax.axis_index(row_axes) if len(row_axes) > 1 else jax.lax.axis_index(row_axes[0])
        return iy * gx + ix

    def exchange(payload, d):
        """Receive the neighbor-in-direction-d's payload (zeros at edges).

        Directions follow repro.core.neighbors slots:
          1=east (+x), 2=west (-x), 3=north (+y), 4=south (-y).
        """

        def self_(p):
            return p

        def east(p):
            return jax.tree.map(
                lambda a: jax.lax.ppermute(a, col_axis, shift_perm(gx, up=True)), p
            )

        def west(p):
            return jax.tree.map(
                lambda a: jax.lax.ppermute(a, col_axis, shift_perm(gx, up=False)), p
            )

        def north(p):
            ax = row_axes if len(row_axes) > 1 else row_axes[0]
            return jax.tree.map(lambda a: jax.lax.ppermute(a, ax, shift_perm(gy, up=True)), p)

        def south(p):
            ax = row_axes if len(row_axes) > 1 else row_axes[0]
            return jax.tree.map(lambda a: jax.lax.ppermute(a, ax, shift_perm(gy, up=False)), p)

        return jax.lax.switch(d, (self_, east, west, north, south), payload)

    def step_shard(state, key, x_l, y_l, m_l, probs_l, neff_l):
        # local block shapes: x_l (1, n_max, dim), probs_l (1, 5), params (1, ...)
        pid = device_pid()
        kd, kb = jax.random.split(jax.random.fold_in(key, state.step))
        d = jax.random.categorical(kd, jnp.log(jnp.maximum(p_dir, 1e-30)))  # global
        idx, bm = sample_row_indices(jax.random.fold_in(kb, pid), m_l[0], B)
        bx = jnp.take(x_l[0], idx, axis=0)  # (B, dim)
        by = jnp.take(y_l[0], idx, axis=0)
        # ONE collective: ship mini-batches one hop against direction d.
        bx, by, bm = exchange((bx, by, bm), d)
        w = probs_l[0, d] / jnp.maximum(p_dir[d], 1e-30)  # importance weight

        params_one = jax.tree.map(lambda a: a[0], state.params)
        loss_fn = functools.partial(_loss_one, cov_fn=cov_fn, scfg=cfg.svgp)
        loss, grads = jax.value_and_grad(loss_fn)(
            params_one, bx=bx, by=by, bm=bm, n_eff=neff_l[0], ll_weight=w
        )
        grads = jax.tree.map(lambda g: g[None], grads)
        new_params, new_opt = adam_update(state.params, grads, state.opt, lr=cfg.learning_rate)
        new_state = PSVGPState(new_params, new_opt, state.step + 1)
        mean_loss = jax.lax.pmean(loss, tuple(axes))
        return new_state, mean_loss

    from repro.gp.covariances import CovarianceParams
    from repro.optim import AdamState

    pspec = P(tuple(axes))  # leading partition axis over the whole mesh
    params_like = svgp.SVGPParams(
        m_star=pspec, s_tril=pspec, z=pspec,
        cov=CovarianceParams(log_lengthscale=pspec, log_variance=pspec),
        log_beta=pspec,
    )
    state_specs = PSVGPState(
        params=params_like,
        opt=AdamState(step=P(), mu=params_like, nu=params_like),
        step=P(),
    )

    step_fn = compat.shard_map(
        step_shard,
        mesh=mesh,
        in_specs=(state_specs, P(), pspec, pspec, pspec, pspec, pspec),
        out_specs=(state_specs, P()),
        check_vma=False,
    )
    return jax.jit(step_fn)
