"""PSVGP — the paper's contribution (§4): N_part local SVGPs trained with
delta-weighted neighbor sampling and decentralized communication.

Two communication modes (DESIGN.md §2):

* ``comm="gather"``  — paper-faithful: every partition independently samples
  its own source partition k' ~ eq. (9) and the mini-batch is materialized
  by a cross-partition gather. On one host this is exactly the paper's
  algorithm; under SPMD it lowers to a small all-gather.

* ``comm="ppermute"`` — TPU-native: one globally shared direction per step,
  mini-batches exchanged with a single ``lax.ppermute`` (ICI collective-
  permute = decentralized point-to-point), unbiasedness restored via
  importance weights pi_j(d)/p(d). Available both as a single-host
  simulation (bit-identical math) and as a true shard_map program
  (``repro.launch.dryrun`` lowers it on the production mesh).

The per-partition models are the ``repro.core.svgp`` SVGP; everything is
stacked on a leading partition axis and vmapped, so one XLA program trains
all 400 partitions at once — the SPMD analogue of the paper's MPI ranks.

Prediction is served through the ``repro.core.posterior`` PosteriorCache:
``posterior_cache`` factorizes all P local posteriors once (per trained
state), and ``predict_local`` / ``predict_at_partitions`` /
``blend.predict_blended`` evaluate O(m^2) against those cached factors —
the serving path for the paper's E3SM in-situ setting. Entry points:
``repro.launch.serve --gp`` (batched query loop with latency/throughput
report) and ``benchmarks.bench_predict`` (cached-vs-seed speedup gate).
"""
from __future__ import annotations

import functools
from collections.abc import Callable
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import posterior, svgp
from repro.core.neighbors import direction_permutations, neighbor_table
from repro.core.partition import PartitionedData
from repro.core.sampler import (
    SlotDistribution,
    gather_minibatch,
    sample_minibatch_indices,
    sample_slots,
    slot_distribution,
)
from repro.gp.covariances import make_covariance
from repro.optim import AdamState, adam_init, adam_update


class PSVGPConfig(NamedTuple):
    svgp: svgp.SVGPConfig
    delta: float = 0.0  # eq. (9): 0 = ISVGP, 1 = full PSVGP
    batch_size: int = 32
    learning_rate: float = 0.02
    comm: str = "gather"  # "gather" | "ppermute"
    seed: int = 0


class PSVGPState(NamedTuple):
    params: svgp.SVGPParams  # every leaf has leading (P, ...) axis
    opt: AdamState
    step: jnp.ndarray  # () int32


class PSVGPStatic(NamedTuple):
    """Static (host-side) companions to the jitted step functions."""

    cfg: PSVGPConfig
    cov_fn: Callable
    dist: SlotDistribution
    perms: jnp.ndarray  # (5, P) direction permutations (ppermute mode)
    p_dir: jnp.ndarray  # (5,) global direction probabilities (ppermute mode)


def build(cfg: PSVGPConfig, data: PartitionedData) -> PSVGPStatic:
    """Precompute topology-dependent tables from the partition grid."""
    tbl = jnp.asarray(neighbor_table(data.grid))
    dist = slot_distribution(data.counts, tbl, cfg.delta)
    perms = jnp.asarray(direction_permutations(data.grid))
    # Global direction distribution for the ppermute mode: the average of the
    # per-partition slot distributions (minimizes the spread of the
    # importance weights pi_j(d)/p(d) around 1).
    p_dir = jnp.mean(dist.probs, axis=0)
    p_dir = p_dir / jnp.sum(p_dir)
    return PSVGPStatic(cfg=cfg, cov_fn=make_covariance(cfg.svgp.covariance), dist=dist, perms=perms, p_dir=p_dir)


def init(key: jax.Array, cfg: PSVGPConfig, data: PartitionedData) -> PSVGPState:
    P = data.num_partitions
    keys = jax.random.split(key, P)
    init_one = functools.partial(svgp.init_svgp_params, cfg=cfg.svgp)
    # mask keeps inducing-point sampling on each partition's VALID rows —
    # padded rows replicate the first point and would collapse Kmm.
    params = jax.vmap(lambda k, x, mk: init_one(k, x_init=x, mask=mk))(
        keys, data.x, data.mask
    )
    return PSVGPState(params=params, opt=adam_init(params), step=jnp.zeros((), jnp.int32))


def _loss_one(params, cov_fn, bx, by, bm, n_eff, scfg: svgp.SVGPConfig, ll_weight=1.0):
    return -svgp.elbo(
        params,
        cov_fn,
        bx,
        by,
        mask=bm,
        n_total=n_eff,
        jitter=scfg.jitter,
        whitened=scfg.whitened,
        use_pallas=scfg.use_pallas,
        ll_weight=ll_weight,
        likelihood=scfg.likelihood,
    )


# --------------------------------------------------------------------------
# Paper-faithful mode: independent neighbor choice per partition (eq. 8/9).
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg", "cov_fn"))
def train_step_gather(
    state: PSVGPState,
    key: jax.Array,
    x: jnp.ndarray,
    y: jnp.ndarray,
    mask: jnp.ndarray,
    dist: SlotDistribution,
    cfg: PSVGPConfig,
    cov_fn: Callable,
) -> tuple[PSVGPState, jnp.ndarray]:
    """One SGD iteration of the paper's algorithm for all partitions at once.

    Communication pattern: partition j pulls a B-point mini-batch from its
    sampled source k'_j — at most ONE neighbor per iteration (the paper's
    key communication bound).
    """
    k_slot, k_batch = jax.random.split(jax.random.fold_in(key, state.step))
    kprime, _slot = sample_slots(k_slot, dist)  # (P,)
    src_mask = jnp.take(mask, kprime, axis=0)  # (P, n_max)
    idx, _ = sample_minibatch_indices(k_batch, src_mask, cfg.batch_size)
    bx, by, bm = gather_minibatch(x, y, mask, kprime, idx)

    loss_fn = functools.partial(_loss_one, cov_fn=cov_fn, scfg=cfg.svgp)
    losses, grads = jax.vmap(jax.value_and_grad(loss_fn))(
        state.params, bx=bx, by=by, bm=bm, n_eff=dist.n_eff
    )
    new_params, new_opt = adam_update(state.params, grads, state.opt, lr=cfg.learning_rate)
    return PSVGPState(new_params, new_opt, state.step + 1), jnp.mean(losses)


# --------------------------------------------------------------------------
# TPU-native mode: synchronized direction + permute, importance-weighted.
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg", "cov_fn"))
def train_step_ppermute(
    state: PSVGPState,
    key: jax.Array,
    x: jnp.ndarray,
    y: jnp.ndarray,
    mask: jnp.ndarray,
    dist: SlotDistribution,
    perms: jnp.ndarray,
    p_dir: jnp.ndarray,
    cfg: PSVGPConfig,
    cov_fn: Callable,
) -> tuple[PSVGPState, jnp.ndarray]:
    """Single-host simulation of the TPU-native step (identical math).

    One global direction d ~ p_dir; every partition ships its OWN mini-batch
    to the neighbor opposite d (a permutation = collective-permute on a real
    mesh); gradients are importance-weighted by pi_j(d)/p(d) so that
    E[update] matches eq. (8) exactly. See ``shard_map_step`` for the
    device-sharded version of the same program.
    """
    kd, kb = jax.random.split(jax.random.fold_in(key, state.step))
    d = jax.random.categorical(kd, jnp.log(jnp.maximum(p_dir, 1e-30)))  # ()
    # Every partition samples from its own data (no communication yet).
    idx, _ = sample_minibatch_indices(kb, mask, cfg.batch_size)
    bx = jnp.take_along_axis(x, idx[:, :, None], axis=1)  # (P, B, dim)
    by = jnp.take_along_axis(y, idx, axis=1)  # (P, B)
    bm = jnp.take_along_axis(mask, idx, axis=1)
    # Route mini-batches: receiver j gets the batch of perms[d][j].
    perm_row = jnp.take(perms, d, axis=0)  # (P,)
    bx = jnp.take(bx, perm_row, axis=0)
    by = jnp.take(by, perm_row, axis=0)
    bm = jnp.take(bm, perm_row, axis=0)
    # Importance weight: pi_j(d)/p(d); partitions with no neighbor in this
    # direction have pi_j(d)=0 -> weight 0 (their likelihood term is a
    # no-op this step). Applied to the likelihood term ONLY — the KL is
    # deterministic and keeps weight 1 (pure variance reduction; E[w]=1
    # makes both versions unbiased, see DESIGN.md §2).
    pi_jd = jnp.take_along_axis(dist.probs, jnp.full((dist.probs.shape[0], 1), d), axis=1)[:, 0]
    w = pi_jd / jnp.maximum(p_dir[d], 1e-30)  # (P,)

    loss_fn = functools.partial(_loss_one, cov_fn=cov_fn, scfg=cfg.svgp)
    losses, grads = jax.vmap(jax.value_and_grad(loss_fn))(
        state.params, bx=bx, by=by, bm=bm, n_eff=dist.n_eff, ll_weight=w
    )
    new_params, new_opt = adam_update(state.params, grads, state.opt, lr=cfg.learning_rate)
    return PSVGPState(new_params, new_opt, state.step + 1), jnp.mean(losses)


def train_step(static: PSVGPStatic, state: PSVGPState, key: jax.Array, data: PartitionedData):
    """Dispatch on the configured communication mode."""
    if static.cfg.comm == "gather":
        return train_step_gather(
            state, key, data.x, data.y, data.mask, static.dist, static.cfg, static.cov_fn
        )
    elif static.cfg.comm == "ppermute":
        return train_step_ppermute(
            state,
            key,
            data.x,
            data.y,
            data.mask,
            static.dist,
            static.perms,
            static.p_dir,
            static.cfg,
            static.cov_fn,
        )
    raise ValueError(f"unknown comm mode {static.cfg.comm!r}")


def fit(
    static: PSVGPStatic,
    state: PSVGPState,
    data: PartitionedData,
    num_iters: int,
    key: jax.Array | None = None,
    log_every: int = 0,
    use_scan: bool = False,
) -> PSVGPState:
    """Run ``num_iters`` SGD iterations (the paper runs 100-150 per E3SM
    time step budget; convergence experiments run a few thousand).

    use_scan batches iterations inside one XLA program via lax.scan.
    §Perf-3 log: HYPOTHESIS REFUTED on CPU — the scan carry double-buffers
    the whole (params, opt) state per iteration and measured 2.5x SLOWER
    than the python loop (7.4 -> 18.5 ms/iter at P=100, m=5), so the
    default stays False; kept as an option since on TPU with donated
    buffers the trade-off may invert. Identical math either way (keys are
    fold_in(key, step)).
    """
    key = jax.random.PRNGKey(static.cfg.seed) if key is None else key
    if use_scan and not log_every:
        chunk = min(num_iters, 200)  # bound one program's trace length

        if static.cfg.comm == "gather":
            args = (data.x, data.y, data.mask, static.dist, static.cfg, static.cov_fn)
            step_fn = train_step_gather
        else:
            args = (data.x, data.y, data.mask, static.dist, static.perms,
                    static.p_dir, static.cfg, static.cov_fn)
            step_fn = train_step_ppermute

        import functools as _ft

        @_ft.partial(jax.jit, static_argnames=())
        def run_chunk(st):
            def body(s, _):
                s2, loss = step_fn(s, key, *args)
                return s2, loss

            return jax.lax.scan(body, st, None, length=chunk)

        done = 0
        while done < num_iters:
            n = min(chunk, num_iters - done)
            if n == chunk:
                state, _ = run_chunk(state)
            else:
                for _ in range(n):
                    state, _ = train_step(static, state, key, data)
            done += n
        return state
    for i in range(num_iters):
        state, loss = train_step(static, state, key, data)
        if log_every and (i + 1) % log_every == 0:
            print(f"  iter {i + 1:5d}  mean -ELBO/partition: {float(loss):.4f}")
    return state


# --------------------------------------------------------------------------
# Prediction / evaluation — all routed through the PosteriorCache subsystem
# (repro.core.posterior): factorize the P local posteriors ONCE per trained
# state, then every prediction is O(Q m^2) against the cached factors.
# --------------------------------------------------------------------------


def posterior_cache(static: PSVGPStatic, state: PSVGPState) -> posterior.PosteriorCache:
    """P-stacked prediction cache for the current state — one batched
    O(P m^3) factorization; reuse it across every prediction call below."""
    scfg = static.cfg.svgp
    return posterior.build_cache_stacked(
        state.params, static.cov_fn, jitter=scfg.jitter, whitened=scfg.whitened
    )


def predict_local(
    static: PSVGPStatic,
    state: PSVGPState,
    xstar: jnp.ndarray,
    cache: posterior.PosteriorCache | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Each partition's model predicts at its OWN rows of xstar (P, Q, d)."""
    if cache is None:
        cache = posterior_cache(static, state)
    return posterior.predict_cached_stacked(cache, static.cov_fn, xstar)


def predict_at_partitions(
    static: PSVGPStatic,
    state: PSVGPState,
    part_ids: jnp.ndarray,
    points: jnp.ndarray,
    cache: posterior.PosteriorCache | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Predict ``points`` (E, Q, d) with the models of ``part_ids`` (E,)."""
    if cache is None:
        cache = posterior_cache(static, state)
    cache_e = posterior.take_cache(cache, part_ids)
    return posterior.predict_cached_stacked(cache_e, static.cov_fn, points)
