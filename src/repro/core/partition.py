"""Spatial grid partitioner — the paper's N_part contiguous data partitions.

The E3SM experiment (§5) partitions ~48.6k observations into a 20x20 grid of
unbalanced partitions (8..222 obs each, median ~150). Partitions are stored
PADDED to a common n_max with a {0,1} mask so the whole collection is one
rectangular array that vmaps/shard_maps over the leading partition axis —
this is the padded-storage layout DESIGN.md §3 describes.

All functions here are host-side (numpy) data preparation; outputs are
device arrays ready for the training loop.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np
import jax.numpy as jnp


class PartitionGrid(NamedTuple):
    """Static description of the partition grid topology."""

    gx: int  # number of cells in x (longitude)
    gy: int  # number of cells in y (latitude)
    x_edges: np.ndarray  # (gx+1,)
    y_edges: np.ndarray  # (gy+1,)
    wrap_x: bool  # longitude wrap-around (global climate grids)

    @property
    def num_partitions(self) -> int:
        return self.gx * self.gy

    def cell_of(self, i: int) -> tuple[int, int]:
        """Partition index -> (ix, iy), row-major with x fastest."""
        return i % self.gx, i // self.gx

    def index_of(self, ix: int, iy: int) -> int:
        return iy * self.gx + ix


class PartitionedData(NamedTuple):
    """Padded per-partition data. Leading axis = partition."""

    x: jnp.ndarray  # (P, n_max, d)
    y: jnp.ndarray  # (P, n_max)
    mask: jnp.ndarray  # (P, n_max) {0,1}
    counts: jnp.ndarray  # (P,) int32 true observation counts n_k
    grid: PartitionGrid

    @property
    def num_partitions(self) -> int:
        return self.x.shape[0]

    @property
    def n_max(self) -> int:
        return self.x.shape[1]


def make_grid(
    x: np.ndarray,
    gx: int,
    gy: int,
    wrap_x: bool = False,
    bounds: tuple[float, float, float, float] | None = None,
) -> PartitionGrid:
    """Build a regular gx x gy grid covering the data (or explicit bounds).

    wrap_x defaults to False even for global (lon, lat) data: the models work
    in raw coordinates, which are NOT periodic across the 0/360 seam, so
    sharing data across it would hand a model points 360 degrees away in
    input space. (A periodic covariance would lift this; see gp/covariances.)
    """
    if bounds is None:
        x0, x1 = float(x[:, 0].min()), float(x[:, 0].max())
        y0, y1 = float(x[:, 1].min()), float(x[:, 1].max())
        # nudge the upper edges so max-coordinate points fall inside the last cell
        eps_x = 1e-6 * max(x1 - x0, 1.0)
        eps_y = 1e-6 * max(y1 - y0, 1.0)
        x1 += eps_x
        y1 += eps_y
    else:
        x0, x1, y0, y1 = bounds
    return PartitionGrid(
        gx=gx,
        gy=gy,
        x_edges=np.linspace(x0, x1, gx + 1),
        y_edges=np.linspace(y0, y1, gy + 1),
        wrap_x=wrap_x,
    )


def cell_indices(grid: PartitionGrid, x: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(ix, iy) owning grid cell of each point in x (N, 2), int64.

    The ONE binning rule shared by training-time partitioning
    (``partition_data``) and serving-time query routing
    (``repro.core.routing.owning_cells``) — they must agree, or routed
    queries land on devices that never trained on their region.
    Out-of-domain points clip to the edge cells.
    """
    ix = np.clip(np.searchsorted(grid.x_edges, x[:, 0], side="right") - 1, 0, grid.gx - 1)
    iy = np.clip(np.searchsorted(grid.y_edges, x[:, 1], side="right") - 1, 0, grid.gy - 1)
    return ix.astype(np.int64), iy.astype(np.int64)


def partition_data(
    x: np.ndarray,
    y: np.ndarray,
    grid: PartitionGrid,
    n_max: int | None = None,
    pad_multiple: int = 8,
) -> PartitionedData:
    """Assign each observation to its grid cell and pad to rectangular storage.

    ``pad_multiple`` rounds n_max up (TPU-friendly sublane alignment).
    """
    x = np.asarray(x, np.float32)
    y = np.asarray(y, np.float32)
    n, d = x.shape
    ix, iy = cell_indices(grid, x)
    part = iy * grid.gx + ix
    p_count = np.bincount(part, minlength=grid.num_partitions)
    nm = int(p_count.max()) if n_max is None else n_max
    nm = ((nm + pad_multiple - 1) // pad_multiple) * pad_multiple

    P = grid.num_partitions
    xp = np.zeros((P, nm, d), np.float32)
    yp = np.zeros((P, nm), np.float32)
    mp = np.zeros((P, nm), np.float32)
    fill = np.zeros(P, np.int64)
    order = np.argsort(part, kind="stable")
    for idx in order:
        p = part[idx]
        k = fill[p]
        if k >= nm:
            continue  # only when explicit n_max truncates
        xp[p, k] = x[idx]
        yp[p, k] = y[idx]
        mp[p, k] = 1.0
        fill[p] += 1
    # Padded slots replicate the partition's first point (any in-bounds
    # location) so covariance matrices stay well-conditioned; mask keeps
    # them out of every sum. Empty partitions keep zeros.
    for p in range(P):
        c = fill[p]
        if 0 < c < nm:
            xp[p, c:] = xp[p, 0]
    return PartitionedData(
        x=jnp.asarray(xp),
        y=jnp.asarray(yp),
        mask=jnp.asarray(mp),
        counts=jnp.asarray(np.minimum(p_count, nm).astype(np.int32)),
        grid=grid,
    )


def partition_centers(grid: PartitionGrid) -> np.ndarray:
    """(P, 2) cell centers, row-major (x fastest)."""
    cx = 0.5 * (grid.x_edges[:-1] + grid.x_edges[1:])
    cy = 0.5 * (grid.y_edges[:-1] + grid.y_edges[1:])
    xx, yy = np.meshgrid(cx, cy)
    return np.stack([xx.ravel(), yy.ravel()], axis=-1)
