"""Query routing for distributed PSVGP serving (the sharded-cache path).

The paper's serving claim is the same as its training claim: a partition's
model only ever needs ONE-HOP information. For prediction that hop is the
blend stencil — ``blend.corner_ids_weights`` assigns every query point the
(up to) 4 partition models whose cell centers surround it, and each of
those corners is always within one grid step (including diagonals) of the
cell that OWNS the point. So when the ``PosteriorCache`` is sharded one
partition per device, a query never needs factors from outside the owning
device's 3x3 neighborhood — corner resolution is a halo exchange, exactly
like the training-time mini-batch ``ppermute`` (Katzfuss & Hammerling 2016
and Peruzzi et al. 2020 exploit the same locality for distributed
partitioned prediction).

This module is the HOST-SIDE half of that design: given a raw query batch
it builds a :class:`RoutingTable` — per-partition padded/masked query
blocks with jit-stable shapes, each query carrying its 4 corner blend
weights and the corner models encoded as 3x3-halo SLOTS (offsets relative
to the owning cell) rather than global partition ids. Slots are what make
the device program mesh-local: slot k on device p always means "the model
at grid offset ``OFFSETS[k]`` from p", whichever device that is.

The device-side half — the shard_map program that halo-exchanges the query
blocks, evaluates every device's local cached posterior, returns results,
and blends — lives in ``repro.launch.serve_sharded``.
:func:`predict_routed` below is its single-host reference implementation
(identical math, gathers instead of collectives), used by the equivalence
tests and as a fallback when no mesh is available.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import posterior
from repro.core.blend import corner_ids_weights
from repro.core.partition import PartitionGrid, cell_indices

# 3x3 halo slot layout, row-major over (dy, dx) in {-1, 0, +1}^2:
# slot k <-> offset (dx, dy) = (k % 3 - 1, k // 3 - 1); slot 4 is self.
# The reverse slot (offset negated) is 8 - k.
OFFSETS: Tuple[Tuple[int, int], ...] = tuple(
    (dx, dy) for dy in (-1, 0, 1) for dx in (-1, 0, 1)
)
SELF_SLOT = 4
NUM_HALO_SLOTS = 9


class RoutingTable(NamedTuple):
    """Per-partition routed query blocks (host numpy; leading axis = P).

    All arrays are padded to a common ``q_max`` so the device program is
    jit-stable across request batches of varying size/skew (q_max itself
    recompiles only when a batch overflows the previous high-water mark).

    Fields:
      xq          (P, q_max, 2) float32: queries owned by each partition.
        Padded rows hold the cell CENTER (an in-domain point, so the
        covariance stays well-conditioned); the mask keeps them out of
        every result.
      qmask       (P, q_max) float32 {0,1}: row validity.
      corner_slot (P, q_max, 4) int32 in [0, 9): each query's 4 corner
        models as 3x3-halo slots relative to the owning partition
        (see OFFSETS). Padded rows point at SELF_SLOT.
      corner_w    (P, q_max, 4) float32: bilinear blend weights (sum to 1
        on valid rows, all-zero on padded rows).
      src_idx     (P, q_max) int32: original index of each routed query in
        the request batch (0 on padded rows) — the scatter map back.
      counts      (P,) int32: true number of queries owned per partition.
    """

    xq: np.ndarray
    qmask: np.ndarray
    corner_slot: np.ndarray
    corner_w: np.ndarray
    src_idx: np.ndarray
    counts: np.ndarray

    @property
    def num_partitions(self) -> int:
        return self.xq.shape[0]

    @property
    def q_max(self) -> int:
        return self.xq.shape[1]

    @property
    def num_queries(self) -> int:
        return int(self.counts.sum())


def owning_cells(grid: PartitionGrid, pts: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """(ix, iy) grid cell owning each point — delegates to the SAME binning
    ``partition.partition_data`` uses (``partition.cell_indices``), so a
    routed query always lands on the device that trained on its region."""
    return cell_indices(grid, pts)


def ceil_to(n: int, k: int) -> int:
    """n rounded up to a multiple of k (shared q_max/pad alignment rule)."""
    return ((n + k - 1) // k) * k


def halo_ids(grid: PartitionGrid) -> np.ndarray:
    """(P, 9) int32: partition id at each 3x3-halo slot of every partition
    (own id where the neighbor is off-grid — those slots are never selected
    by a corner, since clipped corners stay inside the grid)."""
    P = grid.num_partitions
    ids = np.empty((P, NUM_HALO_SLOTS), np.int32)
    for p in range(P):
        ix, iy = grid.cell_of(p)
        for k, (dx, dy) in enumerate(OFFSETS):
            jx, jy = ix + dx, iy + dy
            inside = 0 <= jx < grid.gx and 0 <= jy < grid.gy
            ids[p, k] = grid.index_of(jx, jy) if inside else p
    return ids


def build_routing_table(
    grid: PartitionGrid,
    points: np.ndarray,
    *,
    q_max: int | None = None,
    pad_multiple: int = 8,
    cells: Tuple[np.ndarray, np.ndarray] | None = None,
) -> RoutingTable:
    """Bucket a query batch by owning partition into padded device blocks.

    Args:
      grid: the partition grid (must match the sharded cache's grid).
      points: (N, 2) query coordinates.
      q_max: fixed per-partition block size; default = the batch's max
        bucket count rounded up to ``pad_multiple``. Raises ValueError if a
        bucket overflows an explicit q_max — routing must never silently
        drop queries.
      pad_multiple: round q_max up to this (TPU sublane alignment).
      cells: precomputed ``owning_cells(grid, points)`` for this batch.
        Callers that already binned the batch (the q_max policies — both
        :class:`StreamingQMax` and the whole-stream prepass — must count
        buckets before the table is built) pass it through so the binning
        runs ONCE per request, not once per policy decision plus once per
        table; omitted, it is computed here.

    Returns a :class:`RoutingTable` (see its docstring for shapes).
    """
    pts = np.asarray(points, np.float32)
    if pts.ndim != 2 or pts.shape[1] != 2:
        raise ValueError(f"points must be (N, 2), got {pts.shape}")
    n = pts.shape[0]
    P = grid.num_partitions

    ix, iy = owning_cells(grid, pts) if cells is None else cells
    if ix.shape != (n,) or iy.shape != (n,):
        raise ValueError(
            f"cells must be owning_cells output for the batch: expected two "
            f"({n},) arrays, got {ix.shape} and {iy.shape}"
        )
    own = iy * grid.gx + ix  # (N,) flat owning partition
    ids, w = corner_ids_weights(grid, pts)  # (N, 4), (N, 4)
    dx = ids % grid.gx - ix[:, None]  # (N, 4) in {-1, 0, 1}
    dy = ids // grid.gx - iy[:, None]
    slot = ((dy + 1) * 3 + (dx + 1)).astype(np.int32)

    counts = np.bincount(own, minlength=P).astype(np.int32)
    need = int(counts.max()) if n else 0
    if q_max is None:
        qm = max(need, 1)
    elif need > q_max:
        raise ValueError(
            f"partition bucket of {need} queries overflows q_max={q_max}; "
            "routing never drops queries — raise q_max or split the batch"
        )
    else:
        qm = q_max
    qm = ceil_to(qm, pad_multiple)

    # stable bucket fill, vectorized: position of each query within its
    # owning partition's block = rank among same-owner queries.
    order = np.argsort(own, kind="stable")
    sorted_own = own[order]
    pos = np.arange(n) - np.searchsorted(sorted_own, sorted_own)

    # padded rows: cell centers (valid covariance inputs, masked on output)
    cx = 0.5 * (grid.x_edges[:-1] + grid.x_edges[1:])
    cy = 0.5 * (grid.y_edges[:-1] + grid.y_edges[1:])
    centers = np.stack(np.meshgrid(cx, cy), axis=-1).reshape(P, 2).astype(np.float32)

    xq = np.broadcast_to(centers[:, None, :], (P, qm, 2)).copy()
    qmask = np.zeros((P, qm), np.float32)
    corner_slot = np.full((P, qm, 4), SELF_SLOT, np.int32)
    corner_w = np.zeros((P, qm, 4), np.float32)
    src_idx = np.zeros((P, qm), np.int32)

    xq[sorted_own, pos] = pts[order]
    qmask[sorted_own, pos] = 1.0
    corner_slot[sorted_own, pos] = slot[order]
    corner_w[sorted_own, pos] = w[order]
    src_idx[sorted_own, pos] = order.astype(np.int32)

    return RoutingTable(
        xq=xq, qmask=qmask, corner_slot=corner_slot, corner_w=corner_w,
        src_idx=src_idx, counts=counts,
    )


class StreamingQMax:
    """Streaming high-water-mark q_max policy for a LIVE request stream.

    The whole-stream prepass (``serve_sharded.fixed_q_max``) needs every
    batch up front — impossible for a real stream. This policy instead
    grows q_max only when a batch's max bucket count overflows the current
    high-water mark, jumping to ``need * headroom`` rounded up with the
    SAME :func:`ceil_to` alignment the table applies. Multiplicative
    headroom bounds the total number of shape changes (device-program
    recompiles) at O(log_headroom(peak_need / first_need)) however long
    the stream runs; both overflows and compiles are counted so the
    serving report can show them.

    Usage per batch::

        cells = routing.owning_cells(grid, q)
        q_max = policy.fit(np.bincount(cells_flat, minlength=P))
        table = routing.build_routing_table(grid, q, q_max=q_max, cells=cells)
    """

    def __init__(self, *, headroom: float = 1.25, pad_multiple: int = 8):
        if headroom < 1.0:
            raise ValueError(f"headroom must be >= 1, got {headroom}")
        self.headroom = float(headroom)
        self.pad_multiple = int(pad_multiple)
        self.q_max = 0  # current high-water mark (0 = nothing seen yet)
        self.compiles = 0  # shape changes, INCLUDING the first batch
        self.overflows = 0  # batches that burst the previous high-water mark

    def fit(self, counts: np.ndarray) -> int:
        """Observe a batch's per-partition bucket counts; return the q_max
        to route it with (always >= the batch's max bucket)."""
        need = max(int(np.max(counts)) if np.size(counts) else 0, 1)
        if need > self.q_max:
            if self.q_max:
                self.overflows += 1
            self.q_max = ceil_to(
                int(np.ceil(need * self.headroom)), self.pad_multiple
            )
            self.compiles += 1
        return self.q_max

    def stats(self) -> dict:
        """The SLO-report record: current mark + recompile/overflow counts."""
        return {
            "q_max": self.q_max,
            "compiles": self.compiles,
            "overflows": self.overflows,
        }


def halo_slot_on_grid(grid: PartitionGrid) -> np.ndarray:
    """(P, 9) float32 {0,1}: 1 where the slot's neighbor exists on the grid
    (complement of the off-grid slots ``halo_ids`` clamps to self)."""
    P = grid.num_partitions
    on = np.zeros((P, NUM_HALO_SLOTS), np.float32)
    for p in range(P):
        ix, iy = grid.cell_of(p)
        for k, (dx, dy) in enumerate(OFFSETS):
            if 0 <= ix + dx < grid.gx and 0 <= iy + dy < grid.gy:
                on[p, k] = 1.0
    return on


def make_halo_stacker(grid: PartitionGrid) -> Callable[[np.ndarray], np.ndarray]:
    """Build ``stack(xq) -> hx``: the host-side halo ingest of the sharded
    serving program.

    hx (P, 9, q_max, d) with hx[p, k] = xq[p + OFFSETS[k]] (zeros where the
    neighbor is off-grid — matching ppermute's edge semantics, so the device
    program computes exactly what a mesh-side query exchange would). The
    queries are HOST data: the router already holds every partition's
    block, so shipping each device its full 9-slot stack directly through
    ingest costs one device_put and ZERO mesh collectives — the 1-hop
    reverse halo is reserved for the results, which really do live on
    devices. The (halo_ids, on-grid-mask) tables are precomputed here, once
    per grid, off the per-request path.
    """
    hids = halo_ids(grid)  # (P, 9)
    on = halo_slot_on_grid(grid)  # (P, 9)

    def stack(xq: np.ndarray) -> np.ndarray:
        xq = np.asarray(xq)
        return xq[hids] * on[..., None, None].astype(xq.dtype)

    return stack


def scatter_results(table: RoutingTable, values: np.ndarray) -> np.ndarray:
    """Reassemble per-partition padded results into request order.

    ``values`` is (P, q_max) (or (P, q_max, ...)); returns (N, ...) with N =
    ``table.num_queries``, inverting the routing permutation.
    """
    values = np.asarray(values)
    out = np.empty((table.num_queries,) + values.shape[2:], values.dtype)
    valid = table.qmask > 0
    out[table.src_idx[valid]] = values[valid]
    return out


def blend_slots(
    res_mean: jnp.ndarray,
    res_var: jnp.ndarray,
    corner_slot: jnp.ndarray,
    corner_w: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Resolve per-slot evaluations into the 4-corner bilinear blend.

    Args:
      res_mean / res_var: (9, q) — the halo-resolved evaluations of ONE
        partition's q queries: slot k holds the prediction of the model at
        grid offset OFFSETS[k] from the owner.
      corner_slot: (q, 4) int32 slot index of each query's 4 corners.
      corner_w: (q, 4) bilinear weights.

    Returns (mean (q,), var (q,)) — same mixture formula as
    ``blend.predict_blended``: var is the blend of second moments minus the
    blended mean squared, clamped to >= 1e-12.
    """
    m_c = jnp.take_along_axis(res_mean, corner_slot.T, axis=0).T  # (q, 4)
    v_c = jnp.take_along_axis(res_var, corner_slot.T, axis=0).T
    mean = jnp.sum(corner_w * m_c, axis=1)
    second = jnp.sum(corner_w * (v_c + m_c**2), axis=1)
    var = jnp.maximum(second - mean**2, 1e-12)
    return mean, var


def predict_routed(
    cache: posterior.PosteriorCache,
    cov_fn: Callable,
    grid: PartitionGrid,
    table: RoutingTable,
    *,
    use_pallas: bool = False,
) -> Tuple[np.ndarray, np.ndarray]:
    """Single-host reference of the sharded serving program (same math).

    For every partition p and halo slot k, evaluates the model at
    ``halo_ids(grid)[p, k]`` on p's routed queries, then blends via
    :func:`blend_slots` — exactly what the shard_map program in
    ``repro.launch.serve_sharded`` computes with ``ppermute`` halo
    exchanges instead of gathers. Returns (mean (N,), var (N,)) in request
    order.
    """
    hids = jnp.asarray(halo_ids(grid))  # (P, 9)
    xq = jnp.asarray(table.xq)

    def eval_slot(k):
        cache_k = posterior.take_cache(cache, hids[:, k])  # leaves (P, ...)
        return posterior.predict_cached_stacked(
            cache_k, cov_fn, xq, use_pallas=use_pallas
        )

    res = [eval_slot(k) for k in range(NUM_HALO_SLOTS)]
    res_mean = jnp.stack([m for m, _ in res], axis=1)  # (P, 9, q)
    res_var = jnp.stack([v for _, v in res], axis=1)
    mean, var = jax.vmap(blend_slots)(
        res_mean, res_var, jnp.asarray(table.corner_slot), jnp.asarray(table.corner_w)
    )
    return (
        scatter_results(table, np.asarray(mean)),
        scatter_results(table, np.asarray(var)),
    )
