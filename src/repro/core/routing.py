"""Query routing for distributed PSVGP serving (the sharded-cache path).

The paper's serving claim is the same as its training claim: a partition's
model only ever needs ONE-HOP information. For prediction that hop is the
blend stencil — ``blend.corner_ids_weights`` assigns every query point the
(up to) 4 partition models whose cell centers surround it, and each of
those corners is always within one grid step (including diagonals) of the
cell that OWNS the point. So when the ``PosteriorCache`` is sharded one
partition per device, a query never needs factors from outside the owning
device's 3x3 neighborhood — corner resolution is a halo exchange, exactly
like the training-time mini-batch ``ppermute`` (Katzfuss & Hammerling 2016
and Peruzzi et al. 2020 exploit the same locality for distributed
partitioned prediction).

This module is the HOST-SIDE half of that design: given a raw query batch
it builds a :class:`RoutingTable` — per-partition padded/masked query
blocks with jit-stable shapes, each query carrying its 4 corner blend
weights and the corner models encoded as 3x3-halo SLOTS (offsets relative
to the HOSTING cell) rather than global partition ids. Slots are what make
the device program mesh-local: slot k on device p always means "the model
at grid offset ``OFFSETS[k]`` from p", whichever device that is.

Two-level (skew-aware) routing: with single-level routing every device
block is padded to the HOTTEST cell's count, so a skewed stream (the
common case for regional analyses) wastes ``(q_max - count)`` rows on
nearly every device. The two-level table caps ``q_max`` below the hot-cell
peak and SPILLS the overflow onto neighboring devices. The geometric fact
that makes this free: a query's 4 blend corners span a 2x2 window of
cells, and every cell of that window sees the whole window inside its own
3x3 halo — so a query may be HOSTED by any of its corner cells, not just
its owner, and the existing device program (host-stacked 9-slot ingest,
local slot evaluation, composed reverse halo, per-row corner blend,
``scatter_results`` inverse) computes the identical blend with zero new
communication. ``spill=True`` in :func:`build_routing_table` performs the
primary+spill assignment (:func:`spill_assign`, per-slot occupancy capped
at q_max); :class:`TwoLevelQMax` is the streaming policy that feeds the
post-spill occupancy high-water mark back into the recompile decision.

The device-side half — the shard_map program that halo-exchanges the query
blocks, evaluates every device's local cached posterior, returns results,
and blends — lives in ``repro.launch.serve_sharded``.
:func:`predict_routed` below is its single-host reference implementation
(identical math, gathers instead of collectives), used by the equivalence
tests and as a fallback when no mesh is available.
"""
from __future__ import annotations

from collections.abc import Callable
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.contracts import contract
from repro.core import posterior
from repro.core.blend import corner_ids_weights
from repro.core.partition import PartitionGrid, cell_indices

# 3x3 halo slot layout, row-major over (dy, dx) in {-1, 0, +1}^2:
# slot k <-> offset (dx, dy) = (k % 3 - 1, k // 3 - 1); slot 4 is self.
# The reverse slot (offset negated) is 8 - k.
OFFSETS: tuple[tuple[int, int], ...] = tuple(
    (dx, dy) for dy in (-1, 0, 1) for dx in (-1, 0, 1)
)
SELF_SLOT = 4
NUM_HALO_SLOTS = 9


class RoutingTable(NamedTuple):
    """Per-partition routed query blocks (host numpy; leading axis = P).

    All arrays are padded to a common ``q_max`` so the device program is
    jit-stable across request batches of varying size/skew (q_max itself
    recompiles only when a batch overflows the previous high-water mark).

    A row of partition p's block is either PRIMARY (the query's owning
    cell is p) or, in a two-level table (``spill=True``), a SPILL row: a
    query from an overflowing neighbor cell re-hosted on p. Spill rows are
    indistinguishable to the device program — corner slots are always
    encoded relative to the HOSTING partition, and a spilled query's 4
    corners stay inside the host's 3x3 halo by construction (the host is
    one of the query's corner cells; see :func:`spill_assign`).

    Fields:
      xq          (P, q_max, 2) float32: queries hosted by each partition.
        Padded rows hold the cell CENTER (an in-domain point, so the
        covariance stays well-conditioned); the mask keeps them out of
        every result.
      qmask       (P, q_max) float32 {0,1}: row validity.
      corner_slot (P, q_max, 4) int32 in [0, 9): each query's 4 corner
        models as 3x3-halo slots relative to the hosting partition
        (see OFFSETS). Padded rows point at SELF_SLOT.
      corner_w    (P, q_max, 4) float32: bilinear blend weights (sum to 1
        on valid rows, all-zero on padded rows).
      src_idx     (P, q_max) int32: original index of each routed query in
        the request batch (0 on padded rows) — the scatter map back.
      counts      (P,) int32: occupied rows per partition block (primary +
        spilled-in; equals the owning-cell bucket counts when no spill).
      owner       (P, q_max) int32: flat OWNING cell id of each row's
        query (== the host id on primary and padded rows) — what makes
        spill rows auditable: ``spill_mask`` is owner != host & valid.
    """

    xq: np.ndarray
    qmask: np.ndarray
    corner_slot: np.ndarray
    corner_w: np.ndarray
    src_idx: np.ndarray
    counts: np.ndarray
    owner: np.ndarray

    @property
    def num_partitions(self) -> int:
        return self.xq.shape[0]

    @property
    def q_max(self) -> int:
        return self.xq.shape[1]

    @property
    def num_queries(self) -> int:
        return int(self.counts.sum())

    def spill_mask(self) -> np.ndarray:
        """(P, q_max) bool: valid rows hosted for a foreign owning cell."""
        host = np.arange(self.num_partitions, dtype=self.owner.dtype)[:, None]
        return (self.owner != host) & (self.qmask > 0)

    def num_spilled(self) -> int:
        """Queries re-hosted off their owning cell (0 for single-level)."""
        return int(self.spill_mask().sum())

    def waste_rows(self) -> int:
        """Padded (allocated-but-unused) device rows: P * q_max - N — the
        quantity two-level routing exists to cap under skew."""
        return self.num_partitions * self.q_max - self.num_queries


def owning_cells(grid: PartitionGrid, pts: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(ix, iy) grid cell owning each point — delegates to the SAME binning
    ``partition.partition_data`` uses (``partition.cell_indices``), so a
    routed query always lands on the device that trained on its region."""
    return cell_indices(grid, pts)


def ceil_to(n: int, k: int) -> int:
    """n rounded up to a multiple of k (shared q_max/pad alignment rule)."""
    return ((n + k - 1) // k) * k


def halo_ids(grid: PartitionGrid) -> np.ndarray:
    """(P, 9) int32: partition id at each 3x3-halo slot of every partition
    (own id where the neighbor is off-grid — those slots are never selected
    by a corner, since clipped corners stay inside the grid)."""
    P = grid.num_partitions
    ids = np.empty((P, NUM_HALO_SLOTS), np.int32)
    for p in range(P):
        ix, iy = grid.cell_of(p)
        for k, (dx, dy) in enumerate(OFFSETS):
            jx, jy = ix + dx, iy + dy
            inside = 0 <= jx < grid.gx and 0 <= jy < grid.gy
            ids[p, k] = grid.index_of(jx, jy) if inside else p
    return ids


def spill_assign(
    own: np.ndarray, ids: np.ndarray, q_max: int, num_partitions: int
) -> np.ndarray | None:
    """Two-level host assignment: every query of a cell whose bucket fits
    ``q_max`` stays PRIMARY; hot-cell overflow SPILLS to one of the query's
    other corner cells with free slot capacity.

    Why corner cells are the only legal spill targets: the 4 blend corners
    of a query span a 2x2 window of cells, so any cell of that window sees
    all 4 corners inside its own 3x3 halo — re-hosting the query there
    keeps the device program's slot encoding valid. An arbitrary halo
    neighbor does NOT have that property (a corner can end up 2 steps
    away), which is why the spill candidates are ``set(ids[i]) - {own[i]}``
    and nothing else.

    Deterministic greedy with per-slot occupancy:
      * per hot cell, queries with NO spill candidates (domain-corner
        degenerate windows) are kept primary first, then stable order;
      * overflow is grouped by (owner, corner window) — all queries of a
        group share the same candidate set — groups are processed most
        constrained first (fewest candidates, then largest), and each
        group fills its candidates in descending remaining capacity.

    Args:
      own: (N,) flat owning cell per query.
      ids: (N, 4) corner cell ids (``blend.corner_ids_weights`` order).
      q_max: per-partition slot budget (occupancy hard cap).
      num_partitions: P.

    Returns host (N,) int64 (bincount(host) <= q_max everywhere), or None
    when the overflow does not fit the neighborhood's free capacity at
    this q_max — the caller (policy) must raise q_max.
    """
    host = own.astype(np.int64).copy()
    counts = np.bincount(own, minlength=num_partitions)
    hot = np.flatnonzero(counts > q_max)
    if hot.size == 0:
        return host
    occupancy = np.minimum(counts, q_max)
    has_alt = (ids != own[:, None]).any(axis=1)  # (N,) any candidate != owner

    # collect every hot cell's overflow (candidate-less queries kept
    # primary first — they cannot move, so they must hold a primary slot)
    overflow: list = []
    for p in hot:
        idx = np.flatnonzero(own == p)  # ascending == stable order
        if (~has_alt[idx]).sum() > q_max:
            return None  # immovable queries alone overflow the block
        # candidate-less first (has_alt False sorts before True), stable
        keep_order = idx[np.argsort(has_alt[idx], kind="stable")]
        overflow.append(keep_order[q_max:])
    ovf = np.sort(np.concatenate(overflow))
    if ovf.size == 0:
        return host

    # group by (owner, corner window): one candidate set per group
    keys = np.concatenate([own[ovf, None], ids[ovf]], axis=1)
    uniq, inv = np.unique(keys, axis=0, return_inverse=True)
    groups = []
    for g in range(uniq.shape[0]):
        members = ovf[inv == g]  # ascending original order
        cands = np.unique(uniq[g, 1:])
        cands = cands[cands != uniq[g, 0]]
        groups.append((len(cands), -members.size, g, members, cands))
    groups.sort(key=lambda t: t[:3])  # most constrained first, deterministic

    for _, _, _, members, cands in groups:
        left = members.size
        filled = 0
        # two passes over candidates in descending remaining capacity (id
        # tiebreak): first an even capacity-capped split — leveling the
        # occupancies keeps shared neighbors open for later groups — then
        # a greedy pass that dumps any remainder wherever slots are free.
        order = np.lexsort((cands, occupancy[cands] - q_max))
        for npass in (len(order), 1):
            for t, j in enumerate(order):
                h = cands[j]
                share = -(-left // max(npass - t, 1))  # ceil even split
                take = min(left, share, q_max - int(occupancy[h]))
                if take <= 0:
                    continue
                host[members[filled:filled + take]] = h
                occupancy[h] += take
                filled += take
                left -= take
            if left == 0:
                break
        if left > 0:
            return None  # neighborhood capacity exhausted at this q_max
    return host


def min_spill_q_max(
    own: np.ndarray, ids: np.ndarray, num_partitions: int
) -> int:
    """Smallest q_max the greedy :func:`spill_assign` can route this batch
    at (binary search; the single-level answer, max bucket count, is always
    feasible and bounds the search)."""
    counts = np.bincount(own, minlength=num_partitions)
    hi = max(int(counts.max()) if own.size else 0, 1)
    lo = max(-(-own.size // num_partitions), 1)  # total rows must cover N
    while lo < hi:
        mid = (lo + hi) // 2
        if spill_assign(own, ids, mid, num_partitions) is not None:
            hi = mid
        else:
            lo = mid + 1
    return lo


def build_routing_table(
    grid: PartitionGrid,
    points: np.ndarray,
    *,
    q_max: int | None = None,
    pad_multiple: int = 8,
    cells: tuple[np.ndarray, np.ndarray] | None = None,
    corners: tuple[np.ndarray, np.ndarray] | None = None,
    spill: bool = False,
    hosts: np.ndarray | None = None,
) -> RoutingTable:
    """Bucket a query batch into padded device blocks (single- or two-level).

    Args:
      grid: the partition grid (must match the sharded cache's grid).
      points: (N, 2) query coordinates.
      q_max: fixed per-partition block size; default = the batch's max
        bucket count rounded up to ``pad_multiple``. When a bucket
        overflows an explicit q_max: with ``spill=False`` raises ValueError
        (routing must never silently drop queries); with ``spill=True``
        the overflow is re-hosted on corner-cell neighbors instead.
      pad_multiple: round q_max up to this (TPU sublane alignment).
      cells: precomputed ``owning_cells(grid, points)`` for this batch.
        Callers that already binned the batch (the q_max policies — both
        :class:`StreamingQMax` and the whole-stream prepass — must count
        buckets before the table is built) pass it through so the binning
        runs ONCE per request, not once per policy decision plus once per
        table; omitted, it is computed here.
      corners: precomputed ``corner_ids_weights(grid, points)`` — same
        reuse contract as ``cells`` (the two-level policy needs the corner
        windows for its spill plan; don't recompute them here).
      spill: build a TWO-LEVEL table — hot-cell overflow beyond q_max is
        hosted on the queries' other corner cells (see :func:`spill_assign`
        and the module docstring). Requires an explicit ``q_max`` (the
        whole point is capping the block below the hot-cell peak; a policy
        such as :class:`TwoLevelQMax` owns that choice).
      hosts: precomputed ``spill_assign`` result for exactly this
        (batch, q_max) — the two-level policy already ran the assignment
        for its feasibility decision; pass it through so it runs once.

    Returns a :class:`RoutingTable` (see its docstring for shapes).
    """
    pts = np.asarray(points, np.float32)
    if pts.ndim != 2 or pts.shape[1] != 2:
        raise ValueError(f"points must be (N, 2), got {pts.shape}")
    n = pts.shape[0]
    P = grid.num_partitions

    ix, iy = owning_cells(grid, pts) if cells is None else cells
    if ix.shape != (n,) or iy.shape != (n,):
        raise ValueError(
            f"cells must be owning_cells output for the batch: expected two "
            f"({n},) arrays, got {ix.shape} and {iy.shape}"
        )
    own = iy * grid.gx + ix  # (N,) flat owning partition
    ids, w = corner_ids_weights(grid, pts) if corners is None else corners
    if ids.shape != (n, 4) or w.shape != (n, 4):
        raise ValueError(
            f"corners must be corner_ids_weights output for the batch: "
            f"expected two (n, 4) arrays, got {ids.shape} and {w.shape}"
        )

    counts = np.bincount(own, minlength=P).astype(np.int32)
    need = int(counts.max()) if n else 0
    if spill and q_max is None:
        raise ValueError(
            "spill=True needs an explicit q_max budget (use TwoLevelQMax "
            "or min_spill_q_max to choose one)"
        )
    if q_max is None:
        qm = max(need, 1)
    elif need > q_max and not spill:
        raise ValueError(
            f"partition bucket of {need} queries overflows q_max={q_max}; "
            "routing never drops queries — raise q_max, split the batch, "
            "or route two-level (spill=True)"
        )
    else:
        qm = q_max
    qm = ceil_to(qm, pad_multiple)

    if spill:
        host = spill_assign(own, ids, qm, P) if hosts is None else np.asarray(hosts)
        if host is None and qm != q_max:
            # greedy feasibility is not strictly monotone in q_max, so the
            # pad-rounded budget can in principle fail where the caller's
            # exact q_max succeeded — any assignment within the smaller
            # budget also fits the padded block (occupancy <= q_max <= qm)
            host = spill_assign(own, ids, int(q_max), P)
        if host is None:
            raise ValueError(
                f"two-level routing infeasible at q_max={qm}: hot-cell "
                "overflow exceeds the corner neighborhoods' free capacity "
                "— raise q_max (min_spill_q_max gives the feasible floor)"
            )
        if host.shape != (n,):
            raise ValueError(f"hosts must be ({n},), got {host.shape}")
    else:
        host = own
    counts = np.bincount(host, minlength=P).astype(np.int32)
    if n and int(counts.max()) > qm:
        raise ValueError("spill assignment overflows q_max — invalid hosts=")

    # corner slots RELATIVE TO THE HOST cell; a spill host is one of the
    # query's corner cells, so every slot stays inside the 3x3 halo
    hx_, hy_ = host % grid.gx, host // grid.gx
    dx = ids % grid.gx - hx_[:, None]  # (N, 4) in {-1, 0, 1}
    dy = ids // grid.gx - hy_[:, None]
    slot = ((dy + 1) * 3 + (dx + 1)).astype(np.int32)
    if n and (np.abs(dx).max() > 1 or np.abs(dy).max() > 1):
        raise AssertionError("spill host outside a query's corner window")

    # stable bucket fill, vectorized: position of each query within its
    # hosting partition's block = rank among same-host queries.
    order = np.argsort(host, kind="stable")
    sorted_host = host[order]
    pos = np.arange(n) - np.searchsorted(sorted_host, sorted_host)

    # padded rows: cell centers (valid covariance inputs, masked on output)
    cx = 0.5 * (grid.x_edges[:-1] + grid.x_edges[1:])
    cy = 0.5 * (grid.y_edges[:-1] + grid.y_edges[1:])
    centers = np.stack(np.meshgrid(cx, cy), axis=-1).reshape(P, 2).astype(np.float32)

    xq = np.broadcast_to(centers[:, None, :], (P, qm, 2)).copy()
    qmask = np.zeros((P, qm), np.float32)
    corner_slot = np.full((P, qm, 4), SELF_SLOT, np.int32)
    corner_w = np.zeros((P, qm, 4), np.float32)
    src_idx = np.zeros((P, qm), np.int32)
    owner = np.broadcast_to(
        np.arange(P, dtype=np.int32)[:, None], (P, qm)
    ).copy()

    xq[sorted_host, pos] = pts[order]
    qmask[sorted_host, pos] = 1.0
    corner_slot[sorted_host, pos] = slot[order]
    corner_w[sorted_host, pos] = w[order]
    src_idx[sorted_host, pos] = order.astype(np.int32)
    owner[sorted_host, pos] = own[order].astype(np.int32)

    return RoutingTable(
        xq=xq, qmask=qmask, corner_slot=corner_slot, corner_w=corner_w,
        src_idx=src_idx, counts=counts, owner=owner,
    )


class StreamingQMax:
    """Streaming high-water-mark q_max policy for a LIVE request stream.

    The whole-stream prepass (``serve_sharded.fixed_q_max``) needs every
    batch up front — impossible for a real stream. This policy instead
    grows q_max only when a batch's max bucket count overflows the current
    high-water mark, jumping to ``need * headroom`` rounded up with the
    SAME :func:`ceil_to` alignment the table applies. Multiplicative
    headroom bounds the total number of shape changes (device-program
    recompiles) at O(log_headroom(peak_need / first_need)) however long
    the stream runs; both overflows and compiles are counted so the
    serving report can show them.

    Usage per batch::

        cells = routing.owning_cells(grid, q)
        q_max = policy.fit(np.bincount(cells_flat, minlength=P))
        table = routing.build_routing_table(grid, q, q_max=q_max, cells=cells)
    """

    def __init__(self, *, headroom: float = 1.25, pad_multiple: int = 8):
        if headroom < 1.0:
            raise ValueError(f"headroom must be >= 1, got {headroom}")
        self.headroom = float(headroom)
        self.pad_multiple = int(pad_multiple)
        self.q_max = 0  # current high-water mark (0 = nothing seen yet)
        self.compiles = 0  # shape changes, INCLUDING the first batch
        self.overflows = 0  # batches that burst the previous high-water mark

    def fit(self, counts: np.ndarray) -> int:
        """Observe a batch's per-partition bucket counts; return the q_max
        to route it with (always >= the batch's max bucket)."""
        need = max(int(np.max(counts)) if np.size(counts) else 0, 1)
        if need > self.q_max:
            if self.q_max:
                self.overflows += 1
            self.q_max = ceil_to(
                int(np.ceil(need * self.headroom)), self.pad_multiple
            )
            self.compiles += 1
        return self.q_max

    def stats(self) -> dict:
        """The SLO-report record: current mark + recompile/overflow counts."""
        return {
            "q_max": self.q_max,
            "compiles": self.compiles,
            "overflows": self.overflows,
        }


class TwoLevelQMax(StreamingQMax):
    """Streaming q_max policy for TWO-LEVEL (spill) routing.

    :class:`StreamingQMax` tracks the high-water mark of the raw max
    bucket count — under skew that is the hot cell's peak, and every other
    device pads to it. This policy instead tracks the POST-SPILL per-slot
    occupancy: a batch only forces a recompile when the greedy spill plan
    (:func:`spill_assign`) cannot place it inside the current mark, and
    growth jumps to the batch's minimal FEASIBLE q_max
    (:func:`min_spill_q_max`) times the same multiplicative headroom — so
    spill capacity feeds back into the recompile decision, and a zipf
    stream settles near the neighborhood-balanced budget (~peak/9 for an
    isolated hot cell) instead of the peak itself.

    Usage per batch (``serve_sharded.make_request_stages`` does this)::

        own = iy * grid.gx + ix                    # owning_cells, flat
        ids, w = corner_ids_weights(grid, q)
        q_max, hosts = policy.fit_spill(grid, own, ids)
        table = routing.build_routing_table(
            grid, q, q_max=q_max, cells=(ix, iy), corners=(ids, w),
            spill=True, hosts=hosts)

    Stats extend the base record with ``spilled`` — total queries
    re-hosted off their owning cell so far.
    """

    def __init__(self, *, headroom: float = 1.25, pad_multiple: int = 8):
        super().__init__(headroom=headroom, pad_multiple=pad_multiple)
        self.spilled = 0  # total queries re-hosted so far

    def fit_spill(
        self, grid: PartitionGrid, own: np.ndarray, ids: np.ndarray
    ) -> tuple[int, np.ndarray]:
        """Observe a batch (flat owning cells + corner ids); return the
        (q_max, hosts) to route it with. ``hosts`` is the exact
        ``spill_assign`` result at the returned q_max — pass BOTH into
        ``build_routing_table`` so the plan is never recomputed."""
        P = grid.num_partitions
        if self.q_max:
            host = spill_assign(own, ids, self.q_max, P)
            if host is not None:  # fits the current mark: no shape change
                self.spilled += int(np.sum(host != own))
                return self.q_max, host
            self.overflows += 1
        need = min_spill_q_max(own, ids, P)
        qm = max(
            ceil_to(int(np.ceil(need * self.headroom)), self.pad_multiple),
            self.q_max,
        )
        host = spill_assign(own, ids, qm, P)
        while host is None:  # greedy can be non-monotone near the floor
            qm = ceil_to(qm + self.pad_multiple, self.pad_multiple)
            host = spill_assign(own, ids, qm, P)
        self.q_max = qm
        self.compiles += 1
        self.spilled += int(np.sum(host != own))
        return qm, host

    def fit(self, counts: np.ndarray) -> int:
        raise TypeError(
            "TwoLevelQMax routes on corner windows, not bucket counts — "
            "call fit_spill(grid, own, ids) (see the class docstring)"
        )

    def stats(self) -> dict:
        return {**super().stats(), "spilled": self.spilled}


def halo_slot_on_grid(grid: PartitionGrid) -> np.ndarray:
    """(P, 9) float32 {0,1}: 1 where the slot's neighbor exists on the grid
    (complement of the off-grid slots ``halo_ids`` clamps to self)."""
    P = grid.num_partitions
    on = np.zeros((P, NUM_HALO_SLOTS), np.float32)
    for p in range(P):
        ix, iy = grid.cell_of(p)
        for k, (dx, dy) in enumerate(OFFSETS):
            if 0 <= ix + dx < grid.gx and 0 <= iy + dy < grid.gy:
                on[p, k] = 1.0
    return on


def make_halo_stacker(grid: PartitionGrid) -> Callable[[np.ndarray], np.ndarray]:
    """Build ``stack(xq) -> hx``: the host-side halo ingest of the sharded
    serving program.

    hx (P, 9, q_max, d) with hx[p, k] = xq[p + OFFSETS[k]] (zeros where the
    neighbor is off-grid — matching ppermute's edge semantics, so the device
    program computes exactly what a mesh-side query exchange would). The
    queries are HOST data: the router already holds every partition's
    block, so shipping each device its full 9-slot stack directly through
    ingest costs one device_put and ZERO mesh collectives — the 1-hop
    reverse halo is reserved for the results, which really do live on
    devices. The (halo_ids, on-grid-mask) tables are precomputed here, once
    per grid, off the per-request path.
    """
    hids = halo_ids(grid)  # (P, 9)
    on = halo_slot_on_grid(grid)  # (P, 9)

    def stack(xq: np.ndarray) -> np.ndarray:
        xq = np.asarray(xq)
        return xq[hids] * on[..., None, None].astype(xq.dtype)

    return stack


def coalesce_requests(requests) -> tuple[np.ndarray, np.ndarray]:
    """Concatenate many small independent query arrays into ONE batch.

    The continuous-batching ingest of the async front door
    (``repro.api.frontdoor``): each request is an (n_i, 2) point array;
    the coalesced (N, 2) batch routes through the device program exactly
    like a single large request, and :func:`demux_results` splits the
    answers back per request. Because every per-row quantity of the
    padded serving program depends only on that row's query point and
    the cached factors (the slots kernel's row-independence contract,
    ``kernels.ref.posterior_predict_slots_masked``), the coalesced-then-
    demuxed results over the sharded path are BITWISE equal to serving
    each request alone — the golden property tests/test_frontdoor.py
    gates. (The replicated path agrees to float32 ULP: XLA specializes
    ``predict`` per batch shape there, so tiny requests can round a last
    bit differently inside a larger batch.)

    Returns (points (N, 2) float32, sizes (R,) int64) with
    N = sizes.sum(). Raises on an empty request list, an empty request,
    or a non-(n, 2) shape — admission control must reject malformed
    requests before they reach a device batch.
    """
    if len(requests) == 0:
        raise ValueError("coalesce_requests needs at least one request")
    arrs = []
    for i, r in enumerate(requests):
        a = np.asarray(r, np.float32)
        if a.ndim != 2 or a.shape[1] != 2 or a.shape[0] < 1:
            raise ValueError(
                f"request {i} must be a non-empty (n, 2) point array, "
                f"got shape {a.shape}"
            )
        arrs.append(a)
    sizes = np.asarray([a.shape[0] for a in arrs], np.int64)
    return np.concatenate(arrs, axis=0), sizes


def demux_results(sizes: np.ndarray, *arrays: np.ndarray) -> list[tuple]:
    """Split coalesced per-point results back into per-request tuples.

    Exact inverse of the concatenation order of
    :func:`coalesce_requests`: ``arrays`` are (N, ...) results for the
    coalesced batch (typically mean and var, each (N,)), and the return
    value is a list of R tuples, tuple i holding each array's
    ``sizes[i]``-row slice for request i. Slices are copies — a demuxed
    result must stay valid after the batch buffer is reused.
    """
    sizes = np.asarray(sizes)
    offsets = np.cumsum(sizes)[:-1]
    per_array = []
    for a in arrays:
        a = np.asarray(a)
        if a.shape[0] != int(sizes.sum()):
            raise ValueError(
                f"result rows {a.shape[0]} != coalesced rows {int(sizes.sum())}"
            )
        per_array.append([s.copy() for s in np.split(a, offsets)])
    return list(zip(*per_array, strict=True))


@contract(
    args={"values": "(P, Q)"},
    returns="(N,)",
    invariants=("scatter-is-gather-inverse",),
)
def scatter_results(table: RoutingTable, values: np.ndarray) -> np.ndarray:
    """Reassemble per-partition padded results into request order.

    ``values`` is (P, q_max) (or (P, q_max, ...)); returns (N, ...) with N =
    ``table.num_queries``, inverting the routing permutation. This is also
    the inverse for TWO-LEVEL tables: ``src_idx`` maps every valid row —
    primary or spilled — straight back to its request position, so spilled
    rows need no extra bookkeeping on the way home (the composed reverse
    halo already delivered their corner evaluations to the hosting device,
    same as primary rows).
    """
    values = np.asarray(values)
    out = np.empty((table.num_queries,) + values.shape[2:], values.dtype)
    valid = table.qmask > 0
    out[table.src_idx[valid]] = values[valid]
    return out


def blend_slots(
    res_mean: jnp.ndarray,
    res_var: jnp.ndarray,
    corner_slot: jnp.ndarray,
    corner_w: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Resolve per-slot evaluations into the 4-corner bilinear blend.

    Args:
      res_mean / res_var: (9, q) — the halo-resolved evaluations of ONE
        partition's q queries: slot k holds the prediction of the model at
        grid offset OFFSETS[k] from the owner.
      corner_slot: (q, 4) int32 slot index of each query's 4 corners.
      corner_w: (q, 4) bilinear weights.

    Returns (mean (q,), var (q,)) — same mixture formula as
    ``blend.predict_blended``: var is the blend of second moments minus the
    blended mean squared, clamped to >= 1e-12.
    """
    m_c = jnp.take_along_axis(res_mean, corner_slot.T, axis=0).T  # (q, 4)
    v_c = jnp.take_along_axis(res_var, corner_slot.T, axis=0).T
    mean = jnp.sum(corner_w * m_c, axis=1)
    second = jnp.sum(corner_w * (v_c + m_c**2), axis=1)
    var = jnp.maximum(second - mean**2, 1e-12)
    return mean, var


def predict_routed(
    cache: posterior.PosteriorCache,
    cov_fn: Callable,
    grid: PartitionGrid,
    table: RoutingTable,
    *,
    use_pallas: bool = False,
) -> tuple[np.ndarray, np.ndarray]:
    """Single-host reference of the sharded serving program (same math).

    For every partition p and halo slot k, evaluates the model at
    ``halo_ids(grid)[p, k]`` on p's routed queries, then blends via
    :func:`blend_slots` — exactly what the shard_map program in
    ``repro.launch.serve_sharded`` computes with ``ppermute`` halo
    exchanges instead of gathers. Returns (mean (N,), var (N,)) in request
    order. Works unchanged on TWO-LEVEL tables: a spill row's corner slots
    are encoded relative to its hosting cell and stay inside the host's
    halo, so the same slot evaluations resolve its blend.
    """
    hids = jnp.asarray(halo_ids(grid))  # (P, 9)
    xq = jnp.asarray(table.xq)

    def eval_slot(k):
        cache_k = posterior.take_cache(cache, hids[:, k])  # leaves (P, ...)
        return posterior.predict_cached_stacked(
            cache_k, cov_fn, xq, use_pallas=use_pallas
        )

    res = [eval_slot(k) for k in range(NUM_HALO_SLOTS)]
    res_mean = jnp.stack([m for m, _ in res], axis=1)  # (P, 9, q)
    res_var = jnp.stack([v for _, v in res], axis=1)
    mean, var = jax.vmap(blend_slots)(
        res_mean, res_var, jnp.asarray(table.corner_slot), jnp.asarray(table.corner_w)
    )
    return (
        scatter_results(table, np.asarray(mean)),
        scatter_results(table, np.asarray(var)),
    )
