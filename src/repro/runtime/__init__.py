from repro.runtime.steps import (
    TrainState,
    cross_entropy,
    init_train_state,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)

__all__ = [
    "TrainState",
    "cross_entropy",
    "init_train_state",
    "make_train_step",
    "make_prefill_step",
    "make_decode_step",
]
