"""Jittable train / prefill / decode steps for the LM substrate.

These are the functions the launcher lowers on the production mesh:
  train_step   — fwd + CE loss (+ MoE aux) + AdamW       (train_4k)
  prefill_step — fwd over the prompt, builds the cache    (prefill_32k)
  decode_step  — ONE token against the cache              (decode_32k, long_500k)
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig
from repro.optim import AdamState, adam_init, adamw_update, clip_by_global_norm


class TrainState(NamedTuple):
    params: Any
    opt: AdamState
    step: jnp.ndarray


def init_train_state(key: jax.Array, cfg: ModelConfig) -> TrainState:
    params = transformer.init_model_params(key, cfg)
    return TrainState(params=params, opt=adam_init(params), step=jnp.zeros((), jnp.int32))


def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray) -> jnp.ndarray:
    """Mean token CE in fp32. logits (B, S, V), targets (B, S) int32."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def _loss_fn(params, cfg: ModelConfig, batch: dict[str, jnp.ndarray]):
    logits, _, aux = transformer.forward(
        params,
        cfg,
        batch["tokens"],
        frames=batch.get("frames"),
        patches=batch.get("patches"),
    )
    targets = batch["targets"]
    if logits.shape[1] != targets.shape[1]:
        # VLM: image positions prepended — loss on the text region only
        logits = logits[:, -targets.shape[1] :]
    ce = cross_entropy(logits, targets)
    aux_w = cfg.moe.router_aux_weight if cfg.moe is not None else 0.0
    return ce + aux_w * aux, (ce, aux)


def make_train_step(
    cfg: ModelConfig,
    learning_rate: float = 3e-4,
    clip_norm: float = 1.0,
    microbatches: int = 1,
):
    """Build the jittable train step (the launcher adds shardings).

    microbatches > 1 runs gradient accumulation: the global batch is split
    into M chunks scanned sequentially, activation memory scales ~1/M while
    the optimizer sees the same averaged gradient (§Perf memory lever —
    the grad accumulator is params-shaped, so with FSDP it stays sharded).
    """

    def train_step(state: TrainState, batch: dict[str, jnp.ndarray]):
        if microbatches == 1:
            (loss, (ce, aux)), grads = jax.value_and_grad(_loss_fn, has_aux=True)(
                state.params, cfg, batch
            )
        else:
            mb = {
                k: v.reshape((microbatches, v.shape[0] // microbatches) + v.shape[1:])
                for k, v in batch.items()
            }

            def body(acc, chunk):
                g_acc, l_acc, ce_acc, aux_acc = acc
                (l, (ce_i, aux_i)), g = jax.value_and_grad(_loss_fn, has_aux=True)(
                    state.params, cfg, chunk
                )
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l, ce_acc + ce_i, aux_acc + aux_i), None

            zeros = jax.tree.map(jnp.zeros_like, state.params)
            (grads, loss, ce, aux), _ = jax.lax.scan(
                body, (zeros, 0.0, 0.0, 0.0), mb
            )
            inv = 1.0 / microbatches
            grads = jax.tree.map(lambda g: g * inv, grads)
            loss, ce, aux = loss * inv, ce * inv, aux * inv
        grads = clip_by_global_norm(grads, clip_norm)
        params, opt = adamw_update(state.params, grads, state.opt, lr=learning_rate)
        metrics = {"loss": loss, "ce": ce, "aux": aux}
        return TrainState(params, opt, state.step + 1), metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, cache_len: int):
    """Prompt -> (last-token logits, filled cache)."""
    serve_cfg = dataclasses.replace(cfg, remat=False)

    def prefill_step(params, tokens, frames=None, patches=None):
        B = tokens.shape[0]
        cache = transformer.init_cache(serve_cfg, B, cache_len, jnp.dtype(serve_cfg.dtype))
        logits, cache, _ = transformer.forward(
            params, serve_cfg, tokens, frames=frames, patches=patches,
            cache=cache, cache_pos=jnp.zeros((), jnp.int32),
        )
        return logits[:, -1], cache

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    """(cache, pos, token) -> (logits, new cache). ONE new token."""
    serve_cfg = dataclasses.replace(cfg, remat=False)

    def decode_step(params, cache, cache_pos, tokens):
        logits, cache, _ = transformer.forward(
            params, serve_cfg, tokens, cache=cache, cache_pos=cache_pos
        )
        return logits[:, -1], cache

    return decode_step
