"""JAX API compatibility shims.

The codebase targets the modern jax API surface (``jax.shard_map``,
``jax.set_mesh``, ``jax.sharding.get_abstract_mesh``, typed mesh axes), but
the pinned container runs jax 0.4.37 where those names either live under
``jax.experimental`` or do not exist yet. Every call site imports from THIS
module instead of feature-detecting locally, so the day the pin moves the
shims collapse to re-exports.

Exports
  shard_map(f, *, mesh, in_specs, out_specs, check_vma=...)
      Modern keyword signature; maps ``check_vma`` onto the legacy
      ``check_rep`` flag when falling back to jax.experimental.shard_map.
  get_abstract_mesh() -> Mesh | None
      The mesh of the innermost ``set_mesh`` scope (None outside one).
  set_mesh(mesh)
      Context manager activating ``mesh``; legacy fallback enters the mesh
      itself (Mesh has been a context manager since 0.3).
  make_mesh(axis_shapes, axis_names)
      ``jax.make_mesh`` minus the ``axis_types`` argument, which 0.4.37
      does not accept (axes behave as Auto there, matching our usage).
"""
from __future__ import annotations

from collections.abc import Callable, Sequence
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec


def shard_map(
    f: Callable,
    *,
    mesh: Mesh,
    in_specs: Any,
    out_specs: Any,
    check_vma: bool = True,
) -> Callable:
    """Modern ``jax.shard_map`` signature on any supported jax."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )
    from jax.experimental.shard_map import shard_map as _legacy

    return _legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma)


def get_abstract_mesh() -> Mesh | None:
    """Active mesh context, or None when no mesh scope is open."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return None
        return mesh
    from jax._src import mesh as _mesh_lib

    mesh = _mesh_lib.thread_resources.env.physical_mesh
    return None if mesh.empty else mesh


def set_mesh(mesh: Mesh):
    """Context manager activating ``mesh`` for sharding propagation."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh  # legacy: ``with mesh:`` sets the thread-resource env


def named_shardings(mesh: Mesh, tree: Any) -> Any:
    """Make an in/out_shardings pytree acceptable to this jax's ``jit``.

    Modern jax consumes raw PartitionSpecs (and None = compiler-chosen)
    inside a ``set_mesh`` scope — the tree passes through UNCHANGED there,
    preserving auto-sharding semantics. 0.4.37 requires concrete Sharding
    objects, so on legacy jax PartitionSpec leaves become NamedShardings
    and None leaves fall back to replicated (the closest expressible
    meaning; 0.4.37 has no per-leaf 'unspecified')."""
    if hasattr(jax, "set_mesh"):  # modern: raw specs/None are first-class
        return tree

    def conv(leaf):
        if leaf is None:
            return NamedSharding(mesh, PartitionSpec())
        if isinstance(leaf, PartitionSpec):
            return NamedSharding(mesh, leaf)
        return leaf

    return jax.tree.map(
        conv, tree, is_leaf=lambda l: l is None or isinstance(l, PartitionSpec)
    )


def cost_analysis(compiled: Any) -> dict:
    """``compiled.cost_analysis()`` as a flat dict on any supported jax
    (jax <= 0.4.x returns a one-entry list of per-program dicts)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]) -> Mesh:
    """``jax.make_mesh`` with Auto-typed axes on any supported jax."""
    try:
        from jax.sharding import AxisType  # modern jax

        return jax.make_mesh(
            tuple(axis_shapes), tuple(axis_names),
            axis_types=(AxisType.Auto,) * len(tuple(axis_names)),
        )
    except ImportError:
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))
