"""One front door for the static verification layer.

    PYTHONPATH=src python -m repro.analysis                 # all passes
    PYTHONPATH=src python -m repro.analysis --passes ast    # source lint only
    make analyze                                            # CI entry point

Runs the five passes (HLO invariant linter, repo-rule AST lint,
trace-time contracts, compiled cost-model gates, async race lint),
prints every finding, writes ``ANALYSIS.json`` (per-lane collective
counts and cost records, per-rule tallies, findings) and exits non-zero
iff anything was found — so CI both gates on it and can diff invariant
drift between pushes, the way
``benchmarks/check_bench_regression.py`` gates p50.

Virtual host devices are forced BEFORE anything jax-backed is imported
(the hlo/contracts passes lower real mesh programs), exactly like the
sharded serving entry points.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="PSVGP static verification: HLO invariants, repo-rule "
        "AST lint, trace-time contracts.",
    )
    ap.add_argument(
        "--passes",
        default="hlo,ast,contracts,costs,async",
        help="comma-separated subset of hlo,ast,contracts,costs,async "
        "(default: all)",
    )
    ap.add_argument(
        "--grid", type=int, default=4, help="probe grid side (devices = grid^2)"
    )
    ap.add_argument("--m", type=int, default=8, help="inducing points per partition")
    ap.add_argument("--q-max", type=int, default=64, help="probe block size")
    ap.add_argument(
        "--root", default="src", help="source root for the AST pass"
    )
    ap.add_argument(
        "--out",
        default="ANALYSIS.json",
        help="JSON report path ('' to skip writing)",
    )
    ap.add_argument(
        "--baselines",
        default=None,
        help="cost-baseline JSON path (default: "
        "benchmarks/baselines/analysis_costs.json)",
    )
    ap.add_argument(
        "--update-baselines",
        action="store_true",
        help="rewrite the cost baseline from this run instead of gating "
        "drift against it (commit the result)",
    )
    return ap


def main(argv=None) -> int:
    from repro.analysis import PASSES

    args = build_parser().parse_args(argv)
    passes = tuple(p.strip() for p in args.passes.split(",") if p.strip())
    unknown = set(passes) - set(PASSES)
    if unknown:
        print(f"unknown passes {sorted(unknown)}; choose from {PASSES}")
        return 2

    needs_mesh = bool({"hlo", "contracts", "costs"} & set(passes))
    if needs_mesh:
        # must precede any jax backend touch (see ensure_host_devices)
        from repro.launch.serve_sharded import ensure_host_devices

        n_devices = args.grid * args.grid
        if "costs" in passes:
            # the cost pass sweeps its own fixed grid points
            from repro.analysis.costs import REQUIRED_DEVICES

            n_devices = max(n_devices, REQUIRED_DEVICES)
        ensure_host_devices(n_devices)

    t0 = time.time()
    findings = []
    report = {"passes": {}}
    if "hlo" in passes:
        from repro.analysis import hlo

        fs, rep = hlo.run(grid_side=args.grid, m=args.m, q_max=args.q_max)
        findings.extend(fs)
        report["passes"]["hlo"] = rep
        print(f"[hlo]       {len(rep['lanes'])} lanes, "
              f"{len(rep['programs_lowered'])} programs lowered, "
              f"{len(fs)} finding(s) in {rep['seconds']}s")
    if "ast" in passes:
        from repro.analysis import astlint

        fs, rep = astlint.run(args.root)
        findings.extend(fs)
        report["passes"]["ast"] = rep
        print(f"[ast]       {rep['files_scanned']} files, "
              f"{len(fs)} finding(s)")
    if "contracts" in passes:
        from repro.analysis import contracts

        fs, rep = contracts.run(grid_side=args.grid, m=args.m)
        findings.extend(fs)
        report["passes"]["contracts"] = rep
        print(f"[contracts] {len(rep['targets_checked'])} targets, "
              f"{len(fs)} finding(s) in {rep['seconds']}s")
    if "costs" in passes:
        from repro.analysis import costs

        kw = {"update_baselines": args.update_baselines}
        if args.baselines is not None:
            kw["baseline_path"] = args.baselines
        fs, rep = costs.run(**kw)
        findings.extend(fs)
        report["passes"]["costs"] = rep
        print(f"[costs]     {len(rep['programs'])} programs compiled at "
              f"{sum(len(r['points']) for r in rep['programs'].values())} "
              f"scale points, {len(fs)} finding(s) in {rep['seconds']}s"
              + (" (baselines updated)" if rep["baseline_updated"] else ""))
    if "async" in passes:
        from repro.analysis import asynclint

        fs, rep = asynclint.run(args.root)
        findings.extend(fs)
        report["passes"]["async"] = rep
        print(f"[async]     {rep['files_scanned']} files, "
              f"{len(fs)} finding(s)")

    report["findings"] = [f.to_dict() for f in findings]
    report["total_findings"] = len(findings)
    report["seconds"] = round(time.time() - t0, 3)

    for f in findings:
        print(f"  {f}")
    if args.out:
        with open(args.out, "w", encoding="utf-8") as fh:
            json.dump(report, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"report -> {args.out}")
    verdict = "CLEAN" if not findings else f"{len(findings)} VIOLATION(S)"
    print(f"analysis: {verdict} ({report['seconds']}s)")
    return 0 if not findings else 1


if __name__ == "__main__":
    sys.exit(main())
