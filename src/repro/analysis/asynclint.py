"""Pass 5 — CFG-lite race lint for the asyncio serving layer.

PR 7's ``api.FrontDoor`` put an event loop in front of the device: per-
request ``asyncio.Future``s, a batching engine task, and worker threads
for everything that blocks (device collects, dispatch-time recompiles).
That buys continuous batching — and a whole class of hazards no other
pass sees: a blocking call on the loop stalls EVERY client at once; state
shared between the loop and a worker thread races; a dropped task or an
unresolved future hangs a client forever with no traceback anywhere.

This pass codifies those hazards as AST rules over every source file
(today that means ``api/frontdoor.py``, ``api/server.py``,
``launch/serve_sharded.py``, the ``net/`` transport layer — and any
async code a later PR adds):

  RR005  no blocking calls inside ``async def``: ``time.sleep``,
         ``Future.result()``, stdlib ``queue`` get/put/join,
         ``block_until_ready``, or a direct (un-executored) call of a
         device collect stage. The loop thread only ever coalesces
         python objects; device syncs live in the worker pool.
  RR006  every attribute written from both the event loop and a worker
         thread must be lock-guarded or declared (with its safety
         argument) in the per-class ``CONFINEMENT`` manifest below.
  RR007  ``create_task`` / ``ensure_future`` / ``run_in_executor``
         results must be stored or awaited — a bare statement drops the
         only reference: exceptions vanish and the task can be GC'd
         mid-flight (the lost-task bug).
  RR008  a function that delivers request futures (``set_result``) or an
         engine-shaped loop (``create_task`` + queue reads in one
         ``async def``) must keep its fallible work inside a ``try``
         whose handler rejects the futures (``set_exception``, possibly
         via a one-call helper) — any exception path that can exit
         without resolving the futures is a hung client.

Same contract as ``astlint`` (pass 2): ``# repro: noqa-RRxxx`` on the
offending line suppresses, the shipped tree must be clean, and every rule
has a known-bad fixture under tests/fixtures/analysis/ caught by exactly
that rule. Ruff's ASYNC family backstops RR005 for the stdlib cases in
``make lint``; the device-specific ones (``block_until_ready``, collect
stages) only exist here.
"""
from __future__ import annotations

import ast
import os

from repro.analysis import Finding
from repro.analysis.astlint import NOQA_PREFIX, _suppressed  # noqa: F401 (re-export)

RULES = ("RR005", "RR006", "RR007", "RR008")

# --- RR005 configuration ---------------------------------------------------
# Dotted origins (resolved through import aliases) that block the thread.
BLOCKING_DOTTED = {
    "time.sleep": "time.sleep blocks the loop — use asyncio.sleep",
    "jax.block_until_ready": "a device sync on the loop thread stalls every "
    "client — collect in the worker pool",
}
# Method names that block no matter the receiver.
BLOCKING_ATTRS = {
    "result": "concurrent Future.result() blocks the loop — await the "
    "future (or wrap it with asyncio.wrap_future)",
    "block_until_ready": "a device sync on the loop thread stalls every "
    "client — collect in the worker pool",
}
# Direct calls of a device collect stage inside async code: the collect
# triple's third stage blocks on device results by contract and must go
# through run_in_executor (see FrontDoor._resolve).
COLLECT_ATTRS = ("collect", "_collect")
# Blocking stdlib-queue methods (asyncio.Queue's get/put are coroutines
# and are awaited; a known stdlib queue.Queue is blocking regardless).
QUEUE_BLOCKING_ATTRS = ("get", "put", "join")
STDLIB_QUEUE_TYPES = ("queue.Queue", "queue.SimpleQueue", "queue.LifoQueue",
                      "queue.PriorityQueue")

# --- RR006 configuration ---------------------------------------------------
# Per-class thread-confinement manifest, path-suffix keyed like astlint's
# per-file configs. Maps attribute -> the reason a dual-context write is
# safe; anything not listed (and not lock-guarded) is a finding.
CONFINEMENT: dict = {
    "repro/api/frontdoor.py": {
        # No exemptions: FrontDoor's design is strict confinement — all
        # mutable state belongs to the event loop except the per-batch
        # counters, which the dispatch thread writes UNDER _stats_lock
        # (lock-guarded writes pass without a manifest entry).
        "FrontDoor": {},
    },
    "repro/api/server.py": {
        # Server.swap flips the serving generation while the front door's
        # dispatch/collect threads are mid-stream. The design is a single
        # atomic reference handoff, not shared mutation:
        "Server": {
            "_active": (
                "written only under _swap_lock (Server.swap); readers never "
                "lock — the request_stages trampolines snapshot the "
                "reference EXACTLY ONCE per request (at route time) and "
                "thread the snapshotted context through submit/collect, so "
                "a request is served end-to-end by one model generation "
                "and the flip is a plain atomic reference store"
            ),
        },
    },
    "repro/net/server.py": {
        # The HTTP endpoint is pure event-loop code: connection handlers
        # are loop tasks, the engine's threads live behind FrontDoor's
        # own (already-manifested) confinement, and NetServer never
        # hands a method to a worker — so its transport counters are
        # loop-confined by construction and need no exemptions.
        "NetServer": {},
    },
}
# A with-block on an attribute whose name contains this guards its body.
LOCK_NAME_HINT = "lock"
# Call names that hand a callable to another thread.
THREAD_HANDOFF_CALLS = ("run_in_executor", "submit", "Thread")
MUTATOR_METHODS = ("append", "extend", "insert", "add", "update", "pop",
                   "popleft", "remove", "clear", "setdefault")

# --- RR007 / RR008 configuration -------------------------------------------
TASK_SPAWN_CALLS = ("create_task", "ensure_future", "run_in_executor")
QUEUE_READ_ATTRS = ("get", "get_nowait")
# Call names the RR008 risk model treats as non-throwing plumbing. Keep
# tight: anything novel counts as fallible until listed.
SAFE_CALLS = frozenset({
    "set_result", "set_exception", "done", "cancel", "cancelled",
    "append", "len", "range", "isinstance", "zip", "enumerate", "list",
    "int", "float", "bool", "print", "time", "get_running_loop",
    "get_event_loop",
})


def _import_aliases(tree: ast.Module) -> dict:
    """Local name -> dotted origin for EVERY import (the generic sibling
    of ``astlint.jax_aliases``): ``from time import sleep`` ->
    {"sleep": "time.sleep"}; ``import queue as q`` -> {"q": "queue"}."""
    aliases: dict = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    aliases[a.asname] = a.name
                else:
                    aliases[a.name.split(".")[0]] = a.name.split(".")[0]
        elif isinstance(node, ast.ImportFrom) and node.module and node.level == 0:
            for a in node.names:
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def _dotted(node: ast.AST, aliases: dict) -> str | None:
    """Resolve an Attribute/Name chain through the alias map; unlike the
    astlint variant, an unaliased root still resolves (to itself) so
    ``self._queue.get`` names itself."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(aliases.get(node.id, node.id))
        return ".".join(reversed(parts))
    return None


def _call_name(call: ast.Call) -> str | None:
    """Terminal name of a call: ``loop.create_task`` -> "create_task"."""
    if isinstance(call.func, ast.Attribute):
        return call.func.attr
    if isinstance(call.func, ast.Name):
        return call.func.id
    return None


def _own_nodes(fn: ast.AST) -> list:
    """Every node of ``fn`` excluding nested function/class bodies (their
    code runs in a context of its own)."""
    out = []
    stack = [(fn, True)]
    while stack:
        node, is_root = stack.pop()
        if not is_root and isinstance(
            node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef, ast.Lambda)
        ):
            continue
        out.append(node)
        for child in ast.iter_child_nodes(node):
            stack.append((child, False))
    return out


def _awaited_ids(nodes: list) -> set:
    """ids of every node under an ``await`` expression."""
    out: set = set()
    for node in nodes:
        if isinstance(node, ast.Await):
            for sub in ast.walk(node):
                out.add(id(sub))
    return out


def _stdlib_queues(tree: ast.Module, aliases: dict) -> set:
    """Dotted names bound to a blocking stdlib queue constructor —
    ``self._q = queue.Queue()`` yields {"self._q"}."""
    out: set = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            if _dotted(node.value.func, aliases) in STDLIB_QUEUE_TYPES:
                for tgt in node.targets:
                    d = _dotted(tgt, aliases)
                    if d:
                        out.add(d)
    return out


# --------------------------------------------------------------------------
# RR005 — blocking calls on the event loop
# --------------------------------------------------------------------------


def _check_rr005(path: str, tree: ast.Module, lines: list, aliases: dict) -> list:
    findings = []
    queues = _stdlib_queues(tree, aliases)
    for fn in ast.walk(tree):
        if not isinstance(fn, ast.AsyncFunctionDef):
            continue
        nodes = _own_nodes(fn)
        awaited = _awaited_ids(nodes)
        for node in nodes:
            if not isinstance(node, ast.Call):
                continue
            why = None
            dotted = _dotted(node.func, aliases)
            name = _call_name(node)
            receiver = (
                _dotted(node.func.value, aliases)
                if isinstance(node.func, ast.Attribute)
                else None
            )
            if dotted in BLOCKING_DOTTED:
                why = BLOCKING_DOTTED[dotted]
            elif name in BLOCKING_ATTRS and isinstance(node.func, ast.Attribute):
                why = BLOCKING_ATTRS[name]
            elif name in COLLECT_ATTRS and isinstance(node.func, ast.Attribute):
                why = (
                    "direct call of a collect stage on the loop thread — "
                    "device syncs go through run_in_executor"
                )
            elif receiver in queues and name in QUEUE_BLOCKING_ATTRS:
                why = f"stdlib queue.{name}() blocks the loop — use asyncio.Queue"
            elif (
                name in QUEUE_BLOCKING_ATTRS
                and receiver is not None
                and "queue" in receiver.lower()
                and id(node) not in awaited
            ):
                why = (
                    f"un-awaited .{name}() on a queue inside async code — "
                    "either a blocking stdlib queue or a dropped coroutine"
                )
            if why and not _suppressed(lines, node.lineno, "RR005"):
                findings.append(
                    Finding(
                        "async",
                        "RR005",
                        f"{path}:{node.lineno}",
                        f"blocking call in `async def {fn.name}`: {why}",
                    )
                )
    return findings


# --------------------------------------------------------------------------
# RR006 — loop/worker dual writes without lock or declaration
# --------------------------------------------------------------------------


def _self_attr(node: ast.AST) -> str | None:
    if (
        isinstance(node, ast.Attribute)
        and isinstance(node.value, ast.Name)
        and node.value.id == "self"
    ):
        return node.attr
    return None


def _method_writes(method: ast.AST) -> list:
    """(attr, lineno, guarded) for every ``self.<attr>`` write in a
    method: assignments plus in-place mutator calls, with ``guarded``
    true inside ``with self.<something-lock>:``."""
    writes = []

    def visit(node, guarded):
        if isinstance(node, ast.With):
            has_lock = any(
                (_self_attr(item.context_expr) or "")
                .lower()
                .find(LOCK_NAME_HINT)
                >= 0
                for item in node.items
            )
            for child in ast.iter_child_nodes(node):
                visit(child, guarded or has_lock)
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            if node is not method:
                return
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = node.targets if isinstance(node, ast.Assign) else [node.target]
            for tgt in targets:
                attr = _self_attr(tgt)
                if attr:
                    writes.append((attr, node.lineno, guarded))
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
            if node.func.attr in MUTATOR_METHODS:
                attr = _self_attr(node.func.value)
                if attr:
                    writes.append((attr, node.lineno, guarded))
        for child in ast.iter_child_nodes(node):
            visit(child, guarded)

    visit(method, False)
    return writes


def _context_methods(cls: ast.ClassDef) -> tuple:
    """(loop_methods, worker_methods) by name, each closed over direct
    ``self.<m>()`` calls. Loop context seeds from ``async def``; worker
    context seeds from methods handed to executors/threads."""
    methods = {
        n.name: n
        for n in cls.body
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
    }
    calls: dict = {name: set() for name in methods}
    for name, node in methods.items():
        for sub in _own_nodes(node):
            if isinstance(sub, ast.Call):
                callee = _self_attr(sub.func)
                if callee in methods:
                    calls[name].add(callee)

    worker_seeds = set()
    for node in ast.walk(cls):
        if isinstance(node, ast.Call) and _call_name(node) in THREAD_HANDOFF_CALLS:
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                attr = _self_attr(arg)
                if attr in methods and not isinstance(arg, ast.Call):
                    worker_seeds.add(attr)
    loop_seeds = {
        name for name, node in methods.items()
        if isinstance(node, ast.AsyncFunctionDef)
    }

    def closure(seeds):
        seen = set(seeds)
        frontier = list(seeds)
        while frontier:
            m = frontier.pop()
            for callee in calls.get(m, ()):
                if callee not in seen:
                    seen.add(callee)
                    frontier.append(callee)
        return seen

    return closure(loop_seeds), closure(worker_seeds)


def _confinement_for(path: str, cls_name: str) -> dict | None:
    norm = path.replace(os.sep, "/")
    for suffix, classes in CONFINEMENT.items():
        if norm.endswith(suffix) and cls_name in classes:
            return classes[cls_name]
    return None


def _check_rr006(path: str, tree: ast.Module, lines: list) -> list:
    findings = []
    for cls in ast.walk(tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        loop_methods, worker_methods = _context_methods(cls)
        if not worker_methods:
            continue
        declared = _confinement_for(path, cls.name) or {}
        methods = {
            n.name: n
            for n in cls.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        }
        # attr -> [(context, lineno, guarded)]
        by_attr: dict = {}
        for name, node in methods.items():
            contexts = []
            if name in loop_methods:
                contexts.append("loop")
            if name in worker_methods:
                contexts.append("worker")
            if not contexts:
                continue
            for attr, lineno, guarded in _method_writes(node):
                for ctx in contexts:
                    by_attr.setdefault(attr, []).append((ctx, lineno, guarded))
        for attr, writes in sorted(by_attr.items()):
            ctxs = {c for c, _, _ in writes}
            if len(ctxs) < 2 or attr in declared:
                continue
            unguarded = [(c, ln) for c, ln, g in writes if not g]
            if not unguarded:
                continue
            ctx, lineno = unguarded[0]
            if _suppressed(lines, lineno, "RR006"):
                continue
            findings.append(
                Finding(
                    "async",
                    "RR006",
                    f"{path}:{lineno}",
                    f"`self.{attr}` of class {cls.name} is written from both "
                    "the event loop and a worker thread without a lock — "
                    "guard every write with a lock, or declare the attribute "
                    "(with its safety argument) in asynclint.CONFINEMENT",
                )
            )
    return findings


# --------------------------------------------------------------------------
# RR007 — lost tasks
# --------------------------------------------------------------------------


def _check_rr007(path: str, tree: ast.Module, lines: list) -> list:
    findings = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Expr) and isinstance(node.value, ast.Call)):
            continue
        call = node.value
        if _call_name(call) not in TASK_SPAWN_CALLS:
            continue
        if _suppressed(lines, node.lineno, "RR007"):
            continue
        findings.append(
            Finding(
                "async",
                "RR007",
                f"{path}:{node.lineno}",
                f"{_call_name(call)}(...) result neither stored nor awaited "
                "— the task's only reference is dropped: its exception "
                "vanishes and the task itself may be garbage-collected "
                "mid-flight",
            )
        )
    return findings


# --------------------------------------------------------------------------
# RR008 — orphanable request futures
# --------------------------------------------------------------------------


def _rejecting_methods(tree: ast.Module) -> set:
    """Names of functions whose body calls ``set_exception`` — one level
    of indirection for crash handlers (e.g. ``self._fail_requests``)."""
    out = set()
    for fn in ast.walk(tree):
        if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for sub in _own_nodes(fn):
                if isinstance(sub, ast.Call) and _call_name(sub) == "set_exception":
                    out.add(fn.name)
                    break
    return out


def _handler_rejects(handler: ast.ExceptHandler, rejecting: set) -> bool:
    for sub in ast.walk(handler):
        if isinstance(sub, ast.Call):
            name = _call_name(sub)
            if name == "set_exception" or name in rejecting:
                return True
    return False


def _protected_ids(fn: ast.AST, rejecting: set) -> set:
    """ids of nodes covered by a try whose handler rejects futures."""
    out: set = set()
    for node in _own_nodes(fn):
        if isinstance(node, ast.Try) and any(
            _handler_rejects(h, rejecting) for h in node.handlers
        ):
            for sub in ast.walk(node):
                out.add(id(sub))
    return out


def _delivers_futures(fn: ast.AST) -> bool:
    return any(
        isinstance(n, ast.Call) and _call_name(n) == "set_result"
        for n in _own_nodes(fn)
    )


def _engine_shaped(fn: ast.AST) -> bool:
    if not isinstance(fn, ast.AsyncFunctionDef):
        return False
    nodes = _own_nodes(fn)
    spawns = any(
        isinstance(n, ast.Call) and _call_name(n) in TASK_SPAWN_CALLS for n in nodes
    )
    reads = any(
        isinstance(n, ast.Call)
        and isinstance(n.func, ast.Attribute)
        and n.func.attr in QUEUE_READ_ATTRS
        for n in nodes
    )
    return spawns and reads


def _check_rr008(path: str, tree: ast.Module, lines: list) -> list:
    findings = []
    rejecting = _rejecting_methods(tree)
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if fn.name in rejecting and not _delivers_futures(fn):
            continue  # rejection helpers are the remedy, not the hazard
        if not (_delivers_futures(fn) or _engine_shaped(fn)):
            continue
        protected = _protected_ids(fn, rejecting)
        for node in _own_nodes(fn):
            risky = isinstance(node, ast.Await) or (
                isinstance(node, ast.Call) and _call_name(node) not in SAFE_CALLS
            )
            if not risky or id(node) in protected:
                continue
            if _suppressed(lines, node.lineno, "RR008"):
                break
            findings.append(
                Finding(
                    "async",
                    "RR008",
                    f"{path}:{node.lineno}",
                    f"`{fn.name}` owns per-request futures but this "
                    "expression can raise outside any try/except that "
                    "rejects them (set_exception) — an exception here "
                    "orphans the futures and hangs their clients",
                )
            )
            break  # one finding per function: fix the structure, re-run
    return findings


# --------------------------------------------------------------------------
# Front door (mirrors astlint)
# --------------------------------------------------------------------------


def lint_source(path: str, source: str, *, rules: tuple = RULES) -> list:
    """Lint one file's source. ``path`` keys the confinement manifest
    (suffix-matched), so fixtures can pose as any repo file."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding("async", "RR-PARSE", f"{path}:{e.lineno or 1}", str(e))]
    lines = source.splitlines()
    aliases = _import_aliases(tree)
    findings = []
    if "RR005" in rules:
        findings.extend(_check_rr005(path, tree, lines, aliases))
    if "RR006" in rules:
        findings.extend(_check_rr006(path, tree, lines))
    if "RR007" in rules:
        findings.extend(_check_rr007(path, tree, lines))
    if "RR008" in rules:
        findings.extend(_check_rr008(path, tree, lines))
    return findings


def run(root: str = "src", *, rules: tuple = RULES) -> tuple:
    """Lint every .py under ``root``; returns (findings, report)."""
    findings = []
    files = []
    for dirpath, _dirnames, filenames in os.walk(root):
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            files.append(path)
            with open(path, encoding="utf-8") as f:
                findings.extend(lint_source(path, f.read(), rules=rules))
    per_rule = {r: 0 for r in rules}
    for f in findings:
        per_rule[f.rule] = per_rule.get(f.rule, 0) + 1
    report = {
        "root": root,
        "files_scanned": len(files),
        "rules": {r: per_rule.get(r, 0) for r in sorted(per_rule)},
    }
    return findings, report
