"""Pass 2 — repo-rule AST lint: the bugs this repo has shipped, as rules.

Every rule is a named, tested codification of a failure mode from the PR
history, with file/line diagnostics and a per-line escape hatch
(``# repro: noqa-RRxxx`` on the flagged line):

  RR001  no device-array creation at module import time. The
         ``gp/likelihoods.py`` bug (PR 2): a ``jnp.asarray`` at module
         scope initializes the jax backend before the launcher can set
         ``XLA_FLAGS``, silently pinning the device count to 1.
  RR002  the routing path stays pure numpy. The ``device_put``-inside-
         ``route`` bug: any jax reference in a declared host-side routing
         function moves routing onto the device and stalls the overlapped
         pipeline. Enforced for a declared function list (deleting a
         declared function is itself a finding, so the list can't rot).
  RR003  no bare float64 in kernel/serve hot paths. The serving dtype
         policy is f32; an f64 literal/astype doubles halo bytes and drops
         the TPU fast path. (The HLO pass catches leaks that reach a
         compiled program; this catches them at the source.)
  RR004  frozen-config dataclasses must validate in ``__post_init__``. A
         frozen config without construction-time validation lets an
         illegal combination travel to the middle of a serve run before
         failing (the pre-PR-5 flag-sprawl class of bug).

Pure stdlib (``ast``): this pass never imports the code it checks.
"""
from __future__ import annotations

import ast
import os

from repro.analysis import Finding

RULES = ("RR001", "RR002", "RR003", "RR004")

NOQA_PREFIX = "# repro: noqa-"

# --- RR001: jax roots whose CALL at import time touches the backend.
# jax.jit / jax.vmap / functools.partial(jax.jit, ...) are lazy and fine;
# array constructors and device queries are not.
_VALUE_ROOTS = ("jax.numpy.", "jax.random.")
_DEVICE_CALLS = (
    "jax.device_put",
    "jax.devices",
    "jax.device_count",
    "jax.local_devices",
    "jax.local_device_count",
    "jax.make_mesh",
)

# --- RR002: declared pure-numpy routing path, keyed by path suffix.
# Dotted names descend into nested defs (closures) and class bodies.
PURE_NUMPY_FUNCTIONS = {
    "repro/core/routing.py": (
        "owning_cells",
        "ceil_to",
        "halo_ids",
        "spill_assign",
        "min_spill_q_max",
        "build_routing_table",
        "halo_slot_on_grid",
        "make_halo_stacker",
        "scatter_results",
        "StreamingQMax",
        "TwoLevelQMax",
    ),
    # the route stage built by make_request_stages is the pipeline's
    # host-side overlap window — one jax call here serializes the loop
    "repro/launch/serve_sharded.py": ("make_request_stages.route",),
}

# --- RR003: files whose math must stay f32 end to end.
HOT_PATH_SUFFIXES = (
    "repro/kernels/",
    "repro/core/posterior.py",
    "repro/core/blend.py",
    "repro/core/routing.py",
    "repro/launch/serve.py",
    "repro/launch/serve_sharded.py",
    "repro/api/server.py",
)


def _norm(path: str) -> str:
    return path.replace(os.sep, "/")


def _suppressed(lines: list, lineno: int, rule: str) -> bool:
    if 1 <= lineno <= len(lines):
        return NOQA_PREFIX + rule in lines[lineno - 1]
    return False


def jax_aliases(tree: ast.Module) -> dict:
    """Map of local name -> dotted origin, for every jax-rooted binding.

    ``import jax.numpy as jnp`` -> {"jnp": "jax.numpy"};
    ``from jax import random`` -> {"random": "jax.random"};
    ``from jax.random import PRNGKey`` -> {"PRNGKey": "jax.random.PRNGKey"}.
    """
    aliases: dict = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "jax" or a.name.startswith("jax."):
                    aliases[(a.asname or a.name).split(".")[0]] = (
                        a.name if a.asname else "jax"
                    )
        elif isinstance(node, ast.ImportFrom):
            if node.module and (node.module == "jax" or node.module.startswith("jax.")):
                for a in node.names:
                    aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def _dotted(node: ast.AST, aliases: dict):
    """Resolve an Attribute/Name chain to its dotted origin, or None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name) or node.id not in aliases:
        return None
    parts.append(aliases[node.id])
    return ".".join(reversed(parts))


def _annotation_nodes(tree: ast.AST) -> set:
    """ids of every node inside a type annotation (skipped by RR002)."""
    out: set = set()

    def mark(sub):
        if sub is not None:
            for n in ast.walk(sub):
                out.add(id(n))

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            mark(node.returns)
            for arg in [
                *node.args.posonlyargs,
                *node.args.args,
                *node.args.kwonlyargs,
                node.args.vararg,
                node.args.kwarg,
            ]:
                if arg is not None:
                    mark(arg.annotation)
        elif isinstance(node, ast.AnnAssign):
            mark(node.annotation)
    return out


# --------------------------------------------------------------------------
# RR001 — no device-array creation at import time
# --------------------------------------------------------------------------


def _import_time_statements(tree: ast.Module):
    """Module-scope and class-body statements plus function default args —
    everything Python EXECUTES at import. Function/method bodies are lazy
    and skipped; so are decorators (``partial(jax.jit, ...)`` is lazy)."""
    stack = list(tree.body)
    while stack:
        node = stack.pop()
        if isinstance(node, ast.ClassDef):
            stack.extend(node.body)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield from (d for d in node.args.defaults if d is not None)
            yield from (d for d in node.args.kw_defaults if d is not None)
        else:
            yield node


def _check_rr001(path: str, tree: ast.Module, lines: list) -> list:
    aliases = jax_aliases(tree)
    if not aliases:
        return []
    findings = []
    for stmt in _import_time_statements(tree):
        for node in ast.walk(stmt):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                continue  # lazy bodies nested under a module-scope stmt
            if not isinstance(node, ast.Call):
                continue
            dotted = _dotted(node.func, aliases)
            if dotted is None:
                continue
            bad = dotted.startswith(_VALUE_ROOTS) or dotted in _DEVICE_CALLS
            if bad and not _suppressed(lines, node.lineno, "RR001"):
                findings.append(
                    Finding(
                        "ast",
                        "RR001",
                        f"{path}:{node.lineno}",
                        f"{dotted}(...) at import time initializes the jax "
                        "backend before the launcher can configure it "
                        "(XLA_FLAGS/device count are frozen at first touch) "
                        "— move it behind a function",
                    )
                )
    return findings


# --------------------------------------------------------------------------
# RR002 — declared routing functions stay pure numpy
# --------------------------------------------------------------------------


def _find_def(scope: ast.AST, dotted: str):
    node = scope
    for part in dotted.split("."):
        nxt = None
        for child in ast.walk(node):
            if (
                isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef))
                and child.name == part
                and child is not node
            ):
                nxt = child
                break
        if nxt is None:
            return None
        node = nxt
    return node


def _check_rr002(path: str, tree: ast.Module, lines: list, declared: tuple) -> list:
    aliases = jax_aliases(tree)
    findings = []
    for name in declared:
        target = _find_def(tree, name)
        if target is None:
            findings.append(
                Finding(
                    "ast",
                    "RR002",
                    f"{path}:1",
                    f"declared pure-numpy routing function {name!r} not "
                    "found — update astlint.PURE_NUMPY_FUNCTIONS alongside "
                    "the rename/removal",
                )
            )
            continue
        # local imports inside the function count too
        local = dict(aliases)
        local.update(jax_aliases(ast.Module(body=list(target.body), type_ignores=[])))
        if not local:
            continue
        ann = _annotation_nodes(target)
        for node in ast.walk(target):
            if id(node) in ann:
                continue
            if isinstance(node, ast.Name) and node.id in local:
                if not _suppressed(lines, node.lineno, "RR002"):
                    findings.append(
                        Finding(
                            "ast",
                            "RR002",
                            f"{path}:{node.lineno}",
                            f"jax reference {node.id!r} "
                            f"({local[node.id]}) inside routing-path "
                            f"function {name!r} — routing must stay "
                            "host-side numpy or the pipeline overlap "
                            "window collapses",
                        )
                    )
    return findings


# --------------------------------------------------------------------------
# RR003 — no bare float64 in hot paths
# --------------------------------------------------------------------------


def _check_rr003(path: str, tree: ast.Module, lines: list) -> list:
    findings = []

    def flag(lineno, what):
        if not _suppressed(lines, lineno, "RR003"):
            findings.append(
                Finding(
                    "ast",
                    "RR003",
                    f"{path}:{lineno}",
                    f"{what} in a serving/kernel hot path — the serving "
                    "dtype policy is f32 (halo bytes double, TPU fast "
                    "path lost)",
                )
            )

    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and node.attr == "float64":
            flag(node.lineno, "float64 dtype attribute")
        elif isinstance(node, ast.Name) and node.id == "float64":
            flag(node.lineno, "bare float64 name")
        elif isinstance(node, ast.Constant) and node.value == "float64":
            flag(node.lineno, 'dtype string "float64"')
    return findings


# --------------------------------------------------------------------------
# RR004 — frozen-config dataclasses validate in __post_init__
# --------------------------------------------------------------------------


def _is_frozen_dataclass(cls: ast.ClassDef) -> bool:
    for dec in cls.decorator_list:
        if not isinstance(dec, ast.Call):
            continue
        fn = dec.func
        name = fn.attr if isinstance(fn, ast.Attribute) else getattr(fn, "id", None)
        if name != "dataclass":
            continue
        for kw in dec.keywords:
            if (
                kw.arg == "frozen"
                and isinstance(kw.value, ast.Constant)
                and kw.value.value is True
            ):
                return True
    return False


def _check_rr004(path: str, tree: ast.Module, lines: list) -> list:
    findings = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.ClassDef) and _is_frozen_dataclass(node)):
            continue
        has_post = any(
            isinstance(m, ast.FunctionDef) and m.name == "__post_init__"
            for m in node.body
        )
        if not has_post and not _suppressed(lines, node.lineno, "RR004"):
            findings.append(
                Finding(
                    "ast",
                    "RR004",
                    f"{path}:{node.lineno}",
                    f"frozen dataclass {node.name!r} has no __post_init__ "
                    "— frozen configs must validate at construction, not "
                    "mid-serve when the illegal combination finally bites",
                )
            )
    return findings


# --------------------------------------------------------------------------
# Driver
# --------------------------------------------------------------------------


def lint_source(path: str, source: str, *, rules: tuple = RULES) -> list:
    """Lint one file's source. ``path`` keys the per-file rule config
    (suffix-matched), so fixtures can pose as any repo file."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding("ast", "RR-PARSE", f"{path}:{e.lineno or 1}", str(e))]
    lines = source.splitlines()
    norm = _norm(path)
    findings = []
    if "RR001" in rules:
        findings.extend(_check_rr001(path, tree, lines))
    if "RR002" in rules:
        for suffix, declared in PURE_NUMPY_FUNCTIONS.items():
            if norm.endswith(suffix):
                findings.extend(_check_rr002(path, tree, lines, declared))
    if "RR003" in rules and any(s in norm for s in HOT_PATH_SUFFIXES):
        findings.extend(_check_rr003(path, tree, lines))
    if "RR004" in rules:
        findings.extend(_check_rr004(path, tree, lines))
    return findings


def run(root: str = "src", *, rules: tuple = RULES) -> tuple:
    """Lint every .py under ``root``; returns (findings, report)."""
    findings = []
    files = []
    for dirpath, _dirnames, filenames in os.walk(root):
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            files.append(path)
            with open(path, encoding="utf-8") as f:
                findings.extend(lint_source(path, f.read(), rules=rules))
    per_rule = {r: 0 for r in rules}
    for f in findings:
        per_rule[f.rule] = per_rule.get(f.rule, 0) + 1
    report = {
        "root": root,
        "files_scanned": len(files),
        "rules": list(rules),
        "findings_per_rule": per_rule,
    }
    return findings, report
