"""Pass 3 — trace-time shape/sharding contracts on the serving entry points.

A ``@contract(...)`` decorator declares, next to the code, what shapes a
serving entry point consumes and produces (``"(S, Q)"``-style expressions,
symbols unified across a declaration) plus named cross-stage invariants.
The decorator only REGISTERS the declaration and returns the function
unchanged — zero runtime cost in production. This pass then checks every
declaration against the real code via ``jax.eval_shape`` on abstract
inputs (device programs) or direct execution on tiny host arrays (the
numpy routing stages), over the whole backend/policy matrix.

The point is the desync class of bug: the PR-5 ``pad_multiple`` incident
(the routing table silently re-rounded q_max, so the streaming policy's
compile/overflow counters described block shapes that were never
compiled) was invisible to unit tests of either side — it lived in the
SEAM between the host stages and the device program. The contracts here
check the seams:

  * ``predict_cached_slots``   (S, Q) outputs, f32, on every kernel lane;
  * ``make_sharded_blend``     the built program's in/out shapes, per
                               backend, via eval_shape — no execution;
  * ``make_request_stages``    route's table/blocks agree with the policy
                               (q_max never re-rounded) AND with what the
                               compiled blend accepts, per policy kind;
  * ``scatter_results``        the exact inverse property: gather-by-table
                               then scatter restores request order.

A declaration is load-bearing twice over: deleting a ``@contract`` from an
expected target is itself a finding (``EXPECTED_TARGETS``), and every
shape expression in a declaration is parsed and unified against reality —
a stale string fails the pass.

Import-light: this module is stdlib+numpy at import time (core modules
import it for the decorator); jax loads only inside harnesses.
"""
from __future__ import annotations

import dataclasses
import importlib
import re
import time

import numpy as np

from repro.analysis import Finding

# --------------------------------------------------------------------------
# Declaration machinery
# --------------------------------------------------------------------------

_REGISTRY: dict = {}

# Every target that must carry a @contract. Removing a decorator (or
# renaming a target) without updating this list is a CONTRACT-MISSING
# finding — the declaration cannot silently rot away.
EXPECTED_TARGETS = (
    "repro.core.posterior.predict_cached_slots",
    "repro.core.routing.scatter_results",
    "repro.launch.serve_sharded.make_sharded_blend",
    "repro.launch.serve_sharded.make_request_stages",
)

# Named invariants a declaration may claim; the harnesses enforce exactly
# these. Declaring an unknown name is a finding (both sides stay in sync).
KNOWN_INVARIANTS = (
    "q_max-matches-policy",  # route never re-rounds the policy's q_max
    "q_max-aligned",  # table.q_max % pad_multiple == 0
    "scatter-is-gather-inverse",  # scatter(gather(x)) == x exactly
    "outputs-f32",  # serving math returns float32
)


@dataclasses.dataclass(frozen=True)
class ContractDecl:
    target: str  # "module.qualname"
    spec: dict  # shape expressions + invariant names, per target kind

    def __post_init__(self) -> None:
        if not self.target or "." not in self.target:
            raise ValueError(f"target must be module.qualname, got {self.target!r}")
        if not isinstance(self.spec, dict) or not self.spec:
            raise ValueError(f"empty contract spec for {self.target}")


def contract(**spec):
    """Declare a serving contract. Registers and returns ``fn`` unchanged."""

    def deco(fn):
        target = f"{fn.__module__}.{fn.__qualname__}"
        _REGISTRY[target] = ContractDecl(target=target, spec=spec)
        return fn

    return deco


# --------------------------------------------------------------------------
# Shape-expression parsing and unification
# --------------------------------------------------------------------------

_DIM_RE = re.compile(r"^[A-Za-z_][A-Za-z0-9_]*$")


def parse_shape(expr: str) -> tuple:
    """'(S, Q, 4)' -> ('S', 'Q', 4); '(N,)' -> ('N',)."""
    body = expr.strip()
    if not (body.startswith("(") and body.endswith(")")):
        raise ValueError(f"shape expression must be parenthesized: {expr!r}")
    parts = [p.strip() for p in body[1:-1].split(",") if p.strip()]
    dims = []
    for p in parts:
        if p.lstrip("-").isdigit():
            dims.append(int(p))
        elif _DIM_RE.match(p):
            dims.append(p)
        else:
            raise ValueError(f"bad dimension {p!r} in {expr!r}")
    return tuple(dims)


def unify(expr: str, shape: tuple, env: dict):
    """Unify a shape expression with an actual shape under ``env``.

    Literal dims must match exactly; symbolic dims bind on first use and
    must agree thereafter. Returns an error string, or None on success.
    ``env`` is updated in place so one declaration's symbols are shared
    across all its expressions.
    """
    dims = parse_shape(expr)
    if len(dims) != len(shape):
        return f"rank mismatch: {expr} vs actual {tuple(shape)}"
    for d, s in zip(dims, shape, strict=True):
        s = int(s)
        if isinstance(d, int):
            if d != s:
                return f"{expr} vs actual {tuple(shape)}: literal {d} != {s}"
        elif d in env:
            if env[d] != s:
                return (
                    f"{expr} vs actual {tuple(shape)}: {d}={env[d]} "
                    f"bound earlier, got {s}"
                )
        else:
            env[d] = s
    return None


def _check_invariant_names(decl: ContractDecl) -> list:
    bad = [
        n for n in decl.spec.get("invariants", ()) if n not in KNOWN_INVARIANTS
    ]
    if bad:
        return [
            Finding(
                "contracts",
                "CONTRACT-DECL",
                f"target:{decl.target}",
                f"declares unknown invariants {bad} — add the check to "
                "contracts.KNOWN_INVARIANTS (and a harness) or fix the "
                "declaration",
            )
        ]
    return []


# --------------------------------------------------------------------------
# Harnesses
# --------------------------------------------------------------------------


def _shape_finding(target: str, lane: str, err: str) -> Finding:
    return Finding(
        "contracts", "CONTRACT-SHAPE", f"target:{target}", f"[{lane}] {err}"
    )


def _local_abstract_cache(m: int, d: int = 2):
    """A SINGLE-partition abstract cache (what one device's step sees)."""
    import jax
    import jax.numpy as jnp

    from repro.core import posterior
    from repro.gp.covariances import CovarianceParams

    def f32(*shape):
        return jax.ShapeDtypeStruct(shape, jnp.float32)

    return posterior.PosteriorCache(
        z=f32(m, d),
        w=f32(m, m),
        u=f32(m, m),
        c=f32(m),
        cov=CovarianceParams(log_lengthscale=f32(d), log_variance=f32()),
        log_beta=f32(),
    )


def harness_predict_cached_slots(decl: ContractDecl, *, m: int = 8) -> list:
    """eval_shape the slot-stacked predict on every kernel lane."""
    import jax
    import jax.numpy as jnp

    from repro.core import posterior
    from repro.gp.covariances import make_covariance

    findings = _check_invariant_names(decl)
    cov_fn = make_covariance("rbf")
    cache = _local_abstract_cache(m)
    S, Q, D = 9, 16, 2
    xslots = jax.ShapeDtypeStruct((S, Q, D), jnp.float32)
    for backend in ("ref", "pallas", "fused"):

        def fn(c, xs, _backend=backend):
            return posterior.predict_cached_slots(c, cov_fn, xs, backend=_backend)

        try:
            out = jax.eval_shape(fn, cache, xslots)
        except Exception as e:  # a lane that no longer traces is a finding
            findings.append(
                Finding(
                    "contracts",
                    "CONTRACT-TRACE",
                    f"target:{decl.target}",
                    f"[{backend}] abstract trace failed: {e}",
                )
            )
            continue
        env = {"S": S, "Q": Q, "D": D}
        for expr, leaf in zip(decl.spec.get("returns", ()), out, strict=True):
            err = unify(expr, leaf.shape, env)
            if err:
                findings.append(_shape_finding(decl.target, backend, err))
            if (
                "outputs-f32" in decl.spec.get("invariants", ())
                and leaf.dtype != jnp.float32
            ):
                findings.append(
                    Finding(
                        "contracts",
                        "CONTRACT-DTYPE",
                        f"target:{decl.target}",
                        f"[{backend}] output dtype {leaf.dtype}, policy is f32",
                    )
                )
    return findings


def harness_scatter_results(decl: ContractDecl) -> list:
    """The exact inverse property, on real tiny host arrays — no jax.

    Build a routing table for a small scattered batch, gather each query's
    padded-block coordinate via ``table.src_idx`` semantics (values[p, i]
    = original request index), scatter back, and require identity.
    """
    from repro.core import partition, routing

    findings = _check_invariant_names(decl)
    rng = np.random.default_rng(0)
    pts_all = rng.uniform(0.0, 1.0, (137, 2)).astype(np.float32)
    grid = partition.make_grid(pts_all, gx=3, gy=3)
    for n, pad in ((137, 8), (41, 4), (9, 1)):
        pts = pts_all[:n]
        table = routing.build_routing_table(grid, pts, pad_multiple=pad)
        env = {"P": grid.num_partitions, "Q": table.q_max, "N": n}
        # values[p, i] = the request index routed there (padding rows -1):
        # gather-by-src_idx in its literal form
        values = np.where(
            table.qmask > 0, table.src_idx.astype(np.float32), -1.0
        ).astype(np.float32)
        for expr, shape in (
            (decl.spec.get("args", {}).get("values"), values.shape),
        ):
            if expr:
                err = unify(expr, shape, env)
                if err:
                    findings.append(_shape_finding(decl.target, f"n={n}", err))
        out = routing.scatter_results(table, values)
        err = unify(decl.spec.get("returns", "(N,)"), out.shape, env)
        if err:
            findings.append(_shape_finding(decl.target, f"n={n}", err))
            continue
        if "scatter-is-gather-inverse" in decl.spec.get("invariants", ()):
            if not np.array_equal(out, np.arange(n, dtype=np.float32)):
                findings.append(
                    Finding(
                        "contracts",
                        "CONTRACT-INVERSE",
                        f"target:{decl.target}",
                        f"[n={n} pad={pad}] scatter(gather(x)) != x — "
                        "src_idx no longer inverts the routing permutation",
                    )
                )
    return findings


def _mesh_fixture(grid_side: int, m: int):
    """(grid, mesh, cov_fn, stacked abstract cache) for mesh harnesses.

    Requires one device per partition (the CLI calls
    ``ensure_host_devices`` before jax loads, like the serving drivers).
    """
    import jax

    from repro.analysis import hlo
    from repro.launch import serve_sharded as ss
    from repro.gp.covariances import make_covariance

    grid = hlo.probe_grid(grid_side)
    if jax.device_count() < grid.num_partitions:
        raise RuntimeError(
            f"{grid.num_partitions} devices needed, have {jax.device_count()} "
            "— run via `python -m repro.analysis` (it forces virtual host "
            "devices before jax initializes)"
        )
    return grid, ss.mesh_for_grid(grid), make_covariance("rbf"), hlo.abstract_cache(
        grid.num_partitions, m
    )


def harness_make_sharded_blend(
    decl: ContractDecl, *, grid_side: int = 4, m: int = 8
) -> list:
    """eval_shape the built shard_map program on every backend."""
    import jax
    import jax.numpy as jnp

    from repro.launch import serve_sharded as ss

    findings = _check_invariant_names(decl)
    grid, mesh, cov_fn, cache = _mesh_fixture(grid_side, m)
    P, Q = grid.num_partitions, 64

    def f32(*shape):
        return jax.ShapeDtypeStruct(shape, jnp.float32)

    args = {
        "hx": f32(P, 9, Q, 2),
        "corner_slot": jax.ShapeDtypeStruct((P, Q, 4), jnp.int32),
        "corner_w": f32(P, Q, 4),
    }
    for backend in ("ref", "pallas", "fused"):
        blend_fn = ss.make_sharded_blend(
            mesh, mesh.axis_names, grid, cov_fn, cache, backend=backend
        )
        try:
            out = jax.eval_shape(
                blend_fn, cache, args["hx"], args["corner_slot"], args["corner_w"]
            )
        except Exception as e:
            findings.append(
                Finding(
                    "contracts",
                    "CONTRACT-TRACE",
                    f"target:{decl.target}",
                    f"[{backend}] abstract trace failed: {e}",
                )
            )
            continue
        env = {"P": P, "Q": Q}
        for name, expr in decl.spec.get("args", {}).items():
            err = unify(expr, args[name].shape, env)
            if err:
                findings.append(_shape_finding(decl.target, backend, err))
        for expr, leaf in zip(decl.spec.get("returns", ()), out, strict=True):
            err = unify(expr, leaf.shape, env)
            if err:
                findings.append(_shape_finding(decl.target, backend, err))
            if (
                "outputs-f32" in decl.spec.get("invariants", ())
                and leaf.dtype != jnp.float32
            ):
                findings.append(
                    Finding(
                        "contracts",
                        "CONTRACT-DTYPE",
                        f"target:{decl.target}",
                        f"[{backend}] output dtype {leaf.dtype}, policy is f32",
                    )
                )
    return findings


def harness_make_request_stages(
    decl: ContractDecl, *, grid_side: int = 4, m: int = 8
) -> list:
    """Route on real host data per policy kind; eval_shape the compiled
    blend against the EXACT shapes route produced. This is the seam the
    PR-5 ``pad_multiple`` bug lived in: the policy's q_max counters and
    the table's compiled block shape must be the same number.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import routing
    from repro.launch import serve_sharded as ss

    findings = _check_invariant_names(decl)
    grid, mesh, cov_fn, cache = _mesh_fixture(grid_side, m)
    P = grid.num_partitions
    blend_fn = ss.make_sharded_blend(mesh, mesh.axis_names, grid, cov_fn, cache)
    rng = np.random.default_rng(0)
    base = rng.uniform(0.0, 1.0, (500, 2)).astype(np.float32)
    # a hot cell for the two-level lane: q_max must dip under the peak
    hot = np.concatenate(
        [base, rng.uniform(0.30, 0.42, (900, 2)).astype(np.float32)]
    )
    # a fixed q_max sized to the grid: peak owning-cell bucket, rounded up
    ix, iy = routing.owning_cells(grid, base)
    peak = int(np.bincount(iy * grid.gx + ix, minlength=P).max())
    fixed = routing.ceil_to(peak, 8)
    lanes = (
        ("streaming", dict(policy=routing.StreamingQMax()), base),
        ("streaming/pad5", dict(policy=routing.StreamingQMax(pad_multiple=5)), base),
        ("two-level", dict(policy=routing.TwoLevelQMax()), hot),
        ("fixed-q_max", dict(q_max=fixed), base),
    )
    invs = decl.spec.get("invariants", ())
    for lane, kw, q in lanes:
        route, _submit, _collect = ss.make_request_stages(
            grid, blend_fn, cache, **kw
        )
        table, (hx, cs, cw) = route(q)
        env = {"P": P, "Q": table.q_max, "D": 2, "N": len(q)}
        spec = decl.spec.get("route", {})
        for expr, shape in (
            (spec.get("xq"), table.xq.shape),
            (spec.get("stacked"), hx.shape),
            (spec.get("corner_slot"), cs.shape),
            (spec.get("corner_w"), cw.shape),
        ):
            if expr:
                err = unify(expr, shape, env)
                if err:
                    findings.append(_shape_finding(decl.target, lane, err))
        policy = kw.get("policy")
        if "q_max-matches-policy" in invs and policy is not None:
            if table.q_max != policy.q_max:
                findings.append(
                    Finding(
                        "contracts",
                        "CONTRACT-DESYNC",
                        f"target:{decl.target}",
                        f"[{lane}] table.q_max={table.q_max} != "
                        f"policy.q_max={policy.q_max} — the table re-rounded "
                        "the policy's block size, so the policy's "
                        "compile/overflow counters describe shapes that are "
                        "never compiled (the PR-5 pad_multiple bug)",
                    )
                )
        if "q_max-aligned" in invs:
            pad = (
                policy.pad_multiple
                if policy is not None
                else 8  # the fixed-q_max lane's table default
            )
            if kw.get("q_max") is None and table.q_max % pad != 0:
                findings.append(
                    Finding(
                        "contracts",
                        "CONTRACT-DESYNC",
                        f"target:{decl.target}",
                        f"[{lane}] table.q_max={table.q_max} not aligned to "
                        f"pad_multiple={pad}",
                    )
                )
        # the seam: the compiled program must accept route's exact blocks
        def f32(*shape):
            return jax.ShapeDtypeStruct(shape, jnp.float32)

        try:
            out = jax.eval_shape(
                blend_fn,
                cache,
                f32(*hx.shape),
                jax.ShapeDtypeStruct(cs.shape, jnp.int32),
                f32(*cw.shape),
            )
        except Exception as e:
            findings.append(
                Finding(
                    "contracts",
                    "CONTRACT-TRACE",
                    f"target:{decl.target}",
                    f"[{lane}] blend rejects route's block shapes: {e}",
                )
            )
            continue
        for leaf in out:
            if tuple(leaf.shape) != (P, table.q_max):
                findings.append(
                    _shape_finding(
                        decl.target,
                        lane,
                        f"blend output {tuple(leaf.shape)} != "
                        f"(P={P}, q_max={table.q_max})",
                    )
                )
    return findings


# --------------------------------------------------------------------------
# Driver
# --------------------------------------------------------------------------

# target -> (harness, needs_mesh)
_HARNESSES = {
    "repro.core.posterior.predict_cached_slots": (
        harness_predict_cached_slots,
        False,
    ),
    "repro.core.routing.scatter_results": (harness_scatter_results, False),
    "repro.launch.serve_sharded.make_sharded_blend": (
        harness_make_sharded_blend,
        True,
    ),
    "repro.launch.serve_sharded.make_request_stages": (
        harness_make_request_stages,
        True,
    ),
}


def run(
    *,
    targets: tuple = None,
    include_mesh: bool = True,
    grid_side: int = 4,
    m: int = 8,
) -> tuple:
    """Check every expected contract; returns (findings, report).

    ``targets`` restricts to a subset; ``include_mesh=False`` skips the
    harnesses that need one device per partition (tier-1 runs those via
    the CLI subprocess instead). ``grid_side`` sizes the mesh fixture and
    must not exceed the device count the caller arranged.
    """
    findings: list = []
    t0 = time.time()
    for target in EXPECTED_TARGETS:
        importlib.import_module(target.rsplit(".", 1)[0])
    checked = []
    skipped = []
    for target in EXPECTED_TARGETS:
        if targets is not None and target not in targets:
            continue
        harness, needs_mesh = _HARNESSES[target]
        if needs_mesh and not include_mesh:
            skipped.append(target)
            continue
        decl = _REGISTRY.get(target)
        if decl is None:
            findings.append(
                Finding(
                    "contracts",
                    "CONTRACT-MISSING",
                    f"target:{target}",
                    "expected @contract declaration is gone — restore it or "
                    "update contracts.EXPECTED_TARGETS",
                )
            )
            continue
        checked.append(target)
        if needs_mesh:
            findings.extend(harness(decl, grid_side=grid_side, m=m))
        else:
            findings.extend(harness(decl))
    report = {
        "targets_checked": checked,
        "targets_skipped": skipped,
        "seconds": round(time.time() - t0, 3),
    }
    return findings, report
