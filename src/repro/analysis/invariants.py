"""The declarative per-lane invariant manifest the HLO pass enforces.

Every serving lane (a valid :class:`repro.api.ServeConfig` point) carries a
:class:`LaneInvariant`: which device program it compiles, how many
nearest-neighbor collectives that program may contain, which ops are
forbidden outright, and the dtype/host-transfer policy. The manifest is the
checkable form of the architecture prose in docs/architecture.md:

  * sharded predict is HALO-SHAPED — the composed reverse halo is 4
    ppermutes (row exchange + column exchange of the slot-flipped results);
    the budget of 8 leaves headroom for a second composed exchange but is
    far below the 36 per-slot hops the PR-2 program paid;
  * the cache NEVER moves — no all-gather / all-reduce / reduce-scatter /
    all-to-all anywhere in a serving program (the decentralized-serving
    claim, arXiv 1402.1472-style: ship low-rank summaries once, never
    re-aggregate);
  * replicated predict is mesh-free — ZERO collectives of any kind;
  * serving math is f32 — an f64 leak doubles halo bytes and falls off the
    TPU fast path silently;
  * no host transfers inside a compiled serving program — a callback or
    infeed would stall the overlapped pipeline for a full device window
    (the ``device_put``-inside-``route`` bug class, at the HLO level).

Lanes that share a device program (pipeline/router only change HOST-side
scheduling) point at the same ``program`` key; the HLO pass lowers each
distinct program once and applies every lane's invariant to its text, so a
future divergence between two lanes' programs is caught the moment someone
introduces one.

Stdlib-only: the manifest must be importable (and testable) without jax.
"""
from __future__ import annotations

import dataclasses

# Collective mnemonics as they appear in StableHLO / HLO text. The dashed
# and underscored spellings are both matched by the HLO pass.
COLLECTIVE_OPS = (
    "collective-permute",
    "all-gather",
    "all-reduce",
    "all-to-all",
    "reduce-scatter",
)

# Ops that move data between host and device inside a compiled program.
HOST_TRANSFER_OPS = (
    "infeed",
    "outfeed",
    "send",
    "recv",
    "xla_python_cpu_callback",
    "xla_python_gpu_callback",
    "xla_ffi_python",
    "host_callback",
)

# The factors-never-move claim: nothing may re-aggregate sharded state.
GATHERING_COLLECTIVES = ("all-gather", "all-reduce", "all-to-all", "reduce-scatter")

# Composed reverse halo = 4 ppermutes; budget 8 leaves room for one more
# composed exchange (e.g. a future low-rank global term) but stays an
# order below the 36 per-slot hops the pre-composition program paid.
PPERMUTE_BUDGET = 8


@dataclasses.dataclass(frozen=True)
class LaneInvariant:
    """What one serving lane's compiled program is allowed to contain.

    Fields:
      name: stable lane id, e.g. "sharded/pipelined/two-level/fused".
      serve: the ServeConfig dict of the lane (validated against
        ``repro.api.ServeConfig.from_dict`` by the HLO pass, so manifest
        rot — a field rename, an illegal combination — fails the pass).
      program: device-program key the HLO pass lowers —
        "replicated-blend" | "sharded-blend".
      backend: kernel lane the program is built with ("ref"|"pallas"|
        "fused"); with ``program="replicated-blend"`` must be "ref".
      max_collective_permute: inclusive ppermute budget.
      min_collective_permute: floor — a sharded program with FEWER is just
        as wrong (the halo vanished, or the linter stopped seeing it; the
        floor is what catches a rotted op-matching pattern).
      forbidden_ops: op mnemonics that must not appear at all.
      forbid_f64 / forbid_host_transfer: dtype and host-transfer policy.
    """

    name: str
    serve: dict
    program: str
    backend: str
    max_collective_permute: int
    forbidden_ops: tuple
    min_collective_permute: int = 0
    forbid_f64: bool = True
    forbid_host_transfer: bool = True

    def __post_init__(self) -> None:
        if self.program not in ("replicated-blend", "sharded-blend"):
            raise ValueError(f"unknown program {self.program!r} for lane {self.name!r}")
        if self.backend not in ("ref", "pallas", "fused"):
            raise ValueError(f"unknown backend {self.backend!r} for lane {self.name!r}")
        if self.program == "replicated-blend" and self.backend != "ref":
            raise ValueError(f"replicated lanes have no kernel lane (lane {self.name!r})")
        if self.max_collective_permute < 0:
            raise ValueError(f"negative ppermute budget for lane {self.name!r}")
        if not 0 <= self.min_collective_permute <= self.max_collective_permute:
            raise ValueError(f"bad ppermute floor for lane {self.name!r}")
        unknown = set(self.forbidden_ops) - set(COLLECTIVE_OPS)
        if unknown:
            raise ValueError(f"unknown forbidden ops {sorted(unknown)} for lane {self.name!r}")

    @property
    def program_key(self) -> tuple:
        """(program, backend): lanes sharing it share one lowered text."""
        return (self.program, self.backend)


def _sharded_lanes() -> tuple:
    lanes = []
    for pipeline in ("serial", "pipelined"):
        for router in ("single", "two-level"):
            for backend in ("ref", "pallas", "fused"):
                lanes.append(
                    LaneInvariant(
                        name=f"sharded/{pipeline}/{router}/{backend}",
                        serve={
                            "mode": "sharded",
                            "pipeline": pipeline,
                            "router": router,
                            "backend": backend,
                        },
                        program="sharded-blend",
                        backend=backend,
                        max_collective_permute=PPERMUTE_BUDGET,
                        min_collective_permute=4,
                        forbidden_ops=GATHERING_COLLECTIVES,
                    )
                )
    # the fixed-q_max whole-stream-prepass lane (sharded single-router)
    lanes.append(
        LaneInvariant(
            name="sharded/serial/single/ref/fixed-q_max",
            serve={"mode": "sharded", "backend": "ref", "q_max": 64},
            program="sharded-blend",
            backend="ref",
            max_collective_permute=PPERMUTE_BUDGET,
            min_collective_permute=4,
            forbidden_ops=GATHERING_COLLECTIVES,
        )
    )
    return tuple(lanes)


LANES: tuple = (
    LaneInvariant(
        name="replicated/serial/single/ref",
        serve={"mode": "replicated", "backend": "ref"},
        program="replicated-blend",
        backend="ref",
        max_collective_permute=0,
        forbidden_ops=COLLECTIVE_OPS,
    ),
) + _sharded_lanes()


# --------------------------------------------------------------------------
# Compiled-cost budgets (the ``costs`` pass)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CostBudget:
    """The compiled-cost envelope of one device program.

    The costs pass AOT-compiles each program at several scale points,
    reads XLA's ``cost_analysis()`` / ``memory_analysis()``, fits log-log
    scaling exponents, and enforces:

      * COST-FLOP-SUPERLINEAR — flops must be (near-)linear in the query
        axis (``scale_axis``): fitted exponent <= ``max_flop_exponent``.
        A pairwise/quadratic term sneaking into the blend shows up as an
        exponent near 2 long before any benchmark feels it.
      * COST-MEM-SCALING — compiled SPMD stats are PER DEVICE, so the
        1/P cache-residency claim is simply "per-device argument bytes
        and flops are FLAT as the mesh grows": fitted exponent vs the
        device count <= ``max_device_exponent``. A replicated cache in
        the in_specs makes per-device bytes GROW with P and is caught
        here (sharded programs only).
      * COST-BUDGET — absolute ceilings at the ``anchor`` scale point
        (~2.5-3x headroom over the measured program, so real regressions
        gate while compiler noise does not).

    Stdlib-only, like the lane manifest above.
    """

    program: str  # "replicated-blend" | "sharded-blend"
    scale_axis: str  # axis the flop exponent is fitted against
    anchor: str  # point label the absolute ceilings apply at
    max_flop_exponent: float
    max_flops: float
    max_bytes_accessed: float
    max_arg_bytes: int
    max_temp_bytes: int
    max_device_exponent: float | None = None  # sharded only: vs device count

    def __post_init__(self) -> None:
        if self.program not in ("replicated-blend", "sharded-blend"):
            raise ValueError(f"unknown program {self.program!r} in cost budget")
        if not 1.0 <= self.max_flop_exponent < 2.0:
            # linear is the claim; an allowance at or past quadratic
            # would make the rule vacuous
            raise ValueError(f"flop exponent budget must be in [1, 2) for {self.program!r}")
        if self.max_device_exponent is not None and not 0.0 <= self.max_device_exponent < 1.0:
            raise ValueError(f"device exponent budget must be in [0, 1) for {self.program!r}")
        for field in ("max_flops", "max_bytes_accessed", "max_arg_bytes", "max_temp_bytes"):
            if getattr(self, field) <= 0:
                raise ValueError(f"{field} must be positive for {self.program!r}")


COST_BUDGETS: dict = {
    # jit blend over the full replicated cache; scale points sweep
    # n_queries. ~0.6 Mflop / 1.5 MB accessed measured at n=256.
    "replicated-blend": CostBudget(
        program="replicated-blend",
        scale_axis="n_queries",
        anchor="n=256",
        max_flop_exponent=1.3,
        max_flops=2.0e6,
        max_bytes_accessed=5.0e6,
        max_arg_bytes=131072,
        max_temp_bytes=524288,
    ),
    # shard_map blend, one partition per device; scale points sweep the
    # grid side (device exponent) and q_max (flop exponent). Per-device
    # ~0.22 Mflop / 0.27 MB accessed / 7.3 KB args measured at the
    # (grid=4, q=64) anchor — flat across P by construction.
    "sharded-blend": CostBudget(
        program="sharded-blend",
        scale_axis="q_max",
        anchor="grid=4/q=64",
        max_flop_exponent=1.3,
        max_flops=7.0e5,
        max_bytes_accessed=9.0e5,
        max_arg_bytes=24576,
        max_temp_bytes=262144,
        max_device_exponent=0.3,
    ),
}
