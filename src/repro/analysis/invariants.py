"""The declarative per-lane invariant manifest the HLO pass enforces.

Every serving lane (a valid :class:`repro.api.ServeConfig` point) carries a
:class:`LaneInvariant`: which device program it compiles, how many
nearest-neighbor collectives that program may contain, which ops are
forbidden outright, and the dtype/host-transfer policy. The manifest is the
checkable form of the architecture prose in docs/architecture.md:

  * sharded predict is HALO-SHAPED — the composed reverse halo is 4
    ppermutes (row exchange + column exchange of the slot-flipped results);
    the budget of 8 leaves headroom for a second composed exchange but is
    far below the 36 per-slot hops the PR-2 program paid;
  * the cache NEVER moves — no all-gather / all-reduce / reduce-scatter /
    all-to-all anywhere in a serving program (the decentralized-serving
    claim, arXiv 1402.1472-style: ship low-rank summaries once, never
    re-aggregate);
  * replicated predict is mesh-free — ZERO collectives of any kind;
  * serving math is f32 — an f64 leak doubles halo bytes and falls off the
    TPU fast path silently;
  * no host transfers inside a compiled serving program — a callback or
    infeed would stall the overlapped pipeline for a full device window
    (the ``device_put``-inside-``route`` bug class, at the HLO level).

Lanes that share a device program (pipeline/router only change HOST-side
scheduling) point at the same ``program`` key; the HLO pass lowers each
distinct program once and applies every lane's invariant to its text, so a
future divergence between two lanes' programs is caught the moment someone
introduces one.

Stdlib-only: the manifest must be importable (and testable) without jax.
"""
from __future__ import annotations

import dataclasses

# Collective mnemonics as they appear in StableHLO / HLO text. The dashed
# and underscored spellings are both matched by the HLO pass.
COLLECTIVE_OPS = (
    "collective-permute",
    "all-gather",
    "all-reduce",
    "all-to-all",
    "reduce-scatter",
)

# Ops that move data between host and device inside a compiled program.
HOST_TRANSFER_OPS = (
    "infeed",
    "outfeed",
    "send",
    "recv",
    "xla_python_cpu_callback",
    "xla_python_gpu_callback",
    "xla_ffi_python",
    "host_callback",
)

# The factors-never-move claim: nothing may re-aggregate sharded state.
GATHERING_COLLECTIVES = ("all-gather", "all-reduce", "all-to-all", "reduce-scatter")

# Composed reverse halo = 4 ppermutes; budget 8 leaves room for one more
# composed exchange (e.g. a future low-rank global term) but stays an
# order below the 36 per-slot hops the pre-composition program paid.
PPERMUTE_BUDGET = 8


@dataclasses.dataclass(frozen=True)
class LaneInvariant:
    """What one serving lane's compiled program is allowed to contain.

    Fields:
      name: stable lane id, e.g. "sharded/pipelined/two-level/fused".
      serve: the ServeConfig dict of the lane (validated against
        ``repro.api.ServeConfig.from_dict`` by the HLO pass, so manifest
        rot — a field rename, an illegal combination — fails the pass).
      program: device-program key the HLO pass lowers —
        "replicated-blend" | "sharded-blend".
      backend: kernel lane the program is built with ("ref"|"pallas"|
        "fused"); with ``program="replicated-blend"`` must be "ref".
      max_collective_permute: inclusive ppermute budget.
      min_collective_permute: floor — a sharded program with FEWER is just
        as wrong (the halo vanished, or the linter stopped seeing it; the
        floor is what catches a rotted op-matching pattern).
      forbidden_ops: op mnemonics that must not appear at all.
      forbid_f64 / forbid_host_transfer: dtype and host-transfer policy.
    """

    name: str
    serve: dict
    program: str
    backend: str
    max_collective_permute: int
    forbidden_ops: tuple
    min_collective_permute: int = 0
    forbid_f64: bool = True
    forbid_host_transfer: bool = True

    def __post_init__(self) -> None:
        if self.program not in ("replicated-blend", "sharded-blend"):
            raise ValueError(f"unknown program {self.program!r} for lane {self.name!r}")
        if self.backend not in ("ref", "pallas", "fused"):
            raise ValueError(f"unknown backend {self.backend!r} for lane {self.name!r}")
        if self.program == "replicated-blend" and self.backend != "ref":
            raise ValueError(f"replicated lanes have no kernel lane (lane {self.name!r})")
        if self.max_collective_permute < 0:
            raise ValueError(f"negative ppermute budget for lane {self.name!r}")
        if not 0 <= self.min_collective_permute <= self.max_collective_permute:
            raise ValueError(f"bad ppermute floor for lane {self.name!r}")
        unknown = set(self.forbidden_ops) - set(COLLECTIVE_OPS)
        if unknown:
            raise ValueError(f"unknown forbidden ops {sorted(unknown)} for lane {self.name!r}")

    @property
    def program_key(self) -> tuple:
        """(program, backend): lanes sharing it share one lowered text."""
        return (self.program, self.backend)


def _sharded_lanes() -> tuple:
    lanes = []
    for pipeline in ("serial", "pipelined"):
        for router in ("single", "two-level"):
            for backend in ("ref", "pallas", "fused"):
                lanes.append(
                    LaneInvariant(
                        name=f"sharded/{pipeline}/{router}/{backend}",
                        serve={
                            "mode": "sharded",
                            "pipeline": pipeline,
                            "router": router,
                            "backend": backend,
                        },
                        program="sharded-blend",
                        backend=backend,
                        max_collective_permute=PPERMUTE_BUDGET,
                        min_collective_permute=4,
                        forbidden_ops=GATHERING_COLLECTIVES,
                    )
                )
    # the fixed-q_max whole-stream-prepass lane (sharded single-router)
    lanes.append(
        LaneInvariant(
            name="sharded/serial/single/ref/fixed-q_max",
            serve={"mode": "sharded", "backend": "ref", "q_max": 64},
            program="sharded-blend",
            backend="ref",
            max_collective_permute=PPERMUTE_BUDGET,
            min_collective_permute=4,
            forbidden_ops=GATHERING_COLLECTIVES,
        )
    )
    return tuple(lanes)


LANES: tuple = (
    LaneInvariant(
        name="replicated/serial/single/ref",
        serve={"mode": "replicated", "backend": "ref"},
        program="replicated-blend",
        backend="ref",
        max_collective_permute=0,
        forbidden_ops=COLLECTIVE_OPS,
    ),
) + _sharded_lanes()
