"""Pass 1 — the HLO invariant linter.

AOT-lowers every serving lane of ``invariants.LANES`` on ABSTRACT inputs
(``jax.ShapeDtypeStruct`` — no training, no data, no mesh execution) and
walks the StableHLO text for structural violations:

  * collective budget: ``collective-permute`` count within the lane's
    budget (the composed reverse halo is 4; budget 8);
  * forbidden ops: no ``all-gather``/``all-reduce``/``reduce-scatter``/
    ``all-to-all`` in any sharded program (the cache never moves), no
    collectives at all in the replicated program;
  * dtype policy: no f64 anywhere in a serving program;
  * host transfers: no infeed/outfeed/send/recv/python callbacks inside a
    compiled program (a host round-trip mid-program stalls the overlapped
    pipeline for a full device window).

This subsumes the hand-written HLO asserts that used to live in the slow
SPMD lane of ``tests/test_serve_sharded.py`` — the budget is now checked
on every push, against every lane, from one declarative manifest.

Lowering needs one device per partition of the probe grid (virtual host
devices on CPU): the CLI calls ``serve_sharded.ensure_host_devices`` before
importing anything jax-backed, exactly like the serving entry points.
"""
from __future__ import annotations

import re
import time

import numpy as np

from repro.analysis import Finding
from repro.analysis import invariants as inv

# Abstract-input dimensions of the probe programs. Small on purpose: the
# invariants are shape-independent (a 3x3 halo is 4 composed ppermutes at
# any grid/q_max), so the cheapest lowering that exercises the real
# program builders is the right one.
DEFAULT_GRID_SIDE = 4
DEFAULT_M = 8
DEFAULT_Q_MAX = 64
DEFAULT_N_QUERIES = 256


def _count_op(text: str, op: str) -> int:
    """Occurrences of a collective/transfer op in StableHLO or HLO text.

    Ops appear as ``"stablehlo.collective_permute"(`` (MLIR generic form,
    quoted), ``stablehlo.collective_permute(`` (MLIR pretty form) or
    ``collective-permute(`` / ``collective-permute-start(`` (HLO);
    counting call-anchored mentions of every spelling covers lowered and
    compiled artifacts alike.
    """
    dashed, scored = op, op.replace("-", "_")
    n = len(re.findall(re.escape(dashed) + r'(?:-start)?"?\(', text))
    n += len(re.findall(re.escape(scored) + r'"?\(', text))
    return n


def count_collectives(text: str) -> dict:
    """Per-op counts for every known collective mnemonic."""
    return {op: _count_op(text, op) for op in inv.COLLECTIVE_OPS}


_F64_RE = re.compile(r"xf64>|<f64>|f64\[")


def has_f64(text: str) -> bool:
    """True if any f64-typed value appears (``tensor<..xf64>`` / ``f64[``)."""
    return _F64_RE.search(text) is not None


def host_transfer_ops(text: str) -> list:
    """Host-transfer mnemonics present in the text (call-anchored)."""
    return [op for op in inv.HOST_TRANSFER_OPS if _count_op(text, op) > 0]


def check_text(lane: "inv.LaneInvariant", text: str) -> tuple:
    """Apply one lane's invariant to a lowered/compiled program text.

    Returns (findings, counts) — ``counts`` is the per-collective op tally
    recorded in ANALYSIS.json so CI can diff drift even while the budget
    still holds.
    """
    where = f"lane:{lane.name}"
    findings = []
    counts = count_collectives(text)
    ncp = counts["collective-permute"]
    if ncp > lane.max_collective_permute:
        findings.append(
            Finding(
                "hlo",
                "HLO-COLLECTIVE-BUDGET",
                where,
                f"{ncp} collective-permutes exceed the lane budget of "
                f"{lane.max_collective_permute} (composed reverse halo is 4 "
                "— a per-slot exchange crept back in?)",
            )
        )
    if ncp < lane.min_collective_permute:
        findings.append(
            Finding(
                "hlo",
                "HLO-COLLECTIVE-MISSING",
                where,
                f"only {ncp} collective-permutes, expected >= "
                f"{lane.min_collective_permute} — the halo exchange is gone "
                "from the program (or the linter's op pattern rotted)",
            )
        )
    for op in lane.forbidden_ops:
        if counts.get(op, 0):
            findings.append(
                Finding(
                    "hlo",
                    "HLO-FORBIDDEN-OP",
                    where,
                    f"forbidden op {op!r} appears {counts[op]}x — sharded "
                    "serving must never re-aggregate the cache factors"
                    if op in inv.GATHERING_COLLECTIVES
                    else f"forbidden op {op!r} appears {counts[op]}x",
                )
            )
    if lane.forbid_f64 and has_f64(text):
        findings.append(
            Finding(
                "hlo",
                "HLO-DTYPE-F64",
                where,
                "f64 values in the serving program — the serving dtype "
                "policy is f32 (halo bytes double and the TPU fast path "
                "is lost silently)",
            )
        )
    if lane.forbid_host_transfer:
        ops = host_transfer_ops(text)
        if ops:
            findings.append(
                Finding(
                    "hlo",
                    "HLO-HOST-TRANSFER",
                    where,
                    f"host-transfer ops {ops} inside a compiled serving "
                    "program — a host round-trip stalls the overlapped "
                    "pipeline for a full device window",
                )
            )
    return findings, counts


# --------------------------------------------------------------------------
# Probe-program construction (abstract inputs; lowering only, no execution)
# --------------------------------------------------------------------------


def probe_grid(side: int = DEFAULT_GRID_SIDE):
    """A unit-square partition grid with ``side**2`` cells — the smallest
    geometry that exercises the real program builders."""
    from repro.core.partition import PartitionGrid

    edges = np.linspace(0.0, 1.0, side + 1)
    return PartitionGrid(gx=side, gy=side, x_edges=edges, y_edges=edges, wrap_x=False)


def abstract_cache(num_partitions: int, m: int, d: int = 2):
    """A P-stacked ``PosteriorCache`` of ``ShapeDtypeStruct`` leaves — the
    same pytree STRUCTURE the serving path shards, with no arrays behind
    it (``make_sharded_blend`` only reads the structure for its in_specs)."""
    import jax
    import jax.numpy as jnp

    from repro.core import posterior
    from repro.gp.covariances import CovarianceParams

    def f32(*shape):
        return jax.ShapeDtypeStruct(shape, jnp.float32)

    return posterior.PosteriorCache(
        z=f32(num_partitions, m, d),
        w=f32(num_partitions, m, m),
        u=f32(num_partitions, m, m),
        c=f32(num_partitions, m),
        cov=CovarianceParams(
            log_lengthscale=f32(num_partitions, d), log_variance=f32(num_partitions)
        ),
        log_beta=f32(num_partitions),
    )


def lower_program(
    program_key: tuple,
    *,
    grid_side: int = DEFAULT_GRID_SIDE,
    m: int = DEFAULT_M,
    q_max: int = DEFAULT_Q_MAX,
    n_queries: int = DEFAULT_N_QUERIES,
) -> str:
    """Build + AOT-lower one device program; return its StableHLO text.

    ``program_key`` is ``LaneInvariant.program_key``. Sharded programs are
    the real ``make_sharded_blend`` shard_map over a one-partition-per-
    device mesh; the replicated program is the real ``blend._blend_eval``
    jit. Abstract inputs throughout — nothing executes.
    """
    import jax
    import jax.numpy as jnp

    from repro.gp.covariances import make_covariance

    program, backend = program_key
    grid = probe_grid(grid_side)
    cov_fn = make_covariance("rbf")
    cache = abstract_cache(grid.num_partitions, m)

    def f32(*shape):
        return jax.ShapeDtypeStruct(shape, jnp.float32)

    if program == "replicated-blend":
        from repro.core import blend

        lowered = blend._blend_eval.lower(
            cache,
            cov_fn,
            f32(n_queries, 2),
            jax.ShapeDtypeStruct((n_queries, 4), jnp.int64),
            f32(n_queries, 4),
        )
    elif program == "sharded-blend":
        from repro.launch import serve_sharded as ss

        mesh = ss.mesh_for_grid(grid)
        blend_fn = ss.make_sharded_blend(
            mesh, mesh.axis_names, grid, cov_fn, cache, backend=backend
        )
        P = grid.num_partitions
        lowered = blend_fn.lower(
            cache,
            f32(P, 9, q_max, 2),
            jax.ShapeDtypeStruct((P, q_max, 4), jnp.int32),
            f32(P, q_max, 4),
        )
    else:
        raise ValueError(f"unknown program {program!r}")
    return lowered.as_text()


def run(
    *,
    grid_side: int = DEFAULT_GRID_SIDE,
    m: int = DEFAULT_M,
    q_max: int = DEFAULT_Q_MAX,
    n_queries: int = DEFAULT_N_QUERIES,
    lanes: tuple = None,
) -> tuple:
    """The full pass: lower every distinct program once, apply every lane.

    Returns (findings, report) where ``report`` is the JSON-ready record
    (per-lane program key, collective counts, violation count, timing).
    """
    from repro.api.config import ServeConfig

    lanes = inv.LANES if lanes is None else lanes
    findings: list = []
    lane_records = []
    texts: dict = {}
    t0 = time.time()
    for lane in lanes:
        # manifest rot check: the lane's serve dict must still be a valid
        # ServeConfig (field renames / illegal combinations fail the pass)
        try:
            ServeConfig.from_dict(lane.serve)
        except (ValueError, TypeError) as e:
            findings.append(
                Finding(
                    "hlo",
                    "HLO-MANIFEST",
                    f"lane:{lane.name}",
                    f"lane serve dict no longer parses as a ServeConfig: {e}",
                )
            )
            continue
        key = lane.program_key
        if key not in texts:
            texts[key] = lower_program(
                key, grid_side=grid_side, m=m, q_max=q_max, n_queries=n_queries
            )
        lane_findings, counts = check_text(lane, texts[key])
        findings.extend(lane_findings)
        lane_records.append(
            {
                "lane": lane.name,
                "program": "/".join(key),
                "serve_config": lane.serve,
                "collectives": counts,
                "max_collective_permute": lane.max_collective_permute,
                "violations": len(lane_findings),
            }
        )
    report = {
        "lanes": lane_records,
        "programs_lowered": sorted("/".join(k) for k in texts),
        "grid_side": grid_side,
        "m": m,
        "q_max": q_max,
        "seconds": round(time.time() - t0, 3),
    }
    return findings, report
