"""Pass 4 — compiled cost-model gates.

The paper's in-situ claims are quantitative, not just structural: the
sharded cache must occupy O(1/P) bytes per device, and blend work must be
linear in the query block. The HLO pass (pass 1) proves the *shape* of the
program; this pass proves its *cost*, straight from the compiler — no
execution, no benchmark:

  * every distinct device program is AOT-COMPILED at 2-3 scale points per
    axis (grid side for the sharded program, q_max / n_queries for the
    query axis);
  * XLA's ``compiled.cost_analysis()`` (flops, bytes accessed) and
    ``compiled.memory_analysis()`` (argument / output / peak-temp bytes)
    are recorded per point — for an SPMD program these are PER-DEVICE
    numbers, which is exactly what makes the 1/P claim checkable: a
    correctly sharded cache gives a FLAT per-device curve as the mesh
    grows, a replicated one a growing curve;
  * log-log least-squares exponents are fitted per (metric, axis) and
    checked against the declarative budgets in
    ``invariants.COST_BUDGETS`` (COST-FLOP-SUPERLINEAR, COST-MEM-SCALING,
    COST-BUDGET);
  * every point is also diffed against the checked-in baseline
    (``benchmarks/baselines/analysis_costs.json``) so a cost regression
    gates CI the way ``check_bench_regression.py`` gates p50 — but at
    compile time, deterministically. ``--update-baselines`` refreshes the
    file after an intentional change.

Kernel-lane caveat, stated rather than silently capped: on a CPU host the
pallas/fused lanes run interpret-mode (host callbacks), which makes XLA's
cost model meaningless for them — those lanes are recorded as skipped
with this reason, and the ref program bounds the math they implement.

Measurement (jax-touching ``compile_*`` / ``measure_programs``) is kept
separate from judgment (pure ``fit_exponent`` / ``check_*``), so the
gating logic is unit-testable without a mesh.
"""
from __future__ import annotations

import json
import math
import os
import time

from repro.analysis import Finding
from repro.analysis import invariants as inv

# Fixed scale points — independent of the CLI's --grid/--q-max probes so
# the checked-in budgets and baselines always mean the same program.
M = 8
SHARDED_GRID_SIDES = (2, 3, 4)  # P = 4, 9, 16 devices, at q_max = ANCHOR_Q
SHARDED_Q_POINTS = (32, 64, 128)  # at grid side ANCHOR_GRID
ANCHOR_GRID = 4
ANCHOR_Q = 64
REPLICATED_N_POINTS = (128, 256, 512)
REQUIRED_DEVICES = max(s * s for s in SHARDED_GRID_SIDES)

DEFAULT_BASELINE = os.path.join("benchmarks", "baselines", "analysis_costs.json")
# deterministic compiler stats still move across compiler versions; a
# quarter is far above that noise and far below any real regression
DRIFT_TOLERANCE = 1.25

METRICS = ("flops", "bytes_accessed", "arg_bytes", "out_bytes", "temp_bytes")


# --------------------------------------------------------------------------
# Measurement (jax-touching; imports deferred like hlo.py)
# --------------------------------------------------------------------------


def extract(compiled) -> dict:
    """Flatten one compiled program's cost + memory stats to a JSON row."""
    from repro.runtime import compat

    ca = compat.cost_analysis(compiled)
    mem = compiled.memory_analysis()
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "arg_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
        "out_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
        "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
    }


def compile_sharded(grid_side: int, q_max: int, *, m: int = M, backend: str = "ref"):
    """AOT-compile the sharded blend on a ``grid_side**2``-device mesh."""
    import jax
    import jax.numpy as jnp

    from repro.analysis import hlo
    from repro.gp.covariances import make_covariance
    from repro.launch import serve_sharded as ss

    grid = hlo.probe_grid(grid_side)
    cache = hlo.abstract_cache(grid.num_partitions, m)
    mesh = ss.mesh_for_grid(grid)
    blend_fn = ss.make_sharded_blend(
        mesh, mesh.axis_names, grid, make_covariance("rbf"), cache, backend=backend
    )
    P = grid.num_partitions

    def f32(*shape):
        return jax.ShapeDtypeStruct(shape, jnp.float32)

    return blend_fn.lower(
        cache,
        f32(P, 9, q_max, 2),
        jax.ShapeDtypeStruct((P, q_max, 4), jnp.int32),
        f32(P, q_max, 4),
    ).compile()


def compile_replicated(n_queries: int, *, m: int = M, grid_side: int = ANCHOR_GRID):
    """AOT-compile the replicated blend jit (mesh-free)."""
    import jax
    import jax.numpy as jnp

    from repro.analysis import hlo
    from repro.core import blend
    from repro.gp.covariances import make_covariance

    grid = hlo.probe_grid(grid_side)
    cache = hlo.abstract_cache(grid.num_partitions, m)

    def f32(*shape):
        return jax.ShapeDtypeStruct(shape, jnp.float32)

    return blend._blend_eval.lower(
        cache,
        make_covariance("rbf"),
        f32(n_queries, 2),
        jax.ShapeDtypeStruct((n_queries, 4), jnp.int64),
        f32(n_queries, 4),
    ).compile()


def measure_programs(*, m: int = M) -> dict:
    """Compile every ref program at its scale points; return per-program
    ``{"points": {label: metrics}, "axes": {axis: {label: value}}}``."""
    sharded_points, sharded_axes = {}, {"devices": {}, "q_max": {}}
    for side in SHARDED_GRID_SIDES:
        label = f"grid={side}/q={ANCHOR_Q}"
        sharded_points[label] = extract(compile_sharded(side, ANCHOR_Q, m=m))
        sharded_axes["devices"][label] = side * side
    for q in SHARDED_Q_POINTS:
        label = f"grid={ANCHOR_GRID}/q={q}"
        if label not in sharded_points:
            sharded_points[label] = extract(compile_sharded(ANCHOR_GRID, q, m=m))
        sharded_axes["q_max"][label] = q

    repl_points, repl_axes = {}, {"n_queries": {}}
    for n in REPLICATED_N_POINTS:
        label = f"n={n}"
        repl_points[label] = extract(compile_replicated(n, m=m))
        repl_axes["n_queries"][label] = n

    return {
        "replicated-blend/ref": {"points": repl_points, "axes": repl_axes},
        "sharded-blend/ref": {"points": sharded_points, "axes": sharded_axes},
    }


# --------------------------------------------------------------------------
# Judgment (pure; unit-testable without jax)
# --------------------------------------------------------------------------


def fit_exponent(xs, ys) -> float:
    """Least-squares slope of log(y) on log(x) — the scaling exponent."""
    if len(xs) != len(ys) or len(xs) < 2:
        raise ValueError("need >= 2 (x, y) points to fit an exponent")
    lx = [math.log(float(x)) for x in xs]
    ly = [math.log(max(float(y), 1e-12)) for y in ys]
    n = len(lx)
    mx, my = sum(lx) / n, sum(ly) / n
    den = sum((a - mx) ** 2 for a in lx)
    if den == 0.0:
        raise ValueError("scale points must differ on the x axis")
    return sum((a - mx) * (b - my) for a, b in zip(lx, ly)) / den


def compute_exponents(record: dict) -> dict:
    """Fitted exponent of every metric along every axis of one program's
    record: ``{"flops_vs_q_max": 1.0, "arg_bytes_vs_devices": 0.0, ...}``."""
    out = {}
    for axis, labels in record["axes"].items():
        xs = [labels[lab] for lab in labels]
        for metric in METRICS:
            ys = [record["points"][lab][metric] for lab in labels]
            out[f"{metric}_vs_{axis}"] = round(fit_exponent(xs, ys), 4)
    return out


def check_budget(name: str, record: dict, budget: "inv.CostBudget") -> list:
    """Apply one program's declarative cost budget to its measured record."""
    exps = record["exponents"]
    where = f"program:{name}"
    findings = []

    flop_key = f"flops_vs_{budget.scale_axis}"
    if exps.get(flop_key, 0.0) > budget.max_flop_exponent:
        findings.append(
            Finding(
                "costs",
                "COST-FLOP-SUPERLINEAR",
                where,
                f"flops scale as {budget.scale_axis}^{exps[flop_key]:.2f}, "
                f"budget is ^{budget.max_flop_exponent} — a quadratic "
                "(pairwise) term crept into the blend",
            )
        )
    if budget.max_device_exponent is not None:
        for metric in ("arg_bytes", "flops"):
            key = f"{metric}_vs_devices"
            if exps.get(key, 0.0) > budget.max_device_exponent:
                findings.append(
                    Finding(
                        "costs",
                        "COST-MEM-SCALING",
                        where,
                        f"per-device {metric} scale as devices^{exps[key]:.2f}, "
                        f"budget is ^{budget.max_device_exponent} — per-device "
                        "state/work must stay FLAT as the mesh grows (the 1/P "
                        "residency claim; a replicated cache in the in_specs "
                        "looks exactly like this)",
                    )
                )
    anchor = record["points"].get(budget.anchor)
    if anchor is None:
        findings.append(
            Finding(
                "costs",
                "COST-BUDGET",
                where,
                f"anchor point {budget.anchor!r} missing from the measured "
                "scale points — the budget manifest and the pass disagree",
            )
        )
        return findings
    for metric, ceiling in (
        ("flops", budget.max_flops),
        ("bytes_accessed", budget.max_bytes_accessed),
        ("arg_bytes", budget.max_arg_bytes),
        ("temp_bytes", budget.max_temp_bytes),
    ):
        if anchor[metric] > ceiling:
            findings.append(
                Finding(
                    "costs",
                    "COST-BUDGET",
                    where,
                    f"{metric} = {anchor[metric]:.0f} at {budget.anchor} "
                    f"exceeds the absolute ceiling {ceiling:.0f}",
                )
            )
    return findings


def check_baseline(name: str, record: dict, baseline_record: dict | None,
                   *, tolerance: float = DRIFT_TOLERANCE) -> list:
    """Diff one program's fresh points against the checked-in baseline.

    Increases beyond ``tolerance`` gate (COST-BASELINE-DRIFT); a point or
    metric the baseline has never seen gates too (COST-BASELINE-MISSING —
    run ``--update-baselines`` after an intentional change). Decreases
    never gate: a cheaper program only deserves a baseline refresh.
    """
    where = f"program:{name}"
    if baseline_record is None:
        return [
            Finding(
                "costs",
                "COST-BASELINE-MISSING",
                where,
                "no baseline for this program — run "
                "`python -m repro.analysis --passes costs --update-baselines` "
                "and commit benchmarks/baselines/analysis_costs.json",
            )
        ]
    findings = []
    base_points = baseline_record.get("points", {})
    for label, metrics in record["points"].items():
        base = base_points.get(label)
        if base is None:
            findings.append(
                Finding(
                    "costs",
                    "COST-BASELINE-MISSING",
                    where,
                    f"scale point {label!r} has no baseline — run "
                    "--update-baselines after an intentional change",
                )
            )
            continue
        for metric in METRICS:
            fresh, ref = float(metrics[metric]), float(base.get(metric, 0.0))
            if fresh > ref * tolerance and fresh - ref > 256:
                findings.append(
                    Finding(
                        "costs",
                        "COST-BASELINE-DRIFT",
                        where,
                        f"{metric} at {label}: {fresh:.0f} vs baseline "
                        f"{ref:.0f} (> {tolerance:.2f}x) — a compiled-cost "
                        "regression; if intentional, refresh with "
                        "--update-baselines",
                    )
                )
    return findings


def lane_cost_records(programs: dict) -> list:
    """Map every serving lane onto its program's cost record (or the
    explicit reason it has none) — the per-lane view ANALYSIS.json ships."""
    records = []
    for lane in inv.LANES:
        name = "/".join(lane.program_key)
        if name in programs:
            rec = programs[name]
            records.append(
                {
                    "lane": lane.name,
                    "program": name,
                    "anchor": inv.COST_BUDGETS[lane.program].anchor,
                    "anchor_cost": rec["points"].get(
                        inv.COST_BUDGETS[lane.program].anchor
                    ),
                    "exponents": rec["exponents"],
                }
            )
        else:
            records.append(
                {
                    "lane": lane.name,
                    "program": name,
                    "skipped": (
                        "kernel lane not cost-modeled: pallas runs "
                        "interpret-mode on this host (host callbacks make "
                        "XLA cost_analysis meaningless); the ref program "
                        "bounds the same math"
                    ),
                }
            )
    return records


# --------------------------------------------------------------------------
# The pass
# --------------------------------------------------------------------------


def load_baseline(path: str) -> dict | None:
    if not os.path.exists(path):
        return None
    with open(path, encoding="utf-8") as fh:
        return json.load(fh)


def write_baseline(path: str, programs: dict, *, platform: str) -> None:
    import jax

    payload = {
        "_meta": {
            "platform": platform,
            "jax": jax.__version__,
            "m": M,
            "tolerance": DRIFT_TOLERANCE,
            "note": "deterministic per-device compiled-program costs; "
            "refresh with `python -m repro.analysis --passes costs "
            "--update-baselines` after an intentional change",
        },
        "programs": {
            name: {"points": rec["points"], "exponents": rec["exponents"]}
            for name, rec in programs.items()
        },
    }
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
        fh.write("\n")


def run(
    *,
    m: int = M,
    baseline_path: str = DEFAULT_BASELINE,
    update_baselines: bool = False,
) -> tuple:
    """The full pass. Returns (findings, report)."""
    import jax

    t0 = time.time()
    platform = jax.default_backend()
    findings: list = []
    programs = measure_programs(m=m)
    for name, rec in programs.items():
        rec["exponents"] = compute_exponents(rec)
        findings.extend(check_budget(name, rec, inv.COST_BUDGETS[name.split("/")[0]]))

    baseline = load_baseline(baseline_path)
    baseline_checked = False
    if update_baselines:
        write_baseline(baseline_path, programs, platform=platform)
    elif baseline is not None and baseline.get("_meta", {}).get("platform") != platform:
        # a baseline measured on another platform gates nothing here;
        # stated rather than silently skipped
        pass
    else:
        baseline_checked = True
        base_programs = (baseline or {}).get("programs", {})
        for name, rec in programs.items():
            findings.extend(check_baseline(name, rec, base_programs.get(name)))

    report = {
        "programs": programs,
        "lanes": lane_cost_records(programs),
        "budgets": {
            name: dataclass_dict(b) for name, b in sorted(inv.COST_BUDGETS.items())
        },
        "baseline_path": baseline_path,
        "baseline_checked": baseline_checked,
        "baseline_updated": bool(update_baselines),
        "platform": platform,
        "m": m,
        "seconds": round(time.time() - t0, 3),
    }
    return findings, report


def dataclass_dict(budget) -> dict:
    import dataclasses

    return dataclasses.asdict(budget)
