"""repro.analysis — static verification of the serving stack's invariants.

The PSVGP serving claims are STRUCTURAL: factors never move, the halo
exchange is O(1) ppermutes, no all-gather on the hot path, routing stays
host-side numpy, nothing touches the device at import time. This package
checks those properties without executing the mesh — compiled-artifact and
source-level analysis, cheap enough to run on every push:

  pass 1  ``hlo``        AOT-lower every ServeConfig lane on abstract
                         inputs and enforce the declarative per-lane
                         invariant manifest (``invariants.LANES``) on the
                         StableHLO text: collective budget, forbidden ops,
                         dtype policy, host-transfer detection.
  pass 2  ``ast``        repo-rule source lint (``astlint``): the bugs this
                         repo has already shipped, codified as named rules
                         RR001..RR004 with file/line diagnostics and a
                         ``# repro: noqa-RRxxx`` escape hatch.
  pass 3  ``contracts``  trace-time shape/spec contracts: ``@contract``
                         declarations on the serving entry points, checked
                         via ``jax.eval_shape`` over the config matrix —
                         zero runtime cost in production.
  pass 4  ``costs``      compiled cost-model gates: AOT-compile the device
                         programs at several (grid, q_max) scale points,
                         read ``cost_analysis()``/``memory_analysis()``,
                         fit scaling exponents and enforce the declarative
                         budgets (``invariants.COST_BUDGETS``) plus drift
                         vs ``benchmarks/baselines/analysis_costs.json`` —
                         the 1/P-residency and linear-in-q_max claims,
                         checked without running a benchmark.
  pass 5  ``async``      CFG-lite race lint for the asyncio serving layer
                         (``asynclint``): rules RR005..RR008 — blocking
                         calls on the event loop, unconfined dual-thread
                         writes, lost tasks, orphanable request futures.

One front door::

    PYTHONPATH=src python -m repro.analysis            # all five passes
    make analyze                                       # same, via Makefile

writes ``ANALYSIS.json`` (per-lane op counts, per-rule findings) and exits
non-zero on any violation, so CI can diff invariant drift the same way
``benchmarks/check_bench_regression.py`` gates p50.

This module is stdlib-only at import time (``Finding`` + the pass
registry); the jax-touching passes live in submodules imported by the
CLI — which must be able to force virtual host devices BEFORE the jax
backend initializes, exactly like the sharded serving entry points.
"""
from __future__ import annotations

import dataclasses

PASSES = ("hlo", "ast", "contracts", "costs", "async")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violation: which pass, which rule, where, and what happened.

    ``where`` is ``path:line`` for source findings and ``lane:<name>`` for
    compiled-artifact findings — both stable strings a CI diff of
    ANALYSIS.json can key on.
    """

    pass_name: str  # "hlo" | "ast" | "contracts" | "costs" | "async"
    rule: str  # e.g. "RR001", "HLO-FORBIDDEN-OP", "COST-BUDGET"
    where: str
    message: str

    def __post_init__(self) -> None:
        if self.pass_name not in PASSES:
            raise ValueError(f"pass_name must be one of {PASSES}, got {self.pass_name!r}")
        if not (self.rule and self.where and self.message):
            raise ValueError("rule/where/message must be non-empty")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        return f"[{self.rule}] {self.where}: {self.message}"


__all__ = ["Finding", "PASSES"]
