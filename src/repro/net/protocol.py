"""The versioned msgpack wire protocol of the HTTP front door.

One frame = one msgpack map. Every frame carries ``v`` (the protocol
version — a mismatch is a hard decode error, never a silent best-effort
parse) and ``kind``; the remaining keys are an EXACT set per kind,
validated the way the frozen session configs validate theirs (unknown
keys are protocol rot, not noise). Three kinds:

  ``predict_request``   request_id + n + points ((n, 2) float32 as raw
                        little-endian bytes — 8 bytes per query point,
                        no per-element msgpack framing)
  ``predict_response``  request_id + n + mean/var (raw float32 bytes)
                        + server_version (the model version that
                        answered, ``Server.lifecycle``) + a server-side
                        timing breakdown (decode/engine/total ms)
  ``error``             request_id + a TYPED code — "shed" (admission
                        queue full), "oversized" (request above
                        ``max_request_rows``), "engine-broken" (the
                        front door engine died), "bad-request",
                        "internal" — + message + optional retry_after_ms

Arrays cross the wire as raw ``<f4`` bytes rather than msgpack lists:
the golden property extends BITWISE over the wire only if serialization
is an exact float32 round-trip, and raw bytes make that true by
construction (a per-element float encoding would round-trip through
float64). :func:`decode_frame` raises :class:`ProtocolError` — and only
``ProtocolError`` — on anything malformed: truncated msgpack, trailing
bytes, wrong version, unknown kind, missing/unknown/ill-typed keys, or
byte lengths that disagree with ``n``. Callers never see a msgpack
internal error.
"""
from __future__ import annotations

import dataclasses
import math

import msgpack
import numpy as np

PROTOCOL_VERSION = 1

ERROR_CODES = ("shed", "oversized", "engine-broken", "bad-request", "internal")

# HTTP status each typed error code maps to (server + client share this
# table; docs/net.md renders it)
STATUS_FOR_CODE = {
    "shed": 429,
    "oversized": 413,
    "engine-broken": 503,
    "bad-request": 400,
    "internal": 500,
}

_TIMING_KEYS = ("decode_ms", "engine_ms", "total_ms")


class ProtocolError(ValueError):
    """A frame that cannot be decoded: truncated/trailing/garbage bytes,
    a protocol version mismatch, an unknown kind, or a key set / type /
    byte-length violation. The one exception the wire layer raises for
    malformed input — msgpack internals never leak to callers."""


def _check(cond: bool, msg: str) -> None:
    if not cond:
        raise ProtocolError(msg)


def _f32_bytes(arr, name: str, shape_tail: tuple[int, ...]) -> bytes:
    """Validate + serialize one array field as raw little-endian float32
    bytes (C order). The exactness of the over-the-wire golden property
    lives here: bytes in == bytes out, no re-rounding."""
    a = np.asarray(arr)
    _check(
        a.shape[1:] == shape_tail,
        f"{name} must have trailing shape {shape_tail}, got {a.shape}",
    )
    return np.ascontiguousarray(a, dtype="<f4").tobytes()


def _f32_array(buf: bytes, name: str, shape: tuple[int, ...]) -> np.ndarray:
    count = math.prod(shape)
    _check(
        isinstance(buf, bytes) and len(buf) == 4 * count,
        f"{name} must be {4 * count} raw float32 bytes for shape {shape}, "
        f"got {len(buf) if isinstance(buf, bytes) else type(buf).__name__}",
    )
    return np.frombuffer(buf, dtype="<f4").astype(np.float32).reshape(shape)


def _check_id(request_id) -> None:
    _check(
        isinstance(request_id, str) and 0 < len(request_id) <= 128,
        f"request_id must be a non-empty str of <= 128 chars, got {request_id!r}",
    )


@dataclasses.dataclass(frozen=True)
class PredictRequest:
    """One ``POST /predict`` body: a request id and (n, 2) query points."""

    request_id: str
    n: int
    points_f32: bytes  # (n, 2) float32, raw little-endian C-order bytes

    def __post_init__(self) -> None:
        _check_id(self.request_id)
        _check(
            isinstance(self.n, int) and self.n >= 1,
            f"n must be an int >= 1, got {self.n!r}",
        )
        _check(
            isinstance(self.points_f32, bytes) and len(self.points_f32) == 8 * self.n,
            f"points_f32 must be {8 * self.n} bytes for n={self.n} "
            f"(8 per (x, y) float32 point), got "
            f"{len(self.points_f32) if isinstance(self.points_f32, bytes) else type(self.points_f32).__name__}",
        )

    @classmethod
    def from_points(cls, request_id: str, points) -> PredictRequest:
        pts = np.asarray(points, np.float32)
        _check(
            pts.ndim == 2 and pts.shape[1] == 2 and pts.shape[0] >= 1,
            f"points must be (n >= 1, 2), got shape {pts.shape}",
        )
        return cls(request_id, int(pts.shape[0]), _f32_bytes(pts, "points", (2,)))

    def points(self) -> np.ndarray:
        return _f32_array(self.points_f32, "points_f32", (self.n, 2))

    def encode(self) -> bytes:
        return msgpack.packb(
            {
                "v": PROTOCOL_VERSION,
                "kind": "predict_request",
                "request_id": self.request_id,
                "n": self.n,
                "points_f32": self.points_f32,
            },
            use_bin_type=True,
        )


@dataclasses.dataclass(frozen=True)
class PredictResponse:
    """The success frame: per-point mean/var, the model version that
    served it, and the server-side timing breakdown in milliseconds
    (``decode_ms`` body parse, ``engine_ms`` awaiting
    ``FrontDoor.submit`` — queueing + batching + device, ``total_ms``
    request receipt to response encode)."""

    request_id: str
    n: int
    mean_f32: bytes  # (n,) float32 raw bytes
    var_f32: bytes  # (n,) float32 raw bytes
    server_version: int
    timing_ms: tuple[float, float, float]  # (decode_ms, engine_ms, total_ms)

    def __post_init__(self) -> None:
        _check_id(self.request_id)
        _check(
            isinstance(self.n, int) and self.n >= 1,
            f"n must be an int >= 1, got {self.n!r}",
        )
        for name in ("mean_f32", "var_f32"):
            buf = getattr(self, name)
            _check(
                isinstance(buf, bytes) and len(buf) == 4 * self.n,
                f"{name} must be {4 * self.n} bytes for n={self.n}, got "
                f"{len(buf) if isinstance(buf, bytes) else type(buf).__name__}",
            )
        _check(
            isinstance(self.server_version, int) and self.server_version >= 0,
            f"server_version must be an int >= 0, got {self.server_version!r}",
        )
        t = self.timing_ms
        _check(
            isinstance(t, tuple)
            and len(t) == len(_TIMING_KEYS)
            and all(isinstance(x, float) and math.isfinite(x) and x >= 0 for x in t),
            f"timing_ms must be {len(_TIMING_KEYS)} finite non-negative floats "
            f"{_TIMING_KEYS}, got {t!r}",
        )

    @classmethod
    def from_arrays(
        cls,
        request_id: str,
        mean,
        var,
        *,
        server_version: int,
        timing_ms: tuple[float, float, float],
    ) -> PredictResponse:
        m = np.asarray(mean, np.float32).reshape(-1)
        v = np.asarray(var, np.float32).reshape(-1)
        _check(
            m.shape == v.shape and m.shape[0] >= 1,
            f"mean/var must be equal-length (n >= 1,) arrays, got {m.shape} / {v.shape}",
        )
        return cls(
            request_id,
            int(m.shape[0]),
            _f32_bytes(m, "mean", ()),
            _f32_bytes(v, "var", ()),
            int(server_version),
            tuple(float(x) for x in timing_ms),
        )

    def mean(self) -> np.ndarray:
        return _f32_array(self.mean_f32, "mean_f32", (self.n,))

    def var(self) -> np.ndarray:
        return _f32_array(self.var_f32, "var_f32", (self.n,))

    def timing(self) -> dict:
        return dict(zip(_TIMING_KEYS, self.timing_ms, strict=True))

    def encode(self) -> bytes:
        return msgpack.packb(
            {
                "v": PROTOCOL_VERSION,
                "kind": "predict_response",
                "request_id": self.request_id,
                "n": self.n,
                "mean_f32": self.mean_f32,
                "var_f32": self.var_f32,
                "server_version": self.server_version,
                "timing_ms": list(self.timing_ms),
            },
            use_bin_type=True,
        )


@dataclasses.dataclass(frozen=True)
class ErrorFrame:
    """The typed failure frame. ``code`` is the machine-readable contract
    (one of :data:`ERROR_CODES`, each pinned to an HTTP status by
    :data:`STATUS_FOR_CODE`); ``message`` is for humans;
    ``retry_after_ms`` is set when retrying makes sense (shed,
    engine-broken) and None when it never will (oversized,
    bad-request)."""

    request_id: str  # "" when the failure preceded parsing an id
    code: str
    message: str
    retry_after_ms: float | None = None

    def __post_init__(self) -> None:
        _check(
            isinstance(self.request_id, str) and len(self.request_id) <= 128,
            f"request_id must be a str of <= 128 chars, got {self.request_id!r}",
        )
        _check(
            self.code in ERROR_CODES,
            f"code must be one of {ERROR_CODES}, got {self.code!r}",
        )
        _check(
            isinstance(self.message, str) and 0 < len(self.message) <= 2048,
            "message must be a non-empty str of <= 2048 chars",
        )
        if self.retry_after_ms is not None:
            _check(
                isinstance(self.retry_after_ms, float)
                and math.isfinite(self.retry_after_ms)
                and self.retry_after_ms >= 0,
                f"retry_after_ms must be a finite float >= 0 or None, "
                f"got {self.retry_after_ms!r}",
            )

    @property
    def status(self) -> int:
        return STATUS_FOR_CODE[self.code]

    def encode(self) -> bytes:
        return msgpack.packb(
            {
                "v": PROTOCOL_VERSION,
                "kind": "error",
                "request_id": self.request_id,
                "code": self.code,
                "message": self.message,
                "retry_after_ms": self.retry_after_ms,
            },
            use_bin_type=True,
        )


_FRAME_FIELDS = {
    "predict_request": ("request_id", "n", "points_f32"),
    "predict_response": (
        "request_id",
        "n",
        "mean_f32",
        "var_f32",
        "server_version",
        "timing_ms",
    ),
    "error": ("request_id", "code", "message", "retry_after_ms"),
}


def decode_frame(buf: bytes) -> PredictRequest | PredictResponse | ErrorFrame:
    """Strictly decode one wire frame, or raise :class:`ProtocolError`.

    Strict means: the buffer must be EXACTLY one msgpack map (truncated
    input and trailing bytes both fail), ``v`` must equal
    :data:`PROTOCOL_VERSION`, ``kind`` must be known, and the remaining
    keys must be exactly the kind's field set with every value passing
    the same ``__post_init__`` validation a locally-constructed frame
    gets. A frame that decodes is as trustworthy as one never serialized.
    """
    _check(isinstance(buf, (bytes, bytearray)), f"frame must be bytes, got {type(buf).__name__}")
    try:
        obj = msgpack.unpackb(bytes(buf), raw=False, strict_map_key=True)
    except Exception as err:  # truncated, trailing (ExtraData), or garbage
        raise ProtocolError(f"undecodable msgpack frame: {err}") from err
    _check(isinstance(obj, dict), f"frame must be a msgpack map, got {type(obj).__name__}")
    _check("v" in obj, "frame missing protocol version key 'v'")
    _check(
        obj["v"] == PROTOCOL_VERSION,
        f"protocol version mismatch: frame has v={obj['v']!r}, "
        f"this build speaks v={PROTOCOL_VERSION}",
    )
    kind = obj.get("kind")
    _check(
        kind in _FRAME_FIELDS,
        f"unknown frame kind {kind!r}; expected one of {sorted(_FRAME_FIELDS)}",
    )
    fields = _FRAME_FIELDS[kind]
    expected = {"v", "kind", *fields}
    _check(
        set(obj) == expected,
        f"{kind} frame key set mismatch: got {sorted(obj)}, expected {sorted(expected)}",
    )
    body = {k: obj[k] for k in fields}
    if kind == "predict_request":
        return PredictRequest(**body)
    if kind == "predict_response":
        t = body["timing_ms"]
        _check(
            isinstance(t, list) and all(isinstance(x, (int, float)) for x in t),
            f"timing_ms must be a list of numbers, got {t!r}",
        )
        body["timing_ms"] = tuple(float(x) for x in t)
        return PredictResponse(**body)
    if body["retry_after_ms"] is not None and isinstance(body["retry_after_ms"], int):
        body["retry_after_ms"] = float(body["retry_after_ms"])
    return ErrorFrame(**body)
