"""repro.net — the HTTP front door over the wire.

Everything before this package — continuous batching, admission
control, hot swap (``repro.api.FrontDoor``) — is in-process asyncio:
the "millions of users" story stopped at the Python API boundary. This
package is the actual transport in front of it, in three deliberately
thin layers over the transport-agnostic coalesce/demux/backpressure
engine (which does not change):

  * :mod:`repro.net.protocol` — the versioned, msgpack-framed wire
    protocol: a predict request is a points array + request id; a
    response is mean/var + the serving model version + a timing
    breakdown; failures are TYPED error frames (shed / oversized /
    engine-broken / bad-request / internal). Decoding is strict in the
    spirit of the frozen config dataclasses: unknown keys, truncated
    payloads, and version mismatches all raise.
  * :mod:`repro.net.server` — an asyncio HTTP/1.1 endpoint
    (``POST /predict``, ``GET /healthz``, ``GET /slo``) that is a thin
    adapter over ``FrontDoor.submit``: shed maps to 429 with
    Retry-After, an oversized request to 413, a broken engine to 503.
    ``python -m repro.net.server`` / ``serve --gp --http`` serve it.
  * :mod:`repro.net.client` — a small sync + async client (connection
    reuse, bounded jittered retries on 429/503 honoring Retry-After,
    per-request deadlines) used by the tests and ``bench_net``.

Only small summaries ever cross the wire — query points in, mean/var
out, a few hundred bytes per request — never data or factors, the
Katzfuss/Hammerling low-rank distributed framing (PAPERS.md,
arXiv 1402.1472). ``benchmarks/bench_net.py`` measures what the wire
adds: open-loop Poisson arrivals over real localhost sockets, the
golden bitwise property extended end-to-end over HTTP, and a
wire-overhead column (http p50 − in-process p50) per offered-QPS
level. See docs/net.md.
"""
from repro.net.protocol import (
    PROTOCOL_VERSION,
    ErrorFrame,
    PredictRequest,
    PredictResponse,
    ProtocolError,
    decode_frame,
)

__all__ = [
    "PROTOCOL_VERSION",
    "ErrorFrame",
    "PredictRequest",
    "PredictResponse",
    "ProtocolError",
    "decode_frame",
]
