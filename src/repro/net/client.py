"""Sync + async clients for the HTTP front door.

Small on purpose — the wire is msgpack frames (``repro.net.protocol``),
so a client is: one reusable connection, a bounded retry loop, a
per-request deadline. Both clients implement the same contract:

  * connection reuse: one persistent HTTP/1.1 connection per client,
    transparently reopened when the server closes it (``NetConfig
    .keepalive=False`` servers cost a reconnect per request — exactly
    the difference ``bench_net`` can measure);
  * bounded retries with jitter on 429 (shed) and 503 (engine broken),
    honoring the server's Retry-After: the wait is
    max(server hint, exponential backoff) +/- jitter, and the hint is
    read from the typed error frame's ``retry_after_ms`` (finer than
    the integer-second header) when present. 4xx that will never
    succeed (413 oversized, 400 bad-request) are NOT retried;
  * a per-request ``deadline_s`` spanning all attempts: when the next
    wait (or the next read) would cross it, the client raises
    :class:`DeadlineExceeded` rather than sleeping past it.

Failures are typed: :class:`ServerError` carries the decoded
:class:`~repro.net.protocol.ErrorFrame` (so callers branch on
``err.frame.code``, not on message strings), :class:`DeadlineExceeded`
and :class:`RetriesExhausted` say which budget ran out.

    with NetClient("127.0.0.1", port) as c:
        resp = c.predict(points, deadline_s=2.0)
        mean, var = resp.mean(), resp.var()

    async with AsyncNetClient("127.0.0.1", port) as c:
        resp = await c.predict(points)
"""
from __future__ import annotations

import asyncio
import dataclasses
import http.client
import json
import random
import socket
import time

import numpy as np

from repro.net import protocol


class NetClientError(Exception):
    """Base of every failure this module raises."""


class ServerError(NetClientError):
    """The server answered with a typed error frame that is not (or no
    longer) retryable. ``frame.code`` is the machine-readable reason."""

    def __init__(self, status: int, frame: protocol.ErrorFrame):
        super().__init__(f"HTTP {status} [{frame.code}]: {frame.message}")
        self.status = status
        self.frame = frame


class RetriesExhausted(ServerError):
    """Every attempt drew a retryable answer (429/503) and the attempt
    budget ran out; carries the LAST error frame."""


class DeadlineExceeded(NetClientError):
    """The per-request deadline would be (or was) crossed — by a read
    still in flight, or by a backoff wait longer than the time left."""


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """The bounded-retry schedule both clients share.

    Attempt k (0-based) that draws a retryable status waits
    ``max(server hint, base_backoff_ms * 2**k)`` capped at
    ``max_backoff_ms``, then multiplied by a uniform jitter in
    [1 - jitter, 1 + jitter] — jitter is what keeps a synchronized
    client herd from re-arriving as one burst (the exact traffic shape
    admission control just shed).
    """

    max_attempts: int = 4
    base_backoff_ms: float = 25.0
    max_backoff_ms: float = 2000.0
    jitter: float = 0.5

    def __post_init__(self) -> None:
        if not int(self.max_attempts) >= 1:
            raise ValueError(f"max_attempts must be >= 1, got {self.max_attempts}")
        if not float(self.base_backoff_ms) >= 0:
            raise ValueError(f"base_backoff_ms must be >= 0, got {self.base_backoff_ms}")
        if not float(self.max_backoff_ms) >= float(self.base_backoff_ms):
            raise ValueError(
                f"max_backoff_ms must be >= base_backoff_ms, got {self.max_backoff_ms}"
            )
        if not 0.0 <= float(self.jitter) < 1.0:
            raise ValueError(f"jitter must be in [0, 1), got {self.jitter}")

    def delay_s(self, attempt: int, hint_ms: float | None, rng: random.Random) -> float:
        backoff = min(self.base_backoff_ms * 2.0**attempt, self.max_backoff_ms)
        wait = max(backoff, 0.0 if hint_ms is None else hint_ms)
        return wait * (1.0 + self.jitter * (2.0 * rng.random() - 1.0)) / 1e3


_RETRYABLE = (429, 503)


def _retry_hint_ms(frame: protocol.ErrorFrame | None, headers: dict) -> float | None:
    """The server's wait hint: the frame's retry_after_ms when present,
    else the integer-second Retry-After header."""
    if frame is not None and frame.retry_after_ms is not None:
        return frame.retry_after_ms
    ra = headers.get("retry-after")
    if ra is not None:
        try:
            return float(ra) * 1e3
        except ValueError:
            return None
    return None


def _finish_predict(
    status: int, headers: dict, body: bytes, request_id: str
) -> tuple[protocol.PredictResponse, None] | tuple[None, tuple]:
    """Shared terminal logic of one predict attempt: returns
    (response, None) on success, (None, (hint_ms, last_err)) when the
    attempt is retryable, and raises ServerError when it never will be."""
    frame = protocol.decode_frame(body)
    if status == 200:
        if not isinstance(frame, protocol.PredictResponse):
            raise protocol.ProtocolError(
                f"200 response carried a {type(frame).__name__} frame"
            )
        if frame.request_id != request_id:
            raise protocol.ProtocolError(
                f"response for request {frame.request_id!r}, expected {request_id!r}"
            )
        return frame, None
    if not isinstance(frame, protocol.ErrorFrame):
        raise protocol.ProtocolError(
            f"HTTP {status} carried a {type(frame).__name__} frame, expected error"
        )
    if status in _RETRYABLE:
        return None, (_retry_hint_ms(frame, headers), ServerError(status, frame))
    raise ServerError(status, frame)


def _parse_status(line: bytes) -> int:
    parts = line.decode("latin-1").split(None, 2)
    if len(parts) < 2 or not parts[1].isdigit():
        raise protocol.ProtocolError(f"malformed HTTP status line {line!r}")
    return int(parts[1])


class NetClient:
    """Blocking client on ``http.client`` with one reusable connection."""

    def __init__(
        self,
        host: str,
        port: int,
        *,
        retry: RetryPolicy | None = None,
        timeout_s: float = 30.0,
        seed: int | None = None,
    ):
        self.host, self.port = host, int(port)
        self.retry = RetryPolicy() if retry is None else retry
        self.timeout_s = float(timeout_s)
        self._rng = random.Random(seed)
        self._conn: http.client.HTTPConnection | None = None
        self._count = 0

    def close(self) -> None:
        if self._conn is not None:
            self._conn.close()
            self._conn = None

    def __enter__(self) -> "NetClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _request(
        self, method: str, path: str, body: bytes | None, remaining: float
    ) -> tuple[int, dict, bytes]:
        """One HTTP round trip on the persistent connection, reopened on
        a server-side close. Raises DeadlineExceeded on timeout."""
        if remaining <= 0:
            raise DeadlineExceeded(f"deadline crossed before sending {path}")
        if self._conn is None:
            self._conn = http.client.HTTPConnection(
                self.host, self.port, timeout=min(self.timeout_s, remaining)
            )
        elif self._conn.sock is not None:
            self._conn.sock.settimeout(min(self.timeout_s, remaining))
        headers = {"Content-Type": "application/msgpack"} if body else {}
        try:
            self._conn.request(method, path, body=body, headers=headers)
            resp = self._conn.getresponse()
            data = resp.read()
        except (TimeoutError, socket.timeout) as err:
            self.close()
            raise DeadlineExceeded(f"{path} timed out after {remaining:.3f}s") from err
        except (ConnectionError, http.client.HTTPException, OSError):
            self.close()
            raise
        if resp.will_close:
            self.close()
        return resp.status, {k.lower(): v for k, v in resp.getheaders()}, data

    def predict(
        self,
        points,
        *,
        request_id: str | None = None,
        deadline_s: float | None = None,
    ) -> protocol.PredictResponse:
        """POST one predict request; retry 429/503 within the deadline."""
        if request_id is None:
            self._count += 1
            request_id = f"c{self._count}"
        body = protocol.PredictRequest.from_points(request_id, points).encode()
        t_end = time.monotonic() + (self.timeout_s if deadline_s is None else deadline_s)
        last: ServerError | None = None
        for attempt in range(self.retry.max_attempts):
            try:
                status, headers, data = self._request(
                    "POST", "/predict", body, t_end - time.monotonic()
                )
            except (ConnectionError, http.client.HTTPException, OSError):
                if attempt + 1 >= self.retry.max_attempts:
                    raise
                self._sleep(attempt, None, t_end)
                continue
            resp, retryable = _finish_predict(status, headers, data, request_id)
            if resp is not None:
                return resp
            hint, last = retryable
            if attempt + 1 < self.retry.max_attempts:
                self._sleep(attempt, hint, t_end)
        raise RetriesExhausted(last.status, last.frame)

    def _sleep(self, attempt: int, hint_ms: float | None, t_end: float) -> None:
        delay = self.retry.delay_s(attempt, hint_ms, self._rng)
        if time.monotonic() + delay > t_end:
            raise DeadlineExceeded(
                f"retry backoff of {delay * 1e3:.0f} ms would cross the deadline"
            )
        time.sleep(delay)

    def healthz(self) -> tuple[int, dict]:
        status, _, data = self._request("GET", "/healthz", None, self.timeout_s)
        return status, json.loads(data)

    def slo(self) -> dict:
        status, _, data = self._request("GET", "/slo", None, self.timeout_s)
        if status != 200:
            raise NetClientError(f"GET /slo answered HTTP {status}")
        return json.loads(data)


class AsyncNetClient:
    """asyncio client on a persistent stream pair — the open-loop load
    generator of ``bench_net`` (many of these, one per simulated user)."""

    def __init__(
        self,
        host: str,
        port: int,
        *,
        retry: RetryPolicy | None = None,
        timeout_s: float = 30.0,
        seed: int | None = None,
    ):
        self.host, self.port = host, int(port)
        self.retry = RetryPolicy() if retry is None else retry
        self.timeout_s = float(timeout_s)
        self._rng = random.Random(seed)
        self._reader: asyncio.StreamReader | None = None
        self._writer: asyncio.StreamWriter | None = None
        self._count = 0

    async def close(self) -> None:
        if self._writer is not None:
            self._writer.close()
            try:
                await self._writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass
            self._reader = self._writer = None

    async def __aenter__(self) -> "AsyncNetClient":
        return self

    async def __aexit__(self, *exc) -> None:
        await self.close()

    async def _roundtrip(
        self, method: str, path: str, body: bytes | None, remaining: float
    ) -> tuple[int, dict, bytes]:
        if remaining <= 0:
            raise DeadlineExceeded(f"deadline crossed before sending {path}")
        try:
            return await asyncio.wait_for(
                self._roundtrip_inner(method, path, body),
                min(self.timeout_s, remaining),
            )
        except (TimeoutError, asyncio.TimeoutError) as err:
            await self.close()
            raise DeadlineExceeded(f"{path} timed out after {remaining:.3f}s") from err
        except (ConnectionError, asyncio.IncompleteReadError, OSError):
            await self.close()
            raise

    async def _roundtrip_inner(
        self, method: str, path: str, body: bytes | None
    ) -> tuple[int, dict, bytes]:
        if self._writer is None:
            self._reader, self._writer = await asyncio.open_connection(
                self.host, self.port
            )
        head = f"{method} {path} HTTP/1.1\r\nHost: {self.host}\r\n"
        if body is not None:
            head += f"Content-Type: application/msgpack\r\nContent-Length: {len(body)}\r\n"
        self._writer.write(head.encode("latin-1") + b"\r\n" + (body or b""))
        await self._writer.drain()
        status_line = await self._reader.readline()
        if not status_line:
            raise ConnectionResetError("server closed the connection")
        status = _parse_status(status_line)
        headers: dict = {}
        while True:
            line = await self._reader.readline()
            if line in (b"\r\n", b"\n", b""):
                break
            k, _, v = line.decode("latin-1").partition(":")
            headers[k.strip().lower()] = v.strip()
        data = await self._reader.readexactly(int(headers.get("content-length", "0")))
        if headers.get("connection", "") == "close":
            await self.close()
        return status, headers, data

    async def predict(
        self,
        points,
        *,
        request_id: str | None = None,
        deadline_s: float | None = None,
    ) -> protocol.PredictResponse:
        """Async twin of :meth:`NetClient.predict` — same retry/deadline
        contract, non-blocking waits."""
        if request_id is None:
            self._count += 1
            request_id = f"a{self._count}"
        body = protocol.PredictRequest.from_points(request_id, points).encode()
        loop = asyncio.get_running_loop()
        t_end = loop.time() + (self.timeout_s if deadline_s is None else deadline_s)
        last: ServerError | None = None
        for attempt in range(self.retry.max_attempts):
            try:
                status, headers, data = await self._roundtrip(
                    "POST", "/predict", body, t_end - loop.time()
                )
            except (ConnectionError, asyncio.IncompleteReadError, OSError):
                if attempt + 1 >= self.retry.max_attempts:
                    raise
                await self._wait(attempt, None, t_end)
                continue
            resp, retryable = _finish_predict(status, headers, data, request_id)
            if resp is not None:
                return resp
            hint, last = retryable
            if attempt + 1 < self.retry.max_attempts:
                await self._wait(attempt, hint, t_end)
        raise RetriesExhausted(last.status, last.frame)

    async def _wait(self, attempt: int, hint_ms: float | None, t_end: float) -> None:
        delay = self.retry.delay_s(attempt, hint_ms, self._rng)
        if asyncio.get_running_loop().time() + delay > t_end:
            raise DeadlineExceeded(
                f"retry backoff of {delay * 1e3:.0f} ms would cross the deadline"
            )
        await asyncio.sleep(delay)

    async def healthz(self) -> tuple[int, dict]:
        status, _, data = await self._roundtrip("GET", "/healthz", None, self.timeout_s)
        return status, json.loads(data)

    async def slo(self) -> dict:
        status, _, data = await self._roundtrip("GET", "/slo", None, self.timeout_s)
        if status != 200:
            raise NetClientError(f"GET /slo answered HTTP {status}")
        return json.loads(data)


def predict_points(resp: protocol.PredictResponse) -> tuple[np.ndarray, np.ndarray]:
    """(mean, var) numpy pair of a response — the shape ``FrontDoor
    .submit`` returns, for callers comparing the two paths bitwise."""
    return resp.mean(), resp.var()
