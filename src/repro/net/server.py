"""The asyncio HTTP/1.1 endpoint over ``FrontDoor.submit``.

A deliberately thin adapter: the coalesce/demux/backpressure engine
(``repro.api.frontdoor``) is transport-agnostic and unchanged — this
module only moves msgpack frames (``repro.net.protocol``) across
sockets and maps the engine's typed outcomes onto HTTP statuses:

    POST /predict   one PredictRequest frame in, one PredictResponse
                    (or typed ErrorFrame) out:
                      RequestTooLarge -> 413 "oversized"
                      RequestRejected -> 429 "shed" + Retry-After
                      engine broken   -> 503 "engine-broken" + Retry-After
                      ProtocolError / bad points -> 400 "bad-request"
                      anything else   -> 500 "internal"
    GET  /healthz   JSON liveness: ok (200) or broken (503)
    GET  /slo       JSON ``FrontDoor.report()`` + the transport counters

The server is hand-rolled on ``asyncio.start_server`` (stdlib only —
no framework between the measurement and the engine, and the accept/
read loops stay in reach of the asynclint RR005-RR008 passes; see
``analysis.asynclint.CONFINEMENT`` for the NetServer entry). HTTP/1.1
persistent connections per ``NetConfig.keepalive``; per-read deadline
``read_timeout_s``; a body over ``max_body_bytes`` is refused with 413
before it is read.

Entry points (the bind address comes from the session file's ``net``
section — parsed stdlib-only, BEFORE jax initializes — or NetConfig
defaults):

  PYTHONPATH=src python -m repro.net.server --gp-grid 3 --gp-m 5
  PYTHONPATH=src python -m repro.net.server --config session.json
  PYTHONPATH=src python -m repro.launch.serve --gp --http
"""
from __future__ import annotations

import argparse
import asyncio
import json
import math
import time

from repro.net import protocol

_REASONS = {
    200: "OK",
    400: "Bad Request",
    404: "Not Found",
    405: "Method Not Allowed",
    408: "Request Timeout",
    413: "Payload Too Large",
    429: "Too Many Requests",
    500: "Internal Server Error",
    503: "Service Unavailable",
}
_MSGPACK = "application/msgpack"
_JSON = "application/json"
_MAX_HEADERS = 64

# frame-level retry hints (the Retry-After header is the integer-second
# ceiling of these; the client prefers the finer frame value)
SHED_RETRY_MS = 50.0
BROKEN_RETRY_MS = 1000.0


class _HttpError(Exception):
    """An HTTP-level failure decided before the engine was consulted.
    ``keep`` is False when the connection state is unrecoverable (e.g.
    an unread oversized body still sitting in the socket)."""

    def __init__(self, frame: protocol.ErrorFrame, *, keep: bool = True):
        super().__init__(frame.message)
        self.frame = frame
        self.keep = keep


class NetServer:
    """One listening socket in front of one ``api.Server``.

    Owns a private ``api.FrontDoor`` (created on :meth:`start`, closed
    on :meth:`close`) so every HTTP request rides the same continuous-
    batching engine the in-process benchmarks measure — the wire adds
    transport, never a second batching policy. All mutable state
    (transport counters) is event-loop-confined: connection handlers
    are loop tasks and the server never hands a method to a thread.

    Usage::

        async with NetServer(server, net_cfg) as ns:
            print(ns.port)          # bound port (net_cfg.port 0 -> OS pick)
            await ns.serve_forever()
    """

    def __init__(self, server, net=None, frontdoor=None):
        from repro import api

        self.server = server
        self.net = api.NetConfig() if net is None else net
        self.frontdoor_config = frontdoor  # None -> FrontDoor's default
        self.port: int | None = None
        self._fd = None
        self._listener: asyncio.Server | None = None
        # transport counters, loop-confined (asynclint CONFINEMENT entry)
        self._http_requests = 0
        self._http_errors = dict.fromkeys(protocol.ERROR_CODES, 0)

    # -- lifecycle ---------------------------------------------------------

    async def start(self) -> None:
        from repro import api

        self._fd = api.FrontDoor(self.server, self.frontdoor_config)
        await self._fd.__aenter__()
        self._listener = await asyncio.start_server(
            self._handle_conn, self.net.host, self.net.port
        )
        self.port = self._listener.sockets[0].getsockname()[1]

    async def close(self) -> None:
        if self._listener is not None:
            self._listener.close()
            await self._listener.wait_closed()
        if self._fd is not None:
            await self._fd.close()

    async def __aenter__(self) -> "NetServer":
        await self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.close()

    async def serve_forever(self) -> None:
        await self._listener.serve_forever()

    # -- connection handling ----------------------------------------------

    async def _handle_conn(self, reader, writer) -> None:
        """One task per accepted connection: serve requests until the
        client goes away, keepalive is off, or a read deadline expires.
        Transport errors end the connection, never the server."""
        try:
            while await self._handle_one(reader, writer):
                pass
        except (
            asyncio.IncompleteReadError,
            ConnectionResetError,
            BrokenPipeError,
            TimeoutError,
            asyncio.TimeoutError,
        ):
            pass  # half-closed or idle-timed-out connection: just drop it
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass

    async def _handle_one(self, reader, writer) -> bool:
        """Serve one HTTP request; returns True to keep the connection."""
        line = await asyncio.wait_for(
            reader.readline(), self.net.read_timeout_s
        )
        if not line:
            return False  # clean EOF between requests
        # clock starts once the request line is in hand: on a keepalive
        # connection the readline above blocks across inter-request idle
        # time, which is the client's think time, not server work
        t0 = time.perf_counter()
        try:
            method, path, _version = line.decode("latin-1").split()
        except ValueError:
            body = json.dumps({"error": "malformed request line"}).encode()
            await self._send(writer, 400, body, _JSON, False)
            return False
        headers = await self._read_headers(reader)
        if headers is None:
            body = json.dumps({"error": "malformed headers"}).encode()
            await self._send(writer, 400, body, _JSON, False)
            return False
        keep = self.net.keepalive and headers.get("connection", "") != "close"
        self._http_requests += 1

        if path == "/healthz" and method == "GET":
            return await self._healthz(writer, keep)
        if path == "/slo" and method == "GET":
            body = json.dumps(self.slo(), sort_keys=True).encode()
            return await self._send(writer, 200, body, _JSON, keep)
        if path != "/predict":
            body = json.dumps({"error": f"unknown path {path}"}).encode()
            return await self._send(writer, 404, body, _JSON, keep)
        if method != "POST":
            body = json.dumps({"error": "POST only"}).encode()
            return await self._send(writer, 405, body, _JSON, keep)

        try:
            body = await self._read_body(reader, headers)
            frame = await self._predict(body, t0)
            status = 200
        except _HttpError as err:
            frame, status, keep = err.frame, err.frame.status, keep and err.keep
            self._http_errors[err.frame.code] += 1
        retry = frame.retry_after_ms if isinstance(frame, protocol.ErrorFrame) else None
        return await self._send(
            writer, status, frame.encode(), _MSGPACK, keep, retry_after_ms=retry
        )

    async def _read_headers(self, reader) -> dict | None:
        headers: dict = {}
        for _ in range(_MAX_HEADERS):
            line = await asyncio.wait_for(
                reader.readline(), self.net.read_timeout_s
            )
            if line in (b"\r\n", b"\n"):
                return headers
            if not line.endswith(b"\n") or b":" not in line:
                return None
            k, _, v = line.decode("latin-1").partition(":")
            headers[k.strip().lower()] = v.strip()
        return None  # header section too long

    async def _read_body(self, reader, headers: dict) -> bytes:
        try:
            n = int(headers.get("content-length", ""))
        except ValueError:
            raise _HttpError(
                protocol.ErrorFrame(
                    "", "bad-request", "POST /predict needs a Content-Length body"
                ),
                keep=False,  # an un-lengthed body cannot be drained safely
            ) from None
        if n > self.net.max_body_bytes:
            # refused BEFORE reading: the cap is what protects the server
            # from buffering an arbitrarily large body
            raise _HttpError(
                protocol.ErrorFrame(
                    "",
                    "oversized",
                    f"body of {n} bytes exceeds NetConfig.max_body_bytes="
                    f"{self.net.max_body_bytes}",
                ),
                keep=False,  # the unread body still sits in the socket
            )
        return await asyncio.wait_for(
            reader.readexactly(n), self.net.read_timeout_s
        )

    async def _predict(self, body: bytes, t0: float) -> protocol.PredictResponse:
        """Decode -> ``FrontDoor.submit`` -> encode, translating every
        engine outcome into its typed error frame."""
        try:
            frame = protocol.decode_frame(body)
            if not isinstance(frame, protocol.PredictRequest):
                raise protocol.ProtocolError(
                    f"POST /predict takes a predict_request frame, got "
                    f"{type(frame).__name__}"
                )
            pts = frame.points()
        except protocol.ProtocolError as err:
            raise _HttpError(
                protocol.ErrorFrame("", "bad-request", str(err))
            ) from err
        t1 = time.perf_counter()
        try:
            mean, var = await self._fd.submit(pts)
        except Exception as err:
            raise self._engine_error(frame.request_id, err) from err
        t2 = time.perf_counter()
        return protocol.PredictResponse.from_arrays(
            frame.request_id,
            mean,
            var,
            server_version=int(self.server.lifecycle()["active_version"]),
            timing_ms=(
                (t1 - t0) * 1e3,
                (t2 - t1) * 1e3,
                (time.perf_counter() - t0) * 1e3,
            ),
        )

    def _engine_error(self, request_id: str, err: Exception) -> _HttpError:
        """The status-code contract: every ``FrontDoor.submit`` outcome
        maps onto exactly one typed error code (docs/net.md table)."""
        from repro import api

        if isinstance(err, api.RequestTooLarge):
            code, retry = "oversized", None
        elif isinstance(err, api.RequestRejected):
            code, retry = "shed", SHED_RETRY_MS
        elif isinstance(err, RuntimeError):
            # engine failed / front door closed: retriable server trouble
            code, retry = "engine-broken", BROKEN_RETRY_MS
        elif isinstance(err, ValueError):
            code, retry = "bad-request", None
        else:
            code, retry = "internal", None
        return _HttpError(
            protocol.ErrorFrame(request_id, code, str(err), retry_after_ms=retry)
        )

    async def _healthz(self, writer, keep: bool) -> bool:
        broken = self._fd.broken
        body = json.dumps(
            {
                "status": "broken" if broken else "ok",
                "active_version": self.server.lifecycle()["active_version"],
                "protocol_version": protocol.PROTOCOL_VERSION,
            },
            sort_keys=True,
        ).encode()
        return await self._send(writer, 503 if broken else 200, body, _JSON, keep)

    def slo(self) -> dict:
        """``FrontDoor.report()`` plus the transport's own section."""
        rec = self._fd.report()
        rec["http"] = {
            "requests": self._http_requests,
            "errors": dict(self._http_errors),
            "net_config": self.net.to_dict(),
        }
        return rec

    async def _send(
        self,
        writer,
        status: int,
        body: bytes,
        content_type: str,
        keep: bool,
        *,
        retry_after_ms: float | None = None,
    ) -> bool:
        head = (
            f"HTTP/1.1 {status} {_REASONS[status]}\r\n"
            f"Content-Type: {content_type}\r\n"
            f"Content-Length: {len(body)}\r\n"
            f"Connection: {'keep-alive' if keep else 'close'}\r\n"
        )
        if retry_after_ms is not None:
            head += f"Retry-After: {max(1, math.ceil(retry_after_ms / 1e3))}\r\n"
        writer.write(head.encode("latin-1") + b"\r\n" + body)
        await writer.drain()
        return keep


# --------------------------------------------------------------------------
# CLI driver
# --------------------------------------------------------------------------


def serve_http(args, *, expect_mode: str | None = None) -> None:
    """The shared ``--http`` back half of the serving CLIs: resolve the
    session (fit/serve/net sections), force virtual devices for the
    sharded mode BEFORE any jax work, fit or load the artifact, and run
    the HTTP endpoint until interrupted.

    ``expect_mode`` pins the serve mode the calling CLI promises
    (``serve --gp --http`` -> replicated, ``--sharded`` -> sharded);
    None (the ``python -m repro.net.server`` entry) follows the session
    file's serve section, defaulting to replicated.
    """
    from repro.launch import serve_sharded as ss

    if expect_mode is None:
        expect_mode = "replicated"
        if getattr(args, "config", None):
            from repro.api.config import load_session

            _, s_cfg, _ = load_session(args.config)  # stdlib-only peek
            if s_cfg is not None:
                expect_mode = s_cfg.mode
    fit_cfg, serve_cfg, net_cfg = ss.session_configs(args, expect_mode=expect_mode)
    if net_cfg is None:
        from repro import api

        net_cfg = api.NetConfig()
    if expect_mode == "sharded" and not getattr(args, "gp_artifact", None):
        grid_side = fit_cfg.grid if fit_cfg is not None else args.gp_grid
        ss.ensure_host_devices(grid_side * grid_side)

    from repro import api

    ds, fitted = ss.load_or_train(
        args, ensure_devices=expect_mode == "sharded", fit_cfg=fit_cfg
    )
    del ds  # the endpoint serves live queries, not a synthetic stream
    if serve_cfg is None:
        serve_cfg = api.ServeConfig(
            mode=expect_mode,
            pipeline="pipelined" if expect_mode == "sharded" else "serial",
            router=getattr(args, "gp_router", "single") if expect_mode == "sharded" else "single",
            backend="auto",
        )
    server = api.Server(fitted, serve_cfg)
    try:
        asyncio.run(_run(server, net_cfg))
    except KeyboardInterrupt:
        print("\nshutting down")


async def _run(server, net_cfg) -> None:
    async with NetServer(server, net_cfg) as ns:
        print(
            f"serving {server.config.mode} PSVGP on "
            f"http://{ns.net.host}:{ns.port}  "
            "(POST /predict, GET /healthz, GET /slo; Ctrl-C to stop)"
        )
        await ns.serve_forever()


def main() -> None:
    from repro.launch.serve_sharded import add_gp_args

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=0)
    add_gp_args(ap)
    args = ap.parse_args()
    args.http = True  # this module IS the http entry point
    serve_http(args)


if __name__ == "__main__":
    main()
