"""Pytree checkpointing: npz payload + msgpack treedef manifest.

No orbax/flax in this container, so this is the full implementation:
  * arrays are gathered to host and stored in a single .npz (zip64-capable,
    handles multi-GB checkpoints);
  * the tree structure is serialized as a msgpack manifest of key-paths, so
    restore rebuilds EXACTLY the dict/list/NamedTuple nesting it was given
    a template for (restore requires a like-structured template — the usual
    "init then restore" pattern);
  * per-step directories + a ``latest`` pointer give the train loop
    resumable semantics.

For the PSVGP in-situ use case this is also the paper's "parsimonious
summary": the per-partition inducing-point parameters ARE the model
artifact a simulation would persist (m, S, z, kappa, beta per partition —
a few KB per partition instead of the raw field).
"""
from __future__ import annotations

import os
from typing import Any

import jax
import msgpack
import numpy as np


def _flatten(tree: Any):
    leaves_with_path = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves_with_path:
        key = "/".join(
            str(e.key) if isinstance(e, jax.tree_util.DictKey)
            else (e.name if isinstance(e, jax.tree_util.GetAttrKey) else str(e.idx))
            for e in path
        )
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def save_pytree(path: str, tree: Any) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(path, "arrays.npz"), **flat)
    manifest = {
        "keys": list(flat.keys()),
        "shapes": [list(v.shape) for v in flat.values()],
        "dtypes": [str(v.dtype) for v in flat.values()],
    }
    with open(os.path.join(path, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))


def load_pytree(path: str, template: Any) -> Any:
    """Restore into the structure of ``template`` (shape/dtype-checked)."""
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    if len(flat_t) != len(manifest["keys"]):
        raise ValueError(
            f"checkpoint has {len(manifest['keys'])} leaves, template {len(flat_t)}"
        )
    leaves = []
    for path_t, leaf_t in flat_t:
        key = "/".join(
            str(e.key) if isinstance(e, jax.tree_util.DictKey)
            else (e.name if isinstance(e, jax.tree_util.GetAttrKey) else str(e.idx))
            for e in path_t
        )
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = data[key]
        if tuple(arr.shape) != tuple(np.shape(leaf_t)):
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != template {np.shape(leaf_t)}")
        leaves.append(arr.astype(np.asarray(leaf_t).dtype) if hasattr(leaf_t, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_train_state(ckpt_dir: str, step: int, state: Any) -> str:
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    save_pytree(path, state)
    with open(os.path.join(ckpt_dir, "latest"), "w") as f:
        f.write(os.path.basename(path))
    return path


def latest_step(ckpt_dir: str) -> str | None:
    p = os.path.join(ckpt_dir, "latest")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return os.path.join(ckpt_dir, f.read().strip())


def load_train_state(ckpt_dir: str, template: Any) -> Any | None:
    path = latest_step(ckpt_dir)
    return None if path is None else load_pytree(path, template)
