"""Artifact format=2: an append-only versioned store of serving artifacts.

Format=1 (``api.FittedPSVGP.save``) is one directory = one model. The
in-situ loop produces one model PER SIMULATION STEP, and the paper's
whole premise is that these per-step summaries are small enough to keep
all of them (a few KB per partition per step, versus the raw field). The
store is the on-disk shape of that loop:

    store/
    ├── store.json            the step index: {"format": 2, "steps": [...]}
    ├── step_00000000/        one FULL format=1 artifact per step
    │   ├── artifact.json     (manifest: FitConfig + grid geometry)
    │   ├── arrays.npz
    │   └── manifest.msgpack
    ├── step_00000001/
    │   └── ...
    └── ...

Properties the lifecycle relies on:

  * APPEND-ONLY: a step id can be committed once; re-committing raises.
    Steps need not be contiguous, but must be strictly increasing — the
    index is the simulation's timeline.
  * CRASH-SAFE commits: the step directory is fully written BEFORE the
    index is rewritten (atomically, tmp + ``os.replace``). A crash
    mid-save leaves at worst an orphan step directory the index never
    mentions — every indexed step is complete.
  * PURE-JSON PEEK: this module is stdlib-only, and ``store.json`` +
    each step's ``artifact.json`` are plain JSON — the step index and any
    step's FitConfig are readable before the jax backend initializes
    (the sharded serving path must size its device mesh first; see
    ``api.peek_fit_config``).
  * FORMAT=1 READ-COMPAT: each step directory IS a format=1 artifact, so
    ``FittedPSVGP.load(store/step_00000003)`` works unchanged, and
    format=1 directories keep loading exactly as before.
"""
from __future__ import annotations

import json
import os

STORE_INDEX = "store.json"
STORE_FORMAT = 2


def step_dir_name(step: int) -> str:
    """Directory name of step ``step`` inside a store ("step_00000042")."""
    if int(step) < 0:
        raise ValueError(f"store steps are >= 0, got {step}")
    return f"step_{int(step):08d}"


def is_store(path: str) -> bool:
    """True if ``path`` is a format=2 store (has a ``store.json`` index)."""
    return os.path.isfile(os.path.join(path, STORE_INDEX))


def read_index(path: str) -> dict:
    """The raw store index: ``{"format": 2, "steps": [{"step", "dir", ...}]}``.

    Pure stdlib — no jax anywhere on this path. Raises on a missing index
    or a format this build does not read.
    """
    with open(os.path.join(path, STORE_INDEX)) as f:
        index = json.load(f)
    if index.get("format") != STORE_FORMAT:
        raise ValueError(
            f"store at {path!r} has format {index.get('format')!r}; "
            f"this build reads format {STORE_FORMAT}"
        )
    return index


def store_steps(path: str) -> list[int]:
    """The committed step ids, in commit (= ascending) order."""
    return [int(e["step"]) for e in read_index(path)["steps"]]


def step_dir(path: str, step: int | None = None) -> str:
    """Absolute directory of ``step`` (latest committed step when None) —
    a format=1 artifact directory, loadable on its own."""
    entries = read_index(path)["steps"]
    if not entries:
        raise ValueError(f"store at {path!r} has no committed steps")
    if step is None:
        entry = entries[-1]
    else:
        by_id = {int(e["step"]): e for e in entries}
        if int(step) not in by_id:
            raise KeyError(
                f"store at {path!r} has no step {step}; "
                f"committed steps: {sorted(by_id)}"
            )
        entry = by_id[int(step)]
    return os.path.join(path, entry["dir"])


def commit_step(path: str, step: int, dirname: str, meta: dict | None = None) -> None:
    """Append ``step`` -> ``dirname`` to the store index, atomically.

    The caller must have FINISHED writing the step directory first — the
    index rewrite (tmp file + ``os.replace``) is the commit point, so a
    crash before it leaves only an unindexed orphan directory. Appending
    an already-committed step, or a step id not greater than the newest
    committed one, raises (the store is append-only, strictly increasing).
    ``meta`` (plain-JSON observability: refit wall-clock, fit metrics,
    ...) is merged into the step's index entry.
    """
    os.makedirs(path, exist_ok=True)
    index_path = os.path.join(path, STORE_INDEX)
    if os.path.exists(index_path):
        index = read_index(path)
    else:
        index = {"format": STORE_FORMAT, "steps": []}
    steps = [int(e["step"]) for e in index["steps"]]
    if int(step) in steps:
        raise ValueError(
            f"step {step} is already committed in the store at {path!r} — "
            "the store is append-only; each simulation step commits once"
        )
    if steps and int(step) <= max(steps):
        raise ValueError(
            f"step {step} is older than the newest committed step "
            f"{max(steps)} — the store index is the simulation timeline "
            "and only moves forward"
        )
    entry = {"step": int(step), "dir": dirname}
    if meta:
        clash = set(meta) & set(entry)
        if clash:
            raise ValueError(f"step meta may not override index keys {sorted(clash)}")
        entry.update(json.loads(json.dumps(meta)))  # plain-JSON values only
    index["steps"].append(entry)
    tmp = index_path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(index, f, indent=2)
        f.write("\n")
    os.replace(tmp, index_path)
