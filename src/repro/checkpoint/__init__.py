from repro.checkpoint.checkpoint import load_pytree, save_pytree, latest_step, save_train_state, load_train_state
from repro.checkpoint import store

__all__ = ["save_pytree", "load_pytree", "latest_step", "save_train_state", "load_train_state", "store"]
