"""Logical-axis sharding rules with divisibility fallback (MaxText-style).

Strategy on the production mesh (pod, data, model):
  * batch dims            -> (pod, data) combined
  * tensor-parallel dims  -> model (FFN hidden, head products, vocab,
                             MoE expert axis, recurrent width)
  * any dim not divisible by its mesh-axis size falls back to REPLICATED
    for that axis — this is what lets qwen2's 14 heads or whisper's 8 heads
    lower cleanly on a 16-wide model axis while its FFN/vocab still shard
    (recorded per-arch in EXPERIMENTS.md §Dry-run).

Rules are keyed on parameter-tree path names, so they cover every block
kind in repro.models without per-arch tables. Stacked leaves (period scan)
carry a leading period axis which is never sharded.
"""
from __future__ import annotations

from collections.abc import Sequence
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.optim import AdamState

# name -> per-dim logical axes, innermost dims rightmost. "model" marks the
# tensor-parallel dim; None replicates. Entries match the TRAILING dims of
# the leaf (leading stack/period axes are implicitly None).
_RULES = {
    # embeddings / head
    "embed": ("model", None),  # vocab-parallel
    "pos_embed": (None, None),
    "enc_pos": (None, None),
    "lm_head": (None, "model"),
    # attention projections
    "wq": (None, "model"),
    "wk": (None, "model"),
    "wv": (None, "model"),
    "wo": ("model", None),
    "bq": ("model",),
    "bk": ("model",),
    "bv": ("model",),
    # MLA
    "w_dq": (None, None),
    "w_uq": (None, "model"),
    "w_dkv": (None, None),
    "w_kr": (None, None),
    "w_uk": (None, "model"),
    "w_uv": (None, "model"),
    # MLP (2D) — MoE expert weights (3D) handled by ndim dispatch below
    "w_gate": (None, "model"),
    "w_up": (None, "model"),
    "w_down": ("model", None),
    "b_up": ("model",),
    "b_down": (None,),
    "router": (None, None),
    # recurrent blocks
    "w_a": (None, "model"),
    "w_b": (None, "model"),
    "conv": (None, "model"),
    "w_r": ("model", None),
    "w_i": ("model", None),
    "w_out": ("model", None),
    "w_if": ("model", None),
    "w_in": (None, None),
    "r": (None, None),
    "b": (None,),
    "b_if": (None,),
    "out_norm": ("model",),
    # projector (VLM)
    "w1": (None, None),
    "w2": (None, None),
}

_MOE_RULES = {  # 3D expert-stacked weights: expert-parallel on model axis
    "w_gate": ("model", None, None),
    "w_up": ("model", None, None),
    "w_down": ("model", None, None),
}


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    """Mesh axes used for batch sharding (everything except 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def _axis_ok(dim: int, axis: str | None, mesh: Mesh) -> str | None:
    if axis is None:
        return None
    size = int(np.prod([mesh.shape[a] for a in (axis if isinstance(axis, tuple) else (axis,))]))
    return axis if dim % size == 0 else None


def _spec_for(path_names: Sequence[str], leaf, mesh: Mesh, fsdp: bool = False) -> P:
    name = path_names[-1] if path_names else ""
    in_moe = "moe" in path_names
    rule = None
    if in_moe and leaf.ndim >= 3 and name in _MOE_RULES:
        rule = _MOE_RULES[name]
    elif name in _RULES:
        rule = _RULES[name]
    if rule is None:
        return P()  # norms, scalars, anything unnamed: replicate
    nlead = leaf.ndim - len(rule)
    if nlead < 0:
        return P()
    dims = leaf.shape[nlead:]
    axes = list(_axis_ok(d, a, mesh) for d, a in zip(dims, rule, strict=True))
    if fsdp:
        # ZeRO-3 style: additionally shard the first replicated dim of every
        # weight over the (pod, data) axes. XLA inserts the weight
        # all-gather before use and the reduce-scatter on the grad — the
        # classic memory <-> collective trade (EXPERIMENTS.md §Perf).
        daxes = data_axes(mesh)
        for i, (d, a) in enumerate(zip(dims, axes, strict=True)):
            if a is None and _axis_ok(d, daxes, mesh) is not None:
                axes[i] = daxes
                break
    return P(*((None,) * nlead + tuple(axes)))


def _path_names(path) -> tuple[str, ...]:
    names = []
    for e in path:
        if isinstance(e, jax.tree_util.DictKey):
            names.append(str(e.key))
        elif isinstance(e, jax.tree_util.GetAttrKey):
            names.append(e.name)
        elif isinstance(e, jax.tree_util.SequenceKey):
            names.append(str(e.idx))
    return tuple(names)


def params_pspecs(params: Any, mesh: Mesh, fsdp: bool = False) -> Any:
    """PartitionSpec pytree matching ``params``."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for(_path_names(path), leaf, mesh, fsdp), params
    )


def state_pspecs(state, mesh: Mesh, fsdp: bool = False):
    """Specs for a TrainState/PSVGPState-like (params, AdamState, step).
    With fsdp=True the optimizer moments shard with the params (ZeRO)."""
    pspec = params_pspecs(state.params, mesh, fsdp)
    return type(state)(
        params=pspec,
        opt=AdamState(step=P(), mu=pspec, nu=pspec),
        step=P(),
    )


def batch_pspec(mesh: Mesh, batch_shardable: bool = True) -> P:
    """Spec for (B, S) token arrays: batch over (pod, data)."""
    return P(data_axes(mesh)) if batch_shardable else P()


def gp_stacked_pspecs(tree: Any, mesh: Mesh) -> Any:
    """Specs for P-stacked GP serving pytrees: shard the leading partition
    axis over ALL mesh axes (one partition per device).

    Used for the ``repro.core.posterior.PosteriorCache`` (each device holds
    exactly its own partition's factors — per-device cache memory is 1/P of
    the replicated footprint) and for the routed query blocks of
    ``repro.core.routing.RoutingTable``. The leading axis of every leaf
    must equal ``mesh.size`` (the grid-to-mesh mapping of
    ``repro.core.psvgp_spmd``: partition iy*gx+ix on device (row=iy,
    col=ix)); anything else is a routing bug, so this raises instead of
    falling back to replication.
    """
    lead = P(tuple(mesh.axis_names))

    def spec(leaf):
        if leaf.ndim < 1 or leaf.shape[0] != mesh.size:
            raise ValueError(
                f"GP-stacked leaf {leaf.shape} does not carry a leading "
                f"partition axis of size mesh.size={mesh.size}"
            )
        return lead

    return jax.tree.map(spec, tree)


def cache_pspecs(cache: Any, mesh: Mesh, *, shard_seq: bool) -> Any:
    """Decode-cache specs.

    Default (decode_32k): batch dim over (pod,data), heads/width over model.
    shard_seq (long_500k, batch=1): the SEQUENCE dim of attention caches is
    sharded over (pod,data) instead — sequence-parallel KV.
    """
    daxes = data_axes(mesh)

    def spec(path, leaf):
        names = _path_names(path)
        name = names[-1]
        nlead = 1 if "stack" in names else 0  # stacked period axis
        if name in ("k", "v", "cross_k", "cross_v"):  # (B, S, KV, hd)
            kv = leaf.shape[nlead + 2]
            head_ax = "model" if kv % mesh.shape["model"] == 0 else None
            if shard_seq:
                s = leaf.shape[nlead + 1]
                seq_ok = s % int(np.prod([mesh.shape[a] for a in daxes])) == 0
                return P(*((None,) * nlead), None, daxes if seq_ok else None, head_ax, None)
            b = leaf.shape[nlead]
            b_ok = b % int(np.prod([mesh.shape[a] for a in daxes])) == 0
            return P(*((None,) * nlead), daxes if b_ok else None, None, head_ax, None)
        if name in ("c_kv", "k_rope"):  # (B, S, r) MLA latents
            if shard_seq:
                s = leaf.shape[nlead + 1]
                seq_ok = s % int(np.prod([mesh.shape[a] for a in daxes])) == 0
                return P(*((None,) * nlead), None, daxes if seq_ok else None, None)
            b = leaf.shape[nlead]
            b_ok = b % int(np.prod([mesh.shape[a] for a in daxes])) == 0
            return P(*((None,) * nlead), daxes if b_ok else None, None, None)
        if name in ("conv", "h", "state", "norm", "c", "n", "m"):
            # recurrent states: last dim is width/heads -> model if divisible
            last = leaf.shape[-1]
            ax = "model" if last % mesh.shape["model"] == 0 else None
            return P(*((None,) * (leaf.ndim - 1)), ax)
        return P()

    return jax.tree_util.tree_map_with_path(spec, cache)
