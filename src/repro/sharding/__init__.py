from repro.sharding.rules import (
    batch_pspec,
    cache_pspecs,
    data_axes,
    gp_stacked_pspecs,
    params_pspecs,
    state_pspecs,
)

__all__ = ["params_pspecs", "state_pspecs", "batch_pspec", "cache_pspecs", "data_axes", "gp_stacked_pspecs"]
