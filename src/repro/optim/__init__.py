from repro.optim.adam import (
    AdamState,
    adam_init,
    adam_update,
    adamw_update,
    clip_by_global_norm,
    global_norm,
)
from repro.optim.schedule import constant_schedule, cosine_schedule, linear_warmup_cosine

__all__ = [
    "AdamState",
    "adam_init",
    "adam_update",
    "adamw_update",
    "clip_by_global_norm",
    "global_norm",
    "constant_schedule",
    "cosine_schedule",
    "linear_warmup_cosine",
]
