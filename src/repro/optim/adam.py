"""Adam / AdamW on arbitrary pytrees (no optax dependency).

The paper optimizes the variational parameters phi_j with Adam (Kingma & Ba
2014); the LM substrate uses AdamW. State is a pytree-of-pytrees so it vmaps
over the PSVGP partition axis and shards over the mesh exactly like params.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamState(NamedTuple):
    step: jnp.ndarray  # () int32
    mu: PyTree  # first moment
    nu: PyTree  # second moment


def adam_init(params: PyTree) -> AdamState:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return AdamState(step=jnp.zeros((), jnp.int32), mu=zeros, nu=jax.tree.map(jnp.zeros_like, params))


def _moments(grads: PyTree, state: AdamState, b1: float, b2: float):
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * (g * g), state.nu, grads)
    return mu, nu


def adam_update(
    params: PyTree,
    grads: PyTree,
    state: AdamState,
    lr: float | jnp.ndarray = 1e-3,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
) -> tuple[PyTree, AdamState]:
    """One Adam step minimizing the loss whose gradient is ``grads``."""
    step = state.step + 1
    mu, nu = _moments(grads, state, b1, b2)
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1**t
    bc2 = 1.0 - b2**t

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        return p - lr * mhat / (jnp.sqrt(vhat) + eps)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamState(step=step, mu=mu, nu=nu)


def adamw_update(
    params: PyTree,
    grads: PyTree,
    state: AdamState,
    lr: float | jnp.ndarray = 1e-3,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> tuple[PyTree, AdamState]:
    """AdamW (decoupled weight decay) for the LM substrate."""
    step = state.step + 1
    mu, nu = _moments(grads, state, b1, b2)
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1**t
    bc2 = 1.0 - b2**t

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        return p - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamState(step=step, mu=mu, nu=nu)


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: g * scale, grads)
