"""Attention variants: GQA (w/ qk-norm, biases, sliding window) and MLA.

Cache convention (serve path): a dict per attention block,
  GQA:  {"k": (B, S_cache, KV, hd), "v": (B, S_cache, KV, hd)}
  MLA:  {"c_kv": (B, S_cache, kv_rank), "k_rope": (B, S_cache, rope_dim)}
plus the scalar write position carried by the caller. Sliding-window blocks
allocate only ``window`` slots and write modulo window (ring buffer) — this
is what makes long_500k decode O(window) for SWA architectures.

MLA decode uses the ABSORBED form (q projected into latent space, attention
performed against the compressed c_kv directly), so per-step cost scales
with kv_rank, not with H*hd — the whole point of caching latents.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.common import (
    Params,
    apply_rope,
    causal_mask,
    dense_init,
    rms_norm,
    rope_angles,
    window_mask,
)
from repro.models.config import MLAConfig, ModelConfig

_NEG_INF = -1e30


# --------------------------------------------------------------------------
# GQA
# --------------------------------------------------------------------------


def init_gqa_params(key: jax.Array, cfg: ModelConfig) -> Params:
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, H * hd)),
        "wk": dense_init(ks[1], (D, KV * hd)),
        "wv": dense_init(ks[2], (D, KV * hd)),
        "wo": dense_init(ks[3], (H * hd, D)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), jnp.float32)
        p["bk"] = jnp.zeros((KV * hd,), jnp.float32)
        p["bv"] = jnp.zeros((KV * hd,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _qkv(p: Params, cfg: ModelConfig, x: jnp.ndarray):
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    dt = x.dtype
    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _sdpa(q, k, v, mask, num_kv: int) -> jnp.ndarray:
    """Grouped scaled-dot-product attention.
    q (B,S,H,hd), k/v (B,T,KV,hd), mask (S,T) or (B,S,T) bool."""
    B, S, H, hd = q.shape
    G = H // num_kv
    qg = q.reshape(B, S, num_kv, G, hd)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    logits = logits / jnp.sqrt(hd).astype(jnp.float32)
    m = mask if mask.ndim == 3 else mask[None]
    logits = jnp.where(m[:, None, None, :, :], logits, _NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(B, S, H, hd)


def _sdpa_chunked(q, k, v, num_kv: int, window: int, chunk: int) -> jnp.ndarray:
    """Query-chunked causal/windowed attention (§Perf memory lever).

    The full (B, KV, G, S, S) fp32 logits tensor dominates activation
    memory whenever heads cannot shard (e.g. 14 heads on a 16-wide model
    axis). lax.map over query chunks serializes it to (.., chunk, S), and
    jax.checkpoint on the chunk body keeps backward residuals linear in S
    (flash-attention-via-remat; exact same math, reassociated)."""
    B, S, H, hd = q.shape
    nc = S // chunk
    qc = q.reshape(B, nc, chunk, H, hd).transpose(1, 0, 2, 3, 4)

    @jax.checkpoint
    def body(args):
        qi, ci = args
        off = ci * chunk
        m = window_mask(chunk, S, window, off) if window else causal_mask(chunk, S, off)
        return _sdpa(qi, k, v, m, num_kv)

    out = jax.lax.map(body, (qc, jnp.arange(nc)))
    return out.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)


def gqa_forward(
    p: Params,
    cfg: ModelConfig,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    *,
    window: int = 0,
    cache: Params | None = None,
    cache_pos: jnp.ndarray | None = None,
    encoder_kv: tuple[jnp.ndarray, jnp.ndarray] | None = None,
) -> tuple[jnp.ndarray, Params | None]:
    """One attention call.

    Modes:
      train/prefill: cache None (train) or empty-allocated (prefill fills it)
      decode: x (B, 1, D), cache holds history, cache_pos = current length
      cross-attention: encoder_kv given — no cache mutation, no causal mask.
    """
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    dt = x.dtype

    if encoder_kv is not None:
        k, v = encoder_kv
        q = (x @ p["wq"].astype(dt)).reshape(B, S, H, hd)
        T = k.shape[1]
        mask = jnp.ones((S, T), bool)
        out = _sdpa(q, k, v, mask, KV)
        return out.reshape(B, S, H * hd) @ p["wo"].astype(dt), None

    q, k, v = _qkv(p, cfg, x)
    if cfg.pos_kind == "rope":
        cos, sin = rope_angles(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    if cache is None:
        # --- training / encoder self-attention: full sequence ---
        qc = cfg.attn_q_chunk
        if qc and S > qc and S % qc == 0:
            out = _sdpa_chunked(q, k, v, KV, window, qc)
        else:
            mask = window_mask(S, S, window) if window else causal_mask(S, S)
            out = _sdpa(q, k, v, mask, KV)
        new_cache = None
    elif S > 1:
        # --- prefill: fill the cache, attend within the prompt ---
        mask = window_mask(S, S, window) if window else causal_mask(S, S)
        out = _sdpa(q, k, v, mask, KV)
        Sc = cache["k"].shape[1]
        if window and S >= Sc:
            # ring cache: slot s holds the key of absolute position
            # base + (s - base) % Sc (the unique position in [S-Sc, S) that
            # decode's slot = pos % Sc addressing maps to slot s)
            base = S - Sc
            take_ids = base + (jnp.arange(Sc) - base) % Sc
            kk = jnp.take(k, take_ids, axis=1).astype(cache["k"].dtype)
            vv = jnp.take(v, take_ids, axis=1).astype(cache["v"].dtype)
            new_cache = {"k": kk, "v": vv}
        elif window:
            # prompt shorter than the window: slots [0, S) in order
            kk = cache["k"].at[:, :S].set(k.astype(cache["k"].dtype))
            vv = cache["v"].at[:, :S].set(v.astype(cache["v"].dtype))
            new_cache = {"k": kk, "v": vv}
        else:
            kk = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), 0, 1)
            vv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), 0, 1)
            new_cache = {"k": kk, "v": vv}
    else:
        # --- decode: single step against the cache ---
        Sc = cache["k"].shape[1]
        slot = (cache_pos % Sc) if window else cache_pos
        kk = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        vv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
        new_cache = {"k": kk, "v": vv}
        ar = jnp.arange(Sc)
        if window:
            valid = (ar <= slot) | (cache_pos >= Sc)  # ring full => all valid
        else:
            valid = ar <= cache_pos
        mask = valid[None, None, :]  # (B=1bc, S=1, T)
        out = _sdpa(q, kk.astype(dt), vv.astype(dt), mask, KV)

    return out.reshape(B, S, H * hd) @ p["wo"].astype(dt), new_cache


def init_gqa_cache(cfg: ModelConfig, batch: int, seq: int, window: int, dtype) -> Params:
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    Sc = min(seq, window) if window else seq
    return {
        "k": jnp.zeros((batch, Sc, KV, hd), dtype),
        "v": jnp.zeros((batch, Sc, KV, hd), dtype),
    }


# --------------------------------------------------------------------------
# MLA (Multi-head Latent Attention)
# --------------------------------------------------------------------------


def init_mla_params(key: jax.Array, cfg: ModelConfig) -> Params:
    a: MLAConfig = cfg.mla
    D, H = cfg.d_model, cfg.num_heads
    qd = a.qk_nope_head_dim + a.qk_rope_head_dim
    ks = jax.random.split(key, 7)
    return {
        "w_dq": dense_init(ks[0], (D, a.q_lora_rank)),
        "q_norm": jnp.ones((a.q_lora_rank,), jnp.float32),
        "w_uq": dense_init(ks[1], (a.q_lora_rank, H * qd)),
        "w_dkv": dense_init(ks[2], (D, a.kv_lora_rank)),
        "kv_norm": jnp.ones((a.kv_lora_rank,), jnp.float32),
        "w_kr": dense_init(ks[3], (D, a.qk_rope_head_dim)),
        "w_uk": dense_init(ks[4], (a.kv_lora_rank, H * a.qk_nope_head_dim)),
        "w_uv": dense_init(ks[5], (a.kv_lora_rank, H * a.v_head_dim)),
        "wo": dense_init(ks[6], (H * a.v_head_dim, D)),
    }


def mla_forward(
    p: Params,
    cfg: ModelConfig,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    *,
    cache: Params | None = None,
    cache_pos: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, Params | None]:
    a: MLAConfig = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    dn, dr, dv = a.qk_nope_head_dim, a.qk_rope_head_dim, a.v_head_dim
    dt = x.dtype
    scale = 1.0 / jnp.sqrt(dn + dr)

    cq = rms_norm(x @ p["w_dq"].astype(dt), p["q_norm"], cfg.norm_eps)
    q = (cq @ p["w_uq"].astype(dt)).reshape(B, S, H, dn + dr)
    qn, qr = q[..., :dn], q[..., dn:]
    cos, sin = rope_angles(positions, dr, cfg.rope_theta)
    qr = apply_rope(qr, cos, sin)

    c_kv = rms_norm(x @ p["w_dkv"].astype(dt), p["kv_norm"], cfg.norm_eps)  # (B,S,r)
    kr = (x @ p["w_kr"].astype(dt)).reshape(B, S, 1, dr)
    kr = apply_rope(kr, cos, sin)[:, :, 0]  # (B,S,dr) shared across heads

    if cache is None or S > 1:
        # train / prefill: expand latents directly (compute-bound path)
        kn = (c_kv @ p["w_uk"].astype(dt)).reshape(B, S, H, dn)
        v = (c_kv @ p["w_uv"].astype(dt)).reshape(B, S, H, dv)

        def _mla_block(qn_i, qr_i, off):
            lg = (
                jnp.einsum("bshd,bthd->bhst", qn_i, kn)
                + jnp.einsum("bshd,btd->bhst", qr_i, kr)
            ).astype(jnp.float32) * scale
            m = causal_mask(qn_i.shape[1], S, off)
            lg = jnp.where(m[None, None], lg, _NEG_INF)
            w = jax.nn.softmax(lg, axis=-1).astype(dt)
            return jnp.einsum("bhst,bthd->bshd", w, v)

        qc = cfg.attn_q_chunk
        if cache is None and qc and S > qc and S % qc == 0:
            # query-chunked MLA (same §Perf memory lever as _sdpa_chunked)
            nc = S // qc
            qn_c = qn.reshape(B, nc, qc, H, dn).transpose(1, 0, 2, 3, 4)
            qr_c = qr.reshape(B, nc, qc, H, dr).transpose(1, 0, 2, 3, 4)

            @jax.checkpoint
            def body(args):
                qn_i, qr_i, ci = args
                return _mla_block(qn_i, qr_i, ci * qc)

            out = jax.lax.map(body, (qn_c, qr_c, jnp.arange(nc)))
            out = out.transpose(1, 0, 2, 3, 4).reshape(B, S, H * dv)
        else:
            out = _mla_block(qn, qr, 0).reshape(B, S, H * dv)
        new_cache = None
        if cache is not None:
            ck = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), 0, 1)
            kk = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], kr.astype(cache["k_rope"].dtype), 0, 1)
            new_cache = {"c_kv": ck, "k_rope": kk}
    else:
        # decode: ABSORBED attention against compressed latents
        ck = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, cache_pos, 0))
        kk = jax.lax.dynamic_update_slice(cache["k_rope"], kr.astype(cache["k_rope"].dtype), (0, cache_pos, 0))
        new_cache = {"c_kv": ck, "k_rope": kk}
        T = ck.shape[1]
        w_uk = p["w_uk"].astype(dt).reshape(a.kv_lora_rank, H, dn)
        q_lat = jnp.einsum("bshd,rhd->bshr", qn, w_uk)  # (B,1,H,r)
        logits = (
            jnp.einsum("bshr,btr->bhst", q_lat, ck.astype(dt))
            + jnp.einsum("bshd,btd->bhst", qr, kk.astype(dt))
        ).astype(jnp.float32) * scale
        valid = jnp.arange(T) <= cache_pos
        logits = jnp.where(valid[None, None, None], logits, _NEG_INF)
        w = jax.nn.softmax(logits, axis=-1).astype(dt)
        o_lat = jnp.einsum("bhst,btr->bshr", w, ck.astype(dt))  # (B,1,H,r)
        w_uv = p["w_uv"].astype(dt).reshape(a.kv_lora_rank, H, dv)
        out = jnp.einsum("bshr,rhd->bshd", o_lat, w_uv).reshape(B, S, H * dv)

    return out @ p["wo"].astype(dt), new_cache


def init_mla_cache(cfg: ModelConfig, batch: int, seq: int, dtype) -> Params:
    a: MLAConfig = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, seq, a.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, seq, a.qk_rope_head_dim), dtype),
    }
