"""RG-LRU temporal-mixing block (Griffin / RecurrentGemma).

Block structure (De et al. 2024, arXiv:2402.19427):
    x -> [branch a] linear -> GeLU
      -> [branch b] linear -> causal conv1d(w=4) -> RG-LRU
    out = W_out (a * b)

RG-LRU recurrence (per channel):
    r_t = sigmoid(W_r u_t),  i_t = sigmoid(W_i u_t)
    a_t = exp(c * r_t * (-softplus(lam)))          # lam learned, c = 8
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

The recurrence is linear in h, so training uses ``jax.lax.associative_scan``
— log-depth on TPU, the JAX-native stand-in for Griffin's custom linear-scan
kernel (DESIGN.md hardware-adaptation table). Decode is the exact one-step
update with (conv window, h) carried as cache.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.common import Params, dense_init
from repro.models.config import ModelConfig

_C = 8.0


def init_rglru_params(key: jax.Array, cfg: ModelConfig) -> Params:
    D = cfg.d_model
    W = cfg.rnn_width or cfg.d_model
    cw = cfg.conv_width
    ks = jax.random.split(key, 6)
    # lam init so that a^c spreads over ~(0.9, 0.999) (Griffin's init range)
    u = jax.random.uniform(ks[5], (W,), minval=0.9, maxval=0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^{-1}(-log u / c)
    return {
        "w_a": dense_init(ks[0], (D, W)),
        "w_b": dense_init(ks[1], (D, W)),
        "conv": (jax.random.normal(ks[2], (cw, W)) / jnp.sqrt(cw)).astype(jnp.float32),
        "w_r": dense_init(ks[3], (W, W)),
        "w_i": dense_init(ks[4], (W, W)),
        "lam": lam.astype(jnp.float32),
        "w_out": dense_init(jax.random.fold_in(key, 9), (W, D)),
    }


def _causal_conv(u: jnp.ndarray, kernel: jnp.ndarray, state: jnp.ndarray | None):
    """Depthwise causal conv. u (B, S, W), kernel (cw, W).
    state (B, cw-1, W) holds the trailing inputs for streaming decode."""
    cw = kernel.shape[0]
    if state is None:
        pad = jnp.zeros((u.shape[0], cw - 1, u.shape[2]), u.dtype)
    else:
        pad = state.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1)  # (B, S+cw-1, W)
    out = sum(full[:, i : i + u.shape[1]] * kernel[i].astype(u.dtype) for i in range(cw))
    new_state = full[:, -(cw - 1) :] if cw > 1 else None
    return out, new_state


def _rglru_scan(u: jnp.ndarray, a: jnp.ndarray, h0: jnp.ndarray | None) -> jnp.ndarray:
    """h_t = a_t h_{t-1} + b_t via associative scan. u=b (B,S,W), a (B,S,W)."""
    if h0 is not None:
        # fold the initial state in as a virtual step 0
        a = jnp.concatenate([jnp.zeros_like(a[:, :1]), a], axis=1)
        u = jnp.concatenate([h0[:, None].astype(u.dtype), u], axis=1)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, u), axis=1)
    return h[:, 1:] if h0 is not None else h


def rglru_forward(
    p: Params,
    cfg: ModelConfig,
    x: jnp.ndarray,
    *,
    cache: Params | None = None,
) -> tuple[jnp.ndarray, Params | None]:
    """x (B, S, D) -> (out (B, S, D), new cache {"conv", "h"})."""
    dt = x.dtype
    branch_a = jax.nn.gelu(x @ p["w_a"].astype(dt))  # (B,S,W)
    u = x @ p["w_b"].astype(dt)
    conv_state = cache["conv"] if cache is not None else None
    u, new_conv = _causal_conv(u, p["conv"], conv_state)

    r = jax.nn.sigmoid(u @ p["w_r"].astype(dt)).astype(jnp.float32)
    i = jax.nn.sigmoid(u @ p["w_i"].astype(dt)).astype(jnp.float32)
    log_a = -_C * r * jax.nn.softplus(p["lam"])  # (B,S,W) fp32, <= 0
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * i * u.astype(jnp.float32)

    if cache is None:
        h = _rglru_scan(gated, a, None)
        new_cache = None
    elif x.shape[1] > 1:
        h = _rglru_scan(gated, a, cache["h"])
        new_cache = {"conv": new_conv, "h": h[:, -1].astype(cache["h"].dtype)}
    else:
        h_prev = cache["h"].astype(jnp.float32)
        h = (a[:, 0] * h_prev + gated[:, 0])[:, None]
        new_cache = {"conv": new_conv, "h": h[:, 0].astype(cache["h"].dtype)}

    out = (branch_a * h.astype(dt)) @ p["w_out"].astype(dt)
    return out, new_cache


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype) -> Params:
    W = cfg.rnn_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, W), dtype),
        "h": jnp.zeros((batch, W), jnp.float32),  # recurrent state stays fp32
    }
