"""Model assembly: pattern-period scan over composable blocks.

A LAYER is a temporal-mixing block (attn / local_attn / mla / mlstm /
slstm / rglru) plus — unless ``mlp_kind == "none"`` — a feed-forward block
(dense SwiGLU/GeLU, or MoE for MoE archs), each pre-normed with residuals.

Layers are grouped into PATTERN PERIODS (cfg.block_pattern). Parameters of
all full periods are stacked on a leading axis and the forward pass scans
over them, so the traced program is O(period), not O(num_layers) — the only
way an 80-layer config lowers tractably with 512 virtual devices on one CPU
(DESIGN.md §5). A partial trailing period ("remainder") and an optional
dense "prelude" layer (DeepSeekMoE's dense layer 0) stay unstacked.

KV / recurrent caches mirror the parameter structure:
    {"prelude": c?, "stack": stacked over periods, "remainder": [c...]}
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import ssm as ssm_lib
from repro.models.common import (
    Params,
    apply_mlp,
    dense_init,
    embed_init,
    init_mlp,
    rms_norm,
)
from repro.models.config import ModelConfig

Cache = dict[str, Any]


# --------------------------------------------------------------------------
# single layer
# --------------------------------------------------------------------------


def _init_mixing(key, cfg: ModelConfig, kind: str) -> Params:
    if kind in ("attn", "local_attn"):
        return attn.init_gqa_params(key, cfg)
    if kind == "mla":
        return attn.init_mla_params(key, cfg)
    if kind == "mlstm":
        return ssm_lib.init_mlstm_params(key, cfg)
    if kind == "slstm":
        return ssm_lib.init_slstm_params(key, cfg)
    if kind == "rglru":
        return rglru_lib.init_rglru_params(key, cfg)
    raise ValueError(kind)


def init_layer_params(
    key: jax.Array, cfg: ModelConfig, kind: str, *, dense_ffn: bool = False, cross: bool = False
) -> Params:
    """One layer: mixing + optional FFN (+ optional cross-attention)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Params = {"ln1": jnp.ones((cfg.d_model,), jnp.float32), "mix": _init_mixing(k1, cfg, kind)}
    if cross:
        p["ln_x"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["cross"] = attn.init_gqa_params(k4, cfg)
    if cfg.mlp_kind != "none":
        p["ln2"] = jnp.ones((cfg.d_model,), jnp.float32)
        if cfg.moe is not None and not dense_ffn:
            p["moe"] = moe_lib.init_moe_params(k2, cfg)
        else:
            d_ff = cfg.moe.dense_d_ff if (cfg.moe is not None and dense_ffn) else cfg.d_ff
            p["mlp"] = init_mlp(k3, cfg.d_model, d_ff, _mlp_kind(cfg))
    return p


def _mlp_kind(cfg: ModelConfig) -> str:
    return "gelu" if cfg.mlp_kind == "gelu" else "swiglu"


def init_layer_cache(
    cfg: ModelConfig, kind: str, batch: int, seq: int, dtype, cross_len: int = 0
) -> Cache:
    if kind == "attn":
        c = attn.init_gqa_cache(cfg, batch, seq, 0, dtype)
    elif kind == "local_attn":
        c = attn.init_gqa_cache(cfg, batch, seq, cfg.sliding_window, dtype)
    elif kind == "mla":
        c = attn.init_mla_cache(cfg, batch, seq, dtype)
    elif kind == "mlstm":
        c = ssm_lib.init_mlstm_cache(cfg, batch, dtype)
    elif kind == "slstm":
        c = ssm_lib.init_slstm_cache(cfg, batch, dtype)
    elif kind == "rglru":
        c = rglru_lib.init_rglru_cache(cfg, batch, dtype)
    else:
        raise ValueError(kind)
    if cross_len:
        KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        c = dict(c)
        c["cross_k"] = jnp.zeros((batch, cross_len, KV, hd), dtype)
        c["cross_v"] = jnp.zeros((batch, cross_len, KV, hd), dtype)
    return c


def layer_forward(
    p: Params,
    cfg: ModelConfig,
    kind: str,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    *,
    cache: Cache | None = None,
    cache_pos: jnp.ndarray | None = None,
    encoder_out: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, Cache | None, jnp.ndarray]:
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    mix_cache = None
    if cache is not None:
        mix_cache = {k: v for k, v in cache.items() if not k.startswith("cross_")}
    if kind in ("attn", "local_attn"):
        window = cfg.sliding_window if kind == "local_attn" else 0
        out, new_mix = attn.gqa_forward(
            p["mix"], cfg, h, positions, window=window, cache=mix_cache, cache_pos=cache_pos
        )
    elif kind == "mla":
        out, new_mix = attn.mla_forward(p["mix"], cfg, h, positions, cache=mix_cache, cache_pos=cache_pos)
    elif kind == "mlstm":
        out, new_mix = ssm_lib.mlstm_forward(p["mix"], cfg, h, cache=mix_cache)
    elif kind == "slstm":
        out, new_mix = ssm_lib.slstm_forward(p["mix"], cfg, h, cache=mix_cache)
    elif kind == "rglru":
        out, new_mix = rglru_lib.rglru_forward(p["mix"], cfg, h, cache=mix_cache)
    else:
        raise ValueError(kind)
    x = x + out

    new_cache: Cache | None = None
    if cache is not None:
        new_cache = dict(new_mix or {})

    if "cross" in p:
        hx = rms_norm(x, p["ln_x"], cfg.norm_eps)
        if cache is not None and "cross_k" in cache and encoder_out is None:
            kv = (cache["cross_k"].astype(x.dtype), cache["cross_v"].astype(x.dtype))
            out, _ = attn.gqa_forward(p["cross"], cfg, hx, positions, encoder_kv=kv)
            new_cache["cross_k"] = cache["cross_k"]
            new_cache["cross_v"] = cache["cross_v"]
        else:
            # prefill / training: project encoder output to cross K/V
            B, T, _ = encoder_out.shape
            KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
            dt = x.dtype
            ck = (encoder_out @ p["cross"]["wk"].astype(dt)).reshape(B, T, KV, hd)
            cv = (encoder_out @ p["cross"]["wv"].astype(dt)).reshape(B, T, KV, hd)
            out, _ = attn.gqa_forward(p["cross"], cfg, hx, positions, encoder_kv=(ck, cv))
            if cache is not None:
                new_cache["cross_k"] = ck.astype(cache["cross_k"].dtype)
                new_cache["cross_v"] = cv.astype(cache["cross_v"].dtype)
        x = x + out

    if "moe" in p:
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        out, aux = moe_lib.moe_forward(p["moe"], cfg, h)
        x = x + out
    elif "mlp" in p:
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + apply_mlp(p["mlp"], h, _mlp_kind(cfg))
    return x, new_cache, aux


# --------------------------------------------------------------------------
# whole-model parameters
# --------------------------------------------------------------------------


def init_model_params(key: jax.Array, cfg: ModelConfig) -> Params:
    cfg.validate()
    keys = jax.random.split(key, 10)
    D, V = cfg.d_model, cfg.padded_vocab_size
    p: Params = {"embed": embed_init(keys[0], (V, D))}
    if cfg.pos_kind == "learned":
        p["pos_embed"] = embed_init(keys[1], (cfg.max_position, D))

    prelude_dense = cfg.moe is not None and cfg.moe.first_layer_dense
    n_scan = cfg.num_layers - (1 if prelude_dense else 0)
    period = cfg.period
    n_periods = n_scan // period
    rem = cfg.block_pattern[: n_scan % period]

    if prelude_dense:
        p["prelude"] = init_layer_params(keys[2], cfg, cfg.block_pattern[0], dense_ffn=True)

    cross = cfg.encoder is not None

    def init_period(k):
        ks = jax.random.split(k, period)
        return {
            f"b{i}": init_layer_params(ks[i], cfg, kind, cross=cross)
            for i, kind in enumerate(cfg.block_pattern)
        }

    p["stack"] = jax.vmap(init_period)(jax.random.split(keys[3], n_periods))
    p["remainder"] = [
        init_layer_params(jax.random.fold_in(keys[4], i), cfg, kind, cross=cross)
        for i, kind in enumerate(rem)
    ]
    p["final_norm"] = jnp.ones((D,), jnp.float32)
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(keys[5], (D, V))

    if cfg.encoder is not None:
        e = cfg.encoder
        enc_key = keys[6]
        if e.frontend_dim != D:
            p["enc_proj"] = dense_init(jax.random.fold_in(enc_key, 0), (e.frontend_dim, D))
        p["enc_pos"] = embed_init(jax.random.fold_in(enc_key, 1), (e.num_frames, D))

        def init_enc_layer(k):
            return {"b0": init_layer_params(k, cfg, "attn")}

        p["encoder"] = jax.vmap(init_enc_layer)(jax.random.split(enc_key, e.num_layers))
        p["enc_norm"] = jnp.ones((D,), jnp.float32)

    if cfg.vision is not None:
        v = cfg.vision
        k1, k2 = jax.random.split(keys[7])
        p["projector"] = {
            "w1": dense_init(k1, (v.vit_dim, D)),
            "w2": dense_init(k2, (D, D)),
        }
    return p


def init_cache(cfg: ModelConfig, batch: int, seq: int, dtype=jnp.bfloat16) -> Cache:
    """Decode cache sized for ``seq`` total positions."""
    cross_len = cfg.encoder.num_frames if cfg.encoder is not None else 0
    prelude_dense = cfg.moe is not None and cfg.moe.first_layer_dense
    n_scan = cfg.num_layers - (1 if prelude_dense else 0)
    period = cfg.period
    n_periods = n_scan // period
    rem = cfg.block_pattern[: n_scan % period]

    def one_period(_):
        return {
            f"b{i}": init_layer_cache(cfg, kind, batch, seq, dtype, cross_len)
            for i, kind in enumerate(cfg.block_pattern)
        }

    c: Cache = {
        "stack": jax.vmap(one_period)(jnp.arange(n_periods)),
        "remainder": [
            init_layer_cache(cfg, kind, batch, seq, dtype, cross_len) for kind in rem
        ],
    }
    if prelude_dense:
        c["prelude"] = init_layer_cache(cfg, cfg.block_pattern[0], batch, seq, dtype, cross_len)
    return c


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------


def _encoder_forward(p: Params, cfg: ModelConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """Whisper-style encoder over stubbed frame embeddings (B, T, F)."""
    dt = jnp.dtype(cfg.dtype)
    x = frames.astype(dt)
    if "enc_proj" in p:
        x = x @ p["enc_proj"].astype(dt)
    x = x + p["enc_pos"].astype(dt)[None, : x.shape[1]]

    def body(h, lp):
        # bidirectional self-attention: no cache, no causal mask -> use
        # encoder_kv trick? encoder needs full (non-causal) self-attention.
        h2 = rms_norm(h, lp["b0"]["ln1"], cfg.norm_eps)
        q, k, v = attn._qkv(lp["b0"]["mix"], cfg, h2)
        mask = jnp.ones((h.shape[1], h.shape[1]), bool)
        o = attn._sdpa(q, k, v, mask, cfg.num_kv_heads)
        o = o.reshape(h.shape[0], h.shape[1], -1) @ lp["b0"]["mix"]["wo"].astype(h.dtype)
        h = h + o
        h2 = rms_norm(h, lp["b0"]["ln2"], cfg.norm_eps)
        h = h + apply_mlp(lp["b0"]["mlp"], h2, _mlp_kind(cfg))
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body)
    if cfg.unroll:
        n = jax.tree.leaves(p["encoder"])[0].shape[0]
        for i in range(n):
            x, _ = body(x, jax.tree.map(lambda a, i=i: a[i], p["encoder"]))
    else:
        x, _ = jax.lax.scan(body, x, p["encoder"])
    return rms_norm(x, p["enc_norm"], cfg.norm_eps)


def forward(
    params: Params,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    *,
    frames: jnp.ndarray | None = None,
    patches: jnp.ndarray | None = None,
    cache: Cache | None = None,
    cache_pos: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, Cache | None, jnp.ndarray]:
    """Full model forward.

    tokens (B, S) int32. frames: (B, T, F) stub audio embeddings (enc-dec).
    patches: (B, P, vit_dim) stub ViT embeddings (VLM; prepended).
    cache/cache_pos: decode state (cache_pos = #tokens already consumed).
    Returns (logits fp32 (B, S_out, V), new_cache, aux_loss).
    """
    dt = jnp.dtype(cfg.dtype)
    if dt != jnp.float32:
        # One-shot mixed-precision cast of all >=2-D weights (norm scales
        # stay fp32). Under FSDP this halves the (possibly loop-hoisted)
        # weight all-gathers and every HBM weight stream — §Perf memory
        # lever; the optimizer still holds fp32 masters.
        params = jax.tree.map(
            lambda a: a.astype(dt) if (a.dtype == jnp.float32 and a.ndim >= 2) else a,
            params,
        )
    B, S = tokens.shape
    x = params["embed"].astype(dt)[tokens]

    if cfg.vision is not None and patches is not None:
        pr = params["projector"]
        pe = jax.nn.gelu(patches.astype(dt) @ pr["w1"].astype(dt)) @ pr["w2"].astype(dt)
        x = jnp.concatenate([pe, x], axis=1)  # image tokens first
        S = x.shape[1]

    pos0 = cache_pos if cache_pos is not None else 0
    positions = pos0 + jnp.arange(S)
    if cfg.pos_kind == "learned":
        pe = jnp.take(params["pos_embed"], jnp.minimum(positions, cfg.max_position - 1), axis=0)
        x = x + pe.astype(dt)[None]

    encoder_out = None
    if cfg.encoder is not None and frames is not None:
        encoder_out = _encoder_forward(params, cfg, frames)

    aux_total = jnp.zeros((), jnp.float32)

    if "prelude" in params:
        pc = cache.get("prelude") if cache is not None else None
        x, new_pc, aux = layer_forward(
            params["prelude"], cfg, cfg.block_pattern[0], x, positions,
            cache=pc, cache_pos=cache_pos, encoder_out=encoder_out,
        )
        aux_total += aux

    def period_body(carry, xs):
        h, aux_acc = carry
        p_per, c_per = xs
        new_c_per = {}
        for i, kind in enumerate(cfg.block_pattern):
            ci = c_per.get(f"b{i}") if isinstance(c_per, dict) and c_per else None
            h, nci, aux = layer_forward(
                p_per[f"b{i}"], cfg, kind, h, positions,
                cache=ci, cache_pos=cache_pos, encoder_out=encoder_out,
            )
            new_c_per[f"b{i}"] = nci if nci is not None else {}
        return (h, aux_acc + aux), new_c_per

    body = jax.checkpoint(period_body) if cfg.remat else period_body
    stack_cache = cache["stack"] if cache is not None else {}
    if cfg.unroll:
        n_per = jax.tree.leaves(params["stack"])[0].shape[0]
        collected = []
        carry = (x, aux_total)
        for pi in range(n_per):
            p_per = jax.tree.map(lambda a, pi=pi: a[pi], params["stack"])
            c_per = jax.tree.map(lambda a, pi=pi: a[pi], stack_cache) if cache is not None else {}
            carry, nc = body(carry, (p_per, c_per))
            collected.append(nc)
        (x, aux_total) = carry
        new_stack_cache = (
            jax.tree.map(lambda *xs: jnp.stack(xs), *collected) if collected and cache is not None else {}
        )
    else:
        (x, aux_total), new_stack_cache = jax.lax.scan(
            body, (x, aux_total), (params["stack"], stack_cache)
        )

    new_cache: Cache | None = None
    if cache is not None:
        new_cache = {"stack": new_stack_cache, "remainder": []}
        if "prelude" in params:
            new_cache["prelude"] = new_pc

    for i, lp in enumerate(params["remainder"]):
        kind = cfg.block_pattern[i]
        ci = cache["remainder"][i] if cache is not None else None
        x, nci, aux = layer_forward(
            lp, cfg, kind, x, positions, cache=ci, cache_pos=cache_pos, encoder_out=encoder_out
        )
        aux_total += aux
        if cache is not None:
            new_cache["remainder"].append(nci)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head.astype(dt)).astype(jnp.float32)
    if cfg.padded_vocab_size != cfg.vocab_size:
        # mask pad slots instead of slicing: a slice to a non-256-multiple
        # width would force the (B, S, V) buffer back to unsharded
        pad_mask = jnp.arange(cfg.padded_vocab_size) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    return logits, new_cache, aux_total
