"""Mixture-of-Experts FFN: shared + routed top-k experts (DeepSeekMoE /
Qwen3-MoE style) with capacity-based scatter dispatch.

Dispatch is the scatter/rank formulation (GShard capacity discipline
without the O(T*E*C) dense one-hot): per-token expert ranks come from a
stable argsort over the flattened (token, k) assignments, tokens beyond
each expert's capacity are dropped, and the (E, C, D) expert buffers are
built with a single scatter-add. Experts' weights carry a leading E axis —
the sharding rules put that axis on the ``model`` mesh axis, so the
token->expert buffer exchange lowers to the expected all-to-all pattern
under SPMD (visible in the roofline's collective bytes).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.common import Params, dense_init
from repro.models.config import ModelConfig, MoEConfig
from repro.runtime import compat


def init_moe_params(key: jax.Array, cfg: ModelConfig) -> Params:
    m: MoEConfig = cfg.moe
    D, E, F = cfg.d_model, m.num_experts, m.d_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (D, E)),
        "w_gate": dense_init(ks[1], (E, D, F), in_axis=1),
        "w_up": dense_init(ks[2], (E, D, F), in_axis=1),
        "w_down": dense_init(ks[3], (E, F, D), in_axis=1),
    }
    if m.num_shared > 0:
        sf = m.num_shared * F
        s1, s2, s3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(s1, (D, sf)),
            "w_up": dense_init(s2, (D, sf)),
            "w_down": dense_init(s3, (sf, D)),
        }
    return p


def moe_forward(p: Params, cfg: ModelConfig, x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, D) -> (out (B, S, D), aux load-balance loss scalar).

    Under a multi-device mesh with a "model" axis this routes through the
    manually-partitioned shard_map path (see _moe_forward_spmd) — XLA's
    auto-partitioner replicates the D-wide dispatch scatters otherwise
    (measured: ~5 GiB all-gathers per layer, EXPERIMENTS.md §Perf-2).
    """
    mesh = compat.get_abstract_mesh()
    if (
        mesh is not None
        and "model" in mesh.axis_names
        and mesh.shape["model"] > 1
        and cfg.moe.num_experts % mesh.shape["model"] == 0
    ):
        return _moe_forward_spmd(p, cfg, x, mesh)
    return _moe_forward_local(p, cfg, x)


def _moe_forward_local(p: Params, cfg: ModelConfig, x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    m: MoEConfig = cfg.moe
    B, S, D = x.shape
    E, K = m.num_experts, m.top_k
    T = B * S
    dt = x.dtype
    xt = x.reshape(T, D)

    # --- routing (fp32) ---
    logits = (xt @ p["router"].astype(dt)).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)  # (T, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # --- load-balance auxiliary (Switch-style) ---
    dispatch_frac = jnp.zeros((E,), jnp.float32).at[expert_ids.reshape(-1)].add(1.0) / (T * K)
    prob_frac = probs.mean(0)
    aux = E * jnp.sum(dispatch_frac * prob_frac)

    # --- GROUP-LOCAL capacity ranks (GShard-style groups) ---
    # §Perf log (EXPERIMENTS.md): a GLOBAL argsort over the (T*K,)
    # assignments forces multi-pass sorted all-gathers when T is sharded
    # (measured: 48 s collective term for qwen3-moe train_4k); a global
    # cumsum lowers to an O(T^2) reduce-window (measured: 6x compute
    # blowup); an associative_scan unrolls 20 static passes over (T, E)
    # (compile blowup). The production answer is to make rank computation
    # LOCAL: tokens are split into G groups aligned with the data shards,
    # each group ranks and drops against its own capacity slice C/G
    # (exactly GShard's per-group capacity semantics). Ranks then never
    # cross shards; all communication concentrates in the (G <-> E) buffer
    # transpose below — a single all-to-all, as an MoE should.
    G = m.dispatch_groups
    while T % G:
        G //= 2
    Tg = T // G
    Cg = max(int(m.capacity_factor * Tg * K / E), 1)
    tok_l = jnp.repeat(jnp.arange(Tg), K)  # local owning token (same per group)

    def group_ranks(eid_flat):  # (Tg*K,) -> (Tg*K,) rank within expert
        order = jnp.argsort(eid_flat, stable=True)
        counts = jnp.zeros((E,), jnp.int32).at[eid_flat].add(1)
        seg_start = jnp.cumsum(counts) - counts
        rank_sorted = jnp.arange(Tg * K, dtype=jnp.int32) - seg_start[eid_flat[order]]
        return jnp.zeros((Tg * K,), jnp.int32).at[order].set(rank_sorted)

    eid_g = expert_ids.reshape(G, Tg * K)
    rank_g = jax.vmap(group_ranks)(eid_g)  # (G, Tg*K)
    keep_g = (rank_g < Cg).astype(dt)
    slot_g = eid_g * Cg + jnp.minimum(rank_g, Cg - 1)

    # --- dispatch: per-group scatter into (G, E*Cg, D) buffers ---
    x_g = xt.reshape(G, Tg, D)

    def group_scatter(slots, keeps, xg):
        return jnp.zeros((E * Cg, D), dt).at[slots].add(xg[tok_l] * keeps[:, None])

    buf = jax.vmap(group_scatter)(slot_g, keep_g, x_g)  # (G, E*Cg, D)
    # group-sharded -> expert-sharded: THE all-to-all of the MoE layer
    buf = buf.reshape(G, E, Cg, D).transpose(1, 0, 2, 3).reshape(E, G * Cg, D)

    # --- expert computation (grouped einsum over the E axis) ---
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(dt)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(dt))
    eout = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(dt))

    # --- combine: transpose back, gather per group, weight by gates ---
    eout = eout.reshape(E, G, Cg, D).transpose(1, 0, 2, 3).reshape(G, E * Cg, D)
    gate_g = gate_vals.reshape(G, Tg * K).astype(dt)

    def group_combine(eo, slots, keeps, gates):
        per_assign = eo[slots] * (keeps * gates)[:, None]
        return jnp.zeros((Tg, D), dt).at[tok_l].add(per_assign)

    out = jax.vmap(group_combine)(eout, slot_g, keep_g, gate_g).reshape(T, D)

    # --- always-on shared experts (DeepSeekMoE) ---
    if m.num_shared > 0:
        sp = p["shared"]
        g = jax.nn.silu(xt @ sp["w_gate"].astype(dt))
        out = out + (g * (xt @ sp["w_up"].astype(dt))) @ sp["w_down"].astype(dt)

    return out.reshape(B, S, D), aux


def _moe_forward_spmd(p: Params, cfg: ModelConfig, x: jnp.ndarray, mesh) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Manually partitioned MoE (§Perf-2, beyond-paper).

    Layout: tokens sharded over the (pod, data) axes (replicated over
    "model"); expert weights sharded over "model" (E_local experts per
    device). Every model-row device routes ITS token shard redundantly
    (router is tiny), dispatches LOCALLY into buffers for its own E_local
    experts only, and the per-expert partial outputs are summed with ONE
    psum over "model" — the same collective shape as a tensor-parallel
    FFN. No scatter ever crosses devices.
    """
    from jax.sharding import PartitionSpec as P

    m: MoEConfig = cfg.moe
    B, S, D = x.shape
    dt = x.dtype
    daxes = tuple(a for a in mesh.axis_names if a != "model")
    import numpy as np

    d_size = int(np.prod([mesh.shape[a] for a in daxes]))
    x_spec = P(daxes) if B % d_size == 0 else P()
    n_model = mesh.shape["model"]
    e_local = m.num_experts // n_model

    def body(xb, router, w_gate, w_up, w_down):
        # xb (B_l, S, D); router (D, E) replicated; w_* (E_l, D, F) local
        Bl = xb.shape[0]
        Tl = Bl * S
        E, K = m.num_experts, m.top_k
        xt = xb.reshape(Tl, D)
        logits = (xt @ router.astype(dt)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_ids = jax.lax.top_k(probs, K)
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

        disp = jnp.zeros((E,), jnp.float32).at[expert_ids.reshape(-1)].add(1.0) / (Tl * K)
        aux_l = E * jnp.sum(disp * probs.mean(0))
        aux_l = jax.lax.pmean(aux_l, daxes) if x_spec != P() else aux_l

        # local ranks over the LOCAL token shard (GShard per-group capacity)
        C = max(int(m.capacity_factor * Tl * K / E), 1)
        eid = expert_ids.reshape(-1)
        order = jnp.argsort(eid, stable=True)
        counts = jnp.zeros((E,), jnp.int32).at[eid].add(1)
        seg_start = jnp.cumsum(counts) - counts
        rank_sorted = jnp.arange(Tl * K, dtype=jnp.int32) - seg_start[eid[order]]
        rank = jnp.zeros((Tl * K,), jnp.int32).at[order].set(rank_sorted)
        keep = (rank < C).astype(dt)
        tok = jnp.repeat(jnp.arange(Tl), K)

        # keep only assignments belonging to THIS device's experts
        m_idx = jax.lax.axis_index("model")
        e_lo = m_idx * e_local
        mine = ((eid >= e_lo) & (eid < e_lo + e_local)).astype(dt)
        keep = keep * mine
        slot = (eid - e_lo).clip(0, e_local - 1) * C + jnp.minimum(rank, C - 1)

        buf = jnp.zeros((e_local * C, D), dt).at[slot].add(xt[tok] * keep[:, None])
        buf = buf.reshape(e_local, C, D)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(dt)))
        h = h * jnp.einsum("ecd,edf->ecf", buf, w_up.astype(dt))
        eout = jnp.einsum("ecf,efd->ecd", h, w_down.astype(dt)).reshape(e_local * C, D)

        per_assign = eout[slot] * (keep * gate_vals.reshape(-1).astype(dt))[:, None]
        out_l = jnp.zeros((Tl, D), dt).at[tok].add(per_assign)
        # each model row holds partial sums for its experts only -> ONE psum
        out_l = jax.lax.psum(out_l, "model")
        return out_l.reshape(Bl, S, D), aux_l

    out, aux = compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(x_spec, P(), P("model"), P("model"), P("model")),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])

    if m.num_shared > 0:
        sp = p["shared"]
        xt = x.reshape(B * S, D)
        g = jax.nn.silu(xt @ sp["w_gate"].astype(dt))
        shared = (g * (xt @ sp["w_up"].astype(dt))) @ sp["w_down"].astype(dt)
        out = out + shared.reshape(B, S, D)
    return out, aux
