"""Model configuration for the assigned-architecture fleet.

One frozen dataclass describes every architecture family the framework
supports (dense / MoE / MLA / SSM / hybrid / enc-dec / VLM). Hashable so it
can ride in jit static args; every config file in ``repro.configs`` builds
exactly one of these (plus a reduced smoke variant).

Layer composition uses ``block_pattern``: the temporal-mixing kind of each
layer, cycled (e.g. RecurrentGemma's ("rglru", "rglru", "local_attn")).
The model scans over whole pattern periods with stacked params — HLO size
stays O(period), not O(num_layers) (DESIGN.md §5; essential for lowering
the 80-layer configs with 512 virtual devices on one CPU).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int  # per-expert FFN hidden size
    num_shared: int = 0  # always-on shared experts (DeepSeekMoE)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01  # load-balance loss weight
    first_layer_dense: bool = False  # DeepSeekMoE: layer 0 keeps a dense FFN
    dense_d_ff: int = 0  # d_ff of that dense layer
    dispatch_groups: int = 32  # GShard-style rank/capacity groups, aligned
    # with the data shards so dispatch ranks never cross devices (§Perf)

    def __post_init__(self) -> None:
        if self.num_experts <= 0 or self.d_expert <= 0:
            raise ValueError("num_experts and d_expert must be positive")
        if not 1 <= self.top_k <= self.num_experts:
            raise ValueError(f"top_k={self.top_k} outside [1, {self.num_experts}]")
        if self.num_shared < 0 or self.capacity_factor <= 0 or self.dispatch_groups <= 0:
            raise ValueError("num_shared >= 0, capacity_factor/dispatch_groups > 0")
        if self.first_layer_dense and self.dense_d_ff <= 0:
            raise ValueError("first_layer_dense requires dense_d_ff > 0")


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3)."""

    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int

    def __post_init__(self) -> None:
        if self.q_lora_rank < 0 or self.kv_lora_rank <= 0:
            raise ValueError("q_lora_rank >= 0 and kv_lora_rank > 0 required")
        if min(self.qk_nope_head_dim, self.qk_rope_head_dim, self.v_head_dim) <= 0:
            raise ValueError("MLA head dims must be positive")


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec models (whisper). The modality frontend is
    a stub per the assignment: inputs arrive as precomputed frame embeddings."""

    num_layers: int
    num_frames: int  # encoder sequence length (whisper-base: 1500)
    frontend_dim: int  # embedding dim delivered by the stubbed conv frontend

    def __post_init__(self) -> None:
        if min(self.num_layers, self.num_frames, self.frontend_dim) <= 0:
            raise ValueError("encoder dims must be positive")


@dataclasses.dataclass(frozen=True)
class VisionStubConfig:
    """VLM frontend stub: precomputed ViT patch embeddings + MLP projector."""

    num_patches: int  # patches prepended per sample
    vit_dim: int  # patch embedding dim delivered by the stubbed ViT

    def __post_init__(self) -> None:
        if self.num_patches <= 0 or self.vit_dim <= 0:
            raise ValueError("vision stub dims must be positive")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    # --- layer composition ---
    block_pattern: tuple[str, ...] = ("attn",)
    # block kinds: attn | local_attn | mla | mlstm | slstm | rglru
    mlp_kind: str = "swiglu"  # swiglu | gelu | none (ssm blocks own their mlp)
    # --- attention options ---
    qk_norm: bool = False
    qkv_bias: bool = False
    sliding_window: int = 0  # window for local_attn blocks (0 = unset)
    attn_q_chunk: int = 0  # query-chunked attention at train/prefill (0=off):
    # serializes the (.., S, S) logits to (.., chunk, S) via lax.map +
    # per-chunk remat — the memory lever when heads cannot shard (§Perf)
    rope_theta: float = 10000.0
    pos_kind: str = "rope"  # rope | learned | none
    max_position: int = 0  # for learned positions (0 = unused)
    # --- recurrent options ---
    rnn_width: int = 0  # RG-LRU / xLSTM inner width (0 -> d_model)
    conv_width: int = 4  # temporal conv in recurrent blocks
    # --- sub-configs ---
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    encoder: EncoderConfig | None = None
    vision: VisionStubConfig | None = None
    # --- misc ---
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"  # activation dtype (params stay fp32)
    remat: bool = True  # activation checkpointing over pattern periods
    unroll: bool = False  # python-loop periods instead of lax.scan (used by
    # the dry-run's reduced-depth cost measurements: XLA cost_analysis
    # counts a while body once, unrolled bodies are counted per period)
    citation: str = ""

    def __post_init__(self) -> None:
        # construction-time validation (RR004): the full cross-field check
        # lives in validate(); calling it here means an illegal combination
        # can never travel past the constructor.
        self.validate()

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab_size(self) -> int:
        """Vocab padded to a multiple of 256 so embeddings/logits shard on
        any mesh axis (measured: minicpm3's 73448 vocab left an UNSHARDED
        17.9 GiB fp32 logits buffer per device — EXPERIMENTS.md §Perf).
        Padded slots are masked to -inf in the logits; targets never
        reference them."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def period(self) -> int:
        return len(self.block_pattern)

    @property
    def num_periods(self) -> int:
        return self.num_layers // self.period

    @property
    def remainder_pattern(self) -> tuple[str, ...]:
        return self.block_pattern[: self.num_layers % self.period]

    def validate(self) -> "ModelConfig":
        assert self.arch_type in ("dense", "moe", "ssm", "hybrid", "vlm", "audio"), self.arch_type
        assert self.num_heads % max(self.num_kv_heads, 1) == 0, "GQA group size"
        for b in self.block_pattern:
            assert b in ("attn", "local_attn", "mla", "mlstm", "slstm", "rglru"), b
        if "mla" in self.block_pattern:
            assert self.mla is not None
        if "local_attn" in self.block_pattern:
            assert self.sliding_window > 0
        if self.arch_type == "moe":
            assert self.moe is not None
        if self.arch_type == "audio":
            assert self.encoder is not None
        if self.arch_type == "vlm":
            assert self.vision is not None
        if self.pos_kind == "learned":
            assert self.max_position > 0
        return self

    def has_attention(self) -> bool:
        return any(b in ("attn", "local_attn", "mla") for b in self.block_pattern)

    def is_subquadratic(self) -> bool:
        """True if no block attends to unbounded context (long_500k eligible
        natively — SSM/hybrid/SWA archs)."""
        return not any(b in ("attn", "mla") for b in self.block_pattern)
