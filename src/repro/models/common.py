"""Shared neural building blocks: norms, RoPE, MLPs, initializers.

Params are plain nested dicts of jnp arrays (pytrees): no framework dep,
trivially checkpointable, and sharding rules match on dict paths.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

Params = dict[str, jnp.ndarray]


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------


def dense_init(key: jax.Array, shape: tuple[int, ...], in_axis: int = 0) -> jnp.ndarray:
    """LeCun-normal in fp32 (params are always fp32; activations may be bf16)."""
    fan_in = shape[in_axis]
    return (jax.random.normal(key, shape) / jnp.sqrt(fan_in)).astype(jnp.float32)


def embed_init(key: jax.Array, shape: tuple[int, ...]) -> jnp.ndarray:
    return (jax.random.normal(key, shape) * 0.02).astype(jnp.float32)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * scale).astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean((x32 - mu) ** 2, axis=-1, keepdims=True)
    return (((x32 - mu) * jax.lax.rsqrt(var + eps)) * scale + bias).astype(dt)


# --------------------------------------------------------------------------
# rotary position embedding
# --------------------------------------------------------------------------


def rope_angles(positions: jnp.ndarray, dim: int, theta: float) -> tuple[jnp.ndarray, jnp.ndarray]:
    """positions (...,) -> cos/sin tables (..., dim/2)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., dim/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x (B, S, H, hd) with cos/sin (S, hd/2) — rotate-half convention.

    Positions are shared across the batch (no per-row offsets in this
    framework's pipelines), so the tables broadcast as (1, S, 1, hd/2).
    """
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[None, :, None, :].astype(jnp.float32)
    s = sin[None, :, None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([x1f * c - x2f * s, x2f * c + x1f * s], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------


def init_mlp(key: jax.Array, d_model: int, d_ff: int, kind: str) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    if kind == "swiglu":
        return {
            "w_gate": dense_init(k1, (d_model, d_ff)),
            "w_up": dense_init(k2, (d_model, d_ff)),
            "w_down": dense_init(k3, (d_ff, d_model)),
        }
    if kind == "gelu":
        return {
            "w_up": dense_init(k1, (d_model, d_ff)),
            "b_up": jnp.zeros((d_ff,), jnp.float32),
            "w_down": dense_init(k2, (d_ff, d_model)),
            "b_down": jnp.zeros((d_model,), jnp.float32),
        }
    raise ValueError(kind)


def apply_mlp(p: Params, x: jnp.ndarray, kind: str) -> jnp.ndarray:
    dt = x.dtype
    if kind == "swiglu":
        gate = jax.nn.silu(x @ p["w_gate"].astype(dt))
        return (gate * (x @ p["w_up"].astype(dt))) @ p["w_down"].astype(dt)
    if kind == "gelu":
        h = jax.nn.gelu(x @ p["w_up"].astype(dt) + p["b_up"].astype(dt))
        return h @ p["w_down"].astype(dt) + p["b_down"].astype(dt)
    raise ValueError(kind)


def causal_mask(sq: int, skv: int, offset: jnp.ndarray | int = 0) -> jnp.ndarray:
    """(sq, skv) bool mask: query i attends kv j iff j <= i + offset."""
    qi = jnp.arange(sq)[:, None] + offset
    kj = jnp.arange(skv)[None, :]
    return kj <= qi


def window_mask(sq: int, skv: int, window: int, offset: jnp.ndarray | int = 0) -> jnp.ndarray:
    """Causal + sliding window: i - window < j <= i (absolute positions)."""
    qi = jnp.arange(sq)[:, None] + offset
    kj = jnp.arange(skv)[None, :]
    return (kj <= qi) & (kj > qi - window)
