"""xLSTM blocks: chunkwise-parallel mLSTM and sequential sLSTM.

mLSTM (matrix memory, Beck et al. 2024):
    C_t = f_t C_{t-1} + i_t v_t k_t^T        (per head; C is dk x dv)
    n_t = f_t n_{t-1} + i_t k_t
    h_t = C_t^T q_t / max(|n_t . q_t|, 1)

TPU adaptation (DESIGN.md): training uses the CHUNKWISE form — within a
chunk of length c the contribution of in-chunk tokens is a masked
attention-like (c x c) matmul (MXU work), and only chunk-boundary states
are carried through a short lax.scan (S/c steps). This bounds scan length
and residual memory, where the naive per-token scan would carry the full
(dk x dv) matrix state S times. Gates: f = sigmoid(f~) (decay <= 1 keeps
the in-chunk decay ratios d_t/d_s <= 1, so no log-space max-stabilizer is
needed — a documented simplification of the paper's exp-gate option),
i = exp(clamped i~).

sLSTM (scalar memory, genuinely nonlinear recurrence via h_{t-1} feedback)
cannot be parallelized over time; it runs as a true lax.scan. Its carries
are O(width) vectors so the memory is fine at any S.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.common import Params, dense_init, rms_norm
from repro.models.config import ModelConfig

_ICLAMP = 8.0  # clamp on the exp input-gate preactivation


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------


def _mlstm_dims(cfg: ModelConfig):
    inner = cfg.rnn_width or 2 * cfg.d_model
    H = cfg.num_heads
    dh = inner // H  # per-head q/k/v dim
    return inner, H, dh


def init_mlstm_params(key: jax.Array, cfg: ModelConfig) -> Params:
    D = cfg.d_model
    inner, H, dh = _mlstm_dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], (D, inner)),  # main branch
        "w_gate": dense_init(ks[1], (D, inner)),  # output gating branch
        "conv": (jax.random.normal(ks[2], (cfg.conv_width, inner)) / cfg.conv_width).astype(jnp.float32),
        "wq": dense_init(ks[3], (inner, inner)),
        "wk": dense_init(ks[4], (inner, inner)),
        "wv": dense_init(ks[5], (inner, inner)),
        "w_if": dense_init(ks[6], (inner, 2 * H)),  # input & forget gates per head
        "b_if": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]).astype(jnp.float32),
        "out_norm": jnp.ones((inner,), jnp.float32),
        "w_down": dense_init(ks[7], (inner, D)),
    }


def _chunk_mlstm(q, k, v, i_gate, f_gate, state, norm):
    """One chunk. q,k,v (B,H,c,dh); i/f gates (B,H,c); state (B,H,dh,dh);
    norm (B,H,dh). Returns h (B,H,c,dh), new state, new norm."""
    Bc = q.shape[2]
    f32 = jnp.float32
    q, k, v = q.astype(f32), k.astype(f32), v.astype(f32)
    logf = jnp.log(f_gate + 1e-12)  # <= 0
    cum = jnp.cumsum(logf, axis=-1)  # (B,H,c) log d_t
    d = jnp.exp(cum)
    # intra-chunk "attention": A[t,s] = (d_t/d_s) i_s (q_t . k_s), s <= t
    ratio = jnp.exp(cum[..., :, None] - cum[..., None, :])  # (B,H,c,c) = d_t/d_s
    mask = jnp.tril(jnp.ones((Bc, Bc), bool))
    ratio = jnp.where(mask, ratio, 0.0)
    decay_w = ratio * i_gate[..., None, :]  # (B,H,t,s) = (d_t/d_s) i_s, masked
    scores = jnp.einsum("bhtd,bhsd->bhts", q, k) * decay_w
    intra = jnp.einsum("bhts,bhsd->bhtd", scores, v)
    # normalizer numerator n_t = d_t n_0 + sum_s (d_t/d_s) i_s k_s (q-free)
    intra_n = jnp.einsum("bhts,bhsd->bhtd", decay_w, k)
    # inter-chunk: contribution of the incoming state
    inter = d[..., None] * jnp.einsum("bhtd,bhde->bhte", q, state)
    inter_n = d[..., None] * norm[:, :, None, :]
    h_num = intra + inter
    n_vec = intra_n + inter_n  # (B,H,c,dh)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhtd,bhtd->bht", n_vec, q)), 1.0)
    h = h_num / denom[..., None]
    # chunk-end state: C_c = d_c C_0 + sum_s (d_c/d_s) i_s k_s v_s^T
    w = (jnp.exp(cum[..., -1:] - cum) * i_gate)[..., None]  # (B,H,c,1)
    new_state = d[..., -1, None, None] * state + jnp.einsum("bhsd,bhse->bhde", k * w, v)
    new_norm = d[..., -1, None] * norm + jnp.sum(k * w, axis=2)
    return h, new_state, new_norm


def mlstm_forward(
    p: Params,
    cfg: ModelConfig,
    x: jnp.ndarray,
    *,
    cache: Params | None = None,
    chunk: int = 64,
) -> tuple[jnp.ndarray, Params | None]:
    """x (B, S, D) -> (out, cache {"conv","state","norm"})."""
    from repro.models.rglru import _causal_conv  # shared depthwise conv

    B, S, D = x.shape
    inner, H, dh = _mlstm_dims(cfg)
    dt = x.dtype
    z = x @ p["w_gate"].astype(dt)  # output gate branch
    u = x @ p["w_up"].astype(dt)
    conv_state = cache["conv"] if cache is not None else None
    u, new_conv = _causal_conv(u, p["conv"], conv_state)
    u = jax.nn.silu(u)

    def heads(w):
        return (u @ w.astype(dt)).reshape(B, S, H, dh).transpose(0, 2, 1, 3)

    q, k, v = heads(p["wq"]), heads(p["wk"]) / jnp.sqrt(dh), heads(p["wv"])
    gates = (u @ p["w_if"].astype(dt)).astype(jnp.float32) + p["b_if"]  # (B,S,2H)
    i_gate = jnp.exp(jnp.minimum(gates[..., :H], _ICLAMP)).transpose(0, 2, 1)  # (B,H,S)
    f_gate = jax.nn.sigmoid(gates[..., H:]).transpose(0, 2, 1)

    state = (
        cache["state"].astype(jnp.float32)
        if cache is not None
        else jnp.zeros((B, H, dh, dh), jnp.float32)
    )
    norm = (
        cache["norm"].astype(jnp.float32)
        if cache is not None
        else jnp.zeros((B, H, dh), jnp.float32)
    )

    if S == 1 and cache is not None:
        # decode: exact single-step recurrence
        f1 = f_gate[..., 0][..., None, None]
        i1 = i_gate[..., 0][..., None, None]
        new_state = f1 * state + i1 * (k[:, :, 0, :, None] * v[:, :, 0, None, :])
        new_norm = f1[..., 0] * norm + i1[..., 0] * k[:, :, 0]
        hq = jnp.einsum("bhde,bhd->bhe", new_state, q[:, :, 0].astype(jnp.float32))
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bhd,bhd->bh", new_norm, q[:, :, 0].astype(jnp.float32))), 1.0
        )
        h = (hq / den[..., None])[:, :, None]  # (B,H,1,dh)
    else:
        pad = (-S) % chunk
        if pad:
            def zpad(a, ax):
                return jnp.pad(a, [(0, pad if i == ax else 0) for i in range(a.ndim)])

            q, k, v = zpad(q, 2), zpad(k, 2), zpad(v, 2)
            i_gate = zpad(i_gate, 2)
            f_gate = jnp.pad(f_gate, ((0, 0), (0, 0), (0, pad)), constant_values=1.0)
        nch = q.shape[2] // chunk

        def resh(a):
            return a.reshape(B, H, nch, chunk, -1).transpose(2, 0, 1, 3, 4)

        qc, kc, vc = resh(q), resh(k), resh(v)
        gi = i_gate.reshape(B, H, nch, chunk).transpose(2, 0, 1, 3)
        gf = f_gate.reshape(B, H, nch, chunk).transpose(2, 0, 1, 3)

        def body(carry, xs):
            st, nm = carry
            qx, kx, vx, ix, fx = xs
            h, st2, nm2 = _chunk_mlstm(qx, kx, vx, ix, fx, st, nm)
            return (st2, nm2), h

        (new_state, new_norm), hs = jax.lax.scan(body, (state, norm), (qc, kc, vc, gi, gf))
        h = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, nch * chunk, dh)[:, :, :S]

    hflat = h.transpose(0, 2, 1, 3).reshape(B, S, inner).astype(dt)
    hflat = rms_norm(hflat, p["out_norm"], cfg.norm_eps)
    out = (hflat * jax.nn.silu(z)) @ p["w_down"].astype(dt)
    new_cache = None
    if cache is not None:
        new_cache = {
            "conv": new_conv,
            "state": new_state.astype(cache["state"].dtype),
            "norm": new_norm.astype(cache["norm"].dtype),
        }
    return out, new_cache


def init_mlstm_cache(cfg: ModelConfig, batch: int, dtype) -> Params:
    inner, H, dh = _mlstm_dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, inner), dtype),
        "state": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "norm": jnp.zeros((batch, H, dh), jnp.float32),
    }


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------


def init_slstm_params(key: jax.Array, cfg: ModelConfig) -> Params:
    D = cfg.d_model
    W = cfg.rnn_width or cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        "w_in": dense_init(ks[0], (D, 4 * W)),  # z, i, f, o preactivations
        "r": dense_init(ks[1], (W, 4 * W)),  # recurrent weights (h feedback)
        "b": jnp.zeros((4 * W,), jnp.float32).at[2 * W : 3 * W].set(1.0),
        "out_norm": jnp.ones((W,), jnp.float32),
        "w_down": dense_init(ks[2], (W, D)),
    }


def slstm_forward(
    p: Params,
    cfg: ModelConfig,
    x: jnp.ndarray,
    *,
    cache: Params | None = None,
) -> tuple[jnp.ndarray, Params | None]:
    """Sequential sLSTM with stabilized exponential gating.

    Carries (c, n, h, m): cell, normalizer, hidden, log-max stabilizer.
    """
    B, S, D = x.shape
    W = cfg.rnn_width or cfg.d_model
    dt = x.dtype
    pre = (x @ p["w_in"].astype(dt)).astype(jnp.float32)  # (B,S,4W)

    if cache is not None:
        c0 = cache["c"].astype(jnp.float32)
        n0 = cache["n"].astype(jnp.float32)
        h0 = cache["h"].astype(jnp.float32)
        m0 = cache["m"].astype(jnp.float32)
    else:
        c0 = n0 = h0 = jnp.zeros((B, W), jnp.float32)
        m0 = jnp.full((B, W), -1e30, jnp.float32)

    r = p["r"].astype(jnp.float32)
    b = p["b"]

    def step(carry, pre_t):
        c, n, h, m = carry
        g = pre_t + h @ r + b  # (B, 4W)
        z_t = jnp.tanh(g[:, :W])
        i_t = g[:, W : 2 * W]  # log-space input gate
        f_t = jax.nn.log_sigmoid(g[:, 2 * W : 3 * W])  # log forget
        o_t = jax.nn.sigmoid(g[:, 3 * W :])
        m2 = jnp.maximum(f_t + m, i_t)
        ip = jnp.exp(i_t - m2)
        fp = jnp.exp(f_t + m - m2)
        c2 = fp * c + ip * z_t
        n2 = fp * n + ip
        h2 = o_t * c2 / jnp.maximum(n2, 1.0)
        return (c2, n2, h2, m2), h2

    (c_f, n_f, h_f, m_f), hs = jax.lax.scan(step, (c0, n0, h0, m0), pre.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2).astype(dt)  # (B,S,W)
    h = rms_norm(h, p["out_norm"], cfg.norm_eps)
    out = h @ p["w_down"].astype(dt)
    new_cache = None
    if cache is not None:
        new_cache = {
            "c": c_f.astype(cache["c"].dtype),
            "n": n_f.astype(cache["n"].dtype),
            "h": h_f.astype(cache["h"].dtype),
            "m": m_f.astype(cache["m"].dtype),
        }
    return out, new_cache


def init_slstm_cache(cfg: ModelConfig, batch: int, dtype) -> Params:
    W = cfg.rnn_width or cfg.d_model

    def z():
        return jnp.zeros((batch, W), jnp.float32)

    return {"c": z(), "n": z(), "h": z(), "m": jnp.full((batch, W), -1e30, jnp.float32)}
