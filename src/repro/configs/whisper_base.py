"""whisper-base [audio] — encoder-decoder; conv/mel frontend STUBBED.

6L (x2: 6 encoder + 6 decoder) d_model=512 8H d_ff=2048 vocab=51865,
GeLU MLPs, learned positions, cross-attention from decoder to the 1500
stub frame embeddings. Whisper's real decoder context is 448; we keep a
4096-entry learned table (positions beyond it clamp) so the assigned
train_4k shape lowers — noted deviation. [arXiv:2212.04356]
"""
import dataclasses

from repro.models.config import EncoderConfig, ModelConfig

FULL = ModelConfig(
    name="whisper-base",
    arch_type="audio",
    num_layers=6,  # decoder layers; encoder carries its own 6 below
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    mlp_kind="gelu",
    pos_kind="learned",
    max_position=4096,
    encoder=EncoderConfig(num_layers=6, num_frames=1500, frontend_dim=512),
    citation="arXiv:2212.04356",
).validate()


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL,
        name="whisper-base-smoke",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        max_position=256,
        dtype="float32",
        encoder=EncoderConfig(num_layers=2, num_frames=20, frontend_dim=64),
    ).validate()
