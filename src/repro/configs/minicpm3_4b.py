"""minicpm3-4b [dense] — Multi-head Latent Attention (MLA).

62L d_model=2560 40H d_ff=6400 vocab=73448; MLA q_lora=768 kv_lora=256,
qk_nope=64 qk_rope=32 v_head=64 (per the HF config). The decode cache
stores compressed latents — natively long-context, so long_500k runs the
REAL architecture (no SWA variant needed). [hf:openbmb/MiniCPM3-4B]
"""
import dataclasses

from repro.models.config import MLAConfig, ModelConfig

FULL = ModelConfig(
    name="minicpm3-4b",
    arch_type="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    block_pattern=("mla",),
    mla=MLAConfig(
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
    ),
    citation="hf:openbmb/MiniCPM3-4B",
).validate()


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL,
        name="minicpm3-4b-smoke",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        dtype="float32",
        mla=MLAConfig(
            q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=16,
            qk_rope_head_dim=8, v_head_dim=16,
        ),
    ).validate()
