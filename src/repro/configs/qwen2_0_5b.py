"""qwen2-0.5b [dense] — GQA with QKV bias, tied embeddings.

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936. [arXiv:2407.10671]
"""
import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen2-0.5b",
    arch_type="dense",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1000000.0,
    citation="arXiv:2407.10671",
).validate()


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL,
        name="qwen2-0.5b-smoke",
        num_layers=2,
        d_model=112,  # keeps 14 heads x head_dim 8
        num_heads=14,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        dtype="float32",
    ).validate()
