from repro.configs.registry import ARCH_IDS, get, get_smoke, swa_variant
from repro.configs.shapes import INPUT_SHAPES, input_specs

__all__ = ["ARCH_IDS", "get", "get_smoke", "swa_variant", "INPUT_SHAPES", "input_specs"]
