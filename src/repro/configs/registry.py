"""Architecture registry: ``get(arch_id)`` -> (full config, smoke config).

Every assigned architecture is a module exposing ``FULL`` (the exact
published configuration, citation included) and ``smoke()`` (a reduced
same-family variant: <=2 pattern repeats, d_model<=512, <=4 experts, tiny
vocab — runnable on one CPU in a test).
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

ARCH_IDS: list[str] = [
    "deepseek_moe_16b",
    "internvl2_76b",
    "qwen2_0_5b",
    "minicpm3_4b",
    "qwen3_0_6b",
    "whisper_base",
    "xlstm_350m",
    "recurrentgemma_2b",
    "qwen3_moe_30b_a3b",
    "h2o_danube_3_4b",
]

# external (dashed) ids <-> module names
def canon(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def get(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canon(arch_id)}")
    return mod.FULL


def get_smoke(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canon(arch_id)}")
    return mod.smoke()


def swa_variant(cfg: ModelConfig, window: int = 4096) -> ModelConfig:
    """Sliding-window variant for long_500k decode of quadratic-attention
    archs (explicitly permitted by the assignment; recorded in DESIGN.md §5).
    MLA keeps its native compressed cache (that IS its long-context form)."""
    if cfg.is_subquadratic() or "mla" in cfg.block_pattern:
        return cfg
    pattern = tuple("local_attn" if b == "attn" else b for b in cfg.block_pattern)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "+swa",
        block_pattern=pattern,
        sliding_window=cfg.sliding_window or window,
    )
