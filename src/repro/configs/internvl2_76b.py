"""internvl2-76b [vlm] — InternViT frontend (STUB) + Llama-3-70B-class LLM.

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256. The ViT/projector
frontend is stubbed per the assignment: input_specs delivers precomputed
patch embeddings (256 patches x 3200 = InternViT-6B width); the projector
MLP and the full language backbone are real. [arXiv:2404.16821]
"""
import dataclasses

from repro.models.config import ModelConfig, VisionStubConfig

FULL = ModelConfig(
    name="internvl2-76b",
    arch_type="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=500000.0,  # llama-3 base frequency
    vision=VisionStubConfig(num_patches=256, vit_dim=3200),
    citation="arXiv:2404.16821",
).validate()


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL,
        name="internvl2-76b-smoke",
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        d_ff=512,
        vocab_size=512,
        dtype="float32",
        vision=VisionStubConfig(num_patches=8, vit_dim=96),
    ).validate()
