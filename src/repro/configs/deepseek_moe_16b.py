"""deepseek-moe-16b [moe] — fine-grained MoE: 2 shared + 64 routed top-6.

28L d_model=2048 16H (MHA, kv=16) d_ff=1408(expert) vocab=102400.
Layer 0 keeps a dense FFN (d_ff 10944), per the paper. [arXiv:2401.06066]
"""
import dataclasses

from repro.models.config import ModelConfig, MoEConfig

FULL = ModelConfig(
    name="deepseek-moe-16b",
    arch_type="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    rope_theta=10000.0,
    moe=MoEConfig(
        num_experts=64,
        top_k=6,
        d_expert=1408,
        num_shared=2,
        capacity_factor=1.25,
        first_layer_dense=True,
        dense_d_ff=10944,
    ),
    citation="arXiv:2401.06066",
).validate()


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL,
        name="deepseek-moe-16b-smoke",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=64,
        vocab_size=512,
        dtype="float32",
        moe=MoEConfig(
            num_experts=4, top_k=2, d_expert=64, num_shared=2,
            capacity_factor=1.25, first_layer_dense=True, dense_d_ff=256,
        ),
    ).validate()
