"""psvgp-e3sm — the paper's own experiment configuration (§5).

48,602 observations, 20x20 = 400 partitions for the CPU/benchmark runs;
the TPU dry-run uses a 16x16 = 256-partition grid mapped one-partition-
per-device onto the production mesh (32x16 = 512 for multi-pod), per
DESIGN.md §2. m = 5 inducing points (the paper's in-situ operating point;
fig. 4 also reports m = 10, 20 — see benchmarks/bench_delta.py).
"""
from __future__ import annotations

import dataclasses

from repro.core.psvgp import PSVGPConfig
from repro.core.svgp import SVGPConfig


@dataclasses.dataclass(frozen=True)
class E3SMExperiment:
    n_obs: int = 48602
    grid: tuple[int, int] = (20, 20)  # the paper's N_part = 400
    num_inducing: int = 5
    delta: float = 0.125  # the paper's best boundary-smoothness setting
    batch_size: int = 32
    learning_rate: float = 0.05  # calibrated: delta's fig-4 effect needs
    # converged local models (see EXPERIMENTS.md §Repro regime note)
    iters: int = 2500
    probes_per_edge: int = 23  # ~the paper's 17,556 boundary locations
    seed: int = 0

    def __post_init__(self) -> None:
        if self.n_obs <= 0 or self.num_inducing <= 0:
            raise ValueError("n_obs and num_inducing must be positive")
        if len(self.grid) != 2 or min(self.grid) < 1:
            raise ValueError(f"grid must be two positive cell counts, got {self.grid}")
        if self.delta < 0 or self.learning_rate <= 0:
            raise ValueError("delta >= 0 and learning_rate > 0 required")
        if min(self.batch_size, self.probes_per_edge) <= 0 or self.iters < 0:
            raise ValueError("batch_size/probes_per_edge > 0 and iters >= 0 required")

    def psvgp(self, comm: str = "gather", use_pallas: bool = False) -> PSVGPConfig:
        return PSVGPConfig(
            svgp=SVGPConfig(num_inducing=self.num_inducing, input_dim=2, use_pallas=use_pallas),
            delta=self.delta,
            batch_size=self.batch_size,
            learning_rate=self.learning_rate,
            comm=comm,
            seed=self.seed,
        )


FULL = E3SMExperiment()

# dry-run variant: grid == device grid (one partition per device)
DRYRUN_SINGLE_POD = dataclasses.replace(FULL, grid=(16, 16))
DRYRUN_MULTI_POD = dataclasses.replace(FULL, grid=(16, 32))  # 32 rows = pod x data


def smoke() -> E3SMExperiment:
    return dataclasses.replace(FULL, n_obs=2000, grid=(4, 4), iters=100)
