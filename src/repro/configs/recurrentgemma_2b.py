"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2 pattern.

26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000, window 2048,
rnn_width 2560. Pattern (rglru, rglru, local_attn) x 8 + 2 trailing rglru
(the remainder layers). Natively sub-quadratic -> long_500k runs as-is.
[arXiv:2402.19427]
"""
import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="recurrentgemma-2b",
    arch_type="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    sliding_window=2048,
    rnn_width=2560,
    conv_width=4,
    block_pattern=("rglru", "rglru", "local_attn"),
    citation="arXiv:2402.19427",
).validate()


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL,
        name="recurrentgemma-2b-smoke",
        num_layers=4,  # one full period + 1 remainder rglru
        d_model=128,
        num_heads=4,
        num_kv_heads=1,
        d_ff=256,
        vocab_size=512,
        sliding_window=16,
        rnn_width=128,
        dtype="float32",
    ).validate()
