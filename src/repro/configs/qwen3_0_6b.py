"""qwen3-0.6b [dense] — qk-norm GQA, explicit head_dim=128, tied embeddings.

28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936. [hf:Qwen/Qwen3-8B]
"""
import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen3-0.6b",
    arch_type="dense",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151936,
    qk_norm=True,
    tie_embeddings=True,
    rope_theta=1000000.0,
    citation="hf:Qwen/Qwen3-8B",
).validate()


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL,
        name="qwen3-0.6b-smoke",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        dtype="float32",
    ).validate()
