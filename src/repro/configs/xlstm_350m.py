"""xlstm-350m [ssm] — sLSTM + mLSTM blocks (no separate FFN, d_ff=0).

24L d_model=1024 4H vocab=50304; blocks own their up/down projections
(rnn_width = 2 x d_model). Pattern: 5 mLSTM : 1 sLSTM (the paper's
mLSTM-heavy ratio for this scale). [arXiv:2405.04517]
"""
import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="xlstm-350m",
    arch_type="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    mlp_kind="none",
    pos_kind="none",
    rnn_width=2048,
    block_pattern=("mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "slstm"),
    citation="arXiv:2405.04517",
).validate()


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL,
        name="xlstm-350m-smoke",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        rnn_width=256,
        vocab_size=512,
        dtype="float32",
        block_pattern=("mlstm", "slstm"),
    ).validate()
