"""h2o-danube-3-4b [dense] — llama+mistral mix with sliding-window attention.

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000, window 4096.
Natively sub-quadratic at decode (SWA ring cache) -> long_500k runs as-is.
[arXiv:2401.16818]
"""
import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="h2o-danube-3-4b",
    arch_type="dense",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    sliding_window=4096,
    block_pattern=("local_attn",),
    citation="arXiv:2401.16818",
).validate()


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL,
        name="h2o-danube-3-4b-smoke",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        sliding_window=16,
        dtype="float32",
    ).validate()
