"""qwen3-moe-30b-a3b [moe] — 128 routed experts, top-8, qk-norm GQA.

48L d_model=2048 32H (GQA kv=4, head_dim 128) expert d_ff=768
vocab=151936. No shared experts. [hf:Qwen/Qwen3-30B-A3B]
"""
import dataclasses

from repro.models.config import ModelConfig, MoEConfig

FULL = ModelConfig(
    name="qwen3-moe-30b-a3b",
    arch_type="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1000000.0,
    moe=MoEConfig(
        num_experts=128,
        top_k=8,
        d_expert=768,
        num_shared=0,
        capacity_factor=1.25,
    ),
    citation="hf:Qwen/Qwen3-30B-A3B",
).validate()


def smoke() -> ModelConfig:
    return dataclasses.replace(
        FULL,
        name="qwen3-moe-30b-a3b-smoke",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=64,
        vocab_size=512,
        dtype="float32",
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=64, num_shared=0, capacity_factor=1.25),
    ).validate()
