"""Assigned input shapes and ShapeDtypeStruct builders for the dry-run.

The four assigned (seq_len, global_batch) shapes. ``train_*`` lowers
train_step, ``prefill_*`` lowers prefill_step, ``decode_*``/``long_*``
lower decode_step (ONE new token against a seq_len cache).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


class InputShape(NamedTuple):
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape(4_096, 256, "train"),
    "prefill_32k": InputShape(32_768, 32, "prefill"),
    "decode_32k": InputShape(32_768, 128, "decode"),
    "long_500k": InputShape(524_288, 1, "decode"),
}


def input_specs(cfg: ModelConfig, shape_name: str) -> dict[str, jax.ShapeDtypeStruct]:
    """Data-side inputs as ShapeDtypeStructs (no allocation).

    For decode kinds this is the single-token input; the cache structs are
    built separately (jax.eval_shape over init_cache) by the dry-run.
    """
    sh = INPUT_SHAPES[shape_name]
    B = sh.global_batch
    i32 = jnp.int32
    f32 = jnp.float32

    if sh.kind == "decode":
        specs = {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
        return specs

    S = sh.seq_len
    specs = {}
    if cfg.vision is not None:
        P = cfg.vision.num_patches
        specs["tokens"] = jax.ShapeDtypeStruct((B, S - P), i32)
        specs["patches"] = jax.ShapeDtypeStruct((B, P, cfg.vision.vit_dim), f32)
        tgt = S - P
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        tgt = S
    if cfg.encoder is not None:
        e = cfg.encoder
        specs["frames"] = jax.ShapeDtypeStruct((B, e.num_frames, e.frontend_dim), f32)
    if sh.kind == "train":
        specs["targets"] = jax.ShapeDtypeStruct((B, tgt), i32)
    return specs
