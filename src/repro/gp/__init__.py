"""Gaussian-process substrate: covariance functions, exact GP, likelihoods."""
from repro.gp.covariances import (
    CovarianceParams,
    ard_distance2,
    matern32,
    matern52,
    rbf,
    make_covariance,
    init_covariance_params,
)
from repro.gp.exact import exact_gp_logml, exact_gp_predict
from repro.gp.likelihoods import gaussian_expected_loglik, poisson_expected_loglik

__all__ = [
    "CovarianceParams",
    "ard_distance2",
    "rbf",
    "matern32",
    "matern52",
    "make_covariance",
    "init_covariance_params",
    "exact_gp_logml",
    "exact_gp_predict",
    "gaussian_expected_loglik",
    "poisson_expected_loglik",
]
