"""Likelihoods for the SVGP expected log-likelihood term.

The paper uses an iid Gaussian observation model (eq. 1) whose expectation
under q(f_i) = N(mu_i, s_i) is closed-form — that is the first two terms of
eq. (3). The Poisson likelihood (Gauss-Hermite quadrature) implements the
"extensions to non-Gaussian likelihoods" the paper's §6 names as future
work, for count data common in E3SM-like simulations.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

_LOG2PI = 1.8378770664093453

# 20-point Gauss-Hermite rule (physicists' convention), precomputed with
# numpy so no scipy dependency is needed at runtime. Kept as HOST arrays:
# converting at import time would initialize the jax backend, and the repo
# contract (see repro.launch.mesh) is that imports never touch device state
# — the sharded serving / dry-run entry points must still be able to force
# the virtual device count after modules are imported.
_GH_X, _GH_W = np.polynomial.hermite.hermgauss(20)
_INV_SQRT_PI = 1.0 / np.sqrt(np.pi)


def gaussian_expected_loglik(y, fmean, fvar, log_beta):
    """E_{q(f)}[log N(y | f, beta^{-1})], elementwise.

    = log N(y | fmean, beta^{-1}) - beta/2 * fvar
    which is exactly how eq. (3) splits into its first two terms.
    """
    beta = jnp.exp(log_beta)
    return (
        0.5 * log_beta
        - 0.5 * _LOG2PI
        - 0.5 * beta * (y - fmean) ** 2
        - 0.5 * beta * fvar
    )


def poisson_expected_loglik(y, fmean, fvar, log_beta=None):
    """E_{q(f)}[log Poisson(y | exp(f))], closed form for the log link:

    log p(y|f) = y f - exp(f) - log(y!);  E[y f] = y fmean and
    E[exp(f)] = exp(fmean + fvar/2) under q(f) = N(fmean, fvar).
    The exponent is clamped (rate <= e^15) so early-training excursions of
    the variational mean cannot overflow to inf/NaN gradients.
    log_beta is accepted (and ignored) for interface uniformity.
    """
    from jax.scipy.special import gammaln

    x = fmean + 0.5 * fvar
    # linearized overflow guard: exp(x) for x <= 15, first-order expansion
    # beyond — unlike a hard clamp this keeps d/dx > 0, so a variational
    # mean that overshoots is still pulled back (hard clamp => runaway,
    # observed in the PSVGP count-data test).
    cap = 15.0
    e_rate = jnp.where(x <= cap, jnp.exp(jnp.minimum(x, cap)), jnp.exp(cap) * (1.0 + (x - cap)))
    return y * fmean - e_rate - gammaln(y + 1.0)


def poisson_expected_loglik_quadrature(y, fmean, fvar):
    """Quadrature version used only in tests to validate the closed form."""
    f = fmean[..., None] + jnp.sqrt(2.0 * fvar)[..., None] * jnp.asarray(_GH_X)  # (..., Q)
    from jax.scipy.special import gammaln

    logp = y[..., None] * f - jnp.exp(f) - gammaln(y + 1.0)[..., None]
    return _INV_SQRT_PI * jnp.sum(jnp.asarray(_GH_W) * logp, axis=-1)
