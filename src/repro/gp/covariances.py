"""Stationary covariance functions with ARD lengthscales.

All covariance functions take unconstrained ("log-space") parameters so the
optimizer can run unconstrained SGD/Adam, matching the paper's setup where
covariance hyperparameters kappa are learned jointly with the variational
parameters (eq. 3).

Shapes: X is (n, d), Z is (m, d). Output K(X, Z) is (n, m).
"""
from __future__ import annotations

from collections.abc import Callable
from typing import NamedTuple

import jax.numpy as jnp

_SQRT3 = 1.7320508075688772
_SQRT5 = 2.23606797749979


class CovarianceParams(NamedTuple):
    """Unconstrained covariance hyperparameters (a pytree leaf bundle).

    log_lengthscale: (d,) ARD log-lengthscales.
    log_variance:    ()   log process variance sigma^2.
    """

    log_lengthscale: jnp.ndarray
    log_variance: jnp.ndarray


def init_covariance_params(
    d: int, lengthscale: float = 1.0, variance: float = 1.0, dtype=jnp.float32
) -> CovarianceParams:
    return CovarianceParams(
        log_lengthscale=jnp.full((d,), jnp.log(lengthscale), dtype=dtype),
        log_variance=jnp.asarray(jnp.log(variance), dtype=dtype),
    )


def ard_distance2(x: jnp.ndarray, z: jnp.ndarray, log_lengthscale: jnp.ndarray) -> jnp.ndarray:
    """Squared scaled distance sum_k (x_k - z_k)^2 / l_k^2, shape (n, m).

    Uses the explicit-difference form (not the |x|^2+|z|^2-2xz expansion) for
    numerical robustness at small distances; d is tiny (2-3) for spatial data
    so the FLOP difference is irrelevant at this layer. The Pallas kernel in
    ``repro.kernels.rbf`` makes the same choice for the same reason.
    """
    inv_l = jnp.exp(-log_lengthscale)  # (d,)
    xs = x * inv_l  # (n, d)
    zs = z * inv_l  # (m, d)
    diff = xs[:, None, :] - zs[None, :, :]  # (n, m, d)
    return jnp.sum(diff * diff, axis=-1)


def rbf(params: CovarianceParams, x: jnp.ndarray, z: jnp.ndarray) -> jnp.ndarray:
    r2 = ard_distance2(x, z, params.log_lengthscale)
    return jnp.exp(params.log_variance) * jnp.exp(-0.5 * r2)


def matern32(params: CovarianceParams, x: jnp.ndarray, z: jnp.ndarray) -> jnp.ndarray:
    r = jnp.sqrt(ard_distance2(x, z, params.log_lengthscale) + 1e-20)
    return jnp.exp(params.log_variance) * (1.0 + _SQRT3 * r) * jnp.exp(-_SQRT3 * r)


def matern52(params: CovarianceParams, x: jnp.ndarray, z: jnp.ndarray) -> jnp.ndarray:
    r2 = ard_distance2(x, z, params.log_lengthscale)
    r = jnp.sqrt(r2 + 1e-20)
    return (
        jnp.exp(params.log_variance)
        * (1.0 + _SQRT5 * r + (5.0 / 3.0) * r2)
        * jnp.exp(-_SQRT5 * r)
    )


def periodic_lon_rbf(params: CovarianceParams, x: jnp.ndarray, z: jnp.ndarray) -> jnp.ndarray:
    """RBF, periodic in the FIRST input dimension (longitude) with period
    ``_LON_PERIOD`` in scaled units, plain RBF in the remaining dims.

    k = s^2 exp(-2 sin^2(pi (x0-z0)/P) / l0^2 - 0.5 sum_{d>0} (xd-zd)^2/ld^2)

    This lifts the 0/360-seam limitation documented in core/partition.py:
    with a periodic covariance the grid may wrap in longitude (wrap_x=True)
    and neighbor sampling across the seam becomes geometrically sound.
    """
    inv_l = jnp.exp(-params.log_lengthscale)
    d_lon = x[:, None, 0] - z[None, :, 0]
    s = jnp.sin(jnp.pi * d_lon / _LON_PERIOD)
    r2 = 4.0 * (s * inv_l[0]) ** 2
    diff = (x[:, None, 1:] - z[None, :, 1:]) * inv_l[1:]
    r2 = r2 + jnp.sum(diff * diff, axis=-1)
    return jnp.exp(params.log_variance) * jnp.exp(-0.5 * r2)


# data/spatial.py scales lon by 1/36 => full circle = 10 scaled units
_LON_PERIOD = 10.0

_REGISTRY: dict[str, Callable] = {
    "rbf": rbf,
    "matern32": matern32,
    "matern52": matern52,
    "periodic_lon_rbf": periodic_lon_rbf,
}


def make_covariance(name: str) -> Callable:
    """Look up a covariance function by name (config-file friendly)."""
    try:
        return _REGISTRY[name]
    except KeyError as e:
        raise ValueError(f"unknown covariance {name!r}; have {sorted(_REGISTRY)}") from e


def kdiag(params: CovarianceParams, x: jnp.ndarray) -> jnp.ndarray:
    """diag K(X, X) for any stationary kernel above: just the variance."""
    return jnp.full((x.shape[0],), jnp.exp(params.log_variance), dtype=x.dtype)
