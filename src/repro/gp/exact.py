"""Exact (dense) GP — the O(n^3) reference the paper's eq. (2) describes.

Used as the test oracle for the SVGP: the SVGP ELBO must lower-bound the
exact log marginal likelihood, and SVGP predictions must converge to exact
GP predictions as inducing points -> data points.
"""
from __future__ import annotations

from collections.abc import Callable

import jax.numpy as jnp
import jax.scipy.linalg as jsl

from repro.gp.covariances import CovarianceParams

_LOG2PI = 1.8378770664093453


def _chol(params: CovarianceParams, cov_fn: Callable, x, log_beta, jitter):
    n = x.shape[0]
    knn = cov_fn(params, x, x)
    noise = jnp.exp(-log_beta)  # beta is precision, noise variance = 1/beta
    return jnp.linalg.cholesky(knn + (noise + jitter) * jnp.eye(n, dtype=knn.dtype))


def exact_gp_logml(
    params: CovarianceParams,
    log_beta: jnp.ndarray,
    cov_fn: Callable,
    x: jnp.ndarray,
    y: jnp.ndarray,
    jitter: float = 1e-6,
) -> jnp.ndarray:
    """log N(y | 0, K(X,X) + beta^{-1} I)."""
    n = x.shape[0]
    chol = _chol(params, cov_fn, x, log_beta, jitter)
    alpha = jsl.cho_solve((chol, True), y)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diagonal(chol)))
    return -0.5 * (y @ alpha + logdet + n * _LOG2PI)


def exact_gp_predict(
    params: CovarianceParams,
    log_beta: jnp.ndarray,
    cov_fn: Callable,
    x: jnp.ndarray,
    y: jnp.ndarray,
    xstar: jnp.ndarray,
    jitter: float = 1e-6,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Posterior mean and variance at xstar — the paper's eq. (2)."""
    chol = _chol(params, cov_fn, x, log_beta, jitter)
    ks = cov_fn(params, x, xstar)  # (n, n*)
    alpha = jsl.cho_solve((chol, True), y)
    mean = ks.T @ alpha
    v = jsl.solve_triangular(chol, ks, lower=True)  # (n, n*)
    var = jnp.exp(params.log_variance) - jnp.sum(v * v, axis=0)
    return mean, jnp.maximum(var, 0.0)
