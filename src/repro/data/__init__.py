from repro.data.spatial import e3sm_like_field, SpatialDataset
from repro.data.tokens import synthetic_token_batches

__all__ = ["e3sm_like_field", "SpatialDataset", "synthetic_token_batches"]
