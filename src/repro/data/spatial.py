"""Synthetic E3SM-like spatial fields.

The paper's experiment uses one time slice of an E3SM climate simulation:
48,602 observations over the globe, partitioned 20x20 (400 unbalanced
partitions, 8..222 obs each, median ~150, pole partitions sparse). E3SM
output is not redistributable inside this container, so we synthesize a
surface-temperature-like field with the same geometry:

* observation locations ~ uniform on the sphere => density in (lon, lat)
  coordinates falls off as cos(lat), reproducing the paper's pole-sparse
  partition histogram;
* the field = latitudinal climate trend + smooth Gaussian random field
  (random Fourier features on the embedded sphere => stationary GRF with
  tunable correlation length) + small observation noise (eq. 1's epsilon).

Everything is deterministic given ``seed``.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class SpatialDataset(NamedTuple):
    x: np.ndarray  # (n, 2) scaled (lon, lat) coordinates used as GP inputs
    y: np.ndarray  # (n,) standardized observations
    lonlat: np.ndarray  # (n, 2) raw degrees, for plotting/partitioning
    y_raw: np.ndarray  # (n,) unstandardized field (deg C - like)


def _sphere_points(n: int, rng: np.random.Generator) -> np.ndarray:
    """Uniform points on S^2 -> (lon deg in [0,360), lat deg in [-90,90])."""
    u = rng.uniform(size=n)
    v = rng.uniform(size=n)
    lon = 360.0 * u
    lat = np.degrees(np.arcsin(2.0 * v - 1.0))
    return np.stack([lon, lat], axis=-1)


def _unit_vectors(lonlat: np.ndarray) -> np.ndarray:
    lon = np.radians(lonlat[:, 0])
    lat = np.radians(lonlat[:, 1])
    return np.stack(
        [np.cos(lat) * np.cos(lon), np.cos(lat) * np.sin(lon), np.sin(lat)], axis=-1
    )


def e3sm_like_field(
    n: int = 48602,
    seed: int = 0,
    num_features: int = 256,
    corr_length: float = 0.35,
    grf_amplitude: float = 6.0,
    noise_sd: float = 0.5,
) -> SpatialDataset:
    """Sample an E3SM-like global temperature field.

    corr_length: GRF correlation length in sphere chord units (R=1); 0.35
    gives continental-scale features similar to fig. 1's single time slice.
    """
    rng = np.random.default_rng(seed)
    lonlat = _sphere_points(n, rng)
    u = _unit_vectors(lonlat)  # (n, 3)

    # Random Fourier features: f(u) = sum a_k cos(w_k.u + phi_k) with
    # w ~ N(0, 1/corr_length^2 I) approximates a squared-exponential GRF.
    w = rng.normal(scale=1.0 / corr_length, size=(num_features, 3))
    phi = rng.uniform(0.0, 2.0 * np.pi, size=num_features)
    a = rng.normal(size=num_features) * np.sqrt(2.0 / num_features)
    grf = grf_amplitude * (np.cos(u @ w.T + phi) @ a)

    lat = lonlat[:, 1]
    trend = 32.0 * np.cos(np.radians(lat)) ** 2 - 12.0  # equator warm, poles cold
    y_raw = trend + grf + rng.normal(scale=noise_sd, size=n)

    # GP inputs: degrees scaled to O(1) so unit init lengthscales are sane.
    x = np.stack([lonlat[:, 0] / 36.0, lonlat[:, 1] / 18.0], axis=-1).astype(np.float32)
    y = ((y_raw - y_raw.mean()) / y_raw.std()).astype(np.float32)
    return SpatialDataset(x=x, y=y, lonlat=lonlat.astype(np.float32), y_raw=y_raw.astype(np.float32))


def zipf_query_stream(
    grid,
    batch: int,
    requests: int,
    *,
    alpha: float = 1.1,
    seed: int = 0,
) -> list:
    """Zipf-skewed serving query stream — the E3SM-style regional-analysis
    workload (most requests probe a few hot regions, a long tail covers
    the rest), used to exercise the two-level router.

    Cells of ``grid`` (a ``repro.core.partition.PartitionGrid``) get
    popularity ~ 1/rank^alpha under a seeded random rank permutation;
    each query picks a cell from that law and a uniform location inside
    it. ``alpha=0`` degenerates to a uniform-over-cells stream (NOT
    uniform over area — cells are equal-area here, so it is both).

    Returns ``requests`` host batches of shape (batch, 2) float32.
    """
    if alpha < 0:
        raise ValueError(f"alpha must be >= 0, got {alpha}")
    rng = np.random.default_rng(seed)
    P = grid.gx * grid.gy
    prob = 1.0 / (1.0 + np.arange(P)) ** alpha
    prob = rng.permutation(prob)  # hot cells land anywhere on the grid
    prob /= prob.sum()
    out = []
    for _ in range(requests):
        cell = rng.choice(P, size=batch, p=prob)
        cx, cy = cell % grid.gx, cell // grid.gx
        u = rng.uniform(size=(batch, 2)).astype(np.float64)
        x = grid.x_edges[cx] + u[:, 0] * (grid.x_edges[cx + 1] - grid.x_edges[cx])
        y = grid.y_edges[cy] + u[:, 1] * (grid.y_edges[cy + 1] - grid.y_edges[cy])
        out.append(np.stack([x, y], axis=-1).astype(np.float32))
    return out


def scale_lonlat(lonlat: np.ndarray) -> np.ndarray:
    """The same (lon, lat) -> GP-input scaling used by e3sm_like_field."""
    return np.stack([lonlat[..., 0] / 36.0, lonlat[..., 1] / 18.0], axis=-1).astype(np.float32)
