"""Synthetic token pipeline for the LM substrate.

Deterministic, host-sharded: each data-parallel host slice generates only
its own rows from a counter-based PRNG, so no token ever crosses hosts
(the standard "infinite synthetic corpus" used for performance work).
A light Markov structure (token t+1 depends on t) gives the training loss
something learnable so example runs show a decreasing curve.
"""
from __future__ import annotations

from collections.abc import Iterator

import numpy as np


def synthetic_token_batches(
    vocab_size: int,
    batch: int,
    seq_len: int,
    seed: int = 0,
    num_batches: int | None = None,
    start_row: int = 0,
) -> Iterator[tuple[np.ndarray, np.ndarray]]:
    """Yield (tokens, targets) of shape (batch, seq_len) int32.

    Markov chain: next = (a * cur + noise) mod V with a small noise alphabet,
    so cross-entropy has a learnable floor well below log(V).
    """
    i = 0
    while num_batches is None or i < num_batches:
        rng = np.random.default_rng((seed, start_row + i))
        cur = rng.integers(0, vocab_size, size=(batch, 1), dtype=np.int64)
        noise = rng.integers(0, 17, size=(batch, seq_len), dtype=np.int64)
        rows = [cur[:, 0]]
        for t in range(1, seq_len):
            rows.append((rows[-1] + noise[:, t]) % vocab_size)
        toks = np.stack(rows, axis=1).astype(np.int32)
        targets = np.concatenate([toks[:, 1:], toks[:, :1]], axis=1)
        yield toks, targets
        i += 1
