"""Paper fig. 4: RMSPE and boundary RMSD as a function of delta, for
m in {5, 10, 20} inducing points.

Default is a REDUCED setting sized for this CPU container (10x10 grid,
12k obs, 2 replications); ``--paper-scale`` runs the full 20x20/48.6k/10-rep
configuration (hours on one CPU, the real target is a pod).

Validation targets from the paper (§5):
  * RMSPE increases monotonically (small at low delta) with delta;
  * boundary RMSD DECREASES for delta > 0 (around -3..-5% at delta~0.125);
  * effects are largest for m = 20.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.configs.psvgp_e3sm import FULL as E3SM
from repro.core import psvgp, svgp
from repro.core.metrics import boundary_rmsd, rmspe
from repro.core.neighbors import boundary_probes
from repro.core.partition import make_grid, partition_data
from repro.data.spatial import e3sm_like_field

DELTAS = (0.0, 0.05, 0.125, 0.25, 0.5, 1.0)


def run(paper_scale: bool = False, comm: str = "gather", use_pallas: bool = False,
        out_dir: str = "benchmarks/results") -> dict:
    if paper_scale:
        n, grid_shape, ms, iters, reps, ppe = (
            E3SM.n_obs, E3SM.grid, (5, 10, 20), E3SM.iters, 10, E3SM.probes_per_edge
        )
    else:
        n, grid_shape, ms, iters, reps, ppe = 12_000, (10, 10), (5, 10), 2500, 2, 8

    # Regime note (EXPERIMENTS.md §Repro): the paper's boundary-smoothness
    # effect requires observation noise / sub-partition structure to be
    # non-negligible — with dense low-noise data the independent models
    # already agree at boundaries and neighbor sampling only dilutes the
    # m inducing points. noise_sd=2.5 gives the trade-off profile closest
    # to the paper's fig. 4 (~ -12% bRMSD for ~ +5% RMSPE at delta=0.125).
    ds = e3sm_like_field(n=n, seed=0, noise_sd=2.5)
    grid = make_grid(ds.x, *grid_shape)
    data = partition_data(ds.x, ds.y, grid)
    probes = boundary_probes(grid, probes_per_edge=ppe)
    results = []
    for m in ms:
        for delta in DELTAS:
            r_list, b_list, t_list = [], [], []
            for rep in range(reps):
                cfg = psvgp.PSVGPConfig(
                    svgp=svgp.SVGPConfig(num_inducing=m, input_dim=2, use_pallas=use_pallas),
                    delta=delta, batch_size=E3SM.batch_size,
                    learning_rate=0.05, comm=comm, seed=rep,
                )
                static = psvgp.build(cfg, data)
                state = psvgp.init(jax.random.PRNGKey(rep), cfg, data)
                t0 = time.time()
                state = psvgp.fit(static, state, data, iters)
                jax.block_until_ready(state.params.m_star)
                t_list.append(time.time() - t0)
                r_list.append(float(rmspe(static, state, data)))
                b_list.append(float(boundary_rmsd(static, state, probes)))
            rec = {
                "m": m, "delta": delta, "comm": comm,
                "rmspe": float(np.mean(r_list)), "rmspe_sd": float(np.std(r_list)),
                "boundary_rmsd": float(np.mean(b_list)), "boundary_rmsd_sd": float(np.std(b_list)),
                "fit_seconds": float(np.mean(t_list)), "iters": iters, "reps": reps,
            }
            results.append(rec)
            us = 1e6 * np.mean(t_list) / iters
            print(f"bench_delta[m={m},delta={delta}],{us:.1f},"
                  f"rmspe={rec['rmspe']:.4f};brmsd={rec['boundary_rmsd']:.4f}")
    summary = _validate(results)
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"delta_sweep_{comm}.json"), "w") as f:
        json.dump({"results": results, "validation": summary}, f, indent=2)
    return {"results": results, "validation": summary}


def _validate(results) -> dict:
    """Check the paper's qualitative claims on this run."""
    out = {}
    for m in sorted({r["m"] for r in results}):
        rows = sorted([r for r in results if r["m"] == m], key=lambda r: r["delta"])
        r0 = rows[0]  # delta = 0 == ISVGP
        best_b = min(rows, key=lambda r: r["boundary_rmsd"])
        out[f"m{m}"] = {
            "rmspe_at_0": r0["rmspe"],
            "rmspe_monotone_increasing": all(
                rows[i + 1]["rmspe"] >= rows[i]["rmspe"] - 0.01 for i in range(len(rows) - 1)
            ),
            "boundary_rmsd_at_0": r0["boundary_rmsd"],
            "best_boundary_delta": best_b["delta"],
            "boundary_improvement_pct": 100.0
            * (r0["boundary_rmsd"] - best_b["boundary_rmsd"])
            / max(r0["boundary_rmsd"], 1e-9),
            "delta_positive_improves_boundary": best_b["delta"] > 0.0,
        }
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper-scale", action="store_true")
    ap.add_argument("--comm", default="gather", choices=["gather", "ppermute"])
    ap.add_argument("--pallas", action="store_true")
    args = ap.parse_args()
    out = run(paper_scale=args.paper_scale, comm=args.comm, use_pallas=args.pallas)
    print(json.dumps(out["validation"], indent=2))


if __name__ == "__main__":
    main()
