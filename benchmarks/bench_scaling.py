"""Paper fig. 3: runtime/scaling of PSVGP.

On this single-CPU container the paper's N_proc axis is emulated by the
vmapped partition axis: one XLA program trains all partitions, so
"partitions per processor" = P here. We report:

  (a) per-iteration wall time vs delta (paper: nearly flat — the
      decentralized scheme adds almost no cost as delta grows);
  (b) weak scaling: per-iteration time as P grows at fixed per-partition
      load (paper: flat = perfect weak scaling; here the vmap width grows,
      so flat-per-partition time is the analogue);
  (c) iterations that fit the paper's in-situ budget (1 E3SM step ~ 1 s).

Distributed scaling on real hardware is proven separately by the dry-run
(collective bytes independent of P per device; see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.configs.psvgp_e3sm import FULL as E3SM
from repro.core import psvgp, svgp
from repro.core.partition import make_grid, partition_data
from repro.data.spatial import e3sm_like_field


def _time_iters(static, state, data, iters=60, warmup=10):
    for _ in range(warmup):
        state, _ = psvgp.train_step(static, state, jax.random.PRNGKey(0), data)
    jax.block_until_ready(state.params.m_star)
    t0 = time.time()
    for _ in range(iters):
        state, _ = psvgp.train_step(static, state, jax.random.PRNGKey(0), data)
    jax.block_until_ready(state.params.m_star)
    return (time.time() - t0) / iters


def run(out_dir: str = "benchmarks/results") -> dict:
    results = {"delta_sweep": [], "weak_scaling": []}

    # (a) per-iteration time vs delta at the paper's grid
    ds = e3sm_like_field(n=12_000, seed=0)
    grid = make_grid(ds.x, 10, 10)
    data = partition_data(ds.x, ds.y, grid)
    for comm in ("gather", "ppermute"):
        for delta in (0.0, 0.125, 0.25, 0.5, 1.0):
            cfg = psvgp.PSVGPConfig(
                svgp=svgp.SVGPConfig(num_inducing=5, input_dim=2),
                delta=delta, batch_size=E3SM.batch_size,
                learning_rate=E3SM.learning_rate, comm=comm,
            )
            static = psvgp.build(cfg, data)
            state = psvgp.init(jax.random.PRNGKey(0), cfg, data)
            dt = _time_iters(static, state, data)
            rec = {"comm": comm, "delta": delta, "s_per_iter": dt,
                   "iters_per_e3sm_step": int(1.0 / dt)}
            results["delta_sweep"].append(rec)
            print(f"bench_scaling[delta,{comm},{delta}],{dt*1e6:.0f},"
                  f"iters_per_budget={rec['iters_per_e3sm_step']}")

    # (b) weak scaling in P (fixed per-partition density)
    for gx in (5, 10, 20):
        P = gx * gx
        n = 120 * P  # ~paper's median 150/partition territory
        ds = e3sm_like_field(n=n, seed=1)
        grid = make_grid(ds.x, gx, gx)
        data = partition_data(ds.x, ds.y, grid)
        cfg = psvgp.PSVGPConfig(
            svgp=svgp.SVGPConfig(num_inducing=5, input_dim=2),
            delta=0.125, batch_size=E3SM.batch_size,
            learning_rate=E3SM.learning_rate, comm="gather",
        )
        static = psvgp.build(cfg, data)
        state = psvgp.init(jax.random.PRNGKey(0), cfg, data)
        dt = _time_iters(static, state, data, iters=30)
        rec = {"P": P, "n": n, "s_per_iter": dt, "s_per_iter_per_partition": dt / P}
        results["weak_scaling"].append(rec)
        print(f"bench_scaling[weak,P={P}],{dt*1e6:.0f},per_partition_us={dt/P*1e6:.2f}")

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "scaling.json"), "w") as f:
        json.dump(results, f, indent=2)
    return results


def main() -> None:
    argparse.ArgumentParser().parse_args()
    run()


if __name__ == "__main__":
    main()
