"""Bench regression gate: compare a fresh ``bench_serve --smoke`` report
against the checked-in baseline and FAIL on a large p50 regression.
A ``frontdoor`` section (``bench_frontdoor --smoke``) is auto-detected
and gated too: lowest-offered-load p95 vs its own baseline, plus the
coalesce/demux golden flag. Likewise an ``http`` section
(``bench_net --smoke``): over-the-wire golden flag, end-to-end p95, and
the wire-overhead ceiling (http p50 minus in-process p50).

CI runs this after ``make bench-serve-smoke`` (``make bench-gate`` is the
one-shot lane) so the serving pipeline's latency trajectory is enforced
per-PR, not just observed whenever someone refreshes the full benchmark.

The tolerance is deliberately loose — 2x per gated lane — because the
smoke shapes run on whatever machine CI hands us and absolute
milliseconds vary run to run; the gate exists to catch the step-function
regressions (an accidental sync point, a per-request recompile, a routing
path gone quadratic), which blow straight through 2x. Equivalence flags
in the report are re-asserted here too: a benchmark that went numerically
wrong must fail the gate even if it got faster.

  PYTHONPATH=src python -m benchmarks.check_bench_regression /tmp/BENCH_serve_smoke.json

Refresh the baseline (after an intentional perf change, commit the diff):

  PYTHONPATH=src python -m benchmarks.check_bench_regression /tmp/BENCH_serve_smoke.json --update

COMPILED costs (flops / bytes / per-device residency) are NOT gated here:
they are deterministic compiler facts, not wall-clock samples, so they
live in the static analysis layer — ``--section analysis`` prints the
pointer. Run ``python -m repro.analysis --passes costs`` (or ``make
analyze``), baselined in ``benchmarks/baselines/analysis_costs.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

BASELINE = os.path.join(os.path.dirname(__file__), "baselines", "serve_smoke.json")
FRONTDOOR_BASELINE = os.path.join(
    os.path.dirname(__file__), "baselines", "frontdoor_smoke.json"
)
SWAP_BASELINE = os.path.join(
    os.path.dirname(__file__), "baselines", "frontdoor_swap_smoke.json"
)
NET_BASELINE = os.path.join(os.path.dirname(__file__), "baselines", "net_smoke.json")

# lanes whose p50 the gate holds (path into the report, lane label)
GATED_LANES = (
    ("replicated", "replicated"),
    ("sharded_serial", "sharded serial"),
    ("sharded_pipelined", "sharded pipelined"),
)
MAX_REGRESSION = 2.0  # x over baseline p50
# Sub-millisecond lanes (replicated smoke p50 is ~0.6 ms) can exceed 2x on
# a slower CI machine generation through clock speed alone; a real
# step-function regression also moves absolute time, so the gate requires
# BOTH the ratio and an absolute excursion before failing.
ABS_SLACK_MS = 5.0


def check_frontdoor(
    rec: dict, baseline_path: str = FRONTDOOR_BASELINE, *, update: bool = False,
    label: str = "frontdoor",
) -> list[str]:
    """Gate one ``frontdoor``-shaped report section: golden bitwise flag,
    plus the LOWEST offered-load level's p95 vs the checked-in baseline
    (higher levels deliberately run the endpoint into sheds and recompiles
    — their tails measure overload behavior, not a regression signal).
    The ``frontdoor_swap`` section (hot-swap lane, docs/lifecycle.md) has
    the same shape and is gated through here too — its golden flag folds
    in the swap atomicity properties (bitwise old/new, monotone flip,
    zero sheds), so a broken swap fails the gate even if it got faster."""
    failures = []
    golden = rec.get("golden") or {}
    if not golden.get("ok"):
        failures.append(f"{label} golden gate broken: {golden}")
    level = rec["levels"][0]

    if update or not os.path.exists(baseline_path):
        os.makedirs(os.path.dirname(baseline_path), exist_ok=True)
        base = {
            "p95_ms": level["p95_ms"],
            "_source": {
                "grid": rec["grid"], "m": rec["m"], "mode": rec["mode"],
                "router": rec["router"], "backend": rec["backend"],
                "offered_qps": level["offered_qps"],
                "requests": level["requests"],
            },
        }
        with open(baseline_path, "w") as f:
            json.dump(base, f, indent=2)
            f.write("\n")
        print(f"wrote baseline {baseline_path}")
        return failures

    with open(baseline_path) as f:
        base = json.load(f)
    src = base.get("_source", {})
    for key in ("grid", "m", "mode", "router", "backend"):
        if key in src and rec.get(key) != src[key]:
            failures.append(
                f"{label} report {key}={rec.get(key)!r} does not match the "
                f"baseline's {src[key]!r} — refresh with --update in the "
                "same commit"
            )
    if "offered_qps" in src and level["offered_qps"] != src["offered_qps"]:
        failures.append(
            f"{label} gate level offered_qps={level['offered_qps']} != "
            f"baseline's {src['offered_qps']} — the p95 comparison needs a "
            "fixed offered load; refresh with --update"
        )
    got, ref = level["p95_ms"], base["p95_ms"]
    ratio = got / ref
    bad = ratio > MAX_REGRESSION and got - ref > ABS_SLACK_MS
    status = "FAIL" if bad else "OK"
    print(f"{status}: {label} p95 @ {level['offered_qps']:.0f} qps "
          f"{got:.2f} ms vs baseline {ref:.2f} ms ({ratio:.2f}x, "
          f"limit {MAX_REGRESSION:.1f}x + {ABS_SLACK_MS:.0f} ms slack)")
    if bad:
        failures.append(f"{label} p95 regressed {ratio:.2f}x")
    return failures


def check_net(
    rec: dict, baseline_path: str = NET_BASELINE, *, update: bool = False,
) -> list[str]:
    """Gate the ``http`` section (``bench_net --smoke``): the over-the-wire
    golden flag (HTTP payload bitwise == solo ``Server.submit`` on the
    sharded program), the lowest offered-load level's end-to-end p95 vs
    the checked-in baseline, AND the wire-overhead ceiling — http p50
    minus in-process p50 at the same offered schedule. The overhead gate
    is what catches a transport-layer regression (a lost keep-alive, an
    accidental copy in framing, a blocking read on the loop) that the
    end-to-end tail would blur into engine noise; same 2x-ratio +
    absolute-slack rule as every other lane."""
    failures = []
    golden = rec.get("golden") or {}
    if not golden.get("ok"):
        failures.append(f"http golden gate broken: {golden}")
    level = rec["levels"][0]

    if update or not os.path.exists(baseline_path):
        os.makedirs(os.path.dirname(baseline_path), exist_ok=True)
        base = {
            "p95_ms": level["p95_ms"],
            "wire_overhead_p50_ms": level["wire_overhead_p50_ms"],
            "_source": {
                "grid": rec["grid"], "m": rec["m"], "mode": rec["mode"],
                "router": rec["router"], "backend": rec["backend"],
                "offered_qps": level["offered_qps"],
                "requests": level["requests"],
            },
        }
        with open(baseline_path, "w") as f:
            json.dump(base, f, indent=2)
            f.write("\n")
        print(f"wrote baseline {baseline_path}")
        return failures

    with open(baseline_path) as f:
        base = json.load(f)
    src = base.get("_source", {})
    for key in ("grid", "m", "mode", "router", "backend"):
        if key in src and rec.get(key) != src[key]:
            failures.append(
                f"http report {key}={rec.get(key)!r} does not match the "
                f"baseline's {src[key]!r} — refresh with --update in the "
                "same commit"
            )
    if "offered_qps" in src and level["offered_qps"] != src["offered_qps"]:
        failures.append(
            f"http gate level offered_qps={level['offered_qps']} != "
            f"baseline's {src['offered_qps']} — refresh with --update"
        )
    gates = (
        ("http p95", level["p95_ms"], base["p95_ms"]),
        ("wire overhead p50", level["wire_overhead_p50_ms"],
         base["wire_overhead_p50_ms"]),
    )
    for name, got, ref in gates:
        # overhead can be sub-ms and noisy (even negative under jitter);
        # floor both sides so the ratio stays meaningful
        got_f, ref_f = max(got, 0.01), max(ref, 0.01)
        ratio = got_f / ref_f
        bad = ratio > MAX_REGRESSION and got - ref > ABS_SLACK_MS
        status = "FAIL" if bad else "OK"
        print(f"{status}: {name} @ {level['offered_qps']:.0f} qps "
              f"{got:.2f} ms vs baseline {ref:.2f} ms ({ratio:.2f}x, "
              f"limit {MAX_REGRESSION:.1f}x + {ABS_SLACK_MS:.0f} ms slack)")
        if bad:
            failures.append(f"{name} regressed {ratio:.2f}x")
    return failures


def check(report_path: str, baseline_path: str = BASELINE, *, update: bool = False,
          frontdoor_baseline: str = FRONTDOOR_BASELINE,
          swap_baseline: str = SWAP_BASELINE,
          net_baseline: str = NET_BASELINE) -> int:
    with open(report_path) as f:
        rec = json.load(f)

    # an endpoint-only report (bench_frontdoor / bench_net --out <fresh
    # file>): gate just those sections
    if "replicated" not in rec:
        if not any(k in rec for k in ("frontdoor", "frontdoor_swap", "http")):
            print("FAIL: report has neither serve lanes nor a "
                  "frontdoor/http section")
            return 1
        failures = []
        if "frontdoor" in rec:
            failures += check_frontdoor(
                rec["frontdoor"], frontdoor_baseline, update=update
            )
        if "frontdoor_swap" in rec:
            failures += check_frontdoor(
                rec["frontdoor_swap"], swap_baseline, update=update,
                label="frontdoor_swap",
            )
        if "http" in rec:
            failures += check_net(rec["http"], net_baseline, update=update)
        for msg in failures:
            print(f"FAIL: {msg}")
        if not failures:
            print("bench gate passed")
        return 1 if failures else 0

    failures = []
    if "frontdoor" in rec:
        failures += check_frontdoor(
            rec["frontdoor"], frontdoor_baseline, update=update
        )
    if "frontdoor_swap" in rec:
        failures += check_frontdoor(
            rec["frontdoor_swap"], swap_baseline, update=update,
            label="frontdoor_swap",
        )
    if "http" in rec:
        failures += check_net(rec["http"], net_baseline, update=update)
    eq = rec.get("equivalence", {})
    if not eq.get("atol_1e5_ok"):
        failures.append(f"equivalence gate broken: {eq}")
    if not eq.get("pipelined_bitwise_serial"):
        failures.append("pipelined results no longer bitwise == serial")
    skew = rec.get("skew")
    if skew:
        if not skew["equivalence"].get("atol_1e5_ok"):
            failures.append(f"skew-lane equivalence broken: {skew['equivalence']}")
        if skew["waste_reduction_vs_single"] < 2.0:
            failures.append(
                "two-level router no longer cuts padded-row waste >= 2x "
                f"(got {skew['waste_reduction_vs_single']:.2f}x)"
            )

    if update or not os.path.exists(baseline_path):
        os.makedirs(os.path.dirname(baseline_path), exist_ok=True)
        base = {
            lane: {"p50_ms": rec[lane]["p50_ms"]} for lane, _ in GATED_LANES
        }
        base["_source"] = {
            "grid": rec["grid"], "m": rec["m"], "batch": rec["batch"],
            "backend": rec["backend"],
        }
        with open(baseline_path, "w") as f:
            json.dump(base, f, indent=2)
            f.write("\n")
        print(f"wrote baseline {baseline_path}")
        for msg in failures:
            print(f"FAIL: {msg}")
        return 1 if failures else 0

    with open(baseline_path) as f:
        base = json.load(f)
    src = base.get("_source", {})
    for key in ("grid", "m", "batch", "backend"):
        if key in src and rec.get(key) != src[key]:
            failures.append(
                f"report {key}={rec.get(key)!r} does not match the baseline's "
                f"{src[key]!r} — the smoke shapes changed; refresh the "
                "baseline with --update in the same commit"
            )
    for lane, label in GATED_LANES:
        got = rec[lane]["p50_ms"]
        ref = base[lane]["p50_ms"]
        ratio = got / ref
        bad = ratio > MAX_REGRESSION and got - ref > ABS_SLACK_MS
        status = "FAIL" if bad else "OK"
        print(f"{status}: {label} p50 {got:.2f} ms vs baseline {ref:.2f} ms "
              f"({ratio:.2f}x, limit {MAX_REGRESSION:.1f}x + {ABS_SLACK_MS:.0f} ms slack)")
        if bad:
            failures.append(f"{label} p50 regressed {ratio:.2f}x")
    for msg in failures:
        print(f"FAIL: {msg}")
    if not failures:
        print("bench gate passed")
    return 1 if failures else 0


ANALYSIS_NOTE = """\
compiled-cost regressions are gated STATICALLY, not by this benchmark:
  PYTHONPATH=src python -m repro.analysis --passes costs    # or: make analyze
diffs every AOT-compiled lane's flops / bytes-accessed / per-device
residency against benchmarks/baselines/analysis_costs.json (exponent
budgets + absolute ceilings + drift tolerance) with no timing noise.
Refresh after an intentional change with --update-baselines and commit
the JSON, exactly like --update does for the wall-clock baselines here."""


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("report", nargs="?",
                    help="fresh bench_serve --smoke JSON to gate")
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--frontdoor-baseline", default=FRONTDOOR_BASELINE)
    ap.add_argument("--swap-baseline", default=SWAP_BASELINE)
    ap.add_argument("--net-baseline", default=NET_BASELINE)
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from this report instead of gating")
    ap.add_argument("--section", choices=("serve", "analysis"), default="serve",
                    help="'serve' gates the wall-clock report; 'analysis' "
                    "points at the static compiled-cost gate")
    args = ap.parse_args()
    if args.section == "analysis":
        print(ANALYSIS_NOTE)
        sys.exit(0)
    if args.report is None:
        ap.error("report path required for --section serve")
    sys.exit(check(args.report, args.baseline, update=args.update,
                   frontdoor_baseline=args.frontdoor_baseline,
                   swap_baseline=args.swap_baseline,
                   net_baseline=args.net_baseline))


if __name__ == "__main__":
    main()
