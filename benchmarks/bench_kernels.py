"""Kernel micro-benchmarks: Pallas SVGP projection vs the unfused reference.

On CPU the Pallas kernels execute in interpret mode (Python), so WALL TIME
of the kernel path is not meaningful here — what this bench reports is:

  (a) numerical agreement (max |err|) across paper-relevant shapes;
  (b) the structural win of fusion, derived from cost_analysis of the
      UNFUSED reference: bytes that the fused kernel does not round-trip
      through HBM (the knm re-read — DESIGN.md §6), i.e. the memory-term
      delta the roofline attributes to the kernel on TPU;
  (c) wall time of the jnp reference path (the actual CPU execution used
      by the benchmarks), for regression tracking.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

SHAPES = [(32, 5, 2), (32, 20, 2), (256, 128, 2), (1024, 128, 3)]


def run(out_dir: str = "benchmarks/results") -> list:
    results = []
    for B, m, d in SHAPES:
        key = jax.random.PRNGKey(B + m)
        kx, kz, kl = jax.random.split(key, 3)
        x = jax.random.normal(kx, (B, d))
        z = jax.random.normal(kz, (m, d))
        lls = 0.3 * jax.random.normal(kl, (d,))
        lv = jnp.asarray(0.1)
        kmm = ref.rbf_cross_cov(z, z, lls, lv) + 1e-4 * jnp.eye(m)
        lmm = jnp.linalg.cholesky(kmm)

        got = ops.svgp_projection(x, z, lls, lv, lmm)
        want = ops.svgp_projection_ref(x, z, lls, lv, lmm)
        err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(got, want, strict=True))

        # unfused reference: knm written to HBM then re-read for projection
        ref_fn = jax.jit(lambda *a: ops.svgp_projection_ref(*a))
        from repro.runtime import compat

        c = ref_fn.lower(x, z, lls, lv, lmm).compile()
        ca = compat.cost_analysis(c)
        # fused kernel skips one HBM write+read of knm (B x m fp32)
        knm_bytes = B * m * 4
        t0 = time.time()
        for _ in range(20):
            out = ref_fn(x, z, lls, lv, lmm)
        jax.block_until_ready(out)
        us = (time.time() - t0) / 20 * 1e6
        rec = {
            "B": B, "m": m, "d": d, "max_abs_err": err,
            "ref_flops": float(ca.get("flops", 0)),
            "ref_bytes": float(ca.get("bytes accessed", 0)),
            "fusion_bytes_saved": 2 * knm_bytes,
            "ref_us_per_call_cpu": us,
        }
        results.append(rec)
        print(f"bench_kernels[B={B},m={m},d={d}],{us:.1f},"
              f"err={err:.2e};bytes_saved={2*knm_bytes}")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "kernels.json"), "w") as f:
        json.dump(results, f, indent=2)
    return results


def main() -> None:
    argparse.ArgumentParser().parse_args()
    run()


if __name__ == "__main__":
    main()
