"""Benchmark orchestrator — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines per benchmark (plus JSON
artifacts under benchmarks/results/).

  PYTHONPATH=src python -m benchmarks.run            # default (CPU-sized)
  PYTHONPATH=src python -m benchmarks.run --section delta
  PYTHONPATH=src python -m benchmarks.run --paper-scale   # full fig. 3/4
"""
from __future__ import annotations

import argparse
import json
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", default="all",
                    choices=["all", "delta", "scaling", "kernels", "roofline"])
    ap.add_argument("--paper-scale", action="store_true")
    args = ap.parse_args()

    t0 = time.time()
    print("# name,us_per_call,derived")

    if args.section in ("all", "kernels"):
        print("## bench_kernels — Pallas SVGP kernels vs oracle (DESIGN.md §6)")
        from benchmarks import bench_kernels

        bench_kernels.run()

    if args.section in ("all", "scaling"):
        print("## bench_scaling — paper fig. 3 (runtime / weak scaling)")
        from benchmarks import bench_scaling

        bench_scaling.run()

    if args.section in ("all", "delta"):
        print("## bench_delta — paper fig. 4 (RMSPE & boundary RMSD vs delta)")
        from benchmarks import bench_delta

        out = bench_delta.run(paper_scale=args.paper_scale)
        print(json.dumps(out["validation"], indent=2))

    if args.section in ("all", "roofline"):
        print("## roofline — dry-run derived terms (EXPERIMENTS.md §Roofline)")
        jsonl = "dryrun_single_pod.jsonl"
        if os.path.exists(jsonl):
            from benchmarks import roofline

            recs = roofline.load(jsonl)
            for r in recs:
                t = r["roofline_s"]
                print(f"roofline[{r['config_name']},{r['shape']}],"
                      f"{max(t.values())*1e6:.0f},dominant={r['dominant']}")
        else:
            print(f"(skipped: {jsonl} not present — run repro.launch.dryrun --all)")

    print(f"# total bench time: {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
