"""Wire benchmark — the front-door SLO curve measured over real sockets.

``bench_frontdoor`` measures the continuous-batching endpoint in-process:
client coroutine -> ``FrontDoor.submit`` -> future. This lane puts the
actual transport in front of it (``repro.net``: msgpack frames over
HTTP/1.1 on localhost TCP) and answers the ROADMAP's open question: what
does the wire add to the tail once arrivals carry genuine network jitter?

Per offered-QPS level the SAME seeded Poisson schedule (same request
sizes, same points, same arrival offsets) is driven twice:

  in-process   a fresh ``api.FrontDoor`` on the loop, exactly the
               bench_frontdoor shape — the transport-free reference;
  http         a fresh ``repro.net.NetServer`` (its own FrontDoor over
               the same ``api.Server``) with a pool of persistent
               ``AsyncNetClient`` connections driving the schedule over
               127.0.0.1 sockets, shed-on-full like the reference
               (429s are counted as shed, not retried).

The deliverable is the WIRE-OVERHEAD column: http p50 minus in-process
p50 at the same offered load — serialization + socket + HTTP framing,
everything the transport adds on top of the engine. The response frames'
server-side timing breakdown (decode/engine/total) is averaged per level
so the overhead can be split into server-side framing vs socket transit.

Golden gate (lowest level): every HTTP response payload must be BITWISE
equal to serving the same request alone through ``Server.submit`` — over
the sharded fixed-shape program the wire adds transport, never math (raw
float32 bytes on the wire make serialization an exact round-trip).

The record merges into BENCH_serve.json as the ``http`` section, gated
like ``frontdoor`` by ``check_bench_regression``: golden ok + lowest
level p95 + wire-overhead ceiling vs benchmarks/baselines/net_smoke.json
(same 2x ratio + 5 ms absolute slack rule).

  PYTHONPATH=src python -m benchmarks.bench_net           # merge into BENCH_serve.json
  PYTHONPATH=src python -m benchmarks.bench_net --quick   # CI-sized
  PYTHONPATH=src python -m benchmarks.bench_net --smoke   # seconds (the gated lane)
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import time

import numpy as np


def _schedule(rng, grid, *, n_req: int, max_rows: int, qps: float):
    """One open-loop level: request point sets + Poisson arrival offsets.
    Seeded ONCE per level and shared verbatim by the in-process and the
    HTTP runs — the wire-overhead column only means something if both
    runs answer the identical offered stream."""
    lo = np.array([grid.x_edges[0], grid.y_edges[0]])
    hi = np.array([grid.x_edges[-1], grid.y_edges[-1]])
    sizes = rng.integers(1, max_rows + 1, n_req)
    reqs = [rng.uniform(lo, hi, (int(s), 2)).astype(np.float32) for s in sizes]
    arrivals = np.cumsum(rng.exponential(1.0 / qps, n_req))
    return reqs, arrivals


def _percentiles(lat_s: list) -> dict:
    ms = np.sort(np.asarray(lat_s)) * 1e3
    if not ms.size:
        return {"p50_ms": float("nan"), "p95_ms": float("nan"), "p99_ms": float("nan")}
    return {
        "p50_ms": float(np.percentile(ms, 50)),
        "p95_ms": float(np.percentile(ms, 95)),
        "p99_ms": float(np.percentile(ms, 99)),
    }


def _run_inproc(api, server, fd_cfg, reqs, arrivals) -> tuple[dict, list]:
    """The transport-free reference: the bench_frontdoor drive, on the
    shared schedule."""

    async def client(fd, i, lat):
        await asyncio.sleep(float(arrivals[i]))
        t0 = time.perf_counter()
        try:
            out = await fd.submit(reqs[i])
        except api.RequestRejected:
            return None
        lat.append(time.perf_counter() - t0)
        return out

    async def drive():
        lat: list = []
        t0 = time.perf_counter()
        async with api.FrontDoor(server, fd_cfg) as fd:
            got = await asyncio.gather(*(client(fd, i, lat) for i in range(len(reqs))))
        return got, lat, fd.report(), time.perf_counter() - t0

    got, lat, rep, wall = asyncio.run(drive())
    r = rep["requests"]
    level = {
        "completed": r["completed"],
        "shed": r["shed"],
        "recompiles": rep["recompiles"],
        **_percentiles(lat),
        "achieved_qps": r["completed"] / wall if wall > 0 else 0.0,
    }
    return level, got


def _run_http(server, net_cfg, fd_cfg, reqs, arrivals, *, conns: int):
    """The same schedule over real localhost sockets: a NetServer (its
    own FrontDoor over the same api.Server) and a pool of persistent
    async clients. 429s count as shed — no retries, so completed/shed
    are comparable with the in-process reference."""
    from repro.net.client import AsyncNetClient, RetryPolicy, ServerError
    from repro.net.server import NetServer

    async def drive():
        lat: list = []
        timing = np.zeros(3)
        got: list = [None] * len(reqs)
        shed = 0
        async with NetServer(server, net_cfg, fd_cfg) as ns:
            pool: asyncio.LifoQueue = asyncio.LifoQueue()
            clients = [
                AsyncNetClient(
                    "127.0.0.1", ns.port, seed=k,
                    retry=RetryPolicy(max_attempts=1),
                )
                for k in range(min(conns, len(reqs)))
            ]
            for c in clients:
                pool.put_nowait(c)

            async def one(i):
                nonlocal shed
                await asyncio.sleep(float(arrivals[i]))
                t0 = time.perf_counter()  # offered: conn wait is queueing
                c = await pool.get()
                try:
                    resp = await c.predict(reqs[i], request_id=f"r{i}")
                except ServerError as err:
                    if err.frame.code != "shed":
                        raise
                    shed += 1
                    return
                finally:
                    pool.put_nowait(c)
                lat.append(time.perf_counter() - t0)
                timing[:] += resp.timing_ms
                got[i] = (resp.mean(), resp.var())

            t0 = time.perf_counter()
            await asyncio.gather(*(one(i) for i in range(len(reqs))))
            wall = time.perf_counter() - t0
            rep = ns.slo()
            for c in clients:
                await c.close()
        return got, lat, shed, timing, rep, wall

    got, lat, shed, timing, rep, wall = asyncio.run(drive())
    n_ok = len(lat)
    level = {
        "completed": n_ok,
        "shed": shed,
        "recompiles": rep["recompiles"],
        **_percentiles(lat),
        "achieved_qps": n_ok / wall if wall > 0 else 0.0,
        "server_timing_mean_ms": (
            dict(zip(("decode_ms", "engine_ms", "total_ms"), (timing / n_ok).tolist()))
            if n_ok
            else None
        ),
    }
    return level, got


def run(
    *,
    grid_side: int = 4,
    m: int = 6,
    n_train: int = 4000,
    train_iters: int = 200,
    qps_levels: tuple = (50.0, 100.0, 200.0),
    requests_per_level: int = 80,
    mode: str = "sharded",
    router: str = "two-level",
    max_wait_ms: float = 2.0,
    max_rows: int = 1024,
    queue_depth: int = 256,
    conns: int = 16,
    golden_checks: int = 10,
    out_path: str = "BENCH_serve.json",
) -> dict:
    # virtual devices must be forced before any jax computation
    from repro.launch import serve_sharded as ss

    if mode == "sharded":
        ss.ensure_host_devices(grid_side * grid_side)

    import jax

    from repro import api

    print(f"# bench_net: grid={grid_side}x{grid_side} m={m} mode={mode} "
          f"router={router} levels={list(qps_levels)} conns={conns} "
          f"backend={jax.default_backend()}")
    ds, fitted = ss.train_demo_surface(
        seed=0, n=n_train, grid_side=grid_side, m=m, train_iters=train_iters,
    )
    serve_cfg = api.ServeConfig(
        mode=mode, pipeline="pipelined" if mode == "sharded" else "serial",
        router=router if mode == "sharded" else "single", backend="ref",
    )
    server = api.Server(fitted, serve_cfg)
    # same warm policy as bench_frontdoor: one tiny request compiles the
    # smallest program; q_max growth under load is part of the measurement
    server.submit(np.array([[ds.x[:, 0].mean(), ds.x[:, 1].mean()]], np.float32))

    fd_cfg = api.FrontDoorConfig(
        max_wait_ms=max_wait_ms, max_rows=max_rows,
        queue_depth=queue_depth, admission="shed",
    )
    net_cfg = api.NetConfig(port=0)  # OS-assigned localhost port per level

    levels = []
    golden = None
    for k, qps in enumerate(qps_levels):
        rng = np.random.default_rng(100 + k)
        reqs, arrivals = _schedule(
            rng, fitted.grid, n_req=requests_per_level,
            max_rows=fd_cfg.max_request_rows, qps=float(qps),
        )
        inproc, _ = _run_inproc(api, server, fd_cfg, reqs, arrivals)
        http, got = _run_http(
            server, net_cfg, fd_cfg, reqs, arrivals, conns=conns
        )
        level = {
            "offered_qps": float(qps),
            "requests": requests_per_level,
            "completed": http["completed"],
            "shed": http["shed"],
            "recompiles": http["recompiles"],
            "p50_ms": http["p50_ms"],
            "p95_ms": http["p95_ms"],
            "p99_ms": http["p99_ms"],
            "achieved_qps": http["achieved_qps"],
            "server_timing_mean_ms": http["server_timing_mean_ms"],
            "inproc_p50_ms": inproc["p50_ms"],
            "inproc_p95_ms": inproc["p95_ms"],
            "inproc_completed": inproc["completed"],
            "wire_overhead_p50_ms": http["p50_ms"] - inproc["p50_ms"],
            "wire_overhead_p95_ms": http["p95_ms"] - inproc["p95_ms"],
        }
        levels.append(level)
        print(f"  qps={qps:>7.1f}: http p50={level['p50_ms']:7.2f} ms "
              f"(in-proc {level['inproc_p50_ms']:7.2f} ms, wire "
              f"+{level['wire_overhead_p50_ms']:.2f} ms) "
              f"completed={level['completed']}/{level['requests']} "
              f"shed={level['shed']}")
        if k == 0:
            # golden gate over the wire: HTTP payload == solo Server.submit.
            # Sharded: BITWISE (fixed-shape program + raw-f32 frames).
            # Replicated: float32-exact (XLA re-specializes per shape).
            strict = mode == "sharded"
            checked, ok, max_err = 0, True, 0.0
            for q, out in zip(reqs, got):
                if out is None or checked >= golden_checks:
                    continue
                ms, vs = server.submit(q)
                if strict:
                    ok = ok and np.array_equal(out[0], ms) \
                        and np.array_equal(out[1], vs)
                else:
                    err = max(float(np.abs(out[0] - ms).max()),
                              float(np.abs(out[1] - vs).max()))
                    max_err = max(max_err, err)
                    ok = ok and err <= 1e-5
                checked += 1
            golden = {
                "checked": checked, "mode": mode, "ok": bool(ok),
                "bitwise_ok": bool(ok) if strict else None,
                "max_abs_err": None if strict else max_err,
            }
            if not ok:
                raise SystemExit(
                    "GOLDEN GATE FAILED: HTTP response payloads differ "
                    "from solo Server.submit"
                )

    rec = {
        "grid": f"{grid_side}x{grid_side}",
        "m": m,
        "mode": mode,
        "router": router,
        "backend": jax.default_backend(),
        "requests_per_level": requests_per_level,
        "conns": conns,
        "serve_config": serve_cfg.to_dict(),
        "frontdoor_config": fd_cfg.to_dict(),
        "net_config": net_cfg.to_dict(),
        "fit_config": fitted.config.to_dict(),
        "levels": levels,
        "golden": golden,
        "qmax_policy": server.policy.stats() if server.policy else None,
    }

    # merge into the bench_serve report: the wire is one more lane of the
    # same serving story
    data = {}
    if os.path.exists(out_path):
        with open(out_path) as f:
            data = json.load(f)
    data["http"] = rec
    print(json.dumps(rec, indent=2))
    with open(out_path, "w") as f:
        json.dump(data, f, indent=2)
    print(f"merged http section into {out_path}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized shapes (4x4 mesh, 3 levels)")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale shapes (3x3 mesh) — the regression "
                         "smoke lane (make bench-gate)")
    ap.add_argument("--mode", choices=("sharded", "replicated"),
                    default="sharded",
                    help="serve mode behind the endpoint (default: sharded — "
                         "the bitwise golden lane)")
    ap.add_argument("--router", choices=("single", "two-level"),
                    default="two-level",
                    help="sharded router policy (default: two-level)")
    ap.add_argument("--out", default="BENCH_serve.json",
                    help="bench_serve report to merge the http section into "
                         "(created if missing)")
    args = ap.parse_args()
    if args.smoke:
        run(grid_side=3, m=5, n_train=1200, train_iters=150,
            qps_levels=(25.0, 50.0, 100.0), requests_per_level=40,
            mode=args.mode, router=args.router, conns=8, out_path=args.out)
    elif args.quick:
        run(grid_side=4, m=6, n_train=4000, train_iters=200,
            qps_levels=(50.0, 100.0, 200.0), requests_per_level=60,
            mode=args.mode, router=args.router, out_path=args.out)
    else:
        run(qps_levels=(50.0, 100.0, 200.0, 400.0),
            requests_per_level=120, mode=args.mode, router=args.router,
            out_path=args.out)


if __name__ == "__main__":
    main()
